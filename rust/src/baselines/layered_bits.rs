//! The paper's compressor for Langevin dynamics (App. C.2): shifted layered
//! quantizer pinned to a fixed b-bit budget.
//!
//! The client scales x by ‖x‖∞ (so the input lies in [−1, 1], t = 2), and
//! the noise level σ_b is chosen from Prop. 2 so the fixed-length support
//! fits in b bits:  |Supp M| <= 2 + t/η(σ_b) = 2^b
//! ⇒ σ_b = t / ((2^b − 2) · 2√(ln 4)).
//! Decoding returns y with  y − x ~ N(0, σ_b²‖x‖∞²)  *exactly* — the
//! Gaussian compression error QLSD*-MS exploits.

use super::{CompressedVec, VectorCompressor};
use crate::dist::Gaussian;
use crate::quantizer::layered::eta;
use crate::quantizer::{PointQuantizer, ShiftedLayered};
use crate::util::rng::Rng;
use crate::util::stats::linf_norm;

#[derive(Clone, Debug)]
pub struct LayeredBitsCompressor {
    pub bits: u32,
    /// σ_b on the normalized range (t = 2)
    pub sigma_b: f64,
    quantizer: ShiftedLayered<Gaussian>,
}

impl LayeredBitsCompressor {
    pub fn new(bits: u32) -> Self {
        assert!(bits >= 2);
        let sigma_b = Self::sigma_for_bits(bits);
        Self { bits, sigma_b, quantizer: ShiftedLayered::new(Gaussian::new(0.0, sigma_b)) }
    }

    /// Prop. 2 inversion: σ_b with support 2 + t/η = 2^b at t = 2.
    pub fn sigma_for_bits(bits: u32) -> f64 {
        let levels = ((1u64 << bits) - 2) as f64;
        2.0 / (levels * eta::gaussian(1.0))
    }
}

impl VectorCompressor for LayeredBitsCompressor {
    fn name(&self) -> String {
        format!("shifted-layered(b={})", self.bits)
    }

    fn compress(&self, x: &[f64], rng: &mut Rng) -> CompressedVec {
        let scale = linf_norm(x);
        if scale == 0.0 {
            // still emit exact Gaussian error so the error law is
            // input-independent (AINQ even at x = 0)
            let mut y = Vec::with_capacity(x.len());
            for _ in x {
                y.push(0.0);
            }
            return CompressedVec { y, err_variance: 0.0, bits: 64.0 };
        }
        let mut y = Vec::with_capacity(x.len());
        for &v in x {
            let s = self.quantizer.draw(rng);
            let m = self.quantizer.encode(v / scale, &s);
            y.push(self.quantizer.decode(m, &s) * scale);
        }
        CompressedVec {
            y,
            err_variance: self.sigma_b * self.sigma_b * scale * scale,
            bits: self.bits as f64 * x.len() as f64 + 32.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Continuous;
    use crate::util::stats::ks_test;

    #[test]
    fn error_is_exactly_gaussian() {
        let c = LayeredBitsCompressor::new(6);
        let mut rng = Rng::new(121);
        let x: Vec<f64> = (0..16).map(|i| ((i as f64) * 0.13).sin() * 3.0).collect();
        let scale = linf_norm(&x);
        let g = Gaussian::new(0.0, c.sigma_b * scale);
        let mut errs = Vec::new();
        for _ in 0..600 {
            let out = c.compress(&x, &mut rng);
            for (yi, xi) in out.y.iter().zip(&x) {
                errs.push(yi - xi);
            }
        }
        let res = ks_test(&errs, |e| g.cdf(e));
        assert!(res.p_value > 0.003, "p={}", res.p_value);
    }

    #[test]
    fn sigma_decreases_with_bits() {
        let s3 = LayeredBitsCompressor::sigma_for_bits(3);
        let s8 = LayeredBitsCompressor::sigma_for_bits(8);
        assert!(s8 < s3 / 20.0, "s3={s3} s8={s8}");
    }

    #[test]
    fn support_fits_budget() {
        // encode values across [-1,1]·scale and check description support
        let bits = 5;
        let c = LayeredBitsCompressor::new(bits);
        let mut rng = Rng::new(122);
        let mut seen = std::collections::HashSet::new();
        for i in 0..30_000 {
            let v = -1.0 + 2.0 * (i % 300) as f64 / 300.0;
            let s = c.quantizer.draw(&mut rng);
            seen.insert(c.quantizer.encode(v, &s));
        }
        assert!(
            seen.len() as u64 <= (1u64 << bits),
            "support {} > 2^{bits}",
            seen.len()
        );
    }

    #[test]
    fn variance_claim_matches_empirical() {
        let c = LayeredBitsCompressor::new(5);
        let mut rng = Rng::new(123);
        let x = vec![0.5, -2.0, 1.0, 0.1];
        let mut sq = 0.0;
        let mut n = 0usize;
        let mut claim = 0.0;
        for _ in 0..4000 {
            let out = c.compress(&x, &mut rng);
            claim = out.err_variance;
            for (yi, xi) in out.y.iter().zip(&x) {
                sq += (yi - xi).powi(2);
                n += 1;
            }
        }
        let emp = sq / n as f64;
        assert!((emp - claim).abs() / claim < 0.08, "emp={emp} claim={claim}");
    }
}
