//! Batched multi-round transport sessions: open once, aggregate a window
//! of W rounds, unmask once.
//!
//! The paper's aggregation schemes are built for *repeated* FL rounds, but
//! a naive deployment re-opens the masking session — pairwise agreement,
//! per-round mask derivation, one channel handshake per round — every
//! round, which dominates transport cost in high-frequency FL. A
//! [`TransportSession`] amortizes that: it opens the transport once per
//! window of W rounds, derives every round's transport randomness (for
//! [`crate::mechanisms::pipeline::SecAgg`], the ℤ_m mask schedule of
//! [`crate::secagg::session_mask_root`]) from a single *session seed* via
//! the seeded-PRNG stream derivation of [`crate::util::rng::Rng::derive`],
//! folds incoming per-round [`TransportPartial`]s into a ring of W
//! per-round accumulators — still O(d) server state per in-flight round
//! for the summing transports — and closes with one batched unmask.
//!
//! Three invariants, all tested:
//!
//! * **W=1 is the single-round path.** [`crate::mechanisms::pipeline::run_pipeline`]
//!   delegates to a
//!   one-round session, so ordinary `aggregate(xs, seed)` calls are the
//!   W=1 special case of this module, not a parallel implementation.
//! * **Windowed ≡ independent.** A W-round windowed session over any
//!   transport is bit-identical to W independent rounds over
//!   [`crate::mechanisms::pipeline::Plain`]
//!   (for sum-decodable mechanisms) — the session changes *when* masks are
//!   derived and *when* rounds close, never the decoded values.
//! * **Interrupted sessions fail closed.** [`TransportSession::close`]
//!   refuses to unmask anything unless *every* round of the window
//!   received *every* client's submission: a session torn down mid-window
//!   surfaces no partial payloads.
//!
//! The coordinator drives the same object from its worker shards
//! ([`crate::coordinator::runtime::run_rounds_encoded`]): shards encode
//! their clients for all W rounds and ship per-round partials; the
//! orchestrator folds them into the session ring and batch-decodes.

use std::sync::Arc;

use super::pipeline::{
    ClientEncoder, Descriptions, Payload, ServerDecoder, SharedRound, Transport, TransportPartial,
};
use super::traits::{BitsAccount, RoundOutput};
use crate::util::rng::Rng;

/// Maximum rounds per session window. Bounds in-flight server state at
/// W·O(d) and matches the pipeline's round-cache capacity, so mechanisms
/// with cached per-round derived state (CSGM subsample matrices, DDG
/// rotations) never thrash their cache mid-window.
pub const MAX_WINDOW: usize = super::pipeline::ROUND_CACHE_CAP;

/// Stream tag separating window session seeds from every other derivation
/// of the coordinator root seed.
const SESSION_SEED_STREAM: u64 = 0xBA7C_4ED5_E551_0000;

/// Derive the session seed for the window starting at `start_round` from
/// the run's root seed. Deterministic and collision-separated from the
/// per-round and per-client streams, so re-running a window re-derives the
/// identical mask schedule.
pub fn derive_session_seed(root_seed: u64, start_round: u64) -> u64 {
    Rng::derive(root_seed, SESSION_SEED_STREAM ^ start_round).next_u64()
}

/// The per-round transports of a session: round r of the window runs over
/// [`Transport::for_session_round`]`(session_seed, r)`. Shared by the
/// session itself and by coordinator shards, which must mask with the
/// exact same schedule the orchestrator unmasks.
pub fn session_round_transports(
    transport: &dyn Transport,
    session_seed: u64,
    window: usize,
) -> Vec<Arc<dyn Transport>> {
    (0..window).map(|r| transport.for_session_round(session_seed, r as u64)).collect()
}

/// One in-flight round of the window: its accumulator, bit accounting and
/// submission tracking (the fail-closed gate).
struct RoundSlot {
    partial: TransportPartial,
    bits: BitsAccount,
    submitted: usize,
    /// which clients submitted directly — duplicate submits must not be
    /// able to impersonate a missing client's count
    seen: Vec<bool>,
    /// whether this round received pre-folded shard partials; folds and
    /// direct submits must not mix (a fold cannot mark `seen`, so mixing
    /// would let a duplicate client slip past the fail-closed count)
    folded: bool,
}

/// A transport opened once for a window of W rounds (see the module docs).
///
/// Lifecycle: [`open`](Self::open) fixes the window shape and derives the
/// per-round transport schedule from the session seed; clients (or shard
/// partials) stream in via [`submit`](Self::submit) /
/// [`fold_partial`](Self::fold_partial) in any round order; a single
/// [`close`](Self::close) unmasks every round at once — or panics if any
/// round is incomplete, surfacing nothing.
pub struct TransportSession {
    n_clients: usize,
    rounds: Vec<SharedRound>,
    transports: Vec<Arc<dyn Transport>>,
    slots: Vec<RoundSlot>,
}

impl TransportSession {
    /// Open a session for a window of `round_seeds.len()` rounds (at most
    /// [`MAX_WINDOW`]) of shape (`n_clients`, `dim`). `round_seeds[r]` is
    /// round r's shared-randomness seed (what encoders and decoders
    /// consume); the separate `session_seed` drives only the transport's
    /// session schedule.
    pub fn open(
        transport: &dyn Transport,
        session_seed: u64,
        n_clients: usize,
        dim: usize,
        round_seeds: &[u64],
    ) -> Self {
        assert!(!round_seeds.is_empty(), "a session window needs at least one round");
        assert!(
            round_seeds.len() <= MAX_WINDOW,
            "session window of {} rounds exceeds MAX_WINDOW ({MAX_WINDOW}) — split the run \
             into multiple windows",
            round_seeds.len(),
        );
        assert!(n_clients > 0, "need at least one client");
        let transports = session_round_transports(transport, session_seed, round_seeds.len());
        let rounds: Vec<SharedRound> =
            round_seeds.iter().map(|&s| SharedRound::new(s, n_clients, dim)).collect();
        let slots = rounds
            .iter()
            .zip(&transports)
            .map(|(round, t)| RoundSlot {
                partial: t.empty(round),
                bits: BitsAccount::default(),
                submitted: 0,
                seen: vec![false; n_clients],
                folded: false,
            })
            .collect();
        Self { n_clients, rounds, transports, slots }
    }

    /// Number of rounds in the window.
    pub fn window(&self) -> usize {
        self.rounds.len()
    }

    /// Round r's public context (what encoders/decoders take).
    pub fn round(&self, r: usize) -> &SharedRound {
        &self.rounds[r]
    }

    /// Round r's rekeyed transport — what a remote encoder (e.g. a
    /// coordinator shard) must mask with so the batched unmask cancels.
    pub fn round_transport(&self, r: usize) -> &Arc<dyn Transport> {
        &self.transports[r]
    }

    /// Fold one client's message into round r of the ring. Panics on a
    /// duplicate submission — a client submitting twice must not be able
    /// to stand in for a missing client in the fail-closed count (with
    /// SecAgg, double-counted masks would unmask to garbage).
    pub fn submit(&mut self, r: usize, client: usize, msg: &Descriptions) {
        let slot = &mut self.slots[r];
        assert!(
            !slot.folded,
            "cannot mix direct submits with shard folds in round {r} of the window"
        );
        assert!(
            !slot.seen[client],
            "duplicate submission from client {client} in round {r} of the window"
        );
        slot.seen[client] = true;
        slot.bits.merge(&msg.bits);
        self.transports[r].submit(&mut slot.partial, client, msg, &self.rounds[r]);
        slot.submitted += 1;
    }

    /// Fold a pre-folded shard partial covering `clients` clients into
    /// round r of the ring (the coordinator path: the orchestrator never
    /// sees per-client messages). The count is trusted — shards are
    /// in-process and fold disjoint client ranges; an external caller must
    /// not feed overlapping partials.
    pub fn fold_partial(
        &mut self,
        r: usize,
        partial: TransportPartial,
        clients: usize,
        bits: &BitsAccount,
    ) {
        let slot = &mut self.slots[r];
        assert!(
            slot.submitted == 0 || slot.folded,
            "cannot mix shard folds with direct submits in round {r} of the window"
        );
        slot.folded = true;
        slot.bits.merge(bits);
        self.transports[r].merge(&mut slot.partial, partial);
        slot.submitted += clients;
    }

    /// Whether every round of the window has all client submissions.
    pub fn is_complete(&self) -> bool {
        self.slots.iter().all(|s| s.submitted == self.n_clients)
    }

    /// Batched unmask: close every round of the window and surface the
    /// per-round server views, in round order.
    ///
    /// Fails closed: if ANY round of the window is missing submissions —
    /// a session interrupted mid-window — this panics before unmasking
    /// anything, so no partial payload ever escapes a broken session.
    pub fn close(self) -> Vec<(Payload, BitsAccount)> {
        for (r, slot) in self.slots.iter().enumerate() {
            assert!(
                slot.submitted == self.n_clients,
                "interrupted session fails closed: round {r} of the window has {}/{} client \
                 submissions — refusing any partial unmask",
                slot.submitted,
                self.n_clients,
            );
        }
        self.slots
            .into_iter()
            .zip(&self.rounds)
            .zip(&self.transports)
            .map(|((slot, round), t)| (t.finish(slot.partial, round), slot.bits))
            .collect()
    }
}

/// Run a whole window in-process: encode every client for every round,
/// stream the messages through one [`TransportSession`], batch-close, and
/// decode each round. `rounds` pairs each round's client data with its
/// shared-randomness seed; [`crate::mechanisms::pipeline::run_pipeline`]
/// is exactly this with a single round and `session_seed == seed`.
pub fn run_window(
    encoder: &dyn ClientEncoder,
    transport: &dyn Transport,
    decoder: &dyn ServerDecoder,
    rounds: &[(&[Vec<f64>], u64)],
    session_seed: u64,
) -> Vec<RoundOutput> {
    assert!(!rounds.is_empty(), "a session window needs at least one round");
    let (xs0, _) = rounds[0];
    assert!(!xs0.is_empty(), "need at least one client");
    assert!(
        !transport.sum_only() || decoder.sum_decodable(),
        "mechanism is not homomorphic: it cannot decode from a sum-only transport"
    );
    let n = xs0.len();
    let dim = xs0[0].len();
    let seeds: Vec<u64> = rounds.iter().map(|&(_, seed)| seed).collect();
    let mut session = TransportSession::open(transport, session_seed, n, dim, &seeds);
    for (r, &(xs, _)) in rounds.iter().enumerate() {
        assert_eq!(xs.len(), n, "client count changed mid-session");
        let round = *session.round(r);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), dim, "ragged client vectors");
            let msg = encoder.encode(i, x, &round);
            session.submit(r, i, &msg);
        }
    }
    let shared: Vec<SharedRound> = session.rounds.clone();
    session
        .close()
        .into_iter()
        .zip(shared)
        .map(|((payload, bits), round)| RoundOutput {
            estimate: decoder.decode(&payload, &round),
            bits,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::pipeline::{run_pipeline, MechSpec, Plain, SecAgg};
    use crate::quantizer::round_half_up;

    /// Toy homomorphic mechanism (same shape as the pipeline tests'):
    /// m = round(x + tiny seeded jitter), decode = Σm/n. The jitter makes
    /// per-round seeds observable in the estimates, so windowed-vs-
    /// independent comparisons are not vacuous.
    #[derive(Clone, Debug)]
    struct JitterRound;

    impl ClientEncoder for JitterRound {
        fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions {
            let mut rng = round.client_rng(client);
            let mut bits = BitsAccount::default();
            let ms: Vec<i64> = x
                .iter()
                .map(|&v| {
                    let m = round_half_up(4.0 * (v + rng.u01()));
                    bits.add_description(m);
                    m
                })
                .collect();
            Descriptions { ms, aux: vec![], bits }
        }
    }

    impl ServerDecoder for JitterRound {
        fn sum_decodable(&self) -> bool {
            true
        }

        fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
            payload
                .description_sum()
                .iter()
                .map(|&s| s as f64 / (4.0 * round.n_clients as f64))
                .collect()
        }
    }

    impl MechSpec for JitterRound {
        fn name(&self) -> String {
            "jitter-round".into()
        }

        fn is_homomorphic(&self) -> bool {
            true
        }

        fn gaussian_noise(&self) -> bool {
            false
        }

        fn fixed_length(&self) -> bool {
            false
        }

        fn noise_sd(&self) -> f64 {
            0.0
        }
    }

    fn data(shift: f64) -> Vec<Vec<f64>> {
        vec![
            vec![1.2 + shift, -3.9, 0.5],
            vec![2.2, 1.1 + shift, -7.0],
            vec![0.9, 0.0, 2.0 - shift],
        ]
    }

    fn window_inputs() -> Vec<(Vec<Vec<f64>>, u64)> {
        (0..4).map(|r| (data(r as f64 * 0.3), 1000 + 17 * r as u64)).collect()
    }

    #[test]
    fn windowed_secagg_session_matches_independent_plain_rounds() {
        let inputs = window_inputs();
        let rounds: Vec<(&[Vec<f64>], u64)> =
            inputs.iter().map(|(xs, s)| (xs.as_slice(), *s)).collect();
        let mech = JitterRound;
        let windowed = run_window(&mech, &SecAgg::new(), &mech, &rounds, 0xAB5E55);
        assert_eq!(windowed.len(), 4);
        for (r, &(xs, seed)) in rounds.iter().enumerate() {
            let independent = run_pipeline(&mech, &Plain, &mech, xs, seed);
            assert_eq!(windowed[r].estimate, independent.estimate, "round {r}");
            assert_eq!(windowed[r].bits.messages, independent.bits.messages);
            assert_eq!(windowed[r].bits.variable_total, independent.bits.variable_total);
        }
    }

    #[test]
    fn window_of_one_is_the_single_round_path_bit_for_bit() {
        // W=1 run_window vs driving the legacy transport stages by hand
        let xs = data(0.0);
        let seed = 77;
        let mech = JitterRound;
        let windowed = run_window(&mech, &Plain, &mech, &[(xs.as_slice(), seed)], seed);
        let round = SharedRound::new(seed, xs.len(), xs[0].len());
        let mut part = Plain.empty(&round);
        let mut bits = BitsAccount::default();
        for (i, x) in xs.iter().enumerate() {
            let msg = mech.encode(i, x, &round);
            bits.merge(&msg.bits);
            Plain.submit(&mut part, i, &msg, &round);
        }
        let legacy = mech.decode(&Plain.finish(part, &round), &round);
        assert_eq!(windowed.len(), 1);
        assert_eq!(windowed[0].estimate, legacy);
        assert_eq!(windowed[0].bits.messages, bits.messages);
        assert_eq!(windowed[0].bits.variable_total, bits.variable_total);
    }

    #[test]
    fn session_seed_changes_masks_but_never_estimates() {
        let inputs = window_inputs();
        let rounds: Vec<(&[Vec<f64>], u64)> =
            inputs.iter().map(|(xs, s)| (xs.as_slice(), *s)).collect();
        let mech = JitterRound;
        let a = run_window(&mech, &SecAgg::new(), &mech, &rounds, 1);
        let b = run_window(&mech, &SecAgg::new(), &mech, &rounds, 2);
        for (oa, ob) in a.iter().zip(&b) {
            assert_eq!(oa.estimate, ob.estimate);
        }
    }

    #[test]
    #[should_panic(expected = "fails closed")]
    fn interrupted_session_fails_closed_missing_client() {
        // every round touched, but one round is short a client: close must
        // refuse to unmask ANY round
        let xs = data(0.0);
        let mech = JitterRound;
        let mut session =
            TransportSession::open(&SecAgg::new(), 9, xs.len(), xs[0].len(), &[5, 6]);
        for r in 0..2 {
            let round = *session.round(r);
            for (i, x) in xs.iter().enumerate() {
                if r == 1 && i == 2 {
                    continue; // client 2 drops mid-window
                }
                let msg = mech.encode(i, x, &round);
                session.submit(r, i, &msg);
            }
        }
        assert!(!session.is_complete());
        let _ = session.close();
    }

    #[test]
    #[should_panic(expected = "cannot mix")]
    fn mixing_submit_and_fold_is_rejected() {
        // a fold cannot mark `seen`, so direct submits after a fold could
        // smuggle duplicates past the fail-closed count — rejected
        let xs = data(0.0);
        let mech = JitterRound;
        let mut session =
            TransportSession::open(&SecAgg::new(), 9, xs.len(), xs[0].len(), &[5]);
        let round = *session.round(0);
        let rt = session.round_transport(0).clone();
        let mut p = rt.empty(&round);
        let msg0 = mech.encode(0, &xs[0], &round);
        rt.submit(&mut p, 0, &msg0, &round);
        session.fold_partial(0, p, 1, &msg0.bits);
        session.submit(0, 1, &mech.encode(1, &xs[1], &round));
    }

    #[test]
    #[should_panic(expected = "exceeds MAX_WINDOW")]
    fn oversized_window_is_rejected_at_open() {
        let seeds: Vec<u64> = (0..MAX_WINDOW as u64 + 1).collect();
        let _ = TransportSession::open(&Plain, 1, 3, 2, &seeds);
    }

    #[test]
    #[should_panic(expected = "duplicate submission")]
    fn duplicate_submit_cannot_stand_in_for_missing_client() {
        // client 0 submits twice, client 2 never: the count would reach
        // n_clients, so the duplicate must be rejected at submit time
        let xs = data(0.0);
        let mech = JitterRound;
        let mut session =
            TransportSession::open(&SecAgg::new(), 9, xs.len(), xs[0].len(), &[5]);
        let round = *session.round(0);
        let msg0 = mech.encode(0, &xs[0], &round);
        session.submit(0, 0, &msg0);
        session.submit(0, 1, &mech.encode(1, &xs[1], &round));
        session.submit(0, 0, &msg0);
    }

    #[test]
    #[should_panic(expected = "fails closed")]
    fn interrupted_session_fails_closed_untouched_round() {
        // a complete first round must not leak through close when the
        // second round never ran
        let xs = data(0.0);
        let mech = JitterRound;
        let mut session = TransportSession::open(&Plain, 9, xs.len(), xs[0].len(), &[5, 6]);
        let round = *session.round(0);
        for (i, x) in xs.iter().enumerate() {
            let msg = mech.encode(i, x, &round);
            session.submit(0, i, &msg);
        }
        let _ = session.close();
    }

    #[test]
    fn shard_fold_path_matches_client_submit_path() {
        // two shards pre-fold disjoint clients per round, the session
        // merges partials: identical to submitting clients directly
        let inputs = window_inputs();
        let mech = JitterRound;
        let n = inputs[0].0.len();
        let dim = inputs[0].0[0].len();
        let seeds: Vec<u64> = inputs.iter().map(|&(_, s)| s).collect();
        let t = SecAgg::new();
        let session_seed = 0xFEED;

        let mut direct = TransportSession::open(&t, session_seed, n, dim, &seeds);
        let mut folded = TransportSession::open(&t, session_seed, n, dim, &seeds);
        for (r, (xs, _)) in inputs.iter().enumerate() {
            let round = *direct.round(r);
            let rt = folded.round_transport(r).clone();
            let mut p0 = rt.empty(&round);
            let mut p1 = rt.empty(&round);
            let mut b0 = BitsAccount::default();
            let mut b1 = BitsAccount::default();
            let mut c0 = 0usize;
            let mut c1 = 0usize;
            for (i, x) in xs.iter().enumerate() {
                let msg = mech.encode(i, x, &round);
                direct.submit(r, i, &msg);
                if i % 2 == 0 {
                    rt.submit(&mut p0, i, &msg, &round);
                    b0.merge(&msg.bits);
                    c0 += 1;
                } else {
                    rt.submit(&mut p1, i, &msg, &round);
                    b1.merge(&msg.bits);
                    c1 += 1;
                }
            }
            folded.fold_partial(r, p0, c0, &b0);
            folded.fold_partial(r, p1, c1, &b1);
        }
        assert!(direct.is_complete() && folded.is_complete());
        let a = direct.close();
        let b = folded.close();
        for (r, ((pa, ba), (pb, bb))) in a.iter().zip(&b).enumerate() {
            assert_eq!(pa.description_sum(), pb.description_sum(), "round {r}");
            assert_eq!(ba.messages, bb.messages);
        }
    }

    #[test]
    fn derived_session_seeds_are_window_distinct() {
        let a = derive_session_seed(42, 0);
        let b = derive_session_seed(42, 4);
        let c = derive_session_seed(43, 0);
        assert_eq!(a, derive_session_seed(42, 0));
        assert!(a != b && a != c && b != c);
    }
}
