//! The threaded FL round runtime: a persistent pool of client workers that
//! compute local updates in parallel, plus the round loops that feed those
//! updates through a mechanism and apply the aggregated result.
//!
//! Threading model: clients are multiplexed onto
//! min(n_clients, `std::thread::available_parallelism()`) long-lived worker
//! threads (override with [`ClientPool::spawn_with_threads`], e.g. to pin
//! bench runs), each owning a contiguous shard of clients.
//!
//! Two round shapes:
//!
//! * [`run_round`] — legacy/monolithic: shards compute local vectors, the
//!   orchestrator materializes all of them and calls
//!   [`MeanMechanism::aggregate`]. O(n·d) orchestrator memory.
//! * [`run_round_encoded`] — the pipeline shape: shards *encode* their own
//!   clients ([`ClientEncoder`] runs inside the worker), fold the messages
//!   into a per-shard [`TransportPartial`] and fold bit accounting
//!   locally; the orchestrator only merges shard partials and decodes.
//!   With a summing transport the orchestrator state is O(d) — it never
//!   sees a client vector or a per-client description.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::mechanisms::pipeline::{
    ClientEncoder, ServerDecoder, SharedRound, Transport, TransportPartial,
};
use crate::mechanisms::traits::{BitsAccount, MeanMechanism, RoundOutput};

/// Client-local computation: produce this round's vector from the broadcast
/// global state. Implementations must be deterministic in (round, state)
/// for reproducible runs.
pub trait LocalCompute: Send + Sync + 'static {
    /// `client` is the global client index.
    fn local_update(&self, client: usize, round: u64, state: &[f64]) -> Vec<f64>;
}

impl<F> LocalCompute for F
where
    F: Fn(usize, u64, &[f64]) -> Vec<f64> + Send + Sync + 'static,
{
    fn local_update(&self, client: usize, round: u64, state: &[f64]) -> Vec<f64> {
        self(client, round, state)
    }
}

enum ShardMsg {
    Compute {
        round: u64,
        state: Arc<Vec<f64>>,
    },
    /// Compute AND encode: the per-client vectors never leave the shard.
    Encode {
        round: u64,
        state: Arc<Vec<f64>>,
        seed: u64,
        encoder: Arc<dyn ClientEncoder>,
        transport: Arc<dyn Transport>,
    },
    Shutdown,
}

enum ShardResult {
    Computed {
        start: usize,
        vecs: Vec<Vec<f64>>,
    },
    Encoded {
        start: usize,
        partial: TransportPartial,
        bits: BitsAccount,
        /// Σ of this shard's client vectors (true-mean metric folding)
        x_sum: Vec<f64>,
    },
}

struct Shard {
    tx: mpsc::Sender<ShardMsg>,
    handle: Option<JoinHandle<()>>,
}

/// Persistent pool of client workers.
pub struct ClientPool {
    shards: Vec<Shard>,
    results_rx: mpsc::Receiver<ShardResult>,
    pub n_clients: usize,
}

impl ClientPool {
    /// Spawn a pool over `n_clients` clients evaluating `compute`, with
    /// min(n_clients, available_parallelism) workers.
    pub fn spawn(n_clients: usize, compute: Arc<dyn LocalCompute>) -> Self {
        Self::spawn_with_threads(n_clients, compute, None)
    }

    /// Like [`Self::spawn`] but with an explicit worker-thread count
    /// (benches pin this for stable numbers across machines).
    pub fn spawn_with_threads(
        n_clients: usize,
        compute: Arc<dyn LocalCompute>,
        threads: Option<usize>,
    ) -> Self {
        assert!(n_clients > 0);
        let threads = threads
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
            })
            .min(n_clients)
            .max(1);
        let per = n_clients.div_ceil(threads);
        let (results_tx, results_rx) = mpsc::channel();
        let mut shards = Vec::new();
        for s in 0..threads {
            let lo = s * per;
            let hi = ((s + 1) * per).min(n_clients);
            if lo >= hi {
                break;
            }
            let (tx, rx) = mpsc::channel::<ShardMsg>();
            let results_tx = results_tx.clone();
            let compute = compute.clone();
            let range2 = lo..hi;
            let handle = std::thread::Builder::new()
                .name(format!("fl-shard-{s}"))
                .spawn(move || {
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            ShardMsg::Compute { round, state } => {
                                let vecs: Vec<Vec<f64>> = range2
                                    .clone()
                                    .map(|c| compute.local_update(c, round, &state))
                                    .collect();
                                if results_tx
                                    .send(ShardResult::Computed { start: range2.start, vecs })
                                    .is_err()
                                {
                                    return;
                                }
                            }
                            ShardMsg::Encode { round, state, seed, encoder, transport } => {
                                let mut partial: Option<TransportPartial> = None;
                                let mut bits = BitsAccount::default();
                                let mut x_sum: Vec<f64> = Vec::new();
                                for c in range2.clone() {
                                    let x = compute.local_update(c, round, &state);
                                    if x_sum.is_empty() {
                                        x_sum = vec![0.0; x.len()];
                                    }
                                    for (a, v) in x_sum.iter_mut().zip(&x) {
                                        *a += v;
                                    }
                                    let shared =
                                        SharedRound::new(seed, n_clients, x.len());
                                    let part = partial
                                        .get_or_insert_with(|| transport.empty(&shared));
                                    let d = encoder.encode(c, &x, &shared);
                                    bits.merge(&d.bits);
                                    transport.submit(part, c, &d, &shared);
                                }
                                let partial =
                                    partial.expect("shard ranges are never empty");
                                if results_tx
                                    .send(ShardResult::Encoded {
                                        start: range2.start,
                                        partial,
                                        bits,
                                        x_sum,
                                    })
                                    .is_err()
                                {
                                    return;
                                }
                            }
                            ShardMsg::Shutdown => return,
                        }
                    }
                })
                .expect("spawning shard thread");
            shards.push(Shard { tx, handle: Some(handle) });
        }
        Self { shards, results_rx, n_clients }
    }

    /// Compute all clients' local vectors for one round (parallel).
    pub fn compute_round(&self, round: u64, state: &[f64]) -> Vec<Vec<f64>> {
        let state = Arc::new(state.to_vec());
        for shard in &self.shards {
            shard
                .tx
                .send(ShardMsg::Compute { round, state: state.clone() })
                .expect("shard died");
        }
        let mut out: Vec<Option<Vec<f64>>> = vec![None; self.n_clients];
        for _ in 0..self.shards.len() {
            match self.results_rx.recv().expect("shard result") {
                ShardResult::Computed { start, vecs } => {
                    for (off, v) in vecs.into_iter().enumerate() {
                        out[start + off] = Some(v);
                    }
                }
                ShardResult::Encoded { .. } => {
                    unreachable!("encode result during a compute round")
                }
            }
        }
        out.into_iter().map(|v| v.expect("missing client result")).collect()
    }
}

impl Drop for ClientPool {
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Outcome of one orchestrated round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub round: u64,
    pub output: RoundOutput,
    /// exact mean of the client vectors (for MSE metrics; a real server
    /// cannot see this — test/metric use only)
    pub true_mean: Vec<f64>,
}

/// Per-round seed derivation shared by both round shapes.
fn round_seed(root_seed: u64, round: u64) -> u64 {
    root_seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Run one round, monolith shape: parallel local compute, then the
/// mechanism's in-process aggregate. O(n·d) orchestrator memory.
pub fn run_round(
    pool: &ClientPool,
    mech: &dyn MeanMechanism,
    round: u64,
    state: &[f64],
    root_seed: u64,
) -> RoundReport {
    let xs = pool.compute_round(round, state);
    let true_mean = crate::mechanisms::traits::true_mean(&xs);
    let output = mech.aggregate(&xs, round_seed(root_seed, round));
    RoundReport { round, output, true_mean }
}

/// Run one round, pipeline shape: clients encode inside their worker
/// shards, shard partials and bit accounts fold on the orchestrator, the
/// decoder runs once on the final payload. With a summing transport the
/// orchestrator holds O(d) state (one partial + one bits account).
pub fn run_round_encoded(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    round: u64,
    state: &[f64],
    root_seed: u64,
) -> RoundReport {
    assert!(
        !transport.sum_only() || decoder.sum_decodable(),
        "mechanism is not homomorphic: it cannot decode from a sum-only transport"
    );
    let seed = round_seed(root_seed, round);
    let state = Arc::new(state.to_vec());
    for shard in &pool.shards {
        shard
            .tx
            .send(ShardMsg::Encode {
                round,
                state: state.clone(),
                seed,
                encoder: encoder.clone(),
                transport: transport.clone(),
            })
            .expect("shard died");
    }
    // collect shard partials; fold x-sums in shard order so the true-mean
    // metric is deterministic regardless of arrival order
    let mut pieces: Vec<(usize, TransportPartial, BitsAccount, Vec<f64>)> =
        Vec::with_capacity(pool.shards.len());
    for _ in 0..pool.shards.len() {
        match pool.results_rx.recv().expect("shard result") {
            ShardResult::Encoded { start, partial, bits, x_sum } => {
                pieces.push((start, partial, bits, x_sum));
            }
            ShardResult::Computed { .. } => {
                unreachable!("compute result during an encoded round")
            }
        }
    }
    pieces.sort_by_key(|&(start, _, _, _)| start);
    let dim = pieces[0].3.len();
    let mut bits = BitsAccount::default();
    let mut x_sum = vec![0.0f64; dim];
    let mut total: Option<TransportPartial> = None;
    let shared = SharedRound::new(seed, pool.n_clients, dim);
    for (_, partial, b, xs) in pieces {
        bits.merge(&b);
        for (a, v) in x_sum.iter_mut().zip(&xs) {
            *a += v;
        }
        match &mut total {
            None => total = Some(partial),
            Some(t) => transport.merge(t, partial),
        }
    }
    let payload = transport.finish(total.expect("no shards"), &shared);
    let estimate = decoder.decode(&payload, &shared);
    let true_mean: Vec<f64> = x_sum.into_iter().map(|v| v / pool.n_clients as f64).collect();
    RoundReport { round, output: RoundOutput { estimate, bits }, true_mean }
}

/// Convenience wrapper for mechanisms that implement both pipeline ends
/// (every mechanism in this crate does).
pub fn run_round_mech<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    round: u64,
    state: &[f64],
    root_seed: u64,
) -> RoundReport
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    run_round_encoded(pool, encoder, transport, mech, round, state, root_seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::pipeline::{Plain, SecAgg};
    use crate::mechanisms::{AggregateGaussian, IrwinHallMechanism, MeanMechanism};

    #[test]
    fn pool_computes_all_clients() {
        let pool = ClientPool::spawn(
            23,
            Arc::new(|c: usize, r: u64, s: &[f64]| vec![c as f64, r as f64, s[0]]),
        );
        let out = pool.compute_round(5, &[7.0]);
        assert_eq!(out.len(), 23);
        for (c, v) in out.iter().enumerate() {
            assert_eq!(v, &vec![c as f64, 5.0, 7.0]);
        }
    }

    #[test]
    fn pool_reusable_across_rounds() {
        let pool = ClientPool::spawn(8, Arc::new(|c: usize, r: u64, _: &[f64]| vec![(c + r as usize) as f64]));
        for round in 0..10 {
            let out = pool.compute_round(round, &[]);
            assert_eq!(out[3][0], 3.0 + round as f64);
        }
    }

    #[test]
    fn run_round_aggregates() {
        let pool = ClientPool::spawn(16, Arc::new(|c: usize, _: u64, _: &[f64]| vec![c as f64; 4]));
        let mech = IrwinHallMechanism::new(0.05, 64.0);
        let rep = run_round(&pool, &mech, 0, &[], 42);
        // true mean of 0..15 = 7.5; estimate within a few noise sd
        for j in 0..4 {
            assert!((rep.true_mean[j] - 7.5).abs() < 1e-12);
            assert!((rep.output.estimate[j] - 7.5).abs() < 1.0, "est {}", rep.output.estimate[j]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let pool = ClientPool::spawn(4, Arc::new(|c: usize, _: u64, _: &[f64]| vec![c as f64]));
        let mech = IrwinHallMechanism::new(0.1, 8.0);
        let a = run_round(&pool, &mech, 3, &[], 99);
        let b = run_round(&pool, &mech, 3, &[], 99);
        assert_eq!(a.output.estimate, b.output.estimate);
    }

    #[test]
    fn single_client_pool() {
        let pool = ClientPool::spawn(1, Arc::new(|_: usize, _: u64, _: &[f64]| vec![1.0]));
        assert_eq!(pool.compute_round(0, &[]), vec![vec![1.0]]);
    }

    #[test]
    fn threads_override_respected_and_equivalent() {
        // same round under different worker counts: identical estimates
        // (integer partials are order-free, x-sums fold in shard order)
        let compute = |c: usize, _: u64, _: &[f64]| {
            let mut rng = crate::util::rng::Rng::derive(4242, c as u64);
            (0..6).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
        };
        let mech = IrwinHallMechanism::new(0.2, 4.0);
        let mut estimates = Vec::new();
        for threads in [1usize, 3, 7] {
            let pool =
                ClientPool::spawn_with_threads(13, Arc::new(compute), Some(threads));
            assert!(pool.shards.len() <= threads);
            let rep = run_round_mech(&pool, &mech, Arc::new(Plain), 2, &[], 77);
            estimates.push(rep.output.estimate.clone());
        }
        assert_eq!(estimates[0], estimates[1]);
        assert_eq!(estimates[0], estimates[2]);
    }

    #[test]
    fn encoded_round_matches_monolithic_round() {
        // per-shard encoding must reproduce MeanMechanism::aggregate bit
        // for bit (same streams, same integer sums)
        let compute = |c: usize, r: u64, _: &[f64]| {
            let mut rng = crate::util::rng::Rng::derive(900 + r, c as u64);
            (0..5).map(|_| rng.uniform(-3.0, 3.0)).collect::<Vec<f64>>()
        };
        let pool = ClientPool::spawn(11, Arc::new(compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        for round in 0..4u64 {
            let mono = run_round(&pool, &mech, round, &[], 5);
            let enc = run_round_mech(&pool, &mech, Arc::new(Plain), round, &[], 5);
            assert_eq!(mono.output.estimate, enc.output.estimate, "round {round}");
            assert_eq!(mono.output.bits.messages, enc.output.bits.messages);
            assert!(
                (mono.output.bits.variable_total - enc.output.bits.variable_total).abs()
                    < 1e-9
            );
            for (a, b) in mono.true_mean.iter().zip(&enc.true_mean) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn encoded_round_through_secagg_matches_plain() {
        let compute = |c: usize, _: u64, _: &[f64]| {
            let mut rng = crate::util::rng::Rng::derive(31, c as u64);
            (0..4).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
        };
        let pool = ClientPool::spawn(9, Arc::new(compute));
        let mech = AggregateGaussian::new(0.4, 4.0);
        let plain = run_round_mech(&pool, &mech, Arc::new(Plain), 1, &[], 11);
        let masked = run_round_mech(&pool, &mech, Arc::new(SecAgg::new()), 1, &[], 11);
        assert_eq!(plain.output.estimate, masked.output.estimate);
    }

    #[test]
    fn pool_drop_joins_threads() {
        for _ in 0..3 {
            let pool = ClientPool::spawn(9, Arc::new(|_: usize, _: u64, _: &[f64]| vec![1.0]));
            let _ = pool.compute_round(0, &[]);
            drop(pool);
        }
    }
}
