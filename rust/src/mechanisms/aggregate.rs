//! The aggregate Gaussian mechanism (Def. 8 + §4.4): homomorphic AND
//! exactly Gaussian.
//!
//! Per coordinate: global shared randomness T = (A, B) ~ Decompose(P, Q)
//! with P = IH(n, 0, 1), Q = N(0, 1); per-client dithers Sᵢ ~ U(−1/2, 1/2);
//! step w = 2σ√(3n):
//!
//!   encode:  mᵢ = round(xᵢ / (A·w) + sᵢ)
//!   decode:  y  = (A·w/n)(Σᵢ mᵢ − Σᵢ sᵢ) + B·σ
//!
//! The decode needs only Σ mᵢ — SecAgg compatible (Prop. 3).

use super::decompose::Decomposer;
use super::traits::{BitsAccount, MeanMechanism, RoundOutput};
use crate::quantizer::round_half_up;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct AggregateGaussian {
    /// aggregate noise sd
    pub sigma: f64,
    /// input magnitude bound |x_ij| <= t/2 (communication accounting)
    pub input_range_t: f64,
    decomposer_n: std::cell::RefCell<Option<(usize, std::rc::Rc<Decomposer>)>>,
}

impl AggregateGaussian {
    pub fn new(sigma: f64, input_range_t: f64) -> Self {
        assert!(sigma > 0.0);
        Self { sigma, input_range_t, decomposer_n: std::cell::RefCell::new(None) }
    }

    fn decomposer(&self, n: usize) -> std::rc::Rc<Decomposer> {
        let mut cache = self.decomposer_n.borrow_mut();
        match cache.as_ref() {
            Some((cn, d)) if *cn == n => d.clone(),
            _ => {
                let d = std::rc::Rc::new(Decomposer::new(n as u64));
                *cache = Some((n, d.clone()));
                d
            }
        }
    }

    pub fn step(&self, n: usize) -> f64 {
        2.0 * self.sigma * (3.0 * n as f64).sqrt()
    }

    /// Homomorphic decode (server side, Def. 6 form): from Σ m, Σ s, (A, B).
    pub fn decode_from_sums(&self, m_sum: f64, s_sum: f64, a: f64, b: f64, n: usize) -> f64 {
        a * self.step(n) / n as f64 * (m_sum - s_sum) + b * self.sigma
    }
}

impl MeanMechanism for AggregateGaussian {
    fn name(&self) -> String {
        format!("aggregate-gaussian(sigma={})", self.sigma)
    }

    fn is_homomorphic(&self) -> bool {
        true
    }

    fn gaussian_noise(&self) -> bool {
        true
    }

    fn fixed_length(&self) -> bool {
        false // |A| has no positive lower bound ⇒ unbounded support
    }

    fn noise_sd(&self) -> f64 {
        self.sigma
    }

    fn aggregate(&self, xs: &[Vec<f64>], seed: u64) -> RoundOutput {
        let n = xs.len();
        let d = xs[0].len();
        let w = self.step(n);
        let dec = self.decomposer(n);
        let mut bits = BitsAccount::default();

        // Global shared randomness T = (A_j, B_j) per coordinate: every
        // client and the server derive the same stream (seed, GLOBAL).
        const GLOBAL_STREAM: u64 = u64::MAX;
        let mut trng = Rng::derive(seed, GLOBAL_STREAM);
        let ab: Vec<(f64, f64)> = (0..d).map(|_| dec.draw(&mut trng)).collect();

        // Clients encode; the server sees only Σ m (homomorphic path).
        // hoist the per-coordinate 1/(A_j·w) out of the client loop
        let inv_aw: Vec<f64> = ab.iter().map(|&(a, _)| 1.0 / (a * w)).collect();
        let mut m_sum = vec![0.0f64; d];
        let mut s_sum = vec![0.0f64; d];
        for (i, x) in xs.iter().enumerate() {
            let mut rng = Rng::derive(seed, i as u64);
            for j in 0..d {
                let s = rng.u01() - 0.5;
                let m = round_half_up(x[j] * inv_aw[j] + s);
                bits.add_description(m);
                m_sum[j] += m as f64;
                s_sum[j] += s;
            }
        }
        let estimate: Vec<f64> = (0..d)
            .map(|j| self.decode_from_sums(m_sum[j], s_sum[j], ab[j].0, ab[j].1, n))
            .collect();
        RoundOutput { estimate, bits }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Continuous, Gaussian};
    use crate::mechanisms::traits::true_mean;
    use crate::util::stats::{ks_test, variance};

    fn client_data(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..d).map(|_| rng.uniform(-8.0, 8.0)).collect()).collect()
    }

    fn errors(mech: &AggregateGaussian, xs: &[Vec<f64>], rounds: usize, seed0: u64) -> Vec<f64> {
        let mean = true_mean(xs);
        let mut errs = Vec::new();
        for r in 0..rounds {
            let out = mech.aggregate(xs, seed0 + r as u64);
            for j in 0..mean.len() {
                errs.push(out.estimate[j] - mean[j]);
            }
        }
        errs
    }

    #[test]
    fn noise_is_exactly_gaussian_small_n() {
        // n = 4: Irwin-Hall alone would be visibly non-Gaussian here
        let xs = client_data(4, 4, 11);
        let mech = AggregateGaussian::new(0.8, 16.0);
        let errs = errors(&mech, &xs, 900, 7000);
        let g = Gaussian::new(0.0, 0.8);
        let res = ks_test(&errs, |e| g.cdf(e));
        assert!(res.p_value > 0.003, "p={}", res.p_value);
        assert!((variance(&errs) - 0.64).abs() < 0.04);
    }

    #[test]
    fn noise_is_exactly_gaussian_moderate_n() {
        let xs = client_data(32, 2, 12);
        let mech = AggregateGaussian::new(1.0, 16.0);
        let errs = errors(&mech, &xs, 1200, 8000);
        let g = Gaussian::new(0.0, 1.0);
        assert!(ks_test(&errs, |e| g.cdf(e)).p_value > 0.003);
    }

    #[test]
    fn irwin_hall_would_fail_where_aggregate_passes() {
        // contrast test at n=2: IH noise rejected against the Gaussian cdf,
        // aggregate Gaussian accepted (this is Table 1's "Gaussian noise"
        // column, demonstrated empirically)
        let xs = client_data(2, 8, 13);
        let agg = AggregateGaussian::new(1.0, 16.0);
        let ih = crate::mechanisms::IrwinHallMechanism::new(1.0, 16.0);
        let mean = true_mean(&xs);
        let (mut e_agg, mut e_ih) = (Vec::new(), Vec::new());
        for r in 0..3200 {
            let oa = agg.aggregate(&xs, 100_000 + r);
            let oi = ih.aggregate(&xs, 200_000 + r);
            for j in 0..mean.len() {
                e_agg.push(oa.estimate[j] - mean[j]);
                e_ih.push(oi.estimate[j] - mean[j]);
            }
        }
        let g = Gaussian::new(0.0, 1.0);
        assert!(ks_test(&e_agg, |e| g.cdf(e)).p_value > 0.003);
        assert!(ks_test(&e_ih, |e| g.cdf(e)).p_value < 1e-4);
    }

    #[test]
    fn homomorphic_decode_consistency() {
        // the mechanism's estimate must be reproducible from Σm alone
        let n = 5;
        let d = 3;
        let xs = client_data(n, d, 14);
        let mech = AggregateGaussian::new(1.0, 16.0);
        let seed = 777;
        let out = mech.aggregate(&xs, seed);

        // reconstruct: shared randomness from seed
        let dec = Decomposer::new(n as u64);
        let mut trng = Rng::derive(seed, u64::MAX);
        let ab: Vec<(f64, f64)> = (0..d).map(|_| dec.draw(&mut trng)).collect();
        let w = mech.step(n);
        let mut m_sum = vec![0.0f64; d];
        let mut s_sum = vec![0.0f64; d];
        for (i, x) in xs.iter().enumerate() {
            let mut rng = Rng::derive(seed, i as u64);
            for j in 0..d {
                let s = rng.u01() - 0.5;
                m_sum[j] += round_half_up(x[j] / (ab[j].0 * w) + s) as f64;
                s_sum[j] += s;
            }
        }
        for j in 0..d {
            let y = mech.decode_from_sums(m_sum[j], s_sum[j], ab[j].0, ab[j].1, n);
            assert!((y - out.estimate[j]).abs() < 1e-12, "j={j}");
        }
    }

    #[test]
    fn bits_grow_slowly_with_n() {
        // per-client description magnitudes shrink like 1/(w|A|) with
        // w ∝ √n: more clients ⇒ cheaper messages (Fig. 4 trend)
        let mech = AggregateGaussian::new(1.0, 16.0);
        let xs8 = client_data(8, 16, 15);
        let xs256 = client_data(256, 16, 16);
        let b8 = mech.aggregate(&xs8, 1).bits.variable_per_client(8);
        let b256 = mech.aggregate(&xs256, 1).bits.variable_per_client(256);
        assert!(b256 < b8, "bits/client: n=256 {b256} >= n=8 {b8}");
    }

    #[test]
    fn property_flags() {
        let m = AggregateGaussian::new(1.0, 16.0);
        assert!(m.is_homomorphic());
        assert!(m.gaussian_noise());
        assert!(!m.fixed_length());
    }
}
