//! The aggregate Gaussian mechanism (Def. 8 + §4.4): homomorphic AND
//! exactly Gaussian.
//!
//! Per coordinate: global shared randomness T = (A, B) ~ Decompose(P, Q)
//! with P = IH(n, 0, 1), Q = N(0, 1); per-client dithers Sᵢ ~ U(−1/2, 1/2);
//! step w = 2σ√(3n):
//!
//!   encode:  mᵢ = round(xᵢ / (A·w) + sᵢ)
//!   decode:  y  = (A·w/n)(Σᵢ mᵢ − Σᵢ sᵢ) + B·σ
//!
//! The decode needs only Σ mᵢ — SecAgg compatible (Prop. 3). Both the
//! per-round (A, B) vector and the n-keyed [`Decomposer`] are derived
//! shared randomness / shared configuration: they are memoized behind
//! `Mutex`-based caches (never `Rc<RefCell>`) so the mechanism is
//! `Send + Sync` and usable from the coordinator's worker shards.

use std::ops::Range;
use std::sync::{Arc, Mutex};

use super::decompose::Decomposer;
use super::pipeline::{
    impl_mean_mechanism, ChunkCache, ClientEncoder, Descriptions, MechSpec, Payload, Plain,
    ServerDecoder, SharedRound, SurvivorSet,
};
use super::traits::BitsAccount;
use crate::quantizer::round_half_up;

#[derive(Debug)]
pub struct AggregateGaussian {
    /// aggregate noise sd
    pub sigma: f64,
    /// input magnitude bound |x_ij| <= t/2 (communication accounting)
    pub input_range_t: f64,
    /// n-keyed decomposer (expensive grid build; shared across rounds)
    decomposer_n: Mutex<Option<(usize, Arc<Decomposer>)>>,
    /// per-(round, chunk) (A_j, B_j) global shared randomness — each
    /// entry is O(c), so a bounded-memory streaming run stays bounded
    round_ab: ChunkCache<Vec<(f64, f64)>>,
}

impl Clone for AggregateGaussian {
    fn clone(&self) -> Self {
        // carry the (cheap, Arc'd) decomposer over; round caches re-derive
        let cached = self.decomposer_n.lock().expect("cache poisoned").clone();
        Self {
            sigma: self.sigma,
            input_range_t: self.input_range_t,
            decomposer_n: Mutex::new(cached),
            round_ab: ChunkCache::new(),
        }
    }
}

impl AggregateGaussian {
    pub fn new(sigma: f64, input_range_t: f64) -> Self {
        assert!(sigma > 0.0);
        Self {
            sigma,
            input_range_t,
            decomposer_n: Mutex::new(None),
            round_ab: ChunkCache::new(),
        }
    }

    /// The n-client Gaussian↔Irwin–Hall decomposer, built once per n.
    fn decomposer(&self, n: usize) -> Arc<Decomposer> {
        let mut cache = self.decomposer_n.lock().expect("cache poisoned");
        match cache.as_ref() {
            Some((cn, d)) if *cn == n => d.clone(),
            _ => {
                let d = Arc::new(Decomposer::new(n as u64));
                *cache = Some((n, d.clone()));
                d
            }
        }
    }

    /// The round's global shared randomness T = (A_j, B_j) for one
    /// coordinate chunk: coordinate j's draw comes from its own seekable
    /// stream of the global family, so every client and the server derive
    /// the identical pair for any chunking — and a chunked run only ever
    /// materializes O(c) of the (A, B) vector at a time.
    fn ab_range(&self, round: &SharedRound, range: &Range<usize>) -> Arc<Vec<(f64, f64)>> {
        let dec = self.decomposer(round.n_clients);
        self.round_ab.get_or(round, range, || {
            let global = round.global_coord_stream();
            range
                .clone()
                .map(|j| {
                    let mut rng = global.at(j);
                    dec.draw(&mut rng)
                })
                .collect()
        })
    }

    pub fn step(&self, n: usize) -> f64 {
        2.0 * self.sigma * (3.0 * n as f64).sqrt()
    }

    /// Homomorphic decode (server side, Def. 6 form): from Σ m, Σ s, (A, B).
    pub fn decode_from_sums(&self, m_sum: f64, s_sum: f64, a: f64, b: f64, n: usize) -> f64 {
        a * self.step(n) / n as f64 * (m_sum - s_sum) + b * self.sigma
    }
}

impl MechSpec for AggregateGaussian {
    fn name(&self) -> String {
        format!("aggregate-gaussian(sigma={})", self.sigma)
    }

    fn is_homomorphic(&self) -> bool {
        true
    }

    fn gaussian_noise(&self) -> bool {
        true
    }

    fn fixed_length(&self) -> bool {
        false // |A| has no positive lower bound ⇒ unbounded support
    }

    fn noise_sd(&self) -> f64 {
        self.sigma
    }
}

impl ClientEncoder for AggregateGaussian {
    fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions {
        self.encode_chunk(client, x, 0..x.len(), round)
    }

    /// Chunk-ranged encode: dithers AND the (A, B) decomposition draws
    /// are per-coordinate seekable streams, so any chunking concatenates
    /// to the whole-vector encode bit for bit while touching only O(c)
    /// of the (A, B) vector.
    fn encode_chunk(
        &self,
        client: usize,
        x: &[f64],
        range: std::ops::Range<usize>,
        round: &SharedRound,
    ) -> Descriptions {
        self.encode_chunk_slice(client, &x[range.clone()], range, round)
    }

    /// Slice-ranged encode — the streaming producer's entry point: every
    /// draw is purely per-coordinate, so the chunk slice alone suffices
    /// and `encode_chunk` is just the `&x[range]` delegation above.
    fn slice_chunkable(&self) -> bool {
        true
    }

    fn encode_chunk_slice(
        &self,
        client: usize,
        x_chunk: &[f64],
        range: std::ops::Range<usize>,
        round: &SharedRound,
    ) -> Descriptions {
        assert_eq!(x_chunk.len(), range.len(), "chunk slice does not match its range");
        let w = self.step(round.n_clients);
        let ab = self.ab_range(round, &range);
        // lane-batched centred-dither fill (u01 − ½ per coordinate
        // stream), bit-identical to the scalar at(j) loop; the (A, B)
        // draws stay scalar — they consume a variable number of raws per
        // coordinate and are chunk-cached anyway
        let mut dithers = vec![0.0f64; range.len()];
        round.client_coord_stream(client).fill_dither(range.start, &mut dithers);
        let mut bits = BitsAccount::default();
        let ms: Vec<i64> = x_chunk
            .iter()
            .zip(ab.iter().zip(dithers.iter()))
            .map(|(&xj, (&(a, _), &s))| {
                let inv_aw = 1.0 / (a * w);
                let m = round_half_up(xj * inv_aw + s);
                bits.add_description(m);
                m
            })
            .collect();
        Descriptions { ms, aux: vec![], bits }
    }
}

impl ServerDecoder for AggregateGaussian {
    fn sum_decodable(&self) -> bool {
        true
    }

    fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
        self.decode_survivors(payload, round, &SurvivorSet::full(round.n_clients))
    }

    /// Survivor-aware decode that KEEPS the exact-Gaussian claim. Both the
    /// step w and the decomposition (A, B) ~ Decompose(IH(n), N(0, 1))
    /// were fixed at encode time for the announced n, so conditional on A
    /// a survivor-only sum carries only n′ dither-error terms — an
    /// A·IH(n′) mixture, which is NOT Gaussian. The decoder restores the
    /// n-term law by completing the n − n′ missing U(−1/2, 1/2) terms from
    /// the shared per-dropout completion streams and rescaling the B leg
    /// by n/n′:
    ///
    ///   y = (A·w/n′)(Σ_S m − Σ_S s + Σ_D ũ) + B·σ·(n/n′)
    ///
    /// giving error = (σ·n/n′)·(A·IH_std(n) + B) ~ N(0, (σ·n/n′)²) —
    /// exactly Gaussian at the rescaled n′ variance (KS-tested).
    fn decode_survivors(
        &self,
        payload: &Payload,
        round: &SharedRound,
        survivors: &SurvivorSet,
    ) -> Vec<f64> {
        let est = self.decode_survivors_chunk(payload, 0, round, survivors);
        assert_eq!(est.len(), round.dim, "payload does not cover the coordinate space");
        est
    }

    fn chunk_decodable(&self) -> bool {
        true
    }

    /// The chunk-ranged core of the survivor-aware decode (see
    /// [`ServerDecoder::decode_survivors`] above for the law): every
    /// stream — survivor dithers, (A, B) draws, dropout completions — is
    /// seekable per coordinate, so the server works in O(c) state per
    /// chunk and the concatenation over any chunking is bit-identical to
    /// the whole-d decode.
    fn decode_survivors_chunk(
        &self,
        payload: &Payload,
        lo: usize,
        round: &SharedRound,
        survivors: &SurvivorSet,
    ) -> Vec<f64> {
        let n = round.n_clients;
        assert_eq!(survivors.n(), n, "survivor set shaped for a different fleet");
        let m_sum = payload.description_sum();
        let len = m_sum.len();
        assert!(lo + len <= round.dim, "chunk exceeds the coordinate space");
        let range = lo..lo + len;
        let ab = self.ab_range(round, &range);
        // re-derive the SURVIVORS' dithers for this chunk: O(c) state
        let mut s_sum = vec![0.0f64; len];
        let mut scratch = vec![0.0f64; len];
        for i in survivors.alive_iter() {
            round.client_coord_stream(i).fill_dither(lo, &mut scratch);
            for (sj, &v) in s_sum.iter_mut().zip(scratch.iter()) {
                *sj += v;
            }
        }
        let mut topup = vec![0.0f64; len];
        for j in survivors.dropped_iter() {
            round.dropout_coord_stream(j).fill_dither(lo, &mut scratch);
            for (tj, &v) in topup.iter_mut().zip(scratch.iter()) {
                *tj += v;
            }
        }
        let w = self.step(n);
        let n_alive = survivors.n_alive() as f64;
        let rescale = n as f64 / n_alive;
        (0..len)
            .map(|k| {
                let (a, b) = ab[k];
                a * w / n_alive * (m_sum[k] as f64 - s_sum[k] + topup[k])
                    + b * self.sigma * rescale
            })
            .collect()
    }
}

impl_mean_mechanism!(AggregateGaussian, |_m| Plain);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Continuous, Gaussian};
    use crate::mechanisms::traits::{true_mean, MeanMechanism};
    use crate::util::rng::Rng;
    use crate::util::stats::{ks_test, variance};

    fn client_data(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..d).map(|_| rng.uniform(-8.0, 8.0)).collect()).collect()
    }

    fn errors(mech: &AggregateGaussian, xs: &[Vec<f64>], rounds: usize, seed0: u64) -> Vec<f64> {
        let mean = true_mean(xs);
        let mut errs = Vec::new();
        for r in 0..rounds {
            let out = mech.aggregate(xs, seed0 + r as u64);
            for j in 0..mean.len() {
                errs.push(out.estimate[j] - mean[j]);
            }
        }
        errs
    }

    #[test]
    fn noise_is_exactly_gaussian_small_n() {
        // n = 4: Irwin-Hall alone would be visibly non-Gaussian here
        let xs = client_data(4, 4, 11);
        let mech = AggregateGaussian::new(0.8, 16.0);
        let errs = errors(&mech, &xs, 900, 7000);
        let g = Gaussian::new(0.0, 0.8);
        let res = ks_test(&errs, |e| g.cdf(e));
        assert!(res.p_value > 0.003, "p={}", res.p_value);
        assert!((variance(&errs) - 0.64).abs() < 0.04);
    }

    #[test]
    fn noise_is_exactly_gaussian_moderate_n() {
        let xs = client_data(32, 2, 12);
        let mech = AggregateGaussian::new(1.0, 16.0);
        let errs = errors(&mech, &xs, 1200, 8000);
        let g = Gaussian::new(0.0, 1.0);
        assert!(ks_test(&errs, |e| g.cdf(e)).p_value > 0.003);
    }

    #[test]
    fn irwin_hall_would_fail_where_aggregate_passes() {
        // contrast test at n=2: IH noise rejected against the Gaussian cdf,
        // aggregate Gaussian accepted (this is Table 1's "Gaussian noise"
        // column, demonstrated empirically)
        let xs = client_data(2, 8, 13);
        let agg = AggregateGaussian::new(1.0, 16.0);
        let ih = crate::mechanisms::IrwinHallMechanism::new(1.0, 16.0);
        let mean = true_mean(&xs);
        let (mut e_agg, mut e_ih) = (Vec::new(), Vec::new());
        for r in 0..3200 {
            let oa = agg.aggregate(&xs, 100_000 + r);
            let oi = ih.aggregate(&xs, 200_000 + r);
            for j in 0..mean.len() {
                e_agg.push(oa.estimate[j] - mean[j]);
                e_ih.push(oi.estimate[j] - mean[j]);
            }
        }
        let g = Gaussian::new(0.0, 1.0);
        assert!(ks_test(&e_agg, |e| g.cdf(e)).p_value > 0.003);
        assert!(ks_test(&e_ih, |e| g.cdf(e)).p_value < 1e-4);
    }

    #[test]
    fn homomorphic_decode_consistency() {
        // the mechanism's estimate must be reproducible from Σm alone
        let n = 5;
        let d = 3;
        let xs = client_data(n, d, 14);
        let mech = AggregateGaussian::new(1.0, 16.0);
        let seed = 777;
        let out = mech.aggregate(&xs, seed);

        // reconstruct: shared randomness from the per-coordinate streams
        let round = SharedRound::new(seed, n, d);
        let dec = Decomposer::new(n as u64);
        let global = round.global_coord_stream();
        let ab: Vec<(f64, f64)> = (0..d)
            .map(|j| {
                let mut rng = global.at(j);
                dec.draw(&mut rng)
            })
            .collect();
        let w = mech.step(n);
        let mut m_sum = vec![0.0f64; d];
        let mut s_sum = vec![0.0f64; d];
        for (i, x) in xs.iter().enumerate() {
            let dither = round.client_coord_stream(i);
            for j in 0..d {
                let s = dither.at(j).u01() - 0.5;
                m_sum[j] += round_half_up(x[j] / (ab[j].0 * w) + s) as f64;
                s_sum[j] += s;
            }
        }
        for j in 0..d {
            let y = mech.decode_from_sums(m_sum[j], s_sum[j], ab[j].0, ab[j].1, n);
            assert!((y - out.estimate[j]).abs() < 1e-12, "j={j}");
        }
    }

    #[test]
    fn clone_and_threads_share_nothing_mutable() {
        // Send + Sync: aggregate the same round from several threads and a
        // clone; all outputs must agree (this deadlocked/was impossible
        // with the old Rc<RefCell> cache)
        let xs = client_data(6, 4, 15);
        let mech = std::sync::Arc::new(AggregateGaussian::new(0.7, 16.0));
        let reference = mech.aggregate(&xs, 4242);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = mech.clone();
            let data = xs.clone();
            handles.push(std::thread::spawn(move || m.aggregate(&data, 4242).estimate));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), reference.estimate);
        }
        let cloned = (*mech).clone();
        assert_eq!(cloned.aggregate(&xs, 4242).estimate, reference.estimate);
    }

    #[test]
    fn bits_grow_slowly_with_n() {
        // per-client description magnitudes shrink like 1/(w|A|) with
        // w ∝ √n: more clients ⇒ cheaper messages (Fig. 4 trend)
        let mech = AggregateGaussian::new(1.0, 16.0);
        let xs8 = client_data(8, 16, 15);
        let xs256 = client_data(256, 16, 16);
        let b8 = mech.aggregate(&xs8, 1).bits.variable_per_client(8);
        let b256 = mech.aggregate(&xs256, 1).bits.variable_per_client(256);
        assert!(b256 < b8, "bits/client: n=256 {b256} >= n=8 {b8}");
    }

    #[test]
    fn property_flags() {
        let m: &dyn MeanMechanism = &AggregateGaussian::new(1.0, 16.0);
        assert!(m.is_homomorphic());
        assert!(m.gaussian_noise());
        assert!(!m.fixed_length());
    }
}
