//! Per-round metric recording with CSV / JSON export.

use crate::util::json::{Csv, Json};
use std::collections::BTreeMap;
use std::time::Instant;

/// A metrics sink: named float series sampled per round.
#[derive(Debug)]
pub struct Metrics {
    pub name: String,
    series: BTreeMap<String, Vec<(u64, f64)>>,
    start: Instant,
}

impl Metrics {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), series: BTreeMap::new(), start: Instant::now() }
    }

    pub fn record(&mut self, round: u64, key: &str, value: f64) {
        self.series.entry(key.to_string()).or_default().push((round, value));
    }

    pub fn last(&self, key: &str) -> Option<f64> {
        self.series.get(key).and_then(|v| v.last()).map(|&(_, x)| x)
    }

    pub fn series(&self, key: &str) -> Option<&[(u64, f64)]> {
        self.series.get(key).map(|v| v.as_slice())
    }

    pub fn mean_of(&self, key: &str) -> Option<f64> {
        let s = self.series.get(key)?;
        if s.is_empty() {
            return None;
        }
        Some(s.iter().map(|&(_, x)| x).sum::<f64>() / s.len() as f64)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Render all series into a round-indexed CSV (missing cells empty).
    pub fn to_csv(&self) -> Csv {
        let mut header = vec!["round".to_string()];
        header.extend(self.series.keys().cloned());
        let mut rounds: Vec<u64> =
            self.series.values().flat_map(|s| s.iter().map(|&(r, _)| r)).collect();
        rounds.sort_unstable();
        rounds.dedup();
        let mut csv =
            Csv { header: header.clone(), rows: Vec::with_capacity(rounds.len()) };
        for r in rounds {
            let mut row = vec![r.to_string()];
            for key in self.series.keys() {
                let cell = self.series[key]
                    .iter()
                    .find(|&&(rr, _)| rr == r)
                    .map(|&(_, v)| format!("{v}"))
                    .unwrap_or_default();
                row.push(cell);
            }
            csv.rows.push(row);
        }
        csv
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj().push("name", self.name.as_str());
        for (k, s) in &self.series {
            obj = obj.push(
                k,
                Json::Arr(
                    s.iter()
                        .map(|&(r, v)| Json::Arr(vec![Json::Int(r as i64), Json::Num(v)]))
                        .collect(),
                ),
            );
        }
        obj
    }

    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        self.to_csv().save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut m = Metrics::new("test");
        m.record(0, "loss", 1.0);
        m.record(1, "loss", 0.5);
        m.record(1, "acc", 0.9);
        assert_eq!(m.last("loss"), Some(0.5));
        assert_eq!(m.mean_of("loss"), Some(0.75));
        assert_eq!(m.last("missing"), None);
    }

    #[test]
    fn csv_has_all_rounds() {
        let mut m = Metrics::new("test");
        m.record(0, "a", 1.0);
        m.record(2, "b", 3.0);
        let csv = m.to_csv();
        assert_eq!(csv.header, vec!["round", "a", "b"]);
        assert_eq!(csv.rows.len(), 2);
        assert_eq!(csv.rows[0][1], "1");
        assert_eq!(csv.rows[1][2], "3");
        assert_eq!(csv.rows[1][1], ""); // missing cell
    }

    #[test]
    fn json_renders() {
        let mut m = Metrics::new("t");
        m.record(0, "x", 2.0);
        let s = m.to_json().render();
        assert!(s.contains("\"x\":[[0,2]]"), "{s}");
    }
}
