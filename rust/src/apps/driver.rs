//! Apps-on-the-coordinator driver: run any [`MeanMechanism`] workload
//! through the coordinator's chunk-streamed or async runners instead of
//! the monolithic in-process `aggregate()`.
//!
//! Every app in this module family (mean estimation, FedSGD, QLSD*
//! Langevin, randomized smoothing) produces per-round client vectors from
//! a [`LocalCompute`] and needs the same plumbing: explode the mechanism
//! into its pipeline stages ([`MeanMechanism::pipeline_parts`]), spawn a
//! [`ClientPool`] over the compute, clamp the chunk size to what the
//! mechanism's transport supports, split long runs into `MAX_WINDOW`-sized
//! session windows, and thread sampling policy / dropout schedules /
//! ledger accounting through. [`AppCoordinator`] packages exactly that.
//!
//! Seed contract (the apps-on-coordinator ≡ apps-on-`aggregate()`
//! invariant): round k's shared randomness is
//! `derive_domain(root_seed, seed_domain::ROUND, k)` — the same
//! derivation [`crate::coordinator::runtime`] applies internally — so a
//! monolithic reference path that calls
//! `mech.aggregate(&xs, app_round_seed(root_seed, k))` sees bit-identical
//! estimates and bit accounts at full cohort for every chunk size
//! (property-tested per app in `rust/tests/property_apps.rs`).

use std::sync::Arc;

use crate::coordinator::runtime::{
    run_rounds_encoded_async, run_rounds_encoded_chunked, AsyncRunConfig, ClientPool,
    RoundReport,
};
use crate::coordinator::sampling::SamplingPolicy;
use crate::dp::ledger::PrivacyLedger;
use crate::mechanisms::pipeline::{LocalCompute, PipelineParts};
use crate::mechanisms::session::MAX_WINDOW;
use crate::mechanisms::traits::MeanMechanism;
use crate::util::rng::{seed_domain, Rng};

/// The round-k shared-randomness seed of an app run — the coordinator's
/// own `ROUND`-domain derivation, exported so monolithic reference paths
/// (and the figure sweeps' direct `aggregate()` calls) land on the exact
/// seed the coordinator will re-derive. This replaces the ad-hoc
/// `wrapping_add`/`wrapping_mul` seed mixing the apps used before the
/// seed-format ADR (`docs/determinism.md`) reached this layer.
pub fn app_round_seed(root_seed: u64, round: u64) -> u64 {
    Rng::derive_domain(root_seed, seed_domain::ROUND, round)
}

/// How the driver executes windows.
#[derive(Clone, Copy, Debug)]
pub enum RunMode {
    /// barrier-paced chunk streaming ([`run_rounds_encoded_chunked`])
    Chunked,
    /// work-stealing async runner ([`run_rounds_encoded_async`]) with the
    /// given accumulator-ring depth
    Async { ring: usize },
}

/// Driver knobs shared by every app.
#[derive(Clone, Debug)]
pub struct CoordinatorOpts {
    /// chunk size c of the streaming plan; 0 means whole-d (one chunk).
    /// Clamped to d — and forced to d when the mechanism's transport is
    /// not chunk-capable (per-client [`crate::mechanisms::Unicast`]
    /// delivery has no coordinate offsets).
    pub chunk: usize,
    /// worker/shard threads; `None` = available parallelism
    pub threads: Option<usize>,
    pub mode: RunMode,
    /// per-round cohort sampling (client-side derived, no communication)
    pub policy: SamplingPolicy,
}

impl Default for CoordinatorOpts {
    fn default() -> Self {
        Self { chunk: 0, threads: None, mode: RunMode::Chunked, policy: SamplingPolicy::Full }
    }
}

/// One app workload wired onto the coordinator: a client pool over the
/// app's [`LocalCompute`] plus the mechanism's pipeline stages.
pub struct AppCoordinator {
    pool: ClientPool,
    parts: PipelineParts,
    opts: CoordinatorOpts,
    dim: usize,
    chunk: usize,
    /// accumulator high-water mark (bytes) across every window run so far
    pub peak_accumulator_bytes: usize,
}

impl AppCoordinator {
    /// Wire `mech` and `compute` together for an `n_clients` fleet and a
    /// d-dimensional model. Panics for mechanisms that do not expose
    /// pipeline parts (every mechanism in this crate does).
    pub fn new(
        mech: &dyn MeanMechanism,
        compute: Arc<dyn LocalCompute>,
        n_clients: usize,
        dim: usize,
        opts: CoordinatorOpts,
    ) -> Self {
        let parts = mech.pipeline_parts().unwrap_or_else(|| {
            panic!(
                "mechanism {} exposes no pipeline parts — it cannot run on the coordinator",
                mech.name()
            )
        });
        let requested = if opts.chunk == 0 { dim } else { opts.chunk.min(dim) };
        // per-client transports carry no coordinate offsets: single-chunk
        // plans only (the encode side still goes through the identical
        // chunk cursor, at c = d)
        let chunk = if parts.transport.chunk_capable() { requested } else { dim };
        let pool = ClientPool::spawn_with_threads(n_clients, compute, opts.threads);
        Self { pool, parts, opts, dim, chunk, peak_accumulator_bytes: 0 }
    }

    pub fn n_clients(&self) -> usize {
        self.pool.n_clients
    }

    /// The effective chunk size after transport clamping.
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Run ONE session window (≤ [`MAX_WINDOW`] rounds) with explicit
    /// per-round dropout schedules and optional ledger accounting.
    #[allow(clippy::too_many_arguments)]
    pub fn run_window(
        &mut self,
        start_round: u64,
        window: usize,
        state: &[f64],
        root_seed: u64,
        dropouts: &[Vec<usize>],
        ledger: Option<&mut PrivacyLedger>,
    ) -> Vec<RoundReport> {
        match self.opts.mode {
            RunMode::Chunked => {
                let (reports, stats) = run_rounds_encoded_chunked(
                    &self.pool,
                    self.parts.encoder.clone(),
                    self.parts.transport.clone(),
                    self.parts.decoder.as_ref(),
                    start_round,
                    window,
                    state,
                    root_seed,
                    &self.opts.policy,
                    dropouts,
                    ledger,
                    self.dim,
                    self.chunk,
                );
                self.peak_accumulator_bytes =
                    self.peak_accumulator_bytes.max(stats.peak_accumulator_bytes);
                reports
            }
            RunMode::Async { ring } => {
                let mut cfg = AsyncRunConfig::new(self.dim, self.chunk).with_ring(ring);
                if let Some(t) = self.opts.threads {
                    cfg = cfg.with_workers(t);
                }
                let (reports, stats) = run_rounds_encoded_async(
                    &self.pool,
                    self.parts.encoder.clone(),
                    self.parts.transport.clone(),
                    self.parts.decoder.as_ref(),
                    start_round,
                    window,
                    state,
                    root_seed,
                    &self.opts.policy,
                    dropouts,
                    ledger,
                    &cfg,
                );
                self.peak_accumulator_bytes =
                    self.peak_accumulator_bytes.max(stats.peak_accumulator_bytes);
                reports
            }
        }
    }

    /// Run `n_rounds` dropout-free rounds starting at `start_round`,
    /// split into [`MAX_WINDOW`]-sized session windows. Round ids — and
    /// hence every round's shared-randomness seed
    /// ([`app_round_seed`]) — are absolute, so the window split is
    /// invisible to the estimates.
    pub fn run_rounds(
        &mut self,
        start_round: u64,
        n_rounds: usize,
        state: &[f64],
        root_seed: u64,
    ) -> Vec<RoundReport> {
        let mut reports = Vec::with_capacity(n_rounds);
        let mut done = 0usize;
        while done < n_rounds {
            let w = (n_rounds - done).min(MAX_WINDOW);
            let none: Vec<Vec<usize>> = vec![Vec::new(); w];
            reports.extend(self.run_window(
                start_round + done as u64,
                w,
                state,
                root_seed,
                &none,
                None,
            ));
            done += w;
        }
        reports
    }
}
