//! DDG — the Distributed Discrete Gaussian mechanism (Kairouz et al.
//! 2021a), the Fig. 6 / 8 baseline. Full pipeline:
//!
//!  client: clip to ℓ2 ball c → randomized Hadamard rotation → scale by
//!          1/γ_q → unbiased stochastic rounding to ℤ → + discrete
//!          Gaussian N_ℤ(0, (σ/γ_q)²)  (= the [`ClientEncoder`])
//!  transport: reduce mod 2^b + SecAgg masking — the server observes only
//!          Σᵢ mᵢ mod 2^b
//!  server: signed representative mod 2^b → ·γ_q/n → inverse rotation
//!          (= the [`ServerDecoder`]; it re-applies the 2^b reduction, so
//!          plain summation and SecAgg decode bit-identically)
//!
//! DP guarantee against the *server* (stronger than less-trusted): the
//! summed discrete Gaussian noise gives zCDP ρ ≈ Δ̃²/(2σ²) with the
//! rounding-inflated sensitivity Δ̃² = c² + γ_q²d/4 + γ_q·c·√d
//! (conservative form of Kairouz et al. Thm. 1); we convert via
//! ε = ρ + 2√(ρ ln(1/δ)).
//!
//! The modulus is the whole story of the bits comparison: with too few
//! bits the sum wraps around mod 2^b and the MSE explodes — this is why
//! DDG needs b up to 18 where aggregate Gaussian needs ~2 bits.

use crate::dist::discrete_gaussian::discrete_gaussian;
use crate::mechanisms::pipeline::{
    impl_mean_mechanism, ChunkCache, ClientEncoder, Descriptions, MechSpec, Payload, RoundCache,
    SecAgg, ServerDecoder, SharedRound, SurvivorSet,
};
use crate::mechanisms::traits::BitsAccount;
use crate::secagg::{from_field, to_field, SecAggParams};
use crate::transforms::hadamard::RandomizedRotation;
use crate::util::stats::l2_norm;

#[derive(Clone, Debug)]
pub struct Ddg {
    /// per-client discrete Gaussian scale (on the lattice, i.e. σ_c/γ_q)
    pub sigma_lattice: f64,
    /// lattice step γ_q
    pub gamma_q: f64,
    /// ℓ2 clipping threshold c
    pub clip_c: f64,
    /// bits per coordinate: modulus = 2^bits
    pub bits: u32,
    /// round-derived shared rotation (clients + server)
    round_rot: RoundCache<RandomizedRotation>,
    /// per-(round, client) clipped + rotated vectors, used ONLY by
    /// partial-range `encode_chunk` calls: a chunked client streams
    /// ⌈d/c⌉ chunk encodes per round, and the O(d log d) rotation must
    /// run once, not once per chunk. The cache key reuses [`ChunkCache`]
    /// with the degenerate "range" `client..client + 1` standing in for
    /// the client id (documented abuse — the cache is per (round,
    /// client)). Client-side memory, FIFO-capped at the working set of
    /// one session window — n·MAX_WINDOW entries, one per (in-flight
    /// round, cohort member), each revisited once per chunk pass — so a
    /// chunked window never thrashes back into per-chunk re-rotation;
    /// whole-range (legacy) encodes bypass it. Keys include a fingerprint
    /// of the input vector ([`Ddg::rot_key_seed`]), so re-encoding the
    /// same (round, client) with DIFFERENT data (new model state) can
    /// never reuse a stale rotation.
    rot_vec: ChunkCache<Vec<f64>>,
}

impl Ddg {
    pub fn new(sigma_lattice: f64, gamma_q: f64, clip_c: f64, bits: u32) -> Self {
        assert!(sigma_lattice > 0.0 && gamma_q > 0.0 && bits >= 2 && bits <= 40);
        Self {
            sigma_lattice,
            gamma_q,
            clip_c,
            bits,
            round_rot: RoundCache::new(),
            rot_vec: ChunkCache::new(),
        }
    }

    /// Calibrate for (ε, δ)-DP at n clients, dimension d: pick the total
    /// noise σ_total from the zCDP conversion with the rounding-inflated
    /// sensitivity, then split across clients. The lattice step γ_q is
    /// tuned so the SecAgg sum fits the 2^b modulus with margin: the
    /// per-coordinate sum magnitude is ≲ κ(√n·c/√d + σ_total), so
    /// γ_q = 8(√n·c/√d + σ_total)/2^b — more bits buy a finer lattice
    /// (less rounding error) instead of changing the wraparound risk.
    /// Since the sensitivity inflation depends on γ_q, calibration runs a
    /// short fixed-point iteration.
    pub fn calibrated(
        eps: f64,
        delta: f64,
        clip_c: f64,
        n: usize,
        d: usize,
        bits: u32,
        gamma_q_init: f64,
    ) -> Self {
        let df = d as f64;
        let nf = n as f64;
        let _ = gamma_q_init;
        let mut gamma_q: f64 = 0.1;
        let mut sigma_total = 0.0;
        // replacement adjacency (‖x − x'‖₂ ≤ 2c) to match the Gaussian-
        // mechanism calibration of the AINQ arms in Figs. 6/8
        let sens = 2.0 * clip_c;
        for _ in 0..4 {
            let delta_tilde = (sens * sens
                + gamma_q * gamma_q * df / 4.0
                + gamma_q * sens * df.sqrt())
            .sqrt();
            sigma_total = crate::dp::renyi::zcdp_sigma_for_eps(eps, delta, delta_tilde);
            gamma_q = 8.0 * (nf.sqrt() * clip_c / df.sqrt() + sigma_total)
                / 2f64.powi(bits as i32);
        }
        // n clients each add N_Z(0, σ_c²) on the lattice; the sum has
        // variance n·σ_c² = (σ_total/γ_q)²
        let sigma_c_lattice = sigma_total / gamma_q / nf.sqrt();
        Self::new(sigma_c_lattice.max(1e-3), gamma_q, clip_c, bits)
    }

    fn modulus(&self) -> u64 {
        1u64 << self.bits
    }

    fn rotation(&self, round: &SharedRound) -> std::sync::Arc<RandomizedRotation> {
        self.round_rot
            .get_or(round, || RandomizedRotation::new(round.dim, round.seed ^ 0xDD6))
    }

    /// The transport DDG is meant to run over: SecAgg over ℤ_{2^b}.
    pub fn transport(&self) -> SecAgg {
        SecAgg::with_params(SecAggParams { modulus: self.modulus() })
    }

    /// Cache-key fingerprint of (round seed, input vector): an FNV-1a
    /// fold of the raw f64 bits seeded by the round seed. The rotated
    /// vector depends on the DATA, not just on (round, client) — the
    /// coordinator recomputes `local_update(c, round, state)` against
    /// whatever model state it holds — so the data must be part of the
    /// key or a re-encode with new state would silently reuse a stale
    /// rotation. O(d) per encode_chunk call, negligible next to the
    /// O(d log d) rotation it guards.
    fn rot_key_seed(&self, round_seed: u64, x: &[f64]) -> u64 {
        let mut h = round_seed ^ 0xcbf2_9ce4_8422_2325;
        for v in x {
            h = (h ^ v.to_bits()).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
}

impl MechSpec for Ddg {
    fn name(&self) -> String {
        format!("ddg(sigma={}, gq={}, b={})", self.sigma_lattice, self.gamma_q, self.bits)
    }

    fn is_homomorphic(&self) -> bool {
        true
    }

    fn gaussian_noise(&self) -> bool {
        false // discrete Gaussian + rounding, not a continuous Gaussian
    }

    fn fixed_length(&self) -> bool {
        true // b bits per coordinate by construction
    }

    fn noise_sd(&self) -> f64 {
        // continuous-equivalent sd of the summed lattice noise per client
        self.sigma_lattice * self.gamma_q
    }
}

impl ClientEncoder for Ddg {
    fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions {
        self.encode_chunk(client, x, 0..x.len(), round)
    }

    /// Chunk-ranged encode. The clip + rotation are deterministic
    /// whole-vector transforms of the client's OWN data (clients always
    /// hold their own x — client memory is not what the chunked pipeline
    /// bounds); the per-coordinate randomness — stochastic rounding and
    /// the discrete Gaussian, whose sampler consumes a variable number of
    /// raw draws — comes from seekable per-coordinate streams, so any
    /// chunking concatenates to the whole-vector encode bit for bit.
    ///
    /// Two DDG-specific caveats: (a) DDG's *description* space is the
    /// rotation's padded power-of-two dimension, so partial chunking is
    /// supported only when `d` is already a power of two (description
    /// coordinates then ARE data coordinates; otherwise only the full
    /// range is accepted and the padded tail rides along, exactly as in
    /// the unchunked path); (b) the DECODE side stays whole-d
    /// (`chunk_decodable` = false): the inverse rotation needs every
    /// coordinate, so the streaming runner assembles the O(d) sum — the
    /// size of the estimate itself — before decoding.
    fn encode_chunk(
        &self,
        client: usize,
        x: &[f64],
        range: std::ops::Range<usize>,
        round: &SharedRound,
    ) -> Descriptions {
        let rot = self.rotation(round);
        let full_range = range.start == 0 && range.end == x.len();
        let desc_range = if full_range {
            // full-range call: describe the whole (possibly padded)
            // rotated space, exactly as the legacy whole-d encode did
            0..rot.dim
        } else {
            assert!(
                rot.dim == x.len(),
                "ddg fails closed under chunking: dimension {} pads to a {}-dim rotation — \
                 chunked DDG needs a power-of-two dimension",
                x.len(),
                rot.dim,
            );
            range
        };
        let noise_stream = round.client_coord_stream(client);
        // clip to the l2 ball of radius c, then rotate — an O(d log d)
        // whole-vector transform. A chunked client calls encode_chunk
        // ⌈d/c⌉ times per round, so partial-range calls memoize the
        // rotated vector per (round, client) instead of re-rotating per
        // chunk; the legacy full-range call computes it directly.
        let compute_rotated = || {
            let norm = l2_norm(x);
            let scale = if norm > self.clip_c { self.clip_c / norm } else { 1.0 };
            let clipped: Vec<f64> = x.iter().map(|v| v * scale).collect();
            rot.forward(&clipped)
        };
        let cached;
        let owned;
        let rotated: &[f64] = if full_range {
            owned = compute_rotated();
            &owned
        } else {
            // keyed by (round seed ⊕ data fingerprint, n, dim, client) —
            // the degenerate range client..client+1 carries the client id
            // — with capacity = the window's working set; see the
            // `rot_vec` field docs
            let cap = round
                .n_clients
                .saturating_mul(crate::mechanisms::session::MAX_WINDOW);
            let key = (
                self.rot_key_seed(round.seed, x),
                round.n_clients,
                round.dim,
                client,
                client + 1,
            );
            cached = self.rot_vec.get_or_keyed(key, cap, compute_rotated);
            &cached
        };
        let mut bits = BitsAccount::default();
        let mut ms: Vec<i64> = Vec::with_capacity(desc_range.len());
        for j in desc_range {
            let mut rng = noise_stream.at(j);
            let z = rotated[j] / self.gamma_q;
            // unbiased stochastic rounding
            let fl = z.floor();
            let frac = z - fl;
            let r = fl as i64 + if rng.u01() < frac { 1 } else { 0 };
            // + discrete Gaussian on the lattice
            let noise = discrete_gaussian(&mut rng, self.sigma_lattice);
            let m = r + noise;
            bits.add_description(m);
            ms.push(m);
        }
        bits.fixed_total = Some(self.bits as f64 * ms.len() as f64);
        Descriptions { ms, aux: vec![], bits }
    }
}

impl ServerDecoder for Ddg {
    fn sum_decodable(&self) -> bool {
        true
    }

    fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
        self.decode_survivors(payload, round, &SurvivorSet::full(round.n_clients))
    }

    /// Survivor-aware decode: the survivor sum divides by n′. DDG's
    /// per-client discrete Gaussians were calibrated so that the sum of
    /// *n* of them hits the DP target; with n′ survivors the summed noise
    /// has variance n′σ_c², so the zCDP guarantee degrades by n′/n —
    /// deployments must calibrate σ_c for the minimum expected survivor
    /// count (see the README threat-model section).
    fn decode_survivors(
        &self,
        payload: &Payload,
        round: &SharedRound,
        survivors: &SurvivorSet,
    ) -> Vec<f64> {
        assert_eq!(survivors.n(), round.n_clients, "survivor set shaped for a different fleet");
        let rot = self.rotation(round);
        let m = self.modulus();
        let sum = payload.description_sum();
        assert_eq!(sum.len(), rot.dim);
        // modular semantics of the 2^b uplink: reduce the (possibly exact)
        // sum to its signed representative mod 2^b. Under the SecAgg
        // transport configured with this modulus the value is already
        // reduced and this is the identity — so plain summation and SecAgg
        // decode bit-identically (wraparound happens HERE if b too small).
        let nf = survivors.n_alive() as f64;
        let scaled: Vec<f64> = sum
            .iter()
            .map(|&v| from_field(to_field(v, m), m) as f64 * self.gamma_q / nf)
            .collect();
        rot.inverse(&scaled, round.dim)
    }
}

// §5.2 semantics: the masked modular uplink IS the mechanism
impl_mean_mechanism!(Ddg, |m| m.transport());

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::pipeline::{run_pipeline, Plain};
    use crate::mechanisms::traits::{true_mean, MeanMechanism};
    use crate::util::rng::Rng;
    use crate::util::stats::mse;

    fn sphere_data(n: usize, d: usize, radius: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let v = rng.normal_vec(d);
                let nrm = l2_norm(&v);
                v.into_iter().map(|x| x * radius / nrm).collect()
            })
            .collect()
    }

    #[test]
    fn accurate_with_enough_bits() {
        let n = 20;
        let d = 32;
        let xs = sphere_data(n, d, 1.0, 141);
        let mech = Ddg::new(2.0, 1e-3, 1.0, 24);
        let m = true_mean(&xs);
        let out = mech.aggregate(&xs, 900);
        let e = mse(&out.estimate, &m);
        // noise variance per coordinate ≈ n σ² γ² / n² (tiny here)
        assert!(e < 1e-4, "mse={e}");
    }

    #[test]
    fn wraparound_destroys_accuracy_with_few_bits() {
        let n = 20;
        let d = 32;
        let xs = sphere_data(n, d, 1.0, 142);
        let m = true_mean(&xs);
        let good = Ddg::new(2.0, 1e-3, 1.0, 24).aggregate(&xs, 901);
        let bad = Ddg::new(2.0, 1e-3, 1.0, 10).aggregate(&xs, 901);
        let e_good = mse(&good.estimate, &m);
        let e_bad = mse(&bad.estimate, &m);
        assert!(e_bad > 100.0 * e_good, "good={e_good} bad={e_bad}");
    }

    #[test]
    fn unbiased_at_moderate_noise() {
        let n = 30;
        let d = 16;
        let xs = sphere_data(n, d, 1.0, 143);
        let m = true_mean(&xs);
        let mech = Ddg::new(1.5, 2e-3, 1.0, 22);
        let mut acc = vec![0.0; d];
        let rounds = 300;
        for r in 0..rounds {
            let out = mech.aggregate(&xs, 30_000 + r);
            for j in 0..d {
                acc[j] += out.estimate[j];
            }
        }
        for j in 0..d {
            let avg = acc[j] / rounds as f64;
            assert!((avg - m[j]).abs() < 0.02, "j={j} avg={avg} m={}", m[j]);
        }
    }

    #[test]
    fn calibration_monotone_in_eps() {
        let a = Ddg::calibrated(0.5, 1e-5, 10.0, 500, 75, 18, 0.01);
        let b = Ddg::calibrated(4.0, 1e-5, 10.0, 500, 75, 18, 0.01);
        assert!(b.sigma_lattice < a.sigma_lattice);
    }

    #[test]
    fn secagg_path_used() {
        // the output must equal a direct (unmasked) computation: masks cancel
        let n = 5;
        let d = 8;
        let xs = sphere_data(n, d, 1.0, 144);
        let mech = Ddg::new(1.0, 1e-2, 1.0, 26);
        let o1 = mech.aggregate(&xs, 555);
        let o2 = mech.aggregate(&xs, 555);
        assert_eq!(o1.estimate, o2.estimate);
    }

    #[test]
    fn plain_and_secagg_bit_identical_even_under_wraparound() {
        // the decoder owns the 2^b reduction, so the exact i64 sum (Plain)
        // and the masked modular sum (SecAgg) decode identically — also in
        // the wraparound regime where the modulus actually bites
        let xs = sphere_data(12, 16, 1.0, 145);
        for bits in [10u32, 24] {
            let mech = Ddg::new(2.0, 1e-3, 1.0, bits);
            let plain = run_pipeline(&mech, &Plain, &mech, &xs, 770);
            let masked = run_pipeline(&mech, &mech.transport(), &mech, &xs, 770);
            assert_eq!(plain.estimate, masked.estimate, "bits={bits}");
        }
    }
}
