//! The chunked ≡ unchunked property matrix: for every homomorphic
//! mechanism, over Plain AND SecAgg, composed with announced dropouts and
//! sampled cohorts, the chunk-streamed window must be *bit-identical* —
//! estimates and bit accounting — to the whole-d batched window for chunk
//! sizes {1, 7, d, d + 3}. This is the seed-format guarantee of the
//! chunked pipeline: every per-coordinate stream is seekable
//! (`Rng::derive_coord`), so chunk boundaries cannot change any drawn bit
//! (docs/determinism.md has the argument).
//!
//! The KS companions check that the *exact error laws* — the paper's
//! whole point — survive the chunked path verbatim: the aggregate
//! Gaussian stays exactly N(0, (σn/n′)²) and Irwin–Hall stays exactly
//! IH(n) at the rescaled scale, decoded chunk by chunk under dropouts.

use exact_comp::coordinator::sampling::SamplingPolicy;
use exact_comp::dist::{Continuous, Gaussian, IrwinHall};
use exact_comp::mechanisms::pipeline::{Plain, SecAgg, SurvivorSet};
use exact_comp::mechanisms::session::run_window_chunked;
use exact_comp::mechanisms::{AggregateGaussian, IrwinHallMechanism};
use exact_comp::testing::{assert_chunked_window_matches_unchunked, dropout_schedule, Fleet};

/// Chunk sizes of the acceptance matrix for a given d: {1, 7, d, d + 3}.
fn matrix_chunks(d: usize) -> Vec<usize> {
    vec![1, 7, d, d + 3]
}

/// One dropout schedule per matrix cell: round 0 clean, round 1 loses one
/// cohort member (derived from the policy so the schedule is valid).
fn one_dropout_schedule(policy: &SamplingPolicy, session_seed: u64, n: usize) -> Vec<Vec<usize>> {
    (0..2u64)
        .map(|r| {
            if r == 1 {
                let cohort = policy.cohort(session_seed, r, n);
                if cohort.n_alive() >= 2 {
                    return vec![cohort.alive_iter().next().unwrap()];
                }
            }
            Vec::new()
        })
        .collect()
}

#[test]
fn chunked_matrix_irwin_hall_plain_and_secagg() {
    let (n, d) = (6usize, 11usize);
    let fleet = Fleet::new(n, d, 0x1A4);
    let mech = IrwinHallMechanism::new(0.4, 8.0);
    for (policy, seed) in [
        (SamplingPolicy::Full, 0xA1u64),
        (SamplingPolicy::FixedSize { k: 4 }, 0xA2),
    ] {
        let dropouts = one_dropout_schedule(&policy, seed, n);
        assert_chunked_window_matches_unchunked(
            &mech, &Plain, &fleet, &policy, &dropouts, seed, &matrix_chunks(d),
        );
        assert_chunked_window_matches_unchunked(
            &mech, &SecAgg::new(), &fleet, &policy, &dropouts, seed, &matrix_chunks(d),
        );
    }
}

#[test]
fn chunked_matrix_aggregate_gaussian_plain_and_secagg() {
    let (n, d) = (7usize, 11usize);
    let fleet = Fleet::new(n, d, 0xB0);
    let mech = AggregateGaussian::new(0.6, 8.0);
    for (policy, seed) in [
        (SamplingPolicy::Full, 0xB1u64),
        (SamplingPolicy::Poisson { gamma: 0.7 }, 0xB2),
    ] {
        let dropouts = one_dropout_schedule(&policy, seed, n);
        assert_chunked_window_matches_unchunked(
            &mech, &Plain, &fleet, &policy, &dropouts, seed, &matrix_chunks(d),
        );
        assert_chunked_window_matches_unchunked(
            &mech, &SecAgg::new(), &fleet, &policy, &dropouts, seed, &matrix_chunks(d),
        );
    }
}

#[test]
fn chunked_matrix_csgm_plain_and_secagg() {
    let (n, d) = (6usize, 11usize);
    let fleet = Fleet::new(n, d, 0xC0);
    let mech = exact_comp::baselines::Csgm::new(0.2, 0.6, 4.0, 6);
    for (policy, seed) in [
        (SamplingPolicy::Full, 0xC1u64),
        (SamplingPolicy::FixedSize { k: 5 }, 0xC2),
    ] {
        let dropouts = one_dropout_schedule(&policy, seed, n);
        assert_chunked_window_matches_unchunked(
            &mech, &Plain, &fleet, &policy, &dropouts, seed, &matrix_chunks(d),
        );
        assert_chunked_window_matches_unchunked(
            &mech, &SecAgg::new(), &fleet, &policy, &dropouts, seed, &matrix_chunks(d),
        );
    }
}

#[test]
fn chunked_matrix_ddg_over_its_own_modular_secagg() {
    // DDG chunks its description space, which is the rotation's padded
    // power-of-two dimension — so the matrix runs at d = 8 (see the
    // encode_chunk caveat in baselines/ddg.rs). Its decoder needs the
    // whole-d sum (inverse rotation), exercising the streamed runner's
    // assemble-then-decode path.
    let (n, d) = (6usize, 8usize);
    let fleet = Fleet::new(n, d, 0xD0).with_range(-1.0, 1.0);
    let mech = exact_comp::baselines::Ddg::new(1.5, 1e-2, 4.0, 26);
    for (policy, seed) in [
        (SamplingPolicy::Full, 0xD1u64),
        (SamplingPolicy::FixedSize { k: 4 }, 0xD2),
    ] {
        let dropouts = one_dropout_schedule(&policy, seed, n);
        assert_chunked_window_matches_unchunked(
            &mech, &Plain, &fleet, &policy, &dropouts, seed, &matrix_chunks(d),
        );
        assert_chunked_window_matches_unchunked(
            &mech,
            &mech.transport(),
            &fleet,
            &policy,
            &dropouts,
            seed,
            &matrix_chunks(d),
        );
    }
}

/// The CI chunk suite: a fixed seed matrix — 3 seeds × chunk ∈ {1, 64, d}
/// — every cell's W=3 chunked SecAgg window (with ⌈n/4⌉ dropouts per
/// round) must be bit-identical to the whole-d batched window.
/// (`scripts/ci.sh` runs this by name; keep `chunked` in the test names.)
#[test]
fn chunked_seed_matrix_windows_close_exactly() {
    let n = 9;
    let d = 96;
    for seed in [11u64, 22, 33] {
        let fleet = Fleet::new(n, d, seed);
        let schedule = dropout_schedule(n, 3, n.div_ceil(4), seed ^ 0xC4);
        assert_chunked_window_matches_unchunked(
            &AggregateGaussian::new(0.5, 8.0),
            &SecAgg::new(),
            &fleet,
            &SamplingPolicy::Full,
            &schedule,
            seed,
            &[1, 64, d],
        );
        assert_chunked_window_matches_unchunked(
            &IrwinHallMechanism::new(0.4, 8.0),
            &SecAgg::new(),
            &fleet,
            &SamplingPolicy::Full,
            &schedule,
            seed ^ 1,
            &[1, 64, d],
        );
    }
}

/// KS exactness on the CHUNKED path: the aggregate Gaussian's survivor
/// error, decoded chunk by chunk (c = 3 over d = 4 — a ragged final
/// chunk) under an announced dropout, is STILL exactly N(0, (σ·n/n′)²).
#[test]
fn chunked_gaussian_error_is_exactly_gaussian_under_dropouts() {
    let sigma = 0.5;
    let n = 6;
    let d = 4;
    let fleet = Fleet::new(n, d, 0xF00D);
    let xs = fleet.round_data(0);
    let dropped = vec![3usize];
    let survivors = SurvivorSet::with_dropped(n, &dropped);
    let smean = fleet.survivor_mean(0, &survivors);
    let mech = AggregateGaussian::new(sigma, 8.0);
    let mut errs = Vec::new();
    for r in 0..900u64 {
        let seed = 90_000 + r;
        let out = run_window_chunked(
            &mech,
            &SecAgg::new(),
            &mech,
            &[(xs.as_slice(), seed)],
            seed,
            &[SurvivorSet::full(n)],
            &[dropped.clone()],
            3,
        );
        for j in 0..d {
            errs.push(out[0].estimate[j] - smean[j]);
        }
    }
    let rescaled_sd = sigma * n as f64 / survivors.n_alive() as f64;
    let g = Gaussian::new(0.0, rescaled_sd);
    let res = exact_comp::util::stats::ks_test(&errs, |e| g.cdf(e));
    assert!(res.p_value > 0.003, "chunked exactness violated: p={}", res.p_value);
    let v = exact_comp::util::stats::variance(&errs);
    assert!((v - rescaled_sd * rescaled_sd).abs() < 0.03, "var={v}");
}

/// Irwin–Hall companion: the chunked decode keeps the exact n-term IH law
/// at scale σ·n/n′ against the survivor mean, chunk size 1 (every
/// coordinate its own chunk).
#[test]
fn chunked_irwin_hall_error_is_exactly_irwin_hall_under_dropouts() {
    let sigma = 0.6;
    let n = 8;
    let d = 4;
    let fleet = Fleet::new(n, d, 0xABBA);
    let xs = fleet.round_data(0);
    let dropped = vec![5usize];
    let survivors = SurvivorSet::with_dropped(n, &dropped);
    let smean = fleet.survivor_mean(0, &survivors);
    let mech = IrwinHallMechanism::new(sigma, 8.0);
    let mut errs = Vec::new();
    for r in 0..800u64 {
        let seed = 50_000 + r;
        let out = run_window_chunked(
            &mech,
            &SecAgg::new(),
            &mech,
            &[(xs.as_slice(), seed)],
            seed,
            &[SurvivorSet::full(n)],
            &[dropped.clone()],
            1,
        );
        for j in 0..d {
            errs.push(out[0].estimate[j] - smean[j]);
        }
    }
    let scale = sigma * n as f64 / survivors.n_alive() as f64;
    let ih = IrwinHall::new(n as u64, 0.0, scale);
    let res = exact_comp::util::stats::ks_test(&errs, |e| ih.cdf(e));
    assert!(res.p_value > 0.003, "chunked IH exactness violated: p={}", res.p_value);
    let v = exact_comp::util::stats::variance(&errs);
    assert!((v - scale * scale).abs() < 0.1, "var={v}");
}

/// The non-chunk-capable mechanisms still ride the chunked runner under
/// the single-chunk plan — c = d IS the legacy path for every mechanism.
#[test]
fn chunked_single_chunk_plan_covers_non_chunkable_mechanisms() {
    use exact_comp::mechanisms::pipeline::Unicast;
    use exact_comp::mechanisms::session::run_window_sampled;
    use exact_comp::mechanisms::{IndividualGaussian, LayeredVariant, Sigm};
    use exact_comp::util::rng::{seed_domain, Rng};
    let (n, d) = (5usize, 6usize);
    let fleet = Fleet::new(n, d, 0xE0);
    let datasets: Vec<Vec<Vec<f64>>> = (0..2).map(|r| fleet.round_data(r as u64)).collect();
    let seeds: Vec<u64> =
        (0..2).map(|r| Rng::derive_domain(0xE1, seed_domain::ROUND, r as u64)).collect();
    let rounds: Vec<(&[Vec<f64>], u64)> =
        datasets.iter().zip(&seeds).map(|(xs, &s)| (xs.as_slice(), s)).collect();
    let cohorts = vec![SurvivorSet::full(n); 2];
    let none: Vec<Vec<usize>> = vec![Vec::new(); 2];
    let sigm = Sigm::new(0.3, 0.5, 4.0);
    let indiv = IndividualGaussian::new(0.3, LayeredVariant::Shifted, 4.0);
    let whole_sigm =
        run_window_sampled(&sigm, &Unicast, &sigm, &rounds, 0xE1, &cohorts, &none);
    let chunked_sigm =
        run_window_chunked(&sigm, &Unicast, &sigm, &rounds, 0xE1, &cohorts, &none, d);
    for (a, b) in whole_sigm.iter().zip(&chunked_sigm) {
        assert_eq!(a.estimate, b.estimate);
        assert_eq!(a.bits.messages, b.bits.messages);
    }
    let whole_ind =
        run_window_sampled(&indiv, &Unicast, &indiv, &rounds, 0xE2, &cohorts, &none);
    let chunked_ind =
        run_window_chunked(&indiv, &Unicast, &indiv, &rounds, 0xE2, &cohorts, &none, d + 5);
    for (a, b) in whole_ind.iter().zip(&chunked_ind) {
        assert_eq!(a.estimate, b.estimate);
        assert_eq!(a.bits.messages, b.bits.messages);
    }
}
