//! Hand-rolled CLI argument parsing (clap is not in the offline registry).
//!
//! Grammar: `repro <subcommand> [--flag value]... [--switch]... [positional]`.
//! Flags may be `--key value` or `--key=value`; unknown flags are collected
//! and can be rejected by the subcommand.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    pub fn parse(raw: impl Iterator<Item = String>) -> Self {
        let mut a = Args::default();
        let items: Vec<String> = raw.collect();
        let mut i = 0;
        while i < items.len() {
            let it = &items[i];
            if let Some(stripped) = it.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    a.flags.insert(stripped.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    a.switches.push(stripped.to_string());
                }
            } else if a.subcommand.is_none() {
                a.subcommand = Some(it.clone());
            } else {
                a.positional.push(it.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key) || self.flags.contains_key(key)
    }

    /// Typed flag getters on the shared loud-fail contract
    /// ([`crate::util::parse_or_panic`], same as
    /// `coordinator::config::Config`): a missing flag takes the default,
    /// a present-but-malformed value panics — a typo'd `--sigma O.25`
    /// must not silently run at the default.
    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T, expected: &str) -> T {
        crate::util::parse_or_panic(self.get(key), default, &format!("flag --{key}"), expected)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_or(key, default, "a float")
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_or(key, default, "a non-negative integer")
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_or(key, default, "a non-negative integer")
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Comma-separated list flag: `--ns 100,500,1000`.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("figures --fig 5 --out-dir results --all");
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get("fig"), Some("5"));
        assert_eq!(a.str_or("out-dir", "x"), "results");
        assert!(a.has("all"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("train --sigma=0.5 --rounds=100");
        assert_eq!(a.f64_or("sigma", 0.0), 0.5);
        assert_eq!(a.usize_or("rounds", 0), 100);
    }

    #[test]
    #[should_panic(expected = "malformed value")]
    fn malformed_flag_value_is_loud_not_a_silent_default() {
        // regression: `--sigma O.5` used to silently run at the default
        let a = parse("train --sigma O.5");
        let _ = a.f64_or("sigma", 0.1);
    }

    #[test]
    fn lists() {
        let a = parse("bench --ns 100,500,1000");
        assert_eq!(a.list_or("ns", &[]), vec!["100", "500", "1000"]);
        assert_eq!(a.list_or("ds", &["75"]), vec!["75"]);
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse("run exp1 exp2");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["exp1", "exp2"]);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("x --verbose");
        assert!(a.has("verbose"));
    }
}
