//! The threaded FL round runtime: a persistent pool of client workers that
//! compute local updates in parallel, plus the round loops that feed those
//! updates through a mechanism and apply the aggregated result.
//!
//! Threading model: clients are multiplexed onto
//! min(n_clients, `std::thread::available_parallelism()`) long-lived worker
//! threads (override with [`ClientPool::spawn_with_threads`], e.g. to pin
//! bench runs), each owning a contiguous shard of clients.
//!
//! Two round shapes:
//!
//! * [`run_round`] — legacy/monolithic: shards compute local vectors, the
//!   orchestrator materializes all of them and calls
//!   [`MeanMechanism::aggregate`]. O(n·d) orchestrator memory.
//! * [`run_rounds_encoded`] — the pipeline/session shape: shards *encode*
//!   their own clients ([`ClientEncoder`] runs inside the worker) for a
//!   whole window of W rounds, fold the messages into per-shard, per-round
//!   [`TransportPartial`]s and fold bit accounting locally; the
//!   orchestrator only merges shard partials into one
//!   [`TransportSession`] ring and batch-decodes at window close. With a
//!   summing transport the orchestrator state is O(W·d) — it never sees a
//!   client vector or a per-client description. [`run_round_encoded`] is
//!   the W=1 special case.
//!
//! ## The session/window model
//!
//! A window is one [`TransportSession`]: the transport opens once, every
//! round's mask schedule derives from the window's session seed
//! ([`crate::mechanisms::session::derive_session_seed`] of the run's root
//! seed), shards ship ONE message per window instead of one per round, and
//! the unmask is batched. The broadcast `state` is constant across the
//! window — batching trades per-round feedback for amortized transport,
//! the high-frequency FL regime — while `LocalCompute` still sees each
//! round index. Windowed and independent rounds produce bit-identical
//! estimates (property tested).
//!
//! Real fleets lose clients mid-window:
//! [`run_rounds_encoded_with_dropouts`] takes a per-round dropout
//! schedule, skips dropped clients inside their shard, announces them at
//! window close with the survivors' recovery shares, and decodes each
//! round over its true survivor set n′ (estimates and `true_mean` are
//! both survivor quantities; dropout-aware mechanisms rescale their error
//! to n′ — see
//! [`crate::mechanisms::pipeline::ServerDecoder::decode_survivors`]).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::mechanisms::pipeline::{
    ClientEncoder, ServerDecoder, SharedRound, SurvivorSet, Transport, TransportPartial,
};
use crate::mechanisms::session::{
    derive_session_seed, session_round_transports, RoundDropouts, TransportSession,
};
use crate::mechanisms::traits::{BitsAccount, MeanMechanism, RoundOutput};

/// Client-local computation: produce this round's vector from the broadcast
/// global state. Implementations must be deterministic in (round, state)
/// for reproducible runs.
pub trait LocalCompute: Send + Sync + 'static {
    /// `client` is the global client index.
    fn local_update(&self, client: usize, round: u64, state: &[f64]) -> Vec<f64>;
}

impl<F> LocalCompute for F
where
    F: Fn(usize, u64, &[f64]) -> Vec<f64> + Send + Sync + 'static,
{
    fn local_update(&self, client: usize, round: u64, state: &[f64]) -> Vec<f64> {
        self(client, round, state)
    }
}

enum ShardMsg {
    Compute {
        round: u64,
        state: Arc<Vec<f64>>,
    },
    /// Compute AND encode a whole window of rounds: the per-client vectors
    /// never leave the shard, and the shard answers with ONE message per
    /// window (not per round) — the channel-traffic amortization of the
    /// batched session.
    EncodeWindow {
        start_round: u64,
        state: Arc<Vec<f64>>,
        /// per-round shared-randomness seeds, `seeds.len()` = window W
        seeds: Arc<Vec<u64>>,
        /// per-round announced dropouts (global client ids): a dropped
        /// client is skipped entirely — never computed, never encoded
        dropouts: Arc<Vec<Vec<usize>>>,
        encoder: Arc<dyn ClientEncoder>,
        /// per-round session-rekeyed transports (same schedule the
        /// orchestrator's session will unmask)
        transports: Arc<Vec<Arc<dyn Transport>>>,
    },
    Shutdown,
}

/// One round's shard-local fold: the uplink partial, bit accounting, the
/// Σ of the shard's surviving client vectors (true-mean metric folding)
/// and WHICH survivors the shard folded (global ids, per round since
/// dropouts vary round to round — the session records them so the
/// fail-closed checks cover the folded path too).
struct ShardRoundFold {
    /// `None` when every client of the shard dropped this round
    partial: Option<TransportPartial>,
    bits: BitsAccount,
    x_sum: Vec<f64>,
    clients: Vec<usize>,
}

enum ShardResult {
    Computed {
        start: usize,
        vecs: Vec<Vec<f64>>,
    },
    EncodedWindow {
        start: usize,
        rounds: Vec<ShardRoundFold>,
    },
}

struct Shard {
    tx: mpsc::Sender<ShardMsg>,
    handle: Option<JoinHandle<()>>,
}

/// Persistent pool of client workers.
pub struct ClientPool {
    shards: Vec<Shard>,
    results_rx: mpsc::Receiver<ShardResult>,
    pub n_clients: usize,
}

impl ClientPool {
    /// Spawn a pool over `n_clients` clients evaluating `compute`, with
    /// min(n_clients, available_parallelism) workers.
    pub fn spawn(n_clients: usize, compute: Arc<dyn LocalCompute>) -> Self {
        Self::spawn_with_threads(n_clients, compute, None)
    }

    /// Like [`Self::spawn`] but with an explicit worker-thread count
    /// (benches pin this for stable numbers across machines).
    pub fn spawn_with_threads(
        n_clients: usize,
        compute: Arc<dyn LocalCompute>,
        threads: Option<usize>,
    ) -> Self {
        assert!(n_clients > 0);
        let threads = threads
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
            })
            .min(n_clients)
            .max(1);
        let per = n_clients.div_ceil(threads);
        let (results_tx, results_rx) = mpsc::channel();
        let mut shards = Vec::new();
        for s in 0..threads {
            let lo = s * per;
            let hi = ((s + 1) * per).min(n_clients);
            if lo >= hi {
                break;
            }
            let (tx, rx) = mpsc::channel::<ShardMsg>();
            let results_tx = results_tx.clone();
            let compute = compute.clone();
            let range2 = lo..hi;
            let handle = std::thread::Builder::new()
                .name(format!("fl-shard-{s}"))
                .spawn(move || {
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            ShardMsg::Compute { round, state } => {
                                let vecs: Vec<Vec<f64>> = range2
                                    .clone()
                                    .map(|c| compute.local_update(c, round, &state))
                                    .collect();
                                if results_tx
                                    .send(ShardResult::Computed { start: range2.start, vecs })
                                    .is_err()
                                {
                                    return;
                                }
                            }
                            ShardMsg::EncodeWindow {
                                start_round,
                                state,
                                seeds,
                                dropouts,
                                encoder,
                                transports,
                            } => {
                                let mut rounds = Vec::with_capacity(seeds.len());
                                for (r, (&seed, transport)) in
                                    seeds.iter().zip(transports.iter()).enumerate()
                                {
                                    let round = start_round + r as u64;
                                    let dropped = &dropouts[r];
                                    let mut partial: Option<TransportPartial> = None;
                                    let mut bits = BitsAccount::default();
                                    let mut x_sum: Vec<f64> = Vec::new();
                                    let mut clients: Vec<usize> = Vec::new();
                                    for c in range2.clone() {
                                        if dropped.contains(&c) {
                                            // announced dropout: no local
                                            // compute, no encode, no count
                                            continue;
                                        }
                                        let x = compute.local_update(c, round, &state);
                                        if x_sum.is_empty() {
                                            x_sum = vec![0.0; x.len()];
                                        }
                                        assert_eq!(
                                            x.len(),
                                            x_sum.len(),
                                            "ragged client vectors"
                                        );
                                        for (a, v) in x_sum.iter_mut().zip(&x) {
                                            *a += v;
                                        }
                                        let shared =
                                            SharedRound::new(seed, n_clients, x.len());
                                        let part = partial
                                            .get_or_insert_with(|| transport.empty(&shared));
                                        let d = encoder.encode(c, &x, &shared);
                                        bits.merge(&d.bits);
                                        transport.submit(part, c, &d, &shared);
                                        clients.push(c);
                                    }
                                    rounds.push(ShardRoundFold { partial, bits, x_sum, clients });
                                }
                                if results_tx
                                    .send(ShardResult::EncodedWindow {
                                        start: range2.start,
                                        rounds,
                                    })
                                    .is_err()
                                {
                                    return;
                                }
                            }
                            ShardMsg::Shutdown => return,
                        }
                    }
                })
                .expect("spawning shard thread");
            shards.push(Shard { tx, handle: Some(handle) });
        }
        Self { shards, results_rx, n_clients }
    }

    /// Compute all clients' local vectors for one round (parallel).
    pub fn compute_round(&self, round: u64, state: &[f64]) -> Vec<Vec<f64>> {
        let state = Arc::new(state.to_vec());
        for shard in &self.shards {
            shard
                .tx
                .send(ShardMsg::Compute { round, state: state.clone() })
                .expect("shard died");
        }
        let mut out: Vec<Option<Vec<f64>>> = vec![None; self.n_clients];
        for _ in 0..self.shards.len() {
            match self.results_rx.recv().expect("shard result") {
                ShardResult::Computed { start, vecs } => {
                    for (off, v) in vecs.into_iter().enumerate() {
                        out[start + off] = Some(v);
                    }
                }
                ShardResult::EncodedWindow { .. } => {
                    unreachable!("encode result during a compute round")
                }
            }
        }
        out.into_iter().map(|v| v.expect("missing client result")).collect()
    }
}

impl Drop for ClientPool {
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Outcome of one orchestrated round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub round: u64,
    pub output: RoundOutput,
    /// exact mean of the *surviving* clients' vectors (for MSE metrics; a
    /// real server cannot see this — test/metric use only)
    pub true_mean: Vec<f64>,
    /// how many clients the round actually closed over (n′ ≤ n; equals
    /// the fleet size on dropout-free rounds)
    pub survivors: usize,
}

/// Per-round seed derivation shared by both round shapes.
fn round_seed(root_seed: u64, round: u64) -> u64 {
    root_seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Run one round, monolith shape: parallel local compute, then the
/// mechanism's in-process aggregate. O(n·d) orchestrator memory.
pub fn run_round(
    pool: &ClientPool,
    mech: &dyn MeanMechanism,
    round: u64,
    state: &[f64],
    root_seed: u64,
) -> RoundReport {
    let xs = pool.compute_round(round, state);
    let true_mean = crate::mechanisms::traits::true_mean(&xs);
    let output = mech.aggregate(&xs, round_seed(root_seed, round));
    let survivors = xs.len();
    RoundReport { round, output, true_mean, survivors }
}

/// Run a window of W rounds through ONE transport session, pipeline
/// shape: every shard computes AND encodes its own clients for all W
/// rounds (one channel message per shard per window), the orchestrator
/// folds shard partials into the session's ring of per-round accumulators
/// and batch-decodes at window close. With a summing transport the
/// orchestrator holds O(W·d) state and never sees a client vector or a
/// per-client description. Returns one [`RoundReport`] per round, in
/// round order.
pub fn run_rounds_encoded(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
) -> Vec<RoundReport> {
    assert!(window > 0, "a session window needs at least one round");
    let none: Vec<Vec<usize>> = vec![Vec::new(); window];
    run_rounds_encoded_with_dropouts(
        pool, encoder, transport, decoder, start_round, window, state, root_seed, &none,
    )
}

/// [`run_rounds_encoded`] under a per-round dropout schedule:
/// `dropouts[r]` names the clients that drop in round `start_round + r`
/// of the window. Dropped clients are skipped inside their shard (never
/// computed, never encoded); at window close the orchestrator announces
/// them with the survivors' recovery shares
/// ([`RoundDropouts::announce`]), the session reconstructs their
/// outstanding masks, and each round decodes over its true survivor set
/// ([`ServerDecoder::decode_survivors`]) — so the reported `true_mean`
/// and estimate are both survivor-set quantities. An empty schedule IS
/// `run_rounds_encoded`, bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_encoded_with_dropouts(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    dropouts: &[Vec<usize>],
) -> Vec<RoundReport> {
    assert!(window > 0, "a session window needs at least one round");
    assert!(
        window <= crate::mechanisms::session::MAX_WINDOW,
        "session window of {window} rounds exceeds MAX_WINDOW ({}) — split the run into \
         multiple windows",
        crate::mechanisms::session::MAX_WINDOW,
    );
    assert!(
        !transport.sum_only() || decoder.sum_decodable(),
        "mechanism is not homomorphic: it cannot decode from a sum-only transport"
    );
    assert_eq!(
        dropouts.len(),
        window,
        "dropout schedule must cover every round of the window"
    );
    // validate the schedule before any shard does work (fail closed)
    let survivor_sets: Vec<SurvivorSet> =
        dropouts.iter().map(|d| SurvivorSet::with_dropped(pool.n_clients, d)).collect();
    let session_seed = derive_session_seed(root_seed, start_round);
    let seeds: Arc<Vec<u64>> = Arc::new(
        (0..window).map(|r| round_seed(root_seed, start_round + r as u64)).collect(),
    );
    // the shards must mask with the exact schedule the session will unmask:
    // both sides derive it from (transport, session_seed, W) alone
    let transports: Arc<Vec<Arc<dyn Transport>>> =
        Arc::new(session_round_transports(transport.as_ref(), session_seed, window));
    let dropouts_arc: Arc<Vec<Vec<usize>>> = Arc::new(dropouts.to_vec());
    let state = Arc::new(state.to_vec());
    for shard in &pool.shards {
        shard
            .tx
            .send(ShardMsg::EncodeWindow {
                start_round,
                state: state.clone(),
                seeds: seeds.clone(),
                dropouts: dropouts_arc.clone(),
                encoder: encoder.clone(),
                transports: transports.clone(),
            })
            .expect("shard died");
    }
    // collect shard windows; fold x-sums in shard order so the true-mean
    // metric is deterministic regardless of arrival order
    let mut pieces: Vec<(usize, Vec<ShardRoundFold>)> = Vec::with_capacity(pool.shards.len());
    for _ in 0..pool.shards.len() {
        match pool.results_rx.recv().expect("shard result") {
            ShardResult::EncodedWindow { start, rounds } => {
                pieces.push((start, rounds));
            }
            ShardResult::Computed { .. } => {
                unreachable!("compute result during an encoded round")
            }
        }
    }
    pieces.sort_by_key(|&(start, _)| start);
    // every round has >= 1 survivor (SurvivorSet guarantees it), so some
    // shard-round fold carries a dimension
    let dim = pieces
        .iter()
        .flat_map(|(_, rounds)| rounds.iter())
        .find(|f| !f.x_sum.is_empty())
        .map(|f| f.x_sum.len())
        .expect("every round has at least one survivor");
    let mut session = TransportSession::open(
        transport.as_ref(),
        session_seed,
        pool.n_clients,
        dim,
        seeds.as_slice(),
    );
    let mut x_sums = vec![vec![0.0f64; dim]; window];
    for (_, rounds) in pieces {
        assert_eq!(rounds.len(), window, "shard returned a different window");
        for (r, fold) in rounds.into_iter().enumerate() {
            for (a, v) in x_sums[r].iter_mut().zip(&fold.x_sum) {
                *a += v;
            }
            match fold.partial {
                Some(p) => session.fold_partial(r, p, &fold.clients, &fold.bits),
                None => assert!(fold.clients.is_empty(), "shard lost a partial"),
            }
        }
    }
    // announce the schedule with the survivors' recovery shares (the
    // in-process analogue of the share-collection phase)
    let announced: Vec<RoundDropouts> = survivor_sets
        .iter()
        .enumerate()
        .map(|(r, s)| RoundDropouts::announce(session_seed, r as u64, s))
        .collect();
    let shared: Vec<SharedRound> = (0..window).map(|r| *session.round(r)).collect();
    session
        .close_with_dropouts(&announced)
        .into_iter()
        .zip(shared)
        .zip(x_sums)
        .enumerate()
        .map(|(r, (((payload, bits, survivors), round), x_sum))| {
            let estimate = decoder.decode_survivors(&payload, &round, &survivors);
            let n_alive = survivors.n_alive();
            let true_mean: Vec<f64> =
                x_sum.into_iter().map(|v| v / n_alive as f64).collect();
            RoundReport {
                round: start_round + r as u64,
                output: RoundOutput { estimate, bits },
                true_mean,
                survivors: n_alive,
            }
        })
        .collect()
}

/// Run one round, pipeline shape — the W=1 special case of
/// [`run_rounds_encoded`].
pub fn run_round_encoded(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    round: u64,
    state: &[f64],
    root_seed: u64,
) -> RoundReport {
    run_rounds_encoded(pool, encoder, transport, decoder, round, 1, state, root_seed)
        .pop()
        .expect("one round in, one round out")
}

/// Convenience wrapper for mechanisms that implement both pipeline ends
/// (every mechanism in this crate does).
pub fn run_round_mech<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    round: u64,
    state: &[f64],
    root_seed: u64,
) -> RoundReport
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    run_round_encoded(pool, encoder, transport, mech, round, state, root_seed)
}

/// Windowed convenience wrapper: one transport session over W rounds for a
/// mechanism implementing both pipeline ends.
pub fn run_rounds_mech<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
) -> Vec<RoundReport>
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    run_rounds_encoded(pool, encoder, transport, mech, start_round, window, state, root_seed)
}

/// Windowed convenience wrapper with a per-round dropout schedule (see
/// [`run_rounds_encoded_with_dropouts`]).
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_mech_with_dropouts<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    dropouts: &[Vec<usize>],
) -> Vec<RoundReport>
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    run_rounds_encoded_with_dropouts(
        pool, encoder, transport, mech, start_round, window, state, root_seed, dropouts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::pipeline::{Plain, SecAgg};
    use crate::mechanisms::{AggregateGaussian, IrwinHallMechanism, MeanMechanism};

    #[test]
    fn pool_computes_all_clients() {
        let pool = ClientPool::spawn(
            23,
            Arc::new(|c: usize, r: u64, s: &[f64]| vec![c as f64, r as f64, s[0]]),
        );
        let out = pool.compute_round(5, &[7.0]);
        assert_eq!(out.len(), 23);
        for (c, v) in out.iter().enumerate() {
            assert_eq!(v, &vec![c as f64, 5.0, 7.0]);
        }
    }

    #[test]
    fn pool_reusable_across_rounds() {
        let pool = ClientPool::spawn(8, Arc::new(|c: usize, r: u64, _: &[f64]| vec![(c + r as usize) as f64]));
        for round in 0..10 {
            let out = pool.compute_round(round, &[]);
            assert_eq!(out[3][0], 3.0 + round as f64);
        }
    }

    #[test]
    fn run_round_aggregates() {
        let pool = ClientPool::spawn(16, Arc::new(|c: usize, _: u64, _: &[f64]| vec![c as f64; 4]));
        let mech = IrwinHallMechanism::new(0.05, 64.0);
        let rep = run_round(&pool, &mech, 0, &[], 42);
        // true mean of 0..15 = 7.5; estimate within a few noise sd
        for j in 0..4 {
            assert!((rep.true_mean[j] - 7.5).abs() < 1e-12);
            assert!((rep.output.estimate[j] - 7.5).abs() < 1.0, "est {}", rep.output.estimate[j]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let pool = ClientPool::spawn(4, Arc::new(|c: usize, _: u64, _: &[f64]| vec![c as f64]));
        let mech = IrwinHallMechanism::new(0.1, 8.0);
        let a = run_round(&pool, &mech, 3, &[], 99);
        let b = run_round(&pool, &mech, 3, &[], 99);
        assert_eq!(a.output.estimate, b.output.estimate);
    }

    #[test]
    fn single_client_pool() {
        let pool = ClientPool::spawn(1, Arc::new(|_: usize, _: u64, _: &[f64]| vec![1.0]));
        assert_eq!(pool.compute_round(0, &[]), vec![vec![1.0]]);
    }

    #[test]
    fn threads_override_respected_and_equivalent() {
        // same round under different worker counts: identical estimates
        // (integer partials are order-free, x-sums fold in shard order)
        let compute = |c: usize, _: u64, _: &[f64]| {
            let mut rng = crate::util::rng::Rng::derive(4242, c as u64);
            (0..6).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
        };
        let mech = IrwinHallMechanism::new(0.2, 4.0);
        let mut estimates = Vec::new();
        for threads in [1usize, 3, 7] {
            let pool =
                ClientPool::spawn_with_threads(13, Arc::new(compute), Some(threads));
            assert!(pool.shards.len() <= threads);
            let rep = run_round_mech(&pool, &mech, Arc::new(Plain), 2, &[], 77);
            estimates.push(rep.output.estimate.clone());
        }
        assert_eq!(estimates[0], estimates[1]);
        assert_eq!(estimates[0], estimates[2]);
    }

    #[test]
    fn encoded_round_matches_monolithic_round() {
        // per-shard encoding must reproduce MeanMechanism::aggregate bit
        // for bit (same streams, same integer sums)
        let compute = |c: usize, r: u64, _: &[f64]| {
            let mut rng = crate::util::rng::Rng::derive(900 + r, c as u64);
            (0..5).map(|_| rng.uniform(-3.0, 3.0)).collect::<Vec<f64>>()
        };
        let pool = ClientPool::spawn(11, Arc::new(compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        for round in 0..4u64 {
            let mono = run_round(&pool, &mech, round, &[], 5);
            let enc = run_round_mech(&pool, &mech, Arc::new(Plain), round, &[], 5);
            assert_eq!(mono.output.estimate, enc.output.estimate, "round {round}");
            assert_eq!(mono.output.bits.messages, enc.output.bits.messages);
            assert!(
                (mono.output.bits.variable_total - enc.output.bits.variable_total).abs()
                    < 1e-9
            );
            for (a, b) in mono.true_mean.iter().zip(&enc.true_mean) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn encoded_round_through_secagg_matches_plain() {
        let compute = |c: usize, _: u64, _: &[f64]| {
            let mut rng = crate::util::rng::Rng::derive(31, c as u64);
            (0..4).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
        };
        let pool = ClientPool::spawn(9, Arc::new(compute));
        let mech = AggregateGaussian::new(0.4, 4.0);
        let plain = run_round_mech(&pool, &mech, Arc::new(Plain), 1, &[], 11);
        let masked = run_round_mech(&pool, &mech, Arc::new(SecAgg::new()), 1, &[], 11);
        assert_eq!(plain.output.estimate, masked.output.estimate);
    }

    #[test]
    fn pool_drop_joins_threads() {
        for _ in 0..3 {
            let pool = ClientPool::spawn(9, Arc::new(|_: usize, _: u64, _: &[f64]| vec![1.0]));
            let _ = pool.compute_round(0, &[]);
            drop(pool);
        }
    }

    fn round_varying_compute(c: usize, r: u64, _: &[f64]) -> Vec<f64> {
        let mut rng = crate::util::rng::Rng::derive(6000 + r, c as u64);
        (0..5).map(|_| rng.uniform(-3.0, 3.0)).collect()
    }

    #[test]
    fn windowed_rounds_match_sequential_single_rounds() {
        // a W=4 window over Plain is bit-identical to 4 sequential W=1
        // calls: same per-round seeds, same estimates, bits and true means
        let pool = ClientPool::spawn(10, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let windowed = run_rounds_mech(&pool, &mech, Arc::new(Plain), 2, 4, &[], 31);
        assert_eq!(windowed.len(), 4);
        for (i, rep) in windowed.iter().enumerate() {
            let round = 2 + i as u64;
            let single = run_round_mech(&pool, &mech, Arc::new(Plain), round, &[], 31);
            assert_eq!(rep.round, round);
            assert_eq!(rep.output.estimate, single.output.estimate, "round {round}");
            assert_eq!(rep.output.bits.messages, single.output.bits.messages);
            for (a, b) in rep.true_mean.iter().zip(&single.true_mean) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn windowed_secagg_session_matches_windowed_plain() {
        // one masking session across the window: estimates must equal the
        // plain-summation window bit for bit (masks cancel per round)
        let pool = ClientPool::spawn(9, Arc::new(round_varying_compute));
        let mech = AggregateGaussian::new(0.5, 8.0);
        let plain = run_rounds_mech(&pool, &mech, Arc::new(Plain), 0, 3, &[], 11);
        let masked = run_rounds_mech(&pool, &mech, Arc::new(SecAgg::new()), 0, 3, &[], 11);
        for (p, m) in plain.iter().zip(&masked) {
            assert_eq!(p.output.estimate, m.output.estimate, "round {}", p.round);
            assert_eq!(p.output.bits.messages, m.output.bits.messages);
        }
    }

    #[test]
    fn windowed_rounds_invariant_under_worker_count() {
        let mech = IrwinHallMechanism::new(0.2, 4.0);
        let mut estimates: Vec<Vec<Vec<f64>>> = Vec::new();
        for threads in [1usize, 3, 5] {
            let pool = ClientPool::spawn_with_threads(
                11,
                Arc::new(round_varying_compute),
                Some(threads),
            );
            let reps =
                run_rounds_mech(&pool, &mech, Arc::new(SecAgg::new()), 1, 3, &[], 77);
            estimates.push(reps.into_iter().map(|r| r.output.estimate).collect());
        }
        assert_eq!(estimates[0], estimates[1]);
        assert_eq!(estimates[0], estimates[2]);
    }

    #[test]
    fn dropout_windowed_secagg_matches_dropout_windowed_plain() {
        // W=4 with a different announced dropout each round: the masked
        // session (with recovery) must equal the Plain session over the
        // same survivors, bit for bit, and report survivor counts
        let pool = ClientPool::spawn(9, Arc::new(round_varying_compute));
        let mech = AggregateGaussian::new(0.5, 8.0);
        let schedule: Vec<Vec<usize>> = vec![vec![2], vec![7], vec![0], vec![5]];
        let plain = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(Plain), 0, 4, &[], 11, &schedule,
        );
        let masked = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(SecAgg::new()), 0, 4, &[], 11, &schedule,
        );
        for (p, m) in plain.iter().zip(&masked) {
            assert_eq!(p.output.estimate, m.output.estimate, "round {}", p.round);
            assert_eq!(p.output.bits.messages, m.output.bits.messages);
            assert_eq!(p.survivors, 8);
            assert_eq!(m.survivors, 8);
            assert_eq!(p.true_mean, m.true_mean);
        }
    }

    #[test]
    fn dropout_true_mean_is_survivor_mean() {
        let pool = ClientPool::spawn(6, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let reps = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(Plain), 3, 1, &[], 9, &[vec![1, 4]],
        );
        let rep = &reps[0];
        assert_eq!(rep.survivors, 4);
        let mut want = vec![0.0f64; 5];
        for c in [0usize, 2, 3, 5] {
            for (w, v) in want.iter_mut().zip(round_varying_compute(c, 3, &[])) {
                *w += v;
            }
        }
        for (a, b) in rep.true_mean.iter().zip(want.iter().map(|v| v / 4.0)) {
            assert!((a - b).abs() < 1e-12);
        }
        // the estimate tracks the survivor mean, not the fleet mean
        for (e, t) in rep.output.estimate.iter().zip(&rep.true_mean) {
            assert!((e - t).abs() < 3.0, "est {e} vs true {t}");
        }
    }

    #[test]
    fn dropout_rounds_invariant_under_worker_count() {
        // shards skipping dropped clients must stay order- and
        // partition-free: identical estimates for any worker count,
        // including shards that lose ALL their clients in some round
        let mech = IrwinHallMechanism::new(0.2, 4.0);
        let schedule: Vec<Vec<usize>> = vec![vec![0, 1, 2], vec![10], vec![4, 9]];
        let mut estimates: Vec<Vec<Vec<f64>>> = Vec::new();
        for threads in [1usize, 4, 11] {
            let pool = ClientPool::spawn_with_threads(
                11,
                Arc::new(round_varying_compute),
                Some(threads),
            );
            let reps = run_rounds_mech_with_dropouts(
                &pool, &mech, Arc::new(SecAgg::new()), 1, 3, &[], 77, &schedule,
            );
            estimates.push(reps.into_iter().map(|r| r.output.estimate).collect());
        }
        assert_eq!(estimates[0], estimates[1]);
        assert_eq!(estimates[0], estimates[2]);
    }

    #[test]
    fn dropout_empty_schedule_is_bit_identical_to_plain_run() {
        let pool = ClientPool::spawn(7, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let none: Vec<Vec<usize>> = vec![Vec::new(); 2];
        let a = run_rounds_mech(&pool, &mech, Arc::new(SecAgg::new()), 0, 2, &[], 5);
        let b = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(SecAgg::new()), 0, 2, &[], 5, &none,
        );
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.output.estimate, y.output.estimate);
            assert_eq!(x.survivors, 7);
            assert_eq!(y.survivors, 7);
        }
    }
}
