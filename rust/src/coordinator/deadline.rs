//! Deterministic straggler deadlines on a **virtual clock** — how the
//! async coordinator ([`super::runtime::run_rounds_encoded_async`]) turns
//! "a client missed the round deadline" into an announced dropout on the
//! existing Bonawitz recovery path without surrendering replayability.
//!
//! A real deployment observes wall-clock arrival times; a reproduction
//! must not (the determinism ADR bans platform time as an input to any
//! decision that changes bits). Instead every (round, client) pair gets a
//! virtual arrival delay drawn from its own seed-derived stream under
//! [`seed_domain::DEADLINE`]: a Bernoulli(`straggler_rate`) gate picks the
//! stragglers, and a straggler's delay is Pareto(α = 1) with scale
//! `straggler_scale` — the same heavy-tailed law the scenario engine's
//! straggler subsystem draws, so scenario presets and coordinator
//! deadlines describe the same fleet. A client whose delay exceeds the
//! deadline *is* a dropout: the conversion happens **up front**, before
//! any shard computes, which makes "straggler past the deadline" and
//! "pre-announced dropout" the same schedule by construction — the bit
//! identity the async property suite asserts.
//!
//! `deadline = None` (∞) draws **nothing**: no client can miss an
//! infinite deadline, so the policy touches no RNG stream at all and the
//! async runner reproduces the barrier runner exactly.

use crate::mechanisms::pipeline::SurvivorSet;
use crate::util::rng::{seed_domain, Rng};

/// A deterministic straggler-deadline policy. `PartialEq` is exact; two
/// equal policies convert identical clients on identical seeds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeadlinePolicy {
    /// virtual-clock deadline; `None` means ∞ — no draws, no conversions
    pub deadline: Option<f64>,
    /// per-(round, client) probability of straggling at all
    pub straggler_rate: f64,
    /// Pareto(α = 1) scale of straggler delays (heavy-tailed: infinite
    /// mean, so *some* stragglers miss any finite deadline)
    pub straggler_scale: f64,
}

impl DeadlinePolicy {
    /// No deadline at all: the async runner behaves exactly like the
    /// barrier runner (and draws nothing from the DEADLINE domain).
    pub fn none() -> Self {
        Self { deadline: None, straggler_rate: 0.0, straggler_scale: 1.0 }
    }

    /// A finite virtual deadline with the given straggler law.
    pub fn with_deadline(deadline: f64, straggler_rate: f64, straggler_scale: f64) -> Self {
        let p = Self { deadline: Some(deadline), straggler_rate, straggler_scale };
        p.validate();
        p
    }

    /// Fail closed on shapes no deadline policy can mean.
    pub fn validate(&self) {
        assert!(
            (0.0..=1.0).contains(&self.straggler_rate),
            "straggler_rate must lie in [0, 1], got {}",
            self.straggler_rate
        );
        assert!(self.straggler_scale > 0.0, "straggler delays need a positive scale");
        if let Some(d) = self.deadline {
            assert!(d > 0.0 && d.is_finite(), "a finite deadline must be positive");
        }
    }

    /// The virtual arrival delay of `client` in global round `round`: 0
    /// for non-stragglers, Pareto(α = 1, scale) for stragglers. A pure
    /// function of `(root_seed, round, client)` — the whole point of the
    /// virtual clock: deadline outcomes replay, snapshot, and never
    /// depend on scheduler interleaving or host load.
    pub fn arrival(&self, root_seed: u64, round: u64, client: usize) -> f64 {
        let fam = Rng::derive_domain(root_seed, seed_domain::DEADLINE, round);
        let mut rng = Rng::derive(fam, client as u64);
        if !rng.bernoulli(self.straggler_rate) {
            return 0.0;
        }
        // inverse-CDF Pareto(α = 1): scale / U, via the same
        // scale / (1 − u01()) form the scenario engine draws (u01 ∈ [0,1))
        self.straggler_scale / (1.0 - rng.u01())
    }

    /// Convert every cohort member whose virtual arrival misses the
    /// deadline into an announced dropout, merged (sorted, de-duplicated
    /// against the explicit schedule) into a new per-round dropout
    /// schedule. Returns the merged schedule plus the total conversion
    /// count across the window.
    ///
    /// This runs BEFORE any shard computes — a converted straggler is
    /// never computed, never encoded, and is announced on the Bonawitz
    /// recovery path exactly like a pre-announced dropout, which is what
    /// makes the two schedules bit-identical. With `deadline = None` the
    /// explicit schedule is returned untouched (and nothing is drawn).
    ///
    /// Fails closed, naming the round, if conversions would leave a round
    /// with zero survivors — a fleet that entirely misses its deadline is
    /// an operational error, not a recoverable dropout.
    pub fn convert(
        &self,
        root_seed: u64,
        start_round: u64,
        cohorts: &[SurvivorSet],
        dropouts: &[Vec<usize>],
    ) -> (Vec<Vec<usize>>, usize) {
        self.validate();
        assert_eq!(
            cohorts.len(),
            dropouts.len(),
            "dropout schedule must cover every round of the window"
        );
        let Some(deadline) = self.deadline else {
            return (dropouts.to_vec(), 0);
        };
        let mut merged_schedule = Vec::with_capacity(cohorts.len());
        let mut n_converted = 0usize;
        for (r, (cohort, dropped)) in cohorts.iter().zip(dropouts).enumerate() {
            let round_id = start_round + r as u64;
            let mut already = vec![false; cohort.n()];
            for &c in dropped {
                assert!(c < cohort.n(), "dropped client {c} out of range");
                already[c] = true;
            }
            let mut merged = dropped.clone();
            for c in cohort.alive_iter() {
                if already[c] {
                    continue;
                }
                if self.arrival(root_seed, round_id, c) > deadline {
                    merged.push(c);
                    n_converted += 1;
                }
            }
            assert!(
                merged.len() < cohort.n_alive(),
                "fail closed: round {round_id} (window round {r}) would close with zero \
                 survivors — every cohort member is dropped or past the {deadline} deadline"
            );
            merged.sort_unstable();
            merged_schedule.push(merged);
        }
        (merged_schedule, n_converted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_deadline_none_converts_nothing_and_draws_nothing() {
        let cohorts = vec![SurvivorSet::full(6); 3];
        let dropouts: Vec<Vec<usize>> = vec![vec![2], vec![], vec![5, 0]];
        let (merged, converted) = DeadlinePolicy::none().convert(7, 0, &cohorts, &dropouts);
        assert_eq!(merged, dropouts, "deadline = ∞ must return the schedule untouched");
        assert_eq!(converted, 0);
    }

    #[test]
    fn async_deadline_arrival_is_a_pure_function_of_seed_round_client() {
        let p = DeadlinePolicy::with_deadline(2.0, 0.5, 1.0);
        for round in 0..4u64 {
            for client in 0..16usize {
                let a = p.arrival(99, round, client);
                let b = p.arrival(99, round, client);
                assert_eq!(a.to_bits(), b.to_bits());
                assert!(a >= 0.0);
                if a > 0.0 {
                    assert!(a >= 1.0, "Pareto(α=1, scale=1) delays start at the scale");
                }
            }
        }
        // the stream really is per-round: round 0 and round 1 disagree
        // somewhere on a 16-client fleet at rate 0.5
        assert!(
            (0..16).any(|c| p.arrival(99, 0, c).to_bits() != p.arrival(99, 1, c).to_bits()),
            "per-round arrival streams must differ"
        );
    }

    #[test]
    fn async_deadline_conversion_merges_sorted_past_explicit_dropouts() {
        let p = DeadlinePolicy::with_deadline(1.5, 0.6, 1.0);
        let cohorts = vec![SurvivorSet::full(24)];
        let explicit = vec![vec![11usize]];
        let (merged, converted) = p.convert(42, 5, &cohorts, &explicit);
        assert_eq!(merged.len(), 1);
        // the merged round is sorted, contains the explicit dropout, and
        // contains exactly the members whose arrival missed the deadline
        assert!(merged[0].windows(2).all(|w| w[0] < w[1]), "sorted, no duplicates");
        assert!(merged[0].contains(&11));
        for c in 0..24usize {
            let late = c != 11 && p.arrival(42, 5, c) > 1.5;
            assert_eq!(merged[0].contains(&c), late || c == 11, "client {c}");
        }
        assert_eq!(merged[0].len(), explicit[0].len() + converted);
        assert!(converted >= 1, "rate 0.6 over 24 clients converts someone at this seed");
    }

    #[test]
    #[should_panic(expected = "would close with zero survivors")]
    fn async_deadline_converting_every_survivor_fails_closed_with_named_round() {
        // rate 1 and a deadline below the Pareto scale: EVERY client
        // straggles past the deadline
        let p = DeadlinePolicy::with_deadline(0.5, 1.0, 1.0);
        let cohorts = vec![SurvivorSet::full(4)];
        let _ = p.convert(3, 9, &cohorts, &[Vec::new()]);
    }
}
