//! Kashin representation (Remark 1; Chen et al. 2023 use it to flatten ℓ₂
//! balls into ℓ∞ boxes with a constant-factor loss).
//!
//! We use the classical construction over the redundant tight frame
//! U = [R₁; R₂]/√2 (two independent randomized rotations, frame dimension
//! D = 2d): iterative "clip-and-redistribute" finds coefficients a with
//! x = Uᵀa and ‖a‖∞ <= K‖x‖₂/√D for a small constant K.

use super::hadamard::RandomizedRotation;

/// Kashin frame with two rotation blocks.
#[derive(Clone, Debug)]
pub struct KashinFrame {
    r1: RandomizedRotation,
    r2: RandomizedRotation,
    pub d_input: usize,
    /// number of clip-redistribute iterations
    pub iters: usize,
    /// ℓ∞ level multiplier K
    pub level_k: f64,
}

impl KashinFrame {
    pub fn new(d_input: usize, seed: u64) -> Self {
        Self {
            r1: RandomizedRotation::new(d_input, seed ^ 0xA11CE),
            r2: RandomizedRotation::new(d_input, seed ^ 0xB0B5),
            d_input,
            iters: 12,
            level_k: 3.0,
        }
    }

    /// Frame dimension D = 2·dim (padded).
    pub fn frame_dim(&self) -> usize {
        self.r1.dim + self.r2.dim
    }

    /// Frame analysis: a = U·x (tight with Uᵀ·U = I).
    fn analyze(&self, x: &[f64]) -> Vec<f64> {
        let mut a = self.r1.forward(x);
        let b = self.r2.forward(x);
        for v in a.iter_mut() {
            *v /= std::f64::consts::SQRT_2;
        }
        a.extend(b.into_iter().map(|v| v / std::f64::consts::SQRT_2));
        a
    }

    /// Frame synthesis: x = Uᵀ·a.
    pub fn synthesize(&self, a: &[f64]) -> Vec<f64> {
        let (a1, a2) = a.split_at(self.r1.dim);
        let x1 = self.r1.inverse(a1, self.d_input);
        let x2 = self.r2.inverse(a2, self.d_input);
        x1.iter()
            .zip(&x2)
            .map(|(u, v)| (u + v) / std::f64::consts::SQRT_2)
            .collect()
    }

    /// Compute Kashin coefficients: returns (a, level) with x ≈ Uᵀa and
    /// ‖a‖∞ <= level = K‖x‖₂/√D.
    pub fn represent(&self, x: &[f64]) -> (Vec<f64>, f64) {
        let norm = crate::util::stats::l2_norm(x);
        let dd = self.frame_dim() as f64;
        let level = self.level_k * norm / dd.sqrt();
        if norm == 0.0 {
            return (vec![0.0; self.frame_dim()], 0.0);
        }
        let mut residual = x.to_vec();
        let mut a = vec![0.0; self.frame_dim()];
        let mut lvl = level;
        for _ in 0..self.iters {
            let coeffs = self.analyze(&residual);
            // clip into the ℓ∞ ball of radius lvl, accumulate
            let clipped: Vec<f64> =
                coeffs.iter().map(|&c| c.clamp(-lvl, lvl)).collect();
            for (ai, ci) in a.iter_mut().zip(&clipped) {
                *ai += ci;
            }
            let approx = self.synthesize(&clipped);
            for (ri, pi) in residual.iter_mut().zip(&approx) {
                *ri -= pi;
            }
            lvl /= 2.0; // geometric level decay (standard Kashin iteration)
        }
        (a, level * 2.0) // total ℓ∞ bound: Σ level/2^k < 2·level
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::{l2_norm, linf_norm};

    #[test]
    fn representation_reconstructs() {
        let mut rng = Rng::new(91);
        let x: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let frame = KashinFrame::new(50, 3);
        let (a, _) = frame.represent(&x);
        let back = frame.synthesize(&a);
        let err = x.iter().zip(&back).map(|(u, v)| (u - v).powi(2)).sum::<f64>().sqrt();
        assert!(err < 1e-2 * l2_norm(&x), "err={err}");
    }

    #[test]
    fn coefficients_are_flat() {
        let mut rng = Rng::new(92);
        // adversarial spike input
        let mut x = vec![0.0; 64];
        x[7] = 5.0;
        for v in x.iter_mut().skip(32) {
            *v = 0.01 * rng.normal();
        }
        let frame = KashinFrame::new(64, 4);
        let (a, level) = frame.represent(&x);
        assert!(linf_norm(&a) <= level + 1e-9);
        // flatness: ℓ∞ of coefficients ≲ K·2·‖x‖/√D
        let bound = 2.0 * frame.level_k * l2_norm(&x) / (frame.frame_dim() as f64).sqrt();
        assert!(linf_norm(&a) <= bound + 1e-9);
    }

    #[test]
    fn zero_vector() {
        let frame = KashinFrame::new(10, 5);
        let (a, level) = frame.represent(&vec![0.0; 10]);
        assert_eq!(level, 0.0);
        assert!(a.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn tight_frame_identity() {
        // Uᵀ·U = I: synthesize(analyze(x)) == x
        let mut rng = Rng::new(93);
        let x: Vec<f64> = (0..33).map(|_| rng.normal()).collect();
        let frame = KashinFrame::new(33, 6);
        let a = frame.analyze(&x);
        let back = frame.synthesize(&a);
        for (u, v) in x.iter().zip(&back) {
            assert!((u - v).abs() < 1e-9);
        }
    }
}
