"""Tiled Pallas matmul used by the L2 FL model (MLP forward/backward).

TPU mapping: (M, K) x (K, N) decomposed on a (M/bm, N/bn, K/bk) grid with
128 x 128 output tiles accumulated in float32 across the K grid axis — the
MXU-systolic shape (bf16/fp32 tiles feeding a 128x128 systolic array), not a
CUDA threadblock/WMMA decomposition. The output block is revisited across
the k axis and accumulated in place.

VMEM per grid step = bm*bk + bk*bn + bm*bn float32
                   = 3 * 128 * 128 * 4 B = 192 KiB  << 16 MiB VMEM.

Differentiation: ``pallas_call`` has no automatic vjp, so ``matmul`` carries
a ``jax.custom_vjp`` whose backward pass re-uses the same kernel
(dX = dY @ W^T, dW = X^T @ dY) — every FLOP of fwd *and* bwd goes through
the tiled kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BM = 128
_BK = 128
_BN = 128


def _mm_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(v, b):
    return -(-v // b) * b


def _matmul_raw(x, y):
    """Tiled matmul on zero-padded inputs; returns the unpadded product."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    mp, kp, np_ = _ceil_to(m, _BM), _ceil_to(k, _BK), _ceil_to(n, _BN)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    grid = (mp // _BM, np_ // _BN, kp // _BK)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_BM, _BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((_BK, _BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((_BM, _BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, y):
    """float32 (m,k) @ (k,n) through the tiled Pallas kernel."""
    return _matmul_raw(x, y)


def _matmul_fwd(x, y):
    return _matmul_raw(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    return _matmul_raw(g, y.T), _matmul_raw(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
