//! The FL coordinator (Layer 3): round-based orchestration of n clients and
//! a server around a pluggable [`MeanMechanism`].
//!
//! Architecture: client-local computation (the expensive part — gradients,
//! local potentials) runs on a thread pool, one worker per client batch,
//! communicating with the orchestrator over channels. The *protocol*
//! (shared-randomness derivation, encode/aggregate/decode) is driven by the
//! mechanism itself, which derives every client's randomness from the
//! round seed — exactly how a real deployment shares a seed instead of
//! shipping randomness.
//!
//! * [`config`] — experiment configuration (file + CLI overrides)
//! * [`metrics`] — per-round metric recording, CSV/JSON export
//! * [`runtime`] — the threaded client pool + round loop

pub mod config;
pub mod metrics;
pub mod runtime;

pub use config::Config;
pub use metrics::Metrics;
pub use runtime::{ClientPool, LocalCompute, RoundReport};
