//! Per-round metric recording with CSV / JSON export.

use crate::dp::ledger::PrivacySpend;
use crate::util::json::{Csv, Json};
use std::collections::BTreeMap;
use std::time::Instant;

/// A metrics sink: named float series sampled per round.
#[derive(Debug)]
pub struct Metrics {
    pub name: String,
    series: BTreeMap<String, Vec<(u64, f64)>>,
    start: Instant,
}

impl Metrics {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), series: BTreeMap::new(), start: Instant::now() }
    }

    pub fn record(&mut self, round: u64, key: &str, value: f64) {
        self.series.entry(key.to_string()).or_default().push((round, value));
    }

    /// Record one round's privacy spend (see
    /// [`crate::dp::PrivacyLedger`]): the round's amplified ε and the
    /// cumulative basic-composition (ε, δ) through it, as the series
    /// `dp_eps_round` / `dp_eps_total` / `dp_delta_total`.
    pub fn record_privacy(&mut self, spend: &PrivacySpend) {
        self.record(spend.round, "dp_eps_round", spend.eps_round);
        self.record(spend.round, "dp_eps_total", spend.eps_total);
        self.record(spend.round, "dp_delta_total", spend.delta_total);
    }

    pub fn last(&self, key: &str) -> Option<f64> {
        self.series.get(key).and_then(|v| v.last()).map(|&(_, x)| x)
    }

    pub fn series(&self, key: &str) -> Option<&[(u64, f64)]> {
        self.series.get(key).map(|v| v.as_slice())
    }

    pub fn mean_of(&self, key: &str) -> Option<f64> {
        let s = self.series.get(key)?;
        if s.is_empty() {
            return None;
        }
        Some(s.iter().map(|&(_, x)| x).sum::<f64>() / s.len() as f64)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Render all series into a round-indexed CSV (missing cells empty).
    pub fn to_csv(&self) -> Csv {
        let mut header = vec!["round".to_string()];
        header.extend(self.series.keys().cloned());
        let mut rounds: Vec<u64> =
            self.series.values().flat_map(|s| s.iter().map(|&(r, _)| r)).collect();
        rounds.sort_unstable();
        rounds.dedup();
        let mut csv =
            Csv { header: header.clone(), rows: Vec::with_capacity(rounds.len()) };
        for r in rounds {
            let mut row = vec![r.to_string()];
            for key in self.series.keys() {
                let cell = self.series[key]
                    .iter()
                    .find(|&&(rr, _)| rr == r)
                    .map(|&(_, v)| format!("{v}"))
                    .unwrap_or_default();
                row.push(cell);
            }
            csv.rows.push(row);
        }
        csv
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj().push("name", self.name.as_str());
        for (k, s) in &self.series {
            obj = obj.push(
                k,
                Json::Arr(
                    s.iter()
                        .map(|&(r, v)| Json::Arr(vec![Json::Int(r as i64), Json::Num(v)]))
                        .collect(),
                ),
            );
        }
        obj
    }

    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        self.to_csv().save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut m = Metrics::new("test");
        m.record(0, "loss", 1.0);
        m.record(1, "loss", 0.5);
        m.record(1, "acc", 0.9);
        assert_eq!(m.last("loss"), Some(0.5));
        assert_eq!(m.mean_of("loss"), Some(0.75));
        assert_eq!(m.last("missing"), None);
    }

    #[test]
    fn csv_has_all_rounds() {
        let mut m = Metrics::new("test");
        m.record(0, "a", 1.0);
        m.record(2, "b", 3.0);
        let csv = m.to_csv();
        assert_eq!(csv.header, vec!["round", "a", "b"]);
        assert_eq!(csv.rows.len(), 2);
        assert_eq!(csv.rows[0][1], "1");
        assert_eq!(csv.rows[1][2], "3");
        assert_eq!(csv.rows[1][1], ""); // missing cell
    }

    #[test]
    fn privacy_spend_records_three_series() {
        let mut ledger = crate::dp::PrivacyLedger::new(1.0, 1e-5);
        let mut m = Metrics::new("dp");
        for round in 0..3u64 {
            let spend = ledger.record(round, 0.5);
            m.record_privacy(&spend);
        }
        assert_eq!(m.series("dp_eps_round").unwrap().len(), 3);
        let totals = m.series("dp_eps_total").unwrap();
        assert!(totals[2].1 > totals[1].1 && totals[1].1 > totals[0].1);
        assert!((m.last("dp_delta_total").unwrap() - 1.5e-5).abs() < 1e-16);
    }

    #[test]
    fn json_renders() {
        let mut m = Metrics::new("t");
        m.record(0, "x", 2.0);
        let s = m.to_json().render();
        assert!(s.contains("\"x\":[[0,2]]"), "{s}");
    }
}
