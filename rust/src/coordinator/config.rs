//! Experiment configuration: `key = value` files (a TOML subset: flat keys,
//! comments with '#') plus programmatic/CLI overrides. No serde offline, so
//! parsing is hand-rolled and strict.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A flat typed configuration.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse a `key = value` file (strict: unknown syntax is an error).
    pub fn from_str_strict(text: &str) -> Result<Self> {
        let mut c = Self::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let k = k.trim();
            if k.is_empty() || k.contains(char::is_whitespace) {
                bail!("line {}: bad key {k:?}", lineno + 1);
            }
            c.values.insert(k.to_string(), v.trim().trim_matches('"').to_string());
        }
        Ok(c)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_str_strict(&text)
    }

    pub fn set(&mut self, key: &str, value: impl ToString) -> &mut Self {
        self.values.insert(key.to_string(), value.to_string());
        self
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed getters on the shared loud-fail contract
    /// ([`crate::util::parse_or_panic`]): a missing key takes the
    /// default, a present-but-malformed value panics — a typo'd
    /// `sigma = O.25` must not quietly run the experiment at the default
    /// noise level.
    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T, expected: &str) -> T {
        crate::util::parse_or_panic(self.get(key), default, &format!("config key {key}"), expected)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_or(key, default, "a float")
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_or(key, default, "a non-negative integer")
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_or(key, default, "a non-negative integer")
    }

    /// Booleans accept true/false, 1/0, yes/no (case-insensitive); any
    /// other present value is a loud panic — previously `bool_or("x",
    /// true)` mapped an unrecognized `x = TRUE` to `false`, ignoring both
    /// the value and the default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some(v) => match v.to_ascii_lowercase().as_str() {
                "true" | "1" | "yes" => true,
                "false" | "0" | "no" => false,
                _ => panic!(
                    "config key {key} has malformed boolean {v:?} (use true/false, 1/0, \
                     yes/no)"
                ),
            },
        }
    }

    /// Typed getter that errors on malformed values (strict paths).
    pub fn require_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .with_context(|| format!("missing config key {key}"))?
            .parse()
            .with_context(|| format!("config key {key} is not a float"))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn render(&self) -> String {
        self.values.iter().map(|(k, v)| format!("{k} = {v}\n")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_kv_with_comments() {
        let c = Config::from_str_strict(
            "# experiment\nn_clients = 500\nsigma = 0.25  # noise\nname = \"fig6\"\n\n",
        )
        .unwrap();
        assert_eq!(c.usize_or("n_clients", 0), 500);
        assert_eq!(c.f64_or("sigma", 0.0), 0.25);
        assert_eq!(c.get("name"), Some("fig6"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::from_str_strict("just a line\n").is_err());
        assert!(Config::from_str_strict("a b = 3\n").is_err());
    }

    #[test]
    fn defaults_and_overrides() {
        let mut c = Config::new();
        assert_eq!(c.f64_or("x", 1.5), 1.5);
        c.set("x", 2.0);
        assert_eq!(c.f64_or("x", 1.5), 2.0);
    }

    #[test]
    fn require_errors_on_missing() {
        let c = Config::new();
        assert!(c.require_f64("nope").is_err());
    }

    #[test]
    #[should_panic(expected = "malformed value")]
    fn malformed_float_is_loud_not_a_silent_default() {
        // regression: `.parse().ok()` used to turn the typo into 0.1
        let c = Config::from_str_strict("sigma = O.25\n").unwrap();
        let _ = c.f64_or("sigma", 0.1);
    }

    #[test]
    #[should_panic(expected = "malformed value")]
    fn malformed_integer_is_loud_not_a_silent_default() {
        let c = Config::from_str_strict("n_clients = 5OO\n").unwrap();
        let _ = c.usize_or("n_clients", 8);
    }

    #[test]
    #[should_panic(expected = "malformed value")]
    fn malformed_u64_is_loud_not_a_silent_default() {
        let c = Config::from_str_strict("seed = -3\n").unwrap();
        let _ = c.u64_or("seed", 0);
    }

    #[test]
    fn bool_accepts_common_spellings_case_insensitively() {
        let c = Config::from_str_strict("a = TRUE\nb = No\nc = 1\n").unwrap();
        assert!(c.bool_or("a", false));
        assert!(!c.bool_or("b", true));
        assert!(c.bool_or("c", false));
        assert!(c.bool_or("missing", true));
        assert!(!c.bool_or("missing", false));
    }

    #[test]
    #[should_panic(expected = "malformed boolean")]
    fn malformed_bool_is_loud_not_false() {
        // regression: any unrecognized value used to decode as `false`,
        // ignoring the default entirely
        let c = Config::from_str_strict("flag = enabled\n").unwrap();
        let _ = c.bool_or("flag", true);
    }

    #[test]
    fn render_roundtrip() {
        let mut c = Config::new();
        c.set("b", 2).set("a", 1);
        let c2 = Config::from_str_strict(&c.render()).unwrap();
        assert_eq!(c2.get("a"), Some("1"));
        assert_eq!(c2.get("b"), Some("2"));
    }
}
