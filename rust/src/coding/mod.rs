//! Entropy coding and communication accounting (§3.2, §4.5).
//!
//! The paper compares mechanisms by *bits per client*: fixed-length codes
//! (⌈log |Supp M|⌉ bits — possible exactly when the quantizer has a minimal
//! step size, Prop. 2), variable-length codes (Huffman on p_{M|S}, within
//! 1 bit of H(M|S)), and Elias gamma codes (used for the Fig. 6/9
//! measurements). [`entropy`] computes the exact conditional entropies the
//! figures report. [`packed`] is the fixed-width ℤ_m wire format every
//! masked transport payload and session accumulator slot actually rides —
//! ⌈log₂ m⌉ bits per residue, not a whole u64.

pub mod bitio;
pub mod elias;
pub mod fixed;
pub mod huffman;
pub mod entropy;
pub mod packed;

pub use bitio::{BitReader, BitWriter};
pub use packed::PackedZm;
