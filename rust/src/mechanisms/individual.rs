//! Individual AINQ mechanism (Def. 2): each client runs a point-to-point
//! layered quantizer with error N(0, nσ²); the server averages the n
//! decoded values, so the aggregate error is exactly N(0, σ²).
//!
//! NOT homomorphic: decoding requires each client's description against its
//! own random step draws, so the mechanism rides the Unicast transport.
//!
//! Divisibility requirement: the aggregate noise must be a sum of n iid
//! terms — satisfied by the Gaussian (the paper's "individual Gaussian"
//! mechanism), NOT by e.g. the Laplace for n > 1.

use super::pipeline::{
    impl_mean_mechanism, ClientEncoder, Descriptions, MechSpec, Payload, RoundCache,
    ServerDecoder, SharedRound, Unicast,
};
use super::traits::BitsAccount;
use crate::coding::fixed::FixedCode;
use crate::dist::Gaussian;
use crate::quantizer::layered::eta;
use crate::quantizer::{DirectLayered, PointQuantizer, ShiftedLayered};

/// Which layered quantizer the clients run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayeredVariant {
    /// Def. 4 — near-optimal variable-length communication.
    Direct,
    /// Def. 5 — minimal step η > 0, fixed-length capable.
    Shifted,
}

/// Individual Gaussian mechanism: aggregate error exactly N(0, σ²).
#[derive(Clone, Debug)]
pub struct IndividualGaussian {
    /// target aggregate noise sd
    pub sigma: f64,
    pub variant: LayeredVariant,
    /// input magnitude bound |x_ij| <= t/2 used for fixed-length sizing
    pub input_range_t: f64,
    /// per-round shifted quantizer (η is a 4000-point precomputation; the
    /// per-client sd depends on n, so the cache is round-keyed)
    shifted_q: RoundCache<ShiftedLayered<Gaussian>>,
}

impl IndividualGaussian {
    pub fn new(sigma: f64, variant: LayeredVariant, input_range_t: f64) -> Self {
        assert!(sigma > 0.0 && input_range_t > 0.0);
        Self { sigma, variant, input_range_t, shifted_q: RoundCache::new() }
    }

    /// Per-client error sd: aggregate N(0, σ²) = mean of n iid N(0, nσ²).
    pub fn per_client_sd(&self, n: usize) -> f64 {
        self.sigma * (n as f64).sqrt()
    }

    fn shifted(&self, round: &SharedRound) -> std::sync::Arc<ShiftedLayered<Gaussian>> {
        let sd = self.per_client_sd(round.n_clients);
        self.shifted_q.get_or(round, || ShiftedLayered::new(Gaussian::new(0.0, sd)))
    }
}

impl MechSpec for IndividualGaussian {
    fn name(&self) -> String {
        match self.variant {
            LayeredVariant::Direct => format!("individual-gaussian-direct(sigma={})", self.sigma),
            LayeredVariant::Shifted => format!("individual-gaussian-shifted(sigma={})", self.sigma),
        }
    }

    fn is_homomorphic(&self) -> bool {
        false // per-client random step sizes cannot be summed before decode
    }

    fn gaussian_noise(&self) -> bool {
        true
    }

    fn fixed_length(&self) -> bool {
        self.variant == LayeredVariant::Shifted
    }

    fn noise_sd(&self) -> f64 {
        self.sigma
    }
}

impl ClientEncoder for IndividualGaussian {
    fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions {
        let per_sd = self.per_client_sd(round.n_clients);
        let mut rng = round.client_rng(client);
        let mut bits = BitsAccount::default();
        let ms: Vec<i64> = match self.variant {
            LayeredVariant::Direct => {
                let q = DirectLayered::new(Gaussian::new(0.0, per_sd));
                x.iter()
                    .map(|&xj| {
                        let s = q.draw(&mut rng);
                        let m = q.encode(xj, &s);
                        bits.add_description(m);
                        m
                    })
                    .collect()
            }
            LayeredVariant::Shifted => {
                let q = self.shifted(round);
                // fixed-length code sized by Prop. 2
                let code =
                    FixedCode::from_support_bound(self.input_range_t, eta::gaussian(per_sd));
                let mut fixed_total = 0.0f64;
                let ms = x
                    .iter()
                    .map(|&xj| {
                        let s = q.draw(&mut rng);
                        let m = q.encode(xj, &s);
                        bits.add_description(m);
                        fixed_total += if code.contains(m) {
                            code.bits() as f64
                        } else {
                            // escape: out-of-range descriptions fall back
                            // to a gamma codeword (rare for bounded input)
                            crate::coding::elias::signed_gamma_len(m) as f64
                                + code.bits() as f64
                        };
                        m
                    })
                    .collect();
                bits.fixed_total = Some(fixed_total);
                ms
            }
        };
        Descriptions { ms, aux: vec![], bits }
    }
}

impl ServerDecoder for IndividualGaussian {
    fn sum_decodable(&self) -> bool {
        false
    }

    fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
        let n = round.n_clients;
        let d = round.dim;
        let per_sd = self.per_client_sd(n);
        let list = payload.per_client();
        assert_eq!(list.len(), n);
        let mut estimate = vec![0.0f64; d];
        match self.variant {
            LayeredVariant::Direct => {
                let q = DirectLayered::new(Gaussian::new(0.0, per_sd));
                for (i, (ms, _)) in list.iter().enumerate() {
                    // the server re-derives client i's step draws
                    let mut rng = round.client_rng(i);
                    for (ej, &m) in estimate.iter_mut().zip(ms) {
                        let s = q.draw(&mut rng);
                        *ej += q.decode(m, &s);
                    }
                }
            }
            LayeredVariant::Shifted => {
                let q = self.shifted(round);
                for (i, (ms, _)) in list.iter().enumerate() {
                    let mut rng = round.client_rng(i);
                    for (ej, &m) in estimate.iter_mut().zip(ms) {
                        let s = q.draw(&mut rng);
                        *ej += q.decode(m, &s);
                    }
                }
            }
        }
        for e in estimate.iter_mut() {
            *e /= n as f64;
        }
        estimate
    }
}

impl_mean_mechanism!(IndividualGaussian, |_m| Unicast);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Continuous;
    use crate::mechanisms::traits::{true_mean, MeanMechanism};
    use crate::util::rng::Rng;
    use crate::util::stats::ks_test;

    fn client_data(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..d).map(|_| rng.uniform(-4.0, 4.0)).collect()).collect()
    }

    fn aggregate_errors(mech: &impl MeanMechanism, xs: &[Vec<f64>], rounds: usize) -> Vec<f64> {
        let mean = true_mean(xs);
        let mut errs = Vec::new();
        for r in 0..rounds {
            let out = mech.aggregate(xs, 0xABC0 + r as u64);
            for j in 0..mean.len() {
                errs.push(out.estimate[j] - mean[j]);
            }
        }
        errs
    }

    #[test]
    fn ainq_exact_gaussian_direct() {
        let xs = client_data(8, 4, 1);
        let mech = IndividualGaussian::new(0.7, LayeredVariant::Direct, 8.0);
        let errs = aggregate_errors(&mech, &xs, 400);
        let g = Gaussian::new(0.0, 0.7);
        let res = ks_test(&errs, |e| g.cdf(e));
        assert!(res.p_value > 0.003, "p={}", res.p_value);
    }

    #[test]
    fn ainq_exact_gaussian_shifted() {
        let xs = client_data(8, 4, 2);
        let mech = IndividualGaussian::new(1.2, LayeredVariant::Shifted, 8.0);
        let errs = aggregate_errors(&mech, &xs, 400);
        let g = Gaussian::new(0.0, 1.2);
        let res = ks_test(&errs, |e| g.cdf(e));
        assert!(res.p_value > 0.003, "p={}", res.p_value);
    }

    #[test]
    fn error_independent_of_data_scale() {
        // AINQ: same error law for very different inputs
        let mech = IndividualGaussian::new(1.0, LayeredVariant::Shifted, 2000.0);
        let xs_small = client_data(6, 3, 3);
        let xs_big: Vec<Vec<f64>> =
            xs_small.iter().map(|r| r.iter().map(|v| v * 100.0).collect()).collect();
        let e1 = aggregate_errors(&mech, &xs_small, 300);
        let e2 = aggregate_errors(&mech, &xs_big, 300);
        let res = crate::util::stats::ks_test_two_sample(&e1, &e2);
        assert!(res.p_value > 0.003, "p={}", res.p_value);
    }

    #[test]
    fn shifted_reports_fixed_bits() {
        let xs = client_data(5, 4, 4);
        let mech = IndividualGaussian::new(1.0, LayeredVariant::Shifted, 8.0);
        let out = mech.aggregate(&xs, 99);
        assert!(out.bits.fixed_total.is_some());
        assert!(out.bits.fixed_total.unwrap() > 0.0);
        assert_eq!(out.bits.messages, 20);
    }

    #[test]
    fn direct_has_no_fixed_bits() {
        let xs = client_data(5, 4, 5);
        let mech = IndividualGaussian::new(1.0, LayeredVariant::Direct, 8.0);
        let out = mech.aggregate(&xs, 99);
        assert!(out.bits.fixed_total.is_none());
        assert!(!MeanMechanism::fixed_length(&mech));
    }

    #[test]
    fn decode_reconstructs_encode_roundtrip() {
        // server-side decode must exactly reproduce the per-client decoded
        // values a client-side decoder would compute with the same streams
        let n = 4;
        let d = 3;
        let xs = client_data(n, d, 6);
        let mech = IndividualGaussian::new(0.9, LayeredVariant::Shifted, 8.0);
        let seed = 1234;
        let out = mech.aggregate(&xs, seed);
        let q = ShiftedLayered::new(Gaussian::new(0.0, mech.per_client_sd(n)));
        let mut want = vec![0.0f64; d];
        for (i, x) in xs.iter().enumerate() {
            let mut rng = Rng::derive(seed, i as u64);
            for j in 0..d {
                let s = q.draw(&mut rng);
                let m = q.encode(x[j], &s);
                want[j] += q.decode(m, &s);
            }
        }
        for j in 0..d {
            assert!((out.estimate[j] - want[j] / n as f64).abs() < 1e-12, "j={j}");
        }
    }

    #[test]
    fn property_flags() {
        let m: &dyn MeanMechanism = &IndividualGaussian::new(1.0, LayeredVariant::Shifted, 8.0);
        assert!(!m.is_homomorphic());
        assert!(m.gaussian_noise());
        assert!(m.fixed_length());
    }
}
