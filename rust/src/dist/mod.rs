//! Distribution layer: the error laws of the paper (Gaussian, Laplace,
//! Uniform, Irwin–Hall, discrete Gaussian) with the *superlevel-set
//! geometry* the layered quantizers consume (§3, Defs. 4–5).
//!
//! A unimodal density f partitions the area under its graph into horizontal
//! layers: the layer at height y is the superlevel set
//! L_y = {x : f(x) ≥ y} = [b⁻(y), b⁺(y)], and the *layer-height*
//! distribution D has density f_D(y) = λ(L_y) (the layer width). Sampling
//! D and quantizing with step b⁺(D) − b⁻(D) is exactly the direct layered
//! quantizer (Def. 4); flipping one side gives the shifted variant
//! (Def. 5). Everything here is deterministic given a [`Rng`] stream — the
//! shared-randomness contract of the whole system (see the determinism
//! ADR, `docs/determinism.md`).
//!
//! Place in the pipeline: these laws are what the
//! [`crate::mechanisms::pipeline::ClientEncoder`]s sample their layer
//! heights and dithers from and what the
//! [`crate::mechanisms::pipeline::ServerDecoder`]s re-derive seed-only on
//! the other end — both sides draw from [`Rng`] streams derived from the
//! round seed, which is why a round (or a whole
//! [`crate::mechanisms::session::TransportSession`] window) decodes
//! identically over `Plain` and `SecAgg` transports.

pub mod discrete_gaussian;
pub mod gaussian;
pub mod irwin_hall;
pub mod laplace;
pub mod uniform;

pub use gaussian::Gaussian;
pub use irwin_hall::IrwinHall;
pub use laplace::Laplace;
pub use uniform::Uniform;

use crate::util::rng::Rng;

/// A continuous distribution on ℝ.
pub trait Continuous {
    /// Density f(x).
    fn pdf(&self, x: f64) -> f64;
    /// Cumulative distribution F(x) = P(X <= x).
    fn cdf(&self, x: f64) -> f64;
    /// Draw one sample from the distribution.
    fn sample(&self, rng: &mut Rng) -> f64;
}

/// A unimodal continuous distribution with computable superlevel-set
/// geometry — the interface of the layered quantizers (Defs. 4–5).
pub trait Unimodal: Continuous {
    /// The mode (argmax of the density).
    fn mode(&self) -> f64;

    /// Z̄ = f(mode), the maximal density value.
    fn max_pdf(&self) -> f64;

    /// Right boundary b⁺(y) = sup{x : f(x) ≥ y} of the superlevel set.
    /// For y ≥ Z̄ returns the mode; for y ≤ 0 the right support edge.
    fn b_plus(&self, y: f64) -> f64;

    /// Left boundary b⁻(y) = inf{x : f(x) ≥ y}.
    fn b_minus(&self, y: f64) -> f64;

    /// Variance of the distribution.
    fn variance(&self) -> f64;

    /// Width of the layer at height y: λ(L_y) = b⁺(y) − b⁻(y). This is the
    /// density of the layer-height variable D.
    fn layer_width(&self, y: f64) -> f64 {
        self.b_plus(y) - self.b_minus(y)
    }

    /// Sample D ~ f_D, the layer height: if X ~ f and V | X ~ U(0, f(X)),
    /// the point (X, V) is uniform under the graph of f, so the height V
    /// has density λ(L_v) — exactly f_D.
    fn sample_layer_height(&self, rng: &mut Rng) -> f64 {
        let x = self.sample(rng);
        rng.u01() * self.pdf(x)
    }

    /// Differential entropy h(D) of the layer height, in bits — the
    /// distribution-dependent constant of the Eq. 4 communication lower
    /// bound log(t) + h(D_Z). Computed by quadrature of
    /// −∫₀^Z̄ f_D(y) log2 f_D(y) dy with the graded substitution y = Z̄·t²
    /// that resolves the y → 0 region (where layers are widest).
    fn layer_height_entropy(&self) -> f64 {
        let zbar = self.max_pdf();
        let integrand = |t: f64| {
            if t <= 0.0 || t >= 1.0 {
                return 0.0;
            }
            let w = self.layer_width(zbar * t * t);
            if w <= 0.0 {
                return 0.0;
            }
            w * w.log2() * 2.0 * zbar * t
        };
        -crate::util::interp::simpson(integrand, 0.0, 1.0, 8192)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_height_density_integrates_to_one() {
        // ∫ f_D = ∫ λ(L_y) dy = ∫ f = 1 for every law in the module
        let g = Gaussian::new(0.0, 1.3);
        let l = Laplace::with_sd(0.5, 2.0);
        let u = Uniform::centered(3.0);
        let area = |d: &dyn Unimodal| {
            let zbar = d.max_pdf();
            crate::util::interp::simpson(
                |t| {
                    if t <= 0.0 || t >= 1.0 {
                        0.0
                    } else {
                        d.layer_width(zbar * t * t) * 2.0 * zbar * t
                    }
                },
                0.0,
                1.0,
                4096,
            )
        };
        assert!((area(&g) - 1.0).abs() < 1e-6, "gauss {}", area(&g));
        assert!((area(&l) - 1.0).abs() < 1e-6, "laplace {}", area(&l));
        assert!((area(&u) - 1.0).abs() < 1e-6, "uniform {}", area(&u));
    }

    #[test]
    fn sampled_layer_heights_match_density() {
        // KS test of sample_layer_height against F_D(y) = ∫₀^y λ(L_v) dv
        let g = Gaussian::new(0.0, 1.0);
        let mut rng = Rng::new(901);
        let samples: Vec<f64> = (0..6000).map(|_| g.sample_layer_height(&mut rng)).collect();
        let zbar = g.max_pdf();
        let cdf = |y: f64| {
            if y <= 0.0 {
                return 0.0;
            }
            if y >= zbar {
                return 1.0;
            }
            crate::util::interp::simpson(|v| g.layer_width(v.max(1e-300)), 1e-12, y, 600)
                .clamp(0.0, 1.0)
        };
        let res = crate::util::stats::ks_test(&samples, cdf);
        assert!(res.p_value > 0.003, "p={}", res.p_value);
    }

    #[test]
    fn uniform_layer_entropy_closed_form() {
        // D ~ U(0, Z̄) with density = width W: h(D) = −log2 W
        let w = 2.5;
        let u = Uniform::centered(w);
        let h = u.layer_height_entropy();
        assert!((h + w.log2()).abs() < 1e-3, "h={h}");
    }

    #[test]
    fn entropy_shift_invariance_and_scaling() {
        // scaling x by σ scales layer widths by σ and heights by 1/σ, so
        // D_σ =d D_1/σ and h(D_σ) = h(D_1) − log2 σ (uniform check: width w
        // gives h = −log2 w exactly)
        let h1 = Gaussian::new(0.0, 1.0).layer_height_entropy();
        let h3 = Gaussian::new(0.0, 3.0).layer_height_entropy();
        assert!((h1 - h3 - 3.0f64.log2()).abs() < 1e-3, "h1={h1} h3={h3}");
        // and independent of the mean
        let hm = Gaussian::new(17.0, 1.0).layer_height_entropy();
        assert!((hm - h1).abs() < 1e-6);
    }
}
