"""AOT lowering smoke tests: HLO text is produced and looks loadable."""

import os

import jax
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    # Tiny shapes: this runs the full lowering pipeline quickly.
    aot.build_artifacts(
        out, d_in=4, hidden=8, classes=2, batch=8, enc_clients=8, enc_dim=128
    )
    return out


ARTIFACT_NAMES = ["model_grad", "model_eval", "encode", "decode_mean"]


@pytest.mark.parametrize("name", ARTIFACT_NAMES)
def test_artifact_written_nonempty(artifacts, name):
    path = os.path.join(artifacts, f"{name}.hlo.txt")
    assert os.path.exists(path)
    text = open(path).read()
    assert len(text) > 100
    assert "HloModule" in text
    # HLO text interchange: must not be a serialized proto blob
    assert text.isprintable() or "\n" in text


def test_manifest_contents(artifacts):
    text = open(os.path.join(artifacts, "manifest.txt")).read()
    assert "param_count=" in text
    for name in ARTIFACT_NAMES:
        assert f"artifact={name}" in text
    p = model.param_count(4, 8, 2)
    assert f"param_count={p}" in text


def test_hlo_text_reparses(artifacts):
    """Round-trip through the XLA text parser (same path the rust side uses)."""
    from jax._src.lib import xla_client as xc

    for name in ARTIFACT_NAMES:
        text = open(os.path.join(artifacts, f"{name}.hlo.txt")).read()
        # xla_client exposes the HLO text parser via the computation factory
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None
