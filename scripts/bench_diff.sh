#!/usr/bin/env bash
# Bench trajectory regression gate.
#
# Compares a fresh BENCH_*.json artifact (argument 1, or the
# highest-numbered BENCH_N.json at the repo root) against the most recent
# PRIOR trajectory artifact and fails loudly if any `kernels/*` series
# lost more than 20% throughput. Non-kernel series are reported but do not
# gate: figure/mechanism benches measure whole experiments whose cost
# legitimately moves as the repro grows; the kernel series are the
# contract this gate protects.
#
# Artifacts marked `"quick": true` (BENCH_QUICK smoke runs) or
# `"pending": true` (committed placeholders awaiting a toolchain) carry no
# comparable numbers. A fresh artifact like that is schema-checked only;
# as a BASELINE it is skipped and the search walks BACK to the most recent
# comparable trajectory point — a committed placeholder must never eat the
# regression gate for the whole history behind it. When no comparable
# baseline exists at all, the gate exits 0 but says so LOUDLY on stderr.
set -euo pipefail

cd "$(dirname "$0")/.."

fresh="${1:-}"
if [ -z "$fresh" ]; then
    fresh=$(ls BENCH_*.json 2>/dev/null | sort -t_ -k2 -n | tail -n 1 || true)
fi
if [ -z "$fresh" ] || [ ! -f "$fresh" ]; then
    echo "bench_diff: no trajectory artifact found (expected BENCH_N.json at the repo root)" >&2
    exit 1
fi

# baseline candidates: every BENCH_*.json at the repo root that is not the
# fresh artifact itself, newest first — the comparability walk-back
# happens below, where "pending"/"quick" can actually be read
candidates=()
for f in $(ls BENCH_*.json 2>/dev/null | sort -t_ -k2 -n -r); do
    if [ "$(readlink -f "$f")" != "$(readlink -f "$fresh")" ]; then
        candidates+=("$f")
    fi
done

python3 - "$fresh" ${candidates[@]+"${candidates[@]}"} <<'PY'
import json
import sys

fresh_path, candidate_paths = sys.argv[1], sys.argv[2:]


def load(path):
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("schema") != "benchkit-v1":
        sys.exit(f"bench_diff: {path}: unknown schema {doc.get('schema')!r}")
    for s in doc.get("series", []):
        if "name" not in s or "mean_ns" not in s:
            sys.exit(f"bench_diff: {path}: malformed series entry {s!r}")
    return doc


fresh = load(fresh_path)
print(f"bench_diff: {fresh_path}: schema OK, {len(fresh.get('series', []))} series")

def incomparable(doc, path):
    if doc.get("pending"):
        return f"{path} is a pending placeholder (no recorded numbers)"
    if doc.get("quick"):
        return f"{path} is a BENCH_QUICK smoke artifact (not a trajectory point)"
    if not doc.get("series"):
        return f"{path} has an empty series list"
    return None


reason = incomparable(fresh, fresh_path)
if reason:
    print(f"bench_diff: skipping comparison: {reason}")
    sys.exit(0)

# walk the candidates newest -> oldest to the first COMPARABLE baseline:
# pending placeholders and quick artifacts are stepped over (loudly), not
# silently accepted as "nothing to compare against"
base, baseline_path = None, None
for path in candidate_paths:
    doc = load(path)
    reason = incomparable(doc, path)
    if reason:
        print(f"bench_diff: skipping baseline candidate: {reason}")
        continue
    base, baseline_path = doc, path
    break

if base is None:
    print(
        "bench_diff: WARNING — no comparable baseline among "
        f"{len(candidate_paths)} candidate artifact(s); the regression gate "
        "DID NOT RUN. Regenerate a full (non-quick) trajectory artifact to "
        "restore the gate.",
        file=sys.stderr,
    )
    sys.exit(0)


def throughputs(doc):
    out = {}
    for s in doc["series"]:
        t = s.get("throughput_meps")
        if t:
            out[s["name"]] = t
    return out


old = throughputs(base)
new = throughputs(fresh)
regressions = []
for name in sorted(set(old) & set(new)):
    ratio = new[name] / old[name]
    marker = ""
    if ratio < 0.8:
        marker = "  <-- REGRESSION" if name.startswith("kernels/") else "  (slower, not gated)"
        if name.startswith("kernels/"):
            regressions.append((name, ratio))
    print(f"bench_diff: {name}: {old[name]:.2f} -> {new[name]:.2f} Melem/s ({ratio:.2f}x){marker}")

if regressions:
    print(
        f"bench_diff: FAIL — {len(regressions)} kernels/* series lost >20% throughput "
        f"vs {baseline_path}:",
        file=sys.stderr,
    )
    for name, ratio in regressions:
        print(f"bench_diff:   {name}: {ratio:.2f}x of baseline", file=sys.stderr)
    sys.exit(1)

print(f"bench_diff: OK — no kernels/* series regressed >20% vs {baseline_path}")
PY
