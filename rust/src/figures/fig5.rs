//! Figures 5 and 7: MSE of CSGM vs SIGM against the privacy budget ε.
//!
//! Protocol (§5.1 "Numerical comparison" + App. C.1): data
//! X_i(j) ~ (2·Bern(0.8) − 1)·U/√d; δ = 1e−5; ε ∈ [0.5, 4];
//! γ ∈ {0.3, 0.5, 1.0}; Fig. 5: n ∈ {1000, 2000} × d ∈ {100, 500};
//! Fig. 7: d = 500, n ∈ {250, 500, 1000}. CSGM's bit budget is set to
//! SIGM's measured budget ("the number of bits used by CSGM is kept equal
//! to the number of bits used by SIGM").
//!
//! Calibration (identical for both arms — DESIGN.md "Substitutions"): the
//! analytic Gaussian mechanism at ℓ2 sensitivity √(γd)·c/(γn), c = 1/√d.

use super::FigOpts;
use crate::apps::driver::{app_round_seed, CoordinatorOpts};
use crate::apps::mean_estimation::{evaluate_coordinator, gen_data, DataKind};
use crate::baselines::Csgm;
use crate::dp::accountant::analytic_gaussian_sigma;
use crate::mechanisms::traits::MeanMechanism;
use crate::mechanisms::Sigm;
use crate::util::json::Csv;

pub struct Fig5Point {
    pub n: usize,
    pub d: usize,
    pub gamma: f64,
    pub eps: f64,
    pub sigma: f64,
    pub mse_sigm: f64,
    pub mse_csgm: f64,
    pub bits: f64,
}

pub fn sigma_for(eps: f64, delta: f64, gamma: f64, n: usize, d: usize) -> f64 {
    let c = 1.0 / (d as f64).sqrt();
    let sensitivity = (gamma * d as f64).sqrt() * c / (gamma * n as f64);
    analytic_gaussian_sigma(eps, delta, sensitivity)
}

pub fn eval_point(
    n: usize,
    d: usize,
    gamma: f64,
    eps: f64,
    runs: usize,
    seed: u64,
) -> Fig5Point {
    let delta = 1e-5;
    let c = 1.0 / (d as f64).sqrt();
    let sigma = sigma_for(eps, delta, gamma, n, d);
    let xs = gen_data(DataKind::BernoulliUniform { p: 0.8 }, n, d, seed);

    let sigm = Sigm::new(sigma, gamma, c);
    // Same evaluation seed for both arms: Sigm and Csgm derive the
    // coordinate-subsampling matrix identically from the round seed, so
    // the subsampling noise realization is SHARED and the MSE difference
    // isolates quantization-vs-noise-shaping (the figure's comparison).
    //
    // Both arms run on the coordinator: SIGM's per-client (Unicast)
    // transport clamps to whole-d plans, while CSGM's sum transport
    // streams 128-coordinate chunks with clients producing slices —
    // bit-identical to the monolithic evaluate() either way.
    let res_sigm =
        evaluate_coordinator(&sigm, &xs, runs, seed ^ 0x51, CoordinatorOpts::default());
    // match CSGM's bit budget to SIGM's fixed-length bits per message
    let probe = sigm.aggregate(&xs, app_round_seed(seed ^ 0x52, 0));
    let bits_per_msg =
        probe.bits.fixed_total.unwrap_or(8.0) / probe.bits.messages.max(1) as f64;
    let csgm = Csgm::new(sigma, gamma, c, (bits_per_msg.ceil() as u32).max(1));
    let res_csgm = evaluate_coordinator(
        &csgm,
        &xs,
        runs,
        seed ^ 0x51,
        CoordinatorOpts { chunk: 128, ..CoordinatorOpts::default() },
    );

    Fig5Point {
        n,
        d,
        gamma,
        eps,
        sigma,
        mse_sigm: res_sigm.mse_mean,
        mse_csgm: res_csgm.mse_mean,
        bits: bits_per_msg,
    }
}

pub fn run(opts: &FigOpts, fig7: bool) {
    let (name, configs): (&str, Vec<(usize, usize)>) = if fig7 {
        ("7", vec![(250, 500), (500, 500), (1000, 500)])
    } else {
        ("5", vec![(1000, 100), (1000, 500), (2000, 100), (2000, 500)])
    };
    println!("\n== Figure {name}: MSE of CSGM vs SIGM ==");
    let runs = opts.runs_or(30);
    let gammas: &[f64] = if opts.quick { &[0.5] } else { &[0.3, 0.5, 1.0] };
    let eps_grid: &[f64] = if opts.quick { &[0.5, 2.0, 4.0] } else { &[0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0] };
    let mut csv = Csv::new(&["n", "d", "gamma", "eps", "sigma", "mse_sigm", "mse_csgm", "bits"]);
    println!(
        "{:>6} {:>5} {:>6} {:>5} {:>10} {:>12} {:>12} {:>6}",
        "n", "d", "gamma", "eps", "sigma", "mse-SIGM", "mse-CSGM", "bits"
    );
    for &(n, d) in &configs {
        let (n, d) = if opts.quick { (n / 10, d / 10) } else { (n, d) };
        for &gamma in gammas {
            for &eps in eps_grid {
                let p = eval_point(n, d, gamma, eps, runs, opts.seed);
                println!(
                    "{:>6} {:>5} {:>6} {:>5} {:>10.3e} {:>12.4e} {:>12.4e} {:>6.1}",
                    p.n, p.d, p.gamma, p.eps, p.sigma, p.mse_sigm, p.mse_csgm, p.bits
                );
                csv.row_f64(&[
                    p.n as f64, p.d as f64, p.gamma, p.eps, p.sigma, p.mse_sigm, p.mse_csgm,
                    p.bits,
                ]);
            }
        }
    }
    let path = format!("{}/fig{name}.csv", opts.out_dir);
    csv.save(&path).expect("saving csv");
    println!("saved {path}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::mean_estimation::evaluate;

    #[test]
    fn sigm_never_worse_than_csgm() {
        // the figure's invariant: with subsampling noise shared across
        // arms, CSGM's extra quantization error can only add MSE
        let p = eval_point(100, 32, 0.5, 2.0, 80, 77);
        assert!(
            p.mse_sigm <= p.mse_csgm * 1.05,
            "SIGM {} vs CSGM {}",
            p.mse_sigm,
            p.mse_csgm
        );
    }

    #[test]
    fn sigm_clearly_wins_at_tight_bit_budget() {
        // force a coarse budget on CSGM: its quantization error dominates
        let n = 100;
        let d = 32;
        let gamma = 0.5;
        let eps = 2.0;
        let c = 1.0 / (d as f64).sqrt();
        let sigma = sigma_for(eps, 1e-5, gamma, n, d);
        let xs = gen_data(DataKind::BernoulliUniform { p: 0.8 }, n, d, 79);
        let sigm = evaluate(&Sigm::new(sigma, gamma, c), &xs, 40, 80);
        let csgm = evaluate(&Csgm::new(sigma, gamma, c, 2), &xs, 40, 80);
        assert!(
            sigm.mse_mean < csgm.mse_mean,
            "SIGM {} vs coarse CSGM {}",
            sigm.mse_mean,
            csgm.mse_mean
        );
    }

    #[test]
    fn mse_decreases_with_eps() {
        let lo = eval_point(100, 32, 0.5, 0.5, 15, 78);
        let hi = eval_point(100, 32, 0.5, 4.0, 15, 78);
        assert!(hi.mse_sigm < lo.mse_sigm, "eps=4 {} >= eps=0.5 {}", hi.mse_sigm, lo.mse_sigm);
    }

    #[test]
    fn sigma_calibration_decreases_with_n() {
        let s1 = sigma_for(1.0, 1e-5, 0.5, 100, 32);
        let s2 = sigma_for(1.0, 1e-5, 0.5, 1000, 32);
        assert!(s2 < s1);
    }
}
