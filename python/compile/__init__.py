"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT lowering."""
