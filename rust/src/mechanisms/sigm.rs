//! SIGM — Subsampled Individual Gaussian Mechanism (§5.1, Algorithm 5).
//!
//! Coordinate-wise Bernoulli(γ) subsampling composed with the shifted
//! layered quantizer targeting N(0, (σγn)²) per selected message. The
//! decoded subsampled mean satisfies (App. A.6)
//!
//!   Y(j) − (γn)⁻¹ Σ_{i:Bᵢ(j)=1} xᵢ(j)  ~  N(0, σ²) ,
//!
//! i.e. the quantization *is* the DP noise (compression for free). The MSE
//! against the true mean adds the subsampling variance ≤ c²/(nγ) per
//! coordinate (Prop. 4).
//!
//! Pipeline shape: the subsampling rows Bᵢ are shared randomness — each
//! client's row derives from its own per-coordinate stream family
//! ([`SharedRound::subsample_coord_stream`]), so encoding derives ONE row
//! in O(d) and no party materializes the O(n·d) matrix (the decoder
//! re-derives rows client by client; only the O(d) selected counts ñ(j)
//! are cached per round). A client sends one description per *selected* coordinate,
//! so messages are ragged and the mechanism is NOT homomorphic — it rides
//! the Unicast transport.

use super::pipeline::{
    impl_mean_mechanism, ClientEncoder, Descriptions, MechSpec, Payload, RoundCache,
    ServerDecoder, SharedRound, Unicast,
};
use super::traits::BitsAccount;
use crate::coding::fixed::FixedCode;
use crate::dist::Gaussian;
use crate::quantizer::layered::eta;
use crate::quantizer::{PointQuantizer, ShiftedLayered};

/// Round-derived shared state: the per-coordinate selected counts ñ(j)
/// and the per-client quantizer — O(d), never the O(n·d) subsample matrix
/// (rows are re-derived per client from their own streams on demand).
struct SigmRound {
    n_tilde: Vec<f64>,
    q: ShiftedLayered<Gaussian>,
}

#[derive(Clone, Debug)]
pub struct Sigm {
    /// exact Gaussian noise sd on the subsampled mean
    pub sigma: f64,
    /// coordinate-subsampling probability γ
    pub gamma: f64,
    /// per-coordinate input bound |x_ij| <= c
    pub input_bound_c: f64,
    round_state: RoundCache<SigmRound>,
}

impl Sigm {
    pub fn new(sigma: f64, gamma: f64, input_bound_c: f64) -> Self {
        assert!(sigma > 0.0 && (0.0..=1.0).contains(&gamma));
        Self { sigma, gamma, input_bound_c, round_state: RoundCache::new() }
    }

    fn state(&self, round: &SharedRound) -> std::sync::Arc<SigmRound> {
        let (n, d) = (round.n_clients, round.dim);
        let per_sd = self.sigma * self.gamma * n as f64;
        let gamma = self.gamma;
        self.round_state.get_or(round, || {
            // ñ(j) = Σᵢ Bᵢ(j): fold each client's derived selections
            // without ever materializing the matrix — O(d) memory. The
            // per-coordinate subsample family is shared with CSGM, so the
            // matched-subsample comparison of Figs. 5/7 holds under any
            // chunking of CSGM's coordinate space.
            // lane-batched selection rows: bernoulli(γ) is u01() < γ on
            // the first draw of each coordinate stream
            let mut n_tilde = vec![0.0f64; d];
            let mut u = vec![0.0f64; d];
            for i in 0..n {
                round.subsample_coord_stream(i).fill_u01(0, &mut u);
                for (nt, &uj) in n_tilde.iter_mut().zip(u.iter()) {
                    if uj < gamma {
                        *nt += 1.0;
                    }
                }
            }
            SigmRound { n_tilde, q: ShiftedLayered::new(Gaussian::new(0.0, per_sd)) }
        })
    }
}

impl MechSpec for Sigm {
    fn name(&self) -> String {
        format!("sigm(sigma={}, gamma={})", self.sigma, self.gamma)
    }

    fn is_homomorphic(&self) -> bool {
        false
    }

    fn gaussian_noise(&self) -> bool {
        true // conditionally on the subsample — the DP-relevant law
    }

    fn fixed_length(&self) -> bool {
        true // shifted layered quantizer (Prop. 2 + Prop. 4 cost)
    }

    fn noise_sd(&self) -> f64 {
        self.sigma
    }
}

impl ClientEncoder for Sigm {
    fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions {
        let st = self.state(round);
        let per_sd = self.sigma * self.gamma * round.n_clients as f64;
        // the client derives only ITS OWN subsample selections — O(d)
        // encode (the ragged step-draw stream below stays sequential:
        // SIGM is not chunk-capable, its message has no coordinate grid)
        let mut sel = vec![0.0f64; x.len()];
        round.subsample_coord_stream(client).fill_u01(0, &mut sel);
        let mut rng = round.client_rng(client);
        let mut bits = BitsAccount::default();
        let mut fixed_total = 0.0f64;
        // ragged: one description per SELECTED coordinate, in j order
        let mut ms = Vec::new();
        for (j, &xj) in x.iter().enumerate() {
            if sel[j] >= self.gamma {
                continue;
            }
            let s = st.q.draw(&mut rng);
            let scaled = xj * st.n_tilde[j].sqrt();
            let m = st.q.encode(scaled, &s);
            bits.add_description(m);
            // fixed-length accounting: input magnitude <= c·√ñ(j)
            let code = FixedCode::from_support_bound(
                2.0 * self.input_bound_c * st.n_tilde[j].sqrt(),
                eta::gaussian(per_sd),
            );
            fixed_total += code.bits() as f64;
            ms.push(m);
        }
        bits.fixed_total = Some(fixed_total);
        Descriptions { ms, aux: vec![], bits }
    }
}

impl ServerDecoder for Sigm {
    fn sum_decodable(&self) -> bool {
        false
    }

    fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
        let n = round.n_clients;
        let d = round.dim;
        let nf = n as f64;
        let st = self.state(round);
        let list = payload.per_client();
        assert_eq!(list.len(), n);
        let mut estimate = vec![0.0f64; d];
        let mut sel = vec![0.0f64; d];
        for (i, (ms, _)) in list.iter().enumerate() {
            // re-derive client i's subsample selections and step draws;
            // the draw stream advances only on selected coordinates,
            // matching the encoder — O(d) working state per client, no
            // cached matrix
            round.subsample_coord_stream(i).fill_u01(0, &mut sel);
            let mut rng = round.client_rng(i);
            let mut k = 0usize;
            for (j, ej) in estimate.iter_mut().enumerate() {
                if sel[j] >= self.gamma {
                    continue;
                }
                let s = st.q.draw(&mut rng);
                *ej += st.q.decode(ms[k], &s);
                k += 1;
            }
            assert_eq!(k, ms.len(), "client {i}: description count mismatch");
        }
        let mut extra = round.aux_rng(1);
        for j in 0..d {
            if st.n_tilde[j] > 0.0 {
                estimate[j] /= self.gamma * nf * st.n_tilde[j].sqrt();
            } else {
                // empty subsample: emit pure mechanism noise so the output
                // law stays DP-calibratable
                estimate[j] = extra.normal_ms(0.0, self.sigma);
            }
        }
        estimate
    }
}

impl_mean_mechanism!(Sigm, |_m| Unicast);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Continuous;
    use crate::mechanisms::traits::MeanMechanism;
    use crate::util::rng::Rng;
    use crate::util::stats::{ks_test, variance};

    fn client_data(n: usize, d: usize, c: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..d).map(|_| rng.uniform(-c, c)).collect()).collect()
    }

    /// error of the estimate vs the SUBSAMPLED mean (the AINQ quantity)
    fn subsample_errors(mech: &Sigm, xs: &[Vec<f64>], rounds: usize, seed0: u64) -> Vec<f64> {
        let n = xs.len();
        let d = xs[0].len();
        let mut errs = Vec::new();
        for r in 0..rounds {
            let seed = seed0 + r as u64;
            let out = mech.aggregate(xs, seed);
            // reconstruct the shared subsample rows from their per-client
            // streams (the post-bump derivation)
            let round = crate::mechanisms::pipeline::SharedRound::new(seed, n, d);
            let b: Vec<Vec<bool>> =
                (0..n).map(|i| round.subsample_row(i, mech.gamma)).collect();
            for j in 0..d {
                let sel: Vec<usize> = (0..n).filter(|&i| b[i][j]).collect();
                if sel.is_empty() {
                    continue;
                }
                let sub_mean: f64 =
                    sel.iter().map(|&i| xs[i][j]).sum::<f64>() / (mech.gamma * n as f64);
                errs.push(out.estimate[j] - sub_mean);
            }
        }
        errs
    }

    #[test]
    fn error_vs_subsampled_mean_is_exactly_gaussian() {
        let xs = client_data(20, 4, 1.0, 17);
        let mech = Sigm::new(0.25, 0.5, 1.0);
        let errs = subsample_errors(&mech, &xs, 500, 40_000);
        let g = Gaussian::new(0.0, 0.25);
        let res = ks_test(&errs, |e| g.cdf(e));
        assert!(res.p_value > 0.003, "p={}", res.p_value);
        assert!((variance(&errs) - 0.0625).abs() < 0.01);
    }

    #[test]
    fn gamma_one_recovers_individual_mechanism_error() {
        // γ = 1: no subsampling, error vs true mean ~ N(0, σ²)
        let xs = client_data(10, 5, 1.0, 18);
        let mech = Sigm::new(0.3, 1.0, 1.0);
        let mean = crate::mechanisms::traits::true_mean(&xs);
        let mut errs = Vec::new();
        for r in 0..600 {
            let out = mech.aggregate(&xs, 50_000 + r);
            for j in 0..mean.len() {
                errs.push(out.estimate[j] - mean[j]);
            }
        }
        let g = Gaussian::new(0.0, 0.3);
        assert!(ks_test(&errs, |e| g.cdf(e)).p_value > 0.003);
    }

    #[test]
    fn messages_scale_with_gamma() {
        let xs = client_data(50, 20, 1.0, 19);
        let lo = Sigm::new(0.3, 0.3, 1.0).aggregate(&xs, 3).bits.messages;
        let hi = Sigm::new(0.3, 0.9, 1.0).aggregate(&xs, 3).bits.messages;
        let total = 50 * 20;
        assert!((lo as f64) < 0.45 * total as f64, "lo={lo}");
        assert!((hi as f64) > 0.75 * total as f64, "hi={hi}");
    }

    #[test]
    fn mse_decomposes_per_prop4() {
        // MSE <= c²/(nγ) + σ² per coordinate (Prop. 4 with d=1 scaling)
        let n = 100;
        let c = 1.0;
        let xs = client_data(n, 8, c, 20);
        let mech = Sigm::new(0.1, 0.5, c);
        let mean = crate::mechanisms::traits::true_mean(&xs);
        let mut sq = 0.0;
        let mut cnt = 0usize;
        for r in 0..200 {
            let out = mech.aggregate(&xs, 60_000 + r);
            for j in 0..mean.len() {
                sq += (out.estimate[j] - mean[j]).powi(2);
                cnt += 1;
            }
        }
        let mse = sq / cnt as f64;
        let bound = c * c / (n as f64 * 0.5) + 0.1 * 0.1;
        assert!(mse <= bound * 1.2, "mse={mse} bound={bound}");
    }

    #[test]
    fn property_flags() {
        let m: &dyn MeanMechanism = &Sigm::new(0.3, 0.5, 1.0);
        assert!(!m.is_homomorphic());
        assert!(m.gaussian_noise());
        assert!(m.fixed_length());
    }
}
