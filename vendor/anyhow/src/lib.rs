//! Offline shim for the subset of the `anyhow` API this workspace uses.
//!
//! The build environment has no crates.io access, so this path crate
//! provides source-compatible `Error` / `Result` / `Context` / `bail!` /
//! `anyhow!` with the same semantics for the call sites in this repo:
//! string-context error chains rendered through `Display` (`{}` shows the
//! outermost context, `{:#}` the full chain, `{:?}` a multi-line report).

use std::fmt;

/// A string-chained error: the outermost context first, the root cause last.
pub struct Error {
    /// context chain, outermost first; never empty
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message (mirror of `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, colon-separated (anyhow renders the same)
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error` —
// exactly like the real anyhow — so the blanket `From` below is coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Early-return with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_err() -> Result<i32> {
        let r: std::result::Result<i32, std::num::ParseIntError> = "x".parse();
        r.with_context(|| format!("parsing {}", "x"))
    }

    #[test]
    fn context_chains_render() {
        let e = parse_err().unwrap_err();
        assert!(format!("{e}").starts_with("parsing x"));
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing x: "), "{full}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn bail_and_anyhow() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {}", flag);
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
