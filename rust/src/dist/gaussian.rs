//! Gaussian N(μ, σ²) with closed-form superlevel-set geometry.

use super::{Continuous, Unimodal};
use crate::util::rng::Rng;
use crate::util::special::norm_cdf;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Gaussian {
    pub mean: f64,
    pub sd: f64,
}

impl Gaussian {
    pub fn new(mean: f64, sd: f64) -> Self {
        assert!(sd > 0.0, "sd must be positive, got {sd}");
        Self { mean, sd }
    }

    pub fn standard() -> Self {
        Self::new(0.0, 1.0)
    }

    /// E|X − μ| = σ√(2/π).
    pub fn mean_abs(&self) -> f64 {
        self.sd * (2.0 / std::f64::consts::PI).sqrt()
    }

    /// Half-width r(y) of the superlevel set {f ≥ y}: f(μ ± r) = y gives
    /// r = σ√(−2 ln(y/Z̄)).
    fn superlevel_half_width(&self, y: f64) -> f64 {
        let zbar = self.max_pdf();
        if y >= zbar {
            return 0.0;
        }
        // clamp: y = 0 would give an infinite layer (measure-zero draw)
        let ratio = (y / zbar).max(1e-300);
        self.sd * (-2.0 * ratio.ln()).sqrt()
    }
}

impl Continuous for Gaussian {
    fn pdf(&self, x: f64) -> f64 {
        let z = (x - self.mean) / self.sd;
        (-0.5 * z * z).exp() / (self.sd * (2.0 * std::f64::consts::PI).sqrt())
    }

    fn cdf(&self, x: f64) -> f64 {
        norm_cdf((x - self.mean) / self.sd)
    }

    fn sample(&self, rng: &mut Rng) -> f64 {
        rng.normal_ms(self.mean, self.sd)
    }
}

impl Unimodal for Gaussian {
    fn mode(&self) -> f64 {
        self.mean
    }

    fn max_pdf(&self) -> f64 {
        1.0 / (self.sd * (2.0 * std::f64::consts::PI).sqrt())
    }

    fn b_plus(&self, y: f64) -> f64 {
        self.mean + self.superlevel_half_width(y)
    }

    fn b_minus(&self, y: f64) -> f64 {
        self.mean - self.superlevel_half_width(y)
    }

    fn variance(&self) -> f64 {
        self.sd * self.sd
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{ks_test, mean, variance};

    #[test]
    fn pdf_cdf_known_values() {
        let g = Gaussian::standard();
        assert!((g.pdf(0.0) - 0.398_942_280_401_432_7).abs() < 1e-14);
        assert!((g.cdf(0.0) - 0.5).abs() < 1e-14);
        assert!((g.cdf(1.96) - 0.975_002_104_851_78).abs() < 1e-9);
        let h = Gaussian::new(2.0, 3.0);
        assert!((h.cdf(2.0) - 0.5).abs() < 1e-14);
    }

    #[test]
    fn superlevel_inverts_pdf() {
        let g = Gaussian::new(1.0, 2.2);
        let zbar = g.max_pdf();
        for i in 1..60 {
            let y = zbar * i as f64 / 60.0;
            let bp = g.b_plus(y);
            assert!((g.pdf(bp) - y).abs() < 1e-12 * zbar, "y={y}");
            assert!((g.b_minus(y) - (2.0 * g.mean - bp)).abs() < 1e-12);
            assert!(bp >= g.mode());
        }
        assert_eq!(g.b_plus(zbar * 2.0), g.mode());
    }

    #[test]
    fn samples_match_cdf() {
        let g = Gaussian::new(-1.0, 0.7);
        let mut rng = Rng::new(31);
        let xs: Vec<f64> = (0..6000).map(|_| g.sample(&mut rng)).collect();
        assert!(ks_test(&xs, |x| g.cdf(x)).p_value > 0.003);
        assert!((mean(&xs) + 1.0).abs() < 0.05);
        assert!((variance(&xs) - 0.49).abs() < 0.05);
    }

    #[test]
    fn mean_abs_matches_monte_carlo() {
        let g = Gaussian::new(0.0, 1.8);
        let mut rng = Rng::new(32);
        let m: f64 =
            (0..200_000).map(|_| g.sample(&mut rng).abs()).sum::<f64>() / 200_000.0;
        assert!((m - g.mean_abs()).abs() < 0.01, "mc {m} vs {}", g.mean_abs());
    }
}
