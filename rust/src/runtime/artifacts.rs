//! Artifact manifest: shapes the AOT build (python/compile/aot.py) baked
//! into `artifacts/manifest.txt`, parsed so the rust side never hardcodes
//! model dimensions.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub d_in: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub param_count: usize,
    pub enc_clients: usize,
    pub enc_dim: usize,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut kv = std::collections::HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                if !k.contains(' ') {
                    kv.insert(k.trim().to_string(), v.trim().to_string());
                }
            }
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .with_context(|| format!("manifest missing key {k}"))?
                .parse::<usize>()
                .with_context(|| format!("manifest key {k} not an integer"))
        };
        let m = Self {
            d_in: get("d_in")?,
            hidden: get("hidden")?,
            classes: get("classes")?,
            batch: get("batch")?,
            param_count: get("param_count")?,
            enc_clients: get("enc_clients")?,
            enc_dim: get("enc_dim")?,
            dir,
        };
        if m.param_count != m.d_in * m.hidden + m.hidden + m.hidden * m.classes + m.classes {
            bail!("manifest param_count inconsistent with layer dims");
        }
        Ok(m)
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "d_in=32\nhidden=64\nclasses=2\nbatch=64\nparam_count=2242\n\
                          enc_clients=32\nenc_dim=2304\nartifact=model_grad inputs=...\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.d_in, 32);
        assert_eq!(m.param_count, 2242);
        assert_eq!(m.hlo_path("encode"), PathBuf::from("/tmp/encode.hlo.txt"));
    }

    #[test]
    fn rejects_inconsistent_param_count() {
        let bad = SAMPLE.replace("param_count=2242", "param_count=999");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_missing_key() {
        let bad = SAMPLE.replace("hidden=64\n", "");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }
}
