//! Deterministic PRNG suite.
//!
//! The offline registry has no `rand` crate, and the paper's mechanisms all
//! hinge on *shared randomness*: a client and the server must generate
//! byte-identical random streams from a common seed (§2 "Quantized
//! aggregation"). We therefore implement:
//!
//! * [`SplitMix64`] — seed expansion / stream derivation (Steele et al.).
//! * [`Rng`] — xoshiro256++ core with standard real-valued samplers
//!   (uniform, Gaussian via polar Marsaglia, exponential, geometric, …).
//!
//! Stream derivation (`Rng::derive`) gives every (client, round, purpose)
//! tuple an independent stream from one root seed, which is exactly how the
//! coordinator distributes shared randomness.

/// Root-seed derivation domains for
/// [`crate::util::rng::Rng::derive_domain`]: every family of seeds derived
/// from the coordinator root seed is tagged with one of these, so no
/// family can alias another no matter what indices it uses.
/// (Before the seed-format bump, round seeds were `root ^ round·C` — round
/// 0 was handed the *raw root seed*, and XOR-composed families shared one
/// flat u64 space where collisions were possible by construction.)
pub mod seed_domain {
    /// Round r's shared-randomness seed (what
    /// [`crate::mechanisms::pipeline::SharedRound`] is built from).
    pub const ROUND: u64 = 0xD0_0001;
    /// A session window's transport seed
    /// ([`crate::mechanisms::session::derive_session_seed`]).
    pub const SESSION: u64 = 0xD0_0002;
    /// Round r's client-sampling cohort draw
    /// ([`crate::coordinator::sampling::SamplingPolicy`]).
    pub const COHORT: u64 = 0xD0_0003;
    /// A round's *per-coordinate* stream families
    /// ([`crate::mechanisms::pipeline::SharedRound::coord_family_seed`]):
    /// the seekable seed format of the chunked pipeline, where coordinate
    /// j's draws derive from (family, j) instead of advancing one
    /// sequential stream — so any chunking of the coordinate space
    /// reproduces identical bits.
    pub const COORD_FAMILY: u64 = 0xD0_0004;
    /// A scenario engine's per-subsystem RNG slots
    /// ([`crate::testing::ScenarioEngine`]): slot i of the fixed
    /// subsystem order (churn, outage, straggler, drift, byzantine) draws
    /// from `derive_domain(scenario_seed, SCENARIO, i)`, so no
    /// subsystem's draw count can displace another's stream.
    pub const SCENARIO: u64 = 0xD0_0005;
    /// Property-test case seeds ([`crate::testing::forall`]): case k of a
    /// `forall` run draws from `derive_domain(cfg.seed, PROP_CASE, k)`,
    /// which is the seed a failure report prints for `FORALL_REPLAY`.
    pub const PROP_CASE: u64 = 0xD0_0006;
    /// The async coordinator's virtual straggler clock
    /// ([`crate::coordinator::deadline::DeadlinePolicy`]): round r's
    /// arrival-time draws come from
    /// `derive(derive_domain(root_seed, DEADLINE, r), client)`, so
    /// deadline outcomes are a pure function of the run's root seed —
    /// replayable, and incapable of displacing any other stream (a run
    /// with no deadline draws nothing from this domain and every other
    /// domain is untouched either way).
    pub const DEADLINE: u64 = 0xD0_0007;
    /// App-layer auxiliary streams keyed by absolute round id: the
    /// Langevin injected noise β·Z of round k and the smoothing broadcast
    /// perturbation of round k both draw from
    /// `Rng::new(derive_domain(app_seed, APP_ROUND, k))` — domain-separated
    /// from the aggregation pipeline's [`ROUND`] family, so an app's own
    /// randomness can never alias (or be displaced by) the shared
    /// encode/transport streams, and both the monolithic `aggregate()`
    /// path and the coordinator path of an app re-derive the identical
    /// stream from (app seed, round id) alone.
    pub const APP_ROUND: u64 = 0xD0_0008;
    /// Figure-sweep replicate seeds: repeat r of a sweep derives its data
    /// and chain roots from `derive_domain(sweep_seed, REPLICATE, i(r))`
    /// with distinct indices per stream — replacing the ad-hoc
    /// `seed + r` / `seed ^ (const + r)` mixing the sweeps used before
    /// (which collides across arms whenever the XOR'd constants differ by
    /// a small additive offset).
    pub const REPLICATE: u64 = 0xD0_0009;
}

/// SplitMix64's additive constant (the golden-ratio gamma).
const SM64_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// Coordinate-tag multiplier of [`Rng::derive_coord`] — shared with the
/// lane-batched deriver so both compute the identical per-coordinate tag.
const COORD_MUL: u64 = 0xC2B2_AE3D_27D4_EB4F;

/// SplitMix64's output finalizer (Stafford mix13): the avalanche applied to
/// the post-increment state. Exposed module-internally so [`CoordLanes`]
/// can unroll the exact seed-expansion arithmetic of [`SplitMix64`] +
/// [`Rng::new`] as straight-line lane code — one shared definition is what
/// makes the batched path bit-identical by construction, not by parallel
/// maintenance of two copies.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// SplitMix64: used for seeding and stream derivation (passes BigCrush).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(SM64_GAMMA);
        mix64(self.state)
    }
}

/// xoshiro256++ PRNG with distribution samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from the polar method
    gauss_spare: Option<f64>,
}

/// The complete externalized state of an [`Rng`]: the xoshiro256++ word
/// state plus the polar method's cached spare Gaussian. Capturing this is
/// capturing the generator's exact *stream position* — restoring it via
/// [`Rng::from_state`] continues the stream bit-for-bit where it stopped,
/// which is what snapshot/resume bit-identity hinges on (re-*seeding*
/// would rewind the stream and replay draws; see docs/determinism.md).
///
/// `gauss_spare` must be part of the state: `normal()` draws Gaussians in
/// pairs and caches the second, so two generators with equal word state
/// but different spares diverge on their very next `normal()` call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    /// xoshiro256++ state words, in order.
    pub s: [u64; 4],
    /// Cached second Gaussian from the last polar-method pair, if any.
    pub gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (recommended by the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Capture the generator's exact stream position (see [`RngState`]).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, gauss_spare: self.gauss_spare }
    }

    /// Rebuild a generator at a previously captured stream position: the
    /// restored generator's future draws are bit-identical to what the
    /// captured generator would have drawn next.
    pub fn from_state(state: RngState) -> Self {
        Self { s: state.s, gauss_spare: state.gauss_spare }
    }

    /// Derive an independent stream for a (seed, stream-id) pair.
    ///
    /// Used by the coordinator to give every (client, round, purpose) its
    /// own reproducible stream: both end-points derive the same stream from
    /// the shared root seed without communicating.
    pub fn derive(root_seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(root_seed);
        let a = sm.next_u64();
        let mut sm2 = SplitMix64::new(a ^ stream.wrapping_mul(0xA24B_AED4_963E_E407));
        Self::new(sm2.next_u64())
    }

    /// Domain-separated seed derivation: mix (root seed, domain, index)
    /// through chained SplitMix64 expansions and return the derived seed.
    ///
    /// This is the root-level companion of [`Rng::derive`]: where `derive`
    /// separates *streams under one seed*, `derive_domain` separates the
    /// *seed families* hanging off the coordinator root seed (round seeds,
    /// session seeds, sampling-cohort draws — see [`seed_domain`]). Unlike
    /// the XOR folding it replaced, no (domain, index) pair maps to the
    /// raw root seed (`root ^ 0·C == root` gave round 0 the root itself)
    /// and distinct domains cannot alias by index arithmetic, because each
    /// component passes through a full SplitMix64 avalanche before the
    /// next is folded in.
    pub fn derive_domain(root_seed: u64, domain: u64, index: u64) -> u64 {
        let mut sm = SplitMix64::new(root_seed);
        let expanded = sm.next_u64();
        let mut sm = SplitMix64::new(expanded ^ domain.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let tagged = sm.next_u64();
        let mut sm = SplitMix64::new(tagged ^ index.wrapping_mul(0xA24B_AED4_963E_E407));
        sm.next_u64()
    }

    /// The *seekable* stream of coordinate `coord` under a family seed: a
    /// fresh generator whose draws depend only on (family_seed, coord),
    /// never on how many coordinates were processed before it. This is the
    /// primitive of the chunked pipeline's seed format — an encoder
    /// processing coordinates [lo, hi) derives exactly the streams the
    /// whole-vector encoder derives for those coordinates, so chunk
    /// boundaries cannot change any drawn bit (see docs/determinism.md).
    /// Also safe for samplers that consume a variable number of raw draws
    /// per value (rejection sampling, layered recursion): each coordinate
    /// owns a whole stream, so there is no position to lose.
    ///
    /// Scale caveat (shared by every 64-bit derivation in this module,
    /// `derive` and `pair_seed` included): stream identities live in a
    /// 64-bit space, so across ALL families of a run the birthday bound
    /// applies — with F families of d coordinates, expect ~(F·d)²/2⁶⁵
    /// cross-family stream coincidences. Irrelevant below ~10¹² total
    /// streams (≈ millions of clients × million-coordinate models starts
    /// to approach it); deployments beyond that scale should move the
    /// seed format to a wider (e.g. 128-bit keyed) derivation before
    /// leaning on cross-stream independence. Recorded here rather than
    /// asserted: per-coordinate marginals are unaffected, only joint
    /// independence across colliding streams would quietly degrade.
    pub fn derive_coord(family_seed: u64, coord: u64) -> Self {
        let mut sm = SplitMix64::new(family_seed ^ coord.wrapping_mul(COORD_MUL));
        Self::new(sm.next_u64())
    }

    /// Lane-batched sibling of [`Rng::derive_coord`]: lane `l` of the
    /// returned expander is exactly the stream of coordinate
    /// `base_coord + l`. Because `derive_coord` is position-free, batching
    /// L consecutive coordinates is pure reassociation — no drawn bit can
    /// differ from L scalar derivations (see docs/determinism.md and the
    /// `property_kernels` suite).
    pub fn derive_coord_batch<const L: usize>(
        family_seed: u64,
        base_coord: u64,
    ) -> CoordLanes<L> {
        CoordLanes::derive(family_seed, base_coord)
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn u01(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [a, b).
    #[inline]
    pub fn uniform(&mut self, a: f64, b: f64) -> f64 {
        a + (b - a) * self.u01()
    }

    /// The dither distribution of Example 1: U(-1/2, 1/2).
    #[inline]
    pub fn dither(&mut self) -> f64 {
        self.u01() - 0.5
    }

    /// Standard Gaussian (Marsaglia polar method, spare cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.u01() - 1.0;
            let v = 2.0 * self.u01() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Gaussian with the given mean / standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Exponential with rate 1.
    #[inline]
    pub fn exponential(&mut self) -> f64 {
        // 1 - u01() is in (0, 1]: never takes ln(0)
        -(1.0 - self.u01()).ln()
    }

    /// Laplace(0, b): difference of exponentials.
    #[inline]
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.u01() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.u01() < p
    }

    /// Geometric on {0, 1, ...} with success probability p.
    pub fn geometric(&mut self, p: f64) -> u64 {
        if p >= 1.0 {
            return 0;
        }
        let u = 1.0 - self.u01(); // in (0, 1]
        (u.ln() / (1.0 - p).ln()).floor() as u64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method.
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = lemire_threshold(n);
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fill a vector with standard Gaussians.
    pub fn normal_vec(&mut self, d: usize) -> Vec<f64> {
        (0..d).map(|_| self.normal()).collect()
    }

    /// Fill a vector with U(-1/2, 1/2) dithers.
    pub fn dither_vec(&mut self, d: usize) -> Vec<f64> {
        (0..d).map(|_| self.dither()).collect()
    }
}

/// Default lane width of the batched coordinate kernels: wide enough for
/// two AVX2 (or one AVX-512) u64 vectors' worth of independent streams,
/// small enough that a lane block always fits in registers.
pub const COORD_LANES: usize = 8;

/// Struct-of-arrays expander over L consecutive *seekable* coordinate
/// streams ([`Rng::derive_coord`]): lane `l` carries the xoshiro256++
/// state of coordinate `base_coord + l`, and every operation advances all
/// lanes with branch-free straight-line code the autovectorizer can pack.
///
/// Bit-identity contract: for every lane, every draw equals what the
/// scalar `Rng::derive_coord(family, base + l)` path produces — the
/// derivation unrolls the exact [`SplitMix64`] + [`Rng::new`] arithmetic
/// through the shared `mix64` finalizer, and the rejection slow path of
/// [`CoordLanes::below`] redraws from the rejecting lane's own stream
/// only. Batching is therefore pure reassociation of independent streams;
/// chunk boundaries and lane widths cannot change any drawn bit.
#[derive(Clone, Debug)]
pub struct CoordLanes<const L: usize> {
    s0: [u64; L],
    s1: [u64; L],
    s2: [u64; L],
    s3: [u64; L],
}

impl<const L: usize> CoordLanes<L> {
    /// Derive the streams of coordinates `base_coord .. base_coord + L`
    /// under `family_seed` — the straight-line unroll of
    /// `Rng::derive_coord` per lane: one SplitMix64 step over the
    /// coordinate tag yields the xoshiro seed, four more expand the state.
    pub fn derive(family_seed: u64, base_coord: u64) -> Self {
        let mut s0 = [0u64; L];
        let mut s1 = [0u64; L];
        let mut s2 = [0u64; L];
        let mut s3 = [0u64; L];
        for l in 0..L {
            let coord = base_coord.wrapping_add(l as u64);
            let tag = family_seed ^ coord.wrapping_mul(COORD_MUL);
            let seed = mix64(tag.wrapping_add(SM64_GAMMA));
            s0[l] = mix64(seed.wrapping_add(SM64_GAMMA));
            s1[l] = mix64(seed.wrapping_add(SM64_GAMMA.wrapping_mul(2)));
            s2[l] = mix64(seed.wrapping_add(SM64_GAMMA.wrapping_mul(3)));
            s3[l] = mix64(seed.wrapping_add(SM64_GAMMA.wrapping_mul(4)));
        }
        Self { s0, s1, s2, s3 }
    }

    /// One xoshiro256++ step on every lane.
    #[inline]
    pub fn next_u64(&mut self) -> [u64; L] {
        let mut out = [0u64; L];
        for l in 0..L {
            out[l] = self.s0[l]
                .wrapping_add(self.s3[l])
                .rotate_left(23)
                .wrapping_add(self.s0[l]);
            let t = self.s1[l] << 17;
            self.s2[l] ^= self.s0[l];
            self.s3[l] ^= self.s1[l];
            self.s1[l] ^= self.s2[l];
            self.s0[l] ^= self.s3[l];
            self.s2[l] ^= t;
            self.s3[l] = self.s3[l].rotate_left(45);
        }
        out
    }

    /// One xoshiro256++ step on lane `l` only — the rejection slow path:
    /// a rejecting lane redraws from ITS stream without advancing any
    /// other lane, exactly like the scalar rejection loop.
    #[inline]
    fn next_lane(&mut self, l: usize) -> u64 {
        let out = self.s0[l]
            .wrapping_add(self.s3[l])
            .rotate_left(23)
            .wrapping_add(self.s0[l]);
        let t = self.s1[l] << 17;
        self.s2[l] ^= self.s0[l];
        self.s3[l] ^= self.s1[l];
        self.s1[l] ^= self.s2[l];
        self.s0[l] ^= self.s3[l];
        self.s2[l] ^= t;
        self.s3[l] = self.s3[l].rotate_left(45);
        out
    }

    /// Uniform [0, 1) on every lane (the [`Rng::u01`] mapping per lane).
    #[inline]
    pub fn u01(&mut self) -> [f64; L] {
        let r = self.next_u64();
        let mut out = [0.0f64; L];
        for l in 0..L {
            out[l] = (r[l] >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        }
        out
    }

    /// U(-1/2, 1/2) on every lane (the [`Rng::dither`] mapping per lane).
    #[inline]
    pub fn dither(&mut self) -> [f64; L] {
        let mut out = self.u01();
        for o in out.iter_mut() {
            *o -= 0.5;
        }
        out
    }

    /// Uniform integer in [0, n) on every lane — Lemire's nearly
    /// divisionless method with the rejection threshold `t` hoisted by the
    /// caller ([`lemire_threshold`]), so the per-coordinate loop carries
    /// no modulo. Bit-identical per lane to [`Rng::below`]: since
    /// t = 2⁶⁴ mod n < n, `lo < t` rejects exactly the draws the scalar
    /// `if lo < n { while lo < t … }` rejects, and a rejecting lane
    /// redraws from its own stream without disturbing its neighbours.
    #[inline]
    pub fn below(&mut self, n: u64, t: u64) -> [u64; L] {
        debug_assert_eq!(t, lemire_threshold(n), "threshold hoisted for a different n");
        let r = self.next_u64();
        let mut out = [0u64; L];
        let mut any_reject = false;
        for l in 0..L {
            let m = (r[l] as u128) * (n as u128);
            out[l] = (m >> 64) as u64;
            any_reject |= (m as u64) < t;
        }
        if any_reject {
            for l in 0..L {
                let mut m = (r[l] as u128) * (n as u128);
                while (m as u64) < t {
                    m = (self.next_lane(l) as u128) * (n as u128);
                }
                out[l] = (m >> 64) as u64;
            }
        }
        out
    }
}

/// The Lemire rejection threshold 2⁶⁴ mod n for unbiased `below(n)`
/// draws. Hoisting it out of a per-coordinate fill removes the only
/// division/modulo from the hot loop; always < n, so the hoisted
/// `lo < t` test is exactly the scalar rejection condition.
#[inline]
pub fn lemire_threshold(n: u64) -> u64 {
    debug_assert!(n > 0, "below(0) is ill-defined");
    n.wrapping_neg() % n
}

/// Fill `out[k] = Rng::derive_coord(family_seed, lo + k).below(n)` for the
/// whole slice — the lane-batched mask-expansion kernel. Full lane blocks
/// go through [`CoordLanes`]; the tail falls back to the scalar deriver,
/// which is bit-identical per coordinate by construction (each lane IS the
/// scalar stream), so any split into fills concatenates exactly.
pub fn fill_below_coords(family_seed: u64, lo: u64, n: u64, out: &mut [u64]) {
    let t = lemire_threshold(n);
    let mut base = lo;
    let mut chunks = out.chunks_exact_mut(COORD_LANES);
    for chunk in chunks.by_ref() {
        let mut lanes: CoordLanes<COORD_LANES> = CoordLanes::derive(family_seed, base);
        chunk.copy_from_slice(&lanes.below(n, t));
        base = base.wrapping_add(COORD_LANES as u64);
    }
    for (k, o) in chunks.into_remainder().iter_mut().enumerate() {
        *o = Rng::derive_coord(family_seed, base.wrapping_add(k as u64)).below(n);
    }
}

/// Fill `out[k] = Rng::derive_coord(family_seed, lo + k).u01()` — the
/// lane-batched dither/uniform fill (first draw of each coordinate
/// stream), scalar-tail rules as in [`fill_below_coords`].
pub fn fill_u01_coords(family_seed: u64, lo: u64, out: &mut [f64]) {
    let mut base = lo;
    let mut chunks = out.chunks_exact_mut(COORD_LANES);
    for chunk in chunks.by_ref() {
        let mut lanes: CoordLanes<COORD_LANES> = CoordLanes::derive(family_seed, base);
        chunk.copy_from_slice(&lanes.u01());
        base = base.wrapping_add(COORD_LANES as u64);
    }
    for (k, o) in chunks.into_remainder().iter_mut().enumerate() {
        *o = Rng::derive_coord(family_seed, base.wrapping_add(k as u64)).u01();
    }
}

/// Fill `out[k] = Rng::derive_coord(family_seed, lo + k).dither()` — the
/// U(-1/2, 1/2) sibling of [`fill_u01_coords`].
pub fn fill_dither_coords(family_seed: u64, lo: u64, out: &mut [f64]) {
    let mut base = lo;
    let mut chunks = out.chunks_exact_mut(COORD_LANES);
    for chunk in chunks.by_ref() {
        let mut lanes: CoordLanes<COORD_LANES> = CoordLanes::derive(family_seed, base);
        chunk.copy_from_slice(&lanes.dither());
        base = base.wrapping_add(COORD_LANES as u64);
    }
    for (k, o) in chunks.into_remainder().iter_mut().enumerate() {
        *o = Rng::derive_coord(family_seed, base.wrapping_add(k as u64)).dither();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_domain_separates_families_and_never_returns_the_root() {
        let root = 42u64;
        // deterministic
        assert_eq!(
            Rng::derive_domain(root, seed_domain::ROUND, 0),
            Rng::derive_domain(root, seed_domain::ROUND, 0)
        );
        // index 0 must NOT hand back the raw root (the old XOR-fold bug)
        for &dom in &[seed_domain::ROUND, seed_domain::SESSION, seed_domain::COHORT] {
            assert_ne!(Rng::derive_domain(root, dom, 0), root, "domain {dom:#x}");
        }
        // pairwise distinct across domains × indices for a sweep of roots
        for root in [0u64, 1, 42, u64::MAX] {
            let mut seen = Vec::new();
            for &dom in &[seed_domain::ROUND, seed_domain::SESSION, seed_domain::COHORT] {
                for idx in 0..64u64 {
                    seen.push(Rng::derive_domain(root, dom, idx));
                }
            }
            let len = seen.len();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), len, "derived-seed collision under root {root}");
        }
    }

    #[test]
    fn derive_coord_is_position_free_and_coord_distinct() {
        // the chunked-pipeline primitive: coordinate j's stream depends
        // only on (family, j) — deterministic, distinct across coords and
        // families, and trivially identical no matter what was drawn for
        // other coordinates first
        let fam = Rng::derive_domain(42, seed_domain::COORD_FAMILY, 3);
        let mut a = Rng::derive_coord(fam, 7);
        let mut b = Rng::derive_coord(fam, 7);
        let x = a.next_u64();
        assert_eq!(x, b.next_u64());
        assert_ne!(x, Rng::derive_coord(fam, 8).next_u64());
        let fam2 = Rng::derive_domain(42, seed_domain::COORD_FAMILY, 4);
        assert_ne!(x, Rng::derive_coord(fam2, 7).next_u64());
        // a sweep of coords under one family yields no collisions
        let mut seen: Vec<u64> = (0..512u64)
            .map(|j| Rng::derive_coord(fam, j).next_u64())
            .collect();
        let len = seen.len();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), len);
    }

    #[test]
    fn state_capture_is_stream_position_not_reseed() {
        // Snapshot/resume contract: capturing RngState mid-stream and
        // restoring it continues the exact stream — including through an
        // odd number of normal() draws, where the polar method has a
        // cached spare that a reseed would lose.
        let mut r = Rng::new(0x5EED);
        for _ in 0..17 {
            r.next_u64();
        }
        r.normal(); // leaves a gauss_spare cached
        let snap = r.state();
        assert!(snap.gauss_spare.is_some());
        let mut resumed = Rng::from_state(snap);
        for _ in 0..64 {
            assert_eq!(r.next_u64(), resumed.next_u64());
        }
        assert_eq!(r.normal().to_bits(), resumed.normal().to_bits());
        // ... whereas reseeding from scratch rewinds the stream
        let mut reseeded = Rng::new(0x5EED);
        assert!(reseeded.state() != snap, "fresh seed must not equal mid-stream state");
    }

    #[test]
    fn derive_differs_per_stream() {
        let mut a = Rng::derive(7, 0);
        let mut b = Rng::derive(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn u01_in_range_and_uniform() {
        let mut r = Rng::new(1);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let u = r.u01();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            sum2 += u * u;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 400_000;
        let (mut s1, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
            s4 += z * z * z * z;
        }
        let nf = n as f64;
        assert!((s1 / nf).abs() < 0.01);
        assert!((s2 / nf - 1.0).abs() < 0.02);
        assert!((s4 / nf - 3.0).abs() < 0.1); // kurtosis
    }

    #[test]
    fn laplace_variance() {
        let mut r = Rng::new(3);
        let b = 0.7;
        let n = 300_000;
        let mut s2 = 0.0;
        for _ in 0..n {
            let z = r.laplace(b);
            s2 += z * z;
        }
        // Var of Laplace(0, b) = 2 b^2
        assert!((s2 / n as f64 - 2.0 * b * b).abs() < 0.02);
    }

    #[test]
    fn geometric_mean() {
        let mut r = Rng::new(4);
        let p = 0.25;
        let n = 200_000;
        let mut s = 0u64;
        for _ in 0..n {
            s += r.geometric(p);
        }
        let mean = s as f64 / n as f64;
        assert!((mean - (1.0 - p) / p).abs() < 0.05, "{mean}");
    }

    #[test]
    fn below_is_unbiased() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..140_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 20_000.0).abs() < 1_000.0, "{counts:?}");
        }
    }

    #[test]
    fn coord_lanes_match_scalar_streams_draw_for_draw() {
        // every lane of the batched deriver IS the scalar per-coordinate
        // stream: successive raw draws agree bit for bit
        let fam = Rng::derive_domain(7, seed_domain::COORD_FAMILY, 0);
        for base in [0u64, 1, 13, 1_000_003] {
            let mut lanes: CoordLanes<8> = Rng::derive_coord_batch(fam, base);
            let mut scalars: Vec<Rng> =
                (0..8).map(|l| Rng::derive_coord(fam, base + l)).collect();
            for _ in 0..16 {
                let batch = lanes.next_u64();
                for (l, s) in scalars.iter_mut().enumerate() {
                    assert_eq!(batch[l], s.next_u64(), "lane {l} base {base}");
                }
            }
        }
    }

    #[test]
    fn coord_lanes_below_matches_scalar_under_heavy_rejection() {
        // n just above 2^63 gives t = 2^64 mod n ≈ 2^62: ~1/4 of draws
        // reject, so the per-lane slow path is exercised constantly and
        // must consume exactly the scalar redraw sequence
        let fam = Rng::derive_domain(11, seed_domain::COORD_FAMILY, 2);
        for n in [3u64, 7, (1 << 40), (1 << 63) + (1 << 61), u64::MAX - 1] {
            let t = lemire_threshold(n);
            for base in [0u64, 5, 129] {
                let mut lanes: CoordLanes<8> = CoordLanes::derive(fam, base);
                let batch = lanes.below(n, t);
                for (l, &got) in batch.iter().enumerate() {
                    let want = Rng::derive_coord(fam, base + l as u64).below(n);
                    assert_eq!(got, want, "n={n} lane {l} base {base}");
                    assert!(got < n);
                }
            }
        }
    }

    #[test]
    fn coord_fills_match_scalar_loops_for_unaligned_lengths() {
        // fills over lengths straddling every lane-alignment case (< L,
        // = L, non-multiples) equal the scalar per-coordinate loop
        let fam = Rng::derive_domain(23, seed_domain::COORD_FAMILY, 5);
        let n = 1u64 << 40;
        for len in [0usize, 1, 7, 8, 9, 64, 100] {
            for lo in [0u64, 1, 13] {
                let mut got = vec![0u64; len];
                fill_below_coords(fam, lo, n, &mut got);
                let want: Vec<u64> =
                    (0..len).map(|k| Rng::derive_coord(fam, lo + k as u64).below(n)).collect();
                assert_eq!(got, want, "below len {len} lo {lo}");

                let mut gf = vec![0.0f64; len];
                fill_u01_coords(fam, lo, &mut gf);
                let wf: Vec<f64> =
                    (0..len).map(|k| Rng::derive_coord(fam, lo + k as u64).u01()).collect();
                assert_eq!(gf, wf, "u01 len {len} lo {lo}");

                fill_dither_coords(fam, lo, &mut gf);
                let wd: Vec<f64> =
                    (0..len).map(|k| Rng::derive_coord(fam, lo + k as u64).dither()).collect();
                assert_eq!(gf, wd, "dither len {len} lo {lo}");
            }
        }
    }

    #[test]
    fn coord_lanes_are_lane_width_invariant() {
        // the same coordinate produces the same bits no matter which lane
        // width (or lane position) covers it — batching is reassociation
        let fam = Rng::derive_domain(31, seed_domain::COORD_FAMILY, 1);
        let want: Vec<u64> = (0..32).map(|j| Rng::derive_coord(fam, j).next_u64()).collect();
        macro_rules! check_width {
            ($w:literal) => {
                let mut got = Vec::new();
                let mut base = 0u64;
                while (base as usize) < 32 {
                    let mut lanes: CoordLanes<$w> = CoordLanes::derive(fam, base);
                    got.extend(lanes.next_u64());
                    base += $w;
                }
                got.truncate(32);
                assert_eq!(got, want, "lane width {}", $w);
            };
        }
        check_width!(1);
        check_width!(2);
        check_width!(4);
        check_width!(8);
        check_width!(16);
    }

    #[test]
    fn lemire_threshold_is_two_pow_64_mod_n() {
        for n in [1u64, 2, 3, 7, 1 << 40, (1 << 63) + 1, u64::MAX] {
            let want = ((1u128 << 64) % n as u128) as u64;
            assert_eq!(lemire_threshold(n), want, "n={n}");
            assert!(lemire_threshold(n) < n);
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let idx = r.sample_indices(100, 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }
}
