//! CSGM — the coordinate-subsampled Gaussian mechanism of Chen et al. 2023
//! ("Privacy amplification via compression"), as used for the Fig. 5 / 7
//! comparison: coordinate-wise Bernoulli(γ) subsampling, b-bit subtractive
//! dithered quantization of the selected values, then server-side Gaussian
//! noise to reach the DP target.
//!
//! The structural difference to SIGM is the paper's point: CSGM pays a
//! quantization error *on top of* the (independent) DP noise, whereas SIGM
//! *shapes* the quantization error itself into the exact Gaussian. With
//! the bit budget matched, CSGM's MSE is strictly larger by the
//! quantization variance.
//!
//! Pipeline shape: the fixed shared step makes the decode a function of
//! Σᵢ mᵢ (the dithers and the subsampling matrix are shared randomness the
//! server re-derives), so CSGM is homomorphic: clients emit a dense
//! description vector (0 on unselected coordinates, which drop out of the
//! sum) and the mechanism rides the sum-only transports, SecAgg included.

use crate::mechanisms::pipeline::{
    impl_mean_mechanism, ClientEncoder, Descriptions, MechSpec, Payload, Plain, ServerDecoder,
    SharedRound, SurvivorSet,
};
use crate::mechanisms::traits::BitsAccount;
use crate::quantizer::round_half_up;

#[derive(Clone, Debug)]
pub struct Csgm {
    /// sd of the server-added Gaussian DP noise (same target as SIGM's σ)
    pub sigma: f64,
    /// coordinate-subsampling probability γ
    pub gamma: f64,
    /// per-coordinate input bound |x_ij| <= c
    pub input_bound_c: f64,
    /// quantization bits per selected coordinate (matched to SIGM's budget)
    pub bits: u32,
}

impl Csgm {
    pub fn new(sigma: f64, gamma: f64, input_bound_c: f64, bits: u32) -> Self {
        assert!(sigma > 0.0 && (0.0..=1.0).contains(&gamma) && bits >= 1);
        Self { sigma, gamma, input_bound_c, bits }
    }

    /// quantization step over [−c, c] with 2^b levels
    pub fn step(&self) -> f64 {
        2.0 * self.input_bound_c / ((1u64 << self.bits) - 1) as f64
    }
}

impl MechSpec for Csgm {
    fn name(&self) -> String {
        format!("csgm(sigma={}, gamma={}, b={})", self.sigma, self.gamma, self.bits)
    }

    fn is_homomorphic(&self) -> bool {
        true // fixed-step dithering sums before decoding
    }

    fn gaussian_noise(&self) -> bool {
        false // total error = uniform quantization noise + Gaussian
    }

    fn fixed_length(&self) -> bool {
        true
    }

    fn noise_sd(&self) -> f64 {
        self.sigma
    }
}

impl ClientEncoder for Csgm {
    fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions {
        self.encode_chunk(client, x, 0..x.len(), round)
    }

    /// Chunk-ranged encode: the Bernoulli(γ) selection AND the dither of
    /// coordinate j come from seekable per-coordinate streams (the same
    /// subsample family SIGM reads, so the matched-subsample comparison
    /// of Figs. 5/7 survives chunking), so any chunking concatenates to
    /// the whole-vector encode bit for bit.
    fn encode_chunk(
        &self,
        client: usize,
        x: &[f64],
        range: std::ops::Range<usize>,
        round: &SharedRound,
    ) -> Descriptions {
        self.encode_chunk_slice(client, &x[range.clone()], range, round)
    }

    /// Slice-ranged encode — selection and dither are per-coordinate
    /// streams addressed by the absolute coordinate j, and the data is
    /// read from the chunk slice (`encode_chunk` is the `&x[range]`
    /// delegation above).
    fn slice_chunkable(&self) -> bool {
        true
    }

    fn encode_chunk_slice(
        &self,
        client: usize,
        x_chunk: &[f64],
        range: std::ops::Range<usize>,
        round: &SharedRound,
    ) -> Descriptions {
        assert_eq!(x_chunk.len(), range.len(), "chunk slice does not match its range");
        let w = self.step();
        // the client touches only ITS OWN per-coordinate streams — O(c)
        // work for the chunk, no cached O(n·d) matrix anywhere
        let select = round.subsample_coord_stream(client);
        let dither = round.client_coord_stream(client);
        let mut bits = BitsAccount::default();
        let mut fixed_total = 0.0;
        let ms: Vec<i64> = range
            .zip(x_chunk.iter())
            .map(|(j, &xj)| {
                if !select.at(j).bernoulli(self.gamma) {
                    // unselected coordinates transmit nothing; a zero in
                    // the dense vector leaves Σm untouched
                    return 0;
                }
                let u = dither.at(j).u01();
                let m = round_half_up(xj / w + u);
                bits.add_description(m);
                fixed_total += self.bits as f64;
                m
            })
            .collect();
        bits.fixed_total = Some(fixed_total);
        Descriptions { ms, aux: vec![], bits }
    }
}

impl ServerDecoder for Csgm {
    fn sum_decodable(&self) -> bool {
        true
    }

    fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
        self.decode_survivors(payload, round, &SurvivorSet::full(round.n_clients))
    }

    /// Survivor-aware decode: sum only the survivors' re-derived dithers
    /// and divide by γn′. The quantization error then has a random
    /// Bin(n′, γ) number of terms (CSGM makes no exact-shape claim — its
    /// error is quantization noise PLUS the Gaussian, which is the
    /// paper's point), and the server-side DP noise stays at its
    /// calibrated σ: it is a privacy target, not an n-scaled quantity.
    fn decode_survivors(
        &self,
        payload: &Payload,
        round: &SharedRound,
        survivors: &SurvivorSet,
    ) -> Vec<f64> {
        let est = self.decode_survivors_chunk(payload, 0, round, survivors);
        assert_eq!(est.len(), round.dim, "payload does not cover the coordinate space");
        est
    }

    fn chunk_decodable(&self) -> bool {
        true
    }

    /// The chunk-ranged core of the decode: selections, dithers and the
    /// server-side noise draws are all per-coordinate seekable streams,
    /// so the server works in O(c) state per chunk and the concatenation
    /// over any chunking equals the whole-d decode bit for bit.
    fn decode_survivors_chunk(
        &self,
        payload: &Payload,
        lo: usize,
        round: &SharedRound,
        survivors: &SurvivorSet,
    ) -> Vec<f64> {
        let n = round.n_clients;
        assert_eq!(survivors.n(), n, "survivor set shaped for a different fleet");
        let w = self.step();
        let m_sum = payload.description_sum();
        let len = m_sum.len();
        assert!(lo + len <= round.dim, "chunk exceeds the coordinate space");
        // re-derive the selected SURVIVORS' dithers (shared randomness)
        // for this chunk — O(c) working state, no cached matrix
        let mut s_sum = vec![0.0f64; len];
        for i in survivors.alive_iter() {
            let select = round.subsample_coord_stream(i);
            let dither = round.client_coord_stream(i);
            for (k, sj) in s_sum.iter_mut().enumerate() {
                if select.at(lo + k).bernoulli(self.gamma) {
                    *sj += dither.at(lo + k).u01();
                }
            }
        }
        // divide by γn′ and add the calibrated server-side Gaussian noise
        let nf = survivors.n_alive() as f64;
        let noise = round.aux_coord_stream(2);
        (0..len)
            .map(|k| {
                (m_sum[k] as f64 - s_sum[k]) * w / (self.gamma * nf)
                    + noise.at(lo + k).normal_ms(0.0, self.sigma)
            })
            .collect()
    }
}

impl_mean_mechanism!(Csgm, |_m| Plain);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::traits::{true_mean, MeanMechanism};
    use crate::mechanisms::Sigm;
    use crate::util::rng::Rng;
    use crate::util::stats::mean as vmean;

    fn client_data(n: usize, d: usize, c: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..d).map(|_| rng.uniform(-c, c)).collect()).collect()
    }

    fn mse_of(mech: &dyn MeanMechanism, xs: &[Vec<f64>], rounds: usize, seed0: u64) -> f64 {
        let m = true_mean(xs);
        let mut sq = Vec::new();
        for r in 0..rounds {
            let out = mech.aggregate(xs, seed0 + r as u64);
            sq.push(crate::util::stats::mse(&out.estimate, &m) * m.len() as f64);
        }
        vmean(&sq)
    }

    #[test]
    fn estimate_is_unbiased() {
        let xs = client_data(50, 6, 1.0, 131);
        let mech = Csgm::new(0.05, 0.5, 1.0, 8);
        let m = true_mean(&xs);
        let mut acc = vec![0.0; 6];
        let rounds = 3000;
        for r in 0..rounds {
            let out = mech.aggregate(&xs, 70_000 + r);
            for j in 0..6 {
                acc[j] += out.estimate[j];
            }
        }
        for j in 0..6 {
            let avg = acc[j] / rounds as f64;
            assert!((avg - m[j]).abs() < 0.02, "j={j} avg={avg} want={}", m[j]);
        }
    }

    #[test]
    fn sigm_beats_csgm_at_matched_bits() {
        // the Fig. 5 headline: same ε (σ), same γ, same bit budget ⇒ SIGM
        // has lower MSE because its quantization error IS the DP noise
        let n = 200;
        let c = 1.0;
        let gamma = 0.5;
        let sigma = 0.02;
        let xs = client_data(n, 16, c, 132);
        let sigm = Sigm::new(sigma, gamma, c);
        // measure SIGM's fixed-length budget, hand it to CSGM
        let probe = sigm.aggregate(&xs, 1);
        let bits_per_msg = probe.bits.fixed_total.unwrap() / probe.bits.messages as f64;
        let csgm = Csgm::new(sigma, gamma, c, bits_per_msg.ceil() as u32);
        let mse_sigm = mse_of(&sigm, &xs, 60, 80_000);
        let mse_csgm = mse_of(&csgm, &xs, 60, 90_000);
        assert!(
            mse_sigm < mse_csgm,
            "SIGM {mse_sigm} not better than CSGM {mse_csgm} at b={}",
            bits_per_msg.ceil()
        );
    }

    #[test]
    fn csgm_error_contains_quantization_component() {
        // with coarse bits, MSE is dominated by quantization noise
        let xs = client_data(100, 8, 1.0, 133);
        let fine = Csgm::new(0.01, 1.0, 1.0, 10);
        let coarse = Csgm::new(0.01, 1.0, 1.0, 2);
        let mse_f = mse_of(&fine, &xs, 80, 100_000);
        let mse_c = mse_of(&coarse, &xs, 80, 110_000);
        assert!(mse_c > mse_f * 2.0, "coarse {mse_c} fine {mse_f}");
    }

    #[test]
    fn property_flags() {
        let m: &dyn MeanMechanism = &Csgm::new(0.1, 0.5, 1.0, 8);
        assert!(!m.gaussian_noise());
        assert!(m.fixed_length());
        assert!(m.is_homomorphic());
    }
}
