//! # exact-comp
//!
//! Production-grade reproduction of *"Compression with Exact Error
//! Distribution for Federated Learning"* (Hegazy, Leluc, Li, Dieuleveut,
//! 2023): quantized aggregation mechanisms whose compression error follows a
//! *target distribution exactly* (AINQ — Additive Independent Noise
//! Quantization), their communication analysis, and the paper's three
//! applications (compression-for-free differential privacy, Langevin
//! dynamics, randomized smoothing).
//!
//! ## Layout (three-layer architecture, Python never on the request path)
//!
//! * [`util`] — PRNGs, special functions, statistics, micro-bench harness
//!   (the offline registry has no rand/criterion/proptest; all built here).
//! * [`dist`] — Gaussian / Laplace / Uniform / Irwin–Hall / discrete
//!   Gaussian distributions with superlevel-set geometry for layered
//!   quantizers.
//! * [`coding`] — bit I/O, Elias gamma, Huffman, fixed-length codes and
//!   entropy accounting (communication-cost measurements of §3.2, §4.5).
//! * [`quantizer`] — subtractive dithering (Ex. 1), direct (Def. 4) and
//!   shifted (Def. 5) layered quantizers.
//! * [`mechanisms`] — individual AINQ (Def. 2), Irwin–Hall (§4.2),
//!   aggregate Q / Gaussian (Def. 8 + Algorithms 1–4), SIGM (§5.1, Alg. 5).
//! * [`baselines`] — CSGM (Chen et al. 2023), DDG (Kairouz et al. 2021a),
//!   unbiased b-bit quantization (QLSD baseline).
//! * [`transforms`] — fast Walsh–Hadamard, randomized rotation, Kashin
//!   flattening (Remark 1).
//! * [`dp`] — (ε, δ) / Rényi / zCDP accounting and calibration.
//! * [`secagg`] — additive-masking secure aggregation over ℤ_m.
//! * [`coordinator`] — the FL runtime: thread-per-client rounds, shared
//!   randomness, bit accounting, metrics.
//! * [`runtime`] — PJRT engine loading the AOT-lowered JAX/Pallas HLO
//!   artifacts (`artifacts/*.hlo.txt`).
//! * [`apps`] — distributed mean estimation, QLSD* Langevin, distributed
//!   randomized smoothing, end-to-end FL training.
//! * [`figures`] — regenerates every table and figure of the paper's
//!   evaluation (`repro figures --all`).

pub mod util;
pub mod dist;
pub mod coding;
pub mod quantizer;
pub mod mechanisms;
pub mod baselines;
pub mod transforms;
pub mod dp;
pub mod secagg;
pub mod coordinator;
pub mod runtime;
pub mod apps;
pub mod figures;
pub mod testing;
pub mod cli;
