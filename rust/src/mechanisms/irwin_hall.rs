//! The Irwin–Hall mechanism (§4.2): every client subtractively dithers with
//! the SAME step w = 2σ√(3n). The decoder needs only Σᵢ Mᵢ (and the shared
//! dithers, which it re-derives from the round seed), so the mechanism is
//! homomorphic — it rides the sum-only transports, SecAgg included — but
//! the aggregate noise is IH(n, 0, σ²), only *approximately* Gaussian, and
//! not a DP-calibratable law.

use super::pipeline::{
    impl_mean_mechanism, ClientEncoder, Descriptions, MechSpec, Payload, Plain, ServerDecoder,
    SharedRound, SurvivorSet,
};
use super::traits::BitsAccount;
use crate::coding::fixed::FixedCode;
use crate::quantizer::round_half_up;

#[derive(Clone, Debug)]
pub struct IrwinHallMechanism {
    /// aggregate noise sd
    pub sigma: f64,
    /// input magnitude bound |x_ij| <= t/2 (fixed-length sizing)
    pub input_range_t: f64,
}

impl IrwinHallMechanism {
    pub fn new(sigma: f64, input_range_t: f64) -> Self {
        assert!(sigma > 0.0);
        Self { sigma, input_range_t }
    }

    /// The §4.2 step size.
    pub fn step(&self, n: usize) -> f64 {
        2.0 * self.sigma * (3.0 * n as f64).sqrt()
    }

    /// Homomorphic decode from the aggregated description sum (Def. 6):
    /// only Σ m and Σ s are needed.
    pub fn decode_from_sums(&self, m_sum: f64, s_sum: f64, n: usize) -> f64 {
        self.step(n) * (m_sum - s_sum) / n as f64
    }
}

impl MechSpec for IrwinHallMechanism {
    fn name(&self) -> String {
        format!("irwin-hall(sigma={})", self.sigma)
    }

    fn is_homomorphic(&self) -> bool {
        true
    }

    fn gaussian_noise(&self) -> bool {
        false
    }

    fn fixed_length(&self) -> bool {
        true // fixed step w ⇒ bounded support for bounded inputs
    }

    fn noise_sd(&self) -> f64 {
        self.sigma
    }
}

impl ClientEncoder for IrwinHallMechanism {
    fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions {
        self.encode_chunk(client, x, 0..x.len(), round)
    }

    /// Chunk-ranged encode: coordinate j's dither comes from the seekable
    /// per-coordinate client stream, so any chunking concatenates to the
    /// whole-vector encode bit for bit.
    fn encode_chunk(
        &self,
        client: usize,
        x: &[f64],
        range: std::ops::Range<usize>,
        round: &SharedRound,
    ) -> Descriptions {
        self.encode_chunk_slice(client, &x[range.clone()], range, round)
    }

    /// Slice-ranged encode — purely per-coordinate draws, so the chunk
    /// slice alone suffices (`encode_chunk` is the `&x[range]`
    /// delegation above).
    fn slice_chunkable(&self) -> bool {
        true
    }

    fn encode_chunk_slice(
        &self,
        client: usize,
        x_chunk: &[f64],
        range: std::ops::Range<usize>,
        round: &SharedRound,
    ) -> Descriptions {
        assert_eq!(x_chunk.len(), range.len(), "chunk slice does not match its range");
        let w = self.step(round.n_clients);
        let code_bits = FixedCode::from_support_bound(self.input_range_t, w).bits() as f64;
        // lane-batched dither fill: one u01 per coordinate stream,
        // bit-identical to the scalar at(j).u01() loop
        let mut dithers = vec![0.0f64; range.len()];
        round.client_coord_stream(client).fill_u01(range.start, &mut dithers);
        let mut bits = BitsAccount::default();
        let mut fixed_total = 0.0;
        let ms: Vec<i64> = x_chunk
            .iter()
            .zip(dithers.iter())
            .map(|(&xj, &s)| {
                let m = round_half_up(xj / w + s);
                bits.add_description(m);
                fixed_total += code_bits;
                m
            })
            .collect();
        bits.fixed_total = Some(fixed_total);
        Descriptions { ms, aux: vec![], bits }
    }
}

impl ServerDecoder for IrwinHallMechanism {
    fn sum_decodable(&self) -> bool {
        true
    }

    fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
        self.decode_survivors(payload, round, &SurvivorSet::full(round.n_clients))
    }

    /// Survivor-aware decode. The step w was sized to the *announced* n at
    /// encode time, so with n′ < n survivors the decoder (a) sums only the
    /// survivors' re-derived dithers, (b) completes the n − n′ missing
    /// U(−1/2, 1/2] quantization-error terms from the shared per-dropout
    /// completion streams, and (c) averages over n′. The aggregate error
    /// keeps its exact n-term Irwin–Hall law at the rescaled scale σ·n/n′
    /// (KS-tested).
    fn decode_survivors(
        &self,
        payload: &Payload,
        round: &SharedRound,
        survivors: &SurvivorSet,
    ) -> Vec<f64> {
        let est = self.decode_survivors_chunk(payload, 0, round, survivors);
        assert_eq!(est.len(), round.dim, "payload does not cover the coordinate space");
        est
    }

    fn chunk_decodable(&self) -> bool {
        true
    }

    /// The chunk-ranged core of the decode: every stream it touches —
    /// survivor dithers, dropout completions — is seekable per
    /// coordinate, so the server re-derives only the active chunk's slice
    /// (O(c) working state) and the concatenation over any
    /// [`crate::mechanisms::pipeline::ChunkPlan`] equals the whole-d
    /// decode bit for bit.
    fn decode_survivors_chunk(
        &self,
        payload: &Payload,
        lo: usize,
        round: &SharedRound,
        survivors: &SurvivorSet,
    ) -> Vec<f64> {
        let n = round.n_clients;
        assert_eq!(survivors.n(), n, "survivor set shaped for a different fleet");
        let m_sum = payload.description_sum();
        let len = m_sum.len();
        assert!(lo + len <= round.dim, "chunk exceeds the coordinate space");
        // shared randomness: the server re-derives the SURVIVORS' dithers
        // for this chunk only — O(c) state, never the per-client
        // descriptions
        let mut s_sum = vec![0.0f64; len];
        let mut scratch = vec![0.0f64; len];
        for i in survivors.alive_iter() {
            round.client_coord_stream(i).fill_u01(lo, &mut scratch);
            for (sj, &v) in s_sum.iter_mut().zip(scratch.iter()) {
                *sj += v;
            }
        }
        // dropout noise completion: a fresh shared U(−1/2, 1/2) draw
        // stands in for each dropped client's unknowable dithered
        // quantization error
        let mut topup = vec![0.0f64; len];
        for j in survivors.dropped_iter() {
            round.dropout_coord_stream(j).fill_dither(lo, &mut scratch);
            for (tj, &v) in topup.iter_mut().zip(scratch.iter()) {
                *tj += v;
            }
        }
        let w = self.step(n);
        let n_alive = survivors.n_alive() as f64;
        (0..len).map(|k| w * (m_sum[k] as f64 - s_sum[k] + topup[k]) / n_alive).collect()
    }
}

impl_mean_mechanism!(IrwinHallMechanism, |_m| Plain);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Continuous, IrwinHall};
    use crate::mechanisms::traits::{true_mean, MeanMechanism};
    use crate::util::rng::Rng;
    use crate::util::stats::{ks_test, variance};

    fn client_data(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..d).map(|_| rng.uniform(-8.0, 8.0)).collect()).collect()
    }

    #[test]
    fn noise_is_exactly_irwin_hall() {
        let n = 12;
        let sigma = 0.9;
        let xs = client_data(n, 5, 7);
        let mech = IrwinHallMechanism::new(sigma, 16.0);
        let mean = true_mean(&xs);
        let mut errs = Vec::new();
        for r in 0..600 {
            let out = mech.aggregate(&xs, 5000 + r);
            for j in 0..mean.len() {
                errs.push(out.estimate[j] - mean[j]);
            }
        }
        let ih = IrwinHall::new(n as u64, 0.0, sigma);
        let res = ks_test(&errs, |e| ih.cdf(e));
        assert!(res.p_value > 0.003, "p={}", res.p_value);
        assert!((variance(&errs) - sigma * sigma).abs() < 0.05);
    }

    #[test]
    fn noise_is_not_gaussian_for_small_n() {
        // for n = 2 the noise is a triangle; its KS distance to N(0,1) is
        // ~0.018, so ~25k samples make the rejection decisive
        let xs = client_data(2, 8, 8);
        let mech = IrwinHallMechanism::new(1.0, 16.0);
        let mean = true_mean(&xs);
        let mut errs = Vec::new();
        for r in 0..3200 {
            let out = mech.aggregate(&xs, 9000 + r);
            for j in 0..mean.len() {
                errs.push(out.estimate[j] - mean[j]);
            }
        }
        let g = crate::dist::Gaussian::new(0.0, 1.0);
        assert!(ks_test(&errs, |e| g.cdf(e)).p_value < 1e-4);
    }

    #[test]
    fn homomorphic_decode_equals_full_decode() {
        // decoding from sums == averaging per-client decodes
        let n = 6;
        let xs = client_data(n, 3, 9);
        let mech = IrwinHallMechanism::new(1.0, 16.0);
        let w = mech.step(n);
        let seed = 31337;
        // reproduce client encodings from the per-coordinate streams
        let d = 3;
        let round = crate::mechanisms::pipeline::SharedRound::new(seed, n, d);
        let mut per_client = vec![0.0f64; d];
        let mut m_sum = vec![0.0f64; d];
        let mut s_sum = vec![0.0f64; d];
        for (i, x) in xs.iter().enumerate() {
            let dither = round.client_coord_stream(i);
            for j in 0..d {
                let s = dither.at(j).u01();
                let m = round_half_up(x[j] / w + s);
                per_client[j] += (m as f64 - s) * w;
                m_sum[j] += m as f64;
                s_sum[j] += s;
            }
        }
        for j in 0..d {
            let homo = mech.decode_from_sums(m_sum[j], s_sum[j], n);
            let avg = per_client[j] / n as f64;
            assert!((homo - avg).abs() < 1e-9, "j={j}");
        }
    }

    #[test]
    fn pipeline_output_reproduces_manual_reconstruction() {
        // the pipeline's aggregate() must equal the hand-rolled shared-
        // randomness reconstruction above, bit for bit
        let n = 6;
        let xs = client_data(n, 3, 9);
        let mech = IrwinHallMechanism::new(1.0, 16.0);
        let w = mech.step(n);
        let seed = 31337;
        let out = mech.aggregate(&xs, seed);
        let d = 3;
        let round = crate::mechanisms::pipeline::SharedRound::new(seed, n, d);
        let mut m_sum = vec![0.0f64; d];
        let mut s_sum = vec![0.0f64; d];
        for (i, x) in xs.iter().enumerate() {
            let dither = round.client_coord_stream(i);
            for j in 0..d {
                let s = dither.at(j).u01();
                m_sum[j] += round_half_up(x[j] / w + s) as f64;
                s_sum[j] += s;
            }
        }
        for j in 0..d {
            let want = mech.decode_from_sums(m_sum[j], s_sum[j], n);
            assert!((out.estimate[j] - want).abs() < 1e-12, "j={j}");
        }
        assert_eq!(out.bits.messages, (n * d) as u64);
        assert!(out.bits.fixed_total.unwrap() > 0.0);
    }

    #[test]
    fn chunked_encode_concatenates_to_whole_encode() {
        // chunk-ranged encodes over any chunk size reproduce the
        // whole-vector encode bit for bit — descriptions AND accounting
        let n = 4;
        let d = 7;
        let xs = client_data(n, d, 13);
        let mech = IrwinHallMechanism::new(0.6, 16.0);
        let round = crate::mechanisms::pipeline::SharedRound::new(99, n, d);
        for (i, x) in xs.iter().enumerate() {
            let whole = mech.encode(i, x, &round);
            for c in [1usize, 3, d, d + 2] {
                let mut ms = Vec::new();
                let mut messages = 0u64;
                let mut variable = 0.0;
                let mut fixed = 0.0;
                let mut lo = 0;
                while lo < d {
                    let hi = (lo + c).min(d);
                    let part = mech.encode_chunk(i, x, lo..hi, &round);
                    ms.extend(part.ms);
                    messages += part.bits.messages;
                    variable += part.bits.variable_total;
                    fixed += part.bits.fixed_total.unwrap();
                    lo = hi;
                }
                assert_eq!(ms, whole.ms, "client {i}, chunk {c}");
                assert_eq!(messages, whole.bits.messages);
                assert_eq!(variable, whole.bits.variable_total);
                assert_eq!(fixed, whole.bits.fixed_total.unwrap());
            }
        }
    }

    #[test]
    fn matches_mechanism_output() {
        let xs = client_data(4, 2, 10);
        let mech = IrwinHallMechanism::new(0.5, 16.0);
        let a = mech.aggregate(&xs, 42);
        let b = mech.aggregate(&xs, 42);
        assert_eq!(a.estimate, b.estimate); // deterministic given seed
    }

    #[test]
    fn property_flags() {
        // qualified: MechSpec and MeanMechanism expose the same flags
        let m: &dyn MeanMechanism = &IrwinHallMechanism::new(1.0, 16.0);
        assert!(m.is_homomorphic());
        assert!(!m.gaussian_noise());
        assert!(m.fixed_length());
    }

    #[test]
    fn dropout_decode_at_full_set_equals_decode() {
        use crate::mechanisms::pipeline::{Plain, SurvivorSet, Transport};
        let n = 5;
        let xs = client_data(n, 4, 21);
        let mech = IrwinHallMechanism::new(0.7, 16.0);
        let round = crate::mechanisms::pipeline::SharedRound::new(33, n, 4);
        let mut part = Plain.empty(&round);
        for (i, x) in xs.iter().enumerate() {
            Plain.submit(&mut part, i, &mech.encode(i, x, &round), &round);
        }
        let payload = Plain.finish(part, &round);
        assert_eq!(
            mech.decode(&payload, &round),
            mech.decode_survivors(&payload, &round, &SurvivorSet::full(n))
        );
    }

    #[test]
    fn dropout_survivor_noise_is_exactly_irwin_hall_at_rescaled_scale() {
        // one dropout out of n=8: survivor error must be exactly
        // IH(n, 0, σ·n/n′) — the noise completion keeps the n-term law,
        // the averaging rescales it
        use crate::mechanisms::pipeline::{Plain, SurvivorSet, Transport};
        let n = 8;
        let sigma = 0.8;
        let xs = client_data(n, 5, 77);
        let mech = IrwinHallMechanism::new(sigma, 16.0);
        let survivors = SurvivorSet::with_dropped(n, &[5]);
        let smean: Vec<f64> = {
            let mut m = vec![0.0; 5];
            for i in survivors.alive_iter() {
                for (mj, xj) in m.iter_mut().zip(&xs[i]) {
                    *mj += xj;
                }
            }
            m.into_iter().map(|v| v / survivors.n_alive() as f64).collect()
        };
        let mut errs = Vec::new();
        for r in 0..700u64 {
            let round = crate::mechanisms::pipeline::SharedRound::new(40_000 + r, n, 5);
            let mut part = Plain.empty(&round);
            for i in survivors.alive_iter() {
                Plain.submit(&mut part, i, &mech.encode(i, &xs[i], &round), &round);
            }
            let est = mech.decode_survivors(&Plain.finish(part, &round), &round, &survivors);
            for j in 0..5 {
                errs.push(est[j] - smean[j]);
            }
        }
        let scale = sigma * n as f64 / survivors.n_alive() as f64;
        let ih = IrwinHall::new(n as u64, 0.0, scale);
        let res = ks_test(&errs, |e| ih.cdf(e));
        assert!(res.p_value > 0.003, "p={}", res.p_value);
        assert!((variance(&errs) - scale * scale).abs() < 0.1, "var={}", variance(&errs));
    }
}
