//! End-to-end FL training through the PJRT runtime: FedSGD with
//! exact-error compressed (and optionally DP) gradient aggregation.
//!
//! Per round, every client computes its minibatch gradient by executing
//! the AOT-lowered JAX/Pallas `model_grad` artifact (Layer 2 + 1), the
//! gradients are per-coordinate clipped and aggregated through a
//! [`MeanMechanism`] (Layer 3 — the paper's contribution), and the server
//! applies the SGD step. Python never runs here.
//!
//! Aggregation runs on the coordinator: the clipped gradients sit behind a
//! [`SliceCompute`] and each round is a one-round window of
//! [`crate::coordinator::runtime::run_rounds_encoded_chunked`] via
//! [`AppCoordinator`], with round `r`'s shared randomness derived as
//! `derive_domain(seed, ROUND, r)` — bit-identical to calling
//! `mech.aggregate(&grads, app_round_seed(seed, r))` directly.

use std::sync::Arc;

use anyhow::Result;

use crate::apps::driver::{AppCoordinator, CoordinatorOpts};
use crate::coordinator::metrics::Metrics;
use crate::mechanisms::pipeline::SliceCompute;
use crate::mechanisms::traits::MeanMechanism;
use crate::mechanisms::{AggregateGaussian, IndividualGaussian, IrwinHallMechanism, LayeredVariant};
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// Which aggregation mechanism the run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MechKind {
    /// aggregate Gaussian (homomorphic, exact Gaussian — the paper's §4.4)
    Aggregate,
    /// Irwin–Hall (homomorphic, approximately Gaussian)
    IrwinHall,
    /// individual Gaussian with shifted layered quantizers
    IndividualShifted,
    /// uncompressed FedSGD baseline
    None,
}

#[derive(Clone, Copy, Debug)]
pub struct TrainOpts {
    pub rounds: usize,
    pub lr: f64,
    pub n_clients: usize,
    /// per-coordinate gradient clip c (mechanism input bound)
    pub clip_c: f64,
    pub mech: MechKind,
    /// aggregate noise sd (ignored for MechKind::None)
    pub sigma: f64,
    pub eval_every: usize,
    pub seed: u64,
    /// coordinator streaming chunk size (0 = whole parameter vector; the
    /// driver clamps to d, and to d for non-chunkable transports)
    pub chunk: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self {
            rounds: 300,
            lr: 0.5,
            n_clients: 8,
            clip_c: 0.05,
            mech: MechKind::Aggregate,
            sigma: 1e-3,
            eval_every: 20,
            seed: 0xF1,
            chunk: 0,
        }
    }
}

/// Per-client synthetic classification data (non-iid via client-specific
/// feature shifts), shaped for the AOT artifacts.
pub struct FlDataset {
    /// per client: flattened (batch × d_in) features
    pub xs: Vec<Vec<f32>>,
    /// per client: labels
    pub ys: Vec<Vec<i32>>,
    /// held-out eval batch
    pub eval_x: Vec<f32>,
    pub eval_y: Vec<i32>,
}

pub fn gen_dataset(engine: &Engine, n_clients: usize, seed: u64) -> FlDataset {
    let m = &engine.manifest;
    let mut rng = Rng::new(seed);
    // fixed separating hyperplane
    let w_star: Vec<f64> = (0..m.d_in).map(|_| rng.normal()).collect();
    fn gen_batch(
        rng: &mut Rng,
        batch: usize,
        d_in: usize,
        w_star: &[f64],
        shift: &[f64],
    ) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(batch * d_in);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let feats: Vec<f64> = (0..d_in).map(|j| rng.normal() + shift[j]).collect();
            let score: f64 = feats.iter().zip(w_star).map(|(a, b)| a * b).sum();
            y.push(if score > 0.0 { 1i32 } else { 0i32 });
            x.extend(feats.iter().map(|&v| v as f32));
        }
        (x, y)
    }
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let zero_shift = vec![0.0; m.d_in];
    for _ in 0..n_clients {
        // non-iid: each client sees shifted features
        let shift: Vec<f64> = (0..m.d_in).map(|_| rng.normal_ms(0.0, 0.3)).collect();
        let (x, y) = gen_batch(&mut rng, m.batch, m.d_in, &w_star, &shift);
        xs.push(x);
        ys.push(y);
    }
    let (eval_x, eval_y) = gen_batch(&mut rng, m.batch, m.d_in, &w_star, &zero_shift);
    FlDataset { xs, ys, eval_x, eval_y }
}

fn build_mechanism(opts: &TrainOpts) -> Option<Box<dyn MeanMechanism>> {
    let t = 2.0 * opts.clip_c;
    match opts.mech {
        MechKind::Aggregate => Some(Box::new(AggregateGaussian::new(opts.sigma, t))),
        MechKind::IrwinHall => Some(Box::new(IrwinHallMechanism::new(opts.sigma, t))),
        MechKind::IndividualShifted => {
            Some(Box::new(IndividualGaussian::new(opts.sigma, LayeredVariant::Shifted, t)))
        }
        MechKind::None => None,
    }
}

/// Run FedSGD; returns metrics with series `loss`, `acc`, `bits_per_client`,
/// `grad_norm`.
pub fn train(engine: &Engine, data: &FlDataset, opts: TrainOpts) -> Result<Metrics> {
    let m = &engine.manifest;
    let p = m.param_count;
    let mech = build_mechanism(&opts);
    let mut metrics = Metrics::new("fl_train");
    let mut rng = Rng::new(opts.seed);
    let mut params: Vec<f32> = (0..p).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect();

    // The aggregation fleet: clipped gradients live behind a SliceCompute
    // that is re-pointed (`set`) each round; the pool and pipeline stages
    // spawn once for the whole run.
    let slices = Arc::new(SliceCompute::new(&vec![vec![0.0f64; p]; opts.n_clients]));
    let mut coord = mech.as_ref().map(|m| {
        AppCoordinator::new(
            m.as_ref(),
            slices.clone() as Arc<dyn crate::mechanisms::pipeline::LocalCompute>,
            opts.n_clients,
            p,
            CoordinatorOpts { chunk: opts.chunk, ..CoordinatorOpts::default() },
        )
    });

    for round in 0..opts.rounds {
        // clients: PJRT gradient computation (L2/L1 artifacts)
        let mut grads: Vec<Vec<f64>> = Vec::with_capacity(opts.n_clients);
        let mut loss_sum = 0.0f64;
        for c in 0..opts.n_clients {
            let (loss, g) = engine.model_grad(&params, &data.xs[c], &data.ys[c])?;
            loss_sum += loss as f64;
            // per-coordinate clip: the mechanism's input bound
            grads.push(
                g.into_iter()
                    .map(|v| (v as f64).clamp(-opts.clip_c, opts.clip_c))
                    .collect(),
            );
        }
        let train_loss = loss_sum / opts.n_clients as f64;

        // server: compressed aggregation on the coordinator + SGD step
        let (update, bits_pc) = match &mut coord {
            Some(coord) => {
                slices.set(grads);
                let state: Vec<f64> = params.iter().map(|&v| v as f64).collect();
                let mut reports = coord.run_rounds(round as u64, 1, &state, opts.seed);
                let rep = reports.pop().expect("one-round window yields one report");
                let bits = rep.output.bits.variable_per_client(opts.n_clients);
                (rep.output.estimate, bits)
            }
            None => {
                (crate::mechanisms::traits::true_mean(&grads), 64.0 * p as f64)
            }
        };
        for (pj, uj) in params.iter_mut().zip(&update) {
            *pj -= (opts.lr * uj) as f32;
        }

        metrics.record(round as u64, "train_loss", train_loss);
        metrics.record(round as u64, "bits_per_client", bits_pc);
        if round % opts.eval_every == 0 || round + 1 == opts.rounds {
            let (el, ea) = engine.model_eval(&params, &data.eval_x, &data.eval_y)?;
            metrics.record(round as u64, "loss", el as f64);
            metrics.record(round as u64, "acc", ea as f64);
        }
    }
    Ok(metrics)
}

// Integration tests (need artifacts/): rust/tests/integration_runtime.rs.
