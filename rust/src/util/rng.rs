//! Deterministic PRNG suite.
//!
//! The offline registry has no `rand` crate, and the paper's mechanisms all
//! hinge on *shared randomness*: a client and the server must generate
//! byte-identical random streams from a common seed (§2 "Quantized
//! aggregation"). We therefore implement:
//!
//! * [`SplitMix64`] — seed expansion / stream derivation (Steele et al.).
//! * [`Rng`] — xoshiro256++ core with standard real-valued samplers
//!   (uniform, Gaussian via polar Marsaglia, exponential, geometric, …).
//!
//! Stream derivation (`Rng::derive`) gives every (client, round, purpose)
//! tuple an independent stream from one root seed, which is exactly how the
//! coordinator distributes shared randomness.

/// Root-seed derivation domains for
/// [`crate::util::rng::Rng::derive_domain`]: every family of seeds derived
/// from the coordinator root seed is tagged with one of these, so no
/// family can alias another no matter what indices it uses.
/// (Before the seed-format bump, round seeds were `root ^ round·C` — round
/// 0 was handed the *raw root seed*, and XOR-composed families shared one
/// flat u64 space where collisions were possible by construction.)
pub mod seed_domain {
    /// Round r's shared-randomness seed (what
    /// [`crate::mechanisms::pipeline::SharedRound`] is built from).
    pub const ROUND: u64 = 0xD0_0001;
    /// A session window's transport seed
    /// ([`crate::mechanisms::session::derive_session_seed`]).
    pub const SESSION: u64 = 0xD0_0002;
    /// Round r's client-sampling cohort draw
    /// ([`crate::coordinator::sampling::SamplingPolicy`]).
    pub const COHORT: u64 = 0xD0_0003;
    /// A round's *per-coordinate* stream families
    /// ([`crate::mechanisms::pipeline::SharedRound::coord_family_seed`]):
    /// the seekable seed format of the chunked pipeline, where coordinate
    /// j's draws derive from (family, j) instead of advancing one
    /// sequential stream — so any chunking of the coordinate space
    /// reproduces identical bits.
    pub const COORD_FAMILY: u64 = 0xD0_0004;
}

/// SplitMix64: used for seeding and stream derivation (passes BigCrush).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ PRNG with distribution samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from the polar method
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (recommended by the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent stream for a (seed, stream-id) pair.
    ///
    /// Used by the coordinator to give every (client, round, purpose) its
    /// own reproducible stream: both end-points derive the same stream from
    /// the shared root seed without communicating.
    pub fn derive(root_seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(root_seed);
        let a = sm.next_u64();
        let mut sm2 = SplitMix64::new(a ^ stream.wrapping_mul(0xA24B_AED4_963E_E407));
        Self::new(sm2.next_u64())
    }

    /// Domain-separated seed derivation: mix (root seed, domain, index)
    /// through chained SplitMix64 expansions and return the derived seed.
    ///
    /// This is the root-level companion of [`Rng::derive`]: where `derive`
    /// separates *streams under one seed*, `derive_domain` separates the
    /// *seed families* hanging off the coordinator root seed (round seeds,
    /// session seeds, sampling-cohort draws — see [`seed_domain`]). Unlike
    /// the XOR folding it replaced, no (domain, index) pair maps to the
    /// raw root seed (`root ^ 0·C == root` gave round 0 the root itself)
    /// and distinct domains cannot alias by index arithmetic, because each
    /// component passes through a full SplitMix64 avalanche before the
    /// next is folded in.
    pub fn derive_domain(root_seed: u64, domain: u64, index: u64) -> u64 {
        let mut sm = SplitMix64::new(root_seed);
        let expanded = sm.next_u64();
        let mut sm = SplitMix64::new(expanded ^ domain.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let tagged = sm.next_u64();
        let mut sm = SplitMix64::new(tagged ^ index.wrapping_mul(0xA24B_AED4_963E_E407));
        sm.next_u64()
    }

    /// The *seekable* stream of coordinate `coord` under a family seed: a
    /// fresh generator whose draws depend only on (family_seed, coord),
    /// never on how many coordinates were processed before it. This is the
    /// primitive of the chunked pipeline's seed format — an encoder
    /// processing coordinates [lo, hi) derives exactly the streams the
    /// whole-vector encoder derives for those coordinates, so chunk
    /// boundaries cannot change any drawn bit (see docs/determinism.md).
    /// Also safe for samplers that consume a variable number of raw draws
    /// per value (rejection sampling, layered recursion): each coordinate
    /// owns a whole stream, so there is no position to lose.
    ///
    /// Scale caveat (shared by every 64-bit derivation in this module,
    /// `derive` and `pair_seed` included): stream identities live in a
    /// 64-bit space, so across ALL families of a run the birthday bound
    /// applies — with F families of d coordinates, expect ~(F·d)²/2⁶⁵
    /// cross-family stream coincidences. Irrelevant below ~10¹² total
    /// streams (≈ millions of clients × million-coordinate models starts
    /// to approach it); deployments beyond that scale should move the
    /// seed format to a wider (e.g. 128-bit keyed) derivation before
    /// leaning on cross-stream independence. Recorded here rather than
    /// asserted: per-coordinate marginals are unaffected, only joint
    /// independence across colliding streams would quietly degrade.
    pub fn derive_coord(family_seed: u64, coord: u64) -> Self {
        let mut sm = SplitMix64::new(family_seed ^ coord.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        Self::new(sm.next_u64())
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn u01(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [a, b).
    #[inline]
    pub fn uniform(&mut self, a: f64, b: f64) -> f64 {
        a + (b - a) * self.u01()
    }

    /// The dither distribution of Example 1: U(-1/2, 1/2).
    #[inline]
    pub fn dither(&mut self) -> f64 {
        self.u01() - 0.5
    }

    /// Standard Gaussian (Marsaglia polar method, spare cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.u01() - 1.0;
            let v = 2.0 * self.u01() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Gaussian with the given mean / standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Exponential with rate 1.
    #[inline]
    pub fn exponential(&mut self) -> f64 {
        // 1 - u01() is in (0, 1]: never takes ln(0)
        -(1.0 - self.u01()).ln()
    }

    /// Laplace(0, b): difference of exponentials.
    #[inline]
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.u01() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.u01() < p
    }

    /// Geometric on {0, 1, ...} with success probability p.
    pub fn geometric(&mut self, p: f64) -> u64 {
        if p >= 1.0 {
            return 0;
        }
        let u = 1.0 - self.u01(); // in (0, 1]
        (u.ln() / (1.0 - p).ln()).floor() as u64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method.
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fill a vector with standard Gaussians.
    pub fn normal_vec(&mut self, d: usize) -> Vec<f64> {
        (0..d).map(|_| self.normal()).collect()
    }

    /// Fill a vector with U(-1/2, 1/2) dithers.
    pub fn dither_vec(&mut self, d: usize) -> Vec<f64> {
        (0..d).map(|_| self.dither()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_domain_separates_families_and_never_returns_the_root() {
        let root = 42u64;
        // deterministic
        assert_eq!(
            Rng::derive_domain(root, seed_domain::ROUND, 0),
            Rng::derive_domain(root, seed_domain::ROUND, 0)
        );
        // index 0 must NOT hand back the raw root (the old XOR-fold bug)
        for &dom in &[seed_domain::ROUND, seed_domain::SESSION, seed_domain::COHORT] {
            assert_ne!(Rng::derive_domain(root, dom, 0), root, "domain {dom:#x}");
        }
        // pairwise distinct across domains × indices for a sweep of roots
        for root in [0u64, 1, 42, u64::MAX] {
            let mut seen = Vec::new();
            for &dom in &[seed_domain::ROUND, seed_domain::SESSION, seed_domain::COHORT] {
                for idx in 0..64u64 {
                    seen.push(Rng::derive_domain(root, dom, idx));
                }
            }
            let len = seen.len();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), len, "derived-seed collision under root {root}");
        }
    }

    #[test]
    fn derive_coord_is_position_free_and_coord_distinct() {
        // the chunked-pipeline primitive: coordinate j's stream depends
        // only on (family, j) — deterministic, distinct across coords and
        // families, and trivially identical no matter what was drawn for
        // other coordinates first
        let fam = Rng::derive_domain(42, seed_domain::COORD_FAMILY, 3);
        let mut a = Rng::derive_coord(fam, 7);
        let mut b = Rng::derive_coord(fam, 7);
        let x = a.next_u64();
        assert_eq!(x, b.next_u64());
        assert_ne!(x, Rng::derive_coord(fam, 8).next_u64());
        let fam2 = Rng::derive_domain(42, seed_domain::COORD_FAMILY, 4);
        assert_ne!(x, Rng::derive_coord(fam2, 7).next_u64());
        // a sweep of coords under one family yields no collisions
        let mut seen: Vec<u64> = (0..512u64)
            .map(|j| Rng::derive_coord(fam, j).next_u64())
            .collect();
        let len = seen.len();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), len);
    }

    #[test]
    fn derive_differs_per_stream() {
        let mut a = Rng::derive(7, 0);
        let mut b = Rng::derive(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn u01_in_range_and_uniform() {
        let mut r = Rng::new(1);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let u = r.u01();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            sum2 += u * u;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 400_000;
        let (mut s1, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
            s4 += z * z * z * z;
        }
        let nf = n as f64;
        assert!((s1 / nf).abs() < 0.01);
        assert!((s2 / nf - 1.0).abs() < 0.02);
        assert!((s4 / nf - 3.0).abs() < 0.1); // kurtosis
    }

    #[test]
    fn laplace_variance() {
        let mut r = Rng::new(3);
        let b = 0.7;
        let n = 300_000;
        let mut s2 = 0.0;
        for _ in 0..n {
            let z = r.laplace(b);
            s2 += z * z;
        }
        // Var of Laplace(0, b) = 2 b^2
        assert!((s2 / n as f64 - 2.0 * b * b).abs() < 0.02);
    }

    #[test]
    fn geometric_mean() {
        let mut r = Rng::new(4);
        let p = 0.25;
        let n = 200_000;
        let mut s = 0u64;
        for _ in 0..n {
            s += r.geometric(p);
        }
        let mean = s as f64 / n as f64;
        assert!((mean - (1.0 - p) / p).abs() < 0.05, "{mean}");
    }

    #[test]
    fn below_is_unbiased() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..140_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 20_000.0).abs() < 1_000.0, "{counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let idx = r.sample_indices(100, 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }
}
