//! Classical unbiased b-bit quantization (App. C intro): normalize by
//! ‖x‖∞, subtractively dither on a 2^b-level uniform grid over [−1, 1],
//! rescale. Error is uniform per coordinate with variance
//! (w²/12)·‖x‖∞², w = 2/(2^b − 1) — *bounded-variance* compression, the
//! standard assumption the paper generalizes away from.

use super::{CompressedVec, VectorCompressor};
use crate::quantizer::round_half_up;
use crate::util::rng::Rng;
use crate::util::stats::linf_norm;

#[derive(Clone, Copy, Debug)]
pub struct UnbiasedQuantizer {
    pub bits: u32,
}

impl UnbiasedQuantizer {
    pub fn new(bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 32);
        Self { bits }
    }

    /// grid step on the normalized [−1, 1] range
    pub fn step(&self) -> f64 {
        2.0 / ((1u64 << self.bits) - 1) as f64
    }
}

impl VectorCompressor for UnbiasedQuantizer {
    fn name(&self) -> String {
        format!("unbiased-quant(b={})", self.bits)
    }

    fn compress(&self, x: &[f64], rng: &mut Rng) -> CompressedVec {
        let scale = linf_norm(x);
        if scale == 0.0 {
            return CompressedVec { y: vec![0.0; x.len()], err_variance: 0.0, bits: 64.0 };
        }
        let w = self.step();
        let mut y = Vec::with_capacity(x.len());
        for &v in x {
            let u = rng.u01();
            let m = round_half_up(v / (scale * w) + u);
            y.push((m as f64 - u) * w * scale);
        }
        CompressedVec {
            y,
            err_variance: w * w / 12.0 * scale * scale,
            // b bits per coordinate + 32 bits for the shared norm
            bits: self.bits as f64 * x.len() as f64 + 32.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, variance};

    #[test]
    fn unbiased_and_variance_matches() {
        let q = UnbiasedQuantizer::new(4);
        let mut rng = Rng::new(111);
        let x: Vec<f64> = (0..64).map(|i| ((i * 37) % 100) as f64 / 25.0 - 2.0).collect();
        let mut errs = Vec::new();
        let mut var_claim = 0.0;
        for _ in 0..2000 {
            let c = q.compress(&x, &mut rng);
            var_claim = c.err_variance;
            for (yi, xi) in c.y.iter().zip(&x) {
                errs.push(yi - xi);
            }
        }
        assert!(mean(&errs).abs() < 5e-3, "bias {}", mean(&errs));
        assert!((variance(&errs) - var_claim).abs() / var_claim < 0.05);
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(112);
        let x: Vec<f64> = (0..32).map(|i| (i as f64).sin()).collect();
        let e4 = UnbiasedQuantizer::new(4).compress(&x, &mut rng).err_variance;
        let e8 = UnbiasedQuantizer::new(8).compress(&x, &mut rng).err_variance;
        assert!(e8 < e4 / 100.0);
    }

    #[test]
    fn zero_vector_exact() {
        let q = UnbiasedQuantizer::new(3);
        let mut rng = Rng::new(113);
        let c = q.compress(&[0.0; 5], &mut rng);
        assert_eq!(c.y, vec![0.0; 5]);
        assert_eq!(c.err_variance, 0.0);
    }
}
