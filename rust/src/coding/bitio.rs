//! Bit-level I/O: MSB-first bit writer/reader over a byte buffer.

/// MSB-first bit writer.
#[derive(Clone, Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// number of valid bits in the last byte (0..8); 0 means byte-aligned
    nbits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push_bit(&mut self, bit: bool) {
        if self.nbits % 8 == 0 {
            self.buf.push(0);
        }
        if bit {
            let byte = self.buf.last_mut().unwrap();
            *byte |= 1 << (7 - (self.nbits % 8));
        }
        self.nbits = (self.nbits % 8) + 1;
        if self.nbits == 8 {
            self.nbits = 0;
        }
    }

    /// Write the low `width` bits of `v`, MSB first (byte-chunked: ~8x
    /// faster than bit-at-a-time for the Elias/Huffman encode hot paths).
    ///
    /// Fails loudly — panic, not truncation — on `width > 64` or a value
    /// that does not fit in `width` bits: a silently dropped high bit
    /// would decode as a plausible-but-wrong symbol downstream.
    pub fn push_bits(&mut self, v: u64, width: usize) {
        assert!(width <= 64, "push_bits width {width} > 64");
        assert!(
            width == 64 || v >> width == 0,
            "push_bits value {v:#x} does not fit in {width} bits — refusing to truncate"
        );
        let mut remaining = width;
        while remaining > 0 {
            let free = 8 - (self.nbits % 8);
            if self.nbits % 8 == 0 {
                self.buf.push(0);
            }
            let take = free.min(remaining); // 1..=8
            let chunk = ((v >> (remaining - take)) & ((1u64 << take) - 1)) as u8;
            let byte = self.buf.last_mut().unwrap();
            *byte |= chunk << (free - take);
            remaining -= take;
            self.nbits = (self.nbits % 8 + take) % 8;
        }
    }

    /// Total number of bits written.
    pub fn bit_len(&self) -> usize {
        if self.buf.is_empty() {
            0
        } else if self.nbits == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.nbits
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// MSB-first bit reader.
#[derive(Clone, Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        let byte = self.pos / 8;
        if byte >= self.buf.len() {
            return None;
        }
        let bit = (self.buf[byte] >> (7 - self.pos % 8)) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Read `width` bits MSB-first; `None` once the buffer is exhausted.
    /// Fails loudly on `width > 64` — the result could not hold the bits.
    pub fn read_bits(&mut self, width: usize) -> Option<u64> {
        assert!(width <= 64, "read_bits width {width} > 64");
        let mut v = 0u64;
        for _ in 0..width {
            v = (v << 1) | self.read_bit()? as u64;
        }
        Some(v)
    }

    pub fn bits_consumed(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bits() {
        let mut w = BitWriter::new();
        w.push_bits(0b1011, 4);
        w.push_bits(0xFF, 8);
        w.push_bits(0, 3);
        w.push_bit(true);
        assert_eq!(w.bit_len(), 16);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(4), Some(0b1011));
        assert_eq!(r.read_bits(8), Some(0xFF));
        assert_eq!(r.read_bits(3), Some(0));
        assert_eq!(r.read_bit(), Some(true));
    }

    #[test]
    fn bit_len_counts_partial_bytes() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.push_bit(false);
        assert_eq!(w.bit_len(), 1);
        for _ in 0..8 {
            w.push_bit(true);
        }
        assert_eq!(w.bit_len(), 9);
    }

    #[test]
    fn reader_exhausts() {
        let mut w = BitWriter::new();
        w.push_bits(0b101, 3);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        // the buffer is padded to a byte: 8 readable bits
        assert!(r.read_bits(8).is_some());
        assert!(r.read_bit().is_none());
    }

    #[test]
    fn wide_values() {
        let mut w = BitWriter::new();
        let v = 0xDEAD_BEEF_1234_5678u64;
        w.push_bits(v, 64);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(64), Some(v));
    }

    #[test]
    fn width_edges_roundtrip_with_cross_word_straddles() {
        // every edge width, preceded by a 3-bit phase shim so each value
        // straddles byte (and word) boundaries rather than landing aligned
        for width in [1usize, 7, 32, 63, 64] {
            let max = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            for v in [0u64, 1, max / 2, max.saturating_sub(1), max] {
                let mut w = BitWriter::new();
                w.push_bits(0b101, 3);
                w.push_bits(v, width);
                w.push_bits(0b11, 2);
                assert_eq!(w.bit_len(), 3 + width + 2, "width={width}");
                let bytes = w.into_bytes();
                let mut r = BitReader::new(&bytes);
                assert_eq!(r.read_bits(3), Some(0b101));
                assert_eq!(r.read_bits(width), Some(v), "width={width} v={v:#x}");
                assert_eq!(r.read_bits(2), Some(0b11));
            }
        }
    }

    #[test]
    fn seeded_random_stream_roundtrips_exactly() {
        // full round-trip fuzz over seeded (value, width) streams: widths
        // and values from the repo's deterministic RNG, so a failure is a
        // one-seed repro
        use crate::util::rng::Rng;
        for seed in [0xB17u64, 0xB18, 0xB19] {
            let mut rng = Rng::new(seed);
            let stream: Vec<(u64, usize)> = (0..500)
                .map(|_| {
                    let width = rng.below(64) as usize + 1;
                    // a uniform `width`-bit value: the draw's top bits
                    (rng.next_u64() >> (64 - width), width)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, width) in &stream {
                w.push_bits(v, width);
            }
            let total: usize = stream.iter().map(|&(_, width)| width).sum();
            assert_eq!(w.bit_len(), total, "seed={seed:#x}");
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for (i, &(v, width)) in stream.iter().enumerate() {
                assert_eq!(r.read_bits(width), Some(v), "seed={seed:#x} i={i}");
            }
            assert_eq!(r.bits_consumed(), total);
        }
    }

    #[test]
    #[should_panic(expected = "width 65 > 64")]
    fn push_bits_rejects_width_over_64() {
        BitWriter::new().push_bits(0, 65);
    }

    #[test]
    #[should_panic(expected = "refusing to truncate")]
    fn push_bits_rejects_oversized_value() {
        BitWriter::new().push_bits(0b1000, 3);
    }

    #[test]
    #[should_panic(expected = "width 65 > 64")]
    fn read_bits_rejects_width_over_64() {
        let _ = BitReader::new(&[0, 0]).read_bits(65);
    }
}
