//! Mini property-based-testing harness (proptest is not in the offline
//! registry). Provides seeded generators and a `forall` runner with
//! counterexample shrinking for the coordinator/mechanism invariants
//! exercised in `rust/tests/property_invariants.rs`.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: u32,
    pub seed: u64,
    pub max_shrink_steps: u32,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 64, seed: 0xC0FFEE, max_shrink_steps: 200 }
    }
}

/// A generated value together with candidate shrinks.
pub trait Shrinkable: Clone + std::fmt::Debug {
    /// Propose strictly "smaller" candidates (may be empty).
    fn shrink(&self) -> Vec<Self>;
}

impl Shrinkable for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            if self.abs() > 1.0 {
                out.push(self.signum());
            }
        }
        out
    }
}

impl Shrinkable for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrinkable for i64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
        }
        out
    }
}

impl<T: Shrinkable> Shrinkable for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // drop halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // shrink one element
        for (i, v) in self.iter().enumerate().take(4) {
            for s in v.shrink() {
                let mut c = self.clone();
                c[i] = s;
                out.push(c);
            }
        }
        out
    }
}

impl<A: Shrinkable, B: Shrinkable> Shrinkable for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run `prop` on `cfg.cases` generated inputs; on failure, greedily shrink
/// and panic with the minimal counterexample.
pub fn forall<T, G, P>(name: &str, cfg: PropConfig, generator: G, mut prop: P)
where
    T: Shrinkable,
    G: Fn(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = generator(&mut rng);
        if prop(&input) {
            continue;
        }
        // shrink
        let mut minimal = input.clone();
        let mut steps = 0;
        'outer: while steps < cfg.max_shrink_steps {
            for cand in minimal.shrink() {
                steps += 1;
                if !prop(&cand) {
                    minimal = cand;
                    continue 'outer;
                }
                if steps >= cfg.max_shrink_steps {
                    break;
                }
            }
            break;
        }
        panic!(
            "property `{name}` failed (case {case}, seed {:#x}).\n  original: {input:?}\n  minimal:  {minimal:?}",
            cfg.seed
        );
    }
}

// ---------------------------------------------------------------------------
// generators
// ---------------------------------------------------------------------------

pub fn gen_f64(lo: f64, hi: f64) -> impl Fn(&mut Rng) -> f64 {
    move |rng| rng.uniform(lo, hi)
}

pub fn gen_usize(lo: usize, hi: usize) -> impl Fn(&mut Rng) -> usize {
    move |rng| lo + rng.below((hi - lo + 1) as u64) as usize
}

pub fn gen_vec(len_lo: usize, len_hi: usize, lo: f64, hi: f64) -> impl Fn(&mut Rng) -> Vec<f64> {
    move |rng| {
        let len = len_lo + rng.below((len_hi - len_lo + 1) as u64) as usize;
        (0..len).map(|_| rng.uniform(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("abs-nonneg", PropConfig::default(), gen_f64(-10.0, 10.0), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_panics() {
        forall("always-false", PropConfig { cases: 3, ..Default::default() },
               gen_f64(0.0, 1.0), |_| false);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // property: all elements < 5 ⇒ fails on vectors with big elements;
        // minimal counterexample should be short
        let result = std::panic::catch_unwind(|| {
            forall(
                "small-elems",
                PropConfig { cases: 100, seed: 7, max_shrink_steps: 500 },
                gen_vec(0, 20, 0.0, 10.0),
                |v| v.iter().all(|&x| x < 5.0),
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(_) => panic!("property should have failed"),
        };
        // the minimal example is printed; we at least check shrinking ran
        assert!(msg.contains("minimal:"), "{msg}");
    }

    #[test]
    fn tuple_shrinks_both_sides() {
        let t = (4.0f64, 8usize);
        let shrinks = t.shrink();
        assert!(shrinks.iter().any(|(a, _)| *a == 0.0));
        assert!(shrinks.iter().any(|(_, b)| *b == 4));
    }
}
