#!/usr/bin/env bash
# CI entry point: determinism lint + tier-1 verify + rustdoc gate.
#
# Usage: scripts/ci.sh [--lint-only]
#
# The determinism lint enforces the seeded-PRNG ADR — docs/determinism.md
# has the full context and consequences. In short: ALL randomness must
# flow through util::rng::Rng (xoshiro256++ derived from explicit seeds).
# Platform entropy (rand::thread_rng, SystemTime-seeded generators) would
# silently break the shared-randomness contract between clients and server
# — and the session mask schedules derived from it — so its mere mention
# in rust/src fails the build.

set -euo pipefail
cd "$(dirname "$0")/.."

lint() {
    echo "== determinism lint (rust/src) =="
    # thread_rng / SystemTime / any rand-crate path are forbidden in the
    # library; Instant is allowed (wall-clock metrics, never randomness).
    local pattern='thread_rng|SystemTime|rand::'
    local hits
    hits=$(grep -rnE "$pattern" rust/src --include='*.rs' || true)
    if [ -n "$hits" ]; then
        echo "FORBIDDEN nondeterministic randomness reference(s) found:" >&2
        echo "$hits" >&2
        exit 1
    fi
    echo "ok: no thread_rng / SystemTime / rand:: references"

    echo "== seed-derivation lint (rust/src/apps, rust/src/figures) =="
    # The app/figure layers must derive every seed through
    # Rng::derive_domain (docs/determinism.md "Streamed client compute"):
    # ad-hoc mixing — wrapping arithmetic on seeds, golden-ratio constants,
    # prime-multiply round mixing like `seed ^ (r * 7919)` — collides
    # across domains and silently breaks the apps-on-coordinator ≡
    # apps-on-aggregate() bit-identity contract. The RNG core (util/rng.rs)
    # and test scaffolding own the primitive mixers; apps and figures may
    # not re-invent them.
    local seed_pattern='wrapping_(add|mul|sub)\(|0x9E37|\* 7919|\^ \(0x[0-9A-Fa-f]+ \+|\^ \([a-z_]+ \* [0-9]'
    hits=$(grep -rnE "$seed_pattern" rust/src/apps rust/src/figures --include='*.rs' || true)
    if [ -n "$hits" ]; then
        echo "FORBIDDEN ad-hoc seed mixing in app/figure layer (use Rng::derive_domain):" >&2
        echo "$hits" >&2
        exit 1
    fi
    echo "ok: apps/figures derive seeds via Rng::derive_domain only"
}

lint

if [ "${1:-}" = "--lint-only" ]; then
    exit 0
fi

echo "== tier-1 verify =="
cargo build --release
# the examples are documentation that compiles — keep all five building
cargo build --examples
cargo test -q

# Dropout property suite, run by name for visibility: the fixed seed
# matrix (3 seeds × {0, 1, ⌈n/4⌉} dropouts/round) plus every dropout
# recovery/adversarial/KS test across the lib, property and integration
# targets. Redundant with the full `cargo test -q` above by construction —
# a failure here names the dropout contract directly.
echo "== dropout property suite (seed matrix: 3 seeds x {0,1,ceil(n/4)} dropouts) =="
cargo test -q dropout

# Client-sampling suite, run by name for the same visibility: the fixed
# seed matrix (3 seeds × γ ∈ {0.25, 0.5, 1.0} Poisson cohorts) lives in
# `sampling_seed_matrix_windows_close_exactly`, plus every cohort/ledger/
# KS-at-cohort-scale test across the lib, property and integration
# targets. Redundant with the full `cargo test -q` above by construction —
# a failure here names the sampling contract directly.
echo "== client-sampling property suite (seed matrix: 3 seeds x gamma in {0.25,0.5,1.0}) =="
cargo test -q sampling

# Chunked-streaming suite, run by name for the same visibility: the fixed
# seed matrix (3 seeds × chunk ∈ {1, 64, d}) lives in
# `chunked_seed_matrix_windows_close_exactly`, plus every chunked ≡
# unchunked bit-identity cell (mechanisms × {Plain, SecAgg} × dropouts ×
# sampled cohorts × chunk {1, 7, d, d+3}), the chunked KS-exactness tests,
# and the session/coordinator streaming memory-model tests across the lib,
# property and integration targets. Redundant with the full
# `cargo test -q` above by construction — a failure here names the chunked
# contract directly.
echo "== chunked-streaming property suite (seed matrix: 3 seeds x chunk in {1,64,d}) =="
cargo test -q chunked

# Lane-batched kernel suite, run by name for the same visibility: every
# batched ≡ scalar bit-identity cell (mask expansion, mask recovery,
# u01/dither fills, quantizer encodes × lane widths × chunk geometries
# {1, 7, 64, d, d+3}), the blocked/threaded FWHT schedule identities, and
# the end-to-end Plain ≡ SecAgg and chunked ≡ unchunked re-proofs THROUGH
# the batched kernels. Redundant with the full `cargo test -q` above by
# construction — a failure here names the kernel-batching contract
# directly.
echo "== lane-batched kernel property suite (batched == scalar bit-identity) =="
cargo test -q kernels

# Async-coordinator identity suite, run by name for the same visibility:
# the event-driven work-stealing runner ≡ the chunk-barrier runner ≡ the
# whole-d batched runner, bit for bit, across mechanisms × {Plain,
# SecAgg} × chunk ∈ {1, 64, d} × sampling × dropouts; invariance under
# worker count and ring depth; the deadline identities (∞ ≡ barrier
# exactly; straggler-past-deadline ≡ pre-announced dropout); and the
# fail-closed panic-propagation surface. Every scheduler run inside the
# suite is armed with a wall-clock Watchdog (testing::Watchdog), so a
# scheduler deadlock ABORTS loudly within its limit instead of idling CI
# until the harness' global timeout. Redundant with the full
# `cargo test -q` above by construction — a failure here names the async
# contract directly.
echo "== async-coordinator identity suite (async == barrier, watchdog-armed) =="
cargo test -q async

# Scenario-engine suite, run by name for the same visibility: the seeded
# scenario matrix (3 seeds × {calm, churn, straggler, byzantine} presets)
# lives in the engine's own tests plus `property_scenarios` — generated
# byzantine campaigns (every probe closes exactly or panics fail-closed,
# no third outcome), the straggler preset isolating exactly the
# deadline-conversion path the async coordinator mirrors, KS exactness of
# the decoded error law under hostile fleets, and the scheduled-cohort ≡
# policy-sampled coordinator identity. Redundant with the full
# `cargo test -q` above by construction — a failure here names the
# scenario contract directly.
echo "== scenario-engine suite (3 seeds x {calm, churn, straggler, byzantine}) =="
cargo test -q scenario

# Apps-on-the-coordinator suite, run by name for the same visibility:
# every workload of the paper (mean estimation, QLSD* Langevin, DRS
# smoothing) through the chunk-streamed AND async coordinator ≡ its
# monolithic aggregate() reference, bit for bit, at full cohort across
# mechanisms × chunk ∈ {0, 1, 7, d, d+3}; the KS exactness of the
# aggregate-Gaussian error law, the QLSD* discounted-noise composition
# and the smoothing perturbation on the sampled + chunked path; and the
# streamed-compute memory-model test (a whole-d client materialization
# panics). Redundant with the full `cargo test -q` above by construction —
# a failure here names the apps-on-coordinator contract directly.
echo "== apps-on-coordinator suite (apps == aggregate() bit-identity + KS laws) =="
cargo test -q apps_

# Packed wire-format suite, run by name for the same visibility: the
# packed ≡ unpacked bit identity (roundtrip across moduli — powers of two
# and not — × chunk geometries {1, 7, 64, d, d+3}), the packed fold/merge
# ≡ scalar mod-arithmetic checks, the chunked ≡ unchunked and Plain ≡
# SecAgg re-proofs THROUGH packed accumulators under dropouts and sampled
# cohorts, KS exactness of the error laws on packed SecAgg, and the
# wire-bytes ≡ BitsAccount cross-check. Redundant with the full
# `cargo test -q` above by construction — a failure here names the packed
# wire-format contract directly.
echo "== packed wire-format suite (packed == unpacked bit-identity + wire bytes) =="
cargo test -q packed

# Snapshot/resume suite: byte round-trip losslessness of the versioned
# snapshot format, fail-closed corruption handling, and checkpoint+resume
# bit-identity at EVERY tick across mechanisms × {Plain, SecAgg} × chunk
# ∈ {1, 64, d} — including chunked SecAgg mid-window captures with live
# accumulators, announcements and ledger state.
echo "== snapshot/resume bit-identity suite (mechanisms x transports x chunk) =="
cargo test -q snapshot

# Bench smoke: every bench binary must still run end to end. BENCH_QUICK=1
# shrinks warmup/measure so the three binaries finish in seconds;
# bench_coordinator's smoke includes the coordinator/rounds_async series
# (scaled down from the million-client headline) WITH its O(ring·W·c)
# peak-accumulator assertion, so a scheduler or memory-model break fails
# the smoke, not just the nightly full run. The same binary smokes the
# apps/model_scale_demo series (d = 2^16, n = 1000 sampled in quick mode;
# d = 2^20, n = 10^4 in the full run) with its own assertions that no
# whole-d client vector is ever materialized and the accumulator
# high-water mark stays O(shards·chunk) — now the PACKED ⌈c·w/64⌉·8
# per-slot bound, with the kernels/pack_unpack_* pair and the packed
# rounds_chunked/rounds_async_secagg variants asserting the packed budget
# and the measured wire-bytes counters. bench_coordinator writes its
# artifact to target/BENCH_quick.json in this mode (never the committed
# BENCH_N.json trajectory — quick numbers are not trajectory points).
# bench_diff.sh then schema-checks the artifact; quick artifacts skip the
# regression comparison, and as baselines they are walked PAST to the most
# recent comparable trajectory point.
echo "== bench smoke (BENCH_QUICK=1) =="
BENCH_QUICK=1 cargo bench --bench bench_mechanisms
BENCH_QUICK=1 cargo bench --bench bench_coordinator
BENCH_QUICK=1 cargo bench --bench bench_figures
scripts/bench_diff.sh target/BENCH_quick.json

echo "== clippy (deny warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "cargo-clippy not installed in this toolchain; skipping (install the clippy" \
         "component to enforce the gate locally)"
fi

echo "== rustdoc (deny warnings) =="
# keeps the crate/module docs — including intra-doc links — green
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
echo "CI OK"
