//! The PJRT execution engine: one compiled executable per artifact,
//! compiled once at startup, executed many times on the request path.
//!
//! The real engine binds the `xla` crate (xla_extension PJRT bindings),
//! which the offline registry cannot provide — it is therefore gated behind
//! the `pjrt` cargo feature. Without the feature, [`Engine`] is a stub with
//! the same API whose `load` returns an error, so every consumer
//! (`apps::fl_train`, `repro train`, the runtime integration tests, which
//! all skip or report when the engine is unavailable) still compiles and
//! the rest of the library is fully functional.

use super::artifacts::Manifest;

/// Names of the artifacts the FL training app needs.
pub const ARTIFACTS: &[&str] = &["model_grad", "model_eval", "encode", "decode_mean"];

#[cfg(feature = "pjrt")]
mod imp {
    use super::{Manifest, ARTIFACTS};
    use anyhow::{Context, Result};
    use std::collections::HashMap;

    pub struct Engine {
        pub manifest: Manifest,
        client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Engine {
        /// Load + compile every artifact under `dir` on the PJRT CPU client.
        pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
            let manifest = Manifest::load(&dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let mut exes = HashMap::new();
            for &name in ARTIFACTS {
                let path = manifest.hlo_path(name);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {name}"))?;
                exes.insert(name.to_string(), exe);
            }
            Ok(Self { manifest, client, exes })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Execute an artifact with the given input literals; returns the
        /// elements of the (always-tupled) result.
        pub fn exec(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let exe = self.exes.get(name).with_context(|| format!("unknown artifact {name}"))?;
            let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True
            Ok(result.to_tuple()?)
        }

        // ---- typed convenience wrappers ---------------------------------

        /// (loss, flat gradient) for one client batch.
        pub fn model_grad(
            &self,
            params: &[f32],
            xb: &[f32],
            yb: &[i32],
        ) -> Result<(f32, Vec<f32>)> {
            let m = &self.manifest;
            assert_eq!(params.len(), m.param_count);
            assert_eq!(xb.len(), m.batch * m.d_in);
            assert_eq!(yb.len(), m.batch);
            let p = xla::Literal::vec1(params);
            let x = xla::Literal::vec1(xb).reshape(&[m.batch as i64, m.d_in as i64])?;
            let y = xla::Literal::vec1(yb);
            let out = self.exec("model_grad", &[p, x, y])?;
            let loss = out[0].get_first_element::<f32>()?;
            let grad = out[1].to_vec::<f32>()?;
            Ok((loss, grad))
        }

        /// (loss, accuracy) on one batch.
        pub fn model_eval(&self, params: &[f32], xb: &[f32], yb: &[i32]) -> Result<(f32, f32)> {
            let m = &self.manifest;
            let p = xla::Literal::vec1(params);
            let x = xla::Literal::vec1(xb).reshape(&[m.batch as i64, m.d_in as i64])?;
            let y = xla::Literal::vec1(yb);
            let out = self.exec("model_eval", &[p, x, y])?;
            Ok((out[0].get_first_element::<f32>()?, out[1].get_first_element::<f32>()?))
        }

        /// Batched dither encode (the L1 Pallas kernel): m = round(x*inv + s).
        pub fn encode(&self, x: &[f32], s: &[f32], inv_scale: f32) -> Result<Vec<f32>> {
            let m = &self.manifest;
            let total = m.enc_clients * m.enc_dim;
            assert_eq!(x.len(), total);
            assert_eq!(s.len(), total);
            let xl = xla::Literal::vec1(x).reshape(&[m.enc_clients as i64, m.enc_dim as i64])?;
            let sl = xla::Literal::vec1(s).reshape(&[m.enc_clients as i64, m.enc_dim as i64])?;
            let inv = xla::Literal::scalar(inv_scale);
            let out = self.exec("encode", &[xl, sl, inv])?;
            Ok(out[0].to_vec::<f32>()?)
        }

        /// Homomorphic decode kernel: y = scale/n (m_sum − s_sum) + shift.
        pub fn decode_mean(
            &self,
            m_sum: &[f32],
            s_sum: &[f32],
            scale: f32,
            shift: f32,
            n_clients: f32,
        ) -> Result<Vec<f32>> {
            let m = &self.manifest;
            assert_eq!(m_sum.len(), m.enc_dim);
            let ml = xla::Literal::vec1(m_sum);
            let sl = xla::Literal::vec1(s_sum);
            let out = self.exec(
                "decode_mean",
                &[
                    ml,
                    sl,
                    xla::Literal::scalar(scale),
                    xla::Literal::scalar(shift),
                    xla::Literal::scalar(n_clients),
                ],
            )?;
            Ok(out[0].to_vec::<f32>()?)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::Manifest;
    use anyhow::{bail, Result};

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` cargo feature \
         (the offline registry has no `xla` crate). To enable it, add a \
         local `xla = { path = ... }` dependency to Cargo.toml (see the \
         [features] comment there) and rebuild with `--features pjrt`.";

    /// API-compatible stub: `load` always errors, so no instance can exist
    /// without the `pjrt` feature and the method bodies are unreachable.
    pub struct Engine {
        pub manifest: Manifest,
        _priv: (),
    }

    impl Engine {
        pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
            let _ = dir.as_ref();
            bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn model_grad(
            &self,
            _params: &[f32],
            _xb: &[f32],
            _yb: &[i32],
        ) -> Result<(f32, Vec<f32>)> {
            bail!("{UNAVAILABLE}")
        }

        pub fn model_eval(&self, _params: &[f32], _xb: &[f32], _yb: &[i32]) -> Result<(f32, f32)> {
            bail!("{UNAVAILABLE}")
        }

        pub fn encode(&self, _x: &[f32], _s: &[f32], _inv_scale: f32) -> Result<Vec<f32>> {
            bail!("{UNAVAILABLE}")
        }

        pub fn decode_mean(
            &self,
            _m_sum: &[f32],
            _s_sum: &[f32],
            _scale: f32,
            _shift: f32,
            _n_clients: f32,
        ) -> Result<Vec<f32>> {
            bail!("{UNAVAILABLE}")
        }
    }
}

pub use imp::Engine;

/// Convenience: whether this build carries the real PJRT engine.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

// Integration tests live in rust/tests/integration_runtime.rs (they need
// `make artifacts` to have run, and a `--features pjrt` build).

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_or_real_load_fails_cleanly_without_artifacts() {
        // without artifacts/ (and, in default builds, without the pjrt
        // feature) load must return an error, never panic
        let r = Engine::load("definitely/not/a/dir");
        assert!(r.is_err());
    }

    #[test]
    fn artifact_names_stable() {
        assert_eq!(ARTIFACTS.len(), 4);
        assert!(ARTIFACTS.contains(&"encode"));
    }
}
