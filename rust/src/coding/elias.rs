//! Elias gamma coding — the variable-length code used for the Fig. 6 / 9
//! bits-per-client measurements ("using Elias gamma coding, we calculate
//! the number of bits needed for the aggregate Gaussian mechanism ...").
//!
//! Gamma codes are for positive integers; quantizer descriptions are signed
//! integers centred near 0, so we compose with the standard zigzag map
//! 0 → 1, −1 → 2, 1 → 3, −2 → 4, ... (small |m| ⇒ short codes).

use super::bitio::{BitReader, BitWriter};

/// Number of bits of the gamma code of v >= 1: 2*floor(log2 v) + 1.
pub fn gamma_len(v: u64) -> usize {
    assert!(v >= 1);
    2 * (63 - v.leading_zeros() as usize) + 1
}

/// Encode v >= 1.
pub fn gamma_encode(w: &mut BitWriter, v: u64) {
    assert!(v >= 1);
    let nbits = 63 - v.leading_zeros() as usize; // floor(log2 v)
    if nbits > 0 {
        w.push_bits(0, nbits);
    }
    w.push_bits(v, nbits + 1);
}

/// Decode one gamma codeword.
pub fn gamma_decode(r: &mut BitReader) -> Option<u64> {
    let mut zeros = 0usize;
    loop {
        match r.read_bit()? {
            false => zeros += 1,
            true => break,
        }
        if zeros > 64 {
            return None;
        }
    }
    let rest = r.read_bits(zeros)?;
    Some((1u64 << zeros) | rest)
}

/// Zigzag: ℤ → ℤ≥1 with small |m| mapping to small codes.
///
/// Values are clamped to ±2^61: a quantizer description beyond that arises
/// only when the aggregate mechanism draws an astronomically small scale
/// |A| (probability ~2^-60 per coordinate), where the f64→i64 encode has
/// already saturated; clamping keeps the codec total while preserving the
/// bijection on the entire representable range.
const ZZ_CLAMP: i64 = 1 << 61;

#[inline]
pub fn zigzag(m: i64) -> u64 {
    let m = m.clamp(-ZZ_CLAMP, ZZ_CLAMP);
    if m >= 0 {
        2 * m as u64 + 1
    } else {
        2 * (-m as u64)
    }
}

#[inline]
pub fn unzigzag(v: u64) -> i64 {
    if v % 2 == 1 {
        ((v - 1) / 2) as i64
    } else {
        -((v / 2) as i64)
    }
}

/// Bits to gamma-encode a signed description.
pub fn signed_gamma_len(m: i64) -> usize {
    gamma_len(zigzag(m))
}

/// Encode a whole description vector; returns total bits.
pub fn encode_vec(ms: &[i64]) -> (Vec<u8>, usize) {
    let mut w = BitWriter::new();
    for &m in ms {
        gamma_encode(&mut w, zigzag(m));
    }
    let bits = w.bit_len();
    (w.into_bytes(), bits)
}

/// Decode `count` signed descriptions.
pub fn decode_vec(bytes: &[u8], count: usize) -> Option<Vec<i64>> {
    let mut r = BitReader::new(bytes);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(unzigzag(gamma_decode(&mut r)?));
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_bijection() {
        for m in -1000i64..=1000 {
            assert_eq!(unzigzag(zigzag(m)), m);
        }
        assert_eq!(zigzag(0), 1);
        assert_eq!(zigzag(-1), 2);
        assert_eq!(zigzag(1), 3);
    }

    #[test]
    fn gamma_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 3, 7, 8, 100, 12345, u32::MAX as u64];
        for &v in &vals {
            gamma_encode(&mut w, v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(gamma_decode(&mut r), Some(v));
        }
    }

    #[test]
    fn gamma_len_matches_encoding() {
        for v in 1u64..=300 {
            let mut w = BitWriter::new();
            gamma_encode(&mut w, v);
            assert_eq!(w.bit_len(), gamma_len(v), "v={v}");
        }
    }

    #[test]
    fn known_codeword_lengths() {
        assert_eq!(gamma_len(1), 1);
        assert_eq!(gamma_len(2), 3);
        assert_eq!(gamma_len(3), 3);
        assert_eq!(gamma_len(4), 5);
        assert_eq!(gamma_len(8), 7);
    }

    #[test]
    fn vec_roundtrip() {
        let ms: Vec<i64> = (-50..=50).collect();
        let (bytes, bits) = encode_vec(&ms);
        assert!(bits > 0);
        assert_eq!(decode_vec(&bytes, ms.len()), Some(ms));
    }

    #[test]
    fn small_descriptions_are_cheap() {
        // the whole point: near-zero descriptions cost ~1-5 bits
        assert_eq!(signed_gamma_len(0), 1);
        assert!(signed_gamma_len(1) <= 3);
        assert!(signed_gamma_len(-1) <= 3);
        assert!(signed_gamma_len(2) <= 5);
    }
}
