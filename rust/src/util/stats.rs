//! Statistics: online moments, quantiles, histogram MSE helpers, and a
//! Kolmogorov–Smirnov goodness-of-fit test.
//!
//! The KS test is how the test-suite *proves* the AINQ property: mechanisms
//! claim an exact error law (Def. 1), so for every mechanism we draw many
//! aggregation errors and test them against the target cdf.

/// Welford online mean / variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Unbiased sample variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n < 2 {
            f64::INFINITY
        } else {
            (self.sample_variance() / self.n as f64).sqrt()
        }
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Empirical quantile (linear interpolation between order statistics).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Mean squared error between two vectors.
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / a.len() as f64
}

pub fn l2_norm(xs: &[f64]) -> f64 {
    xs.iter().map(|x| x * x).sum::<f64>().sqrt()
}

pub fn linf_norm(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0f64, |m, x| m.max(x.abs()))
}

/// Result of a one-sample Kolmogorov–Smirnov test against a cdf.
#[derive(Clone, Copy, Debug)]
pub struct KsResult {
    /// KS statistic D_n = sup |F_emp - F|
    pub statistic: f64,
    /// asymptotic p-value (Kolmogorov distribution)
    pub p_value: f64,
    pub n: usize,
}

/// One-sample KS test of `samples` against the cdf `f`.
pub fn ks_test(samples: &[f64], f: impl Fn(f64) -> f64) -> KsResult {
    let n = samples.len();
    assert!(n > 0);
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let nf = n as f64;
    let mut d = 0.0f64;
    for (i, &x) in v.iter().enumerate() {
        let cdf = f(x);
        let d_plus = (i as f64 + 1.0) / nf - cdf;
        let d_minus = cdf - i as f64 / nf;
        d = d.max(d_plus).max(d_minus);
    }
    KsResult { statistic: d, p_value: ks_p_value(d, n), n }
}

/// Asymptotic Kolmogorov p-value with the Stephens small-sample correction:
/// Q(λ) = 2 Σ_{k≥1} (-1)^{k-1} exp(-2 k² λ²),
/// λ = (√n + 0.12 + 0.11/√n) · D.
pub fn ks_p_value(d: f64, n: usize) -> f64 {
    let sn = (n as f64).sqrt();
    let lambda = (sn + 0.12 + 0.11 / sn) * d;
    if lambda < 1e-3 {
        return 1.0;
    }
    let mut sum = 0.0;
    let mut sign = 1.0;
    for k in 1..=100 {
        let term = (-2.0 * (k as f64) * (k as f64) * lambda * lambda).exp();
        sum += sign * term;
        sign = -sign;
        if term < 1e-12 {
            break;
        }
    }
    (2.0 * sum).clamp(0.0, 1.0)
}

/// Two-sample KS test (used to compare mechanism errors against a sampled
/// reference when no closed-form cdf exists, e.g. Irwin–Hall).
pub fn ks_test_two_sample(a: &[f64], b: &[f64]) -> KsResult {
    let mut av = a.to_vec();
    let mut bv = b.to_vec();
    av.sort_by(|x, y| x.partial_cmp(y).unwrap());
    bv.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let (na, nb) = (av.len() as f64, bv.len() as f64);
    let (mut i, mut j) = (0usize, 0usize);
    let mut d = 0.0f64;
    while i < av.len() && j < bv.len() {
        let xa = av[i];
        let xb = bv[j];
        if xa <= xb {
            i += 1;
        }
        if xb <= xa {
            j += 1;
        }
        d = d.max((i as f64 / na - j as f64 / nb).abs());
    }
    let ne = na * nb / (na + nb);
    KsResult { statistic: d, p_value: ks_p_value(d, ne.round() as usize), n: a.len() + b.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::special::norm_cdf;

    #[test]
    fn online_stats_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut os = OnlineStats::new();
        os.extend(&xs);
        assert!((os.mean() - mean(&xs)).abs() < 1e-12);
        assert!((os.variance() - variance(&xs)).abs() < 1e-12);
    }

    #[test]
    fn quantile_basics() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ks_accepts_true_distribution() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..5000).map(|_| r.normal()).collect();
        let res = ks_test(&xs, norm_cdf);
        assert!(res.p_value > 0.01, "p={} d={}", res.p_value, res.statistic);
    }

    #[test]
    fn ks_rejects_wrong_distribution() {
        let mut r = Rng::new(12);
        // Laplace samples against Gaussian cdf: must reject strongly
        let xs: Vec<f64> = (0..5000).map(|_| r.laplace(1.0)).collect();
        let res = ks_test(&xs, norm_cdf);
        assert!(res.p_value < 1e-4, "p={}", res.p_value);
    }

    #[test]
    fn ks_rejects_shifted_mean() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..5000).map(|_| r.normal() + 0.2).collect();
        let res = ks_test(&xs, norm_cdf);
        assert!(res.p_value < 1e-4);
    }

    #[test]
    fn two_sample_ks_same_vs_different() {
        let mut r = Rng::new(14);
        let a: Vec<f64> = (0..4000).map(|_| r.normal()).collect();
        let b: Vec<f64> = (0..4000).map(|_| r.normal()).collect();
        let c: Vec<f64> = (0..4000).map(|_| r.normal() * 1.3).collect();
        assert!(ks_test_two_sample(&a, &b).p_value > 0.01);
        assert!(ks_test_two_sample(&a, &c).p_value < 1e-4);
    }

    #[test]
    fn mse_and_norms() {
        let a = vec![1.0, 2.0];
        let b = vec![2.0, 4.0];
        assert!((mse(&a, &b) - 2.5).abs() < 1e-12);
        assert!((l2_norm(&vec![3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(linf_norm(&vec![-7.0, 2.0]), 7.0);
    }
}
