//! PJRT runtime integration: loads the AOT artifacts (`make artifacts`
//! must have run — these tests SKIP with a message if artifacts/ is
//! missing) and validates the Layer-2/Layer-1 numerics from rust, then the
//! end-to-end FL training driver.

use exact_comp::apps::fl_train::{self, MechKind, TrainOpts};
use exact_comp::quantizer::round_half_up;
use exact_comp::runtime::Engine;
use exact_comp::util::rng::Rng;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Engine::load("artifacts").expect("engine"))
}

#[test]
fn engine_loads_and_reports_platform() {
    let Some(e) = engine() else { return };
    assert_eq!(e.platform(), "cpu");
    assert!(e.manifest.param_count > 0);
}

#[test]
fn model_grad_matches_finite_differences() {
    let Some(e) = engine() else { return };
    let m = e.manifest.clone();
    let mut rng = Rng::new(41);
    let params: Vec<f32> = (0..m.param_count).map(|_| rng.normal_ms(0.0, 0.2) as f32).collect();
    let xb: Vec<f32> = (0..m.batch * m.d_in).map(|_| rng.normal() as f32).collect();
    let yb: Vec<i32> = (0..m.batch).map(|_| (rng.bernoulli(0.5)) as i32).collect();

    let (loss, grad) = e.model_grad(&params, &xb, &yb).unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    assert_eq!(grad.len(), m.param_count);

    // central finite differences on a few random coordinates
    let h = 1e-2f32;
    for k in [0usize, m.param_count / 3, m.param_count - 1] {
        let mut pp = params.clone();
        pp[k] += h;
        let (lp, _) = e.model_grad(&pp, &xb, &yb).unwrap();
        pp[k] -= 2.0 * h;
        let (lm, _) = e.model_grad(&pp, &xb, &yb).unwrap();
        let fd = (lp - lm) / (2.0 * h);
        assert!(
            (fd - grad[k]).abs() < 2e-2 + 0.1 * fd.abs().max(grad[k].abs()),
            "coord {k}: fd {fd} vs grad {}",
            grad[k]
        );
    }
}

#[test]
fn encode_kernel_matches_rust_dithering() {
    let Some(e) = engine() else { return };
    let m = e.manifest.clone();
    let total = m.enc_clients * m.enc_dim;
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..total).map(|_| rng.uniform(-50.0, 50.0) as f32).collect();
    let s: Vec<f32> = (0..total).map(|_| rng.dither() as f32).collect();
    let inv_scale = 0.37f32;
    let out = e.encode(&x, &s, inv_scale).unwrap();
    let mut mismatches = 0usize;
    for i in 0..total {
        let want = round_half_up((x[i] * inv_scale + s[i]) as f64) as f32;
        if (out[i] - want).abs() > 0.0 {
            // fma-vs-two-op rounding can flip exact .5 ties; must be ±1
            assert!((out[i] - want).abs() <= 1.0, "i={i} out={} want={want}", out[i]);
            mismatches += 1;
        }
    }
    assert!(
        mismatches < total / 1000,
        "{mismatches}/{total} tie-flips (too many)"
    );
}

#[test]
fn decode_kernel_matches_formula() {
    let Some(e) = engine() else { return };
    let m = e.manifest.clone();
    let mut rng = Rng::new(43);
    let m_sum: Vec<f32> = (0..m.enc_dim).map(|_| rng.uniform(-100.0, 100.0) as f32).collect();
    let s_sum: Vec<f32> = (0..m.enc_dim).map(|_| rng.uniform(-4.0, 4.0) as f32).collect();
    let (scale, shift, n) = (0.55f32, -1.25f32, 9.0f32);
    let y = e.decode_mean(&m_sum, &s_sum, scale, shift, n).unwrap();
    for j in 0..m.enc_dim {
        let want = scale / n * (m_sum[j] - s_sum[j]) + shift;
        assert!((y[j] - want).abs() < 1e-4, "j={j}");
    }
}

#[test]
fn fl_training_e2e_loss_decreases() {
    let Some(e) = engine() else { return };
    let opts = TrainOpts {
        rounds: 60,
        lr: 0.5,
        n_clients: 4,
        clip_c: 0.05,
        mech: MechKind::Aggregate,
        sigma: 5e-4,
        eval_every: 10,
        seed: 0xE2E,
        chunk: 0,
    };
    let data = fl_train::gen_dataset(&e, opts.n_clients, opts.seed);
    let metrics = fl_train::train(&e, &data, opts).unwrap();
    let series = metrics.series("train_loss").unwrap();
    let first = series[0].1;
    let last = series.last().unwrap().1;
    assert!(last < first * 0.8, "loss {first} -> {last}");
    let acc = metrics.last("acc").unwrap();
    assert!(acc > 0.7, "eval acc {acc}");
    assert!(metrics.mean_of("bits_per_client").unwrap() > 0.0);
}

#[test]
fn fl_training_compressed_tracks_uncompressed() {
    let Some(e) = engine() else { return };
    let base = TrainOpts {
        rounds: 50,
        lr: 0.5,
        n_clients: 4,
        clip_c: 0.05,
        mech: MechKind::None,
        sigma: 5e-4,
        eval_every: 25,
        seed: 0xBEE,
        chunk: 0,
    };
    let data = fl_train::gen_dataset(&e, base.n_clients, base.seed);
    let plain = fl_train::train(&e, &data, base).unwrap();
    let compressed = fl_train::train(
        &e,
        &data,
        TrainOpts { mech: MechKind::Aggregate, ..base },
    )
    .unwrap();
    let lp = plain.last("train_loss").unwrap();
    let lc = compressed.last("train_loss").unwrap();
    assert!(lc < lp * 1.5 + 0.1, "compressed {lc} vs plain {lp}");
    // and compression actually saves bits vs float32
    let bits = compressed.mean_of("bits_per_client").unwrap();
    let raw = 32.0 * e.manifest.param_count as f64;
    assert!(bits < raw / 4.0, "bits {bits} vs raw {raw}");
}
