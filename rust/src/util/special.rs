//! Special functions: log-gamma, regularized incomplete gamma, erf / erfc,
//! the standard normal cdf / quantile.
//!
//! Everything here is implemented from first principles (no libm beyond
//! `f64` intrinsics): `erf` via the regularized incomplete gamma (series +
//! Lentz continued fraction, ~1e-14 accurate), `norm_ppf` via Acklam's
//! rational approximation refined with one Halley step — these feed the
//! Gaussian superlevel sets, DP calibration and KS tests, all of which need
//! much better than single precision.

/// Natural log of the gamma function (Lanczos, g = 7, n = 9).
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_59,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = G[0];
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate().skip(1) {
        a += g / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma P(a, x) by series expansion (x < a+1).
fn gamma_p_series(a: f64, x: f64) -> f64 {
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-16 {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma(a)).exp()
}

/// Regularized upper incomplete gamma Q(a, x) by Lentz continued fraction
/// (x >= a+1).
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    const FPMIN: f64 = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / FPMIN;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = b + an / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// Regularized lower incomplete gamma P(a, x).
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0);
    if x <= 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        gamma_p_series(a, x)
    } else {
        1.0 - gamma_q_cf(a, x)
    }
}

/// Error function, |error| ~ 1e-14.
pub fn erf(x: f64) -> f64 {
    if x == 0.0 {
        return 0.0;
    }
    let p = gamma_p(0.5, x * x);
    if x > 0.0 {
        p
    } else {
        -p
    }
}

/// Complementary error function (accurate for large x).
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x == 0.0 {
        return 1.0;
    }
    let x2 = x * x;
    if x2 < 1.5 {
        1.0 - gamma_p_series(0.5, x2)
    } else {
        gamma_q_cf(0.5, x2)
    }
}

/// Standard normal cdf Φ(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Standard normal pdf φ(x).
pub fn norm_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal quantile Φ⁻¹(p): Acklam's rational approximation
/// followed by one Halley refinement step (≈ full double precision).
pub fn norm_ppf(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "p out of range: {p}");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    const A: [f64; 6] = [
        -3.969_683_028_665_376e1,
        2.209_460_984_245_205e2,
        -2.759_285_104_469_687e2,
        1.383_577_518_672_69e2,
        -3.066_479_806_614_716e1,
        2.506_628_277_459_239,
    ];
    const B: [f64; 5] = [
        -5.447_609_879_822_406e1,
        1.615_858_368_580_409e2,
        -1.556_989_798_598_866e2,
        6.680_131_188_771_972e1,
        -1.328_068_155_288_572e1,
    ];
    const C: [f64; 6] = [
        -7.784_894_002_430_293e-3,
        -3.223_964_580_411_365e-1,
        -2.400_758_277_161_838,
        -2.549_732_539_343_734,
        4.374_664_141_464_968,
        2.938_163_982_698_783,
    ];
    const D: [f64; 4] = [
        7.784_695_709_041_462e-3,
        3.224_671_290_700_398e-1,
        2.445_134_137_142_996,
        3.754_408_661_907_416,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };

    // One Halley step: e = Φ(x) - p, u = e / φ(x)
    let e = norm_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// log2 helper used throughout communication accounting.
#[inline]
pub fn log2(x: f64) -> f64 {
    x.log2()
}

/// Binomial coefficient as f64 via ln_gamma (exact enough for n <= 60).
pub fn binomial(n: u64, k: u64) -> f64 {
    if k > n {
        return 0.0;
    }
    (ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0)).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(1/2)=√π
        assert!((ln_gamma(1.0)).abs() < 1e-12);
        assert!((ln_gamma(2.0)).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-12);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-12);
    }

    #[test]
    fn erf_known_values() {
        // Reference values (Wolfram): erf(0.5)=0.5204998778, erf(1)=0.8427007929,
        // erf(2)=0.9953222650
        assert!((erf(0.5) - 0.520_499_877_813_046_5).abs() < 1e-12);
        assert!((erf(1.0) - 0.842_700_792_949_714_9).abs() < 1e-12);
        assert!((erf(2.0) - 0.995_322_265_018_952_7).abs() < 1e-12);
        assert!((erf(-1.0) + erf(1.0)).abs() < 1e-15);
    }

    #[test]
    fn erfc_large_x_no_cancellation() {
        // erfc(5) = 1.5374597944280348e-12
        assert!((erfc(5.0) / 1.537_459_794_428_034_8e-12 - 1.0).abs() < 1e-9);
        // erfc(10) = 2.0884875837625447e-45
        assert!((erfc(10.0) / 2.088_487_583_762_544_7e-45 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn norm_cdf_symmetry_and_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-15);
        assert!((norm_cdf(1.959_963_984_540_054) - 0.975).abs() < 1e-12);
        for &x in &[-3.0, -1.0, 0.3, 2.2] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1e-13);
        }
    }

    #[test]
    fn ppf_inverts_cdf() {
        for i in 1..999 {
            let p = i as f64 / 1000.0;
            let x = norm_ppf(p);
            assert!((norm_cdf(x) - p).abs() < 1e-12, "p={p}");
        }
        // tails
        for &p in &[1e-10, 1e-6, 1.0 - 1e-6] {
            let x = norm_ppf(p);
            assert!((norm_cdf(x) - p).abs() / p.min(1.0 - p) < 1e-8, "p={p}");
        }
    }

    #[test]
    fn gamma_p_complementarity() {
        for &(a, x) in &[(0.5, 0.3), (2.0, 1.0), (5.0, 7.0)] {
            let p = gamma_p(a, x);
            assert!((0.0..=1.0).contains(&p));
        }
        // P(1, x) = 1 - e^{-x}
        assert!((gamma_p(1.0, 2.0) - (1.0 - (-2.0f64).exp())).abs() < 1e-13);
    }

    #[test]
    fn binomial_small() {
        assert_eq!(binomial(5, 2).round() as u64, 10);
        assert_eq!(binomial(20, 10).round() as u64, 184_756);
    }
}
