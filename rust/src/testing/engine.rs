//! The tick-driven fleet scenario engine: deterministic churn, regional
//! outages, heavy-tailed stragglers, non-i.i.d. data drift and generated
//! byzantine campaigns, composed over the real windowed transport
//! machinery ([`crate::mechanisms::session::TransportSession`]).
//!
//! One [`ScenarioEngine::tick`] executes one aggregation round. Every
//! `cfg.window` ticks the engine opens a fresh session window and plans
//! it in full: the five subsystems run in a FIXED order — churn →
//! outages → stragglers → data-drift → byzantine — each drawing only
//! from its own domain-separated RNG slot ([`super::scenario::slot`]),
//! so no subsystem's draw count can perturb another's stream. The plan
//! ([`super::scenario::WindowPlan`]) is then immutable: cohorts become
//! the session's sampled cohorts, outage/straggler dropouts are
//! announced up front on the Bonawitz recovery path (streamed-close
//! style), drifted data feeds the honest encoders, and byzantine probes
//! are replayed against a restored replica of the live session — a probe
//! that does NOT panic the fail-closed surface panics the engine itself
//! ("fails open"), so every campaign ends in an exact close or a
//! fail-closed panic, never a third outcome.
//!
//! Snapshot/resume: [`ScenarioEngine::snapshot`] captures the engine
//! tick, all five RNG slot states (*stream positions*, not reseeds —
//! [`crate::util::rng::RngState`]), the fleet membership and drift
//! state, the event log, the active window plan, the transport-session
//! state and the privacy ledger. [`ScenarioEngine::from_snapshot`]
//! re-enters exactly that state, and the resumed engine's subsequent
//! [`crate::coordinator::RoundReport`]s are bit-identical to an
//! uninterrupted run's — the contract `rust/tests/property_scenarios.rs`
//! enforces across mechanisms × transports × chunk sizes (see
//! docs/determinism.md).

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::coordinator::RoundReport;
use crate::dp::PrivacyLedger;
use crate::mechanisms::pipeline::{
    ChunkPlan, ClientEncoder, Payload, ServerDecoder, SurvivorSet, Transport,
};
use crate::mechanisms::session::{derive_session_seed, RoundDropouts, TransportSession};
use crate::mechanisms::traits::RoundOutput;
use crate::util::rng::{seed_domain, Rng};

use super::scenario::{slot, Attack, ScenarioConfig, ScenarioEvent, WindowPlan};
use super::snapshot::ScenarioSnapshot;
use super::validate_dropout_schedule;

/// Snapshot cadence of [`run_scenario_checked`]: a snapshot/resume
/// round-trip is exercised every this many ticks (including mid-window
/// ticks, where the session state is live).
pub const SNAPSHOT_INTERVAL: u64 = 8;

/// The deterministic fleet scenario engine (see the module docs).
///
/// The engine owns only *state* — fleet membership, drift means, RNG
/// slots, the current window plan and its live session. The mechanism
/// triple (encoder, transport, decoder) is passed into every
/// [`ScenarioEngine::tick`] and must stay the same across a scenario:
/// the transport schedule and session state are derived for it.
pub struct ScenarioEngine {
    cfg: ScenarioConfig,
    /// global tick = global round id (each tick executes one round)
    tick: u64,
    /// per-subsystem RNG slots, indexed by [`slot`] in execution order
    rngs: [Rng; slot::COUNT],
    /// current fleet membership (the churn subsystem's persistent state)
    active: Vec<bool>,
    /// per-client data-mean random walk (the drift subsystem's state)
    drift: Vec<f64>,
    ledger: Option<PrivacyLedger>,
    events: Vec<ScenarioEvent>,
    plan: Option<WindowPlan>,
    session: Option<TransportSession>,
}

impl ScenarioEngine {
    pub fn new(cfg: ScenarioConfig) -> Self {
        cfg.validate();
        let rngs = std::array::from_fn(|i| {
            Rng::new(Rng::derive_domain(cfg.seed, seed_domain::SCENARIO, i as u64))
        });
        Self {
            cfg,
            tick: 0,
            rngs,
            active: vec![true; cfg.n_clients],
            drift: vec![0.0; cfg.n_clients],
            ledger: None,
            events: Vec::new(),
            plan: None,
            session: None,
        }
    }

    /// Thread a privacy ledger through the scenario: every executed round
    /// is recorded at its *realized* participation rate γ = n′_cohort/n
    /// with zero TV slack — honest bookkeeping under data-dependent
    /// churn, NOT a subsampling-amplification guarantee (see
    /// [`crate::coordinator::run_rounds_encoded_scheduled`]).
    pub fn with_ledger(mut self, ledger: PrivacyLedger) -> Self {
        self.ledger = Some(ledger);
        self
    }

    /// The next tick to execute (= number of rounds executed so far).
    pub fn tick_count(&self) -> u64 {
        self.tick
    }

    /// The replayable event log so far.
    pub fn events(&self) -> &[ScenarioEvent] {
        &self.events
    }

    /// Consume the engine, surfacing its event log.
    pub fn into_events(self) -> Vec<ScenarioEvent> {
        self.events
    }

    /// Capture the engine's complete state. The capture is
    /// non-destructive; resuming from it
    /// ([`ScenarioEngine::from_snapshot`]) re-enters the exact stream
    /// positions of every RNG slot, so resume ≡ uninterrupted run, bit
    /// for bit.
    pub fn snapshot(&self) -> ScenarioSnapshot {
        ScenarioSnapshot {
            cfg: self.cfg,
            tick: self.tick,
            rng_states: std::array::from_fn(|i| self.rngs[i].state()),
            active: self.active.clone(),
            drift: self.drift.clone(),
            ledger: self.ledger.as_ref().map(|l| l.snapshot()),
            events: self.events.clone(),
            plan: self.plan.clone(),
            session: self.session.as_ref().map(|s| s.extract_state()),
        }
    }

    /// Re-enter a captured scenario state. `transport` must be the same
    /// transport the captured engine was ticking with — the session's
    /// masking schedule is re-derived from it
    /// ([`TransportSession::restore`]).
    pub fn from_snapshot(snap: &ScenarioSnapshot, transport: &dyn Transport) -> Self {
        snap.cfg.validate();
        assert_eq!(
            snap.active.len(),
            snap.cfg.n_clients,
            "scenario snapshot fails closed: membership mask shaped for a different fleet"
        );
        assert_eq!(
            snap.drift.len(),
            snap.cfg.n_clients,
            "scenario snapshot fails closed: drift state shaped for a different fleet"
        );
        assert_eq!(
            snap.plan.is_some(),
            snap.session.is_some(),
            "scenario snapshot fails closed: a window plan and its session are captured \
             together or not at all"
        );
        if let Some(p) = &snap.plan {
            assert!(
                snap.tick >= p.start_tick
                    && snap.tick - p.start_tick < p.round_seeds.len() as u64,
                "scenario snapshot fails closed: tick {} lies outside its captured window",
                snap.tick,
            );
        }
        Self {
            cfg: snap.cfg,
            tick: snap.tick,
            rngs: std::array::from_fn(|i| Rng::from_state(snap.rng_states[i])),
            active: snap.active.clone(),
            drift: snap.drift.clone(),
            ledger: snap.ledger.as_ref().map(PrivacyLedger::from_snapshot),
            events: snap.events.clone(),
            plan: snap.plan.clone(),
            session: snap.session.as_ref().map(|st| TransportSession::restore(transport, st)),
        }
    }

    /// Execute one round: open a window if none is active (planning all
    /// its rounds subsystem by subsystem), replay this tick's byzantine
    /// probes against a restored session replica, run the honest round
    /// chunk-by-chunk through the live session, and close the window on
    /// its last tick.
    pub fn tick(
        &mut self,
        encoder: &dyn ClientEncoder,
        transport: &dyn Transport,
        decoder: &dyn ServerDecoder,
    ) -> RoundReport {
        assert!(
            !transport.sum_only() || decoder.sum_decodable(),
            "mechanism is not homomorphic: it cannot decode from a sum-only transport"
        );
        if self.plan.is_none() {
            self.open_window(transport);
        }
        let (r, window, attacks) = {
            let plan = self.plan.as_ref().expect("window just opened");
            let r = (self.tick - plan.start_tick) as usize;
            (r, plan.round_seeds.len(), plan.attacks[r].clone())
        };
        for atk in attacks {
            self.probe_attack(atk, encoder, transport);
        }
        let report = self.run_round(r, encoder, decoder);
        self.tick += 1;
        if r + 1 == window {
            let mut session = self.session.take().expect("window has a live session");
            session.close_streamed();
            self.plan = None;
        }
        report
    }

    /// Plan one whole window — subsystems in fixed order, one RNG slot
    /// each — then open the session over the planned cohorts and announce
    /// every round's dropouts up front (the streamed-close discipline,
    /// which also guarantees [`Attack::ConflictingReannounce`] always
    /// hits an existing announcement).
    fn open_window(&mut self, transport: &dyn Transport) {
        let cfg = self.cfg;
        let n = cfg.n_clients;
        let start_tick = self.tick;
        let session_seed = derive_session_seed(cfg.seed, start_tick);
        let round_seeds: Vec<u64> = (0..cfg.window)
            .map(|r| Rng::derive_domain(cfg.seed, seed_domain::ROUND, start_tick + r as u64))
            .collect();
        let multi_chunk = ChunkPlan::new(cfg.dim, cfg.chunk).n_chunks() > 1;
        let mut cohorts: Vec<Vec<bool>> = Vec::with_capacity(cfg.window);
        let mut dropouts: Vec<Vec<usize>> = Vec::with_capacity(cfg.window);
        let mut data: Vec<Vec<Vec<f64>>> = Vec::with_capacity(cfg.window);
        let mut attacks: Vec<Vec<Attack>> = Vec::with_capacity(cfg.window);
        for r in 0..cfg.window {
            let tick = start_tick + r as u64;
            // 1. churn — membership flips, then the floor revives the
            // lowest-id inactive clients (deterministic, no draw)
            for c in 0..n {
                if self.rngs[slot::CHURN].bernoulli(cfg.churn_rate) {
                    self.active[c] = !self.active[c];
                    self.events.push(if self.active[c] {
                        ScenarioEvent::ClientJoined { tick, client: c }
                    } else {
                        ScenarioEvent::ClientLeft { tick, client: c }
                    });
                }
            }
            let mut alive = self.active.iter().filter(|&&a| a).count();
            for c in 0..n {
                if alive >= cfg.min_active {
                    break;
                }
                if !self.active[c] {
                    self.active[c] = true;
                    alive += 1;
                    self.events.push(ScenarioEvent::ClientJoined { tick, client: c });
                }
            }
            let cohort = SurvivorSet::from_alive_mask(self.active.clone());
            // 2. regional outage — a contiguous client-id span drops
            let mut dropped: Vec<usize> = Vec::new();
            let mut outage: Option<(usize, usize)> = None;
            if self.rngs[slot::OUTAGE].bernoulli(cfg.outage_rate) {
                let lo = self.rngs[slot::OUTAGE].below(n as u64) as usize;
                let hi = (lo + cfg.outage_span).min(n);
                outage = Some((lo, hi));
                dropped.extend((lo..hi).filter(|&c| cohort.is_alive(c)));
            }
            // 3. stragglers — Pareto(α = 1) delays past the deadline drop
            let mut stragglers: Vec<(usize, f64)> = Vec::new();
            for c in cohort.alive_iter() {
                if dropped.contains(&c) {
                    continue;
                }
                if self.rngs[slot::STRAGGLER].bernoulli(cfg.straggler_rate) {
                    let delay =
                        cfg.straggler_scale / (1.0 - self.rngs[slot::STRAGGLER].u01());
                    if delay > cfg.deadline {
                        dropped.push(c);
                        stragglers.push((c, delay));
                    }
                }
            }
            dropped.sort_unstable();
            // the engine never drops a round to zero survivors: reprieve
            // the highest-id dropouts until one cohort member remains
            while dropped.len() >= cohort.n_alive() {
                let reprieved = dropped.pop().expect("a non-empty dropout list");
                stragglers.retain(|&(c, _)| c != reprieved);
            }
            if let Some((lo, hi)) = outage {
                let in_region = dropped.iter().filter(|&&c| (lo..hi).contains(&c)).count();
                self.events.push(ScenarioEvent::RegionalOutage {
                    tick,
                    lo,
                    hi,
                    dropped: in_region,
                });
            }
            for (client, delay) in stragglers {
                self.events.push(ScenarioEvent::StragglerDropped { tick, client, delay });
            }
            // 4. data drift — every client's mean random-walks (clamped
            // well inside the mechanisms' input range), data = mean +
            // bounded noise; the walk advances for inactive clients too,
            // so membership cannot perturb the drift stream
            let rng = &mut self.rngs[slot::DRIFT];
            let mut round_data: Vec<Vec<f64>> = Vec::with_capacity(n);
            for c in 0..n {
                self.drift[c] =
                    (self.drift[c] + cfg.drift_step * rng.normal()).clamp(-3.0, 3.0);
                let mean = self.drift[c];
                round_data.push(
                    (0..cfg.dim)
                        .map(|_| (mean + rng.uniform(-0.5, 0.5)).clamp(-3.5, 3.5))
                        .collect(),
                );
            }
            // 5. byzantine — generate a probe guaranteed to violate the
            // session contract; kinds without a valid target this round
            // fall back to a conflicting re-announcement, which always
            // has one (every round is announced at open)
            let mut round_attacks = Vec::new();
            if self.rngs[slot::BYZANTINE].bernoulli(cfg.attack_rate) {
                let survivors = cohort.drop_clients(&dropped);
                let target =
                    survivors.alive_iter().next().expect("the floor keeps one survivor");
                let atk = match self.rngs[slot::BYZANTINE].below(6) {
                    0 if multi_chunk => Attack::MalformedChunkLen { round: r, client: target },
                    0 | 1 => Attack::DuplicateChunk { round: r, client: target },
                    2 => Attack::OutOfOrderChunk { round: r, client: target },
                    3 => match (0..n).find(|&c| !cohort.is_alive(c)) {
                        Some(c) => Attack::OutOfCohortSubmit { round: r, client: c },
                        None => Attack::ConflictingReannounce { round: r },
                    },
                    4 => match dropped.first() {
                        Some(&c) => Attack::SubmitAfterDrop { round: r, client: c },
                        None => Attack::ConflictingReannounce { round: r },
                    },
                    _ => Attack::ConflictingReannounce { round: r },
                };
                round_attacks.push(atk);
            }
            cohorts.push(self.active.clone());
            dropouts.push(dropped);
            data.push(round_data);
            attacks.push(round_attacks);
        }
        // planning self-check, then open + announce everything up front
        validate_dropout_schedule(n, &dropouts);
        let cohort_sets: Vec<SurvivorSet> =
            cohorts.iter().map(|m| SurvivorSet::from_alive_mask(m.clone())).collect();
        let mut session = TransportSession::open_sampled_chunked(
            transport,
            session_seed,
            n,
            cfg.dim,
            &round_seeds,
            &cohort_sets,
            cfg.chunk,
        );
        for (r, (cohort, dropped)) in cohort_sets.iter().zip(&dropouts).enumerate() {
            let survivors = cohort.drop_cohort_members(dropped, r);
            session.announce_dropouts(
                r,
                &RoundDropouts::announce_among(session_seed, r as u64, &survivors, dropped),
            );
        }
        self.events.push(ScenarioEvent::WindowOpened {
            tick: start_tick,
            window: cfg.window,
            session_seed,
        });
        self.plan = Some(WindowPlan {
            start_tick,
            session_seed,
            round_seeds,
            cohorts,
            dropouts,
            data,
            attacks,
        });
        self.session = Some(session);
    }

    /// Replay one byzantine probe against a restored replica of the live
    /// session (the replica is built from
    /// [`TransportSession::extract_state`], so probing can never corrupt
    /// the real session). The probe MUST panic on the fail-closed
    /// surface; a probe the session absorbs panics the engine itself.
    fn probe_attack(
        &mut self,
        atk: Attack,
        encoder: &dyn ClientEncoder,
        transport: &dyn Transport,
    ) {
        let state = self.session.as_ref().expect("window has a live session").extract_state();
        let data = self.plan.as_ref().expect("window open").data[atk.round()].clone();
        // restore OUTSIDE the catch: a restore panic is an engine bug,
        // not a rejected attack
        let mut replica = TransportSession::restore(transport, &state);
        let outcome = catch_unwind(AssertUnwindSafe(move || {
            apply_attack(&mut replica, encoder, &data, atk);
        }));
        match outcome {
            Err(_) => {
                self.events.push(ScenarioEvent::AttackRejected { tick: self.tick, attack: atk })
            }
            Ok(()) => panic!(
                "scenario fails open: byzantine probe {atk:?} was absorbed at tick {} \
                 without tripping the fail-closed surface",
                self.tick,
            ),
        }
    }

    /// Run round `r` of the active window honestly: every survivor
    /// encodes and submits chunk by chunk, each chunk unmasks the moment
    /// it completes, and the round decodes over its survivor set.
    fn run_round(
        &mut self,
        r: usize,
        encoder: &dyn ClientEncoder,
        decoder: &dyn ServerDecoder,
    ) -> RoundReport {
        let data: Vec<Vec<f64>> = self.plan.as_ref().expect("window open").data[r].clone();
        let session = self.session.as_mut().expect("window has a live session");
        let chunk_plan = session.plan();
        let round = *session.round(r);
        let survivors = session.survivors(r).clone();
        let cohort_alive = session.cohort(r).n_alive();
        let n = self.cfg.n_clients;
        let dim = self.cfg.dim;
        let whole = chunk_plan.is_whole();
        let chunk_dec = decoder.chunk_decodable();
        let mut estimate = vec![0.0f64; dim];
        // non-chunk-decodable mechanisms over a multi-chunk plan assemble
        // the whole-d sum — O(d), the size of the estimate itself
        let mut sums: Vec<i64> = vec![0; if chunk_dec || whole { 0 } else { dim }];
        for k in 0..chunk_plan.n_chunks() {
            let range = chunk_plan.range(k);
            for i in survivors.alive_iter() {
                let msg = encoder.encode_chunk(i, &data[i], range.clone(), &round);
                session.submit_chunk(r, k, i, &msg);
            }
            let payload = session.finish_chunk(r, k);
            if chunk_dec {
                let est =
                    decoder.decode_survivors_chunk(&payload, range.start, &round, &survivors);
                estimate[range.clone()].copy_from_slice(&est);
            } else if whole {
                estimate = decoder.decode_survivors(&payload, &round, &survivors);
            } else {
                match payload {
                    Payload::Sum(v) => sums[range.clone()].copy_from_slice(&v),
                    _ => unreachable!("multi-chunk sessions run only over summing transports"),
                }
            }
        }
        if !chunk_dec && !whole {
            estimate = decoder.decode_survivors(
                &Payload::Sum(std::mem::take(&mut sums)),
                &round,
                &survivors,
            );
        }
        let bits = session.round_bits(r);
        let n_alive = survivors.n_alive();
        let mut true_mean = vec![0.0f64; dim];
        for i in survivors.alive_iter() {
            for (mj, xj) in true_mean.iter_mut().zip(&data[i]) {
                *mj += xj;
            }
        }
        for mj in true_mean.iter_mut() {
            *mj /= n_alive as f64;
        }
        let tick = self.tick;
        let gamma = n_alive as f64 / n as f64;
        let privacy =
            self.ledger.as_mut().map(|l| l.record_with_tv_slack(tick, gamma, 0.0));
        self.events.push(ScenarioEvent::RoundClosed {
            tick: self.tick,
            survivors: n_alive,
            cohort: cohort_alive,
        });
        RoundReport {
            round: self.tick,
            output: RoundOutput { estimate, bits },
            true_mean,
            survivors: n_alive,
            cohort: cohort_alive,
            privacy,
        }
    }
}

/// Apply one attack to a session replica. Contains NO assertions of its
/// own — every panic comes from the session's fail-closed surface, which
/// is exactly what the probe is measuring.
fn apply_attack(
    replica: &mut TransportSession,
    encoder: &dyn ClientEncoder,
    data: &[Vec<f64>],
    atk: Attack,
) {
    let r = atk.round();
    let round = *replica.round(r);
    let plan = replica.plan();
    match atk {
        Attack::MalformedChunkLen { client, .. } => {
            let range = plan.range(0);
            let mut msg = encoder.encode_chunk(client, &data[client], range, &round);
            msg.ms.push(0); // one description too many for the chunk's range
            replica.submit_chunk(r, 0, client, &msg);
        }
        Attack::DuplicateChunk { client, .. } => {
            let range = plan.range(0);
            let msg = encoder.encode_chunk(client, &data[client], range, &round);
            replica.submit_chunk(r, 0, client, &msg);
            replica.submit_chunk(r, 0, client, &msg);
        }
        Attack::OutOfOrderChunk { client, .. } => {
            let range = plan.range(0);
            let msg = encoder.encode_chunk(client, &data[client], range, &round);
            replica.submit_chunk(r, 1, client, &msg);
        }
        Attack::OutOfCohortSubmit { client, .. } | Attack::SubmitAfterDrop { client, .. } => {
            let range = plan.range(0);
            let msg = encoder.encode_chunk(client, &data[client], range, &round);
            replica.submit_chunk(r, 0, client, &msg);
        }
        Attack::ConflictingReannounce { .. } => {
            replica.announce_dropouts(r, &RoundDropouts::default());
        }
    }
}

/// Run a scenario end to end with the snapshot/resume contract ON THE
/// MAINLINE: every [`SNAPSHOT_INTERVAL`] ticks the engine is captured,
/// serialized to bytes, deserialized, resumed — and the run CONTINUES
/// from the resumed engine, asserting the round-trip was lossless at
/// every step. Returns the per-tick reports and the event log.
pub fn run_scenario_checked(
    cfg: ScenarioConfig,
    encoder: &dyn ClientEncoder,
    transport: &dyn Transport,
    decoder: &dyn ServerDecoder,
    ticks: u64,
    ledger: Option<PrivacyLedger>,
) -> (Vec<RoundReport>, Vec<ScenarioEvent>) {
    let mut engine = ScenarioEngine::new(cfg);
    if let Some(l) = ledger {
        engine = engine.with_ledger(l);
    }
    let mut reports = Vec::with_capacity(ticks as usize);
    for t in 0..ticks {
        if t > 0 && t % SNAPSHOT_INTERVAL == 0 {
            let snap = engine.snapshot();
            let bytes = snap.to_bytes();
            let back = ScenarioSnapshot::from_bytes(&bytes);
            assert_eq!(back, snap, "snapshot byte round-trip must be lossless");
            let resumed = ScenarioEngine::from_snapshot(&back, transport);
            assert_eq!(
                resumed.snapshot(),
                snap,
                "resume must re-enter the exact captured state"
            );
            engine = resumed;
        }
        reports.push(engine.tick(encoder, transport, decoder));
    }
    (reports, engine.into_events())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::pipeline::{Plain, SecAgg};
    use crate::mechanisms::{AggregateGaussian, IrwinHallMechanism};

    fn run(cfg: ScenarioConfig, transport: &dyn Transport, ticks: u64) -> Vec<RoundReport> {
        let mech = IrwinHallMechanism::new(0.4, 8.0);
        let mut engine = ScenarioEngine::new(cfg);
        (0..ticks).map(|_| engine.tick(&mech, transport, &mech)).collect()
    }

    #[test]
    fn scenario_engine_replays_bit_identically() {
        let cfg = ScenarioConfig::churn(6, 4, 3, 2, 0xFEED);
        let a = run(cfg, &SecAgg::new(), 7);
        let b = run(cfg, &SecAgg::new(), 7);
        assert_eq!(a, b, "same config must replay the same run, bit for bit");
        assert_ne!(
            a,
            run(ScenarioConfig::churn(6, 4, 3, 2, 0xFEE0), &SecAgg::new(), 7),
            "a different scenario seed must change the run"
        );
    }

    #[test]
    fn scenario_resume_mid_window_matches_uninterrupted_run() {
        let cfg = ScenarioConfig::churn(6, 4, 3, 2, 0xBEE5);
        let mech = AggregateGaussian::new(0.5, 8.0);
        let transport = SecAgg::new();
        let straight: Vec<RoundReport> = {
            let mut e = ScenarioEngine::new(cfg).with_ledger(PrivacyLedger::new(0.8, 1e-6));
            (0..7).map(|_| e.tick(&mech, &transport, &mech)).collect()
        };
        // snapshot at tick 4 — mid-way through the second window
        let mut e = ScenarioEngine::new(cfg).with_ledger(PrivacyLedger::new(0.8, 1e-6));
        let mut resumed_reports = Vec::new();
        for t in 0..7 {
            if t == 4 {
                let bytes = e.snapshot().to_bytes();
                e = ScenarioEngine::from_snapshot(
                    &ScenarioSnapshot::from_bytes(&bytes),
                    &transport,
                );
            }
            resumed_reports.push(e.tick(&mech, &transport, &mech));
        }
        assert_eq!(straight, resumed_reports, "resume must be bit-identical, ledger included");
    }

    #[test]
    fn scenario_byzantine_probes_are_all_rejected() {
        let cfg = ScenarioConfig::byzantine(6, 4, 3, 2, 0xD00F);
        let mech = IrwinHallMechanism::new(0.4, 8.0);
        let mut engine = ScenarioEngine::new(cfg);
        for _ in 0..9 {
            engine.tick(&mech, &SecAgg::new(), &mech);
        }
        let rejected = engine
            .events()
            .iter()
            .filter(|e| matches!(e, ScenarioEvent::AttackRejected { .. }))
            .count();
        assert!(rejected >= 1, "a byzantine scenario must have probed the surface");
        let closed = engine
            .events()
            .iter()
            .filter(|e| matches!(e, ScenarioEvent::RoundClosed { .. }))
            .count();
        assert_eq!(closed, 9, "every probed round must still close exactly");
    }

    #[test]
    fn scenario_churn_floor_holds() {
        let cfg = ScenarioConfig {
            churn_rate: 0.9,
            min_active: 2,
            ..ScenarioConfig::churn(5, 3, 2, 3, 0xAB)
        };
        for report in run(cfg, &Plain, 8) {
            assert!(report.cohort >= 2, "churn floor violated: cohort {}", report.cohort);
            assert!(report.survivors >= 1, "a round closed without survivors");
        }
    }

    #[test]
    fn scenario_checked_runner_exercises_snapshots() {
        let cfg = ScenarioConfig::churn(5, 3, 3, 3, 0x5EED);
        let mech = IrwinHallMechanism::new(0.4, 8.0);
        let ticks = SNAPSHOT_INTERVAL * 2 + 3;
        let (reports, events) = run_scenario_checked(
            cfg,
            &mech,
            &SecAgg::new(),
            &mech,
            ticks,
            Some(PrivacyLedger::new(1.0, 1e-6)),
        );
        assert_eq!(reports.len(), ticks as usize);
        // the checked runner (two snapshot/resume round-trips) must match
        // an uninterrupted engine exactly
        let straight: Vec<RoundReport> = {
            let mut e = ScenarioEngine::new(cfg).with_ledger(PrivacyLedger::new(1.0, 1e-6));
            (0..ticks).map(|_| e.tick(&mech, &SecAgg::new(), &mech)).collect()
        };
        assert_eq!(reports, straight);
        assert!(events
            .iter()
            .any(|e| matches!(e, ScenarioEvent::WindowOpened { .. })));
    }
}
