//! Micro-benchmark harness (criterion is not available offline).
//!
//! API mirrors the criterion subset we need: named benchmarks with warmup,
//! adaptive iteration counts, and mean / p50 / p95 reporting. `cargo bench`
//! targets are `harness = false` binaries that drive [`Suite`].

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// optional elements-per-iteration for throughput reporting
    pub elements: Option<u64>,
}

impl Measurement {
    pub fn throughput_mps(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.mean_ns * 1e3)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:8.1} ns")
    } else if ns < 1e6 {
        format!("{:8.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.2} ms", ns / 1e6)
    } else {
        format!("{:8.2} s ", ns / 1e9)
    }
}

/// Benchmark suite: collects measurements and prints a report table.
pub struct Suite {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub results: Vec<Measurement>,
}

impl Default for Suite {
    fn default() -> Self {
        Self::new()
    }
}

impl Suite {
    pub fn new() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(700),
            min_samples: 10,
            results: Vec::new(),
        }
    }

    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_samples: 5,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs ONE logical operation per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &Measurement {
        self.bench_elements(name, None, move || f())
    }

    /// Benchmark with a per-iteration element count (throughput reporting).
    pub fn bench_elements(
        &mut self,
        name: &str,
        elements: Option<u64>,
        mut f: impl FnMut(),
    ) -> &Measurement {
        // Warmup and calibrate batch size so one batch is ~1ms.
        let w0 = Instant::now();
        let mut calib_iters = 0u64;
        while w0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let batch = ((1e6 / per_iter).ceil() as u64).clamp(1, 1_000_000);

        // Measure in batches until the time budget or min samples reached.
        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let m0 = Instant::now();
        while m0.elapsed() < self.measure || samples.len() < self.min_samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(ns);
            total_iters += batch;
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let m = Measurement {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            p50_ns: p(0.5),
            p95_ns: p(0.95),
            elements,
        };
        println!(
            "bench {:44} mean {}  p50 {}  p95 {}{}",
            m.name,
            fmt_ns(m.mean_ns),
            fmt_ns(m.p50_ns),
            fmt_ns(m.p95_ns),
            m.throughput_mps()
                .map(|t| format!("  thrpt {t:9.2} Melem/s"))
                .unwrap_or_default()
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Print a summary table of all measurements.
    pub fn report(&self) {
        println!("\n== benchkit report ({} benchmarks) ==", self.results.len());
        for m in &self.results {
            println!(
                "{:44} {:>12} iters  mean {}",
                m.name,
                m.iters,
                fmt_ns(m.mean_ns)
            );
        }
    }
}

/// Re-export-style helper so benches read like criterion code.
pub fn consume<T>(x: T) -> T {
    bb(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut s = Suite {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(10),
            min_samples: 2,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        s.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(s.results.len(), 1);
        assert!(s.results[0].mean_ns > 0.0);
        assert!(s.results[0].iters > 0);
    }

    #[test]
    fn throughput_reported() {
        let mut s = Suite {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(10),
            min_samples: 2,
            results: Vec::new(),
        };
        let xs = vec![1.0f64; 1024];
        let m = s
            .bench_elements("sum1k", Some(1024), || {
                consume(xs.iter().sum::<f64>());
            })
            .clone();
        assert!(m.throughput_mps().unwrap() > 0.0);
    }
}
