//! The FL coordinator (Layer 3): round-based orchestration of n clients and
//! a server around the client-encode / transport / server-decode pipeline.
//!
//! Architecture: client-local computation (the expensive part — gradients,
//! local potentials) runs on a thread pool, one worker per client shard,
//! communicating with the orchestrator over channels. In the pipeline
//! round shape ([`runtime::run_round_encoded`]) the *encoder* runs inside
//! the shard too: client vectors never leave their worker, shards fold
//! description sums and bit accounting locally, and the orchestrator only
//! merges O(d) partials and decodes. Shared randomness is derived from the
//! round seed on both ends — exactly how a real deployment shares a seed
//! instead of shipping randomness.
//!
//! For high-frequency FL, [`runtime::run_rounds_encoded`] batches a window
//! of W rounds into one
//! [`crate::mechanisms::session::TransportSession`]: the masking transport
//! opens once per window, shards ship one message per window, and the
//! server unmasks all rounds in a single batched close (single rounds are
//! the W=1 special case).
//!
//! Fleets at scale neither keep every client alive
//! ([`runtime::run_rounds_encoded_with_dropouts`]) nor touch every client
//! every round: [`runtime::run_rounds_encoded_sampled`] derives each
//! round's cohort from the root seed through a
//! [`sampling::SamplingPolicy`] (flat Poisson/fixed-size rates or a
//! per-round [`sampling::SamplingPolicy::Schedule`]), opens the masked
//! session over the cohort only, and threads each round's
//! subsampling-amplified DP spend through a
//! [`crate::dp::PrivacyLedger`].
//!
//! Models too large for whole-vector buffers stream their coordinate
//! space: [`runtime::run_rounds_encoded_chunked`] runs the window over a
//! [`crate::mechanisms::pipeline::ChunkPlan`] — one bounded channel
//! message per (shard, chunk), a cross-shard chunk barrier, and per-chunk
//! unmask + decode — so peak orchestrator memory is O(shards·c) instead
//! of O(shards·d), bit-identical to the whole-d runner for every chunk
//! size.
//!
//! And fleets at real scale drop the barrier too:
//! [`runtime::run_rounds_encoded_async`] runs the chunked window on an
//! event-driven work-stealing scheduler ([`scheduler::WorkStealPool`]) —
//! no shard ever waits for another, accumulators close per (round, chunk)
//! as their cohort's submissions arrive, backpressure comes from the
//! bounded accumulator ring, and stragglers past a deterministic
//! virtual-clock deadline ([`deadline::DeadlinePolicy`]) convert into
//! announced dropouts on the Bonawitz recovery path. Straggler-free
//! schedules reproduce the barrier runners bit for bit.
//!
//! * [`config`] — experiment configuration (file + CLI overrides)
//! * [`deadline`] — deterministic virtual-clock straggler deadlines
//! * [`metrics`] — per-round metric recording, CSV/JSON export
//! * [`runtime`] — the threaded client pool + round loops
//! * [`sampling`] — seed-derived per-round client sampling policies
//! * [`scheduler`] — the std-only M:N work-stealing task pool

pub mod config;
pub mod deadline;
pub mod metrics;
pub mod runtime;
pub mod sampling;
pub mod scheduler;

pub use config::Config;
pub use deadline::DeadlinePolicy;
pub use metrics::Metrics;
pub use runtime::{
    run_round, run_round_encoded, run_round_mech, run_rounds_encoded,
    run_rounds_encoded_async, run_rounds_encoded_chunked, run_rounds_encoded_sampled,
    run_rounds_encoded_scheduled, run_rounds_encoded_with_dropouts, run_rounds_mech,
    run_rounds_mech_async, run_rounds_mech_chunked, run_rounds_mech_sampled,
    run_rounds_mech_with_dropouts, AsyncRunConfig, AsyncStreamStats, ChunkStreamStats,
    ClientPool, LocalCompute, RoundReport, SliceCompute,
};
pub use sampling::SamplingPolicy;
pub use scheduler::{WorkStealPool, WorkerFailure};
