//! Point-to-point AINQ quantizers (§3 of the paper).
//!
//! All three quantizers share the same shape: the shared randomness S
//! determines a (step, offset, dither) triple; encoding is
//! `m = round(x/step + dither)` and decoding is
//! `y = (m - dither)·step + offset`, so the error `y - x` is uniform on an
//! interval of length `step` centred at `offset` *conditionally on S*.
//! The step/offset law is what differs:
//!
//! * [`dither::SubtractiveDither`] — fixed step w, offset 0
//!   ⇒ error U(-w/2, w/2) (Example 1);
//! * [`layered::DirectLayered`] — step = layer width f_D(D), D ~ f_D
//!   ⇒ error exactly f_Z (Def. 4, Hegazy–Li 2022);
//! * [`layered::ShiftedLayered`] — multishift coupling (Wilson 2000)
//!   ⇒ error exactly f_Z with a step bounded below by η_Z > 0 (Def. 5,
//!   Prop. 2) — enabling fixed-length codes.

pub mod dither;
pub mod layered;

pub use dither::SubtractiveDither;
pub use layered::{DirectLayered, ShiftedLayered};

use crate::util::rng::Rng;

/// The paper's rounding ⌈v⌋ := ⌊v + 1/2⌋.
#[inline]
pub fn round_half_up(v: f64) -> i64 {
    (v + 0.5).floor() as i64
}

/// One draw of point-to-point shared randomness S.
#[derive(Clone, Copy, Debug)]
pub struct StepDraw {
    /// quantization step size (w in Ex. 1, f_D(D) in Def. 4, f_W(W) in Def. 5)
    pub step: f64,
    /// decoder offset ((b⁺+b⁻)/2 terms of Defs. 4–5)
    pub offset: f64,
    /// dither U ~ U(0, 1)
    pub dither: f64,
}

/// A point-to-point AINQ quantizer: error `decode(encode(x,S),S) - x ~ Q`
/// independent of x.
pub trait PointQuantizer {
    /// Sample the shared randomness S. Client and server call this with
    /// identically-seeded RNGs, so both sides know (step, offset, dither).
    fn draw(&self, rng: &mut Rng) -> StepDraw;

    #[inline]
    fn encode(&self, x: f64, s: &StepDraw) -> i64 {
        round_half_up(x / s.step + s.dither)
    }

    #[inline]
    fn decode(&self, m: i64, s: &StepDraw) -> f64 {
        (m as f64 - s.dither) * s.step + s.offset
    }

    /// Convenience: one full draw-encode-decode round trip.
    fn quantize(&self, x: f64, rng: &mut Rng) -> (i64, f64, StepDraw) {
        let s = self.draw(rng);
        let m = self.encode(x, &s);
        (m, self.decode(m, &s), s)
    }

    /// Minimal step size η, if bounded away from zero (Prop. 2). A
    /// quantizer with `Some(η)` supports fixed-length coding with
    /// |Supp M| <= 2 + t/η for inputs in an interval of length t.
    fn min_step(&self) -> Option<f64>;

    /// Standard deviation of the error distribution this quantizer realizes.
    fn error_sd(&self) -> f64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_half_up_matches_paper() {
        // ⌈v⌋ = ⌊v + 1/2⌋
        assert_eq!(round_half_up(0.49), 0);
        assert_eq!(round_half_up(0.5), 1); // half rounds up
        assert_eq!(round_half_up(-0.5), 0);
        assert_eq!(round_half_up(-0.51), -1);
        assert_eq!(round_half_up(2.5), 3);
    }
}
