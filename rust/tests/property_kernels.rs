//! The lane-batched ≡ scalar kernel property matrix.
//!
//! PR 6 rewrites every per-coordinate hot loop — SecAgg mask expansion,
//! dither/u01 fills, the quantizer encode paths — on the lane-batched
//! coordinate expander (`CoordLanes`). The batching is pure
//! reassociation of position-free derivations (docs/determinism.md has
//! the argument), so NONE of it may change a single drawn bit. This
//! suite is the enforcement: batched expansions are compared against
//! literal scalar `Rng::derive_coord` loops across lane widths and chunk
//! geometries, and the end-to-end identities the repo already guarantees
//! (Plain ≡ SecAgg, chunked ≡ unchunked) are re-proven THROUGH the
//! batched kernels.
//!
//! Every test name carries the `kernels_` prefix so `cargo test -q
//! kernels` runs exactly this matrix (plus the in-module kernel unit
//! tests).

use exact_comp::coordinator::sampling::SamplingPolicy;
use exact_comp::mechanisms::pipeline::{
    ChunkPlan, ClientEncoder, Plain, SecAgg, SharedRound,
};
use exact_comp::mechanisms::{AggregateGaussian, IrwinHallMechanism};
use exact_comp::secagg::{self, pair_seed, SecAggParams};
use exact_comp::testing::{
    assert_chunked_window_matches_unchunked, assert_window_closes_exactly, Fleet,
};
use exact_comp::transforms::hadamard::{fwht, fwht_naive, fwht_threaded};
use exact_comp::util::rng::{
    fill_below_coords, fill_dither_coords, fill_u01_coords, lemire_threshold, seed_domain,
    Rng,
};

/// The chunk geometries of the acceptance matrix for dimension d:
/// {1, 7, 64, d, d + 3} — sub-lane, non-multiple-of-lane, multi-lane,
/// exact, and past-the-end chunk sizes.
fn matrix_chunks(d: usize) -> Vec<usize> {
    vec![1, 7, 64, d, d + 3]
}

/// A deterministic stand-in for a coordinate-stream family seed.
fn family(tag: u64) -> u64 {
    Rng::derive_domain(0x6B65_726E, seed_domain::COORD_FAMILY, tag)
}

// --- raw fill kernels vs scalar derivations ----------------------------

#[test]
fn kernels_fill_below_matches_scalar_derive_coord_loop() {
    let d = 257usize; // prime: exercises every lane-tail combination
    let m = SecAggParams::default().modulus;
    for (f, n) in [(family(1), m), (family(2), 3), (family(3), (1u64 << 63) + (1 << 61))] {
        for chunk in matrix_chunks(d) {
            let plan = ChunkPlan::new(d, chunk);
            let mut got = vec![0u64; d];
            for r in plan.ranges() {
                let lo = r.start;
                fill_below_coords(f, lo as u64, n, &mut got[r]);
            }
            let want: Vec<u64> =
                (0..d).map(|j| Rng::derive_coord(f, j as u64).below(n)).collect();
            assert_eq!(got, want, "fill_below n={n} chunk={chunk}");
        }
    }
}

#[test]
fn kernels_fill_u01_and_dither_match_scalar_draws() {
    let d = 129usize;
    let f = family(4);
    for chunk in matrix_chunks(d) {
        let plan = ChunkPlan::new(d, chunk);
        let mut u = vec![0.0f64; d];
        let mut s = vec![0.0f64; d];
        for r in plan.ranges() {
            let lo = r.start as u64;
            fill_u01_coords(f, lo, &mut u[r.clone()]);
            fill_dither_coords(f, lo, &mut s[r]);
        }
        for j in 0..d {
            let mut a = Rng::derive_coord(f, j as u64);
            let mut b = Rng::derive_coord(f, j as u64);
            assert_eq!(u[j], a.u01(), "u01 j={j} chunk={chunk}");
            assert_eq!(s[j], b.dither(), "dither j={j} chunk={chunk}");
        }
    }
}

#[test]
fn kernels_lane_width_does_not_change_any_bit() {
    // the same coordinate block expanded at every lane width must agree
    // with the scalar stream draw for draw, including through rejection
    // sampling (n chosen so below() rejects ~1/4 of raw u64 draws)
    let f = family(5);
    let n = (1u64 << 63) + (1 << 61);
    let t = lemire_threshold(n);
    macro_rules! check_width {
        ($L:literal) => {{
            let mut lanes = Rng::derive_coord_batch::<$L>(f, 40);
            let raw = lanes.next_u64();
            let us = lanes.u01();
            let bs = lanes.below(n, t);
            for l in 0..$L {
                let mut scalar = Rng::derive_coord(f, 40 + l as u64);
                assert_eq!(raw[l], scalar.next_u64(), "L={} lane={l} raw", $L);
                assert_eq!(us[l], scalar.u01(), "L={} lane={l} u01", $L);
                assert_eq!(bs[l], scalar.below(n), "L={} lane={l} below", $L);
            }
        }};
    }
    check_width!(1);
    check_width!(2);
    check_width!(4);
    check_width!(8);
    check_width!(16);
}

// --- SecAgg mask expansion ---------------------------------------------

#[test]
fn kernels_mask_expansion_matches_scalar_reference() {
    let params = SecAggParams::default();
    let m = params.modulus;
    let (n_clients, d) = (5usize, 83usize);
    let root = family(6);
    let ms: Vec<i64> = (0..d as i64).map(|j| (j * 7 - 120) % 50).collect();
    for client in 0..n_clients {
        // scalar reference: per-leg, per-coordinate derive_coord loop —
        // the pre-batching implementation, kept alive here as the spec
        let mut want: Vec<u64> = ms.iter().map(|&v| secagg::to_field(v, m)).collect();
        for other in 0..n_clients {
            if other == client {
                continue;
            }
            let ps = pair_seed(root, client, other);
            for (j, w) in want.iter_mut().enumerate() {
                let mask = Rng::derive_coord(ps, j as u64).below(m);
                *w = if client < other { (*w + mask) % m } else { (*w + m - mask) % m };
            }
        }
        let got = secagg::mask_descriptions(&ms, client, n_clients, root, params);
        assert_eq!(got, want, "client {client}: batched masking diverged from scalar");
        // and chunked: concatenation over every matrix geometry
        for chunk in matrix_chunks(d) {
            let plan = ChunkPlan::new(d, chunk);
            let mut cat = Vec::with_capacity(d);
            for r in plan.ranges() {
                cat.extend(secagg::mask_descriptions_range(
                    &ms[r.clone()],
                    client,
                    n_clients,
                    root,
                    params,
                    r.start,
                ));
            }
            assert_eq!(cat, want, "client {client} chunk={chunk}");
        }
    }
}

#[test]
fn kernels_mask_reconstruction_matches_scalar_reference() {
    let params = SecAggParams::default();
    let m = params.modulus;
    let (n_clients, d, dropped) = (6usize, 41usize, 2usize);
    let root = family(7);
    let shares: Vec<_> = (0..n_clients)
        .filter(|&h| h != dropped)
        .map(|h| secagg::recovery_share(root, h, dropped))
        .collect();
    let mut want = vec![0u64; d];
    for share in &shares {
        for (j, w) in want.iter_mut().enumerate() {
            let mask = Rng::derive_coord(share.pair_seed, j as u64).below(m);
            *w = if dropped < share.holder { (*w + mask) % m } else { (*w + m - mask) % m };
        }
    }
    assert_eq!(secagg::reconstruct_dropped_masks(dropped, &shares, d, params), want);
    for chunk in matrix_chunks(d) {
        let plan = ChunkPlan::new(d, chunk);
        let mut cat = Vec::with_capacity(d);
        for r in plan.ranges() {
            cat.extend(secagg::reconstruct_dropped_masks_range(
                dropped,
                &shares,
                r.start,
                r.len(),
                params,
            ));
        }
        assert_eq!(cat, want, "chunk={chunk}");
    }
}

// --- quantizer encode kernels ------------------------------------------

#[test]
fn kernels_quantizer_encode_chunks_match_whole_vector() {
    let (n, d) = (7usize, 83usize);
    let round = SharedRound::new(family(8), n, d);
    let mut rng = Rng::new(31);
    let xs: Vec<Vec<f64>> =
        (0..n).map(|_| (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect()).collect();
    let ih = IrwinHallMechanism::new(0.4, 4.0);
    let ag = AggregateGaussian::new(0.4, 4.0);
    for client in [0usize, 3, 6] {
        let ih_whole = ih.encode(client, &xs[client], &round);
        let ag_whole = ag.encode(client, &xs[client], &round);
        for chunk in matrix_chunks(d) {
            let plan = ChunkPlan::new(d, chunk);
            let mut ih_cat: Vec<i64> = Vec::with_capacity(d);
            let mut ag_cat: Vec<i64> = Vec::with_capacity(d);
            for r in plan.ranges() {
                ih_cat.extend(ih.encode_chunk(client, &xs[client], r.clone(), &round).ms);
                ag_cat.extend(ag.encode_chunk(client, &xs[client], r, &round).ms);
            }
            assert_eq!(ih_cat, ih_whole.ms, "IH client {client} chunk={chunk}");
            assert_eq!(ag_cat, ag_whole.ms, "AG client {client} chunk={chunk}");
        }
    }
}

#[test]
fn kernels_irwin_hall_dither_matches_scalar_stream() {
    // the batched encode must consume exactly one u01 per coordinate of
    // the client stream — the scalar spec is round_half_up(x/w + u01(j))
    let (n, d) = (5usize, 67usize);
    let round = SharedRound::new(family(9), n, d);
    let ih = IrwinHallMechanism::new(0.3, 4.0);
    let w = ih.step(n);
    let mut rng = Rng::new(32);
    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
    let got = ih.encode(1, &x, &round).ms;
    let stream = round.client_coord_stream(1);
    let want: Vec<i64> = (0..d)
        .map(|j| {
            let s = stream.at(j).u01();
            exact_comp::quantizer::round_half_up(x[j] / w + s)
        })
        .collect();
    assert_eq!(got, want);
}

// --- end-to-end identities through the batched kernels -----------------

#[test]
fn kernels_plain_equals_secagg_through_batched_path() {
    let fleet = Fleet::new(6, 37, 91);
    let schedule = vec![vec![], vec![1, 4], vec![]];
    let mech = IrwinHallMechanism::new(0.5, 4.0);
    assert_window_closes_exactly(&mech, &SecAgg::new(), &fleet, &schedule, family(10));
    let mech = AggregateGaussian::new(0.5, 4.0);
    assert_window_closes_exactly(&mech, &SecAgg::new(), &fleet, &schedule, family(11));
}

#[test]
fn kernels_chunked_equals_unchunked_through_batched_path() {
    let d = 37usize;
    let fleet = Fleet::new(6, d, 92);
    let schedule = vec![vec![], vec![2]];
    let mech = IrwinHallMechanism::new(0.5, 4.0);
    for transport in [&Plain as &dyn exact_comp::mechanisms::pipeline::Transport, &SecAgg::new()]
    {
        assert_chunked_window_matches_unchunked(
            &mech,
            transport,
            &fleet,
            &SamplingPolicy::Full,
            &schedule,
            family(12),
            &matrix_chunks(d),
        );
    }
}

// --- FWHT schedules ----------------------------------------------------

#[test]
fn kernels_fwht_blocked_and_threaded_match_naive() {
    // past the tile (2¹²) so both the blocked top levels and the
    // recursive threaded split are active
    let mut rng = Rng::new(33);
    for n in [1usize << 13, 1 << 15] {
        let base: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut want = base.clone();
        fwht_naive(&mut want);
        let mut blocked = base.clone();
        fwht(&mut blocked);
        assert_eq!(blocked, want, "blocked n={n}");
        for threads in [1usize, 2, 4, 6] {
            let mut x = base.clone();
            fwht_threaded(&mut x, threads);
            assert_eq!(x, want, "threaded n={n} threads={threads}");
        }
    }
}
