//! Aggregate AINQ mechanisms (§2, §4, §5): n clients → server mean estimate
//! with an exact aggregation-error distribution.
//!
//! Every mechanism is implemented as a client-encode / transport /
//! server-decode pipeline ([`pipeline`]): the struct carries the mechanism
//! parameters and implements [`pipeline::ClientEncoder`] (what client i
//! sends given its vector and the round's shared randomness),
//! [`pipeline::ServerDecoder`] (what the server reconstructs from the
//! transported payload) and [`pipeline::MechSpec`] (the Table 1 property
//! flags). The monolithic [`traits::MeanMechanism::aggregate`] entry point
//! survives as a thin wrapper over [`pipeline::run_pipeline`].
//!
//! * [`individual`] — Def. 2: per-client point-to-point AINQ quantizers
//!   (direct or shifted layered), averaged by the server. Exact Gaussian
//!   noise, NOT homomorphic (Unicast transport).
//! * [`irwin_hall`] — §4.2: shared-step subtractive dithering; homomorphic
//!   (sum-only transports, SecAgg-compatible) but the noise is Irwin–Hall,
//!   not Gaussian.
//! * [`decompose`] — Algorithms 1–2: decomposition of the Gaussian into a
//!   mixture of shifted/scaled Irwin–Hall laws (the (A, B) sampler).
//! * [`aggregate`] — Def. 8 + §4.4: the aggregate Gaussian mechanism —
//!   homomorphic AND exactly Gaussian.
//! * [`sigm`] — §5.1 + Alg. 5: subsampled individual Gaussian mechanism.
//! * [`session`] — batched multi-round transport sessions: one opening per
//!   window of W rounds, a ring of per-round accumulators, one batched
//!   unmask (with Bonawitz-style pairwise-seed recovery for announced
//!   dropouts); single-round aggregation is the W=1 special case. The
//!   coordinate space runs under a [`pipeline::ChunkPlan`]: chunked
//!   sessions keep O(c) accumulators per chunk, unmask and release each
//!   chunk as it completes, and — because every per-coordinate stream is
//!   seekable — decode bit-identically to the whole-d path for every
//!   chunk size (the whole-d path IS the single-chunk plan).

pub mod traits;
pub mod pipeline;
pub mod session;
pub mod individual;
pub mod irwin_hall;
pub mod decompose;
pub mod aggregate;
pub mod sigm;

pub use aggregate::AggregateGaussian;
pub use decompose::Decomposer;
pub use individual::{IndividualGaussian, LayeredVariant};
pub use irwin_hall::IrwinHallMechanism;
pub use pipeline::{
    run_pipeline, ChunkCache, ChunkPlan, ClientEncoder, CoordStream, Descriptions, LocalCompute,
    MechSpec, Payload, Pipeline, PipelineParts, Plain, RoundCache, SecAgg, ServerDecoder,
    SharedRound, SliceCompute, SurvivorSet, Transport, TransportPartial, Unicast,
};
pub use session::{
    derive_session_seed, run_window, run_window_chunked, run_window_chunked_from,
    run_window_sampled, run_window_with_dropouts, session_recovery_share, ChunkSlotState,
    RoundDropouts, RoundSlotState, SessionState, TransportSession,
};
pub use sigm::Sigm;
pub use traits::{BitsAccount, MeanMechanism, RoundOutput};
