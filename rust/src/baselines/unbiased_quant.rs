//! Classical unbiased b-bit quantization (App. C intro): normalize by
//! ‖x‖∞, subtractively dither on a 2^b-level uniform grid over [−1, 1],
//! rescale. Error is uniform per coordinate with variance
//! (w²/12)·‖x‖∞², w = 2/(2^b − 1) — *bounded-variance* compression, the
//! standard assumption the paper generalizes away from.
//!
//! Two roles:
//! * [`VectorCompressor`] — the QLSD* compressor of the Langevin app
//!   (caller-supplied RNG, transmitted per-vector norm);
//! * pipeline mean mechanism — the same scheme as an n-client aggregation
//!   baseline. The per-client ‖x‖∞ is *data*, not shared randomness: it
//!   travels in the message's `aux` slot, so the mechanism is NOT
//!   homomorphic and rides the Unicast transport.

use super::{CompressedVec, VectorCompressor};
use crate::mechanisms::pipeline::{
    impl_mean_mechanism, ClientEncoder, Descriptions, MechSpec, Payload, ServerDecoder,
    SharedRound, Unicast,
};
use crate::mechanisms::traits::BitsAccount;
use crate::quantizer::round_half_up;
use crate::util::rng::Rng;
use crate::util::stats::linf_norm;

#[derive(Clone, Copy, Debug)]
pub struct UnbiasedQuantizer {
    pub bits: u32,
}

impl UnbiasedQuantizer {
    pub fn new(bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 32);
        Self { bits }
    }

    /// grid step on the normalized [−1, 1] range
    pub fn step(&self) -> f64 {
        2.0 / ((1u64 << self.bits) - 1) as f64
    }
}

impl VectorCompressor for UnbiasedQuantizer {
    fn name(&self) -> String {
        format!("unbiased-quant(b={})", self.bits)
    }

    fn compress(&self, x: &[f64], rng: &mut Rng) -> CompressedVec {
        let scale = linf_norm(x);
        if scale == 0.0 {
            return CompressedVec { y: vec![0.0; x.len()], err_variance: 0.0, bits: 64.0 };
        }
        let w = self.step();
        let mut y = Vec::with_capacity(x.len());
        for &v in x {
            let u = rng.u01();
            let m = round_half_up(v / (scale * w) + u);
            y.push((m as f64 - u) * w * scale);
        }
        CompressedVec {
            y,
            err_variance: w * w / 12.0 * scale * scale,
            // b bits per coordinate + 32 bits for the shared norm
            bits: self.bits as f64 * x.len() as f64 + 32.0,
        }
    }
}

impl MechSpec for UnbiasedQuantizer {
    fn name(&self) -> String {
        VectorCompressor::name(self)
    }

    fn is_homomorphic(&self) -> bool {
        false // per-client norm scaling: descriptions don't share a grid
    }

    fn gaussian_noise(&self) -> bool {
        false // uniform quantization error
    }

    fn fixed_length(&self) -> bool {
        true
    }

    fn noise_sd(&self) -> f64 {
        0.0 // data-dependent error, no fixed aggregate target
    }
}

impl ClientEncoder for UnbiasedQuantizer {
    fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions {
        self.encode_chunk(client, x, 0..x.len(), round)
    }

    /// Chunk-ranged encode: the ℓ∞ norm is computed over the client's
    /// FULL vector (it is the client's own data), while coordinate j's
    /// dither comes from its seekable per-coordinate stream — so chunk
    /// encodes concatenate to the whole-vector encode bit for bit (the
    /// 32-bit norm transmission is accounted once, on the chunk starting
    /// at coordinate 0). NOTE: no transport can carry per-chunk unicast
    /// messages today — this mechanism rides [`Unicast`], which runs only
    /// under single-chunk plans — so partial ranges are exercised by the
    /// chunk-invariance unit test below and kept so the encoder is ready
    /// if a chunk-capable per-client transport lands.
    fn encode_chunk(
        &self,
        client: usize,
        x: &[f64],
        range: std::ops::Range<usize>,
        round: &SharedRound,
    ) -> Descriptions {
        let scale = linf_norm(x);
        let mut bits = BitsAccount::default();
        let norm_bits = if range.start == 0 { 32.0 } else { 0.0 };
        if scale == 0.0 {
            // nothing to send beyond the (zero) norm: 32 bits on both
            // accountings, same convention as the non-zero branch
            bits.variable_total += norm_bits;
            bits.fixed_total = Some(norm_bits);
            return Descriptions { ms: vec![0; range.len()], aux: vec![0.0], bits };
        }
        let w = self.step();
        let dither = round.client_coord_stream(client);
        let ms: Vec<i64> = range
            .clone()
            .map(|j| {
                let u = dither.at(j).u01();
                let m = round_half_up(x[j] / (scale * w) + u);
                bits.add_description(m);
                m
            })
            .collect();
        // 32 bits for the transmitted norm, on both accountings
        bits.variable_total += norm_bits;
        bits.fixed_total = Some(self.bits as f64 * range.len() as f64 + norm_bits);
        Descriptions { ms, aux: vec![scale], bits }
    }
}

impl ServerDecoder for UnbiasedQuantizer {
    fn sum_decodable(&self) -> bool {
        false
    }

    fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
        let n = round.n_clients;
        let d = round.dim;
        let w = self.step();
        let list = payload.per_client();
        assert_eq!(list.len(), n);
        let mut estimate = vec![0.0f64; d];
        for (i, (ms, aux)) in list.iter().enumerate() {
            let scale = aux[0];
            if scale == 0.0 {
                // the zero vector transmitted nothing (and its dither
                // streams were never touched)
                continue;
            }
            let dither = round.client_coord_stream(i);
            for (j, (ej, &m)) in estimate.iter_mut().zip(ms).enumerate() {
                let u = dither.at(j).u01();
                *ej += (m as f64 - u) * w * scale;
            }
        }
        for e in estimate.iter_mut() {
            *e /= n as f64;
        }
        estimate
    }
}

impl_mean_mechanism!(UnbiasedQuantizer, |_m| Unicast);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::traits::MeanMechanism;
    use crate::util::stats::{mean, variance};

    #[test]
    fn unbiased_and_variance_matches() {
        let q = UnbiasedQuantizer::new(4);
        let mut rng = Rng::new(111);
        let x: Vec<f64> = (0..64).map(|i| ((i * 37) % 100) as f64 / 25.0 - 2.0).collect();
        let mut errs = Vec::new();
        let mut var_claim = 0.0;
        for _ in 0..2000 {
            let c = q.compress(&x, &mut rng);
            var_claim = c.err_variance;
            for (yi, xi) in c.y.iter().zip(&x) {
                errs.push(yi - xi);
            }
        }
        assert!(mean(&errs).abs() < 5e-3, "bias {}", mean(&errs));
        assert!((variance(&errs) - var_claim).abs() / var_claim < 0.05);
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(112);
        let x: Vec<f64> = (0..32).map(|i| (i as f64).sin()).collect();
        let e4 = UnbiasedQuantizer::new(4).compress(&x, &mut rng).err_variance;
        let e8 = UnbiasedQuantizer::new(8).compress(&x, &mut rng).err_variance;
        assert!(e8 < e4 / 100.0);
    }

    #[test]
    fn zero_vector_exact() {
        let q = UnbiasedQuantizer::new(3);
        let mut rng = Rng::new(113);
        let c = q.compress(&[0.0; 5], &mut rng);
        assert_eq!(c.y, vec![0.0; 5]);
        assert_eq!(c.err_variance, 0.0);
    }

    #[test]
    fn mean_mechanism_is_unbiased() {
        // the pipeline port: averaged decode is an unbiased mean estimate
        let mut drng = Rng::new(114);
        let n = 40;
        let d = 6;
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| drng.uniform(-2.0, 2.0)).collect()).collect();
        let m = crate::mechanisms::traits::true_mean(&xs);
        let mech = UnbiasedQuantizer::new(6);
        let mut acc = vec![0.0; d];
        let rounds = 2000;
        for r in 0..rounds {
            let out = mech.aggregate(&xs, 500 + r);
            for j in 0..d {
                acc[j] += out.estimate[j];
            }
        }
        for j in 0..d {
            let avg = acc[j] / rounds as f64;
            assert!((avg - m[j]).abs() < 0.02, "j={j} avg={avg} want={}", m[j]);
        }
    }

    #[test]
    fn chunked_encode_concatenates_to_whole_encode() {
        // chunk encodes reproduce the whole-vector encode bit for bit —
        // descriptions, aux norm, and accounting (norm bits counted once)
        let d = 9usize;
        let mut drng = Rng::new(515);
        let x: Vec<f64> = (0..d).map(|_| drng.uniform(-3.0, 3.0)).collect();
        let q = UnbiasedQuantizer::new(5);
        let round = crate::mechanisms::pipeline::SharedRound::new(77, 3, d);
        let whole = q.encode(1, &x, &round);
        for c in [1usize, 4, d, d + 2] {
            let mut ms = Vec::new();
            let mut variable = 0.0;
            let mut fixed = 0.0;
            let mut messages = 0u64;
            let mut lo = 0;
            while lo < d {
                let hi = (lo + c).min(d);
                let part = q.encode_chunk(1, &x, lo..hi, &round);
                assert_eq!(part.aux, whole.aux, "norm travels with every chunk");
                ms.extend(part.ms);
                variable += part.bits.variable_total;
                fixed += part.bits.fixed_total.unwrap();
                messages += part.bits.messages;
                lo = hi;
            }
            assert_eq!(ms, whole.ms, "chunk {c}");
            assert_eq!(variable, whole.bits.variable_total);
            assert_eq!(fixed, whole.bits.fixed_total.unwrap());
            assert_eq!(messages, whole.bits.messages);
        }
        // the zero vector chunks consistently too
        let zeros = vec![0.0f64; d];
        let zwhole = q.encode(0, &zeros, &round);
        let z0 = q.encode_chunk(0, &zeros, 0..4, &round);
        let z1 = q.encode_chunk(0, &zeros, 4..d, &round);
        assert_eq!(z0.ms.len() + z1.ms.len(), zwhole.ms.len());
        assert_eq!(
            z0.bits.fixed_total.unwrap() + z1.bits.fixed_total.unwrap(),
            zwhole.bits.fixed_total.unwrap()
        );
    }

    #[test]
    fn mean_mechanism_handles_zero_clients_vectors() {
        let xs = vec![vec![0.0; 4], vec![1.0, -1.0, 0.5, 2.0]];
        let mech = UnbiasedQuantizer::new(5);
        let out = mech.aggregate(&xs, 9);
        assert_eq!(out.estimate.len(), 4);
        assert!(out.estimate.iter().all(|v| v.is_finite()));
        // only the non-zero client sent descriptions
        assert_eq!(out.bits.messages, 4);
    }

    #[test]
    fn property_flags() {
        let m: &dyn MeanMechanism = &UnbiasedQuantizer::new(8);
        assert!(!m.is_homomorphic());
        assert!(!m.gaussian_noise());
        assert!(m.fixed_length());
    }
}
