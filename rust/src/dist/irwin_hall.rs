//! Irwin–Hall IH(n, μ, σ): the centered sum of n iid U(−1/2, 1/2) scaled to
//! standard deviation σ and shifted to mean μ — the aggregate error law of
//! the shared-step dithered mechanism (§4.2) and the P of the Gaussian
//! decomposition (Algorithms 1–2).
//!
//! Density evaluation is the numerically delicate part:
//!
//! * n ≤ 16 — the exact piecewise-polynomial
//!   f(u) = (n−1)!⁻¹ Σ_k (−1)^k C(n,k)(u−k)₊^{n−1} with compensated
//!   summation (cancellation grows like C(n, n/2)(n/2)^{n−1}/(n−1)! ≈ 10⁵
//!   at n = 16 — still 10+ accurate digits in f64);
//! * n ≥ 17 — characteristic-function quadrature
//!   f_S(s) = (2/π)∫₀^T sinc(τ)ⁿ cos(2τs) dτ (sinc decays like a Gaussian
//!   of scale √(6/n), so T = max(1, 10^{18/n}) truncates below 1e−18),
//!   evaluated over the whole grid with a cosine rotation recurrence.
//!
//! Either way the density is tabulated once on a uniform grid (a
//! [`UniformGrid`] cubic interpolant) in standardized-sum coordinates
//! s ∈ [0, s_max]; pdf/cdf/derivative/superlevel queries interpolate. The
//! tail beyond 16 standard deviations (possible only for n ≥ 86) is
//! truncated — it sits below 1e−56, far under the 1e−7 floors every
//! consumer applies.

use super::{Continuous, Unimodal};
use crate::util::interp::{bisect_monotone, UniformGrid};
use crate::util::rng::Rng;

/// Largest n evaluated with the exact alternating sum.
const N_EXACT_MAX: u64 = 16;
/// Grid resolution (points on [0, s_max]).
const GRID_POINTS: usize = 2001;

#[derive(Clone, Debug)]
pub struct IrwinHall {
    pub n: u64,
    pub mean: f64,
    pub sd: f64,
    /// x = mean + s·scale maps standardized-sum coordinates to X
    scale: f64,
    /// density of the centered sum S = Σ(Uᵢ − 1/2) on s ∈ [0, s_max]
    grid: UniformGrid,
    /// cumulative ∫₀^{s_i} f_S, normalized so the last entry is exactly 1/2
    cum: Vec<f64>,
}

impl IrwinHall {
    pub fn new(n: u64, mean: f64, sd: f64) -> Self {
        assert!(n >= 1, "need at least one summand");
        assert!(sd > 0.0, "sd must be positive, got {sd}");
        let nf = n as f64;
        let scale = sd * (12.0 / nf).sqrt();
        // sum sd is √(n/12); truncate the grid at 16 sum-sds (only ever
        // shorter than the true support n/2 for n >= 86)
        let s_max = (nf / 2.0).min(16.0 * (nf / 12.0).sqrt());
        let dx = s_max / (GRID_POINTS - 1) as f64;
        let ys: Vec<f64> = if n <= N_EXACT_MAX {
            (0..GRID_POINTS).map(|i| exact_sum_density(n, i as f64 * dx)).collect()
        } else {
            let pts: Vec<f64> = (0..GRID_POINTS).map(|i| i as f64 * dx).collect();
            cf_sum_density(n, &pts)
        };
        let grid = UniformGrid::new(0.0, dx, ys);
        // cumulative trapezoid, then normalize the half-mass to exactly 1/2
        let mut cum = Vec::with_capacity(GRID_POINTS);
        let mut acc = 0.0f64;
        cum.push(0.0);
        for i in 1..GRID_POINTS {
            acc += 0.5 * (grid.y[i - 1] + grid.y[i]) * dx;
            cum.push(acc);
        }
        let half = cum[GRID_POINTS - 1].max(1e-300);
        for c in cum.iter_mut() {
            *c *= 0.5 / half;
        }
        Self { n, mean, sd, scale, grid, cum }
    }

    /// IH(n, 0, 1) — the standardized law used by the decomposition.
    pub fn standard(n: u64) -> Self {
        Self::new(n, 0.0, 1.0)
    }

    /// Half-width of the (true) support: σ√(3n).
    pub fn support_half_width(&self) -> f64 {
        self.sd * (3.0 * self.n as f64).sqrt()
    }

    /// Grid edge in standardized-sum coordinates.
    fn s_edge(&self) -> f64 {
        self.grid.x_max()
    }

    /// Density of the centered standardized sum at |s| (0 outside).
    fn sum_pdf(&self, s_abs: f64) -> f64 {
        if s_abs >= self.s_edge() {
            0.0
        } else {
            self.grid.eval(s_abs).max(0.0)
        }
    }

    /// d f_X / d x — used by the decomposition's λ computation.
    pub fn pdf_deriv(&self, x: f64) -> f64 {
        let s = (x - self.mean) / self.scale;
        let a = s.abs();
        if a >= self.s_edge() {
            return 0.0;
        }
        let d = self.grid.eval_deriv(a);
        let signed = if s >= 0.0 { d } else { -d };
        signed / (self.scale * self.scale)
    }

    /// E|X − μ| by quadrature of the tabulated density.
    pub fn mean_abs(&self) -> f64 {
        let dx = self.grid.dx;
        let mut acc = 0.0;
        for i in 1..self.grid.y.len() {
            let s0 = (i - 1) as f64 * dx;
            let s1 = i as f64 * dx;
            acc += 0.5 * (s0 * self.grid.y[i - 1] + s1 * self.grid.y[i]) * dx;
        }
        2.0 * acc * self.scale
    }
}

/// Exact density of the centered sum of n U(−1/2, 1/2) at s (n ≤ 16):
/// the alternating B-spline sum with Kahan compensation.
fn exact_sum_density(n: u64, s: f64) -> f64 {
    let nf = n as f64;
    if n == 1 {
        // discontinuous at the edges; the grid stores the interior value so
        // cubic interpolation stays exact inside the support
        return if s.abs() <= 0.5 { 1.0 } else { 0.0 };
    }
    let u = s + nf / 2.0;
    if u <= 0.0 || u >= nf {
        return 0.0;
    }
    // (n−1)! and C(n, k) are exact in f64 for n ≤ 16
    let mut fact = 1.0f64;
    for i in 1..n {
        fact *= i as f64;
    }
    let k_max = u.floor() as u64;
    let mut sum = 0.0f64;
    let mut comp = 0.0f64; // Kahan compensation
    let mut binom = 1.0f64; // C(n, k)
    for k in 0..=k_max.min(n) {
        let base = u - k as f64;
        let term = if base > 0.0 { base.powi(n as i32 - 1) } else { 0.0 };
        let signed = if k % 2 == 0 { binom * term } else { -binom * term };
        let y = signed - comp;
        let t = sum + y;
        comp = (t - sum) - y;
        sum = t;
        binom = binom * (n - k) as f64 / (k + 1) as f64;
    }
    (sum / fact).max(0.0)
}

/// Characteristic-function quadrature of the centered-sum density at every
/// grid point (n ≥ 17): f_S(s) = (2/π) ∫₀^T sinc(τ)ⁿ cos(2τs) dτ, Simpson
/// weights precomputed once and the cos(2τ_k s) stream generated with the
/// rotation recurrence (no trig in the inner loop).
fn cf_sum_density(n: u64, s_pts: &[f64]) -> Vec<f64> {
    let nf = n as f64;
    // T with |sinc(τ)|ⁿ < 1e−18 for τ ≥ T: |sinc| ≤ min(1, 1/τ)
    let t_max = (1e18f64.powf(1.0 / nf)).max(1.0);
    let s_big = s_pts.last().copied().unwrap_or(1.0);
    // resolve the cos oscillation (period π/s_big in τ) with ≥ ~40 points
    let mut panels = ((t_max * s_big * 2.0 / std::f64::consts::PI * 40.0).ceil() as usize)
        .clamp(1024, 20_000);
    if panels % 2 == 1 {
        panels += 1;
    }
    let dt = t_max / panels as f64;
    // Simpson-weighted CF samples w_k = c_k · sinc(τ_k)ⁿ · dt/3 · (2/π)
    let front = 2.0 / std::f64::consts::PI * dt / 3.0;
    let weights: Vec<f64> = (0..=panels)
        .map(|k| {
            let tau = k as f64 * dt;
            let sinc = if tau == 0.0 { 1.0 } else { tau.sin() / tau };
            let c = if k == 0 || k == panels {
                1.0
            } else if k % 2 == 1 {
                4.0
            } else {
                2.0
            };
            front * c * sinc.powi(n as i32)
        })
        .collect();
    s_pts
        .iter()
        .map(|&s| {
            // cos(2·dt·k·s) via the rotation recurrence
            let theta = 2.0 * dt * s;
            let c1 = theta.cos();
            let mut c_prev = 1.0f64; // cos(0)
            let mut c_cur = c1;
            let mut acc = weights[0]; // k = 0 term (cos = 1)
            for w in &weights[1..] {
                acc += w * c_cur;
                let c_next = 2.0 * c1 * c_cur - c_prev;
                c_prev = c_cur;
                c_cur = c_next;
            }
            acc.max(0.0)
        })
        .collect()
}

impl Continuous for IrwinHall {
    fn pdf(&self, x: f64) -> f64 {
        let s = ((x - self.mean) / self.scale).abs();
        self.sum_pdf(s) / self.scale
    }

    fn cdf(&self, x: f64) -> f64 {
        let s = (x - self.mean) / self.scale;
        let a = s.abs();
        let half = if a >= self.s_edge() {
            0.5
        } else {
            let pos = a / self.grid.dx;
            let i = (pos.floor() as usize).min(self.cum.len() - 2);
            let frac = pos - i as f64;
            self.cum[i] + frac * (self.cum[i + 1] - self.cum[i])
        };
        if s >= 0.0 {
            0.5 + half
        } else {
            0.5 - half
        }
    }

    fn sample(&self, rng: &mut Rng) -> f64 {
        let mut acc = 0.0f64;
        for _ in 0..self.n {
            acc += rng.u01();
        }
        self.mean + (acc - self.n as f64 / 2.0) * self.scale
    }
}

impl Unimodal for IrwinHall {
    fn mode(&self) -> f64 {
        self.mean
    }

    fn max_pdf(&self) -> f64 {
        self.sum_pdf(0.0) / self.scale
    }

    fn b_plus(&self, y: f64) -> f64 {
        // superlevel of f_X at y ↔ superlevel of f_S at y·scale
        let ys = y * self.scale;
        if self.n == 1 {
            // uniform: layers are the full support
            let r = if ys > self.sum_pdf(0.0) { 0.0 } else { 0.5 };
            return self.mean + r * self.scale;
        }
        if ys >= self.sum_pdf(0.0) {
            return self.mean;
        }
        let edge = self.s_edge();
        let edge_value = *self.grid.y.last().expect("non-empty grid");
        let s = if ys <= edge_value {
            // below the tabulated range (possible only when the grid is
            // tail-truncated, n >= 86): the true support edge
            self.n as f64 / 2.0
        } else {
            bisect_monotone(|s| self.sum_pdf(s), ys, 0.0, edge, true, 80)
        };
        self.mean + s * self.scale
    }

    fn b_minus(&self, y: f64) -> f64 {
        2.0 * self.mean - self.b_plus(y)
    }

    fn variance(&self) -> f64 {
        self.sd * self.sd
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{ks_test, variance};

    #[test]
    fn exact_matches_known_small_n() {
        // n = 2: triangle on [−1, 1] with apex 1
        assert!((exact_sum_density(2, 0.0) - 1.0).abs() < 1e-12);
        assert!((exact_sum_density(2, 0.5) - 0.5).abs() < 1e-12);
        assert!(exact_sum_density(2, 1.0).abs() < 1e-12);
        // n = 3: f(0) = 3/4 (sum of 3 uniforms at its mode)
        assert!((exact_sum_density(3, 0.0) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn cf_branch_agrees_with_exact_branch() {
        // the two evaluation paths must agree where both are accurate;
        // compare n = 16 exact vs the CF quadrature run at the same points
        let pts: Vec<f64> = (0..200).map(|i| i as f64 * 0.04).collect();
        let cf = cf_sum_density(16, &pts);
        for (i, &s) in pts.iter().enumerate() {
            let ex = exact_sum_density(16, s);
            assert!((cf[i] - ex).abs() < 2e-6, "s={s} cf={} exact={ex}", cf[i]);
        }
    }

    #[test]
    fn density_integrates_to_one_and_matches_gaussian_for_large_n() {
        for &n in &[2u64, 5, 17, 64, 300] {
            let ih = IrwinHall::standard(n);
            // mass via the cdf at the edges
            assert!((ih.cdf(ih.support_half_width()) - 1.0).abs() < 1e-9, "n={n}");
            assert!(ih.cdf(-ih.support_half_width()).abs() < 1e-9, "n={n}");
            // sd-1 law: pdf(0) → 1/√(2π) as n grows
            if n >= 64 {
                let want = 1.0 / (2.0 * std::f64::consts::PI).sqrt();
                assert!((ih.max_pdf() - want).abs() < 0.01 / (n as f64).sqrt() + 2e-3, "n={n}");
            }
        }
    }

    #[test]
    fn samples_match_cdf_and_moments() {
        for &n in &[1u64, 2, 3, 12, 40] {
            let ih = IrwinHall::new(n, 0.5, 1.4);
            let mut rng = Rng::new(600 + n);
            let xs: Vec<f64> = (0..6000).map(|_| ih.sample(&mut rng)).collect();
            let res = ks_test(&xs, |x| ih.cdf(x));
            assert!(res.p_value > 0.003, "n={n} p={}", res.p_value);
            assert!((variance(&xs) - 1.96).abs() < 0.15, "n={n}");
        }
    }

    #[test]
    fn superlevel_inverts_pdf() {
        for &n in &[2u64, 7, 25] {
            let ih = IrwinHall::standard(n);
            let zbar = ih.max_pdf();
            for i in 1..40 {
                let y = zbar * i as f64 / 41.0;
                let bp = ih.b_plus(y);
                assert!(
                    (ih.pdf(bp) - y).abs() < 1e-6 * zbar,
                    "n={n} y={y} pdf(b+)={}",
                    ih.pdf(bp)
                );
                assert!((ih.b_minus(y) - (2.0 * ih.mean - bp)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn support_half_width_formula() {
        let ih = IrwinHall::new(12, 0.0, 2.0);
        assert!((ih.support_half_width() - 2.0 * 6.0).abs() < 1e-12);
        assert!(ih.pdf(ih.support_half_width() + 0.1) == 0.0);
    }

    #[test]
    fn deriv_matches_finite_differences() {
        for &n in &[3u64, 30] {
            let ih = IrwinHall::standard(n);
            let h = 1e-5;
            for &x in &[0.3, 1.0, -0.7, 2.0] {
                let fd = (ih.pdf(x + h) - ih.pdf(x - h)) / (2.0 * h);
                let d = ih.pdf_deriv(x);
                assert!((fd - d).abs() < 1e-4 + 1e-3 * fd.abs(), "n={n} x={x} fd={fd} d={d}");
            }
        }
    }

    #[test]
    fn mean_abs_matches_monte_carlo() {
        let ih = IrwinHall::new(9, 0.0, 1.0);
        let mut rng = Rng::new(777);
        let mc: f64 =
            (0..100_000).map(|_| ih.sample(&mut rng).abs()).sum::<f64>() / 100_000.0;
        assert!((mc - ih.mean_abs()).abs() < 0.02, "mc={mc} quad={}", ih.mean_abs());
    }
}
