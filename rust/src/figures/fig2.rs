//! Figure 2: conditional entropy H(M|S) of the direct and shifted layered
//! quantizers with Gaussian / Laplace error, σ ∈ {1, 3}, input X ~ U(0, t)
//! for t = 2^1 .. 2^12 — plus the Eq. 4 lower bound log(t) + h(D_Z).

use super::FigOpts;
use crate::coding::entropy::cond_entropy_mc;
use crate::dist::{Gaussian, Laplace, Unimodal};
use crate::quantizer::{DirectLayered, PointQuantizer, ShiftedLayered};
use crate::util::json::Csv;
use crate::util::rng::Rng;

fn mc_entropy<Q: PointQuantizer>(q: &Q, t: f64, reps: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    cond_entropy_mc(t, reps, || {
        let s = q.draw(&mut rng);
        (s.step, s.dither)
    })
}

pub fn run(opts: &FigOpts) {
    println!("\n== Figure 2: H(M|S) of layered quantizers ==");
    let reps = opts.runs_or(400);
    let ks: Vec<u32> = if opts.quick { (1..=6).collect() } else { (1..=12).collect() };
    let mut csv = Csv::new(&[
        "t",
        "sigma",
        "gauss_direct",
        "gauss_shifted",
        "gauss_lower_bound",
        "laplace_direct",
        "laplace_shifted",
        "laplace_lower_bound",
    ]);
    println!(
        "{:>6} {:>5} {:>12} {:>13} {:>12} {:>13} {:>13} {:>13}",
        "t", "sigma", "gauss-direct", "gauss-shifted", "gauss-bound",
        "lap-direct", "lap-shifted", "lap-bound"
    );
    for &sigma in &[1.0f64, 3.0] {
        let g = Gaussian::new(0.0, sigma);
        let l = Laplace::with_sd(0.0, sigma);
        let gd = DirectLayered::new(g);
        let gs = ShiftedLayered::new(g);
        let ld = DirectLayered::new(l);
        let ls = ShiftedLayered::new(l);
        // Eq. 4 lower bound: log(t) + h(D_Z); h(D_Z) computed numerically
        let hd_g = g.layer_height_entropy();
        let hd_l = l.layer_height_entropy();
        for &k in &ks {
            let t = 2f64.powi(k as i32);
            let row = [
                t,
                sigma,
                mc_entropy(&gd, t, reps, opts.seed + k as u64),
                mc_entropy(&gs, t, reps, opts.seed + 100 + k as u64),
                t.log2() + hd_g,
                mc_entropy(&ld, t, reps, opts.seed + 200 + k as u64),
                mc_entropy(&ls, t, reps, opts.seed + 300 + k as u64),
                t.log2() + hd_l,
            ];
            println!(
                "{:>6} {:>5} {:>12.3} {:>13.3} {:>12.3} {:>13.3} {:>13.3} {:>13.3}",
                row[0], row[1], row[2], row[3], row[4], row[5], row[6], row[7]
            );
            csv.row_f64(&row);
        }
    }
    let path = format!("{}/fig2.csv", opts.out_dir);
    csv.save(&path).expect("saving fig2 csv");
    println!("saved {path}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direct_within_one_bit_of_lower_bound_large_t() {
        // Hegazy–Li: the direct layered quantizer is near-optimal; at
        // t = 256 the gap to log(t)+h(D_Z) must be < 1 bit (it is o(1))
        let g = Gaussian::new(0.0, 1.0);
        let q = DirectLayered::new(g);
        let t = 256.0;
        let h = mc_entropy(&q, t, 400, 9);
        let bound = t.log2() + g.layer_height_entropy();
        assert!(h >= bound - 0.05, "h={h} bound={bound}");
        assert!(h <= bound + 1.0, "h={h} bound={bound}");
    }

    #[test]
    fn shifted_gap_bounded_per_prop1() {
        // Prop. 1: optimality gap of shifted <= 8 log(e)/t·sd + 2; Fig. 2
        // shows the observed gap is < 1 bit
        let g = Gaussian::new(0.0, 3.0);
        let direct = DirectLayered::new(g);
        let shifted = ShiftedLayered::new(g);
        let t = 512.0;
        let hd = mc_entropy(&direct, t, 300, 11);
        let hs = mc_entropy(&shifted, t, 300, 12);
        assert!(hs >= hd - 0.1, "shifted {hs} below direct {hd}?");
        assert!(hs - hd < 1.0, "gap {} >= 1 bit", hs - hd);
    }

    #[test]
    fn entropy_grows_like_log_t() {
        let l = Laplace::with_sd(0.0, 1.0);
        let q = DirectLayered::new(l);
        let h1 = mc_entropy(&q, 64.0, 300, 13);
        let h2 = mc_entropy(&q, 128.0, 300, 13);
        assert!((h2 - h1 - 1.0).abs() < 0.15, "h2-h1={}", h2 - h1);
    }
}
