//! Appendix D: compression as randomized smoothing — objective traces of
//! plain distributed subgradient descent vs DRS where the broadcast model
//! is AINQ-compressed with a Gaussian error (the compressor IS the
//! smoother).

use super::FigOpts;
use crate::apps::smoothing::{drs_compressed, subgradient_descent, L1Problem, SmoothingOpts};
use crate::util::json::Csv;

pub fn run(opts: &FigOpts) {
    println!("\n== Appendix D: DRS-via-compression vs subgradient descent ==");
    let iters = if opts.quick { 200 } else { 2000 };
    let p = L1Problem::generate(120, 16, 8, opts.seed);
    let sg = subgradient_descent(
        &p,
        SmoothingOpts { iters, lr: 0.8, sigma: 0.0, m_samples: 1, seed: opts.seed },
    );
    let drs = drs_compressed(
        &p,
        SmoothingOpts { iters, lr: 0.25, sigma: 0.05, m_samples: 4, seed: opts.seed },
    );
    let mut csv = Csv::new(&["iter", "subgradient_obj", "drs_obj"]);
    println!("{:>8} {:>16} {:>12}", "iter", "subgradient f", "DRS f");
    for (a, b) in sg.iter().zip(&drs) {
        if a.0 % (iters / 10).max(1) == 0 {
            println!("{:>8} {:>16.5} {:>12.5}", a.0, a.1, b.1);
        }
        csv.row_f64(&[a.0 as f64, a.1, b.1]);
    }
    let (sa, sb) = (sg.last().unwrap().1, drs.last().unwrap().1);
    println!("final: subgradient {sa:.5}  DRS {sb:.5}");
    let path = format!("{}/appd.csv", opts.out_dir);
    csv.save(&path).expect("saving csv");
    println!("saved {path}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_arms_converge_and_drs_competitive() {
        let p = L1Problem::generate(60, 8, 4, 1);
        let sg = subgradient_descent(
            &p,
            SmoothingOpts { iters: 600, lr: 0.8, sigma: 0.0, m_samples: 1, seed: 2 },
        );
        let drs = drs_compressed(
            &p,
            SmoothingOpts { iters: 600, lr: 0.25, sigma: 0.05, m_samples: 4, seed: 2 },
        );
        let s0 = sg.first().unwrap().1;
        let s1 = sg.last().unwrap().1;
        let d1 = drs.last().unwrap().1;
        assert!(s1 < s0 * 0.5);
        assert!(d1 < s0 * 0.5);
    }
}
