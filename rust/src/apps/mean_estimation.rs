//! Distributed mean-estimation experiment harness: the workload generators
//! and MSE/bits evaluation behind Figures 5–9.
//!
//! Two evaluation paths, bit-identical at full cohort:
//! [`evaluate`] runs the monolithic [`MeanMechanism::aggregate`] in
//! process; [`evaluate_coordinator`] runs the same rounds through the
//! chunk-streamed coordinator ([`crate::apps::driver::AppCoordinator`])
//! with the client dataset held behind a
//! [`crate::mechanisms::pipeline::SliceCompute`] — each simulated client
//! "computes" its row per coordinate range, so no whole-(n×d) residue
//! crosses the orchestrator.

use std::sync::Arc;

use crate::apps::driver::{app_round_seed, AppCoordinator, CoordinatorOpts};
use crate::mechanisms::pipeline::SliceCompute;
use crate::mechanisms::traits::{true_mean, MeanMechanism};
use crate::util::rng::Rng;
use crate::util::stats::{l2_norm, OnlineStats};

/// Client-data generators used in the paper's experiments.
#[derive(Clone, Copy, Debug)]
pub enum DataKind {
    /// X_i(j) ~ (2·Bern(p) − 1)·U/√d with U ~ U(0,1) — the Fig. 5/7 data
    /// (Chen et al. 2023 protocol, continuous variant).
    BernoulliUniform { p: f64 },
    /// uniform on the ℓ2 sphere of the given radius — the Fig. 6/8 data.
    Sphere { radius: f64 },
    /// iid U(−c, c) per coordinate.
    BoxUniform { c: f64 },
}

/// Generate an (n × d) client dataset.
pub fn gen_data(kind: DataKind, n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    match kind {
        DataKind::BernoulliUniform { p } => (0..n)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        let sign = if rng.bernoulli(p) { 1.0 } else { -1.0 };
                        sign * rng.u01() / (d as f64).sqrt()
                    })
                    .collect()
            })
            .collect(),
        DataKind::Sphere { radius } => (0..n)
            .map(|_| {
                let v = rng.normal_vec(d);
                let nrm = l2_norm(&v).max(1e-12);
                v.into_iter().map(|x| x * radius / nrm).collect()
            })
            .collect(),
        DataKind::BoxUniform { c } => {
            (0..n).map(|_| (0..d).map(|_| rng.uniform(-c, c)).collect()).collect()
        }
    }
}

/// Aggregated evaluation of a mechanism over repeated runs.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub mse_mean: f64,
    pub mse_sem: f64,
    pub bits_var_per_client: f64,
    pub bits_fixed_per_client: Option<f64>,
    pub runs: usize,
}

/// Run `runs` independent rounds (fresh shared randomness each) and report
/// the MSE of the estimate vs the true mean plus bits/client.
pub fn evaluate(
    mech: &dyn MeanMechanism,
    xs: &[Vec<f64>],
    runs: usize,
    seed0: u64,
) -> EvalResult {
    let n = xs.len();
    let mean = true_mean(xs);
    let mut mse = OnlineStats::new();
    let mut bits_v = OnlineStats::new();
    let mut bits_f = OnlineStats::new();
    let mut any_fixed = true;
    for r in 0..runs {
        // run r IS round r of a coordinator session: same ROUND-domain
        // seed derivation, so evaluate() ≡ evaluate_coordinator() bit
        // for bit at full cohort.
        let out = mech.aggregate(xs, app_round_seed(seed0, r as u64));
        // squared l2 error of the d-dim estimate (the papers' MSE)
        let sq: f64 = out
            .estimate
            .iter()
            .zip(&mean)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        mse.push(sq);
        bits_v.push(out.bits.variable_per_client(n));
        match out.bits.fixed_per_client(n) {
            Some(b) => bits_f.push(b),
            None => any_fixed = false,
        }
    }
    EvalResult {
        mse_mean: mse.mean(),
        mse_sem: mse.sem(),
        bits_var_per_client: bits_v.mean(),
        bits_fixed_per_client: (any_fixed && bits_f.count() > 0).then(|| bits_f.mean()),
        runs,
    }
}

/// [`evaluate`], rewired onto the coordinator: the same `runs` rounds
/// (round r uses shared seed `derive_domain(seed0, ROUND, r)`), but each
/// client's vector is pulled per coordinate range from a
/// [`SliceCompute`] by the chunk-streamed (or async) runner instead of
/// being handed whole to `aggregate()`. At [`SamplingPolicy::Full`]
/// cohorts the two paths are bit-identical for every chunk size — the
/// property suite (`rust/tests/property_apps.rs`) pins this per
/// mechanism.
///
/// Sampled policies are the production shape: rounds whose cohort came up
/// empty are skipped in the MSE/bits averages (no estimate exists), which
/// matches how a deployment would treat an empty round.
///
/// [`SamplingPolicy::Full`]: crate::coordinator::sampling::SamplingPolicy::Full
pub fn evaluate_coordinator(
    mech: &dyn MeanMechanism,
    xs: &[Vec<f64>],
    runs: usize,
    seed0: u64,
    copts: CoordinatorOpts,
) -> EvalResult {
    let n = xs.len();
    let dim = xs[0].len();
    let mean = true_mean(xs);
    // Stream rows when the mechanism's encoder accepts chunk slices;
    // mechanisms that need the whole client vector (Ddg rotation,
    // ℓ∞-norm quantizers) get the materialized path, which the runners
    // select via `streams_chunks()`.
    let streams =
        mech.pipeline_parts().map_or(false, |p| p.encoder.slice_chunkable() && copts.chunk != 0);
    let compute = if streams {
        Arc::new(SliceCompute::streamed(xs))
    } else {
        Arc::new(SliceCompute::new(xs))
    };
    let mut coord = AppCoordinator::new(mech, compute, n, dim, copts);
    let state = vec![0.0f64; dim];
    let reports = coord.run_rounds(0, runs, &state, seed0);

    let mut mse = OnlineStats::new();
    let mut bits_v = OnlineStats::new();
    let mut bits_f = OnlineStats::new();
    let mut any_fixed = true;
    for rep in &reports {
        let cohort = rep.cohort;
        if cohort == 0 {
            continue;
        }
        let sq: f64 = rep
            .output
            .estimate
            .iter()
            .zip(&mean)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        mse.push(sq);
        bits_v.push(rep.output.bits.variable_per_client(cohort));
        match rep.output.bits.fixed_per_client(cohort) {
            Some(b) => bits_f.push(b),
            None => any_fixed = false,
        }
    }
    EvalResult {
        mse_mean: mse.mean(),
        mse_sem: mse.sem(),
        bits_var_per_client: bits_v.mean(),
        bits_fixed_per_client: (any_fixed && bits_f.count() > 0).then(|| bits_f.mean()),
        runs: mse.count() as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::{AggregateGaussian, IrwinHallMechanism};

    #[test]
    fn data_generators_respect_bounds() {
        let xs = gen_data(DataKind::BernoulliUniform { p: 0.8 }, 50, 100, 1);
        let bound = 1.0 / 10.0;
        for x in &xs {
            for &v in x {
                assert!(v.abs() <= bound + 1e-12);
            }
        }
        let xs = gen_data(DataKind::Sphere { radius: 10.0 }, 20, 75, 2);
        for x in &xs {
            assert!((l2_norm(x) - 10.0).abs() < 1e-9);
        }
    }

    #[test]
    fn bernoulli_data_biased_mean() {
        // p = 0.8 ⇒ positive mean ≈ (2p−1)·E[U]/√d = 0.3/√d
        let d = 64;
        let xs = gen_data(DataKind::BernoulliUniform { p: 0.8 }, 4000, d, 3);
        let m = true_mean(&xs);
        let want = 0.3 / (d as f64).sqrt();
        let avg = m.iter().sum::<f64>() / d as f64;
        assert!((avg - want).abs() < 0.1 * want, "avg={avg} want={want}");
    }

    #[test]
    fn evaluate_reports_noise_floor() {
        // MSE of an exact mechanism ≈ d·σ²
        let d = 8;
        let sigma = 0.2;
        let xs = gen_data(DataKind::BoxUniform { c: 2.0 }, 16, d, 4);
        let mech = AggregateGaussian::new(sigma, 4.0);
        let res = evaluate(&mech, &xs, 200, 5);
        let want = d as f64 * sigma * sigma;
        assert!((res.mse_mean - want).abs() < 4.0 * res.mse_sem + 0.1 * want,
                "mse={} want={want}", res.mse_mean);
        assert!(res.bits_var_per_client > 0.0);
    }

    #[test]
    fn evaluate_bits_reporting() {
        let xs = gen_data(DataKind::BoxUniform { c: 1.0 }, 8, 4, 6);
        let res = evaluate(&IrwinHallMechanism::new(0.5, 2.0), &xs, 10, 7);
        assert!(res.bits_fixed_per_client.is_some());
        assert_eq!(res.runs, 10);
    }
}
