//! Secure-aggregation simulation (Bonawitz et al. 2017): pairwise additive
//! masking over ℤ_m. Each ordered client pair (i, j), i < j, derives a
//! shared mask from a pairwise seed; client i adds it, client j subtracts
//! it, so the masks cancel in the sum and the server learns ONLY Σᵢ mᵢ.
//!
//! This is what makes the homomorphic mechanisms (Irwin–Hall, aggregate
//! Gaussian — Def. 6) deployable in the less-trusted-server setting of
//! §5.2: the server decodes from the masked sum without seeing any
//! individual description.
//!
//! ## Session-scoped mask schedule (batched multi-round SecAgg)
//!
//! Opening a masking session — in a real deployment the pairwise key
//! agreement and secret sharing — is the expensive part of SecAgg, and
//! high-frequency FL cannot afford to pay it every round. A
//! [`crate::mechanisms::session::TransportSession`] therefore opens ONE
//! session per window of W rounds and stretches a single *session seed*
//! into W per-round mask roots through the deterministic stream derivation
//! of [`crate::util::rng::Rng::derive`]:
//!
//! * [`session_mask_root`] — session seed → the schedule's root (one
//!   domain-separated derivation per window);
//! * [`round_mask_root`] — schedule root + round-in-window → that round's
//!   pairwise-mask root, from which [`mask_descriptions`] expands the
//!   per-pair ℤ_m streams.
//!
//! Mask expansion itself is *per coordinate* and seekable
//! ([`crate::util::rng::Rng::derive_coord`]): coordinate j's mask under a
//! pair seed depends only on (pair seed, j). The chunked pipeline
//! therefore masks, sums, and — on dropout — recovers one coordinate
//! chunk at a time ([`mask_descriptions_range`],
//! [`reconstruct_dropped_masks_range`]) in O(chunk) state, bit-identical
//! to whole-vector masking for every chunking.
//!
//! Every client and the server derive the identical schedule from the
//! session seed alone, so no per-round communication is needed, and
//! because each round's masks still cancel exactly over the full client
//! set, a windowed session remains bit-identical to independent
//! [`crate::mechanisms::pipeline::Plain`] rounds (property tested). Every
//! pipeline path rekeys through
//! [`crate::mechanisms::pipeline::Transport::for_session_round`] — a
//! single `run_pipeline` round is the W=1 session, with the round seed as
//! session seed. The legacy per-round derivation
//! ([`crate::mechanisms::pipeline::SecAgg::root_seed`]) applies only when
//! a `SecAgg` transport is driven stage-by-stage outside a session.
//!
//! ## Dropout recovery (Bonawitz-style pairwise-seed reconstruction)
//!
//! A client that goes silent mid-round leaves its pairwise masks
//! *uncancelled* in every survivor's submission: the masked survivor sum
//! carries the residual `Σ_{i∈S} ±PRG(s_ij)` for each dropped client j.
//! In the real protocol the survivors hold Shamir shares of j's pairwise
//! secrets and hand the server enough of them to re-expand those PRG
//! streams; this simulation keeps the same information flow with
//! [`RecoveryShare`] (a survivor reveals its pairwise seed with the
//! dropped client, [`recovery_share`]) and
//! [`reconstruct_dropped_masks`] (the server re-expands the dropped
//! client's outstanding mask legs over the survivor set and adds them
//! back, cancelling the residual exactly). Because the reconstruction is
//! restricted to *surviving* holders, pairs of two dropped clients —
//! whose masks appear in no submission — are correctly never expanded.
//! [`crate::mechanisms::session::TransportSession::close_with_dropouts`]
//! is the consumer; it fails closed unless every dropped client's share
//! set covers exactly the survivor set.

use crate::coding::packed::PackedZm;
use crate::util::rng::{fill_below_coords, Rng};

/// Stream tag separating the session mask schedule from every other use of
/// the session seed (client streams, global streams, round seeds).
const SESSION_MASK_STREAM: u64 = 0x5EC_A665;

/// Root of a session's ℤ_m mask schedule: one derivation per window of W
/// rounds — the simulation analogue of running the pairwise agreement once
/// per session instead of once per round.
pub fn session_mask_root(session_seed: u64) -> u64 {
    Rng::derive(session_seed, SESSION_MASK_STREAM).next_u64()
}

/// Pairwise-mask root for round `round_in_window` of a session window,
/// drawn from the schedule root's derived stream. Distinct rounds get
/// independent mask streams; both end-points re-derive it seed-only.
pub fn round_mask_root(session_root: u64, round_in_window: u64) -> u64 {
    Rng::derive(session_root, round_in_window).next_u64()
}

/// Modulus configuration for the masked integer field.
#[derive(Clone, Copy, Debug)]
pub struct SecAggParams {
    /// modulus m (must exceed the range of any honest sum)
    pub modulus: u64,
}

impl Default for SecAggParams {
    fn default() -> Self {
        Self { modulus: 1 << 40 }
    }
}

/// Map a signed description into ℤ_m.
#[inline]
pub fn to_field(v: i64, m: u64) -> u64 {
    v.rem_euclid(m as i64) as u64
}

/// Map a field element back to the signed representative in (−m/2, m/2].
#[inline]
pub fn from_field(v: u64, m: u64) -> i64 {
    if v > m / 2 {
        v as i64 - m as i64
    } else {
        v as i64
    }
}

/// Seed of the ordered pair (min(i,j), max(i,j))'s shared mask stream —
/// symmetric in (i, j), so both end-points (and a recovery holder) expand
/// the identical PRG stream. In a real deployment this is the pairwise
/// Diffie–Hellman secret; here it is a public derivation of the round's
/// mask root (the simulation models the *information flow*, not the
/// cryptography — see the module docs).
pub fn pair_seed(root: u64, i: usize, j: usize) -> u64 {
    // order-independent pairwise stream id
    let (a, b) = if i < j { (i, j) } else { (j, i) };
    root ^ ((a as u64) << 32 | b as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// One survivor's contribution to reconstructing a dropped client's
/// outstanding masks: the `holder` reveals its pairwise seed with
/// `dropped` (the simulation analogue of handing the server one's Shamir
/// share of the dropped client's pairwise secret).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecoveryShare {
    /// the dropped client this share helps reconstruct
    pub dropped: usize,
    /// the surviving client revealing the share
    pub holder: usize,
    /// the pairwise seed `s_{holder,dropped}` (see [`pair_seed`])
    pub pair_seed: u64,
}

/// Survivor-side: the recovery share `holder` reveals for `dropped` under
/// a given round mask root.
pub fn recovery_share(root_seed: u64, holder: usize, dropped: usize) -> RecoveryShare {
    assert_ne!(holder, dropped, "a client holds no recovery share for itself");
    RecoveryShare { dropped, holder, pair_seed: pair_seed(root_seed, holder, dropped) }
}

/// Reusable scratch for the lane-batched mask expansion: one pair leg's
/// worth of field elements. The masking and recovery hot paths fold
/// O(n_pairs) legs per chunk — reusing one buffer per caller (or per
/// thread, see [`mask_descriptions_range`]) caps the temporary
/// field-vector allocation at a single chunk-sized buffer instead of one
/// fresh `Vec` per (pair-leg, chunk).
#[derive(Clone, Debug, Default)]
pub struct MaskScratch {
    masks: Vec<u64>,
}

// The zero-argument public wrappers ([`mask_descriptions_range`],
// [`reconstruct_dropped_masks_range`]) serve the session masking path
// through the object-safe `Transport` trait, which has no scratch
// parameter and is called concurrently from the shard workers — a shared
// Mutex scratch would serialize them, so the wrapper scratch lives per
// worker thread instead.
thread_local! {
    static TL_SCRATCH: std::cell::RefCell<MaskScratch> =
        std::cell::RefCell::new(MaskScratch::default());
}

fn with_thread_scratch<R>(f: impl FnOnce(&mut MaskScratch) -> R) -> R {
    TL_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Expand one pairwise mask stream over coordinates `[lo, lo + out.len())`
/// and fold it into `out` (mod m) with the given sign — the shared core of
/// masking ([`mask_descriptions_range`]) and recovery
/// ([`reconstruct_dropped_masks_range`]).
///
/// The expansion is *seekable* per coordinate ([`Rng::derive_coord`]): the
/// mask of coordinate j depends only on (pair seed, j), never on how many
/// coordinates were expanded before it — so the chunked pipeline masks
/// (and recovers) only the active chunk's slice, bit-identical to
/// whole-vector masking for every chunking (see docs/determinism.md). The
/// expansion runs through the lane-batched
/// [`crate::util::rng::fill_below_coords`] kernel (Lemire threshold
/// hoisted, straight-line lane code), which is bit-identical to deriving a
/// fresh scalar generator per coordinate; the sign branch is hoisted out
/// of the per-coordinate loop.
fn fold_mask_stream(
    out: &mut [u64],
    pair_seed: u64,
    add: bool,
    m: u64,
    lo: usize,
    scratch: &mut MaskScratch,
) {
    let masks = &mut scratch.masks;
    masks.resize(out.len(), 0);
    fill_below_coords(pair_seed, lo as u64, m, masks);
    if add {
        for (o, &mask) in out.iter_mut().zip(masks.iter()) {
            *o = (*o + mask) % m;
        }
    } else {
        for (o, &mask) in out.iter_mut().zip(masks.iter()) {
            *o = (*o + m - mask) % m;
        }
    }
}

/// Server-side: re-expand dropped client `dropped`'s outstanding pairwise
/// mask legs over the share holders (mod m). Adding the result to the
/// masked survivor sum cancels exactly the residual masks the dropped
/// client left behind — this is what lets a round close over survivors
/// instead of aborting.
///
/// The caller is responsible for passing shares from exactly the survivor
/// set (the session layer enforces it); this function fails closed on
/// structurally bad bundles: a share for a different client, a holder
/// equal to the dropped client, or a duplicate holder all panic.
pub fn reconstruct_dropped_masks(
    dropped: usize,
    shares: &[RecoveryShare],
    d: usize,
    params: SecAggParams,
) -> Vec<u64> {
    reconstruct_dropped_masks_range(dropped, shares, 0, d, params)
}

/// [`reconstruct_dropped_masks`] for one coordinate chunk: re-expand only
/// the mask slice covering coordinates `[lo, lo + len)` — O(len) work and
/// state, the recovery path of the chunked session (each chunk of a round
/// with announced dropouts re-expands the dropped clients' legs for its
/// own range as it closes).
pub fn reconstruct_dropped_masks_range(
    dropped: usize,
    shares: &[RecoveryShare],
    lo: usize,
    len: usize,
    params: SecAggParams,
) -> Vec<u64> {
    let mut out = vec![0u64; len];
    with_thread_scratch(|scratch| {
        add_reconstructed_masks_range(&mut out, dropped, shares, lo, params, scratch)
    });
    out
}

/// [`reconstruct_dropped_masks_range`] folded DIRECTLY into an existing
/// field accumulator covering coordinates `[acc_lo, acc_lo + acc.len())`
/// — the session recovery path uses this to cancel a dropped client's
/// residual masks in place, with a caller-provided scratch, so closing a
/// chunk allocates no per-dropout reconstruction vector at all.
pub fn add_reconstructed_masks_range(
    acc: &mut [u64],
    dropped: usize,
    shares: &[RecoveryShare],
    acc_lo: usize,
    params: SecAggParams,
    scratch: &mut MaskScratch,
) {
    let m = params.modulus;
    let mut holders: Vec<usize> = Vec::with_capacity(shares.len());
    for share in shares {
        assert_eq!(
            share.dropped, dropped,
            "recovery share for client {} offered during reconstruction of client {dropped}",
            share.dropped,
        );
        assert_ne!(share.holder, dropped, "a client holds no recovery share for itself");
        assert!(
            !holders.contains(&share.holder),
            "duplicate recovery share from holder {} for dropped client {dropped}",
            share.holder,
        );
        holders.push(share.holder);
        // the dropped client's perspective of the pair (mirrors
        // `mask_descriptions`): it would have ADDED the stream for
        // higher-indexed peers and SUBTRACTED it for lower-indexed ones
        let add = dropped < share.holder;
        fold_mask_stream(acc, share.pair_seed, add, m, acc_lo, scratch);
    }
}

/// Fold one pairwise mask leg (client ↔ other) into an already-lifted
/// field vector covering coordinates `[lo, lo + out.len())`: `client`
/// ADDS the pair stream when it is the lower-indexed end, SUBTRACTS it
/// otherwise — the sign convention both [`mask_descriptions_range`] and
/// [`reconstruct_dropped_masks_range`] mirror. The pair seed is derived
/// once per leg; the per-coordinate expansion is the lane-batched
/// [`fold_mask_stream`].
fn fold_pair_leg(
    out: &mut [u64],
    client: usize,
    other: usize,
    root_seed: u64,
    m: u64,
    lo: usize,
    scratch: &mut MaskScratch,
) {
    let ps = pair_seed(root_seed, client, other);
    fold_mask_stream(out, ps, client < other, m, lo, scratch);
}

/// Client-side masking: add `Σ_{j>i} PRG_ij − Σ_{j<i} PRG_ij` (mod m) to
/// each coordinate of the description vector.
pub fn mask_descriptions(
    ms: &[i64],
    client: usize,
    n_clients: usize,
    root_seed: u64,
    params: SecAggParams,
) -> Vec<u64> {
    mask_descriptions_range(ms, client, n_clients, root_seed, params, 0)
}

/// [`mask_descriptions`] for one coordinate chunk: `ms` holds the
/// descriptions of coordinates `[lo, lo + ms.len())` and the masks are the
/// per-coordinate expansions for exactly that slice — O(chunk) work per
/// pair leg, and bit-identical to the corresponding slice of the
/// whole-vector masking for any chunking.
pub fn mask_descriptions_range(
    ms: &[i64],
    client: usize,
    n_clients: usize,
    root_seed: u64,
    params: SecAggParams,
    lo: usize,
) -> Vec<u64> {
    with_thread_scratch(|scratch| {
        mask_descriptions_range_scratch(ms, client, n_clients, root_seed, params, lo, scratch)
    })
}

/// [`mask_descriptions_range`] with a caller-provided scratch buffer —
/// the allocation-capped form for callers that mask many chunks (the
/// zero-argument wrapper reuses a per-thread scratch for the `Transport`
/// trait path, which cannot thread one through).
pub fn mask_descriptions_range_scratch(
    ms: &[i64],
    client: usize,
    n_clients: usize,
    root_seed: u64,
    params: SecAggParams,
    lo: usize,
    scratch: &mut MaskScratch,
) -> Vec<u64> {
    let m = params.modulus;
    let mut out: Vec<u64> = ms.iter().map(|&v| to_field(v, m)).collect();
    for other in 0..n_clients {
        if other == client {
            continue;
        }
        fold_pair_leg(&mut out, client, other, root_seed, m, lo, scratch);
    }
    out
}

/// [`mask_descriptions`] restricted to an explicit member set: masks pair
/// only among `members` (global client ids, strictly increasing), so the
/// masks cancel over the *members'* sum. This is the client-sampling
/// schedule — a round's cohort is known when the session opens, cohort
/// members agree pairwise among themselves, and sampled-out clients hold
/// no mask legs at all (nothing to recover if one of them would have
/// dropped). Panics (fail closed) if `client` is not itself a member — a
/// sampled-out client must not submit — or if `members` is not strictly
/// increasing: a duplicated id would fold one pair leg twice and leave an
/// uncancelled mask in the aggregate instead of an error.
pub fn mask_descriptions_among(
    ms: &[i64],
    client: usize,
    members: &[usize],
    root_seed: u64,
    params: SecAggParams,
) -> Vec<u64> {
    mask_descriptions_among_range(ms, client, members, root_seed, params, 0)
}

/// [`mask_descriptions_among`] for one coordinate chunk (see
/// [`mask_descriptions_range`] for the chunk semantics).
pub fn mask_descriptions_among_range(
    ms: &[i64],
    client: usize,
    members: &[usize],
    root_seed: u64,
    params: SecAggParams,
    lo: usize,
) -> Vec<u64> {
    assert!(
        members.windows(2).all(|w| w[0] < w[1]),
        "cohort member list must be strictly increasing (sorted, duplicate-free)"
    );
    assert!(
        members.contains(&client),
        "fails closed: client {client} masks as a cohort member but is sampled out"
    );
    let m = params.modulus;
    let mut out: Vec<u64> = ms.iter().map(|&v| to_field(v, m)).collect();
    with_thread_scratch(|scratch| {
        for &other in members {
            if other == client {
                continue;
            }
            fold_pair_leg(&mut out, client, other, root_seed, m, lo, scratch);
        }
    });
    out
}

/// [`mask_descriptions_range`] straight into the packed ℤ_m wire format:
/// the masked field vector leaves this function at its true
/// ⌈log₂ m⌉-bit width ([`crate::coding::packed::PackedZm`]). Packing is
/// a pure re-layout AFTER every mask draw, so the packed payload decodes
/// to the exact field vector the u64 path produces (bit identity;
/// docs/determinism.md, "Packed words cannot change any drawn bit").
pub fn mask_descriptions_range_packed(
    ms: &[i64],
    client: usize,
    n_clients: usize,
    root_seed: u64,
    params: SecAggParams,
    lo: usize,
) -> PackedZm {
    PackedZm::from_residues(
        &mask_descriptions_range(ms, client, n_clients, root_seed, params, lo),
        params.modulus,
    )
}

/// Bonawitz recovery over a PACKED accumulator: unpack the O(c) chunk
/// slot to u64 scratch once, fold every announced dropout's
/// reconstructed mask legs via [`add_reconstructed_masks_range`] (the
/// proven path — arithmetic never runs on packed words), and repack.
/// `dropped_shares` carries each dropped client with the survivor shares
/// offered for it; `acc_lo` is the accumulator's coordinate offset.
pub fn add_reconstructed_masks_packed(
    acc: &mut PackedZm,
    dropped_shares: &[(usize, Vec<RecoveryShare>)],
    acc_lo: usize,
    params: SecAggParams,
    scratch: &mut MaskScratch,
) {
    assert_eq!(
        acc.modulus(),
        params.modulus,
        "packed accumulator modulus disagrees with the recovery params"
    );
    if dropped_shares.is_empty() {
        return;
    }
    let mut residues = acc.to_residues();
    for (dropped, shares) in dropped_shares {
        add_reconstructed_masks_range(&mut residues, *dropped, shares, acc_lo, params, scratch);
    }
    *acc = PackedZm::from_residues(&residues, params.modulus);
}

/// Server-side: sum masked vectors mod m; masks cancel, leaving Σ ms.
pub fn aggregate_masked(masked: &[Vec<u64>], params: SecAggParams) -> Vec<i64> {
    assert!(!masked.is_empty());
    let m = params.modulus;
    let d = masked[0].len();
    let mut sum = vec![0u64; d];
    for mv in masked {
        assert_eq!(mv.len(), d);
        for (s, &v) in sum.iter_mut().zip(mv) {
            *s = (*s + v) % m;
        }
    }
    sum.into_iter().map(|v| from_field(v, m)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_roundtrip() {
        let m = 1 << 20;
        for v in [-1000i64, -1, 0, 1, 523_287] {
            assert_eq!(from_field(to_field(v, m), m), v);
        }
    }

    #[test]
    fn masks_cancel_exactly() {
        let params = SecAggParams::default();
        let n = 7;
        let d = 16;
        let mut rng = Rng::new(101);
        let descriptions: Vec<Vec<i64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.below(2000) as i64 - 1000).collect())
            .collect();
        let masked: Vec<Vec<u64>> = (0..n)
            .map(|i| mask_descriptions(&descriptions[i], i, n, 0xFEED, params))
            .collect();
        let agg = aggregate_masked(&masked, params);
        for j in 0..d {
            let want: i64 = descriptions.iter().map(|m| m[j]).sum();
            assert_eq!(agg[j], want, "j={j}");
        }
    }

    #[test]
    fn packed_masking_is_the_unpacked_masking_relaid() {
        // the packed producer must be the unpacked producer followed by a
        // pure re-layout — every residue, every modulus shape, offset or not
        for modulus in [1u64 << 8, 1 << 12, 1 << 40, 999_983] {
            let params = SecAggParams { modulus };
            let (n, d) = (5usize, 23usize);
            let mut rng = Rng::new(0xACC ^ modulus);
            let ms: Vec<i64> = (0..d).map(|_| rng.below(11) as i64 - 5).collect();
            for lo in [0usize, 7] {
                let unpacked = mask_descriptions_range(&ms, 2, n, 0xFEED, params, lo);
                let packed = mask_descriptions_range_packed(&ms, 2, n, 0xFEED, params, lo);
                assert_eq!(
                    packed,
                    PackedZm::from_residues(&unpacked, modulus),
                    "modulus={modulus} lo={lo}"
                );
                assert_eq!(packed.to_residues(), unpacked);
                assert_eq!(packed.byte_len(), PackedZm::byte_len_for(d, modulus));
            }
        }
    }

    #[test]
    fn packed_recovery_matches_unpacked_recovery() {
        // survivors' masked sum, two announced dropouts (one pair among
        // the dropped — its legs appear in no submission and must never
        // be expanded): the packed one-unpack-fold-repack recovery must
        // land on exactly the residues of the proven u64 recovery
        let params = SecAggParams::default();
        let (n, d) = (6usize, 17usize);
        let root = 0x5EC0_4E3;
        let dropped = [1usize, 4];
        let survivors: Vec<usize> = (0..n).filter(|i| !dropped.contains(i)).collect();
        let mut rng = Rng::new(0xD0_0D);
        let descriptions: Vec<Vec<i64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.below(2000) as i64 - 1000).collect())
            .collect();
        let m = params.modulus;
        let mut acc = vec![0u64; d];
        for &i in &survivors {
            let masked = mask_descriptions(&descriptions[i], i, n, root, params);
            for (a, v) in acc.iter_mut().zip(masked) {
                *a = (*a + v) % m;
            }
        }
        let mut packed = PackedZm::from_residues(&acc, m);
        let dropped_shares: Vec<(usize, Vec<RecoveryShare>)> = dropped
            .iter()
            .map(|&j| (j, survivors.iter().map(|&i| recovery_share(root, i, j)).collect()))
            .collect();
        let mut scratch = MaskScratch::default();
        for (j, shares) in &dropped_shares {
            add_reconstructed_masks_range(&mut acc, *j, shares, 0, params, &mut scratch);
        }
        add_reconstructed_masks_packed(&mut packed, &dropped_shares, 0, params, &mut scratch);
        assert_eq!(packed.to_residues(), acc);
        // and the residual masks cancelled: the signed lift is the
        // survivors' exact sum
        for k in 0..d {
            let want: i64 = survivors.iter().map(|&i| descriptions[i][k]).sum();
            assert_eq!(from_field(packed.get(k), m), want, "k={k}");
        }
    }

    #[test]
    fn packed_recovery_with_no_dropouts_is_a_no_op() {
        let params = SecAggParams::default();
        let residues: Vec<u64> = (0..9).map(|k| k * 31 % params.modulus).collect();
        let mut packed = PackedZm::from_residues(&residues, params.modulus);
        let before = packed.clone();
        let mut scratch = MaskScratch::default();
        add_reconstructed_masks_packed(&mut packed, &[], 0, params, &mut scratch);
        assert_eq!(packed, before);
    }

    #[test]
    fn single_masked_vector_reveals_nothing_obvious() {
        // a masked vector is (statistically) uniform: its empirical mean
        // over Z_m is near m/2 regardless of the plaintext
        let params = SecAggParams { modulus: 1 << 30 };
        let d = 4096;
        let ms = vec![3i64; d];
        let masked = mask_descriptions(&ms, 0, 3, 0xBEEF, params);
        let mean = masked.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let half = (params.modulus / 2) as f64;
        assert!((mean - half).abs() < 0.05 * params.modulus as f64, "mean={mean}");
    }

    #[test]
    fn negative_sums_supported() {
        let params = SecAggParams::default();
        let n = 3;
        let descriptions = vec![vec![-5i64], vec![-7], vec![2]];
        let masked: Vec<Vec<u64>> = (0..n)
            .map(|i| mask_descriptions(&descriptions[i], i, n, 7, params))
            .collect();
        assert_eq!(aggregate_masked(&masked, params), vec![-10]);
    }

    #[test]
    fn session_schedule_is_deterministic_and_per_round_distinct() {
        let root = session_mask_root(0xABCD);
        assert_eq!(root, session_mask_root(0xABCD));
        assert_ne!(root, session_mask_root(0xABCE));
        let r0 = round_mask_root(root, 0);
        let r1 = round_mask_root(root, 1);
        assert_eq!(r0, round_mask_root(root, 0));
        assert_ne!(r0, r1);
        // schedule roots feed the same masking primitive: masks still cancel
        let params = SecAggParams::default();
        let descriptions = vec![vec![4i64, -9], vec![1, 1], vec![-3, 7]];
        let masked: Vec<Vec<u64>> = (0..3)
            .map(|i| mask_descriptions(&descriptions[i], i, 3, r0, params))
            .collect();
        assert_eq!(aggregate_masked(&masked, params), vec![2, -1]);
    }

    #[test]
    fn cohort_masks_cancel_over_the_member_sum() {
        // masks exchanged among an arbitrary member set cancel over that
        // set's sum — the client-sampling analogue of masks_cancel_exactly
        let params = SecAggParams::default();
        let members = [0usize, 2, 3, 6];
        let d = 10;
        let mut rng = Rng::new(404);
        let descriptions: Vec<Vec<i64>> = (0..7)
            .map(|_| (0..d).map(|_| rng.below(2000) as i64 - 1000).collect())
            .collect();
        let m = params.modulus;
        let mut sum = vec![0u64; d];
        for &i in &members {
            let masked =
                mask_descriptions_among(&descriptions[i], i, &members, 0xC0607, params);
            for (s, v) in sum.iter_mut().zip(masked) {
                *s = (*s + v) % m;
            }
        }
        let got: Vec<i64> = sum.into_iter().map(|v| from_field(v, m)).collect();
        for j in 0..d {
            let want: i64 = members.iter().map(|&i| descriptions[i][j]).sum();
            assert_eq!(got[j], want, "j={j}");
        }
    }

    #[test]
    fn cohort_masking_over_full_fleet_matches_unsampled_masking() {
        let params = SecAggParams::default();
        let all: Vec<usize> = (0..5).collect();
        let ms = vec![7i64, -2, 0, 991];
        for client in 0..5 {
            assert_eq!(
                mask_descriptions_among(&ms, client, &all, 0xF00, params),
                mask_descriptions(&ms, client, 5, 0xF00, params),
            );
        }
    }

    #[test]
    #[should_panic(expected = "sampled out")]
    fn sampled_out_client_cannot_mask_into_the_cohort() {
        let _ = mask_descriptions_among(&[1], 4, &[0, 1, 2], 9, SecAggParams::default());
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn duplicate_cohort_member_fails_closed_instead_of_double_masking() {
        // a duplicated id would fold the (0,1) leg twice for client 0 but
        // once for client 1 — an uncancelled mask, caught at the API edge
        let _ = mask_descriptions_among(&[1], 0, &[0, 1, 1], 9, SecAggParams::default());
    }

    #[test]
    fn chunked_mask_ranges_concatenate_to_whole_masking() {
        // per-coordinate mask expansion: masking chunk [lo, hi) produces
        // exactly the slice of the whole-vector masking, for any chunking
        let params = SecAggParams::default();
        let ms: Vec<i64> = (0..11).map(|i| 3 * i - 16).collect();
        let whole = mask_descriptions(&ms, 1, 4, 0xAB, params);
        for c in [1usize, 3, 11, 14] {
            let mut got = Vec::new();
            let mut lo = 0;
            while lo < ms.len() {
                let hi = (lo + c).min(ms.len());
                got.extend(mask_descriptions_range(&ms[lo..hi], 1, 4, 0xAB, params, lo));
                lo = hi;
            }
            assert_eq!(got, whole, "chunk size {c}");
        }
        // the cohort variant slices identically
        let members = [0usize, 1, 3];
        let whole_c = mask_descriptions_among(&ms, 1, &members, 0xAB, params);
        let mut got = Vec::new();
        for lo in (0..ms.len()).step_by(4) {
            let hi = (lo + 4).min(ms.len());
            got.extend(mask_descriptions_among_range(
                &ms[lo..hi], 1, &members, 0xAB, params, lo,
            ));
        }
        assert_eq!(got, whole_c);
    }

    #[test]
    fn chunked_recovery_ranges_concatenate_to_whole_reconstruction() {
        let params = SecAggParams::default();
        let shares = [recovery_share(9, 0, 2), recovery_share(9, 1, 2)];
        let d = 10;
        let whole = reconstruct_dropped_masks(2, &shares, d, params);
        for c in [1usize, 4, 10] {
            let mut got = Vec::new();
            let mut lo = 0;
            while lo < d {
                let len = c.min(d - lo);
                got.extend(reconstruct_dropped_masks_range(2, &shares, lo, len, params));
                lo += len;
            }
            assert_eq!(got, whole, "chunk size {c}");
        }
    }

    #[test]
    fn batched_masking_matches_scalar_per_coordinate_expansion() {
        // the lane-batched fold must reproduce the definitional scalar
        // expansion: a fresh derive_coord(pair_seed, j).below(m) per
        // (leg, coordinate), folded with the i<j sign convention
        let params = SecAggParams::default();
        let m = params.modulus;
        let root = 0x1234_5678;
        let (client, n) = (2usize, 5usize);
        let ms: Vec<i64> = (0..19).map(|i| 11 * i - 90).collect();
        for lo in [0usize, 1, 9] {
            let mut want: Vec<u64> = ms.iter().map(|&v| to_field(v, m)).collect();
            for other in 0..n {
                if other == client {
                    continue;
                }
                let ps = pair_seed(root, client, other);
                let add = client < other;
                for (k, o) in want.iter_mut().enumerate() {
                    let mask = Rng::derive_coord(ps, (lo + k) as u64).below(m);
                    *o = if add { (*o + mask) % m } else { (*o + m - mask) % m };
                }
            }
            assert_eq!(
                mask_descriptions_range(&ms, client, n, root, params, lo),
                want,
                "lo={lo}"
            );
        }
    }

    #[test]
    fn scratch_variants_match_wrappers_and_reuse_the_buffer() {
        let params = SecAggParams::default();
        let ms = vec![5i64, -3, 77, 0, -1];
        let mut scratch = MaskScratch::default();
        for lo in [0usize, 4] {
            assert_eq!(
                mask_descriptions_range_scratch(&ms, 1, 6, 0xAB, params, lo, &mut scratch),
                mask_descriptions_range(&ms, 1, 6, 0xAB, params, lo),
            );
        }
        // in-place recovery fold equals reconstruct-then-add
        let shares = [recovery_share(9, 0, 2), recovery_share(9, 1, 2)];
        let m = params.modulus;
        let mut acc: Vec<u64> = (0..7u64).map(|v| v * 1000 % m).collect();
        let mut want = acc.clone();
        for (a, r) in
            want.iter_mut().zip(reconstruct_dropped_masks_range(2, &shares, 3, 7, params))
        {
            *a = (*a + r) % m;
        }
        add_reconstructed_masks_range(&mut acc, 2, &shares, 3, params, &mut scratch);
        assert_eq!(acc, want);
    }

    #[test]
    fn different_roots_different_masks() {
        let params = SecAggParams::default();
        let a = mask_descriptions(&[0; 8], 0, 2, 1, params);
        let b = mask_descriptions(&[0; 8], 0, 2, 2, params);
        assert_ne!(a, b);
    }

    /// The recovery identity: survivor submissions + reconstructed masks
    /// of every dropped client = Σ over survivors — even with multiple
    /// dropouts (whose mutual pair masks must NOT be expanded).
    #[test]
    fn dropout_recovery_cancels_residual_masks() {
        let params = SecAggParams::default();
        let n = 7;
        let d = 12;
        let root = 0xFACE;
        let dropped = [1usize, 4];
        let survivors: Vec<usize> =
            (0..n).filter(|c| !dropped.contains(c)).collect();
        let mut rng = Rng::new(909);
        let descriptions: Vec<Vec<i64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.below(2000) as i64 - 1000).collect())
            .collect();
        // survivors mask against the FULL fleet (they cannot know who will
        // drop) and the server folds only their submissions
        let m = params.modulus;
        let mut sum = vec![0u64; d];
        for &i in &survivors {
            let masked = mask_descriptions(&descriptions[i], i, n, root, params);
            for (s, v) in sum.iter_mut().zip(masked) {
                *s = (*s + v) % m;
            }
        }
        // recovery: every survivor reveals its pairwise seed per dropout
        for &j in &dropped {
            let shares: Vec<RecoveryShare> =
                survivors.iter().map(|&i| recovery_share(root, i, j)).collect();
            let rec = reconstruct_dropped_masks(j, &shares, d, params);
            for (s, v) in sum.iter_mut().zip(rec) {
                *s = (*s + v) % m;
            }
        }
        let got: Vec<i64> = sum.into_iter().map(|v| from_field(v, m)).collect();
        for k in 0..d {
            let want: i64 = survivors.iter().map(|&i| descriptions[i][k]).sum();
            assert_eq!(got[k], want, "k={k}");
        }
    }

    #[test]
    fn dropout_recovery_share_is_pair_symmetric() {
        // the holder's revealed seed equals the seed the dropped client
        // would have used — both expand the same stream
        let root = 0xB0B;
        assert_eq!(recovery_share(root, 2, 5).pair_seed, pair_seed(root, 5, 2));
        assert_eq!(recovery_share(root, 5, 2).pair_seed, pair_seed(root, 2, 5));
    }

    #[test]
    #[should_panic(expected = "duplicate recovery share")]
    fn dropout_duplicate_holder_share_rejected() {
        let params = SecAggParams::default();
        let shares = [recovery_share(1, 0, 2), recovery_share(1, 0, 2)];
        let _ = reconstruct_dropped_masks(2, &shares, 4, params);
    }

    #[test]
    #[should_panic(expected = "offered during reconstruction")]
    fn dropout_share_for_other_client_rejected() {
        let params = SecAggParams::default();
        let shares = [recovery_share(1, 0, 3)];
        let _ = reconstruct_dropped_masks(2, &shares, 4, params);
    }
}
