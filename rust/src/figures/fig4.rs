//! Figure 4: communication cost per client (bits) vs number of clients n
//! for the aggregate Gaussian, individual Gaussian (direct layered), and
//! Irwin–Hall mechanisms; σ = 1, inputs in [−2⁵, 2⁵] (a) and [−2¹⁰, 2¹⁰]
//! (b). Bounds computed per Theorems 1–2 plus Eq. 5; we also report
//! *measured* Elias-gamma bits to validate the bound shapes.

use super::FigOpts;
use crate::apps::mean_estimation::{evaluate, gen_data, DataKind};
use crate::dist::{Continuous, Gaussian, IrwinHall, Unimodal};
use crate::mechanisms::{AggregateGaussian, Decomposer, IndividualGaussian, IrwinHallMechanism, LayeredVariant};
use crate::util::json::Csv;

/// Theorem 1 bound with the Theorem 2 lower bound on h_M(Q‖P), plus the
/// measured E[−log|A|] version (our constructive mixture).
fn aggregate_bound(n: u64, sigma: f64, t: f64, neg_log_a: f64) -> f64 {
    let p = IrwinHall::new(n, 0.0, sigma);
    let q = Gaussian::new(0.0, sigma);
    let w_term = (t / (2.0 * sigma * (3.0 * n as f64).sqrt())).log2();
    let ratio = q.mean_abs() / p.mean_abs();
    neg_log_a + w_term + 6.0 * sigma * (3.0 * n as f64).sqrt() * std::f64::consts::LOG2_E / t * ratio + 1.0
}

/// Eq. 5 bound for the n-client individual (direct) Gaussian mechanism:
/// per-client error N(0, nσ²), H(M|S) <= log t + (8 log e)/t·√(nσ²) + h(D).
fn individual_bound(n: u64, sigma: f64, t: f64) -> f64 {
    let per = Gaussian::new(0.0, sigma * (n as f64).sqrt());
    t.log2() + 8.0 * std::f64::consts::LOG2_E / t * per.variance().sqrt() + per.layer_height_entropy()
}

/// Fixed-length cost of the Irwin–Hall mechanism: ceil(log2(2 + t/w)).
fn irwin_hall_bound(n: u64, sigma: f64, t: f64) -> f64 {
    let w = 2.0 * sigma * (3.0 * n as f64).sqrt();
    (2.0 + t / w).log2().ceil().max(1.0)
}

pub fn run(opts: &FigOpts) {
    println!("\n== Figure 4: bits/client vs n (sigma=1) ==");
    let sigma = 1.0;
    let ks: Vec<u32> = if opts.quick { vec![0, 2, 4, 6, 8] } else { (0..=13).collect() };
    let runs = opts.runs_or(8);
    for (panel, t) in [("a", 2f64.powi(6)), ("b", 2f64.powi(11))] {
        let mut csv = Csv::new(&[
            "n",
            "aggregate_bound",
            "aggregate_measured",
            "individual_bound",
            "individual_measured",
            "irwin_hall_bound",
            "irwin_hall_measured",
        ]);
        println!("-- panel ({panel}): x in [-{0}, {0}] --", t / 2.0);
        println!(
            "{:>6} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "n", "agg-bnd", "agg-meas", "ind-bnd", "ind-meas", "ih-bnd", "ih-meas"
        );
        for &k in &ks {
            let n = 1usize << k;
            let neg_log_a = Decomposer::new(n as u64)
                .expected_neg_log_a(if opts.quick { 300 } else { 1500 }, opts.seed + k as u64);
            let b_agg = aggregate_bound(n as u64, sigma, t, neg_log_a);
            let b_ind = individual_bound(n as u64, sigma, t);
            let b_ih = irwin_hall_bound(n as u64, sigma, t);

            // measured: a few aggregation rounds on U(-t/2, t/2) data
            let d = 16;
            let xs = gen_data(DataKind::BoxUniform { c: t / 2.0 }, n, d, opts.seed + 7 * k as u64);
            let m_agg = evaluate(&AggregateGaussian::new(sigma, t), &xs, runs, opts.seed)
                .bits_var_per_client
                / d as f64;
            let m_ih = evaluate(&IrwinHallMechanism::new(sigma, t), &xs, runs, opts.seed)
                .bits_var_per_client
                / d as f64;
            // individual direct measured only for moderate n (cost grows n·d)
            let m_ind = if n <= 1024 {
                evaluate(
                    &IndividualGaussian::new(sigma, LayeredVariant::Direct, t),
                    &xs,
                    runs.min(4),
                    opts.seed,
                )
                .bits_var_per_client
                    / d as f64
            } else {
                f64::NAN
            };
            println!(
                "{:>6} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
                n, b_agg, m_agg, b_ind, m_ind, b_ih, m_ih
            );
            csv.row_f64(&[n as f64, b_agg, m_agg, b_ind, m_ind, b_ih, m_ih]);
        }
        let path = format!("{}/fig4{panel}.csv", opts.out_dir);
        csv.save(&path).expect("saving fig4 csv");
        println!("saved {path}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_gap_to_individual_shrinks_with_n() {
        // the Fig. 4 trend in the BOUNDS: both fall like −½log n and the
        // aggregate's E[−log A] overhead vanishes as IH(n) → N(0,1), so
        // the gap (agg − ind) shrinks monotonically with n
        let t = 2048.0;
        let gap = |n: u64, seed: u64| {
            let neg_log_a = Decomposer::new(n).expected_neg_log_a(1200, seed);
            aggregate_bound(n, 1.0, t, neg_log_a) - individual_bound(n, 1.0, t)
        };
        let g4 = gap(4, 3);
        let g64 = gap(64, 4);
        let g2048 = gap(2048, 5);
        assert!(g64 < g4, "gap(64)={g64} >= gap(4)={g4}");
        assert!(g2048 < g64 + 0.1, "gap(2048)={g2048} >= gap(64)={g64}");
    }

    #[test]
    fn aggregate_measured_bits_beat_individual_for_large_n() {
        // the Fig. 4 crossover, on MEASURED Elias-gamma bits: with many
        // clients the aggregate mechanism's near-zero descriptions are
        // cheaper than the individual (direct) quantizer's
        let t = 64.0;
        let n = 1024;
        let d = 8;
        let xs = gen_data(DataKind::BoxUniform { c: t / 2.0 }, n, d, 31);
        let agg = evaluate(&AggregateGaussian::new(1.0, t), &xs, 4, 32)
            .bits_var_per_client
            / d as f64;
        let ind = evaluate(
            &IndividualGaussian::new(1.0, LayeredVariant::Direct, t),
            &xs,
            4,
            33,
        )
        .bits_var_per_client
            / d as f64;
        assert!(agg < ind, "agg {agg} >= ind {ind}");
    }

    #[test]
    fn irwin_hall_is_cheapest() {
        let t = 64.0;
        for &n in &[4u64, 64, 1024] {
            let neg_log_a = Decomposer::new(n).expected_neg_log_a(500, 4);
            let ih = irwin_hall_bound(n, 1.0, t);
            let agg = aggregate_bound(n, 1.0, t, neg_log_a);
            assert!(ih <= agg + 0.5, "n={n}: ih {ih} > agg {agg}");
        }
    }

    #[test]
    fn individual_bound_u_shape_in_n() {
        // per-client noise sd is σ√n: coarser steps make bits DECREASE like
        // −½log n first (b256 < b1), until the (8 log e)√(nσ²)/t penalty
        // term dominates and the bound turns upward (b65536 > b256)
        let t = 64.0;
        let b1 = individual_bound(1, 1.0, t);
        let b256 = individual_bound(256, 1.0, t);
        let b65536 = individual_bound(65_536, 1.0, t);
        assert!(b256 < b1, "b256={b256} b1={b1}");
        assert!(b65536 > b256, "b65536={b65536} b256={b256}");
    }

    #[test]
    fn bounds_dominate_measured_bits() {
        // measured Elias bits ≈ H(M|S) + zigzag overhead; the fixed-length
        // IH bound must exceed the *entropy*; we check the measured agg
        // bits land within a few bits of the Thm 1 bound (shape check)
        let n = 64;
        let t = 64.0;
        let d = 8;
        let xs = gen_data(DataKind::BoxUniform { c: t / 2.0 }, n, d, 5);
        let meas = evaluate(&AggregateGaussian::new(1.0, t), &xs, 5, 6).bits_var_per_client / d as f64;
        let neg_log_a = Decomposer::new(n as u64).expected_neg_log_a(500, 7);
        let bound = aggregate_bound(n as u64, 1.0, t, neg_log_a);
        assert!(meas < bound + 4.0, "measured {meas} far above bound {bound}");
        assert!(meas > 0.5);
    }
}
