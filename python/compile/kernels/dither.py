"""Pallas kernels for subtractive-dither encode / decode.

These are the per-coordinate hot spots of every AINQ mechanism in the paper
(Example 1, Definitions 4, 5, 8):

    encode:  m  = round(x * inv_scale + s)          (round half up, paper's
                                                     notation ceil(v) := floor(v + 1/2))
    decode:  y  = scale * (sum_m - sum_s) / n + b   (homomorphic decode of the
                                                     Irwin-Hall / aggregate Q
                                                     mechanism, Def. 8)

TPU mapping (DESIGN.md "Hardware adaptation"): the encode is a fused
elementwise op over a (clients x d) matrix. We tile it into (8, 128)
sublane-by-lane VMEM blocks so that each grid step is a single VPU vector op
on a resident tile; there is no MXU work here. The decode is a vector
reduction with the same tiling. ``interpret=True`` everywhere (CPU PJRT
cannot run Mosaic custom-calls); numerics are validated against
``ref.py`` by pytest + hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile shape: 8 sublanes x 128 lanes = one float32 VREG tile on TPU.
_BLOCK_ROWS = 8
_BLOCK_COLS = 128


def _round_half_up(v):
    """The paper's quantizer rounding: ceil(v) := floor(v + 1/2)."""
    return jnp.floor(v + 0.5)


def _encode_kernel(x_ref, s_ref, inv_scale_ref, m_ref):
    inv_scale = inv_scale_ref[0]
    m_ref[...] = _round_half_up(x_ref[...] * inv_scale + s_ref[...])


def _pad2(a, rows, cols):
    """Zero-pad a 2-d array up to (rows, cols)."""
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


@functools.partial(jax.jit, static_argnames=())
def dither_encode(x, s, inv_scale):
    """Batched subtractive-dither encoder.

    Args:
      x: (n, d) float32 client data (rows = clients).
      s: (n, d) float32 dither, U(-1/2, 1/2) shared randomness.
      inv_scale: scalar float32, 1 / (a * w) in the aggregate mechanism.

    Returns:
      (n, d) float32 of integer-valued descriptions ``m``.
    """
    x = jnp.asarray(x, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    n, d = x.shape
    rows = -(-n // _BLOCK_ROWS) * _BLOCK_ROWS
    cols = -(-d // _BLOCK_COLS) * _BLOCK_COLS
    xp, sp = _pad2(x, rows, cols), _pad2(s, rows, cols)
    inv = jnp.reshape(jnp.asarray(inv_scale, jnp.float32), (1,))

    grid = (rows // _BLOCK_ROWS, cols // _BLOCK_COLS)
    out = pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i, j: (i, j)),
            pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, _BLOCK_COLS), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(xp, sp, inv)
    return out[:n, :d]


def _decode_kernel(msum_ref, ssum_ref, scale_ref, shift_ref, inv_n_ref, y_ref):
    scale = scale_ref[0]
    shift = shift_ref[0]
    inv_n = inv_n_ref[0]
    y_ref[...] = scale * inv_n * (msum_ref[...] - ssum_ref[...]) + shift


@functools.partial(jax.jit, static_argnames=())
def dither_decode_mean(m_sum, s_sum, scale, shift, n_clients):
    """Homomorphic decode of Def. 8: y = (a*w/n) (sum m - sum s) + b.

    Args:
      m_sum: (d,) float32 sum of descriptions (e.g. out of SecAgg).
      s_sum: (d,) float32 sum of the dithers.
      scale: scalar a*w.
      shift: scalar b.
      n_clients: scalar float32 n.

    Returns:
      (d,) float32 mean estimate.
    """
    m_sum = jnp.asarray(m_sum, jnp.float32)
    s_sum = jnp.asarray(s_sum, jnp.float32)
    d = m_sum.shape[0]
    cols = -(-d // _BLOCK_COLS) * _BLOCK_COLS
    mp = jnp.pad(m_sum, (0, cols - d)).reshape(1, cols)
    sp = jnp.pad(s_sum, (0, cols - d)).reshape(1, cols)
    args = [
        jnp.reshape(jnp.asarray(scale, jnp.float32), (1,)),
        jnp.reshape(jnp.asarray(shift, jnp.float32), (1,)),
        jnp.reshape(1.0 / jnp.asarray(n_clients, jnp.float32), (1,)),
    ]
    grid = (cols // _BLOCK_COLS,)
    out = pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, _BLOCK_COLS), lambda j: (0, j)),
            pl.BlockSpec((1, _BLOCK_COLS), lambda j: (0, j)),
            pl.BlockSpec((1,), lambda j: (0,)),
            pl.BlockSpec((1,), lambda j: (0,)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, _BLOCK_COLS), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, cols), jnp.float32),
        interpret=True,
    )(mp, sp, *args)
    return out[0, :d]
