//! Foundation utilities: PRNGs, special functions, statistics, numeric
//! helpers, micro-benchmark harness, JSON/CSV writers.

pub mod rng;
pub mod special;
pub mod stats;
pub mod interp;
pub mod benchkit;
pub mod json;

pub use rng::Rng;
