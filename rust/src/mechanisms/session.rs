//! Batched multi-round transport sessions: open once, aggregate a window
//! of W rounds, unmask once.
//!
//! The paper's aggregation schemes are built for *repeated* FL rounds, but
//! a naive deployment re-opens the masking session — pairwise agreement,
//! per-round mask derivation, one channel handshake per round — every
//! round, which dominates transport cost in high-frequency FL. A
//! [`TransportSession`] amortizes that: it opens the transport once per
//! window of W rounds, derives every round's transport randomness (for
//! [`crate::mechanisms::pipeline::SecAgg`], the ℤ_m mask schedule of
//! [`crate::secagg::session_mask_root`]) from a single *session seed* via
//! the seeded-PRNG stream derivation of [`crate::util::rng::Rng::derive`],
//! folds incoming per-round [`TransportPartial`]s into a ring of W
//! per-round accumulators — still O(d) server state per in-flight round
//! for the summing transports — and closes with one batched unmask.
//!
//! Four invariants, all tested:
//!
//! * **W=1 is the single-round path.** [`crate::mechanisms::pipeline::run_pipeline`]
//!   delegates to a
//!   one-round session, so ordinary `aggregate(xs, seed)` calls are the
//!   W=1 special case of this module, not a parallel implementation.
//! * **Windowed ≡ independent.** A W-round windowed session over any
//!   transport is bit-identical to W independent rounds over
//!   [`crate::mechanisms::pipeline::Plain`]
//!   (for sum-decodable mechanisms) — the session changes *when* masks are
//!   derived and *when* rounds close, never the decoded values.
//! * **Interrupted sessions fail closed.** [`TransportSession::close`]
//!   refuses to unmask anything unless *every* round of the window
//!   received *every* client's submission: a session torn down mid-window
//!   surfaces no partial payloads.
//! * **Announced dropouts recover; unannounced gaps abort.** Real fleets
//!   lose clients mid-window. [`TransportSession::close_with_dropouts`]
//!   closes each round over its *survivors*: for masked transports it
//!   reconstructs every dropped client's outstanding pairwise masks from
//!   the survivors' [`crate::secagg::RecoveryShare`]s (Bonawitz-style
//!   seed recovery, [`crate::secagg::reconstruct_dropped_masks`]) before
//!   unmasking, so the survivor sum decodes bit-identically to Plain
//!   summation over the same survivor set. The fail-closed contract is
//!   preserved: a client may not both submit and be announced dropped, a
//!   recovery share offered for a live client is rejected, a dropped
//!   client's share set must cover exactly the survivor set, gaps that
//!   nobody announced still abort, and nothing can be announced once the
//!   session is closed.
//!
//! The coordinator drives the same object from its worker shards
//! ([`crate::coordinator::runtime::run_rounds_encoded`]): shards encode
//! their clients for all W rounds and ship per-round partials; the
//! orchestrator folds them into the session ring and batch-decodes.

use std::sync::Arc;

use super::pipeline::{
    ClientEncoder, Descriptions, Payload, ServerDecoder, SharedRound, SurvivorSet, Transport,
    TransportPartial,
};
use super::traits::{BitsAccount, RoundOutput};
use crate::secagg::{self, RecoveryShare, SecAggParams};
use crate::util::rng::{seed_domain, Rng};

/// Maximum rounds per session window. Bounds in-flight server state at
/// W·O(d) and matches the pipeline's round-cache capacity, so mechanisms
/// with cached per-round derived state (the aggregate mechanism's (A, B)
/// vectors, SIGM's ñ counts) never thrash their cache mid-window.
pub const MAX_WINDOW: usize = super::pipeline::ROUND_CACHE_CAP;

/// Derive the session seed for the window starting at `start_round` from
/// the run's root seed, via the domain-separated mixer
/// ([`Rng::derive_domain`] under [`seed_domain::SESSION`]) — structurally
/// collision-free against the round-seed and cohort-seed families hanging
/// off the same root, so re-running a window re-derives the identical
/// mask schedule and no window can alias another derivation.
pub fn derive_session_seed(root_seed: u64, start_round: u64) -> u64 {
    Rng::derive_domain(root_seed, seed_domain::SESSION, start_round)
}

/// The per-round transports of a session: round r of the window runs over
/// [`Transport::for_session_round`]`(session_seed, r)`. Shared by the
/// session itself and by coordinator shards, which must mask with the
/// exact same schedule the orchestrator unmasks.
pub fn session_round_transports(
    transport: &dyn Transport,
    session_seed: u64,
    window: usize,
) -> Vec<Arc<dyn Transport>> {
    (0..window).map(|r| transport.for_session_round(session_seed, r as u64)).collect()
}

/// The per-round transports of a *sampled* session: round r runs over
/// [`Transport::for_session_round_sampled`] with its cohort, so masked
/// transports open their pairwise schedule over the cohort only. A window
/// of full cohorts is [`session_round_transports`] bit for bit.
pub fn session_round_transports_sampled(
    transport: &dyn Transport,
    session_seed: u64,
    cohorts: &[SurvivorSet],
) -> Vec<Arc<dyn Transport>> {
    cohorts
        .iter()
        .enumerate()
        .map(|(r, c)| transport.for_session_round_sampled(session_seed, r as u64, c))
        .collect()
}

/// A surviving `holder`'s recovery share for `dropped` in round
/// `round_in_window` of a session opened with `session_seed`. The pairwise
/// seed derives from the same per-round mask root the SecAgg transport was
/// rekeyed with
/// ([`crate::secagg::session_mask_root`] → [`crate::secagg::round_mask_root`]),
/// so the server's reconstruction expands exactly the mask streams the
/// survivors folded into their submissions.
pub fn session_recovery_share(
    session_seed: u64,
    round_in_window: u64,
    holder: usize,
    dropped: usize,
) -> RecoveryShare {
    let root =
        secagg::round_mask_root(secagg::session_mask_root(session_seed), round_in_window);
    secagg::recovery_share(root, holder, dropped)
}

/// One round's dropout announcement: which clients dropped, plus the
/// survivors' recovery shares for each of them. Validated fail-closed by
/// [`TransportSession::close_with_dropouts`]: every dropped client needs a
/// share from *every* survivor, shares for live clients or from dropped
/// holders are rejected, and the announced set must exactly explain the
/// round's submission gap.
#[derive(Clone, Debug, Default)]
pub struct RoundDropouts {
    /// announced dropped client ids
    pub dropped: Vec<usize>,
    /// recovery shares, any order; one per (survivor, dropped) pair
    pub shares: Vec<RecoveryShare>,
}

impl RoundDropouts {
    /// The full announcement for one session round: every survivor
    /// contributes its pairwise share for every dropped client (the
    /// simulation analogue of the share-collection phase of Bonawitz et
    /// al. — in-process, the survivors' shares are derived directly).
    /// Every dead client of `survivors` is treated as dropped — the
    /// unsampled shape; sampled rounds use
    /// [`RoundDropouts::announce_among`], where sampled-out clients are
    /// dead but NOT announced (they left no masks to recover).
    pub fn announce(session_seed: u64, round_in_window: u64, survivors: &SurvivorSet) -> Self {
        let dropped: Vec<usize> = survivors.dropped_iter().collect();
        Self::announce_among(session_seed, round_in_window, survivors, &dropped)
    }

    /// The announcement for a *sampled* session round: `survivors` is the
    /// final decode set (cohort minus mid-round dropouts) and `dropped`
    /// names only the mid-round dropouts — cohort members whose masks are
    /// outstanding. Sampled-out clients appear in neither: they exchanged
    /// no masks, so there is nothing to announce or recover for them.
    pub fn announce_among(
        session_seed: u64,
        round_in_window: u64,
        survivors: &SurvivorSet,
        dropped: &[usize],
    ) -> Self {
        let mut shares = Vec::with_capacity(dropped.len() * survivors.n_alive());
        for &j in dropped {
            for i in survivors.alive_iter() {
                shares.push(session_recovery_share(session_seed, round_in_window, i, j));
            }
        }
        Self { dropped: dropped.to_vec(), shares }
    }
}

/// One in-flight round of the window: its accumulator, bit accounting and
/// submission tracking (the fail-closed gate).
struct RoundSlot {
    partial: TransportPartial,
    bits: BitsAccount,
    submitted: usize,
    /// which clients submitted — directly or through a shard fold.
    /// Duplicates must not stand in for a missing client's count, and
    /// dropout announcements are checked against this record at close.
    seen: Vec<bool>,
    /// whether this round is fed by pre-folded shard partials; folds and
    /// direct submits must not mix (one aggregation discipline per round)
    folded: bool,
}

/// A transport opened once for a window of W rounds (see the module docs).
///
/// Lifecycle: [`open`](Self::open) fixes the window shape and derives the
/// per-round transport schedule from the session seed; clients (or shard
/// partials) stream in via [`submit`](Self::submit) /
/// [`fold_partial`](Self::fold_partial) in any round order; a single
/// [`close`](Self::close) unmasks every round at once — or panics if any
/// round is incomplete, surfacing nothing.
pub struct TransportSession {
    n_clients: usize,
    rounds: Vec<SharedRound>,
    transports: Vec<Arc<dyn Transport>>,
    slots: Vec<RoundSlot>,
    /// per-round participating cohort, fixed at open (full on unsampled
    /// sessions): submissions from outside it fail closed, completeness
    /// and dropout accounting are measured against it
    cohorts: Vec<SurvivorSet>,
    /// set once a close succeeded: every later submit/fold/announce/close
    /// fails closed (nothing can be amended post-unmask)
    closed: bool,
}

impl TransportSession {
    /// Open a session for a window of `round_seeds.len()` rounds (at most
    /// [`MAX_WINDOW`]) of shape (`n_clients`, `dim`). `round_seeds[r]` is
    /// round r's shared-randomness seed (what encoders and decoders
    /// consume); the separate `session_seed` drives only the transport's
    /// session schedule. Every round's cohort is the full fleet — the
    /// unsampled special case of [`TransportSession::open_sampled`].
    pub fn open(
        transport: &dyn Transport,
        session_seed: u64,
        n_clients: usize,
        dim: usize,
        round_seeds: &[u64],
    ) -> Self {
        let cohorts = vec![SurvivorSet::full(n_clients.max(1)); round_seeds.len()];
        Self::open_sampled(transport, session_seed, n_clients, dim, round_seeds, &cohorts)
    }

    /// Open a session whose per-round participating *cohort* is known in
    /// advance (seed-derived client sampling,
    /// [`crate::coordinator::sampling::SamplingPolicy`]): round r expects
    /// submissions from exactly `cohorts[r]`'s alive clients, and masked
    /// transports open their pairwise ℤ_m schedule over that cohort only
    /// ([`Transport::for_session_round_sampled`]). Being *sampled out* is
    /// cheaper than dropping out — it is known at open, so no mask legs
    /// exist and no [`crate::secagg::RecoveryShare`] is ever needed; the
    /// two compose, with dropouts remaining the mid-round failure path
    /// ([`TransportSession::close_with_dropouts`]).
    pub fn open_sampled(
        transport: &dyn Transport,
        session_seed: u64,
        n_clients: usize,
        dim: usize,
        round_seeds: &[u64],
        cohorts: &[SurvivorSet],
    ) -> Self {
        assert!(!round_seeds.is_empty(), "a session window needs at least one round");
        assert!(
            round_seeds.len() <= MAX_WINDOW,
            "session window of {} rounds exceeds MAX_WINDOW ({MAX_WINDOW}) — split the run \
             into multiple windows",
            round_seeds.len(),
        );
        assert!(n_clients > 0, "need at least one client");
        assert_eq!(
            cohorts.len(),
            round_seeds.len(),
            "cohort schedule must cover every round of the window"
        );
        for (r, c) in cohorts.iter().enumerate() {
            assert_eq!(
                c.n(),
                n_clients,
                "round {r}: cohort shaped for a different fleet"
            );
        }
        let transports = session_round_transports_sampled(transport, session_seed, cohorts);
        let rounds: Vec<SharedRound> =
            round_seeds.iter().map(|&s| SharedRound::new(s, n_clients, dim)).collect();
        let slots = rounds
            .iter()
            .zip(&transports)
            .map(|(round, t)| RoundSlot {
                partial: t.empty(round),
                bits: BitsAccount::default(),
                submitted: 0,
                seen: vec![false; n_clients],
                folded: false,
            })
            .collect();
        Self {
            n_clients,
            rounds,
            transports,
            slots,
            cohorts: cohorts.to_vec(),
            closed: false,
        }
    }

    /// Number of rounds in the window.
    pub fn window(&self) -> usize {
        self.rounds.len()
    }

    /// Announced fleet size n — every cohort and survivor set of this
    /// session is shaped to it.
    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    /// Round r's participating cohort (full on unsampled sessions).
    pub fn cohort(&self, r: usize) -> &SurvivorSet {
        &self.cohorts[r]
    }

    /// Round r's public context (what encoders/decoders take).
    pub fn round(&self, r: usize) -> &SharedRound {
        &self.rounds[r]
    }

    /// Round r's rekeyed transport — what a remote encoder (e.g. a
    /// coordinator shard) must mask with so the batched unmask cancels.
    pub fn round_transport(&self, r: usize) -> &Arc<dyn Transport> {
        &self.transports[r]
    }

    /// Fold one client's message into round r of the ring. Panics on a
    /// duplicate submission — a client submitting twice must not be able
    /// to stand in for a missing client in the fail-closed count (with
    /// SecAgg, double-counted masks would unmask to garbage).
    pub fn submit(&mut self, r: usize, client: usize, msg: &Descriptions) {
        assert!(!self.closed, "fails closed: the session is already closed");
        assert!(
            self.cohorts[r].is_alive(client),
            "fails closed: client {client} is sampled out of round {r} of the window and \
             cannot submit"
        );
        let slot = &mut self.slots[r];
        assert!(
            !slot.folded,
            "cannot mix direct submits with shard folds in round {r} of the window"
        );
        assert!(
            !slot.seen[client],
            "duplicate submission from client {client} in round {r} of the window"
        );
        slot.seen[client] = true;
        slot.bits.merge(&msg.bits);
        self.transports[r].submit(&mut slot.partial, client, msg, &self.rounds[r]);
        slot.submitted += 1;
    }

    /// Fold a pre-folded shard partial covering the listed `clients`
    /// (global ids) into round r of the ring (the coordinator path: the
    /// orchestrator never sees per-client messages). Every listed client
    /// is marked submitted, so overlapping shard partials are rejected
    /// like duplicate direct submissions, and dropout announcements are
    /// checked against the same record at close — the fail-closed
    /// contract is identical on both feeding paths.
    pub fn fold_partial(
        &mut self,
        r: usize,
        partial: TransportPartial,
        clients: &[usize],
        bits: &BitsAccount,
    ) {
        assert!(!self.closed, "fails closed: the session is already closed");
        let slot = &mut self.slots[r];
        assert!(
            slot.submitted == 0 || slot.folded,
            "cannot mix shard folds with direct submits in round {r} of the window"
        );
        slot.folded = true;
        for &c in clients {
            assert!(
                self.cohorts[r].is_alive(c),
                "fails closed: client {c} is sampled out of round {r} of the window and \
                 cannot submit"
            );
            assert!(
                !slot.seen[c],
                "duplicate submission from client {c} in round {r} of the window"
            );
            slot.seen[c] = true;
        }
        slot.bits.merge(bits);
        self.transports[r].merge(&mut slot.partial, partial);
        slot.submitted += clients.len();
    }

    /// Whether every round of the window has all its *cohort's*
    /// submissions (the full fleet on unsampled sessions).
    pub fn is_complete(&self) -> bool {
        self.slots.iter().zip(&self.cohorts).all(|(s, c)| s.submitted == c.n_alive())
    }

    /// Batched unmask: close every round of the window and surface the
    /// per-round server views, in round order.
    ///
    /// Fails closed: if ANY round of the window is missing submissions —
    /// a session interrupted mid-window — this panics before unmasking
    /// anything, so no partial payload ever escapes a broken session. For
    /// windows with *announced* dropouts use
    /// [`close_with_dropouts`](Self::close_with_dropouts); this strict
    /// close treats every gap as an interruption.
    pub fn close(&mut self) -> Vec<(Payload, BitsAccount)> {
        // a strict close IS the empty announcement: every gap is an
        // interruption (close_with_dropouts enforces submitted + 0 == n
        // per round with the same fail-closed message)
        let none = vec![RoundDropouts::default(); self.window()];
        self.close_with_dropouts(&none).into_iter().map(|(p, b, _)| (p, b)).collect()
    }

    /// Batched unmask over announced dropouts: close every round of the
    /// window over its survivor set, reconstructing dropped clients'
    /// outstanding pairwise masks from the survivors' recovery shares
    /// before unmasking (see the module docs). Returns the per-round
    /// server view, bit accounting, and survivor set, in round order.
    ///
    /// Fail-closed contract (every violation panics before ANY round is
    /// unmasked):
    /// * announcing after a close already happened,
    /// * a client that both submitted and is announced dropped,
    /// * a submission gap no announcement explains,
    /// * a recovery share offered for a live (unannounced) client,
    /// * a share held by a dropped client, a duplicate share, or a share
    ///   set that does not cover every survivor.
    pub fn close_with_dropouts(
        &mut self,
        announced: &[RoundDropouts],
    ) -> Vec<(Payload, BitsAccount, SurvivorSet)> {
        assert!(
            !self.closed,
            "fails closed: dropout announced after close — the session is already closed"
        );
        assert_eq!(
            announced.len(),
            self.window(),
            "dropout announcements must cover every round of the window"
        );
        // validate the whole window before unmasking any round
        let mut survivor_sets = Vec::with_capacity(self.window());
        for (r, ((slot, ann), cohort)) in
            self.slots.iter().zip(announced).zip(&self.cohorts).enumerate()
        {
            // the final decode set: the open-time cohort minus the
            // mid-round dropouts (identical to the PR 3 shape when the
            // cohort is the full fleet); only cohort members hold mask
            // legs, so announcing a sampled-out client fails closed here
            let survivors = cohort.drop_cohort_members(&ann.dropped, r);
            // the seen-record covers BOTH feeding paths (direct submits
            // and shard folds), so this check cannot be bypassed by an
            // announcement whose count happens to balance a real gap
            for &j in &ann.dropped {
                assert!(
                    !slot.seen[j],
                    "fails closed: client {j} submitted in round {r} but was announced \
                     dropped — a live client cannot be recovered"
                );
            }
            assert!(
                slot.submitted + ann.dropped.len() == cohort.n_alive(),
                "interrupted session fails closed: round {r} of the window has {}/{} cohort \
                 submissions with {} announced dropouts — refusing any partial unmask",
                slot.submitted,
                cohort.n_alive(),
                ann.dropped.len(),
            );
            Self::validate_recovery_shares(r, ann, &survivors);
            survivor_sets.push(survivors);
        }
        self.closed = true;
        let slots = std::mem::take(&mut self.slots);
        slots
            .into_iter()
            .zip(&self.rounds)
            .zip(&self.transports)
            .zip(announced)
            .zip(survivor_sets)
            .map(|((((slot, round), t), ann), survivors)| {
                let mut partial = slot.partial;
                // masked transports: fold the reconstructed masks of every
                // dropped client back in so the residuals cancel
                if let TransportPartial::Masked { sum: Some(v), modulus } = &mut partial {
                    let params = SecAggParams { modulus: *modulus };
                    for &j in &ann.dropped {
                        let shares: Vec<RecoveryShare> =
                            ann.shares.iter().filter(|s| s.dropped == j).copied().collect();
                        let rec =
                            secagg::reconstruct_dropped_masks(j, &shares, v.len(), params);
                        for (a, mval) in v.iter_mut().zip(rec) {
                            *a = (*a + mval) % *modulus;
                        }
                    }
                }
                (t.finish_survivors(partial, round, &survivors), slot.bits, survivors)
            })
            .collect()
    }

    /// The share-bundle half of the fail-closed contract (see
    /// [`close_with_dropouts`](Self::close_with_dropouts)). The share
    /// *seeds* themselves cannot be verified server-side — that is the
    /// security point — but a wrong seed yields uncancelled masks and is
    /// caught by the Plain ≡ SecAgg property tests.
    fn validate_recovery_shares(r: usize, ann: &RoundDropouts, survivors: &SurvivorSet) {
        for share in &ann.shares {
            assert!(
                ann.dropped.contains(&share.dropped),
                "fails closed: recovery share offered for live client {} in round {r} — only \
                 announced dropouts may be recovered",
                share.dropped,
            );
            assert!(
                share.holder < survivors.n(),
                "recovery share holder {} out of range in round {r}",
                share.holder,
            );
            assert!(
                survivors.is_alive(share.holder),
                "fails closed: recovery share for client {} held by dropped client {} in \
                 round {r} — only survivors may contribute shares",
                share.dropped,
                share.holder,
            );
        }
        for &j in &ann.dropped {
            let mut have = vec![false; survivors.n()];
            for share in ann.shares.iter().filter(|s| s.dropped == j) {
                assert!(
                    !have[share.holder],
                    "fails closed: duplicate recovery share from holder {} for dropped \
                     client {j} in round {r}",
                    share.holder,
                );
                have[share.holder] = true;
            }
            for i in survivors.alive_iter() {
                assert!(
                    have[i],
                    "fails closed: recovery for dropped client {j} in round {r} is missing \
                     the share of survivor {i} — refusing a partial reconstruction"
                );
            }
        }
    }
}

/// Run a whole window in-process: encode every client for every round,
/// stream the messages through one [`TransportSession`], batch-close, and
/// decode each round. `rounds` pairs each round's client data with its
/// shared-randomness seed; [`crate::mechanisms::pipeline::run_pipeline`]
/// is exactly this with a single round and `session_seed == seed`.
pub fn run_window(
    encoder: &dyn ClientEncoder,
    transport: &dyn Transport,
    decoder: &dyn ServerDecoder,
    rounds: &[(&[Vec<f64>], u64)],
    session_seed: u64,
) -> Vec<RoundOutput> {
    assert!(!rounds.is_empty(), "a session window needs at least one round");
    let none: Vec<Vec<usize>> = vec![Vec::new(); rounds.len()];
    run_window_with_dropouts(encoder, transport, decoder, rounds, session_seed, &none)
}

/// [`run_window`] under a per-round dropout schedule: `dropouts[r]` names
/// the clients that drop in round r of the window. Dropped clients never
/// encode or submit; at close the session recovers their outstanding
/// masks from the survivors' shares ([`RoundDropouts::announce`]) and
/// each round decodes over its true survivor set via
/// [`ServerDecoder::decode_survivors`]. With an empty schedule this IS
/// `run_window`, bit for bit.
pub fn run_window_with_dropouts(
    encoder: &dyn ClientEncoder,
    transport: &dyn Transport,
    decoder: &dyn ServerDecoder,
    rounds: &[(&[Vec<f64>], u64)],
    session_seed: u64,
    dropouts: &[Vec<usize>],
) -> Vec<RoundOutput> {
    assert!(!rounds.is_empty(), "a session window needs at least one round");
    let (xs0, _) = rounds[0];
    assert!(!xs0.is_empty(), "need at least one client");
    let cohorts = vec![SurvivorSet::full(xs0.len()); rounds.len()];
    run_window_sampled(encoder, transport, decoder, rounds, session_seed, &cohorts, dropouts)
}

/// The general sampled window: round r's participating cohort is
/// `cohorts[r]` (seed-derived client sampling, known at session open) and
/// `dropouts[r]` names the *mid-round* dropouts — cohort members that went
/// silent after the session opened. Sampled-out clients never encode, hold
/// no masks and need no recovery; dropped cohort members are recovered
/// Bonawitz-style exactly as in [`run_window_with_dropouts`]. Each round
/// decodes over cohort minus dropped via
/// [`ServerDecoder::decode_survivors`], so the exact error laws hold at
/// the contributing count n′. Full cohorts make this
/// `run_window_with_dropouts` bit for bit.
pub fn run_window_sampled(
    encoder: &dyn ClientEncoder,
    transport: &dyn Transport,
    decoder: &dyn ServerDecoder,
    rounds: &[(&[Vec<f64>], u64)],
    session_seed: u64,
    cohorts: &[SurvivorSet],
    dropouts: &[Vec<usize>],
) -> Vec<RoundOutput> {
    assert!(!rounds.is_empty(), "a session window needs at least one round");
    assert_eq!(
        cohorts.len(),
        rounds.len(),
        "cohort schedule must cover every round of the window"
    );
    assert_eq!(
        dropouts.len(),
        rounds.len(),
        "dropout schedule must cover every round of the window"
    );
    let (xs0, _) = rounds[0];
    assert!(!xs0.is_empty(), "need at least one client");
    assert!(
        !transport.sum_only() || decoder.sum_decodable(),
        "mechanism is not homomorphic: it cannot decode from a sum-only transport"
    );
    let n = xs0.len();
    let dim = xs0[0].len();
    let seeds: Vec<u64> = rounds.iter().map(|&(_, seed)| seed).collect();
    let mut session =
        TransportSession::open_sampled(transport, session_seed, n, dim, &seeds, cohorts);
    let mut announced = Vec::with_capacity(rounds.len());
    for (r, &(xs, _)) in rounds.iter().enumerate() {
        assert_eq!(xs.len(), n, "client count changed mid-session");
        let survivors = cohorts[r].drop_cohort_members(&dropouts[r], r);
        let round = *session.round(r);
        for i in survivors.alive_iter() {
            let x = &xs[i];
            assert_eq!(x.len(), dim, "ragged client vectors");
            let msg = encoder.encode(i, x, &round);
            session.submit(r, i, &msg);
        }
        announced.push(RoundDropouts::announce_among(
            session_seed,
            r as u64,
            &survivors,
            &dropouts[r],
        ));
    }
    let shared: Vec<SharedRound> = session.rounds.clone();
    session
        .close_with_dropouts(&announced)
        .into_iter()
        .zip(shared)
        .map(|((payload, bits, survivors), round)| RoundOutput {
            estimate: decoder.decode_survivors(&payload, &round, &survivors),
            bits,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::pipeline::{run_pipeline, MechSpec, Plain, SecAgg, Unicast};
    use crate::quantizer::round_half_up;

    /// Toy homomorphic mechanism (same shape as the pipeline tests'):
    /// m = round(x + tiny seeded jitter), decode = Σm/n. The jitter makes
    /// per-round seeds observable in the estimates, so windowed-vs-
    /// independent comparisons are not vacuous.
    #[derive(Clone, Debug)]
    struct JitterRound;

    impl ClientEncoder for JitterRound {
        fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions {
            let mut rng = round.client_rng(client);
            let mut bits = BitsAccount::default();
            let ms: Vec<i64> = x
                .iter()
                .map(|&v| {
                    let m = round_half_up(4.0 * (v + rng.u01()));
                    bits.add_description(m);
                    m
                })
                .collect();
            Descriptions { ms, aux: vec![], bits }
        }
    }

    impl ServerDecoder for JitterRound {
        fn sum_decodable(&self) -> bool {
            true
        }

        fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
            self.decode_survivors(payload, round, &SurvivorSet::full(round.n_clients))
        }

        fn decode_survivors(
            &self,
            payload: &Payload,
            _round: &SharedRound,
            survivors: &SurvivorSet,
        ) -> Vec<f64> {
            payload
                .description_sum()
                .iter()
                .map(|&s| s as f64 / (4.0 * survivors.n_alive() as f64))
                .collect()
        }
    }

    impl MechSpec for JitterRound {
        fn name(&self) -> String {
            "jitter-round".into()
        }

        fn is_homomorphic(&self) -> bool {
            true
        }

        fn gaussian_noise(&self) -> bool {
            false
        }

        fn fixed_length(&self) -> bool {
            false
        }

        fn noise_sd(&self) -> f64 {
            0.0
        }
    }

    fn data(shift: f64) -> Vec<Vec<f64>> {
        vec![
            vec![1.2 + shift, -3.9, 0.5],
            vec![2.2, 1.1 + shift, -7.0],
            vec![0.9, 0.0, 2.0 - shift],
        ]
    }

    fn window_inputs() -> Vec<(Vec<Vec<f64>>, u64)> {
        (0..4).map(|r| (data(r as f64 * 0.3), 1000 + 17 * r as u64)).collect()
    }

    #[test]
    fn windowed_secagg_session_matches_independent_plain_rounds() {
        let inputs = window_inputs();
        let rounds: Vec<(&[Vec<f64>], u64)> =
            inputs.iter().map(|(xs, s)| (xs.as_slice(), *s)).collect();
        let mech = JitterRound;
        let windowed = run_window(&mech, &SecAgg::new(), &mech, &rounds, 0xAB5E55);
        assert_eq!(windowed.len(), 4);
        for (r, &(xs, seed)) in rounds.iter().enumerate() {
            let independent = run_pipeline(&mech, &Plain, &mech, xs, seed);
            assert_eq!(windowed[r].estimate, independent.estimate, "round {r}");
            assert_eq!(windowed[r].bits.messages, independent.bits.messages);
            assert_eq!(windowed[r].bits.variable_total, independent.bits.variable_total);
        }
    }

    #[test]
    fn window_of_one_is_the_single_round_path_bit_for_bit() {
        // W=1 run_window vs driving the legacy transport stages by hand
        let xs = data(0.0);
        let seed = 77;
        let mech = JitterRound;
        let windowed = run_window(&mech, &Plain, &mech, &[(xs.as_slice(), seed)], seed);
        let round = SharedRound::new(seed, xs.len(), xs[0].len());
        let mut part = Plain.empty(&round);
        let mut bits = BitsAccount::default();
        for (i, x) in xs.iter().enumerate() {
            let msg = mech.encode(i, x, &round);
            bits.merge(&msg.bits);
            Plain.submit(&mut part, i, &msg, &round);
        }
        let legacy = mech.decode(&Plain.finish(part, &round), &round);
        assert_eq!(windowed.len(), 1);
        assert_eq!(windowed[0].estimate, legacy);
        assert_eq!(windowed[0].bits.messages, bits.messages);
        assert_eq!(windowed[0].bits.variable_total, bits.variable_total);
    }

    #[test]
    fn session_seed_changes_masks_but_never_estimates() {
        let inputs = window_inputs();
        let rounds: Vec<(&[Vec<f64>], u64)> =
            inputs.iter().map(|(xs, s)| (xs.as_slice(), *s)).collect();
        let mech = JitterRound;
        let a = run_window(&mech, &SecAgg::new(), &mech, &rounds, 1);
        let b = run_window(&mech, &SecAgg::new(), &mech, &rounds, 2);
        for (oa, ob) in a.iter().zip(&b) {
            assert_eq!(oa.estimate, ob.estimate);
        }
    }

    #[test]
    #[should_panic(expected = "fails closed")]
    fn interrupted_session_fails_closed_missing_client() {
        // every round touched, but one round is short a client: close must
        // refuse to unmask ANY round
        let xs = data(0.0);
        let mech = JitterRound;
        let mut session =
            TransportSession::open(&SecAgg::new(), 9, xs.len(), xs[0].len(), &[5, 6]);
        for r in 0..2 {
            let round = *session.round(r);
            for (i, x) in xs.iter().enumerate() {
                if r == 1 && i == 2 {
                    continue; // client 2 drops mid-window
                }
                let msg = mech.encode(i, x, &round);
                session.submit(r, i, &msg);
            }
        }
        assert!(!session.is_complete());
        let _ = session.close();
    }

    #[test]
    #[should_panic(expected = "cannot mix")]
    fn mixing_submit_and_fold_is_rejected() {
        // one aggregation discipline per round: direct submits after a
        // fold are rejected
        let xs = data(0.0);
        let mech = JitterRound;
        let mut session =
            TransportSession::open(&SecAgg::new(), 9, xs.len(), xs[0].len(), &[5]);
        let round = *session.round(0);
        let rt = session.round_transport(0).clone();
        let mut p = rt.empty(&round);
        let msg0 = mech.encode(0, &xs[0], &round);
        rt.submit(&mut p, 0, &msg0, &round);
        session.fold_partial(0, p, &[0], &msg0.bits);
        session.submit(0, 1, &mech.encode(1, &xs[1], &round));
    }

    #[test]
    #[should_panic(expected = "duplicate submission")]
    fn overlapping_shard_folds_are_rejected() {
        // two shard partials claiming the same client: the seen-record
        // catches the overlap exactly like a duplicate direct submit
        let xs = data(0.0);
        let mech = JitterRound;
        let mut session =
            TransportSession::open(&SecAgg::new(), 9, xs.len(), xs[0].len(), &[5]);
        let round = *session.round(0);
        let rt = session.round_transport(0).clone();
        let mut p0 = rt.empty(&round);
        rt.submit(&mut p0, 0, &mech.encode(0, &xs[0], &round), &round);
        rt.submit(&mut p0, 1, &mech.encode(1, &xs[1], &round), &round);
        let mut p1 = rt.empty(&round);
        rt.submit(&mut p1, 1, &mech.encode(1, &xs[1], &round), &round);
        session.fold_partial(0, p0, &[0, 1], &BitsAccount::default());
        session.fold_partial(0, p1, &[1], &BitsAccount::default());
    }

    #[test]
    #[should_panic(expected = "exceeds MAX_WINDOW")]
    fn oversized_window_is_rejected_at_open() {
        let seeds: Vec<u64> = (0..MAX_WINDOW as u64 + 1).collect();
        let _ = TransportSession::open(&Plain, 1, 3, 2, &seeds);
    }

    #[test]
    #[should_panic(expected = "duplicate submission")]
    fn duplicate_submit_cannot_stand_in_for_missing_client() {
        // client 0 submits twice, client 2 never: the count would reach
        // n_clients, so the duplicate must be rejected at submit time
        let xs = data(0.0);
        let mech = JitterRound;
        let mut session =
            TransportSession::open(&SecAgg::new(), 9, xs.len(), xs[0].len(), &[5]);
        let round = *session.round(0);
        let msg0 = mech.encode(0, &xs[0], &round);
        session.submit(0, 0, &msg0);
        session.submit(0, 1, &mech.encode(1, &xs[1], &round));
        session.submit(0, 0, &msg0);
    }

    #[test]
    #[should_panic(expected = "fails closed")]
    fn interrupted_session_fails_closed_untouched_round() {
        // a complete first round must not leak through close when the
        // second round never ran
        let xs = data(0.0);
        let mech = JitterRound;
        let mut session = TransportSession::open(&Plain, 9, xs.len(), xs[0].len(), &[5, 6]);
        let round = *session.round(0);
        for (i, x) in xs.iter().enumerate() {
            let msg = mech.encode(i, x, &round);
            session.submit(0, i, &msg);
        }
        let _ = session.close();
    }

    #[test]
    fn shard_fold_path_matches_client_submit_path() {
        // two shards pre-fold disjoint clients per round, the session
        // merges partials: identical to submitting clients directly
        let inputs = window_inputs();
        let mech = JitterRound;
        let n = inputs[0].0.len();
        let dim = inputs[0].0[0].len();
        let seeds: Vec<u64> = inputs.iter().map(|&(_, s)| s).collect();
        let t = SecAgg::new();
        let session_seed = 0xFEED;

        let mut direct = TransportSession::open(&t, session_seed, n, dim, &seeds);
        let mut folded = TransportSession::open(&t, session_seed, n, dim, &seeds);
        for (r, (xs, _)) in inputs.iter().enumerate() {
            let round = *direct.round(r);
            let rt = folded.round_transport(r).clone();
            let mut p0 = rt.empty(&round);
            let mut p1 = rt.empty(&round);
            let mut b0 = BitsAccount::default();
            let mut b1 = BitsAccount::default();
            let mut c0: Vec<usize> = Vec::new();
            let mut c1: Vec<usize> = Vec::new();
            for (i, x) in xs.iter().enumerate() {
                let msg = mech.encode(i, x, &round);
                direct.submit(r, i, &msg);
                if i % 2 == 0 {
                    rt.submit(&mut p0, i, &msg, &round);
                    b0.merge(&msg.bits);
                    c0.push(i);
                } else {
                    rt.submit(&mut p1, i, &msg, &round);
                    b1.merge(&msg.bits);
                    c1.push(i);
                }
            }
            folded.fold_partial(r, p0, &c0, &b0);
            folded.fold_partial(r, p1, &c1, &b1);
        }
        assert!(direct.is_complete() && folded.is_complete());
        let a = direct.close();
        let b = folded.close();
        for (r, ((pa, ba), (pb, bb))) in a.iter().zip(&b).enumerate() {
            assert_eq!(pa.description_sum(), pb.description_sum(), "round {r}");
            assert_eq!(ba.messages, bb.messages);
        }
    }

    #[test]
    fn derived_session_seeds_are_window_distinct() {
        let a = derive_session_seed(42, 0);
        let b = derive_session_seed(42, 4);
        let c = derive_session_seed(43, 0);
        assert_eq!(a, derive_session_seed(42, 0));
        assert!(a != b && a != c && b != c);
    }

    // -----------------------------------------------------------------
    // dropout recovery: happy path + the adversarial fail-closed suite
    // -----------------------------------------------------------------

    /// Open a SecAgg session over the toy data, submit every client
    /// except those in `dropped[r]`, and return it with the announced
    /// fleet shape.
    fn dropout_session(
        session_seed: u64,
        dropped: &[Vec<usize>],
    ) -> (TransportSession, Vec<Vec<Vec<f64>>>) {
        let mech = JitterRound;
        let datasets: Vec<Vec<Vec<f64>>> =
            (0..dropped.len()).map(|r| data(r as f64 * 0.5)).collect();
        let n = datasets[0].len();
        let seeds: Vec<u64> = (0..dropped.len() as u64).map(|r| 40 + r).collect();
        let mut session =
            TransportSession::open(&SecAgg::new(), session_seed, n, datasets[0][0].len(), &seeds);
        for (r, xs) in datasets.iter().enumerate() {
            let round = *session.round(r);
            for (i, x) in xs.iter().enumerate() {
                if dropped[r].contains(&i) {
                    continue;
                }
                session.submit(r, i, &mech.encode(i, x, &round));
            }
        }
        (session, datasets)
    }

    #[test]
    fn dropout_window_closes_and_matches_plain_survivors() {
        // a W=2 masked window with one announced dropout per round closes
        // over the survivors and decodes bit-identically to Plain
        // summation over the same survivor set
        let mech = JitterRound;
        let session_seed = 0xD0;
        let dropped = vec![vec![2usize], vec![0usize]];
        let (mut session, datasets) = dropout_session(session_seed, &dropped);
        assert!(!session.is_complete());
        let announced: Vec<RoundDropouts> = (0..2)
            .map(|r| {
                let survivors = SurvivorSet::with_dropped(3, &dropped[r]);
                RoundDropouts::announce(session_seed, r as u64, &survivors)
            })
            .collect();
        let shared: Vec<SharedRound> = (0..2).map(|r| *session.round(r)).collect();
        let closed = session.close_with_dropouts(&announced);
        for (r, (payload, _bits, survivors)) in closed.iter().enumerate() {
            assert_eq!(survivors.n_alive(), 2);
            // Plain reference over the identical SharedRound + survivors
            let mut part = Plain.empty(&shared[r]);
            for i in survivors.alive_iter() {
                Plain.submit(&mut part, i, &mech.encode(i, &datasets[r][i], &shared[r]), &shared[r]);
            }
            let reference = Plain.finish(part, &shared[r]);
            assert_eq!(payload.description_sum(), reference.description_sum(), "round {r}");
            assert_eq!(
                mech.decode_survivors(payload, &shared[r], survivors),
                mech.decode_survivors(&reference, &shared[r], survivors),
                "round {r}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "announced dropped")]
    fn dropout_submitted_client_cannot_be_announced_dropped() {
        // adversarial: a client both submits and is announced dropped —
        // recovering a live client's masks would expose its submission
        let session_seed = 0xD1;
        let (mut session, _) = dropout_session(session_seed, &[vec![]]);
        let survivors = SurvivorSet::with_dropped(3, &[1]);
        let announced = [RoundDropouts::announce(session_seed, 0, &survivors)];
        let _ = session.close_with_dropouts(&announced);
    }

    #[test]
    #[should_panic(expected = "recovery share offered for live client")]
    fn dropout_recovery_share_for_live_client_rejected() {
        // adversarial: the bundle smuggles a share targeting a client that
        // was never announced dropped
        let session_seed = 0xD2;
        let (mut session, _) = dropout_session(session_seed, &[vec![2]]);
        let survivors = SurvivorSet::with_dropped(3, &[2]);
        let mut ann = RoundDropouts::announce(session_seed, 0, &survivors);
        ann.shares.push(session_recovery_share(session_seed, 0, 0, 1)); // client 1 is live
        let _ = session.close_with_dropouts(&[ann]);
    }

    #[test]
    #[should_panic(expected = "already closed")]
    fn dropout_announced_after_close_fails_closed() {
        // adversarial: once the batched unmask ran, nothing can be
        // announced or re-closed
        let session_seed = 0xD3;
        let (mut session, _) = dropout_session(session_seed, &[vec![]]);
        let _ = session.close();
        let survivors = SurvivorSet::with_dropped(3, &[2]);
        let announced = [RoundDropouts::announce(session_seed, 0, &survivors)];
        let _ = session.close_with_dropouts(&announced);
    }

    #[test]
    #[should_panic(expected = "announced dropped")]
    fn dropout_folded_submitted_client_cannot_be_announced_dropped() {
        // the folded (coordinator) path is held to the same contract:
        // client 2 is genuinely missing from the folds, but the
        // announcement names live client 1 — the counts would balance
        // (2 submitted + 1 dropped == 3), so only the seen-record can
        // catch the inconsistency
        let mech = JitterRound;
        let xs = data(0.0);
        let session_seed = 0xD7;
        let mut session =
            TransportSession::open(&SecAgg::new(), session_seed, xs.len(), xs[0].len(), &[5]);
        let round = *session.round(0);
        let rt = session.round_transport(0).clone();
        let mut p = rt.empty(&round);
        rt.submit(&mut p, 0, &mech.encode(0, &xs[0], &round), &round);
        rt.submit(&mut p, 1, &mech.encode(1, &xs[1], &round), &round);
        session.fold_partial(0, p, &[0, 1], &BitsAccount::default());
        let survivors = SurvivorSet::with_dropped(3, &[1]);
        let announced = [RoundDropouts::announce(session_seed, 0, &survivors)];
        let _ = session.close_with_dropouts(&announced);
    }

    #[test]
    #[should_panic(expected = "fails closed")]
    fn dropout_unannounced_gap_still_aborts() {
        // client 2 is missing but nobody announced it: the window must
        // abort exactly like an interrupted session
        let session_seed = 0xD4;
        let (mut session, _) = dropout_session(session_seed, &[vec![2]]);
        let _ = session.close_with_dropouts(&[RoundDropouts::default()]);
    }

    #[test]
    #[should_panic(expected = "missing the share of survivor")]
    fn dropout_partial_share_set_rejected() {
        // recovery needs a share from EVERY survivor; a partial bundle
        // would leave residual masks in the sum
        let session_seed = 0xD5;
        let (mut session, _) = dropout_session(session_seed, &[vec![2]]);
        let ann = RoundDropouts {
            dropped: vec![2],
            shares: vec![session_recovery_share(session_seed, 0, 0, 2)], // survivor 1 missing
        };
        let _ = session.close_with_dropouts(&[ann]);
    }

    #[test]
    #[should_panic(expected = "held by dropped client")]
    fn dropout_share_from_dropped_holder_rejected() {
        // a dropped client cannot vouch for another dropped client
        let session_seed = 0xD6;
        let (mut session, _) = dropout_session(session_seed, &[vec![1, 2]]);
        let ann = RoundDropouts {
            dropped: vec![1, 2],
            shares: vec![
                session_recovery_share(session_seed, 0, 0, 1),
                session_recovery_share(session_seed, 0, 0, 2),
                session_recovery_share(session_seed, 0, 2, 1), // holder 2 is dropped
            ],
        };
        let _ = session.close_with_dropouts(&[ann]);
    }

    #[test]
    #[should_panic(expected = "cannot close over a partial client set")]
    fn dropout_unicast_window_fails_closed() {
        // per-client transports are not dropout-aware: announcing a
        // dropout over Unicast must abort, not mis-deliver
        let mech = JitterRound;
        let xs = data(0.0);
        let mut session = TransportSession::open(&Unicast, 9, xs.len(), xs[0].len(), &[5]);
        let round = *session.round(0);
        for (i, x) in xs.iter().enumerate() {
            if i == 2 {
                continue;
            }
            session.submit(0, i, &mech.encode(i, x, &round));
        }
        let survivors = SurvivorSet::with_dropped(3, &[2]);
        let announced = [RoundDropouts::announce(9, 0, &survivors)];
        let _ = session.close_with_dropouts(&announced);
    }

    // -----------------------------------------------------------------
    // seed-derived client sampling: cohort-scoped sessions
    // -----------------------------------------------------------------

    #[test]
    fn sampling_sampled_secagg_window_matches_plain_over_cohort() {
        // a sampled masked window — cohort-scoped mask schedule, no
        // recovery shares — decodes bit-identically to Plain summation
        // over the same cohort, round for round
        let mech = JitterRound;
        let inputs = window_inputs();
        let rounds: Vec<(&[Vec<f64>], u64)> =
            inputs.iter().map(|(xs, s)| (xs.as_slice(), *s)).collect();
        let n = inputs[0].0.len();
        let cohorts: Vec<SurvivorSet> = vec![
            SurvivorSet::with_dropped(n, &[1]),
            SurvivorSet::full(n),
            SurvivorSet::with_dropped(n, &[0, 2]),
            SurvivorSet::with_dropped(n, &[2]),
        ];
        let none: Vec<Vec<usize>> = vec![Vec::new(); rounds.len()];
        let masked = run_window_sampled(
            &mech, &SecAgg::new(), &mech, &rounds, 0x5A11, &cohorts, &none,
        );
        let plain =
            run_window_sampled(&mech, &Plain, &mech, &rounds, 0x5A11, &cohorts, &none);
        for (r, (m, p)) in masked.iter().zip(&plain).enumerate() {
            assert_eq!(m.estimate, p.estimate, "round {r}");
            assert_eq!(m.bits.messages, p.bits.messages, "round {r}");
        }
    }

    #[test]
    fn sampling_full_cohorts_are_the_dropout_path_bit_for_bit() {
        let mech = JitterRound;
        let inputs = window_inputs();
        let rounds: Vec<(&[Vec<f64>], u64)> =
            inputs.iter().map(|(xs, s)| (xs.as_slice(), *s)).collect();
        let n = inputs[0].0.len();
        let cohorts = vec![SurvivorSet::full(n); rounds.len()];
        let schedule: Vec<Vec<usize>> = vec![vec![2], vec![], vec![0], vec![1]];
        let a = run_window_with_dropouts(&mech, &SecAgg::new(), &mech, &rounds, 7, &schedule);
        let b = run_window_sampled(
            &mech, &SecAgg::new(), &mech, &rounds, 7, &cohorts, &schedule,
        );
        for (oa, ob) in a.iter().zip(&b) {
            assert_eq!(oa.estimate, ob.estimate);
            assert_eq!(oa.bits.messages, ob.bits.messages);
        }
    }

    #[test]
    fn sampling_composes_with_midround_dropouts() {
        // cohort fixed at open AND a cohort member drops mid-round: the
        // dropped member is recovered over the final survivors, and the
        // result equals Plain over (cohort minus dropped)
        let mech = JitterRound;
        let inputs = window_inputs();
        let rounds: Vec<(&[Vec<f64>], u64)> =
            inputs.iter().map(|(xs, s)| (xs.as_slice(), *s)).collect();
        let n = inputs[0].0.len();
        // cohort {0, 2} in round 0 (client 1 sampled out), full elsewhere
        let cohorts: Vec<SurvivorSet> = vec![
            SurvivorSet::with_dropped(n, &[1]),
            SurvivorSet::full(n),
            SurvivorSet::full(n),
            SurvivorSet::full(n),
        ];
        let dropouts: Vec<Vec<usize>> = vec![vec![2], vec![1], vec![], vec![]];
        let masked = run_window_sampled(
            &mech, &SecAgg::new(), &mech, &rounds, 0xC0DE, &cohorts, &dropouts,
        );
        let plain = run_window_sampled(
            &mech, &Plain, &mech, &rounds, 0xC0DE, &cohorts, &dropouts,
        );
        for (r, (m, p)) in masked.iter().zip(&plain).enumerate() {
            assert_eq!(m.estimate, p.estimate, "round {r}");
        }
    }

    #[test]
    #[should_panic(expected = "sampled out")]
    fn sampling_sampled_out_client_cannot_submit() {
        let xs = data(0.0);
        let mech = JitterRound;
        let cohorts = [SurvivorSet::with_dropped(3, &[1])];
        let mut session = TransportSession::open_sampled(
            &SecAgg::new(), 9, xs.len(), xs[0].len(), &[5], &cohorts,
        );
        let round = *session.round(0);
        session.submit(0, 1, &mech.encode(1, &xs[1], &round));
    }

    #[test]
    #[should_panic(expected = "sampled out")]
    fn sampling_sampled_out_client_cannot_be_announced_dropped() {
        // a sampled-out client held no masks — announcing it dropped (and
        // "recovering" it) must fail closed
        let xs = data(0.0);
        let mech = JitterRound;
        let cohorts = [SurvivorSet::with_dropped(3, &[1])];
        let mut session = TransportSession::open_sampled(
            &SecAgg::new(), 9, xs.len(), xs[0].len(), &[5], &cohorts,
        );
        let round = *session.round(0);
        for i in [0usize, 2] {
            session.submit(0, i, &mech.encode(i, &xs[i], &round));
        }
        let ann = [RoundDropouts { dropped: vec![1], shares: vec![] }];
        let _ = session.close_with_dropouts(&ann);
    }

    #[test]
    #[should_panic(expected = "fails closed")]
    fn sampling_missing_cohort_member_still_aborts() {
        // completeness is measured against the cohort: a cohort member
        // that never submits (and is not announced) aborts the window
        let xs = data(0.0);
        let mech = JitterRound;
        let cohorts = [SurvivorSet::with_dropped(3, &[1])];
        let mut session = TransportSession::open_sampled(
            &SecAgg::new(), 9, xs.len(), xs[0].len(), &[5], &cohorts,
        );
        let round = *session.round(0);
        session.submit(0, 0, &mech.encode(0, &xs[0], &round));
        // cohort member 2 missing
        let _ = session.close_with_dropouts(&[RoundDropouts::default()]);
    }

    #[test]
    fn sampling_is_complete_measures_the_cohort() {
        let xs = data(0.0);
        let mech = JitterRound;
        let cohorts = [SurvivorSet::with_dropped(3, &[1])];
        let mut session = TransportSession::open_sampled(
            &SecAgg::new(), 9, xs.len(), xs[0].len(), &[5], &cohorts,
        );
        let round = *session.round(0);
        session.submit(0, 0, &mech.encode(0, &xs[0], &round));
        assert!(!session.is_complete());
        session.submit(0, 2, &mech.encode(2, &xs[2], &round));
        assert!(session.is_complete());
    }

    #[test]
    fn dropout_run_window_with_empty_schedule_is_run_window() {
        let inputs = window_inputs();
        let rounds: Vec<(&[Vec<f64>], u64)> =
            inputs.iter().map(|(xs, s)| (xs.as_slice(), *s)).collect();
        let mech = JitterRound;
        let none: Vec<Vec<usize>> = vec![Vec::new(); rounds.len()];
        let a = run_window(&mech, &SecAgg::new(), &mech, &rounds, 0xAB);
        let b = run_window_with_dropouts(&mech, &SecAgg::new(), &mech, &rounds, 0xAB, &none);
        for (oa, ob) in a.iter().zip(&b) {
            assert_eq!(oa.estimate, ob.estimate);
            assert_eq!(oa.bits.messages, ob.bits.messages);
        }
    }
}
