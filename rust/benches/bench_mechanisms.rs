//! Hot-path micro-benchmarks: quantizer draws, mechanism encode/decode,
//! decomposition sampling, entropy coding. (criterion is unavailable in
//! the offline registry; `benchkit` is the in-repo harness.)

use exact_comp::baselines::{Csgm, Ddg, VectorCompressor};
use exact_comp::coding::elias;
use exact_comp::dist::{Gaussian, Laplace};
use exact_comp::mechanisms::traits::MeanMechanism;
use exact_comp::mechanisms::{
    AggregateGaussian, Decomposer, IndividualGaussian, IrwinHallMechanism, LayeredVariant, Sigm,
};
use exact_comp::quantizer::{DirectLayered, PointQuantizer, ShiftedLayered, SubtractiveDither};
use exact_comp::util::benchkit::{black_box, Suite};
use exact_comp::util::rng::Rng;

fn main() {
    let mut s = Suite::from_env();
    let mut rng = Rng::new(1);

    // --- point quantizers -------------------------------------------------
    let dither = SubtractiveDither::new(1.0);
    s.bench("quantizer/dither/quantize", || {
        black_box(dither.quantize(black_box(3.7), &mut rng));
    });
    let direct = DirectLayered::new(Gaussian::new(0.0, 1.0));
    s.bench("quantizer/direct_gaussian/quantize", || {
        black_box(direct.quantize(black_box(3.7), &mut rng));
    });
    let shifted = ShiftedLayered::new(Gaussian::new(0.0, 1.0));
    s.bench("quantizer/shifted_gaussian/quantize", || {
        black_box(shifted.quantize(black_box(3.7), &mut rng));
    });
    let shifted_lap = ShiftedLayered::new(Laplace::with_sd(0.0, 1.0));
    s.bench("quantizer/shifted_laplace/quantize", || {
        black_box(shifted_lap.quantize(black_box(3.7), &mut rng));
    });

    // --- decomposition (the aggregate mechanism's shared randomness) ------
    for n in [4u64, 64, 1024] {
        let dec = Decomposer::new(n);
        s.bench(&format!("decompose/draw/n={n}"), || {
            black_box(dec.draw(&mut rng));
        });
    }

    // --- full mechanism rounds --------------------------------------------
    let d = 128;
    for n in [16usize, 256] {
        let mut drng = Rng::new(2);
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| drng.uniform(-4.0, 4.0)).collect()).collect();
        let elems = Some((n * d) as u64);

        let agg = AggregateGaussian::new(1.0, 8.0);
        let mut seed = 0u64;
        s.bench_elements(&format!("mechanism/aggregate_gaussian/n={n},d={d}"), elems, || {
            seed += 1;
            black_box(agg.aggregate(&xs, seed));
        });
        let ih = IrwinHallMechanism::new(1.0, 8.0);
        s.bench_elements(&format!("mechanism/irwin_hall/n={n},d={d}"), elems, || {
            seed += 1;
            black_box(ih.aggregate(&xs, seed));
        });
        let ind = IndividualGaussian::new(1.0, LayeredVariant::Shifted, 8.0);
        s.bench_elements(&format!("mechanism/individual_shifted/n={n},d={d}"), elems, || {
            seed += 1;
            black_box(ind.aggregate(&xs, seed));
        });
        let sigm = Sigm::new(1.0, 0.5, 4.0);
        s.bench_elements(&format!("mechanism/sigm/n={n},d={d}"), elems, || {
            seed += 1;
            black_box(sigm.aggregate(&xs, seed));
        });
        let csgm = Csgm::new(1.0, 0.5, 4.0, 8);
        s.bench_elements(&format!("baseline/csgm/n={n},d={d}"), elems, || {
            seed += 1;
            black_box(csgm.aggregate(&xs, seed));
        });
    }

    // DDG is heavyweight (rotation + discrete Gaussian + SecAgg): bench small
    {
        let mut drng = Rng::new(3);
        let n = 16;
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..64).map(|_| drng.uniform(-1.0, 1.0)).collect()).collect();
        let ddg = Ddg::new(2.0, 1e-2, 1.0, 22);
        let mut seed = 0u64;
        s.bench_elements("baseline/ddg/n=16,d=64", Some((n * 64) as u64), || {
            seed += 1;
            black_box(ddg.aggregate(&xs, seed));
        });
    }

    // --- compressors (Langevin hot path) ----------------------------------
    {
        let mut drng = Rng::new(4);
        let x: Vec<f64> = (0..256).map(|_| drng.normal()).collect();
        let lb = exact_comp::baselines::LayeredBitsCompressor::new(8);
        s.bench_elements("compressor/layered_bits_b8/d=256", Some(256), || {
            black_box(lb.compress(&x, &mut rng));
        });
        let uq = exact_comp::baselines::UnbiasedQuantizer::new(8);
        s.bench_elements("compressor/unbiased_b8/d=256", Some(256), || {
            black_box(uq.compress(&x, &mut rng));
        });
    }

    // --- coding ------------------------------------------------------------
    {
        let ms: Vec<i64> = (0..1024).map(|i| ((i * 37) % 15) as i64 - 7).collect();
        s.bench_elements("coding/elias_gamma_encode/d=1024", Some(1024), || {
            black_box(elias::encode_vec(&ms));
        });
        let (bytes, _) = elias::encode_vec(&ms);
        s.bench_elements("coding/elias_gamma_decode/d=1024", Some(1024), || {
            black_box(elias::decode_vec(&bytes, ms.len()));
        });
    }

    s.report();
}
