//! (ε, δ) calibration of the Gaussian mechanism.

use crate::util::special::norm_cdf;

/// Classical sufficient condition (Dwork–Roth 2014, used in Eq. 3 of the
/// paper): σ² ≥ 2 Δ² ln(1.25/δ) / ε².
pub fn classical_gaussian_sigma(eps: f64, delta: f64, sensitivity: f64) -> f64 {
    assert!(eps > 0.0 && delta > 0.0 && sensitivity > 0.0);
    sensitivity * (2.0 * (1.25 / delta).ln()).sqrt() / eps
}

/// Exact δ(ε, σ) of the Gaussian mechanism with ℓ2 sensitivity Δ
/// (Balle–Wang 2018, Theorem 8):
/// δ = Φ(Δ/(2σ) − εσ/Δ) − e^ε · Φ(−Δ/(2σ) − εσ/Δ).
pub fn gaussian_delta(eps: f64, sigma: f64, sensitivity: f64) -> f64 {
    let a = sensitivity / (2.0 * sigma);
    let b = eps * sigma / sensitivity;
    (norm_cdf(a - b) - eps.exp() * norm_cdf(-a - b)).max(0.0)
}

/// Minimal σ achieving (ε, δ)-DP (analytic Gaussian mechanism): binary
/// search on the exact δ(ε, σ) curve, which is decreasing in σ.
pub fn analytic_gaussian_sigma(eps: f64, delta: f64, sensitivity: f64) -> f64 {
    assert!(eps > 0.0 && delta > 0.0 && sensitivity > 0.0);
    let mut lo = 1e-8 * sensitivity;
    let mut hi = classical_gaussian_sigma(eps, delta, sensitivity).max(sensitivity) * 4.0;
    // ensure bracketing
    while gaussian_delta(eps, hi, sensitivity) > delta {
        hi *= 2.0;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if gaussian_delta(eps, mid, sensitivity) > delta {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi
}

/// Privacy amplification by subsampling (Poisson sampling rate γ) for an
/// (ε, δ)-DP base mechanism: ε' = ln(1 + γ(e^ε − 1)), δ' = γδ
/// (Balle–Barthe–Gaboardi 2018).
pub fn amplify_by_subsampling(eps: f64, delta: f64, gamma: f64) -> (f64, f64) {
    assert!((0.0..=1.0).contains(&gamma));
    ((1.0 + gamma * (eps.exp() - 1.0)).ln(), gamma * delta)
}

/// Inverse of the amplification: the base ε needed so that after
/// γ-subsampling the released ε equals `eps_target`.
pub fn deamplify_eps(eps_target: f64, gamma: f64) -> f64 {
    assert!(gamma > 0.0);
    (((eps_target.exp() - 1.0) / gamma) + 1.0).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classical_formula() {
        let s = classical_gaussian_sigma(1.0, 1e-5, 1.0);
        assert!((s - (2.0f64 * (1.25e5f64).ln()).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn analytic_beats_classical() {
        // analytic calibration is strictly tighter (smaller σ)
        for &(eps, delta) in &[(0.5, 1e-5), (1.0, 1e-6), (4.0, 1e-5)] {
            let c = classical_gaussian_sigma(eps, delta, 1.0);
            let a = analytic_gaussian_sigma(eps, delta, 1.0);
            assert!(a < c, "eps={eps}: analytic {a} >= classical {c}");
            assert!(a > 0.1 * c, "suspiciously small: {a} vs {c}");
        }
    }

    #[test]
    fn analytic_sigma_achieves_delta() {
        let (eps, delta) = (1.5, 1e-5);
        let s = analytic_gaussian_sigma(eps, delta, 2.0);
        let d = gaussian_delta(eps, s, 2.0);
        assert!(d <= delta * 1.001, "d={d}");
        // and is tight: slightly smaller σ violates δ
        let d2 = gaussian_delta(eps, s * 0.99, 2.0);
        assert!(d2 > delta, "calibration not tight: {d2}");
    }

    #[test]
    fn delta_monotone_in_sigma_and_eps() {
        let d1 = gaussian_delta(1.0, 1.0, 1.0);
        let d2 = gaussian_delta(1.0, 2.0, 1.0);
        assert!(d2 < d1);
        let d3 = gaussian_delta(2.0, 1.0, 1.0);
        assert!(d3 < d1);
    }

    #[test]
    fn amplification_roundtrip() {
        let (eps, gamma) = (0.8, 0.3);
        let (amp, _) = amplify_by_subsampling(eps, 1e-5, gamma);
        assert!(amp < eps);
        let back = deamplify_eps(amp, gamma);
        assert!((back - eps).abs() < 1e-10);
    }

    #[test]
    fn gamma_one_is_identity() {
        let (e, d) = amplify_by_subsampling(1.3, 1e-5, 1.0);
        assert!((e - 1.3).abs() < 1e-12);
        assert!((d - 1e-5).abs() < 1e-18);
    }
}
