//! Compression as randomized smoothing (App. D): the broadcast model is
//! AINQ-compressed with an exact Gaussian error, and clients evaluate
//! subgradients at the compressed point — recovering distributed
//! randomized smoothing with bi-directional compression for free.
//!
//! Run: `cargo run --release --example randomized_smoothing`

use exact_comp::apps::smoothing::{
    drs_compressed, subgradient_descent, L1Problem, SmoothingOpts,
};

fn main() {
    let p = L1Problem::generate(120, 16, 8, 7);
    let iters = 1500;
    println!("distributed L1 regression: f(theta) = (1/m) * sum |a_i' theta - b_i|");
    println!("m = {} rows, d = {}, {} clients\n", p.a.len(), p.dim(), p.n_clients);

    let sg = subgradient_descent(
        &p,
        SmoothingOpts { iters, lr: 0.8, sigma: 0.0, m_samples: 1, seed: 1 },
    );
    let drs = drs_compressed(
        &p,
        SmoothingOpts { iters, lr: 0.25, sigma: 0.05, m_samples: 4, seed: 1 },
    );
    println!("{:>8} {:>18} {:>18}", "iter", "subgradient f", "DRS-compressed f");
    for (a, b) in sg.iter().zip(&drs).step_by(15) {
        println!("{:>8} {:>18.6} {:>18.6}", a.0, a.1, b.1);
    }
    let (sa, sb) = (sg.last().unwrap().1, drs.last().unwrap().1);
    println!("\nfinal: subgradient {sa:.6} | DRS-compressed {sb:.6}");
}
