//! Coordinator / substrate benchmarks: round loop, SecAgg masking, FWHT,
//! Huffman construction, statistics.

use std::sync::Arc;

use exact_comp::coordinator::runtime::{
    run_round, run_round_mech, run_rounds_mech, run_rounds_mech_chunked,
    run_rounds_mech_sampled, run_rounds_mech_with_dropouts, ClientPool,
};
use exact_comp::coordinator::sampling::SamplingPolicy;
use exact_comp::mechanisms::pipeline::{Plain, SecAgg};
use exact_comp::mechanisms::{AggregateGaussian, IrwinHallMechanism};
use exact_comp::secagg::{aggregate_masked, mask_descriptions, SecAggParams};
use exact_comp::transforms::hadamard::{fwht, RandomizedRotation};
use exact_comp::util::benchkit::{black_box, Suite};
use exact_comp::util::rng::Rng;
use exact_comp::util::stats::ks_test;

fn main() {
    let mut s = Suite::new();

    // round loop: parallel local compute + aggregation. Worker count is
    // pinned so numbers are comparable across machines.
    for n in [8usize, 64] {
        let d = 256;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(4),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let mut round = 0u64;
        s.bench_elements(&format!("coordinator/round(n={n},d={d})"), Some((n * d) as u64), || {
            round += 1;
            black_box(run_round(&pool, &mech, round, &[], 42));
        });
        // pipeline shape: per-shard encode, O(d) orchestrator folding
        let mut round2 = 0u64;
        s.bench_elements(
            &format!("coordinator/round_encoded(n={n},d={d})"),
            Some((n * d) as u64),
            || {
                round2 += 1;
                black_box(run_round_mech(&pool, &mech, Arc::new(Plain), round2, &[], 42));
            },
        );
        // the aggregate mechanism's encode is dominated by the
        // Decomposer's ψ-layer boundary search — this series is where the
        // per-n lookup table (built once, bracketing every draw to one
        // table cell) shows up against the old full-range bisection
        let agg = AggregateGaussian::new(0.5, 4.0);
        let mut round3 = 0u64;
        s.bench_elements(
            &format!("coordinator/round_encoded_aggregate(n={n},d={d})"),
            Some((n * d) as u64),
            || {
                round3 += 1;
                black_box(run_round_mech(&pool, &agg, Arc::new(Plain), round3, &[], 42));
            },
        );
    }

    // batched multi-round sessions: one SecAgg opening per window of W
    // rounds, shards answer once per window, unmask batched. W=1 is the
    // single-round baseline; larger W shows the amortization.
    {
        let n = 16usize;
        let d = 256usize;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(4),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        for w in [1usize, 4, 16] {
            let mut start = 0u64;
            s.bench_elements(
                &format!("coordinator/rounds_windowed(n={n},d={d},W={w})"),
                Some((n * d * w) as u64),
                || {
                    let reps = run_rounds_mech(
                        &pool,
                        &mech,
                        Arc::new(SecAgg::new()),
                        start,
                        w,
                        &[],
                        42,
                    );
                    start += w as u64;
                    black_box(reps);
                },
            );
        }

        // dropout-robust windows: same session shape, but every round
        // loses ⌈n/4⌉ announced clients — measures the recovery overhead
        // (share reconstruction + survivor-aware decode) on top of the
        // windowed baseline above. Elements are normalized by SURVIVOR
        // work (n − drops clients actually compute/encode), so the
        // per-element rate is comparable to the no-dropout series.
        for w in [4usize] {
            let drops = n.div_ceil(4);
            let schedule = exact_comp::testing::dropout_schedule(n, w, drops, 0xD20);
            let mut start = 0u64;
            s.bench_elements(
                &format!("coordinator/rounds_windowed_dropout(n={n},d={d},W={w},drop={drops})"),
                Some(((n - drops) * d * w) as u64),
                || {
                    let reps = run_rounds_mech_with_dropouts(
                        &pool,
                        &mech,
                        Arc::new(SecAgg::new()),
                        start,
                        w,
                        &[],
                        42,
                        &schedule,
                    );
                    start += w as u64;
                    black_box(reps);
                },
            );
        }
    }

    // seed-derived client sampling: Poisson(γ) cohorts per round — the
    // shards skip sampled-out clients entirely and the masked session
    // opens over the cohort only, so per-round work scales with γ·n, not
    // n. Elements are normalized by the EXPECTED cohort work (γ·n·d·W),
    // so the per-element rate is comparable to the full-participation
    // windowed series above.
    {
        let n = 16usize;
        let d = 256usize;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(4),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let w = 4usize;
        for gamma in [0.25f64, 0.5] {
            let policy = SamplingPolicy::Poisson { gamma };
            let none: Vec<Vec<usize>> = vec![Vec::new(); w];
            let mut start = 0u64;
            let elements = (gamma * (n * d * w) as f64) as u64;
            s.bench_elements(
                &format!("coordinator/rounds_sampled(n={n},d={d},W={w},gamma={gamma})"),
                Some(elements.max(1)),
                || {
                    let reps = run_rounds_mech_sampled(
                        &pool,
                        &mech,
                        Arc::new(SecAgg::new()),
                        start,
                        w,
                        &[],
                        42,
                        &policy,
                        &none,
                        None,
                    );
                    start += w as u64;
                    black_box(reps);
                },
            );
        }
    }

    // chunked coordinate-space streaming: the same windowed SecAgg
    // session run over chunk plans c ∈ {64, 1024, d} — wall time plus the
    // session's measured peak accumulator bytes, asserting the O(c)
    // memory model (the whole point of chunking: peak scales with c, not
    // d, while estimates stay bit-identical).
    {
        let n = 16usize;
        let d = 4096usize;
        let w = 4usize;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(4),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let mut peaks = Vec::new();
        for chunk in [64usize, 1024, d] {
            let mut start = 0u64;
            let mut peak = 0usize;
            s.bench_elements(
                &format!("coordinator/rounds_chunked(n={n},d={d},W={w},c={chunk})"),
                Some((n * d * w) as u64),
                || {
                    let (reps, stats) = run_rounds_mech_chunked(
                        &pool,
                        &mech,
                        Arc::new(SecAgg::new()),
                        start,
                        w,
                        &[],
                        42,
                        d,
                        chunk,
                    );
                    start += w as u64;
                    peak = peak.max(stats.peak_accumulator_bytes);
                    black_box(reps);
                },
            );
            println!(
                "  coordinator/rounds_chunked(c={chunk}): peak accumulator bytes = {peak}"
            );
            peaks.push((chunk, peak));
        }
        // the memory-model acceptance: peak accumulator bytes are O(c) —
        // the c=64 run must stay far below the whole-d run's peak, and
        // within a small constant of (shards + in-flight) · W · c
        let (c_small, small) = peaks[0];
        let (_, whole) = peaks[peaks.len() - 1];
        assert!(
            small * 8 < whole,
            "chunked peak {small} not O(c) vs whole-d peak {whole}"
        );
        let budget = 3 * (4 + 1) * w * c_small * 8;
        assert!(
            small <= budget,
            "chunked peak {small} exceeds O(shards·W·c) budget {budget}"
        );
    }

    // SecAgg masking
    {
        let params = SecAggParams::default();
        let ms: Vec<i64> = (0..512).map(|i| (i % 13) as i64 - 6).collect();
        s.bench_elements("secagg/mask(d=512,n=16)", Some(512), || {
            black_box(mask_descriptions(&ms, 3, 16, 7, params));
        });
        let masked: Vec<Vec<u64>> =
            (0..16).map(|i| mask_descriptions(&ms, i, 16, 7, params)).collect();
        s.bench_elements("secagg/aggregate(d=512,n=16)", Some(512 * 16), || {
            black_box(aggregate_masked(&masked, params));
        });
    }

    // FWHT + rotation
    {
        let mut rng = Rng::new(1);
        let mut v: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        s.bench_elements("transforms/fwht(4096)", Some(4096), || {
            fwht(black_box(&mut v));
        });
        let rot = RandomizedRotation::new(4096, 5);
        let x: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        s.bench_elements("transforms/rotation_fwd(4096)", Some(4096), || {
            black_box(rot.forward(&x));
        });
    }

    // Huffman build from an empirical description table
    {
        let mut counts = std::collections::HashMap::new();
        for m in -40i64..=40 {
            counts.insert(m, (1000.0 * (-0.15 * (m.abs() as f64)).exp()) as u64 + 1);
        }
        s.bench("coding/huffman_build(81 symbols)", || {
            black_box(exact_comp::coding::huffman::Huffman::from_counts(&counts));
        });
    }

    // KS test (the AINQ verifier)
    {
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        s.bench_elements("stats/ks_test(4000)", Some(4000), || {
            black_box(ks_test(&xs, exact_comp::util::special::norm_cdf));
        });
    }

    s.report();
}
