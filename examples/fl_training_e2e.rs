//! END-TO-END driver: FL training of the MLP through the full three-layer
//! stack on a real (synthetic-classification) workload.
//!
//!   L1/L2: AOT-lowered JAX+Pallas artifacts (`make artifacts`) executed
//!          via PJRT — gradients and eval never touch Python at runtime;
//!   L3:    the rust coordinator aggregates per-round client gradients
//!          through the paper's aggregate Gaussian mechanism and logs the
//!          loss curve + communication bits.
//!
//! Run: `make artifacts && cargo run --release --example fl_training_e2e`

use exact_comp::apps::fl_train::{self, MechKind, TrainOpts};
use exact_comp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load("artifacts").map_err(|e| {
        anyhow::anyhow!("{e:#}\nrun `make artifacts` first")
    })?;
    println!(
        "PJRT engine: platform={}, model={} params, batch={}, {} clients/batch encode tile",
        engine.platform(),
        engine.manifest.param_count,
        engine.manifest.batch,
        engine.manifest.enc_clients,
    );

    let opts = TrainOpts {
        rounds: 300,
        lr: 0.5,
        n_clients: 8,
        clip_c: 0.05,
        mech: MechKind::Aggregate,
        sigma: 1e-3,
        eval_every: 20,
        seed: 0xE2E,
    };
    let data = fl_train::gen_dataset(&engine, opts.n_clients, opts.seed);
    println!("training {} rounds, {} clients, aggregate Gaussian sigma={} ...\n",
             opts.rounds, opts.n_clients, opts.sigma);
    let metrics = fl_train::train(&engine, &data, opts)?;

    println!("{:>7} {:>12} {:>10} {:>8}", "round", "train loss", "eval loss", "acc");
    if let Some(series) = metrics.series("loss") {
        for &(round, eval_loss) in series {
            let train_loss = metrics
                .series("train_loss")
                .and_then(|s| s.iter().find(|&&(r, _)| r == round))
                .map(|&(_, v)| v)
                .unwrap_or(f64::NAN);
            let acc = metrics
                .series("acc")
                .and_then(|s| s.iter().find(|&&(r, _)| r == round))
                .map(|&(_, v)| v)
                .unwrap_or(f64::NAN);
            println!("{round:>7} {train_loss:>12.4} {eval_loss:>10.4} {acc:>8.3}");
        }
    }
    let bits = metrics.mean_of("bits_per_client").unwrap_or(f64::NAN);
    let raw = 32.0 * engine.manifest.param_count as f64;
    println!(
        "\ncommunication: {bits:.0} bits/client/round vs {raw:.0} raw float32 ({:.1}x compression)",
        raw / bits
    );
    metrics.save_csv("results/fl_training_e2e.csv")?;
    println!("loss curve saved to results/fl_training_e2e.csv ({:.1}s total)", metrics.elapsed_secs());
    Ok(())
}
