//! Compression-for-free differential privacy (§5): SIGM vs the CSGM
//! baseline at a matched privacy budget and bit budget, plus the
//! aggregate-Gaussian-vs-DDG comparison of the less-trusted-server setting.
//!
//! Run: `cargo run --release --example dp_mean_estimation`

use exact_comp::apps::mean_estimation::{evaluate, gen_data, DataKind};
use exact_comp::baselines::{Csgm, Ddg};
use exact_comp::dp::accountant::analytic_gaussian_sigma;
use exact_comp::mechanisms::traits::MeanMechanism;
use exact_comp::mechanisms::{AggregateGaussian, Sigm};

fn main() {
    let delta = 1e-5;

    // --- trusted server: SIGM vs CSGM (the Fig. 5 setting) ---------------
    println!("== trusted server: SIGM vs CSGM (n=500, d=100, gamma=0.5) ==");
    let (n, d, gamma) = (500usize, 100usize, 0.5f64);
    let c = 1.0 / (d as f64).sqrt();
    let xs = gen_data(DataKind::BernoulliUniform { p: 0.8 }, n, d, 1);
    println!("{:>5} {:>10} {:>12} {:>12} {:>8}", "eps", "sigma", "MSE SIGM", "MSE CSGM", "bits");
    for eps in [0.5, 1.0, 2.0, 4.0] {
        let sens = (gamma * d as f64).sqrt() * c / (gamma * n as f64);
        let sigma = analytic_gaussian_sigma(eps, delta, sens);
        let sigm = Sigm::new(sigma, gamma, c);
        let r_sigm = evaluate(&sigm, &xs, 20, 100);
        let probe = sigm.aggregate(&xs, 3);
        let bits = (probe.bits.fixed_total.unwrap() / probe.bits.messages as f64).ceil();
        let csgm = Csgm::new(sigma, gamma, c, bits as u32);
        let r_csgm = evaluate(&csgm, &xs, 20, 100);
        println!(
            "{eps:>5} {sigma:>10.3e} {:>12.4e} {:>12.4e} {bits:>8}",
            r_sigm.mse_mean, r_csgm.mse_mean
        );
    }

    // --- less-trusted server: aggregate Gaussian vs DDG (Fig. 6) ---------
    println!("\n== less-trusted server: aggregate Gaussian vs DDG (n=200, d=75) ==");
    let (n, d) = (200usize, 75usize);
    let radius = 10.0;
    let xs = gen_data(DataKind::Sphere { radius }, n, d, 2);
    println!(
        "{:>5} {:>12} {:>10} {:>14} {:>14}",
        "eps", "MSE agg", "agg bits/c", "MSE DDG b=12", "MSE DDG b=18"
    );
    for eps in [2.0, 4.0, 8.0] {
        let sigma = analytic_gaussian_sigma(eps, delta, 2.0 * radius / n as f64);
        let agg = evaluate(&AggregateGaussian::new(sigma, 2.0 * radius), &xs, 15, 200);
        let ddg12 = evaluate(&Ddg::calibrated(eps, delta, radius, n, d, 12, 0.1), &xs, 8, 201);
        let ddg18 = evaluate(&Ddg::calibrated(eps, delta, radius, n, d, 18, 0.1), &xs, 8, 202);
        println!(
            "{eps:>5} {:>12.4e} {:>10.2} {:>14.4e} {:>14.4e}",
            agg.mse_mean,
            agg.bits_var_per_client / d as f64,
            ddg12.mse_mean,
            ddg18.mse_mean
        );
    }
    println!("\n(aggregate Gaussian matches the Gaussian mechanism at ~2-4 bits/coordinate;");
    println!(" DDG needs 12-18 bits to approach the same utility — Fig. 6's headline)");
}
