//! Baseline mechanisms the paper compares against.
//!
//! * [`unbiased_quant`] — classical b-bit dithered quantization after ℓ∞
//!   normalization (App. C intro): the "QLSD* with unbiased quantization"
//!   compressor of Fig. 10.
//! * [`layered_bits`] — the paper's shifted-layered compressor pinned to a
//!   b-bit fixed-length budget via Prop. 2 (the "QLSD*-MS" compressor).
//! * [`csgm`] — CSGM (Chen et al. 2023): coordinate subsampling + b-bit
//!   quantization + additive Gaussian DP noise (Fig. 5 / 7 baseline).
//! * [`ddg`] — Distributed Discrete Gaussian (Kairouz et al. 2021a):
//!   randomized rotation + randomized rounding + discrete Gaussian +
//!   modular SecAgg (Fig. 6 / 8 baseline).

pub mod unbiased_quant;
pub mod layered_bits;
pub mod csgm;
pub mod ddg;

pub use csgm::Csgm;
pub use ddg::Ddg;
pub use layered_bits::LayeredBitsCompressor;
pub use unbiased_quant::UnbiasedQuantizer;

use crate::util::rng::Rng;

/// Result of compressing one client vector.
#[derive(Clone, Debug)]
pub struct CompressedVec {
    /// decoded (decompressed) vector
    pub y: Vec<f64>,
    /// per-coordinate error variance (known to the server for QLSD*'s
    /// noise-compensation step)
    pub err_variance: f64,
    /// bits used to transmit this vector
    pub bits: f64,
}

/// A per-client vector compressor (the 𝒞 operator of App. C.2).
pub trait VectorCompressor {
    fn name(&self) -> String;
    fn compress(&self, x: &[f64], rng: &mut Rng) -> CompressedVec;
}

/// Identity "compressor" (the LSD / no-compression arm of Fig. 10).
#[derive(Clone, Copy, Debug)]
pub struct NoCompression;

impl VectorCompressor for NoCompression {
    fn name(&self) -> String {
        "none".into()
    }

    fn compress(&self, x: &[f64], _rng: &mut Rng) -> CompressedVec {
        CompressedVec { y: x.to_vec(), err_variance: 0.0, bits: 64.0 * x.len() as f64 }
    }
}
