"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dither_encode, dither_decode_mean, matmul
from compile.kernels.ref import (
    dither_encode_ref,
    dither_decode_mean_ref,
    matmul_ref,
)

jax.config.update("jax_platform_name", "cpu")


def _arr(rng, shape, lo=-100.0, hi=100.0):
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# dither encode
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 40),
    d=st.integers(1, 400),
    inv_scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_dither_encode_matches_ref(n, d, inv_scale, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (n, d))
    s = rng.uniform(-0.5, 0.5, size=(n, d)).astype(np.float32)
    got = np.asarray(dither_encode(x, s, inv_scale))
    want = np.asarray(dither_encode_ref(x, s, inv_scale))
    # XLA may fuse x*inv_scale+s into an fma while interpret mode computes in
    # two float32 ops; at exact round-half ties this flips floor(v + 0.5) by
    # one. Accept off-by-one ONLY at near-tie points.
    diff = got - want
    mism = diff != 0
    if mism.any():
        assert np.all(np.abs(diff[mism]) <= 1.0)
        v = x.astype(np.float64) * float(np.float32(inv_scale)) + s
        frac = v[mism] - np.floor(v[mism])
        # "near tie" is relative to the float32 ULP of v (large v => wide ties)
        tol = 4 * np.spacing(np.abs(v[mism]).astype(np.float32)) + 1e-6
        assert np.all(np.abs(frac - 0.5) < tol), (frac, tol)


def test_dither_encode_integer_valued():
    rng = np.random.default_rng(0)
    x = _arr(rng, (16, 257))
    s = rng.uniform(-0.5, 0.5, size=(16, 257)).astype(np.float32)
    m = np.asarray(dither_encode(x, s, 0.37))
    np.testing.assert_array_equal(m, np.round(m))


def test_dither_encode_uniform_error():
    """Subtractive dithering error ~ U(-w/2, w/2) (Example 1): moment check."""
    rng = np.random.default_rng(1)
    w = 0.8
    x = _arr(rng, (64, 512), -10, 10)
    s = rng.uniform(-0.5, 0.5, size=x.shape).astype(np.float32)
    m = np.asarray(dither_encode(x, s, 1.0 / w))
    y = (m - s) * w
    err = (y - x).ravel()
    assert np.all(np.abs(err) <= w / 2 + 1e-5)
    assert abs(err.mean()) < 0.01
    assert abs(err.var() - w**2 / 12) < 0.01


@settings(max_examples=20, deadline=None)
@given(d=st.integers(1, 600), seed=st.integers(0, 2**31 - 1))
def test_dither_decode_matches_ref(d, seed):
    rng = np.random.default_rng(seed)
    m_sum = _arr(rng, (d,), -1e4, 1e4)
    s_sum = _arr(rng, (d,), -50, 50)
    scale, shift, n = 0.123, -4.2, 17.0
    got = np.asarray(dither_decode_mean(m_sum, s_sum, scale, shift, n))
    want = np.asarray(dither_decode_mean_ref(m_sum, s_sum, scale, shift, n))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_encode_decode_roundtrip_mean():
    """n-client Irwin–Hall round trip: decode(sum encode) ≈ mean + IH noise."""
    rng = np.random.default_rng(7)
    n, d, sigma = 16, 256, 0.5
    w = 2 * sigma * np.sqrt(3 * n)
    x = _arr(rng, (n, d), -5, 5)
    s = rng.uniform(-0.5, 0.5, size=(n, d)).astype(np.float32)
    m = np.asarray(dither_encode(x, s, 1.0 / w))
    y = np.asarray(
        dither_decode_mean(m.sum(axis=0), s.sum(axis=0), w, 0.0, float(n))
    )
    err = y - x.mean(axis=0)
    # IH(n, 0, sigma^2) has mean 0, variance sigma^2, support sigma*sqrt(3n)
    assert np.all(np.abs(err) <= sigma * np.sqrt(3 * n) + 1e-4)
    assert abs(err.mean()) < 5 * sigma / np.sqrt(d)
    assert abs(err.var() - sigma**2) < 0.15


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (m, k), -2, 2)
    y = _arr(rng, (k, n), -2, 2)
    got = np.asarray(matmul(x, y))
    want = np.asarray(matmul_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_multi_k_tiles():
    """K > block size exercises the accumulate-over-k grid axis."""
    rng = np.random.default_rng(3)
    x = _arr(rng, (64, 300), -1, 1)
    y = _arr(rng, (300, 32), -1, 1)
    np.testing.assert_allclose(
        np.asarray(matmul(x, y)), np.asarray(matmul_ref(x, y)),
        rtol=1e-4, atol=1e-4,
    )


def test_matmul_gradients_match_jnp():
    """custom_vjp path: grads of a scalar loss agree with pure-jnp grads."""
    rng = np.random.default_rng(4)
    x = _arr(rng, (9, 17), -1, 1)
    y = _arr(rng, (17, 5), -1, 1)

    def f_pallas(x, y):
        return jnp.sum(jnp.tanh(matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.tanh(matmul_ref(x, y)))

    gx, gy = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    rx, ry = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(ry), rtol=1e-4, atol=1e-5)
