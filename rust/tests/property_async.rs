//! The async ≡ barrier property matrix (ISSUE 8): the event-driven
//! work-stealing coordinator must be *bit-identical* — whole
//! `RoundReport`s, ledger spends and all — to the chunk-barrier runner
//! AND the whole-d batched runner on every straggler-free schedule,
//! across mechanisms × {Plain, SecAgg} × chunk ∈ {1, 64, d} × sampling ×
//! dropouts; invariant under worker count and ring depth; and with
//! deadlines on, "straggler past the deadline" must equal
//! "pre-announced dropout" exactly (the conversion happens before any
//! bit is drawn — docs/determinism.md, "Work stealing cannot change any
//! drawn bit", has the argument).
//!
//! Every scheduler run is armed with a [`Watchdog`]: a deadlocked event
//! loop aborts the suite loudly in seconds instead of hanging CI.
//! (`scripts/ci.sh` runs this suite by name; keep `async` in the test
//! names.)

use std::sync::Arc;
use std::time::Duration;

use exact_comp::coordinator::deadline::DeadlinePolicy;
use exact_comp::coordinator::runtime::{
    run_rounds_encoded_async, run_rounds_encoded_chunked, run_rounds_encoded_sampled,
    run_rounds_mech_async, run_rounds_mech_chunked, run_rounds_mech_with_dropouts,
    AsyncRunConfig, ClientPool,
};
use exact_comp::coordinator::sampling::SamplingPolicy;
use exact_comp::dp::PrivacyLedger;
use exact_comp::mechanisms::pipeline::{
    ClientEncoder, Plain, SecAgg, ServerDecoder, Transport,
};
use exact_comp::mechanisms::{AggregateGaussian, IrwinHallMechanism};
use exact_comp::testing::{Fleet, Watchdog};

/// One watchdog limit for every scheduler run in this suite: generous
/// against slow CI hosts, still far below any harness-level timeout.
const WATCHDOG: Duration = Duration::from_secs(120);

/// Mid-round dropout schedule: round 1 loses one member of its cohort.
fn one_dropout_schedule(
    policy: &SamplingPolicy,
    session_seed: u64,
    n: usize,
    window: usize,
) -> Vec<Vec<usize>> {
    (0..window as u64)
        .map(|r| {
            if r == 1 {
                let cohort = policy.cohort(session_seed, r, n);
                if cohort.n_alive() >= 2 {
                    let first = cohort
                        .alive_iter()
                        .next()
                        .expect("a cohort with >= 2 members has a first survivor");
                    return vec![first];
                }
            }
            Vec::new()
        })
        .collect()
}

/// The acceptance matrix cell: run the SAME sampled window with the same
/// dropouts three ways — whole-d batched, chunk-barrier streamed, and
/// async work-stealing — and assert whole-report bit identity plus
/// identical ledger spends.
fn assert_async_cell<M>(
    mech: &M,
    transport: Arc<dyn Transport>,
    policy: &SamplingPolicy,
    n: usize,
    dim: usize,
    chunk: usize,
    root_seed: u64,
) where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let _wd = Watchdog::arm("async-matrix-cell", WATCHDOG);
    let window = 3usize;
    let fleet = Fleet::new(n, dim, root_seed ^ 0xDA7A);
    let pool = ClientPool::spawn(n, Arc::new(fleet.compute()));
    let dropouts = one_dropout_schedule(policy, root_seed, n, window);
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());

    let mut ledger_whole = PrivacyLedger::new(1.0, 1e-5);
    let whole = run_rounds_encoded_sampled(
        &pool,
        encoder.clone(),
        transport.clone(),
        mech,
        0,
        window,
        &[],
        root_seed,
        policy,
        &dropouts,
        Some(&mut ledger_whole),
    );
    let mut ledger_chunked = PrivacyLedger::new(1.0, 1e-5);
    let (chunked, _) = run_rounds_encoded_chunked(
        &pool,
        encoder.clone(),
        transport.clone(),
        mech,
        0,
        window,
        &[],
        root_seed,
        policy,
        &dropouts,
        Some(&mut ledger_chunked),
        dim,
        chunk,
    );
    let mut ledger_async = PrivacyLedger::new(1.0, 1e-5);
    let (async_reports, stats) = run_rounds_encoded_async(
        &pool,
        encoder,
        transport.clone(),
        mech,
        0,
        window,
        &[],
        root_seed,
        policy,
        &dropouts,
        Some(&mut ledger_async),
        &AsyncRunConfig::new(dim, chunk),
    );

    let tag = format!("{}/chunk={chunk}/seed={root_seed:#x}", transport.name());
    assert_eq!(async_reports, whole, "{tag}: async runner != whole-d batched runner");
    assert_eq!(async_reports, chunked, "{tag}: async runner != chunk-barrier runner");
    assert_eq!(
        ledger_async.snapshot(),
        ledger_whole.snapshot(),
        "{tag}: async ledger spends diverge from the whole-d runner"
    );
    assert_eq!(
        ledger_async.snapshot(),
        ledger_chunked.snapshot(),
        "{tag}: async ledger spends diverge from the chunk-barrier runner"
    );
    assert_eq!(stats.converted_stragglers, 0, "{tag}: no deadline means no conversions");
}

/// The CI async identity matrix: both homomorphic mechanisms × {Plain,
/// SecAgg} × chunk ∈ {1, 64 (clamps to whole-d), d} × {Full, FixedSize}
/// sampling, with a mid-round dropout — every cell bit-identical to both
/// barrier runners.
#[test]
fn async_matrix_matches_chunked_and_whole_d_runners() {
    let (n, dim) = (6usize, 11usize);
    let secagg: Arc<dyn Transport> = Arc::new(SecAgg::new());
    let plain: Arc<dyn Transport> = Arc::new(Plain);
    let ih = IrwinHallMechanism::new(0.4, 8.0);
    let ag = AggregateGaussian::new(0.6, 8.0);
    for chunk in [1usize, 64, dim] {
        for (policy, seed) in [
            (SamplingPolicy::Full, 0xA51u64),
            (SamplingPolicy::FixedSize { k: 4 }, 0xA52),
        ] {
            assert_async_cell(&ih, plain.clone(), &policy, n, dim, chunk, seed);
            assert_async_cell(&ih, secagg.clone(), &policy, n, dim, chunk, seed);
            assert_async_cell(&ag, plain.clone(), &policy, n, dim, chunk, seed ^ 1);
            assert_async_cell(&ag, secagg.clone(), &policy, n, dim, chunk, seed ^ 1);
        }
    }
}

/// Worker count and ring depth are pure scheduling knobs: every
/// (workers, ring) pair produces the identical report vector. THE
/// determinism claim of the work-stealing design, as an integration
/// property.
#[test]
fn async_reports_invariant_under_workers_and_ring() {
    let _wd = Watchdog::arm("async-workers-ring", WATCHDOG);
    let (n, dim, chunk) = (7usize, 13usize, 3usize);
    let fleet = Fleet::new(n, dim, 0x9A9A);
    let pool = ClientPool::spawn(n, Arc::new(fleet.compute()));
    let mech = IrwinHallMechanism::new(0.4, 8.0);
    let baseline = run_rounds_mech_async(
        &pool,
        &mech,
        Arc::new(SecAgg::new()),
        5,
        3,
        &[],
        0xB00C,
        &AsyncRunConfig::new(dim, chunk),
    )
    .0;
    for workers in [1usize, 3, 8] {
        for ring in [1usize, 2, 4] {
            let cfg = AsyncRunConfig::new(dim, chunk).with_workers(workers).with_ring(ring);
            let got = run_rounds_mech_async(
                &pool,
                &mech,
                Arc::new(SecAgg::new()),
                5,
                3,
                &[],
                0xB00C,
                &cfg,
            )
            .0;
            assert_eq!(
                got, baseline,
                "workers={workers}, ring={ring}: scheduling knobs changed a bit"
            );
        }
    }
}

/// `deadline = None` (∞) draws nothing from the DEADLINE domain, so the
/// async runner IS the chunk-barrier runner exactly — the degenerate end
/// of the deadline-identity family.
#[test]
fn async_infinite_deadline_is_the_barrier_runner_exactly() {
    let _wd = Watchdog::arm("async-infinite-deadline", WATCHDOG);
    let (n, dim, chunk) = (6usize, 9usize, 4usize);
    let fleet = Fleet::new(n, dim, 0x1DEA);
    let pool = ClientPool::spawn(n, Arc::new(fleet.compute()));
    let mech = AggregateGaussian::new(0.5, 8.0);
    let (barrier, _) = run_rounds_mech_chunked(
        &pool,
        &mech,
        Arc::new(SecAgg::new()),
        2,
        3,
        &[],
        0xFEED,
        dim,
        chunk,
    );
    let cfg = AsyncRunConfig::new(dim, chunk).with_deadline(DeadlinePolicy::none());
    let (async_reports, stats) = run_rounds_mech_async(
        &pool,
        &mech,
        Arc::new(SecAgg::new()),
        2,
        3,
        &[],
        0xFEED,
        &cfg,
    );
    assert_eq!(async_reports, barrier);
    assert_eq!(stats.converted_stragglers, 0, "an infinite deadline converts nobody");
}

/// The deadline identity: a straggler past the virtual deadline is a
/// pre-announced dropout, bit for bit. The expected schedule comes from
/// `DeadlinePolicy::convert` (the same pure function the runner calls),
/// fed to the barrier runner as explicit announced dropouts.
#[test]
fn async_straggler_past_deadline_equals_preannounced_dropout() {
    use exact_comp::mechanisms::pipeline::SurvivorSet;
    let _wd = Watchdog::arm("async-deadline-identity", WATCHDOG);
    let (n, dim, chunk, window) = (8usize, 7usize, 3usize, 3usize);
    let policy = DeadlinePolicy::with_deadline(2.0, 0.35, 1.0);
    let fleet = Fleet::new(n, dim, 0x57A6);
    let pool = ClientPool::spawn(n, Arc::new(fleet.compute()));
    let mech = IrwinHallMechanism::new(0.4, 8.0);
    let mut checked = 0u32;
    for root_seed in 0x600u64..0x640 {
        let cohorts = vec![SurvivorSet::full(n); window];
        let none: Vec<Vec<usize>> = vec![Vec::new(); window];
        let (merged, converted) = policy.convert(root_seed, 4, &cohorts, &none);
        if converted == 0 {
            continue;
        }
        let reference = run_rounds_mech_with_dropouts(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            4,
            window,
            &[],
            root_seed,
            &merged,
        );
        let cfg = AsyncRunConfig::new(dim, chunk).with_deadline(policy);
        let (async_reports, stats) = run_rounds_mech_async(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            4,
            window,
            &[],
            root_seed,
            &cfg,
        );
        assert_eq!(
            async_reports, reference,
            "seed {root_seed:#x}: deadline conversion != pre-announced dropout"
        );
        assert_eq!(stats.converted_stragglers, converted, "seed {root_seed:#x}");
        checked += 1;
        if checked >= 4 {
            break;
        }
    }
    assert!(
        checked >= 4,
        "rate 0.35 over 64 seeds must produce at least 4 windows with conversions"
    );
}

/// A window whose every cohort member misses the deadline is an
/// operational error, not a recoverable dropout: the runner fails closed
/// naming the global round before any shard computes.
#[test]
#[should_panic(expected = "round 7 (window round 0) would close with zero survivors")]
fn async_converting_every_survivor_fails_closed_naming_the_round() {
    let n = 4usize;
    let fleet = Fleet::new(n, 5, 0xDEAD);
    let pool = ClientPool::spawn(n, Arc::new(fleet.compute()));
    let mech = IrwinHallMechanism::new(0.4, 8.0);
    // rate 1 and a deadline below the Pareto scale: EVERY client misses
    let cfg = AsyncRunConfig::new(5, 2)
        .with_deadline(DeadlinePolicy::with_deadline(0.5, 1.0, 1.0));
    let _ = run_rounds_mech_async(&pool, &mech, Arc::new(Plain), 7, 2, &[], 0x17, &cfg);
}

/// A panicking encode task must surface through the event loop as a
/// named worker failure carrying the original message — never a bare
/// channel-disconnect panic, never a hang (the watchdog proves the
/// latter).
#[test]
fn async_worker_panic_propagates_worker_and_message() {
    let _wd = Watchdog::arm("async-panic-propagation", WATCHDOG);
    let n = 6usize;
    let pool = ClientPool::spawn(
        n,
        Arc::new(|c: usize, _r: u64, _s: &[f64]| {
            if c == 3 {
                panic!("client 3 exploded in the async suite");
            }
            vec![1.0; 6]
        }),
    );
    let mech = IrwinHallMechanism::new(0.4, 8.0);
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_rounds_mech_async(
            &pool,
            &mech,
            Arc::new(Plain),
            0,
            2,
            &[],
            0x30,
            &AsyncRunConfig::new(6, 2),
        )
    }))
    .expect_err("a panicking client must fail the async run");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .expect("panic payload is a string");
    assert!(msg.contains("async worker"), "panic must name the worker: {msg}");
    assert!(
        msg.contains("client 3 exploded in the async suite"),
        "panic must carry the original cause: {msg}"
    );
}
