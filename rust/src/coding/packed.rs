//! Bit-packed ℤ_m residue vectors: the wire format of every masked
//! transport payload and session accumulator slot.
//!
//! The paper's whole point is cutting communication, yet a residue mod
//! m = 2⁴⁰ carried in a `u64` wastes 24 of its 64 bits — and quantizer
//! description spaces are narrower still. [`PackedZm`] stores `len`
//! residues at their fixed width w = ⌈log₂ m⌉ in `⌈len·w/64⌉` little-
//! endian u64 words (LSB-first within each word, the word-oriented
//! sibling of the byte-MSB [`super::bitio`] codecs), shrinking payload
//! and accumulator bytes by 64/w. [`PackedZm::byte_len`] is the single
//! source of truth for wire size: ⌈len·w/64⌉·8 bytes, exactly the
//! per-slot bound the session and coordinator memory models assert.
//!
//! Arithmetic never happens on packed words. The accumulate paths
//! ([`PackedZm::fold_residues`], [`PackedZm::add_assign_mod`]) unpack a
//! fixed [`PACK_BLOCK`]-residue block into on-stack scratch, add on the
//! proven u64 path, and repack — the same SoA scratch discipline as the
//! `CoordLanes` kernels (`util::rng`), with [`PACK_BLOCK`] a multiple of
//! 64 so every block starts word-aligned for ANY width. Packing is a
//! pure re-layout of already-drawn residues, so packed ≡ unpacked is a
//! bit identity on every residue (docs/determinism.md, "Packed words
//! cannot change any drawn bit").

/// Residues per pack/unpack kernel block. A multiple of 64, so a block
/// boundary `b·PACK_BLOCK·w` bits is word-aligned for every width w —
/// blocks pack and repack independently without read-modify-write of a
/// neighbour's word. 1024 residues = 8 KiB of u64 scratch, L1-resident.
pub const PACK_BLOCK: usize = 1024;

/// Fixed residue width for modulus m: w = ⌈log₂ m⌉ bits represent every
/// residue in [0, m). Deterministic in m alone — both ends of a wire
/// derive the same layout from the transport's modulus, no negotiation.
///
/// Panics on m < 2 (a zero/unit modulus has no residues to pack).
pub fn width_for_modulus(modulus: u64) -> u32 {
    assert!(modulus >= 2, "packed ℤ_m needs a modulus >= 2, got {modulus}");
    64 - (modulus - 1).leading_zeros()
}

#[inline]
fn width_mask(width: u32) -> u64 {
    if width == 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// a + b mod m for a, b < m, carry-aware: correct for every m ≥ 2 (the
/// intermediate sum may wrap u64; the wrap implies exactly one
/// subtraction of m is due).
#[inline]
fn add_mod_residue(a: u64, b: u64, m: u64) -> u64 {
    debug_assert!(a < m && b < m);
    let (s, carry) = a.overflowing_add(b);
    if carry || s >= m {
        s.wrapping_sub(m)
    } else {
        s
    }
}

/// A fixed-width packed vector of residues mod m.
///
/// Representation is canonical: every residue is < m (asserted on every
/// ingest path) and the bits past `len·w` in the last word are zero — so
/// the derived `PartialEq` is exactly residue-sequence equality, which
/// is what the snapshot round-trip and bit-identity tests compare.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedZm {
    modulus: u64,
    width: u32,
    len: usize,
    words: Vec<u64>,
}

impl PackedZm {
    /// Packed word count for `len` residues mod `modulus`: ⌈len·w/64⌉.
    fn word_count(len: usize, width: u32) -> usize {
        len.checked_mul(width as usize)
            .expect("packed bit length overflows usize")
            .div_ceil(64)
    }

    /// The wire size in bytes of `len` residues mod `modulus` —
    /// ⌈len·w/64⌉·8 — without constructing a vector. This is the
    /// per-slot accumulator bound the memory-model tests and benches
    /// assert against.
    pub fn byte_len_for(len: usize, modulus: u64) -> usize {
        Self::word_count(len, width_for_modulus(modulus)) * 8
    }

    /// All-zero residue vector (the identity of `add_assign_mod`).
    pub fn zeros(len: usize, modulus: u64) -> Self {
        let width = width_for_modulus(modulus);
        Self { modulus, width, len, words: vec![0u64; Self::word_count(len, width)] }
    }

    /// Pack a residue slice. Every residue must already be reduced
    /// (< modulus) — packing is a re-layout, never arithmetic, so an
    /// unreduced input fails loudly instead of silently truncating.
    pub fn from_residues(residues: &[u64], modulus: u64) -> Self {
        let mut out = Self::zeros(residues.len(), modulus);
        if !residues.is_empty() {
            out.pack_block(0, residues);
        }
        out
    }

    /// Reassemble from externalized parts (the snapshot read path).
    /// Fails closed on a word count that disagrees with (len, modulus),
    /// a dirty tail (bits past len·w set), or an unreduced residue —
    /// a corrupt snapshot must never yield a plausible-but-wrong vector.
    pub fn from_raw_parts(modulus: u64, len: usize, words: Vec<u64>) -> Self {
        let width = width_for_modulus(modulus);
        let expect = Self::word_count(len, width);
        assert!(
            words.len() == expect,
            "packed ℤ_m fails closed: {} words for {len} residues of width {width} \
             (expected {expect})",
            words.len(),
        );
        let tail_bits = (len * width as usize) % 64;
        if tail_bits != 0 {
            let last = *words.last().expect("tail_bits != 0 implies a last word");
            assert!(
                last >> tail_bits == 0,
                "packed ℤ_m fails closed: dirty bits past the final residue"
            );
        }
        let out = Self { modulus, width, len, words };
        for i in 0..len {
            let r = out.get(i);
            assert!(r < modulus, "packed ℤ_m fails closed: residue {r} >= modulus {modulus}");
        }
        out
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn modulus(&self) -> u64 {
        self.modulus
    }

    /// Fixed residue width w = ⌈log₂ m⌉.
    pub fn width(&self) -> u32 {
        self.width
    }

    /// The packed words (what the snapshot format serializes).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Payload bytes on the wire / in an accumulator slot:
    /// ⌈len·w/64⌉·8. The single source of truth every byte-accounting
    /// path (`TransportPartial::wire_bytes`, session peaks, runner
    /// `wire_bytes` counters) routes through.
    pub fn byte_len(&self) -> usize {
        self.words.len() * 8
    }

    /// Residue i.
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        assert!(i < self.len, "index {i} out of bounds for {} residues", self.len);
        let w = self.width as usize;
        let bit = i * w;
        let (wi, off) = (bit / 64, bit % 64);
        let mut v = self.words[wi] >> off;
        if off + w > 64 {
            v |= self.words[wi + 1] << (64 - off);
        }
        v & width_mask(self.width)
    }

    /// Unpack the whole vector into `out` (length must match).
    pub fn unpack_into(&self, out: &mut [u64]) {
        assert_eq!(out.len(), self.len, "unpack buffer length mismatch");
        if self.len > 0 {
            self.unpack_block(0, out);
        }
    }

    /// Unpack into a fresh buffer.
    pub fn to_residues(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.len];
        self.unpack_into(&mut out);
        out
    }

    /// Streaming unpack of `out.len()` residues starting at residue `lo`;
    /// `lo` must be block-aligned (`lo % PACK_BLOCK == 0`) so the read
    /// starts on a word boundary.
    fn unpack_block(&self, lo: usize, out: &mut [u64]) {
        debug_assert!(lo % PACK_BLOCK == 0, "block start {lo} not PACK_BLOCK-aligned");
        debug_assert!(lo + out.len() <= self.len);
        let w = self.width as usize;
        if w == 64 {
            out.copy_from_slice(&self.words[lo..lo + out.len()]);
            return;
        }
        let mask = width_mask(self.width);
        let mut wi = lo * w / 64;
        let mut off = 0usize;
        for o in out.iter_mut() {
            let mut v = self.words[wi] >> off;
            if off + w > 64 {
                v |= self.words[wi + 1] << (64 - off);
            }
            *o = v & mask;
            off += w;
            if off >= 64 {
                off -= 64;
                wi += 1;
            }
        }
    }

    /// Streaming pack of `block` residues starting at residue `lo`; `lo`
    /// must be block-aligned and the write must either fill whole words
    /// or end at the vector's tail (both hold for PACK_BLOCK blocks and
    /// the final partial block), so no neighbouring bits need preserving.
    fn pack_block(&mut self, lo: usize, block: &[u64]) {
        debug_assert!(lo % PACK_BLOCK == 0, "block start {lo} not PACK_BLOCK-aligned");
        let w = self.width as usize;
        debug_assert!(
            lo + block.len() == self.len || (block.len() * w) % 64 == 0,
            "pack_block must end at the vector tail or on a word boundary"
        );
        let m = self.modulus;
        if w == 64 {
            for &r in block {
                assert!(r < m, "residue {r} out of range for modulus {m}");
            }
            self.words[lo..lo + block.len()].copy_from_slice(block);
            return;
        }
        let mut wi = lo * w / 64;
        let mut acc = 0u64;
        let mut fill = 0usize;
        for &r in block {
            assert!(r < m, "residue {r} out of range for modulus {m}");
            acc |= r << fill;
            if fill + w >= 64 {
                self.words[wi] = acc;
                wi += 1;
                acc = if fill > 0 { r >> (64 - fill) } else { 0 };
                fill = fill + w - 64;
            } else {
                fill += w;
            }
        }
        if fill > 0 {
            // the vector tail: bits past len·w stay zero (canonical form)
            self.words[wi] = acc;
        }
    }

    /// Masked accumulation against an unpacked residue slice: unpack one
    /// PACK_BLOCK of self into on-stack scratch, add mod m on the u64
    /// path, repack — O(PACK_BLOCK) live scratch however long the
    /// vector. The summing transports fold every client's masked chunk
    /// through this, so accumulator slots stay packed between folds.
    pub fn fold_residues(&mut self, residues: &[u64]) {
        assert_eq!(
            residues.len(),
            self.len,
            "residue length changed mid-accumulation"
        );
        let m = self.modulus;
        let mut scratch = [0u64; PACK_BLOCK];
        let mut lo = 0usize;
        while lo < self.len {
            let take = PACK_BLOCK.min(self.len - lo);
            let s = &mut scratch[..take];
            self.unpack_block(lo, s);
            for (a, &v) in s.iter_mut().zip(&residues[lo..lo + take]) {
                assert!(v < m, "residue {v} out of range for modulus {m}");
                *a = add_mod_residue(*a, v, m);
            }
            self.pack_block(lo, s);
            lo += take;
        }
    }

    /// Merge another packed accumulator: self[i] = (self[i] + other[i])
    /// mod m, blockwise through the same scratch discipline.
    pub fn add_assign_mod(&mut self, other: &PackedZm) {
        assert_eq!(self.modulus, other.modulus, "modulus mismatch in packed merge");
        assert_eq!(self.len, other.len, "length mismatch in packed merge");
        let m = self.modulus;
        let mut sa = [0u64; PACK_BLOCK];
        let mut sb = [0u64; PACK_BLOCK];
        let mut lo = 0usize;
        while lo < self.len {
            let take = PACK_BLOCK.min(self.len - lo);
            self.unpack_block(lo, &mut sa[..take]);
            other.unpack_block(lo, &mut sb[..take]);
            for (a, &b) in sa[..take].iter_mut().zip(&sb[..take]) {
                *a = add_mod_residue(*a, b, m);
            }
            self.pack_block(lo, &sa[..take]);
            lo += take;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const MODULI: [u64; 5] = [1 << 8, 1 << 12, 1 << 40, 999_983, 77];

    fn random_residues(rng: &mut Rng, len: usize, m: u64) -> Vec<u64> {
        (0..len).map(|_| rng.below(m)).collect()
    }

    #[test]
    fn packed_width_formula() {
        assert_eq!(width_for_modulus(2), 1);
        assert_eq!(width_for_modulus(3), 2);
        assert_eq!(width_for_modulus(256), 8);
        assert_eq!(width_for_modulus(257), 9);
        assert_eq!(width_for_modulus(1 << 40), 40);
        assert_eq!(width_for_modulus((1 << 40) + 1), 41);
        assert_eq!(width_for_modulus(u64::MAX), 64);
    }

    #[test]
    #[should_panic(expected = "modulus >= 2")]
    fn packed_width_rejects_unit_modulus() {
        let _ = width_for_modulus(1);
    }

    #[test]
    fn packed_roundtrip_every_modulus_and_ragged_length() {
        let mut rng = Rng::new(0x9AC7);
        for &m in &MODULI {
            for len in [0usize, 1, 7, 63, 64, 65, PACK_BLOCK - 1, PACK_BLOCK, PACK_BLOCK + 3] {
                let rs = random_residues(&mut rng, len, m);
                let p = PackedZm::from_residues(&rs, m);
                assert_eq!(p.len(), len);
                assert_eq!(p.to_residues(), rs, "m={m} len={len}");
                for (i, &r) in rs.iter().enumerate() {
                    assert_eq!(p.get(i), r, "m={m} len={len} i={i}");
                }
            }
        }
    }

    #[test]
    fn packed_byte_len_is_the_ceil_formula() {
        for &m in &MODULI {
            let w = width_for_modulus(m) as usize;
            for len in [0usize, 1, 7, 64, 100, 1025] {
                let p = PackedZm::zeros(len, m);
                assert_eq!(p.byte_len(), (len * w).div_ceil(64) * 8, "m={m} len={len}");
                assert_eq!(p.byte_len(), PackedZm::byte_len_for(len, m));
            }
        }
        // the headline shrink: 2^40 residues ride 40 bits, not 64
        assert_eq!(PackedZm::byte_len_for(64, 1 << 40), 40 * 8);
    }

    #[test]
    fn packed_width_64_degenerates_to_plain_words() {
        let mut rng = Rng::new(3);
        let rs = random_residues(&mut rng, 130, u64::MAX);
        let p = PackedZm::from_residues(&rs, u64::MAX);
        assert_eq!(p.width(), 64);
        assert_eq!(p.words(), &rs[..]);
        assert_eq!(p.to_residues(), rs);
    }

    #[test]
    fn packed_fold_matches_scalar_mod_arithmetic() {
        let mut rng = Rng::new(0xF01D);
        for &m in &MODULI {
            for len in [1usize, 7, 64, PACK_BLOCK + 5] {
                let a = random_residues(&mut rng, len, m);
                let b = random_residues(&mut rng, len, m);
                let mut p = PackedZm::from_residues(&a, m);
                p.fold_residues(&b);
                let want: Vec<u64> =
                    a.iter().zip(&b).map(|(&x, &y)| add_mod_residue(x, y, m)).collect();
                assert_eq!(p.to_residues(), want, "m={m} len={len}");
            }
        }
    }

    #[test]
    fn packed_merge_matches_fold() {
        let mut rng = Rng::new(0x3E6);
        for &m in &MODULI {
            let len = PACK_BLOCK + 17;
            let a = random_residues(&mut rng, len, m);
            let b = random_residues(&mut rng, len, m);
            let mut via_merge = PackedZm::from_residues(&a, m);
            via_merge.add_assign_mod(&PackedZm::from_residues(&b, m));
            let mut via_fold = PackedZm::from_residues(&a, m);
            via_fold.fold_residues(&b);
            assert_eq!(via_merge, via_fold, "m={m}");
            let mut zero = PackedZm::zeros(len, m);
            zero.add_assign_mod(&via_merge);
            assert_eq!(zero, via_merge, "zeros is the merge identity, m={m}");
        }
    }

    #[test]
    fn packed_equality_is_residue_equality() {
        // canonical form: two packings of the same residues are equal as
        // words, so PartialEq on PackedZm == equality of residue vectors
        let mut rng = Rng::new(44);
        let rs = random_residues(&mut rng, 99, 1 << 12);
        let a = PackedZm::from_residues(&rs, 1 << 12);
        let mut b = PackedZm::zeros(99, 1 << 12);
        b.fold_residues(&rs);
        assert_eq!(a, b);
    }

    #[test]
    fn packed_raw_parts_roundtrip() {
        let mut rng = Rng::new(0x5AF);
        let rs = random_residues(&mut rng, 130, 999_983);
        let p = PackedZm::from_residues(&rs, 999_983);
        let q = PackedZm::from_raw_parts(p.modulus(), p.len(), p.words().to_vec());
        assert_eq!(p, q);
    }

    #[test]
    #[should_panic(expected = "fails closed")]
    fn packed_raw_parts_rejects_word_count_mismatch() {
        let _ = PackedZm::from_raw_parts(1 << 8, 100, vec![0u64; 3]);
    }

    #[test]
    #[should_panic(expected = "dirty bits")]
    fn packed_raw_parts_rejects_dirty_tail() {
        // 3 residues of width 8 occupy 24 bits of one word; bit 60 is junk
        let _ = PackedZm::from_raw_parts(1 << 8, 3, vec![1u64 << 60]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn packed_rejects_unreduced_residue() {
        let _ = PackedZm::from_residues(&[256], 1 << 8);
    }
}
