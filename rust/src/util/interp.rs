//! Interpolation and quadrature helpers used by the Irwin–Hall density grid
//! (see `dist::irwin_hall`): a uniform-grid cubic (Catmull–Rom) interpolant
//! with analytic derivative, plus composite Simpson integration.

/// Cubic interpolation on a uniform grid.
///
/// Stores values `y[i] = f(x0 + i*dx)` and evaluates f and f' anywhere in
/// `[x0, x0 + (len-1)*dx]` with Catmull–Rom splines (C¹, exact on cubics up
/// to boundary cells).
#[derive(Clone, Debug)]
pub struct UniformGrid {
    pub x0: f64,
    pub dx: f64,
    pub y: Vec<f64>,
}

impl UniformGrid {
    pub fn new(x0: f64, dx: f64, y: Vec<f64>) -> Self {
        assert!(y.len() >= 4, "grid needs >= 4 points");
        assert!(dx > 0.0);
        Self { x0, dx, y }
    }

    pub fn x_max(&self) -> f64 {
        self.x0 + (self.y.len() - 1) as f64 * self.dx
    }

    #[inline]
    fn locate(&self, x: f64) -> (usize, f64) {
        let t = (x - self.x0) / self.dx;
        let i = (t.floor() as isize).clamp(0, self.y.len() as isize - 2) as usize;
        (i, t - i as f64)
    }

    #[inline]
    fn stencil(&self, i: usize) -> (f64, f64, f64, f64) {
        let n = self.y.len();
        let ym = if i == 0 { 2.0 * self.y[0] - self.y[1] } else { self.y[i - 1] };
        let yp2 = if i + 2 >= n { 2.0 * self.y[n - 1] - self.y[n - 2] } else { self.y[i + 2] };
        (ym, self.y[i], self.y[i + 1], yp2)
    }

    /// Interpolated value at x (clamped to the grid domain).
    pub fn eval(&self, x: f64) -> f64 {
        let (i, t) = self.locate(x);
        let (y0, y1, y2, y3) = self.stencil(i);
        // Catmull-Rom basis
        let a = -0.5 * y0 + 1.5 * y1 - 1.5 * y2 + 0.5 * y3;
        let b = y0 - 2.5 * y1 + 2.0 * y2 - 0.5 * y3;
        let c = -0.5 * y0 + 0.5 * y2;
        ((a * t + b) * t + c) * t + y1
    }

    /// Interpolated derivative d f / d x at x.
    pub fn eval_deriv(&self, x: f64) -> f64 {
        let (i, t) = self.locate(x);
        let (y0, y1, y2, y3) = self.stencil(i);
        let a = -0.5 * y0 + 1.5 * y1 - 1.5 * y2 + 0.5 * y3;
        let b = y0 - 2.5 * y1 + 2.0 * y2 - 0.5 * y3;
        let c = -0.5 * y0 + 0.5 * y2;
        ((3.0 * a * t + 2.0 * b) * t + c) / self.dx
    }
}

/// Composite Simpson integration of `f` over [a, b] with n panels
/// (n rounded up to even).
pub fn simpson(f: impl Fn(f64) -> f64, a: f64, b: f64, n: usize) -> f64 {
    let n = if n % 2 == 0 { n } else { n + 1 };
    let h = (b - a) / n as f64;
    let mut s = f(a) + f(b);
    for i in 1..n {
        let x = a + i as f64 * h;
        s += f(x) * if i % 2 == 1 { 4.0 } else { 2.0 };
    }
    s * h / 3.0
}

/// Bisection root of a monotone function: returns x in [lo, hi] with
/// f(x) ≈ target, assuming f decreasing (dec=true) or increasing.
pub fn bisect_monotone(
    f: impl Fn(f64) -> f64,
    target: f64,
    mut lo: f64,
    mut hi: f64,
    dec: bool,
    iters: usize,
) -> f64 {
    for _ in 0..iters {
        let mid = 0.5 * (lo + hi);
        let v = f(mid);
        let go_right = if dec { v > target } else { v < target };
        if go_right {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_reproduces_quadratic_exactly() {
        // Catmull-Rom uses central-difference tangents: exact on quadratics
        let x0 = -2.0;
        let dx = 0.1;
        let y: Vec<f64> = (0..41).map(|i| {
            let x = x0 + i as f64 * dx;
            x * x - 2.0 * x
        }).collect();
        let g = UniformGrid::new(x0, dx, y);
        for i in 0..200 {
            let x = -1.8 + i as f64 * 0.018; // interior
            let want = x * x - 2.0 * x;
            assert!((g.eval(x) - want).abs() < 1e-10, "x={x}");
            let dwant = 2.0 * x - 2.0;
            assert!((g.eval_deriv(x) - dwant).abs() < 1e-8, "x={x}");
        }
    }

    #[test]
    fn grid_approximates_smooth_function() {
        // O(dx^3) accuracy on a generic smooth function
        let x0 = 0.0;
        let dx = 0.01;
        let y: Vec<f64> = (0..501).map(|i| ((x0 + i as f64 * dx) * 2.0).sin()).collect();
        let g = UniformGrid::new(x0, dx, y);
        for i in 0..400 {
            let x = 0.05 + i as f64 * 0.012;
            assert!((g.eval(x) - (2.0 * x).sin()).abs() < 1e-5, "x={x}");
            assert!((g.eval_deriv(x) - 2.0 * (2.0 * x).cos()).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn simpson_exact_on_polynomials() {
        let v = simpson(|x| x * x * x, 0.0, 2.0, 8);
        assert!((v - 4.0).abs() < 1e-12);
        let v = simpson(|x| x.sin(), 0.0, std::f64::consts::PI, 200);
        assert!((v - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bisect_finds_root() {
        // decreasing f(x) = e^{-x}, solve e^{-x} = 0.3
        let x = bisect_monotone(|x| (-x).exp(), 0.3, 0.0, 10.0, true, 80);
        assert!((x - (1.0f64 / 0.3).ln()).abs() < 1e-10);
    }
}
