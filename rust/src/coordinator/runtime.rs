//! The threaded FL round runtime: a persistent pool of client workers that
//! compute local updates in parallel, plus the round loops that feed those
//! updates through a mechanism and apply the aggregated result.
//!
//! Threading model: clients are multiplexed onto
//! min(n_clients, `std::thread::available_parallelism()`) long-lived worker
//! threads (override with [`ClientPool::spawn_with_threads`], e.g. to pin
//! bench runs), each owning a contiguous shard of clients.
//!
//! Two round shapes:
//!
//! * [`run_round`] — legacy/monolithic: shards compute local vectors, the
//!   orchestrator materializes all of them and calls
//!   [`MeanMechanism::aggregate`]. O(n·d) orchestrator memory.
//! * [`run_rounds_encoded`] — the pipeline/session shape: shards *encode*
//!   their own clients ([`ClientEncoder`] runs inside the worker) for a
//!   whole window of W rounds, fold the messages into per-shard, per-round
//!   [`TransportPartial`]s and fold bit accounting locally; the
//!   orchestrator only merges shard partials into one
//!   [`TransportSession`] ring and batch-decodes at window close. With a
//!   summing transport the orchestrator state is O(W·d) — it never sees a
//!   client vector or a per-client description. [`run_round_encoded`] is
//!   the W=1 special case.
//!
//! ## The session/window model
//!
//! A window is one [`TransportSession`]: the transport opens once, every
//! round's mask schedule derives from the window's session seed
//! ([`crate::mechanisms::session::derive_session_seed`] of the run's root
//! seed), shards ship ONE message per window instead of one per round, and
//! the unmask is batched. The broadcast `state` is constant across the
//! window — batching trades per-round feedback for amortized transport,
//! the high-frequency FL regime — while `LocalCompute` still sees each
//! round index. Windowed and independent rounds produce bit-identical
//! estimates (property tested).
//!
//! Real fleets lose clients mid-window:
//! [`run_rounds_encoded_with_dropouts`] takes a per-round dropout
//! schedule, skips dropped clients inside their shard, announces them at
//! window close with the survivors' recovery shares, and decodes each
//! round over its true survivor set n′ (estimates and `true_mean` are
//! both survivor quantities; dropout-aware mechanisms rescale their error
//! to n′ — see
//! [`crate::mechanisms::pipeline::ServerDecoder::decode_survivors`]).
//!
//! Real fleets also do not touch every client every round:
//! [`run_rounds_encoded_sampled`] derives each round's participating
//! *cohort* from the root seed through a
//! [`crate::coordinator::sampling::SamplingPolicy`] (Poisson(γ) or
//! fixed-size without replacement) — client and server agree on the
//! cohort without communication, the masked transport opens its pairwise
//! schedule over the cohort only (sampled-out ≠ dropped: no masks, no
//! recovery shares), sampling composes with the mid-round dropout path,
//! and an optional [`PrivacyLedger`] records every executed round's
//! subsampling-amplified (ε, δ) spend into [`RoundReport::privacy`] —
//! per round, so γ *schedules*
//! ([`crate::coordinator::sampling::SamplingPolicy::Schedule`]) account
//! each round at exactly the rate it sampled at.
//!
//! Real models also outgrow whole-vector buffers:
//! [`run_rounds_encoded_chunked`] streams the window over a
//! [`ChunkPlan`] — shards ship one bounded-channel message per chunk
//! (all W rounds' O(c) partials), a cross-shard barrier keeps the fleet
//! in chunk lockstep, and the orchestrator unmasks, decodes and frees
//! each (round, chunk) as its last shard fold lands. Peak orchestrator
//! accumulator memory is O(shards·c) instead of O(shards·d)
//! ([`ChunkStreamStats`] reports the measured high-water mark), and the
//! results are bit-identical to the whole-d runner for every chunk size.
//!
//! And fleets at real scale cannot afford the barrier either:
//! [`run_rounds_encoded_async`] replaces the fixed-shard chunk-lockstep
//! runner with an event-driven M:N work-stealing runtime
//! ([`super::scheduler::WorkStealPool`]) — client-encode jobs are
//! (block, chunk) *tasks* on per-worker deques fed by a global injector,
//! per-(round, chunk) accumulators close the moment their cohort's
//! submissions arrive (no shard ever waits for another), and
//! backpressure comes from the bounded accumulator ring: encode tasks
//! for chunk k + R are admitted only once the session reports chunk k
//! fully closed ([`TransportSession::chunk_fully_closed`]). Stragglers
//! that miss a configurable deadline on a deterministic virtual clock
//! ([`super::deadline::DeadlinePolicy`], seed-derived under
//! [`seed_domain::DEADLINE`]) convert automatically into announced
//! dropouts on the existing Bonawitz recovery path. On straggler-free
//! schedules the async runner is bit-identical to the barrier runner for
//! every chunk size, worker count and ring depth (property-tested);
//! [`AsyncStreamStats`] reports the measured accumulator peak, which
//! stays O(shards·c) at n = 10⁶ clients (`rounds_async` bench series).
//!
//! Failure propagation (all runners): a panic inside a shard or worker
//! task is caught at its origin, and the orchestrator fails closed with
//! an error naming the shard/worker and carrying the original panic
//! message — never a bare "shard died" with the cause swallowed.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

use super::deadline::DeadlinePolicy;
use super::sampling::SamplingPolicy;
use super::scheduler::{panic_message, WorkStealPool};
use crate::dp::ledger::{PrivacyLedger, PrivacySpend};
use crate::mechanisms::pipeline::{
    ChunkPlan, ClientEncoder, Payload, ServerDecoder, SharedRound, SurvivorSet, Transport,
    TransportPartial,
};
// The client-compute abstraction moved to the pipeline layer (it is the
// producer side of encode/transport/decode); re-exported here so every
// existing `coordinator::runtime::LocalCompute` / `coordinator::
// LocalCompute` import keeps working.
pub use crate::mechanisms::pipeline::{LocalCompute, SliceCompute};
use crate::mechanisms::session::{
    derive_session_seed, session_round_transports_sampled, RoundDropouts, TransportSession,
};
use crate::mechanisms::traits::{BitsAccount, MeanMechanism, RoundOutput};
use crate::util::rng::{seed_domain, Rng};

enum ShardMsg {
    Compute {
        round: u64,
        state: Arc<Vec<f64>>,
    },
    /// Compute AND encode a whole window of rounds: the per-client vectors
    /// never leave the shard, and the shard answers with ONE message per
    /// window (not per round) — the channel-traffic amortization of the
    /// batched session.
    EncodeWindow {
        start_round: u64,
        state: Arc<Vec<f64>>,
        /// per-round shared-randomness seeds, `seeds.len()` = window W
        seeds: Arc<Vec<u64>>,
        /// per-round participation mask over the whole fleet: a client
        /// that is sampled out of the round's cohort OR announced dropped
        /// is inactive — never computed, never encoded
        active: Arc<Vec<Vec<bool>>>,
        encoder: Arc<dyn ClientEncoder>,
        /// per-round session-rekeyed transports (same schedule the
        /// orchestrator's session will unmask)
        transports: Arc<Vec<Arc<dyn Transport>>>,
    },
    /// The chunk-streamed sibling of `EncodeWindow`: the shard computes
    /// its clients' window vectors once (client-side memory — a client
    /// always holds its own update), then streams ONE message per *chunk*
    /// covering all W rounds' O(c) partials for that coordinate range.
    /// Backpressure is structural: `results` is a bounded channel (one
    /// slot per shard) and `barrier` holds every shard at the end of each
    /// chunk, so at most two chunks' accumulators are ever live at the
    /// orchestrator — the O(shards·c) streaming memory model.
    EncodeWindowChunked {
        start_round: u64,
        state: Arc<Vec<f64>>,
        seeds: Arc<Vec<u64>>,
        active: Arc<Vec<Vec<bool>>>,
        encoder: Arc<dyn ClientEncoder>,
        transports: Arc<Vec<Arc<dyn Transport>>>,
        /// the model dimension d — explicit so a shard whose clients are
        /// ALL sampled out still walks the identical chunk plan (it never
        /// computes a vector to measure)
        dim: usize,
        chunk: usize,
        results: mpsc::SyncSender<ChunkStreamMsg>,
        barrier: Arc<Barrier>,
    },
    Shutdown,
}

/// One round's shard-local fold: the uplink partial, bit accounting, the
/// Σ of the shard's surviving client vectors (true-mean metric folding)
/// and WHICH survivors the shard folded (global ids, per round since
/// dropouts vary round to round — the session records them so the
/// fail-closed checks cover the folded path too).
struct ShardRoundFold {
    /// `None` when every client of the shard dropped this round
    partial: Option<TransportPartial>,
    bits: BitsAccount,
    x_sum: Vec<f64>,
    clients: Vec<usize>,
}

/// One (shard, chunk) message of a chunk-streamed window: per round, the
/// O(c) chunk partial, the bits folded for that chunk, the chunk slice of
/// the shard's survivor x-sum, and the folded client ids.
struct ShardChunkWindow {
    /// first global client id of the shard — the orchestrator folds the
    /// f64 x-sum contributions in shard order (f64 addition is not
    /// associative, and the true-mean metric must be bit-identical to the
    /// whole-d runner, which sorts shard pieces for exactly this reason)
    start: usize,
    /// chunk index k of the window's [`ChunkPlan`]
    chunk: usize,
    rounds: Vec<ShardChunkFold>,
}

struct ShardChunkFold {
    partial: Option<TransportPartial>,
    bits: BitsAccount,
    x_sum_chunk: Vec<f64>,
    clients: Vec<usize>,
}

/// What travels on the chunk-stream channel: a (shard, chunk) window
/// message, or a failure report naming the shard and carrying the
/// original panic message so the orchestrator's fail-closed error names
/// the actual cause instead of a bare channel disconnect.
enum ChunkStreamMsg {
    Window(ShardChunkWindow),
    Failed { shard: usize, message: String },
}

enum ShardResult {
    Computed {
        start: usize,
        vecs: Vec<Vec<f64>>,
    },
    EncodedWindow {
        start: usize,
        rounds: Vec<ShardRoundFold>,
    },
    /// A shard's compute/encode panicked: the originating shard id and
    /// the panic message, propagated through the result channel so the
    /// orchestrator can fail closed naming the cause (the shard thread
    /// still re-raises the original panic after sending).
    Failed { shard: usize, message: String },
}

struct Shard {
    tx: mpsc::Sender<ShardMsg>,
    handle: Option<JoinHandle<()>>,
}

/// Persistent pool of client workers.
pub struct ClientPool {
    shards: Vec<Shard>,
    results_rx: mpsc::Receiver<ShardResult>,
    pub n_clients: usize,
    /// the pool's client computation — kept so the async runner can run
    /// the SAME clients on its work-stealing scheduler
    compute: Arc<dyn LocalCompute>,
    /// the contiguous client range of each shard. The async runner's task
    /// *blocks* are exactly these ranges: the f64 true-mean fold walks
    /// block sums in ascending-start order, which is what makes the async
    /// runner bit-identical to the barrier runners (f64 addition is not
    /// associative — same pieces, same order, same bits).
    ranges: Vec<Range<usize>>,
}

impl ClientPool {
    /// Spawn a pool over `n_clients` clients evaluating `compute`, with
    /// min(n_clients, available_parallelism) workers.
    pub fn spawn(n_clients: usize, compute: Arc<dyn LocalCompute>) -> Self {
        Self::spawn_with_threads(n_clients, compute, None)
    }

    /// Like [`Self::spawn`] but with an explicit worker-thread count
    /// (benches pin this for stable numbers across machines).
    pub fn spawn_with_threads(
        n_clients: usize,
        compute: Arc<dyn LocalCompute>,
        threads: Option<usize>,
    ) -> Self {
        assert!(n_clients > 0);
        let threads = threads
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
            })
            .min(n_clients)
            .max(1);
        let per = n_clients.div_ceil(threads);
        let (results_tx, results_rx) = mpsc::channel();
        let mut shards = Vec::new();
        let mut ranges = Vec::new();
        for s in 0..threads {
            let lo = s * per;
            let hi = ((s + 1) * per).min(n_clients);
            if lo >= hi {
                break;
            }
            ranges.push(lo..hi);
            let (tx, rx) = mpsc::channel::<ShardMsg>();
            let results_tx = results_tx.clone();
            let compute = compute.clone();
            let range2 = lo..hi;
            let handle = std::thread::Builder::new()
                .name(format!("fl-shard-{s}"))
                .spawn(move || {
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            ShardMsg::Compute { round, state } => {
                                // catch task panics at their origin so the
                                // orchestrator fails closed knowing WHICH
                                // shard died and WHY, instead of a bare
                                // disconnected-channel expect
                                let computed = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| {
                                        range2
                                            .clone()
                                            .map(|c| compute.local_update(c, round, &state))
                                            .collect::<Vec<Vec<f64>>>()
                                    }),
                                );
                                match computed {
                                    Ok(vecs) => {
                                        if results_tx
                                            .send(ShardResult::Computed {
                                                start: range2.start,
                                                vecs,
                                            })
                                            .is_err()
                                        {
                                            return;
                                        }
                                    }
                                    Err(p) => {
                                        let _ = results_tx.send(ShardResult::Failed {
                                            shard: s,
                                            message: panic_message(p.as_ref()),
                                        });
                                        std::panic::resume_unwind(p);
                                    }
                                }
                            }
                            ShardMsg::EncodeWindow {
                                start_round,
                                state,
                                seeds,
                                active,
                                encoder,
                                transports,
                            } => {
                                let encoded = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| {
                                        let mut rounds = Vec::with_capacity(seeds.len());
                                        for (r, (&seed, transport)) in
                                            seeds.iter().zip(transports.iter()).enumerate()
                                        {
                                            let round = start_round + r as u64;
                                            let participating = &active[r];
                                            let mut partial: Option<TransportPartial> = None;
                                            let mut bits = BitsAccount::default();
                                            let mut x_sum: Vec<f64> = Vec::new();
                                            let mut clients: Vec<usize> = Vec::new();
                                            for c in range2.clone() {
                                                if !participating[c] {
                                                    // sampled out or announced
                                                    // dropped: no local compute,
                                                    // no encode, no count
                                                    continue;
                                                }
                                                let x =
                                                    compute.local_update(c, round, &state);
                                                if x_sum.is_empty() {
                                                    x_sum = vec![0.0; x.len()];
                                                }
                                                assert_eq!(
                                                    x.len(),
                                                    x_sum.len(),
                                                    "ragged client vectors"
                                                );
                                                for (a, v) in x_sum.iter_mut().zip(&x) {
                                                    *a += v;
                                                }
                                                let shared =
                                                    SharedRound::new(seed, n_clients, x.len());
                                                let part = partial.get_or_insert_with(|| {
                                                    transport.empty(&shared)
                                                });
                                                let d = encoder.encode(c, &x, &shared);
                                                bits.merge(&d.bits);
                                                transport.submit(part, c, &d, &shared);
                                                clients.push(c);
                                            }
                                            rounds.push(ShardRoundFold {
                                                partial,
                                                bits,
                                                x_sum,
                                                clients,
                                            });
                                        }
                                        rounds
                                    }),
                                );
                                match encoded {
                                    Ok(rounds) => {
                                        if results_tx
                                            .send(ShardResult::EncodedWindow {
                                                start: range2.start,
                                                rounds,
                                            })
                                            .is_err()
                                        {
                                            return;
                                        }
                                    }
                                    Err(p) => {
                                        let _ = results_tx.send(ShardResult::Failed {
                                            shard: s,
                                            message: panic_message(p.as_ref()),
                                        });
                                        std::panic::resume_unwind(p);
                                    }
                                }
                            }
                            ShardMsg::EncodeWindowChunked {
                                start_round,
                                state,
                                seeds,
                                active,
                                encoder,
                                transports,
                                dim,
                                chunk,
                                results,
                                barrier,
                            } => {
                                // Panic containment: a shard that dies
                                // before pacing every chunk barrier would
                                // park its siblings in Barrier::wait()
                                // forever and wedge the orchestrator's
                                // recv() — so BOTH phases (window compute
                                // and per-chunk encode) run under
                                // catch_unwind, a failed shard sends ONE
                                // `ChunkStreamMsg::Failed` naming itself
                                // and carrying the panic message, keeps
                                // pacing the barrier without sending
                                // windows, and re-raises the original
                                // panic once the window's rendezvous is
                                // over. The orchestrator fails closed
                                // naming the shard and the cause, exactly
                                // like the non-chunked path does.
                                let window = seeds.len();
                                // a streaming compute skips the window
                                // materialization entirely — the per-chunk
                                // loop below pulls O(c) slices straight
                                // from compute_chunk, so NO whole-d client
                                // vector is ever allocated; materialized
                                // computes (the compatibility case) build
                                // the window vectors once, as before.
                                // Either path is bit-identical: the
                                // compute is pure, and slice-capable
                                // encoders define encode_chunk(x, range)
                                // as encode_chunk_slice(&x[range], range).
                                let streams = compute.streams_chunks();
                                let computed = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| {
                                        (0..window)
                                            .map(|r| {
                                                let round = start_round + r as u64;
                                                range2
                                                    .clone()
                                                    .filter(|&c| active[r][c])
                                                    .map(|c| {
                                                        let x = if streams {
                                                            Vec::new()
                                                        } else {
                                                            compute.local_update(
                                                                c, round, &state,
                                                            )
                                                        };
                                                        (c, x)
                                                    })
                                                    .collect::<Vec<(usize, Vec<f64>)>>()
                                            })
                                            .collect::<Vec<_>>()
                                    }),
                                );
                                let mut panicked = None;
                                let vecs: Vec<Vec<(usize, Vec<f64>)>> = match computed {
                                    Ok(v) => v,
                                    Err(p) => {
                                        let _ = results.send(ChunkStreamMsg::Failed {
                                            shard: s,
                                            message: panic_message(p.as_ref()),
                                        });
                                        panicked = Some(p);
                                        Vec::new()
                                    }
                                };
                                let plan = ChunkPlan::new(dim, chunk);
                                let mut dead = panicked.is_some();
                                for k in 0..plan.n_chunks() {
                                    if dead {
                                        // still rendezvous: every shard
                                        // must pace every chunk barrier
                                        barrier.wait();
                                        continue;
                                    }
                                    let range = plan.range(k);
                                    let encoded = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(|| {
                                            let mut rounds_out =
                                                Vec::with_capacity(window);
                                            for (r, (&seed, transport)) in
                                                seeds.iter().zip(transports.iter()).enumerate()
                                            {
                                                let shared =
                                                    SharedRound::new(seed, n_clients, dim);
                                                let mut partial: Option<TransportPartial> =
                                                    None;
                                                let mut bits = BitsAccount::default();
                                                let mut x_sum_chunk =
                                                    vec![0.0f64; range.len()];
                                                let mut clients: Vec<usize> = Vec::new();
                                                let round = start_round + r as u64;
                                                let mut buf = if streams {
                                                    vec![0.0f64; range.len()]
                                                } else {
                                                    Vec::new()
                                                };
                                                for (c, x) in &vecs[r] {
                                                    let msg = if streams {
                                                        compute.compute_chunk(
                                                            *c,
                                                            round,
                                                            &state,
                                                            range.clone(),
                                                            &mut buf,
                                                        );
                                                        for (o, v) in x_sum_chunk
                                                            .iter_mut()
                                                            .zip(buf.iter())
                                                        {
                                                            *o += v;
                                                        }
                                                        encoder.encode_chunk_slice(
                                                            *c,
                                                            &buf,
                                                            range.clone(),
                                                            &shared,
                                                        )
                                                    } else {
                                                        assert_eq!(
                                                            x.len(),
                                                            dim,
                                                            "ragged client vectors"
                                                        );
                                                        for (o, j) in x_sum_chunk
                                                            .iter_mut()
                                                            .zip(range.clone())
                                                        {
                                                            *o += x[j];
                                                        }
                                                        encoder.encode_chunk(
                                                            *c,
                                                            x,
                                                            range.clone(),
                                                            &shared,
                                                        )
                                                    };
                                                    let part =
                                                        partial.get_or_insert_with(|| {
                                                            transport.empty(&shared)
                                                        });
                                                    transport.submit_chunk(
                                                        part,
                                                        *c,
                                                        &msg,
                                                        range.start,
                                                        &shared,
                                                    );
                                                    bits.merge(&msg.bits);
                                                    clients.push(*c);
                                                }
                                                rounds_out.push(ShardChunkFold {
                                                    partial,
                                                    bits,
                                                    x_sum_chunk,
                                                    clients,
                                                });
                                            }
                                            rounds_out
                                        }),
                                    );
                                    match encoded {
                                        Ok(rounds_out) => {
                                            if results
                                                .send(ChunkStreamMsg::Window(
                                                    ShardChunkWindow {
                                                        start: range2.start,
                                                        chunk: k,
                                                        rounds: rounds_out,
                                                    },
                                                ))
                                                .is_err()
                                            {
                                                // the orchestrator died
                                                // (e.g. a fail-closed panic
                                                // mid-stream): keep pacing
                                                // the barrier so sibling
                                                // shards already parked in
                                                // wait() are released
                                                // instead of deadlocking
                                                // ClientPool::drop
                                                dead = true;
                                            }
                                        }
                                        Err(p) => {
                                            let _ = results.send(ChunkStreamMsg::Failed {
                                                shard: s,
                                                message: panic_message(p.as_ref()),
                                            });
                                            panicked = Some(p);
                                            dead = true;
                                        }
                                    }
                                    // chunk-lockstep: no shard starts
                                    // chunk k+1 before every shard sent
                                    // chunk k
                                    barrier.wait();
                                }
                                // disconnect BEFORE re-raising, so the
                                // orchestrator's recv() observes the
                                // failure instead of waiting on a sender
                                // pinned by an unwinding thread
                                drop(results);
                                if let Some(p) = panicked {
                                    std::panic::resume_unwind(p);
                                }
                            }
                            ShardMsg::Shutdown => return,
                        }
                    }
                })
                .expect("spawning shard thread");
            shards.push(Shard { tx, handle: Some(handle) });
        }
        Self { shards, results_rx, n_clients, compute, ranges }
    }

    /// The contiguous client range each shard owns (ascending by start) —
    /// also the async runner's task-block partition.
    pub fn shard_ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }

    /// Compute all clients' local vectors for one round (parallel).
    pub fn compute_round(&self, round: u64, state: &[f64]) -> Vec<Vec<f64>> {
        let state = Arc::new(state.to_vec());
        for (i, shard) in self.shards.iter().enumerate() {
            shard
                .tx
                .send(ShardMsg::Compute { round, state: state.clone() })
                .unwrap_or_else(|_| {
                    panic!(
                        "fail closed: shard {i} is no longer running — its thread exited \
                         before the round was dispatched"
                    )
                });
        }
        let mut out: Vec<Option<Vec<f64>>> = vec![None; self.n_clients];
        for _ in 0..self.shards.len() {
            match self.results_rx.recv().unwrap_or_else(|_| {
                panic!(
                    "fail closed: every shard disconnected before round {round} returned \
                     a result"
                )
            }) {
                ShardResult::Computed { start, vecs } => {
                    for (off, v) in vecs.into_iter().enumerate() {
                        out[start + off] = Some(v);
                    }
                }
                ShardResult::EncodedWindow { .. } => {
                    unreachable!("encode result during a compute round")
                }
                ShardResult::Failed { shard, message } => {
                    panic!(
                        "fail closed: shard {shard} panicked during local compute in round \
                         {round}: {message}"
                    )
                }
            }
        }
        out.into_iter().map(|v| v.expect("missing client result")).collect()
    }
}

impl Drop for ClientPool {
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Outcome of one orchestrated round.
///
/// `PartialEq` is exact (bit-level f64) equality across every field —
/// what the snapshot/resume and scheduled-vs-sampled bit-identity tests
/// assert.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundReport {
    pub round: u64,
    pub output: RoundOutput,
    /// exact mean of the *surviving* clients' vectors (for MSE metrics; a
    /// real server cannot see this — test/metric use only)
    pub true_mean: Vec<f64>,
    /// how many clients the round actually closed over (n′ ≤ cohort ≤ n;
    /// equals the fleet size on unsampled dropout-free rounds)
    pub survivors: usize,
    /// how many clients were sampled into the round's cohort (n on
    /// unsampled rounds; `survivors` is this minus mid-round dropouts)
    pub cohort: usize,
    /// the round's recorded privacy spend, when the run threads a
    /// [`PrivacyLedger`]: per-round amplified (ε, δ) plus the cumulative
    /// basic-composition totals through this round
    pub privacy: Option<PrivacySpend>,
}

/// Per-round seed derivation shared by both round shapes: the
/// [`seed_domain::ROUND`] family of the root seed, domain-separated from
/// session and cohort seeds by the SplitMix-style mixer
/// [`Rng::derive_domain`]. (The previous XOR fold handed round 0 the raw
/// root seed — the seed-format bump this replaced.)
fn round_seed(root_seed: u64, round: u64) -> u64 {
    Rng::derive_domain(root_seed, seed_domain::ROUND, round)
}

/// Validate a window's dropout schedule against its cohorts BEFORE any
/// shard does work, failing closed with the **global round named** when a
/// round's entire cohort is announced dropped. (The un-named
/// [`SurvivorSet::drop_clients`] zero-survivor panic still backstops the
/// type's own invariant, but a runner-level schedule error must say WHICH
/// round emptied — a W=64 window gives the operator 64 candidates
/// otherwise.)
fn resolve_survivors(
    cohorts: &[SurvivorSet],
    dropouts: &[Vec<usize>],
    start_round: u64,
) -> Vec<SurvivorSet> {
    cohorts
        .iter()
        .zip(dropouts)
        .enumerate()
        .map(|(r, (cohort, dropped))| {
            assert!(
                dropped.len() < cohort.n_alive(),
                "fail closed: round {} (window round {r}) would close with zero survivors \
                 — all {} cohort members are announced dropped",
                start_round + r as u64,
                cohort.n_alive(),
            );
            cohort.drop_cohort_members(dropped, r)
        })
        .collect()
}

/// Run one round, monolith shape: parallel local compute, then the
/// mechanism's in-process aggregate. O(n·d) orchestrator memory.
pub fn run_round(
    pool: &ClientPool,
    mech: &dyn MeanMechanism,
    round: u64,
    state: &[f64],
    root_seed: u64,
) -> RoundReport {
    let xs = pool.compute_round(round, state);
    let true_mean = crate::mechanisms::traits::true_mean(&xs);
    let output = mech.aggregate(&xs, round_seed(root_seed, round));
    let survivors = xs.len();
    RoundReport { round, output, true_mean, survivors, cohort: survivors, privacy: None }
}

/// Run a window of W rounds through ONE transport session, pipeline
/// shape: every shard computes AND encodes its own clients for all W
/// rounds (one channel message per shard per window), the orchestrator
/// folds shard partials into the session's ring of per-round accumulators
/// and batch-decodes at window close. With a summing transport the
/// orchestrator holds O(W·d) state and never sees a client vector or a
/// per-client description. Returns one [`RoundReport`] per round, in
/// round order.
pub fn run_rounds_encoded(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
) -> Vec<RoundReport> {
    assert!(window > 0, "a session window needs at least one round");
    let none: Vec<Vec<usize>> = vec![Vec::new(); window];
    run_rounds_encoded_with_dropouts(
        pool, encoder, transport, decoder, start_round, window, state, root_seed, &none,
    )
}

/// [`run_rounds_encoded`] under a per-round dropout schedule:
/// `dropouts[r]` names the clients that drop in round `start_round + r`
/// of the window. Dropped clients are skipped inside their shard (never
/// computed, never encoded); at window close the orchestrator announces
/// them with the survivors' recovery shares
/// ([`RoundDropouts::announce`]), the session reconstructs their
/// outstanding masks, and each round decodes over its true survivor set
/// ([`ServerDecoder::decode_survivors`]) — so the reported `true_mean`
/// and estimate are both survivor-set quantities. An empty schedule IS
/// `run_rounds_encoded`, bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_encoded_with_dropouts(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    dropouts: &[Vec<usize>],
) -> Vec<RoundReport> {
    run_rounds_encoded_sampled(
        pool,
        encoder,
        transport,
        decoder,
        start_round,
        window,
        state,
        root_seed,
        &SamplingPolicy::Full,
        dropouts,
        None,
    )
}

/// The general windowed runner: every round's participating *cohort* is
/// derived from the root seed by `policy`
/// ([`crate::coordinator::sampling::SamplingPolicy`] — clients re-derive
/// their own membership, no communication), `dropouts[r]` names the
/// *mid-round* dropouts among cohort members, and an optional
/// [`PrivacyLedger`] records each executed round's
/// subsampling-amplified (ε, δ) spend (surfaced in
/// [`RoundReport::privacy`]).
///
/// Sampled-out clients are skipped inside their shard exactly like
/// dropped ones, but the transport knows the difference: the session's
/// masked schedule opens over the cohort only
/// ([`TransportSession::open_sampled`]), so sampled-out clients hold no
/// masks and need no recovery, while dropped cohort members still go
/// through Bonawitz-style share recovery. Each round decodes over cohort
/// minus dropped ([`ServerDecoder::decode_survivors`]), keeping the exact
/// error laws at the contributing count n′. `SamplingPolicy::Full` with
/// ledger `None` IS [`run_rounds_encoded_with_dropouts`], bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_encoded_sampled(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    policy: &SamplingPolicy,
    dropouts: &[Vec<usize>],
    ledger: Option<&mut PrivacyLedger>,
) -> Vec<RoundReport> {
    let n = pool.n_clients;
    // derive the cohorts and per-round accounting rates from the policy;
    // the cohort-explicit core does the rest
    let cohorts: Vec<SurvivorSet> = policy.cohorts(root_seed, start_round, window, n);
    // per-round rate: γ schedules amplify each round with exactly the
    // rate it sampled at. Poisson's empty-cohort redraw deviates from the
    // idealized sampler by TV ≤ (1−γ)^(n−1) on every neighboring dataset
    // — surrendered as a per-round δ surcharge
    let rates: Vec<(f64, f64)> = (0..window)
        .map(|r| {
            let round_id = start_round + r as u64;
            (policy.amplification_gamma(n, round_id), policy.conditioning_tv(n, round_id))
        })
        .collect();
    run_rounds_encoded_cohorts(
        pool, encoder, transport, decoder, start_round, window, state, root_seed, &cohorts,
        &rates, dropouts, ledger,
    )
}

/// The scenario-scheduled sibling of [`run_rounds_encoded_sampled`]:
/// round r's participating cohort is given EXPLICITLY instead of being
/// derived from a [`SamplingPolicy`] — the shape a scenario engine
/// produces, where membership comes from simulated churn rather than a
/// sampling scheme (`window = cohorts.len()`). Session opening, shard
/// masking, dropout recovery and decode run through the identical core,
/// so explicit cohorts equal to a policy's derived ones reproduce
/// [`run_rounds_encoded_sampled`] bit for bit.
///
/// Ledger accounting: with no sampling scheme there is no scheme-derived
/// amplification rate, so each executed round is recorded at its
/// *realized* participation rate γᵣ = |cohort r| / n with zero TV slack.
/// Under data-dependent (e.g. adversarial-churn) membership this is
/// honest bookkeeping of the realized rate, NOT a subsampling
/// amplification guarantee — amplification requires a randomized,
/// data-independent sampler (see `dp/ledger.rs`'s scope notes).
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_encoded_scheduled(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    start_round: u64,
    state: &[f64],
    root_seed: u64,
    cohorts: &[SurvivorSet],
    dropouts: &[Vec<usize>],
    ledger: Option<&mut PrivacyLedger>,
) -> Vec<RoundReport> {
    let n = pool.n_clients;
    for (r, c) in cohorts.iter().enumerate() {
        assert_eq!(c.n(), n, "round {r}: scheduled cohort shaped for a different fleet");
    }
    let rates: Vec<(f64, f64)> =
        cohorts.iter().map(|c| (c.n_alive() as f64 / n as f64, 0.0)).collect();
    run_rounds_encoded_cohorts(
        pool,
        encoder,
        transport,
        decoder,
        start_round,
        cohorts.len(),
        state,
        root_seed,
        cohorts,
        &rates,
        dropouts,
        ledger,
    )
}

/// The shared cohort-explicit core of the windowed runners: cohorts and
/// per-round (γ, tv) accounting rates arrive precomputed; everything else
/// — session opening over the cohorts, shard fan-out, dropout
/// announcement, survivor decode, ledger recording — is identical for the
/// policy-sampled and scenario-scheduled entry points.
#[allow(clippy::too_many_arguments)]
fn run_rounds_encoded_cohorts(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    cohorts: &[SurvivorSet],
    rates: &[(f64, f64)],
    dropouts: &[Vec<usize>],
    mut ledger: Option<&mut PrivacyLedger>,
) -> Vec<RoundReport> {
    assert!(window > 0, "a session window needs at least one round");
    assert!(
        window <= crate::mechanisms::session::MAX_WINDOW,
        "session window of {window} rounds exceeds MAX_WINDOW ({}) — split the run into \
         multiple windows",
        crate::mechanisms::session::MAX_WINDOW,
    );
    assert!(
        !transport.sum_only() || decoder.sum_decodable(),
        "mechanism is not homomorphic: it cannot decode from a sum-only transport"
    );
    assert_eq!(
        dropouts.len(),
        window,
        "dropout schedule must cover every round of the window"
    );
    assert_eq!(
        cohorts.len(),
        window,
        "cohort schedule must cover every round of the window"
    );
    assert_eq!(
        rates.len(),
        window,
        "accounting-rate schedule must cover every round of the window"
    );
    let n = pool.n_clients;
    // validate the whole schedule before any shard does work (fail
    // closed): dropouts must name cohort members, and every round must
    // keep at least one survivor — with the offending round NAMED
    let survivor_sets = resolve_survivors(cohorts, dropouts, start_round);
    let session_seed = derive_session_seed(root_seed, start_round);
    let seeds: Arc<Vec<u64>> = Arc::new(
        (0..window).map(|r| round_seed(root_seed, start_round + r as u64)).collect(),
    );
    // the shards must mask with the exact schedule the session will unmask:
    // both sides derive it from (transport, session_seed, cohorts) alone
    let transports: Arc<Vec<Arc<dyn Transport>>> = Arc::new(session_round_transports_sampled(
        transport.as_ref(),
        session_seed,
        cohorts,
    ));
    let active: Arc<Vec<Vec<bool>>> =
        Arc::new(survivor_sets.iter().map(|s| s.alive_mask().to_vec()).collect());
    let state = Arc::new(state.to_vec());
    for (i, shard) in pool.shards.iter().enumerate() {
        shard
            .tx
            .send(ShardMsg::EncodeWindow {
                start_round,
                state: state.clone(),
                seeds: seeds.clone(),
                active: active.clone(),
                encoder: encoder.clone(),
                transports: transports.clone(),
            })
            .unwrap_or_else(|_| {
                panic!(
                    "fail closed: shard {i} is no longer running — its thread exited \
                     before the window was dispatched"
                )
            });
    }
    // collect shard windows; fold x-sums in shard order so the true-mean
    // metric is deterministic regardless of arrival order
    let mut pieces: Vec<(usize, Vec<ShardRoundFold>)> = Vec::with_capacity(pool.shards.len());
    for _ in 0..pool.shards.len() {
        match pool.results_rx.recv().unwrap_or_else(|_| {
            panic!(
                "fail closed: every shard disconnected before the window starting at round \
                 {start_round} returned a result"
            )
        }) {
            ShardResult::EncodedWindow { start, rounds } => {
                pieces.push((start, rounds));
            }
            ShardResult::Computed { .. } => {
                unreachable!("compute result during an encoded round")
            }
            ShardResult::Failed { shard, message } => {
                panic!(
                    "fail closed: shard {shard} panicked while encoding the window \
                     starting at round {start_round}: {message}"
                )
            }
        }
    }
    pieces.sort_by_key(|&(start, _)| start);
    // resolve_survivors guaranteed every round >= 1 survivor, so some
    // shard-round fold carries a dimension
    let dim = pieces
        .iter()
        .flat_map(|(_, rounds)| rounds.iter())
        .find(|f| !f.x_sum.is_empty())
        .map(|f| f.x_sum.len())
        .expect("unreachable: resolve_survivors guarantees a survivor in every round");
    let mut session = TransportSession::open_sampled(
        transport.as_ref(),
        session_seed,
        n,
        dim,
        seeds.as_slice(),
        cohorts,
    );
    let mut x_sums = vec![vec![0.0f64; dim]; window];
    for (_, rounds) in pieces {
        assert_eq!(rounds.len(), window, "shard returned a different window");
        for (r, fold) in rounds.into_iter().enumerate() {
            for (a, v) in x_sums[r].iter_mut().zip(&fold.x_sum) {
                *a += v;
            }
            match fold.partial {
                Some(p) => session.fold_partial(r, p, &fold.clients, &fold.bits),
                None => assert!(fold.clients.is_empty(), "shard lost a partial"),
            }
        }
    }
    // announce the mid-round dropouts with the final survivors' recovery
    // shares (the in-process analogue of the share-collection phase);
    // sampled-out clients are announced nowhere — they left no masks
    let announced: Vec<RoundDropouts> = survivor_sets
        .iter()
        .zip(dropouts)
        .enumerate()
        .map(|(r, (s, dropped))| {
            RoundDropouts::announce_among(session_seed, r as u64, s, dropped)
        })
        .collect();
    let shared: Vec<SharedRound> = (0..window).map(|r| *session.round(r)).collect();
    session
        .close_with_dropouts(&announced)
        .into_iter()
        .zip(shared)
        .zip(x_sums)
        .enumerate()
        .map(|(r, (((payload, bits, survivors), round), x_sum))| {
            let estimate = decoder.decode_survivors(&payload, &round, &survivors);
            let n_alive = survivors.n_alive();
            let true_mean: Vec<f64> =
                x_sum.into_iter().map(|v| v / n_alive as f64).collect();
            let round_id = start_round + r as u64;
            let (gamma, tv) = rates[r];
            let privacy =
                ledger.as_deref_mut().map(|l| l.record_with_tv_slack(round_id, gamma, tv));
            RoundReport {
                round: round_id,
                output: RoundOutput { estimate, bits },
                true_mean,
                survivors: n_alive,
                cohort: cohorts[r].n_alive(),
                privacy,
            }
        })
        .collect()
}

/// Memory summary of one chunk-streamed window (what the
/// `rounds_chunked` bench series reports and asserts on).
#[derive(Clone, Copy, Debug)]
pub struct ChunkStreamStats {
    /// high-water mark of the orchestrator session's live accumulator
    /// payload bytes — O(shards-in-flight · c), never O(d), and measured
    /// at the packed ⌈c·w/64⌉·8 width for masked transports
    pub peak_accumulator_bytes: usize,
    /// total payload bytes shipped over the shard→orchestrator channel
    /// this window, summed via [`TransportPartial::wire_bytes`] — the
    /// measured (packed) wire traffic, not a ×8-per-residue estimate
    pub wire_bytes: usize,
    /// the chunk size actually used (clamped to d)
    pub chunk: usize,
    pub n_chunks: usize,
}

/// The chunk-streamed sibling of [`run_rounds_encoded_sampled`]: the
/// whole window runs over a [`ChunkPlan`] of chunk size `chunk`. Shards
/// compute their clients' window vectors once, then stream ONE channel
/// message per (shard, chunk) — each carrying the W rounds' O(c) chunk
/// partials — through a bounded channel with a cross-shard chunk
/// barrier, so the orchestrator (and the channel) hold O(shards·c) bytes
/// instead of O(shards·d). The orchestrator folds each message into the
/// chunked [`TransportSession`], finishes and decodes every (round,
/// chunk) the moment its last shard fold lands, and releases the
/// accumulator before the next chunk streams in.
///
/// `dim` is explicit — the model dimension is a deployment constant, and
/// a shard whose clients are all sampled out of the window could not
/// otherwise agree on the chunk plan. Bit-identity: for every chunk
/// size, estimates, bits and reports equal
/// [`run_rounds_encoded_sampled`] exactly (property-tested); the
/// returned [`ChunkStreamStats`] carries the measured accumulator peak.
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_encoded_chunked(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    policy: &SamplingPolicy,
    dropouts: &[Vec<usize>],
    mut ledger: Option<&mut PrivacyLedger>,
    dim: usize,
    chunk: usize,
) -> (Vec<RoundReport>, ChunkStreamStats) {
    assert!(window > 0, "a session window needs at least one round");
    assert!(
        window <= crate::mechanisms::session::MAX_WINDOW,
        "session window of {window} rounds exceeds MAX_WINDOW ({}) — split the run into \
         multiple windows",
        crate::mechanisms::session::MAX_WINDOW,
    );
    assert!(
        !transport.sum_only() || decoder.sum_decodable(),
        "mechanism is not homomorphic: it cannot decode from a sum-only transport"
    );
    assert_eq!(
        dropouts.len(),
        window,
        "dropout schedule must cover every round of the window"
    );
    let n = pool.n_clients;
    let cohorts: Vec<SurvivorSet> = policy.cohorts(root_seed, start_round, window, n);
    let survivor_sets = resolve_survivors(&cohorts, dropouts, start_round);
    let session_seed = derive_session_seed(root_seed, start_round);
    let seeds: Arc<Vec<u64>> = Arc::new(
        (0..window).map(|r| round_seed(root_seed, start_round + r as u64)).collect(),
    );
    let transports: Arc<Vec<Arc<dyn Transport>>> = Arc::new(session_round_transports_sampled(
        transport.as_ref(),
        session_seed,
        &cohorts,
    ));
    let active: Arc<Vec<Vec<bool>>> =
        Arc::new(survivor_sets.iter().map(|s| s.alive_mask().to_vec()).collect());
    let state = Arc::new(state.to_vec());
    let n_shards = pool.shards.len();
    // bounded per-chunk channel + chunk barrier: at most one in-flight
    // message per shard, and no shard runs ahead a full chunk
    let (chunk_tx, chunk_rx) = mpsc::sync_channel::<ChunkStreamMsg>(n_shards);
    let barrier = Arc::new(Barrier::new(n_shards));
    for (i, shard) in pool.shards.iter().enumerate() {
        shard
            .tx
            .send(ShardMsg::EncodeWindowChunked {
                start_round,
                state: state.clone(),
                seeds: seeds.clone(),
                active: active.clone(),
                encoder: encoder.clone(),
                transports: transports.clone(),
                dim,
                chunk,
                results: chunk_tx.clone(),
                barrier: barrier.clone(),
            })
            .unwrap_or_else(|_| {
                panic!(
                    "fail closed: shard {i} is no longer running — its thread exited \
                     before the chunked window was dispatched"
                )
            });
    }
    drop(chunk_tx);
    let mut session = TransportSession::open_sampled_chunked(
        transport.as_ref(),
        session_seed,
        n,
        dim,
        seeds.as_slice(),
        &cohorts,
        chunk,
    );
    let plan = session.plan();
    // announce dropouts up front so every chunk can recover + unmask the
    // moment its last shard fold lands
    for (r, (survivors, dropped)) in survivor_sets.iter().zip(dropouts).enumerate() {
        session.announce_dropouts(
            r,
            &RoundDropouts::announce_among(session_seed, r as u64, survivors, dropped),
        );
    }
    let mut x_sums = vec![vec![0.0f64; dim]; window];
    let mut estimates: Vec<Vec<f64>> = vec![vec![0.0f64; dim]; window];
    let mut sums: Vec<Vec<i64>> = if decoder.chunk_decodable() {
        Vec::new()
    } else {
        vec![vec![0i64; dim]; window]
    };
    let shared: Vec<SharedRound> =
        (0..window).map(|r| SharedRound::new(seeds[r], n, dim)).collect();
    let total_msgs = n_shards * plan.n_chunks();
    // the f64 x-sum metric folds in SHARD order, not channel-arrival
    // order (f64 addition is not associative; the whole-d runner sorts
    // shard pieces for the same reason) — chunk-k contributions are
    // buffered until every shard's chunk-k message landed, which the
    // chunk barrier guarantees happens before any chunk-k+1 message
    let mut x_pending: Vec<(usize, usize, Vec<Vec<f64>>)> = Vec::with_capacity(n_shards);
    // measured channel traffic: every shard partial's packed payload size
    let mut wire_bytes = 0usize;
    for _ in 0..total_msgs {
        let msg = match chunk_rx.recv() {
            Ok(ChunkStreamMsg::Window(w)) => w,
            Ok(ChunkStreamMsg::Failed { shard, message }) => panic!(
                "fail closed: shard {shard} panicked while encoding the chunked window \
                 starting at round {start_round}: {message}"
            ),
            Err(_) => panic!(
                "fail closed: the chunk stream disconnected before the window starting at \
                 round {start_round} completed — a shard thread died without reporting"
            ),
        };
        let k = msg.chunk;
        let range = plan.range(k);
        let mut x_chunks: Vec<Vec<f64>> = Vec::with_capacity(window);
        for (r, fold) in msg.rounds.into_iter().enumerate() {
            x_chunks.push(fold.x_sum_chunk);
            match fold.partial {
                Some(p) => {
                    wire_bytes += p.wire_bytes();
                    session.fold_chunk_partial(r, k, p, &fold.clients, &fold.bits)
                }
                None => assert!(fold.clients.is_empty(), "shard lost a partial"),
            }
            // the chunk closes — and its accumulator frees — the moment
            // the last shard's fold lands
            if session.chunk_complete(r, k) {
                let payload = session.finish_chunk(r, k);
                if decoder.chunk_decodable() {
                    let est = decoder.decode_survivors_chunk(
                        &payload,
                        range.start,
                        &shared[r],
                        &survivor_sets[r],
                    );
                    estimates[r][range.clone()].copy_from_slice(&est);
                } else {
                    match payload {
                        Payload::Sum(v) if !plan.is_whole() => {
                            sums[r][range.clone()].copy_from_slice(&v)
                        }
                        p => {
                            estimates[r] = decoder.decode_survivors(
                                &p,
                                &shared[r],
                                &survivor_sets[r],
                            );
                        }
                    }
                }
            }
        }
        x_pending.push((msg.start, k, x_chunks));
        if x_pending.len() == n_shards {
            x_pending.sort_by_key(|&(start, _, _)| start);
            for (_, pk, shard_chunks) in x_pending.drain(..) {
                // the chunk barrier + FIFO channel group messages by chunk
                assert_eq!(pk, k, "shard chunk messages interleaved across chunks");
                for (r, chunk_sum) in shard_chunks.into_iter().enumerate() {
                    for (o, v) in x_sums[r][range.clone()].iter_mut().zip(&chunk_sum) {
                        *o += v;
                    }
                }
            }
        }
    }
    let stats = ChunkStreamStats {
        peak_accumulator_bytes: session.peak_accumulator_bytes(),
        wire_bytes,
        chunk: plan.chunk(),
        n_chunks: plan.n_chunks(),
    };
    let closed = session.close_streamed();
    let reports = closed
        .into_iter()
        .enumerate()
        .map(|(r, (bits, survivors))| {
            let estimate = if !decoder.chunk_decodable()
                && transport.sum_only()
                && !plan.is_whole()
            {
                decoder.decode_survivors(
                    &Payload::Sum(std::mem::take(&mut sums[r])),
                    &shared[r],
                    &survivors,
                )
            } else {
                std::mem::take(&mut estimates[r])
            };
            let n_alive = survivors.n_alive();
            let true_mean: Vec<f64> =
                std::mem::take(&mut x_sums[r]).into_iter().map(|v| v / n_alive as f64).collect();
            let round_id = start_round + r as u64;
            let gamma = policy.amplification_gamma(n, round_id);
            let tv = policy.conditioning_tv(n, round_id);
            let privacy =
                ledger.as_deref_mut().map(|l| l.record_with_tv_slack(round_id, gamma, tv));
            RoundReport {
                round: round_id,
                output: RoundOutput { estimate, bits },
                true_mean,
                survivors: n_alive,
                cohort: cohorts[r].n_alive(),
                privacy,
            }
        })
        .collect();
    (reports, stats)
}

/// Chunk-streamed convenience wrapper for mechanisms implementing both
/// pipeline ends (see [`run_rounds_encoded_chunked`]).
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_mech_chunked<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    dim: usize,
    chunk: usize,
) -> (Vec<RoundReport>, ChunkStreamStats)
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    let none: Vec<Vec<usize>> = vec![Vec::new(); window];
    run_rounds_encoded_chunked(
        pool,
        encoder,
        transport,
        mech,
        start_round,
        window,
        state,
        root_seed,
        &SamplingPolicy::Full,
        &none,
        None,
        dim,
        chunk,
    )
}

/// Configuration of the event-driven async runner
/// ([`run_rounds_encoded_async`]): chunk geometry, accumulator-ring
/// depth, scheduler width and the straggler-deadline policy.
#[derive(Clone, Debug)]
pub struct AsyncRunConfig {
    /// model dimension d (explicit, exactly as in the chunked runner)
    pub dim: usize,
    /// chunk size c of the streaming [`ChunkPlan`]
    pub chunk: usize,
    /// accumulator-ring depth R: at most R chunk-waves of live
    /// accumulators — encode tasks for chunk k + R are admitted only once
    /// the session reports chunk k fully closed
    /// ([`TransportSession::chunk_fully_closed`])
    pub ring: usize,
    /// work-stealing worker count; `None` = one worker per task block
    pub workers: Option<usize>,
    /// the virtual-clock straggler deadline (default: none — the runner
    /// is then bit-identical to the barrier runners)
    pub deadline: DeadlinePolicy,
}

impl AsyncRunConfig {
    /// Chunk geometry with the defaults: ring depth 2, one worker per
    /// block, no deadline.
    pub fn new(dim: usize, chunk: usize) -> Self {
        Self { dim, chunk, ring: 2, workers: None, deadline: DeadlinePolicy::none() }
    }

    pub fn with_ring(mut self, ring: usize) -> Self {
        assert!(ring >= 1, "the accumulator ring needs at least one wave");
        self.ring = ring;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        assert!(workers >= 1, "the scheduler needs at least one worker");
        self.workers = Some(workers);
        self
    }

    pub fn with_deadline(mut self, deadline: DeadlinePolicy) -> Self {
        deadline.validate();
        self.deadline = deadline;
        self
    }
}

/// Summary of one async window (what the `rounds_async` bench series
/// reports and asserts on).
#[derive(Clone, Copy, Debug)]
pub struct AsyncStreamStats {
    /// high-water mark of the session's live accumulator payload bytes —
    /// O(ring · W · c) by the ring admission rule, never O(d), measured
    /// at the packed ⌈c·w/64⌉·8 width for masked transports
    pub peak_accumulator_bytes: usize,
    /// total payload bytes shipped over the task→orchestrator channel
    /// this window ([`TransportPartial::wire_bytes`]) — measured packed
    /// wire traffic
    pub wire_bytes: usize,
    /// the chunk size actually used (clamped to d)
    pub chunk: usize,
    pub n_chunks: usize,
    /// total (block, chunk) encode tasks executed
    pub tasks: usize,
    /// work-stealing workers the window ran on
    pub workers: usize,
    /// cohort members the deadline converted into announced dropouts
    pub converted_stragglers: usize,
}

/// One work-stealing task: encode client block `block` for chunk `chunk`
/// across every round of the window.
#[derive(Clone, Copy)]
struct AsyncTask {
    block: usize,
    chunk: usize,
}

/// One completed task's event: the block's per-round chunk folds.
struct AsyncChunkMsg {
    block: usize,
    chunk: usize,
    rounds: Vec<ShardChunkFold>,
}

/// The event-driven sibling of [`run_rounds_encoded_chunked`]: no
/// cross-shard barrier anywhere. Client-encode jobs are (block, chunk)
/// *tasks* on a work-stealing scheduler
/// ([`super::scheduler::WorkStealPool`]) whose blocks are exactly the
/// pool's shard ranges (same f64 fold tree → same bits); each
/// per-(round, chunk) accumulator closes the moment its cohort's
/// submissions arrive, and the bounded accumulator ring provides the
/// backpressure the barrier used to: encode tasks for chunk k + R are
/// injected only once chunk k is fully closed, so live accumulator
/// memory stays O(ring · W · c) however far the scheduler races ahead.
///
/// Stragglers: `cfg.deadline` draws every (round, client) virtual
/// arrival from the seed-derived [`seed_domain::DEADLINE`] stream and
/// converts cohort members past the deadline into announced dropouts on
/// the Bonawitz recovery path BEFORE any task runs — "straggler past
/// deadline" and "pre-announced dropout" are the same schedule by
/// construction, and with no deadline the runner reproduces
/// [`run_rounds_encoded_chunked`] (hence the whole-d runners) bit for
/// bit for every chunk size, worker count and ring depth
/// (property-tested).
///
/// Failure model: a panicking task poisons the scheduler; the
/// orchestrator fails closed naming the worker and the original panic
/// message — it never hangs on a silent channel and never reports a bare
/// disconnect.
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_encoded_async(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    policy: &SamplingPolicy,
    dropouts: &[Vec<usize>],
    mut ledger: Option<&mut PrivacyLedger>,
    cfg: &AsyncRunConfig,
) -> (Vec<RoundReport>, AsyncStreamStats) {
    let dim = cfg.dim;
    assert!(window > 0, "a session window needs at least one round");
    assert!(
        window <= crate::mechanisms::session::MAX_WINDOW,
        "session window of {window} rounds exceeds MAX_WINDOW ({}) — split the run into \
         multiple windows",
        crate::mechanisms::session::MAX_WINDOW,
    );
    assert!(
        !transport.sum_only() || decoder.sum_decodable(),
        "mechanism is not homomorphic: it cannot decode from a sum-only transport"
    );
    assert_eq!(
        dropouts.len(),
        window,
        "dropout schedule must cover every round of the window"
    );
    let n = pool.n_clients;
    let cohorts: Vec<SurvivorSet> = policy.cohorts(root_seed, start_round, window, n);
    // the deadline conversion runs BEFORE any task: a straggler past the
    // deadline is never computed, never encoded, and is announced exactly
    // like a pre-announced dropout
    let (merged, converted) =
        cfg.deadline.convert(root_seed, start_round, &cohorts, dropouts);
    let survivor_sets = resolve_survivors(&cohorts, &merged, start_round);
    let session_seed = derive_session_seed(root_seed, start_round);
    let seeds: Arc<Vec<u64>> = Arc::new(
        (0..window).map(|r| round_seed(root_seed, start_round + r as u64)).collect(),
    );
    let transports: Arc<Vec<Arc<dyn Transport>>> = Arc::new(session_round_transports_sampled(
        transport.as_ref(),
        session_seed,
        &cohorts,
    ));
    let active: Arc<Vec<Vec<bool>>> =
        Arc::new(survivor_sets.iter().map(|s| s.alive_mask().to_vec()).collect());
    let state = Arc::new(state.to_vec());
    let mut session = TransportSession::open_sampled_chunked(
        transport.as_ref(),
        session_seed,
        n,
        dim,
        seeds.as_slice(),
        &cohorts,
        cfg.chunk,
    );
    let plan = session.plan();
    let n_chunks = plan.n_chunks();
    // announce (explicit + converted) dropouts up front so every chunk
    // can recover + unmask the moment its last block fold lands
    for (r, (survivors, dropped)) in survivor_sets.iter().zip(&merged).enumerate() {
        session.announce_dropouts(
            r,
            &RoundDropouts::announce_among(session_seed, r as u64, survivors, dropped),
        );
    }
    let blocks: Arc<Vec<Range<usize>>> = Arc::new(pool.ranges.clone());
    let n_blocks = blocks.len();
    let n_workers = cfg.workers.unwrap_or(n_blocks).max(1);
    let ring = cfg.ring.max(1);
    // lazily-computed per-block window vectors (client-side memory — a
    // client always holds its own update): the block's FIRST task
    // computes them under the block's mutex (a contending task waits
    // instead of duplicating the work); the block's LAST task frees them
    type BlockVecs = Vec<Vec<(usize, Vec<f64>)>>;
    let store: Arc<Vec<Mutex<Option<Arc<BlockVecs>>>>> =
        Arc::new((0..n_blocks).map(|_| Mutex::new(None)).collect());
    let remaining: Arc<Vec<AtomicUsize>> =
        Arc::new((0..n_blocks).map(|_| AtomicUsize::new(n_chunks)).collect());
    // bounded event channel: outstanding messages never exceed the
    // admitted-but-unprocessed waves (≤ ring · blocks), so the capacity
    // below means workers never block on send in a healthy run
    let (events_tx, events_rx) =
        mpsc::sync_channel::<AsyncChunkMsg>(n_blocks * (ring + 1));
    let ws = {
        let compute = pool.compute.clone();
        let blocks = blocks.clone();
        let state = state.clone();
        let seeds = seeds.clone();
        let active = active.clone();
        let encoder = encoder.clone();
        let transports = transports.clone();
        let store = store.clone();
        let remaining = remaining.clone();
        WorkStealPool::spawn(n_workers, move |_worker, task: AsyncTask| {
            let AsyncTask { block, chunk: k } = task;
            // a streaming compute never materializes the block's window
            // vectors — each task pulls O(c) slices straight from
            // compute_chunk below; the materialized path keeps the lazy
            // per-block store (first task computes under the block mutex,
            // last task frees). Bit-identical either way: the compute is
            // pure, and slice-capable encoders define
            // encode_chunk(x, range) as encode_chunk_slice(&x[range]).
            let streams = compute.streams_chunks();
            let vecs: Arc<BlockVecs> = if streams {
                Arc::new(Vec::new())
            } else {
                let mut slot = store[block].lock().unwrap();
                match &*slot {
                    Some(v) => v.clone(),
                    None => {
                        let computed: BlockVecs = (0..seeds.len())
                            .map(|r| {
                                let round = start_round + r as u64;
                                blocks[block]
                                    .clone()
                                    .filter(|&c| active[r][c])
                                    .map(|c| (c, compute.local_update(c, round, &state)))
                                    .collect()
                            })
                            .collect();
                        let arc = Arc::new(computed);
                        *slot = Some(arc.clone());
                        arc
                    }
                }
            };
            let range = plan.range(k);
            let mut rounds_out = Vec::with_capacity(seeds.len());
            let mut buf = if streams { vec![0.0f64; range.len()] } else { Vec::new() };
            for (r, (&seed, transport)) in seeds.iter().zip(transports.iter()).enumerate()
            {
                let shared = SharedRound::new(seed, n, dim);
                let round = start_round + r as u64;
                let mut partial: Option<TransportPartial> = None;
                let mut bits = BitsAccount::default();
                let mut x_sum_chunk = vec![0.0f64; range.len()];
                let mut clients: Vec<usize> = Vec::new();
                if streams {
                    for c in blocks[block].clone().filter(|&c| active[r][c]) {
                        compute.compute_chunk(c, round, &state, range.clone(), &mut buf);
                        for (o, v) in x_sum_chunk.iter_mut().zip(buf.iter()) {
                            *o += v;
                        }
                        let msg = encoder.encode_chunk_slice(c, &buf, range.clone(), &shared);
                        let part = partial.get_or_insert_with(|| transport.empty(&shared));
                        transport.submit_chunk(part, c, &msg, range.start, &shared);
                        bits.merge(&msg.bits);
                        clients.push(c);
                    }
                } else {
                    for (c, x) in &vecs[r] {
                        assert_eq!(x.len(), dim, "ragged client vectors");
                        for (o, j) in x_sum_chunk.iter_mut().zip(range.clone()) {
                            *o += x[j];
                        }
                        let msg = encoder.encode_chunk(*c, x, range.clone(), &shared);
                        let part = partial.get_or_insert_with(|| transport.empty(&shared));
                        transport.submit_chunk(part, *c, &msg, range.start, &shared);
                        bits.merge(&msg.bits);
                        clients.push(*c);
                    }
                }
                rounds_out.push(ShardChunkFold { partial, bits, x_sum_chunk, clients });
            }
            // a send error means the orchestrator already failed closed
            // and is unwinding — nothing useful left for this task
            let _ = events_tx.send(AsyncChunkMsg { block, chunk: k, rounds: rounds_out });
            if !streams && remaining[block].fetch_sub(1, Ordering::AcqRel) == 1 {
                // every chunk of this block is encoded: free the vectors
                store[block].lock().unwrap().take();
            }
        })
    };
    // chunk-major initial admission: the ring starts with waves
    // 0..min(ring, n_chunks) in flight
    let initial = ring.min(n_chunks);
    ws.inject(
        (0..initial)
            .flat_map(|k| (0..n_blocks).map(move |b| AsyncTask { block: b, chunk: k })),
    );
    let mut next_inject = initial;
    let total_msgs = n_blocks * n_chunks;
    // per-block reorder buffers: the session's streaming cursor requires
    // each client's chunks folded in coordinate order, and stolen tasks
    // of one block may complete out of order
    let mut stash: Vec<BTreeMap<usize, AsyncChunkMsg>> =
        (0..n_blocks).map(|_| BTreeMap::new()).collect();
    let mut next_k: Vec<usize> = vec![0; n_blocks];
    // per-chunk f64 wave buffers: the true-mean fold walks blocks in
    // ascending order once every block's chunk-k sums arrived (f64
    // addition is not associative; same fold tree as the barrier runners)
    let mut x_wave: BTreeMap<usize, Vec<(usize, Vec<Vec<f64>>)>> = BTreeMap::new();
    let mut x_sums = vec![vec![0.0f64; dim]; window];
    let mut estimates: Vec<Vec<f64>> = vec![vec![0.0f64; dim]; window];
    let mut sums: Vec<Vec<i64>> = if decoder.chunk_decodable() {
        Vec::new()
    } else {
        vec![vec![0i64; dim]; window]
    };
    let shared: Vec<SharedRound> =
        (0..window).map(|r| SharedRound::new(seeds[r], n, dim)).collect();
    let mut processed = 0usize;
    // measured channel traffic: every task partial's packed payload size
    let mut wire_bytes = 0usize;
    while processed < total_msgs {
        let msg = match events_rx.recv() {
            Ok(m) => m,
            Err(_) => {
                // every worker exited before the window completed: a task
                // panicked (recorded by the scheduler) — name the worker
                // and the cause instead of dying on a bare disconnect
                let failures = ws.failures();
                match failures.first() {
                    Some(f) => panic!(
                        "fail closed: async worker {} panicked while encoding the window \
                         starting at round {start_round}: {}",
                        f.worker, f.message
                    ),
                    None => panic!(
                        "fail closed: the async event stream disconnected with {processed} \
                         of {total_msgs} tasks reported and no recorded failure"
                    ),
                }
            }
        };
        let b = msg.block;
        stash[b].insert(msg.chunk, msg);
        while let Some(m) = stash[b].remove(&next_k[b]) {
            next_k[b] += 1;
            processed += 1;
            let k = m.chunk;
            let range = plan.range(k);
            let mut x_chunks: Vec<Vec<f64>> = Vec::with_capacity(window);
            for (r, fold) in m.rounds.into_iter().enumerate() {
                x_chunks.push(fold.x_sum_chunk);
                match fold.partial {
                    Some(p) => {
                        wire_bytes += p.wire_bytes();
                        session.fold_chunk_partial(r, k, p, &fold.clients, &fold.bits)
                    }
                    None => assert!(fold.clients.is_empty(), "block lost a partial"),
                }
                // the accumulator closes — and frees — the moment the
                // last block's fold lands; no other block is waited on
                if session.chunk_complete(r, k) {
                    let payload = session.finish_chunk(r, k);
                    if decoder.chunk_decodable() {
                        let est = decoder.decode_survivors_chunk(
                            &payload,
                            range.start,
                            &shared[r],
                            &survivor_sets[r],
                        );
                        estimates[r][range.clone()].copy_from_slice(&est);
                    } else {
                        match payload {
                            Payload::Sum(v) if !plan.is_whole() => {
                                sums[r][range.clone()].copy_from_slice(&v)
                            }
                            p => {
                                estimates[r] = decoder.decode_survivors(
                                    &p,
                                    &shared[r],
                                    &survivor_sets[r],
                                );
                            }
                        }
                    }
                }
            }
            let bufs = x_wave.entry(k).or_default();
            bufs.push((b, x_chunks));
            if bufs.len() == n_blocks {
                assert!(
                    session.chunk_fully_closed(k),
                    "every block folded chunk {k} but the session reports unfinished rounds"
                );
                let mut wave = x_wave.remove(&k).expect("wave buffered above");
                wave.sort_by_key(|&(blk, _)| blk);
                for (_, block_chunks) in wave {
                    for (r, chunk_sum) in block_chunks.into_iter().enumerate() {
                        for (o, v) in x_sums[r][range.clone()].iter_mut().zip(&chunk_sum) {
                            *o += v;
                        }
                    }
                }
                // ring advance: chunk k fully closed → admit the next wave
                if next_inject < n_chunks {
                    let admit = next_inject;
                    ws.inject(
                        (0..n_blocks).map(|blk| AsyncTask { block: blk, chunk: admit }),
                    );
                    next_inject += 1;
                }
            }
        }
    }
    let failures = ws.join();
    assert!(
        failures.is_empty(),
        "fail closed: async worker {} panicked after its last report: {}",
        failures.first().map(|f| f.worker).unwrap_or(0),
        failures.first().map(|f| f.message.as_str()).unwrap_or(""),
    );
    let stats = AsyncStreamStats {
        peak_accumulator_bytes: session.peak_accumulator_bytes(),
        wire_bytes,
        chunk: plan.chunk(),
        n_chunks,
        tasks: total_msgs,
        workers: n_workers,
        converted_stragglers: converted,
    };
    let closed = session.close_streamed();
    let reports = closed
        .into_iter()
        .enumerate()
        .map(|(r, (bits, survivors))| {
            let estimate = if !decoder.chunk_decodable()
                && transport.sum_only()
                && !plan.is_whole()
            {
                decoder.decode_survivors(
                    &Payload::Sum(std::mem::take(&mut sums[r])),
                    &shared[r],
                    &survivors,
                )
            } else {
                std::mem::take(&mut estimates[r])
            };
            let n_alive = survivors.n_alive();
            let true_mean: Vec<f64> =
                std::mem::take(&mut x_sums[r]).into_iter().map(|v| v / n_alive as f64).collect();
            let round_id = start_round + r as u64;
            let gamma = policy.amplification_gamma(n, round_id);
            let tv = policy.conditioning_tv(n, round_id);
            let privacy =
                ledger.as_deref_mut().map(|l| l.record_with_tv_slack(round_id, gamma, tv));
            RoundReport {
                round: round_id,
                output: RoundOutput { estimate, bits },
                true_mean,
                survivors: n_alive,
                cohort: cohorts[r].n_alive(),
                privacy,
            }
        })
        .collect();
    (reports, stats)
}

/// Async convenience wrapper for mechanisms implementing both pipeline
/// ends (see [`run_rounds_encoded_async`]).
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_mech_async<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    cfg: &AsyncRunConfig,
) -> (Vec<RoundReport>, AsyncStreamStats)
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    let none: Vec<Vec<usize>> = vec![Vec::new(); window];
    run_rounds_encoded_async(
        pool,
        encoder,
        transport,
        mech,
        start_round,
        window,
        state,
        root_seed,
        &SamplingPolicy::Full,
        &none,
        None,
        cfg,
    )
}

/// Run one round, pipeline shape — the W=1 special case of
/// [`run_rounds_encoded`].
pub fn run_round_encoded(
    pool: &ClientPool,
    encoder: Arc<dyn ClientEncoder>,
    transport: Arc<dyn Transport>,
    decoder: &dyn ServerDecoder,
    round: u64,
    state: &[f64],
    root_seed: u64,
) -> RoundReport {
    run_rounds_encoded(pool, encoder, transport, decoder, round, 1, state, root_seed)
        .pop()
        .expect("one round in, one round out")
}

/// Convenience wrapper for mechanisms that implement both pipeline ends
/// (every mechanism in this crate does).
pub fn run_round_mech<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    round: u64,
    state: &[f64],
    root_seed: u64,
) -> RoundReport
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    run_round_encoded(pool, encoder, transport, mech, round, state, root_seed)
}

/// Windowed convenience wrapper: one transport session over W rounds for a
/// mechanism implementing both pipeline ends.
pub fn run_rounds_mech<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
) -> Vec<RoundReport>
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    run_rounds_encoded(pool, encoder, transport, mech, start_round, window, state, root_seed)
}

/// Windowed convenience wrapper with a per-round dropout schedule (see
/// [`run_rounds_encoded_with_dropouts`]).
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_mech_with_dropouts<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    dropouts: &[Vec<usize>],
) -> Vec<RoundReport>
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    run_rounds_encoded_with_dropouts(
        pool, encoder, transport, mech, start_round, window, state, root_seed, dropouts,
    )
}

/// Windowed convenience wrapper with seed-derived client sampling, an
/// optional mid-round dropout schedule and an optional privacy ledger
/// (see [`run_rounds_encoded_sampled`]).
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_mech_sampled<M>(
    pool: &ClientPool,
    mech: &M,
    transport: Arc<dyn Transport>,
    start_round: u64,
    window: usize,
    state: &[f64],
    root_seed: u64,
    policy: &SamplingPolicy,
    dropouts: &[Vec<usize>],
    ledger: Option<&mut PrivacyLedger>,
) -> Vec<RoundReport>
where
    M: ClientEncoder + ServerDecoder + Clone + 'static,
{
    let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
    run_rounds_encoded_sampled(
        pool, encoder, transport, mech, start_round, window, state, root_seed, policy,
        dropouts, ledger,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mechanisms::pipeline::{Plain, SecAgg};
    use crate::mechanisms::{AggregateGaussian, IrwinHallMechanism, MeanMechanism};

    #[test]
    fn pool_computes_all_clients() {
        let pool = ClientPool::spawn(
            23,
            Arc::new(|c: usize, r: u64, s: &[f64]| vec![c as f64, r as f64, s[0]]),
        );
        let out = pool.compute_round(5, &[7.0]);
        assert_eq!(out.len(), 23);
        for (c, v) in out.iter().enumerate() {
            assert_eq!(v, &vec![c as f64, 5.0, 7.0]);
        }
    }

    #[test]
    fn pool_reusable_across_rounds() {
        let pool = ClientPool::spawn(8, Arc::new(|c: usize, r: u64, _: &[f64]| vec![(c + r as usize) as f64]));
        for round in 0..10 {
            let out = pool.compute_round(round, &[]);
            assert_eq!(out[3][0], 3.0 + round as f64);
        }
    }

    #[test]
    fn run_round_aggregates() {
        let pool = ClientPool::spawn(16, Arc::new(|c: usize, _: u64, _: &[f64]| vec![c as f64; 4]));
        let mech = IrwinHallMechanism::new(0.05, 64.0);
        let rep = run_round(&pool, &mech, 0, &[], 42);
        // true mean of 0..15 = 7.5; estimate within a few noise sd
        for j in 0..4 {
            assert!((rep.true_mean[j] - 7.5).abs() < 1e-12);
            assert!((rep.output.estimate[j] - 7.5).abs() < 1.0, "est {}", rep.output.estimate[j]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let pool = ClientPool::spawn(4, Arc::new(|c: usize, _: u64, _: &[f64]| vec![c as f64]));
        let mech = IrwinHallMechanism::new(0.1, 8.0);
        let a = run_round(&pool, &mech, 3, &[], 99);
        let b = run_round(&pool, &mech, 3, &[], 99);
        assert_eq!(a.output.estimate, b.output.estimate);
    }

    #[test]
    fn single_client_pool() {
        let pool = ClientPool::spawn(1, Arc::new(|_: usize, _: u64, _: &[f64]| vec![1.0]));
        assert_eq!(pool.compute_round(0, &[]), vec![vec![1.0]]);
    }

    #[test]
    fn threads_override_respected_and_equivalent() {
        // same round under different worker counts: identical estimates
        // (integer partials are order-free, x-sums fold in shard order)
        let compute = |c: usize, _: u64, _: &[f64]| {
            let mut rng = crate::util::rng::Rng::derive(4242, c as u64);
            (0..6).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
        };
        let mech = IrwinHallMechanism::new(0.2, 4.0);
        let mut estimates = Vec::new();
        for threads in [1usize, 3, 7] {
            let pool =
                ClientPool::spawn_with_threads(13, Arc::new(compute), Some(threads));
            assert!(pool.shards.len() <= threads);
            let rep = run_round_mech(&pool, &mech, Arc::new(Plain), 2, &[], 77);
            estimates.push(rep.output.estimate.clone());
        }
        assert_eq!(estimates[0], estimates[1]);
        assert_eq!(estimates[0], estimates[2]);
    }

    #[test]
    fn encoded_round_matches_monolithic_round() {
        // per-shard encoding must reproduce MeanMechanism::aggregate bit
        // for bit (same streams, same integer sums)
        let compute = |c: usize, r: u64, _: &[f64]| {
            let mut rng = crate::util::rng::Rng::derive(900 + r, c as u64);
            (0..5).map(|_| rng.uniform(-3.0, 3.0)).collect::<Vec<f64>>()
        };
        let pool = ClientPool::spawn(11, Arc::new(compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        for round in 0..4u64 {
            let mono = run_round(&pool, &mech, round, &[], 5);
            let enc = run_round_mech(&pool, &mech, Arc::new(Plain), round, &[], 5);
            assert_eq!(mono.output.estimate, enc.output.estimate, "round {round}");
            assert_eq!(mono.output.bits.messages, enc.output.bits.messages);
            assert!(
                (mono.output.bits.variable_total - enc.output.bits.variable_total).abs()
                    < 1e-9
            );
            for (a, b) in mono.true_mean.iter().zip(&enc.true_mean) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn encoded_round_through_secagg_matches_plain() {
        let compute = |c: usize, _: u64, _: &[f64]| {
            let mut rng = crate::util::rng::Rng::derive(31, c as u64);
            (0..4).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
        };
        let pool = ClientPool::spawn(9, Arc::new(compute));
        let mech = AggregateGaussian::new(0.4, 4.0);
        let plain = run_round_mech(&pool, &mech, Arc::new(Plain), 1, &[], 11);
        let masked = run_round_mech(&pool, &mech, Arc::new(SecAgg::new()), 1, &[], 11);
        assert_eq!(plain.output.estimate, masked.output.estimate);
    }

    #[test]
    fn pool_drop_joins_threads() {
        for _ in 0..3 {
            let pool = ClientPool::spawn(9, Arc::new(|_: usize, _: u64, _: &[f64]| vec![1.0]));
            let _ = pool.compute_round(0, &[]);
            drop(pool);
        }
    }

    fn round_varying_compute(c: usize, r: u64, _: &[f64]) -> Vec<f64> {
        let mut rng = crate::util::rng::Rng::derive(6000 + r, c as u64);
        (0..5).map(|_| rng.uniform(-3.0, 3.0)).collect()
    }

    /// 64-dimensional sibling of [`round_varying_compute`] for the
    /// streaming-memory tests, whose chunk plans need d >> c.
    fn wide_compute(c: usize, r: u64, _: &[f64]) -> Vec<f64> {
        let mut rng = crate::util::rng::Rng::derive(6100 + r, c as u64);
        (0..64).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn windowed_rounds_match_sequential_single_rounds() {
        // a W=4 window over Plain is bit-identical to 4 sequential W=1
        // calls: same per-round seeds, same estimates, bits and true means
        let pool = ClientPool::spawn(10, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let windowed = run_rounds_mech(&pool, &mech, Arc::new(Plain), 2, 4, &[], 31);
        assert_eq!(windowed.len(), 4);
        for (i, rep) in windowed.iter().enumerate() {
            let round = 2 + i as u64;
            let single = run_round_mech(&pool, &mech, Arc::new(Plain), round, &[], 31);
            assert_eq!(rep.round, round);
            assert_eq!(rep.output.estimate, single.output.estimate, "round {round}");
            assert_eq!(rep.output.bits.messages, single.output.bits.messages);
            for (a, b) in rep.true_mean.iter().zip(&single.true_mean) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn windowed_secagg_session_matches_windowed_plain() {
        // one masking session across the window: estimates must equal the
        // plain-summation window bit for bit (masks cancel per round)
        let pool = ClientPool::spawn(9, Arc::new(round_varying_compute));
        let mech = AggregateGaussian::new(0.5, 8.0);
        let plain = run_rounds_mech(&pool, &mech, Arc::new(Plain), 0, 3, &[], 11);
        let masked = run_rounds_mech(&pool, &mech, Arc::new(SecAgg::new()), 0, 3, &[], 11);
        for (p, m) in plain.iter().zip(&masked) {
            assert_eq!(p.output.estimate, m.output.estimate, "round {}", p.round);
            assert_eq!(p.output.bits.messages, m.output.bits.messages);
        }
    }

    #[test]
    fn windowed_rounds_invariant_under_worker_count() {
        let mech = IrwinHallMechanism::new(0.2, 4.0);
        let mut estimates: Vec<Vec<Vec<f64>>> = Vec::new();
        for threads in [1usize, 3, 5] {
            let pool = ClientPool::spawn_with_threads(
                11,
                Arc::new(round_varying_compute),
                Some(threads),
            );
            let reps =
                run_rounds_mech(&pool, &mech, Arc::new(SecAgg::new()), 1, 3, &[], 77);
            estimates.push(reps.into_iter().map(|r| r.output.estimate).collect());
        }
        assert_eq!(estimates[0], estimates[1]);
        assert_eq!(estimates[0], estimates[2]);
    }

    #[test]
    fn dropout_windowed_secagg_matches_dropout_windowed_plain() {
        // W=4 with a different announced dropout each round: the masked
        // session (with recovery) must equal the Plain session over the
        // same survivors, bit for bit, and report survivor counts
        let pool = ClientPool::spawn(9, Arc::new(round_varying_compute));
        let mech = AggregateGaussian::new(0.5, 8.0);
        let schedule: Vec<Vec<usize>> = vec![vec![2], vec![7], vec![0], vec![5]];
        let plain = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(Plain), 0, 4, &[], 11, &schedule,
        );
        let masked = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(SecAgg::new()), 0, 4, &[], 11, &schedule,
        );
        for (p, m) in plain.iter().zip(&masked) {
            assert_eq!(p.output.estimate, m.output.estimate, "round {}", p.round);
            assert_eq!(p.output.bits.messages, m.output.bits.messages);
            assert_eq!(p.survivors, 8);
            assert_eq!(m.survivors, 8);
            assert_eq!(p.true_mean, m.true_mean);
        }
    }

    #[test]
    fn dropout_true_mean_is_survivor_mean() {
        let pool = ClientPool::spawn(6, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let reps = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(Plain), 3, 1, &[], 9, &[vec![1, 4]],
        );
        let rep = &reps[0];
        assert_eq!(rep.survivors, 4);
        let mut want = vec![0.0f64; 5];
        for c in [0usize, 2, 3, 5] {
            for (w, v) in want.iter_mut().zip(round_varying_compute(c, 3, &[])) {
                *w += v;
            }
        }
        for (a, b) in rep.true_mean.iter().zip(want.iter().map(|v| v / 4.0)) {
            assert!((a - b).abs() < 1e-12);
        }
        // the estimate tracks the survivor mean, not the fleet mean
        for (e, t) in rep.output.estimate.iter().zip(&rep.true_mean) {
            assert!((e - t).abs() < 3.0, "est {e} vs true {t}");
        }
    }

    #[test]
    fn dropout_rounds_invariant_under_worker_count() {
        // shards skipping dropped clients must stay order- and
        // partition-free: identical estimates for any worker count,
        // including shards that lose ALL their clients in some round
        let mech = IrwinHallMechanism::new(0.2, 4.0);
        let schedule: Vec<Vec<usize>> = vec![vec![0, 1, 2], vec![10], vec![4, 9]];
        let mut estimates: Vec<Vec<Vec<f64>>> = Vec::new();
        for threads in [1usize, 4, 11] {
            let pool = ClientPool::spawn_with_threads(
                11,
                Arc::new(round_varying_compute),
                Some(threads),
            );
            let reps = run_rounds_mech_with_dropouts(
                &pool, &mech, Arc::new(SecAgg::new()), 1, 3, &[], 77, &schedule,
            );
            estimates.push(reps.into_iter().map(|r| r.output.estimate).collect());
        }
        assert_eq!(estimates[0], estimates[1]);
        assert_eq!(estimates[0], estimates[2]);
    }

    #[test]
    fn sampling_full_policy_is_the_dropout_path_bit_for_bit() {
        let pool = ClientPool::spawn(8, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let schedule: Vec<Vec<usize>> = vec![vec![3], vec![], vec![0, 6]];
        let a = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(SecAgg::new()), 1, 3, &[], 21, &schedule,
        );
        let b = run_rounds_mech_sampled(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            1,
            3,
            &[],
            21,
            &SamplingPolicy::Full,
            &schedule,
            None,
        );
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.output.estimate, y.output.estimate);
            assert_eq!(x.survivors, y.survivors);
            assert_eq!(y.cohort, 8);
            assert!(y.privacy.is_none());
        }
    }

    #[test]
    fn sampling_sampled_secagg_window_matches_sampled_plain_window() {
        // the acceptance property at the coordinator level: a γ-sampled
        // masked window is bit-identical to Plain over the same cohorts
        let pool = ClientPool::spawn(10, Arc::new(round_varying_compute));
        let mech = AggregateGaussian::new(0.5, 8.0);
        let policy = SamplingPolicy::Poisson { gamma: 0.6 };
        let none: Vec<Vec<usize>> = vec![Vec::new(); 4];
        let plain = run_rounds_mech_sampled(
            &pool, &mech, Arc::new(Plain), 0, 4, &[], 33, &policy, &none, None,
        );
        let masked = run_rounds_mech_sampled(
            &pool, &mech, Arc::new(SecAgg::new()), 0, 4, &[], 33, &policy, &none, None,
        );
        for (p, m) in plain.iter().zip(&masked) {
            assert_eq!(p.output.estimate, m.output.estimate, "round {}", p.round);
            assert_eq!(p.output.bits.messages, m.output.bits.messages);
            assert_eq!(p.cohort, m.cohort);
            assert_eq!(p.survivors, p.cohort, "no dropouts: survivors == cohort");
            // the derived cohorts match the policy's own derivation
            let want = policy.cohort(33, p.round, 10).n_alive();
            assert_eq!(p.cohort, want);
        }
    }

    #[test]
    fn sampling_true_mean_is_the_cohort_mean() {
        let pool = ClientPool::spawn(7, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let policy = SamplingPolicy::FixedSize { k: 3 };
        let reps = run_rounds_mech_sampled(
            &pool,
            &mech,
            Arc::new(Plain),
            5,
            2,
            &[],
            9,
            &policy,
            &[vec![], vec![]],
            None,
        );
        for rep in &reps {
            assert_eq!(rep.cohort, 3);
            assert_eq!(rep.survivors, 3);
            let cohort = policy.cohort(9, rep.round, 7);
            let mut want = vec![0.0f64; 5];
            for c in cohort.alive_iter() {
                for (w, v) in want.iter_mut().zip(round_varying_compute(c, rep.round, &[])) {
                    *w += v;
                }
            }
            for (a, b) in rep.true_mean.iter().zip(want.iter().map(|v| v / 3.0)) {
                assert!((a - b).abs() < 1e-12, "round {}", rep.round);
            }
        }
    }

    #[test]
    fn sampling_composes_with_dropouts_and_ledger() {
        use crate::dp::ledger::PrivacyLedger;
        let n = 8;
        let pool = ClientPool::spawn(n, Arc::new(round_varying_compute));
        let mech = AggregateGaussian::new(0.5, 8.0);
        let policy = SamplingPolicy::FixedSize { k: 5 };
        // drop one cohort member per round (derived from the policy so the
        // schedule is always valid)
        let schedule: Vec<Vec<usize>> = (0..3u64)
            .map(|r| {
                let cohort = policy.cohort(77, r, n);
                vec![cohort.alive_iter().next().expect("fixed-size cohorts are never empty")]
            })
            .collect();
        let mut ledger = PrivacyLedger::new(1.0, 1e-5);
        let masked = run_rounds_mech_sampled(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            0,
            3,
            &[],
            77,
            &policy,
            &schedule,
            Some(&mut ledger),
        );
        let plain = run_rounds_mech_sampled(
            &pool, &mech, Arc::new(Plain), 0, 3, &[], 77, &policy, &schedule, None,
        );
        // fixed-size accounting runs at rate k/n — valid under
        // substitution adjacency with a substitution-calibrated base
        // (see SamplingPolicy::amplification_gamma); this asserts the
        // ledger's contract, not an add/remove guarantee
        let gamma = 5.0 / 8.0;
        let (amp_eps, _) = crate::dp::amplify_by_subsampling(1.0, 1e-5, gamma);
        for (r, (m, p)) in masked.iter().zip(&plain).enumerate() {
            assert_eq!(m.output.estimate, p.output.estimate, "round {r}");
            assert_eq!(m.cohort, 5);
            assert_eq!(m.survivors, 4);
            let spend = m.privacy.expect("ledger threaded");
            assert!((spend.eps_round - amp_eps).abs() < 1e-12);
            assert!(spend.eps_round < 1.0, "amplified ε not below base");
            assert!(
                (spend.eps_total - amp_eps * (r + 1) as f64).abs() < 1e-9,
                "cumulative spend"
            );
        }
        assert_eq!(ledger.rounds(), 3);
    }

    #[test]
    #[should_panic(expected = "sampled out of the cohort")]
    fn sampling_dropping_a_sampled_out_client_fails_closed() {
        let n = 6;
        let pool = ClientPool::spawn(n, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let policy = SamplingPolicy::FixedSize { k: 3 };
        // find a client that is NOT in round 0's cohort and announce it
        let cohort = policy.cohort(5, 0, n);
        let outsider = (0..n)
            .find(|&c| !cohort.is_alive(c))
            .expect("a k=3 cohort of 6 clients always leaves an outsider");
        let _ = run_rounds_mech_sampled(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            0,
            1,
            &[],
            5,
            &policy,
            &[vec![outsider]],
            None,
        );
    }

    #[test]
    fn sampling_rounds_invariant_under_worker_count() {
        let mech = IrwinHallMechanism::new(0.2, 4.0);
        let policy = SamplingPolicy::Poisson { gamma: 0.5 };
        let none: Vec<Vec<usize>> = vec![Vec::new(); 3];
        let mut estimates: Vec<Vec<Vec<f64>>> = Vec::new();
        for threads in [1usize, 4, 11] {
            let pool = ClientPool::spawn_with_threads(
                11,
                Arc::new(round_varying_compute),
                Some(threads),
            );
            let reps = run_rounds_mech_sampled(
                &pool,
                &mech,
                Arc::new(SecAgg::new()),
                1,
                3,
                &[],
                77,
                &policy,
                &none,
                None,
            );
            estimates.push(reps.into_iter().map(|r| r.output.estimate).collect());
        }
        assert_eq!(estimates[0], estimates[1]);
        assert_eq!(estimates[0], estimates[2]);
    }

    #[test]
    fn chunked_coordinator_window_matches_whole_d_window_bit_for_bit() {
        // the tentpole acceptance at the coordinator level: the
        // chunk-streamed runner equals the whole-d sampled runner for
        // every chunk size — estimates, bits, true means, reports — with
        // sampling and dropouts composed
        let n = 9;
        let d = 5;
        let pool = ClientPool::spawn(n, Arc::new(round_varying_compute));
        let mech = AggregateGaussian::new(0.5, 8.0);
        let policy = SamplingPolicy::Poisson { gamma: 0.7 };
        // drop one cohort member in round 1 (derived so the schedule is
        // valid for this root seed)
        let schedule: Vec<Vec<usize>> = (0..3u64)
            .map(|r| {
                if r == 1 {
                    let cohort = policy.cohort(77, r, n);
                    if cohort.n_alive() >= 2 {
                        return vec![cohort
                            .alive_iter()
                            .next()
                            .expect("a cohort with >= 2 members has a first survivor")];
                    }
                }
                Vec::new()
            })
            .collect();
        let whole = run_rounds_mech_sampled(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            0,
            3,
            &[],
            77,
            &policy,
            &schedule,
            None,
        );
        for chunk in [1usize, 2, d, d + 3] {
            let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
            let (chunked, stats) = run_rounds_encoded_chunked(
                &pool,
                encoder,
                Arc::new(SecAgg::new()),
                &mech,
                0,
                3,
                &[],
                77,
                &policy,
                &schedule,
                None,
                d,
                chunk,
            );
            assert_eq!(stats.chunk, chunk.min(d));
            assert_eq!(stats.n_chunks, d.div_ceil(chunk.min(d)));
            for (c, w) in chunked.iter().zip(&whole) {
                assert_eq!(c.output.estimate, w.output.estimate, "chunk {chunk}, round {}", w.round);
                assert_eq!(c.output.bits.messages, w.output.bits.messages);
                assert_eq!(c.output.bits.variable_total, w.output.bits.variable_total);
                assert_eq!(c.true_mean, w.true_mean);
                assert_eq!(c.survivors, w.survivors);
                assert_eq!(c.cohort, w.cohort);
            }
        }
    }

    #[test]
    fn chunked_coordinator_peak_accumulator_bytes_scale_with_chunk() {
        // the memory-model acceptance: the orchestrator's peak
        // accumulator bytes are O(shards · c), never O(d) — with the
        // lock-step barrier at most ~2 chunks per round are in flight
        let n = 8;
        let d = 64;
        let w = 4;
        let pool = ClientPool::spawn_with_threads(n, Arc::new(wide_compute), Some(4));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let chunk = 4usize;
        let (_, small) = run_rounds_mech_chunked(
            &pool, &mech, Arc::new(SecAgg::new()), 0, w, &[], 5, d, chunk,
        );
        let (_, big) = run_rounds_mech_chunked(
            &pool, &mech, Arc::new(SecAgg::new()), 0, w, &[], 5, d, d,
        );
        // whole-d streaming still pins O(shards·W·d); the chunked run
        // must stay far below it, within a small constant of
        // (shards + in-flight) · W · c accumulator payloads
        assert!(small.peak_accumulator_bytes < big.peak_accumulator_bytes / 4, "small {} big {}", small.peak_accumulator_bytes, big.peak_accumulator_bytes);
        let budget = 3 * (4 + 1) * w * chunk * 8; // shards + slack, W rounds, c u64s
        assert!(
            small.peak_accumulator_bytes <= budget,
            "peak {} exceeds O(shards·W·c) budget {budget}",
            small.peak_accumulator_bytes,
        );
        // the packed wire format tightens the per-slot bound from c·8 to
        // ⌈c·w_bits/64⌉·8 — the same budget scaled by the packed ratio
        let slot = crate::coding::packed::PackedZm::byte_len_for(
            chunk,
            crate::secagg::SecAggParams::default().modulus,
        );
        assert!(slot <= chunk * 8, "packed slot {slot} exceeds the u64 slot");
        let packed_budget = 3 * (4 + 1) * w * slot;
        assert!(
            small.peak_accumulator_bytes <= packed_budget,
            "peak {} exceeds the PACKED O(shards·W·⌈c·w/64⌉·8) budget {packed_budget}",
            small.peak_accumulator_bytes,
        );
        // measured channel traffic: every shard ships one packed O(c)
        // partial per (round, chunk) — shards with no cohort clients ship
        // none, so the measured total is bounded by the full-shard count
        assert!(small.wire_bytes > 0, "chunked window moved no payload bytes");
        let n_shards = pool.shard_ranges().len();
        let max_wire: usize = (0..d.div_ceil(chunk))
            .map(|k| {
                let len = chunk.min(d - k * chunk);
                n_shards
                    * w
                    * crate::coding::packed::PackedZm::byte_len_for(
                        len,
                        crate::secagg::SecAggParams::default().modulus,
                    )
            })
            .sum();
        assert!(
            small.wire_bytes <= max_wire,
            "wire {} exceeds shards×rounds×packed-chunk bound {max_wire}",
            small.wire_bytes,
        );
    }

    #[test]
    fn chunked_rounds_invariant_under_worker_count() {
        let mech = AggregateGaussian::new(0.4, 8.0);
        let mut estimates: Vec<Vec<Vec<f64>>> = Vec::new();
        for threads in [1usize, 3, 7] {
            let pool = ClientPool::spawn_with_threads(
                11,
                Arc::new(round_varying_compute),
                Some(threads),
            );
            let (reps, _) = run_rounds_mech_chunked(
                &pool, &mech, Arc::new(SecAgg::new()), 1, 3, &[], 77, 5, 2,
            );
            estimates.push(reps.into_iter().map(|r| r.output.estimate).collect());
        }
        assert_eq!(estimates[0], estimates[1]);
        assert_eq!(estimates[0], estimates[2]);
    }

    #[test]
    fn sampling_schedule_policy_threads_per_round_gamma_into_reports() {
        use crate::dp::ledger::PrivacyLedger;
        let n = 10;
        let pool = ClientPool::spawn(n, Arc::new(round_varying_compute));
        let mech = AggregateGaussian::new(0.5, 8.0);
        let policy = SamplingPolicy::Schedule { gammas: vec![0.3, 0.6, 0.9] };
        let none: Vec<Vec<usize>> = vec![Vec::new(); 4];
        let mut ledger = PrivacyLedger::new(1.0, 1e-5);
        let reps = run_rounds_mech_sampled(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            0,
            4,
            &[],
            91,
            &policy,
            &none,
            Some(&mut ledger),
        );
        for rep in &reps {
            let gamma = policy.round_gamma(rep.round);
            let spend = rep.privacy.expect("ledger threaded");
            assert_eq!(spend.gamma, gamma, "round {}", rep.round);
            let (want_eps, _) = crate::dp::amplify_by_subsampling(1.0, 1e-5, gamma);
            assert!((spend.eps_round - want_eps).abs() < 1e-12, "round {}", rep.round);
            // cohorts really were drawn at the scheduled rate
            let want_cohort = policy.cohort(91, rep.round, n).n_alive();
            assert_eq!(rep.cohort, want_cohort);
        }
        // warmup: later rounds spend more ε than the γ=0.3 round
        let eps: Vec<f64> = reps.iter().map(|r| r.privacy.unwrap().eps_round).collect();
        assert!(eps[0] < eps[1] && eps[1] < eps[2]);
        // the last rate persists: round 3 spends like round 2
        assert!((eps[2] - eps[3]).abs() < 1e-12);
    }

    #[test]
    fn dropout_empty_schedule_is_bit_identical_to_plain_run() {
        let pool = ClientPool::spawn(7, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let none: Vec<Vec<usize>> = vec![Vec::new(); 2];
        let a = run_rounds_mech(&pool, &mech, Arc::new(SecAgg::new()), 0, 2, &[], 5);
        let b = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(SecAgg::new()), 0, 2, &[], 5, &none,
        );
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.output.estimate, y.output.estimate);
            assert_eq!(x.survivors, 7);
            assert_eq!(y.survivors, 7);
        }
    }

    #[test]
    fn async_coordinator_matches_whole_d_runner_bit_for_bit() {
        // the tentpole acceptance: the work-stealing runner equals the
        // whole-d barrier runner — whole RoundReports, exact PartialEq —
        // for every chunk size, with sampling and dropouts composed
        let n = 9;
        let d = 5;
        let pool = ClientPool::spawn_with_threads(n, Arc::new(round_varying_compute), Some(3));
        let mech = AggregateGaussian::new(0.5, 8.0);
        let policy = SamplingPolicy::Poisson { gamma: 0.7 };
        let schedule: Vec<Vec<usize>> = (0..3u64)
            .map(|r| {
                if r == 1 {
                    let cohort = policy.cohort(77, r, n);
                    if cohort.n_alive() >= 2 {
                        return vec![cohort
                            .alive_iter()
                            .next()
                            .expect("a cohort with >= 2 members has a first survivor")];
                    }
                }
                Vec::new()
            })
            .collect();
        let whole = run_rounds_mech_sampled(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            0,
            3,
            &[],
            77,
            &policy,
            &schedule,
            None,
        );
        for chunk in [1usize, 2, d, d + 3] {
            let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
            let cfg = AsyncRunConfig::new(d, chunk);
            let (reports, stats) = run_rounds_encoded_async(
                &pool,
                encoder,
                Arc::new(SecAgg::new()),
                &mech,
                0,
                3,
                &[],
                77,
                &policy,
                &schedule,
                None,
                &cfg,
            );
            assert_eq!(stats.chunk, chunk.min(d));
            assert_eq!(stats.n_chunks, d.div_ceil(chunk.min(d)));
            assert_eq!(stats.tasks, stats.n_chunks * pool.shard_ranges().len());
            assert_eq!(stats.converted_stragglers, 0);
            assert_eq!(reports, whole, "chunk {chunk}");
        }
    }

    #[test]
    fn async_rounds_invariant_under_workers_and_ring() {
        // scheduler geometry is not allowed to change any bit: every
        // (workers, ring) pair reproduces the same reports on the same
        // pool, and different pool partitions agree on the estimates
        let mech = AggregateGaussian::new(0.4, 8.0);
        let pool =
            ClientPool::spawn_with_threads(11, Arc::new(round_varying_compute), Some(4));
        let (base, _) = run_rounds_mech_async(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            1,
            3,
            &[],
            77,
            &AsyncRunConfig::new(5, 2),
        );
        for workers in [1usize, 3, 8] {
            for ring in [1usize, 2, 4] {
                let cfg = AsyncRunConfig::new(5, 2).with_workers(workers).with_ring(ring);
                let (reps, stats) = run_rounds_mech_async(
                    &pool,
                    &mech,
                    Arc::new(SecAgg::new()),
                    1,
                    3,
                    &[],
                    77,
                    &cfg,
                );
                assert_eq!(stats.workers, workers);
                assert_eq!(reps, base, "workers {workers} ring {ring}");
            }
        }
        for threads in [1usize, 3, 7] {
            let p2 = ClientPool::spawn_with_threads(
                11,
                Arc::new(round_varying_compute),
                Some(threads),
            );
            let (reps, _) = run_rounds_mech_async(
                &p2,
                &mech,
                Arc::new(SecAgg::new()),
                1,
                3,
                &[],
                77,
                &AsyncRunConfig::new(5, 2),
            );
            for (a, b) in reps.iter().zip(&base) {
                assert_eq!(a.output.estimate, b.output.estimate, "threads {threads}");
            }
        }
    }

    #[test]
    fn async_deadline_infinite_is_the_barrier_runner_exactly() {
        // deadline = ∞ draws nothing and converts nobody: the async
        // window IS the barrier window, whole reports, exact equality
        let pool = ClientPool::spawn_with_threads(9, Arc::new(round_varying_compute), Some(3));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let barrier = run_rounds_mech(&pool, &mech, Arc::new(SecAgg::new()), 2, 3, &[], 31);
        let cfg = AsyncRunConfig::new(5, 2); // deadline: none
        let (reps, stats) =
            run_rounds_mech_async(&pool, &mech, Arc::new(SecAgg::new()), 2, 3, &[], 31, &cfg);
        assert_eq!(stats.converted_stragglers, 0);
        assert_eq!(reps, barrier);
    }

    #[test]
    fn async_straggler_past_deadline_equals_preannounced_dropout() {
        // the deadline-conversion identity: running the async coordinator
        // WITH a deadline equals pre-announcing the converted stragglers
        // explicitly on the barrier runner — the same schedule by
        // construction, hence the same bits
        let n = 10;
        let w = 3;
        let mech = AggregateGaussian::new(0.5, 8.0);
        let policy = DeadlinePolicy::with_deadline(2.0, 0.4, 1.0);
        let none: Vec<Vec<usize>> = vec![Vec::new(); w];
        let mut checked = 0usize;
        for seed in 0..50u64 {
            let cohorts = vec![SurvivorSet::full(n); w];
            let (merged, converted) = policy.convert(seed, 0, &cohorts, &none);
            if converted == 0 {
                continue;
            }
            let pool =
                ClientPool::spawn_with_threads(n, Arc::new(round_varying_compute), Some(3));
            let encoder: Arc<dyn ClientEncoder> = Arc::new(mech.clone());
            let cfg = AsyncRunConfig::new(5, 2).with_deadline(policy);
            let (with_deadline, stats) = run_rounds_encoded_async(
                &pool,
                encoder,
                Arc::new(SecAgg::new()),
                &mech,
                0,
                w,
                &[],
                seed,
                &SamplingPolicy::Full,
                &none,
                None,
                &cfg,
            );
            assert_eq!(stats.converted_stragglers, converted, "seed {seed}");
            let reference = run_rounds_mech_with_dropouts(
                &pool,
                &mech,
                Arc::new(SecAgg::new()),
                0,
                w,
                &[],
                seed,
                &merged,
            );
            assert_eq!(with_deadline, reference, "seed {seed}");
            checked += 1;
            if checked >= 3 {
                break;
            }
        }
        assert!(checked >= 1, "no seed in 0..50 converted a straggler — retune the rate");
    }

    #[test]
    #[should_panic(expected = "round 4 (window round 1) would close with zero survivors")]
    fn dropping_an_entire_cohort_fails_closed_naming_the_round() {
        // satellite-2 regression: emptying one round of a window must
        // fail closed naming the GLOBAL round, before any shard works
        let pool = ClientPool::spawn(5, Arc::new(round_varying_compute));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let schedule: Vec<Vec<usize>> = vec![Vec::new(), (0..5).collect(), Vec::new()];
        let _ = run_rounds_mech_with_dropouts(
            &pool, &mech, Arc::new(SecAgg::new()), 3, 3, &[], 9, &schedule,
        );
    }

    fn exploding_compute(c: usize, _r: u64, _s: &[f64]) -> Vec<f64> {
        if c == 5 {
            panic!("client 5 compute exploded");
        }
        vec![1.0; 5]
    }

    #[test]
    fn shard_panic_propagates_shard_id_and_message() {
        // satellite-1 regression: the orchestrator's fail-closed panic
        // names the shard and carries the original panic message instead
        // of a bare "shard result" disconnect
        let pool = ClientPool::spawn_with_threads(8, Arc::new(exploding_compute), Some(4));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_round(&pool, &mech, 0, &[], 1)
        }))
        .err()
        .expect("a shard panic must fail the round closed");
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("shard 2"), "unexpected message: {msg}");
        assert!(msg.contains("panicked during local compute"), "unexpected message: {msg}");
        assert!(msg.contains("client 5 compute exploded"), "unexpected message: {msg}");
    }

    #[test]
    fn encode_window_panic_propagates_shard_id_and_message() {
        let pool = ClientPool::spawn_with_threads(8, Arc::new(exploding_compute), Some(4));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_rounds_mech(&pool, &mech, Arc::new(Plain), 0, 2, &[], 1)
        }))
        .err()
        .expect("a shard panic must fail the window closed");
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("shard 2"), "unexpected message: {msg}");
        assert!(msg.contains("panicked while encoding"), "unexpected message: {msg}");
        assert!(msg.contains("client 5 compute exploded"), "unexpected message: {msg}");
    }

    #[test]
    fn chunked_shard_panic_fails_closed_naming_shard_and_cause() {
        let pool = ClientPool::spawn_with_threads(8, Arc::new(exploding_compute), Some(4));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_rounds_mech_chunked(&pool, &mech, Arc::new(Plain), 0, 2, &[], 1, 5, 2)
        }))
        .err()
        .expect("a shard panic must fail the chunked window closed");
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("shard 2"), "unexpected message: {msg}");
        assert!(
            msg.contains("panicked while encoding the chunked window"),
            "unexpected message: {msg}"
        );
        assert!(msg.contains("client 5 compute exploded"), "unexpected message: {msg}");
    }

    #[test]
    fn async_worker_panic_propagates_worker_and_message() {
        // a task panic poisons the scheduler and the orchestrator fails
        // closed naming the worker and the original cause — never a hang,
        // never a bare disconnect
        let pool = ClientPool::spawn_with_threads(8, Arc::new(exploding_compute), Some(4));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_rounds_mech_async(
                &pool,
                &mech,
                Arc::new(Plain),
                0,
                2,
                &[],
                1,
                &AsyncRunConfig::new(5, 2),
            )
        }))
        .err()
        .expect("a worker panic must fail the async window closed");
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("async worker"), "unexpected message: {msg}");
        assert!(msg.contains("client 5 compute exploded"), "unexpected message: {msg}");
    }

    #[test]
    fn async_peak_accumulator_bytes_scale_with_ring_and_chunk() {
        // the memory-model acceptance: live accumulators are bounded by
        // the ring — O(ring · W · c) — never O(d)
        let n = 8;
        let d = 64;
        let w = 4;
        let chunk = 4usize;
        let ring = 2usize;
        let pool = ClientPool::spawn_with_threads(n, Arc::new(wide_compute), Some(4));
        let mech = IrwinHallMechanism::new(0.3, 8.0);
        let (_, small) = run_rounds_mech_async(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            0,
            w,
            &[],
            5,
            &AsyncRunConfig::new(d, chunk).with_ring(ring),
        );
        let (_, big) = run_rounds_mech_async(
            &pool,
            &mech,
            Arc::new(SecAgg::new()),
            0,
            w,
            &[],
            5,
            &AsyncRunConfig::new(d, d),
        );
        assert!(
            small.peak_accumulator_bytes < big.peak_accumulator_bytes / 4,
            "small {} big {}",
            small.peak_accumulator_bytes,
            big.peak_accumulator_bytes
        );
        // ring waves of W rounds' O(c) accumulators, with fold slack
        let budget = 3 * (ring + 1) * w * chunk * 8;
        assert!(
            small.peak_accumulator_bytes <= budget,
            "peak {} exceeds O(ring·W·c) budget {budget}",
            small.peak_accumulator_bytes,
        );
        // packed per-slot bound: the same budget at ⌈c·w_bits/64⌉·8
        let slot = crate::coding::packed::PackedZm::byte_len_for(
            chunk,
            crate::secagg::SecAggParams::default().modulus,
        );
        assert!(slot <= chunk * 8, "packed slot {slot} exceeds the u64 slot");
        let packed_budget = 3 * (ring + 1) * w * slot;
        assert!(
            small.peak_accumulator_bytes <= packed_budget,
            "peak {} exceeds the PACKED O(ring·W·⌈c·w/64⌉·8) budget {packed_budget}",
            small.peak_accumulator_bytes,
        );
        // measured packed traffic: one packed O(c) partial per (block,
        // round, chunk). Chunking can only add per-chunk word-boundary
        // rounding on top of the whole-d payload, never remove bytes
        assert!(small.wire_bytes > 0, "async window moved no payload bytes");
        assert!(
            small.wire_bytes >= big.wire_bytes,
            "chunked wire {} fell below the whole-d packed payload {}",
            small.wire_bytes,
            big.wire_bytes,
        );
        let n_blocks = pool.shard_ranges().len();
        let max_wire: usize = (0..d.div_ceil(chunk))
            .map(|k| {
                let len = chunk.min(d - k * chunk);
                n_blocks
                    * w
                    * crate::coding::packed::PackedZm::byte_len_for(
                        len,
                        crate::secagg::SecAggParams::default().modulus,
                    )
            })
            .sum();
        assert!(
            small.wire_bytes <= max_wire,
            "wire {} exceeds blocks×rounds×packed-chunk bound {max_wire}",
            small.wire_bytes,
        );
    }
}
