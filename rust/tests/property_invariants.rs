//! Property-based tests (in-repo `testing` harness — proptest is not in
//! the offline registry) over coordinator and mechanism invariants.

use exact_comp::coding::bitio::{BitReader, BitWriter};
use exact_comp::coding::elias;
use exact_comp::coding::fixed::FixedCode;
use exact_comp::dist::{Continuous, Gaussian, Unimodal};
use exact_comp::mechanisms::pipeline::{
    run_pipeline, ClientEncoder, MechSpec, Plain, SecAgg, ServerDecoder, Transport, Unicast,
};
use exact_comp::mechanisms::pipeline::SurvivorSet;
use exact_comp::mechanisms::session::{run_window, run_window_with_dropouts, RoundDropouts, TransportSession};
use exact_comp::mechanisms::traits::MeanMechanism;
use exact_comp::mechanisms::{
    AggregateGaussian, IndividualGaussian, IrwinHallMechanism, LayeredVariant, Pipeline, Sigm,
};
use exact_comp::quantizer::{DirectLayered, PointQuantizer, ShiftedLayered, SubtractiveDither};
use exact_comp::secagg::{aggregate_masked, mask_descriptions, SecAggParams};
use exact_comp::coordinator::sampling::SamplingPolicy;
use exact_comp::testing::{
    assert_sampled_window_closes_exactly, assert_window_closes_exactly, dropout_schedule,
    forall, gen_f64, gen_usize, Fleet, PropConfig,
};
use exact_comp::transforms::hadamard::RandomizedRotation;
use exact_comp::util::rng::Rng;

fn cfg(cases: u32) -> PropConfig {
    PropConfig { cases, seed: 0xFACADE, max_shrink_steps: 100 }
}

#[test]
fn prop_dither_error_bounded_by_half_step() {
    // |decode(encode(x)) - x| <= step/2 for ANY x and any step draw
    let q = SubtractiveDither::new(0.9);
    let mut srng = Rng::new(1);
    forall("dither-error-bound", cfg(300), gen_f64(-1e6, 1e6), move |&x| {
        let (_, y, s) = q.quantize(x, &mut srng);
        (y - x).abs() <= s.step / 2.0 + 1e-9
    });
}

#[test]
fn prop_layered_error_bounded_by_layer() {
    // the layered quantizers' error lies inside the drawn layer interval
    let g = Gaussian::new(0.0, 1.0);
    let direct = DirectLayered::new(g);
    let shifted = ShiftedLayered::new(g);
    let mut srng = Rng::new(2);
    forall("layered-error-in-layer", cfg(300), gen_f64(-1e4, 1e4), move |&x| {
        let (_, y1, s1) = direct.quantize(x, &mut srng);
        let ok1 = (y1 - x - s1.offset).abs() <= s1.step / 2.0 + 1e-9;
        let (_, y2, s2) = shifted.quantize(x, &mut srng);
        let ok2 = (y2 - x - s2.offset).abs() <= s2.step / 2.0 + 1e-9;
        ok1 && ok2
    });
}

#[test]
fn prop_shifted_step_at_least_eta() {
    let g = Gaussian::new(0.0, 2.0);
    let q = ShiftedLayered::new(g);
    let eta = q.min_step().unwrap();
    let mut srng = Rng::new(3);
    forall("shifted-min-step", cfg(500), gen_usize(0, 1000), move |_| {
        let s = q.draw(&mut srng);
        s.step >= eta - 1e-9
    });
}

#[test]
fn prop_elias_roundtrip_any_vector() {
    forall(
        "elias-roundtrip",
        cfg(200),
        |rng: &mut Rng| {
            let len = 1 + rng.below(64) as usize;
            (0..len).map(|_| rng.below(2_000_000) as i64 - 1_000_000).collect::<Vec<i64>>()
        },
        |ms| {
            let (bytes, _) = elias::encode_vec(ms);
            elias::decode_vec(&bytes, ms.len()).as_deref() == Some(ms.as_slice())
        },
    );
}

#[test]
fn prop_fixed_code_roundtrip() {
    forall(
        "fixed-roundtrip",
        cfg(200),
        |rng: &mut Rng| {
            let lo = rng.below(1000) as i64 - 500;
            let hi = lo + rng.below(1000) as i64;
            let m = lo + rng.below((hi - lo + 1) as u64) as i64;
            (lo, (hi, m))
        },
        |&(lo, (hi, m))| {
            let c = FixedCode::new(lo, hi);
            let mut w = BitWriter::new();
            c.encode(&mut w, m);
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            c.decode(&mut r) == Some(m)
        },
    );
}

#[test]
fn prop_secagg_masks_cancel() {
    forall(
        "secagg-cancel",
        cfg(60),
        |rng: &mut Rng| {
            let n = 2 + rng.below(9) as usize;
            let d = 1 + rng.below(32) as usize;
            let seed = rng.below(1 << 30) as usize;
            (n, (d, seed))
        },
        |&(n, (d, seed))| {
            let params = SecAggParams::default();
            let mut rng = Rng::new(seed as u64);
            let descriptions: Vec<Vec<i64>> = (0..n)
                .map(|_| (0..d).map(|_| rng.below(2000) as i64 - 1000).collect())
                .collect();
            let masked: Vec<Vec<u64>> = (0..n)
                .map(|i| mask_descriptions(&descriptions[i], i, n, seed as u64, params))
                .collect();
            let agg = aggregate_masked(&masked, params);
            (0..d).all(|j| agg[j] == descriptions.iter().map(|m| m[j]).sum::<i64>())
        },
    );
}

#[test]
fn prop_rotation_isometry_and_inverse() {
    forall(
        "rotation-roundtrip",
        cfg(60),
        |rng: &mut Rng| {
            let d = 1 + rng.below(200) as usize;
            let seed = rng.below(1 << 30) as usize;
            let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            (x, seed)
        },
        |(x, seed)| {
            if x.is_empty() {
                return true; // shrinking may empty the vector
            }
            let rot = RandomizedRotation::new(x.len(), *seed as u64);
            let y = rot.forward(x);
            let norm_ok = (exact_comp::util::stats::l2_norm(&y)
                - exact_comp::util::stats::l2_norm(x))
            .abs()
                < 1e-8 * (1.0 + exact_comp::util::stats::l2_norm(x));
            let back = rot.inverse(&y, x.len());
            let inv_ok = back
                .iter()
                .zip(x)
                .all(|(a, b)| (a - b).abs() < 1e-8 * (1.0 + b.abs()));
            norm_ok && inv_ok
        },
    );
}

#[test]
fn prop_superlevel_geometry_consistent() {
    // for every height y: pdf(b_plus(y)) == y and width >= 0, for Gaussian
    // of random scale
    forall(
        "superlevel-geometry",
        cfg(200),
        |rng: &mut Rng| (rng.uniform(0.1, 5.0), rng.u01()),
        |&(sd, frac)| {
            if sd <= 0.0 {
                return true; // shrunk out of the valid domain
            }
            let g = Gaussian::new(0.0, sd);
            let y = frac.clamp(1e-9, 0.999) * g.max_pdf();
            let bp = g.b_plus(y);
            let ok_inv = (g.pdf(bp) - y).abs() < 1e-9 * g.max_pdf();
            ok_inv && g.layer_width(y) >= 0.0 && bp >= g.mode()
        },
    );
}

#[test]
fn prop_mechanism_estimate_within_noise_envelope() {
    // the aggregate-Gaussian estimate deviates from the true mean by at
    // most a few σ per coordinate (no wild decoding errors for any data)
    use exact_comp::mechanisms::traits::true_mean;
    use exact_comp::mechanisms::traits::MeanMechanism;
    let sigma = 0.25;
    let mech = exact_comp::mechanisms::AggregateGaussian::new(sigma, 8.0);
    forall(
        "estimate-envelope",
        cfg(40),
        |rng: &mut Rng| {
            let n = 2 + rng.below(12) as usize;
            let d = 1 + rng.below(8) as usize;
            let xs: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..d).map(|_| rng.uniform(-4.0, 4.0)).collect())
                .collect();
            let seed = rng.below(1 << 30) as usize;
            (xs, seed)
        },
        move |(xs, seed)| {
            if xs.is_empty() || xs.iter().any(|x| x.is_empty() || x.len() != xs[0].len()) {
                return true; // shrunk into an invalid shape
            }
            let out = mech.aggregate(xs, *seed as u64);
            let mean = true_mean(xs);
            out.estimate
                .iter()
                .zip(&mean)
                .all(|(e, m)| (e - m).abs() < 8.0 * sigma)
        },
    );
}

#[test]
fn prop_huffman_roundtrip_random_tables() {
    use exact_comp::coding::huffman::Huffman;
    forall(
        "huffman-roundtrip",
        cfg(80),
        |rng: &mut Rng| {
            let k = 1 + rng.below(40) as usize;
            let syms: Vec<(i64, f64)> =
                (0..k).map(|i| (i as i64 - 20, rng.u01() + 1e-6)).collect();
            let msg: Vec<i64> =
                (0..30).map(|_| syms[rng.below(k as u64) as usize].0).collect();
            (syms.iter().map(|&(s, _)| s).collect::<Vec<i64>>(), msg)
        },
        |(sym_ids, msg)| {
            if sym_ids.is_empty() {
                return true;
            }
            let mut ids = sym_ids.clone();
            ids.sort_unstable();
            ids.dedup();
            let syms: Vec<(i64, f64)> = ids.iter().map(|&s| (s, 1.0)).collect();
            let h = Huffman::from_weights(&syms);
            let mut w = BitWriter::new();
            for &s in msg {
                if !ids.contains(&s) {
                    continue;
                }
                if !h.encode(&mut w, s) {
                    return false;
                }
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            msg.iter().filter(|s| ids.contains(s)).all(|&s| h.decode(&mut r) == Some(s))
        },
    );
}

// ---------------------------------------------------------------------------
// pipeline invariants: encoder / transport / decoder
// ---------------------------------------------------------------------------

/// Run one mechanism over Plain and SecAgg and demand *bit-identical*
/// RoundOutput: the transport may change who sees what, never the value.
fn transports_bit_identical<M>(mech: &M, xs: &[Vec<f64>], seed: u64) -> bool
where
    M: ClientEncoder + ServerDecoder + MechSpec,
{
    let plain = run_pipeline(mech, &Plain, mech, xs, seed);
    let masked = run_pipeline(mech, &SecAgg::new(), mech, xs, seed);
    plain.estimate == masked.estimate
        && plain.bits.messages == masked.bits.messages
        && plain.bits.variable_total == masked.bits.variable_total
        && plain.bits.fixed_total == masked.bits.fixed_total
}

fn gen_round_shape(rng: &mut Rng) -> (usize, (usize, usize)) {
    let n = 2 + rng.below(10) as usize;
    let d = 1 + rng.below(12) as usize;
    let seed = rng.below(1 << 30) as usize;
    (n, (d, seed))
}

/// Round data via the shared [`Fleet`] harness (one derivation for every
/// test file instead of per-test `client_data` copies).
fn gen_round_data(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
    Fleet::new(n, d, seed).round_data(0)
}

#[test]
fn prop_irwin_hall_plain_secagg_bit_identical() {
    forall("ih-transport-identical", cfg(40), gen_round_shape, |&(n, (d, seed))| {
        if n < 2 || d == 0 {
            return true; // shrunk out of the valid domain
        }
        let xs = gen_round_data(n, d, seed as u64);
        transports_bit_identical(&IrwinHallMechanism::new(0.4, 8.0), &xs, seed as u64)
    });
}

#[test]
fn prop_aggregate_gaussian_plain_secagg_bit_identical() {
    forall("agg-transport-identical", cfg(25), gen_round_shape, |&(n, (d, seed))| {
        if n < 2 || d == 0 {
            return true;
        }
        let xs = gen_round_data(n, d, seed as u64);
        transports_bit_identical(&AggregateGaussian::new(0.6, 8.0), &xs, seed as u64)
    });
}

#[test]
fn prop_csgm_plain_secagg_bit_identical() {
    forall("csgm-transport-identical", cfg(25), gen_round_shape, |&(n, (d, seed))| {
        if n < 2 || d == 0 {
            return true;
        }
        let xs = gen_round_data(n, d, seed as u64);
        transports_bit_identical(
            &exact_comp::baselines::Csgm::new(0.2, 0.6, 4.0, 6),
            &xs,
            seed as u64,
        )
    });
}

#[test]
fn prop_ddg_plain_secagg_bit_identical() {
    forall("ddg-transport-identical", cfg(12), gen_round_shape, |&(n, (d, seed))| {
        if n < 2 || d == 0 {
            return true;
        }
        let xs = gen_round_data(n, d, seed as u64);
        let mech = exact_comp::baselines::Ddg::new(1.5, 1e-2, 4.0, 26);
        // DDG's own uplink is SecAgg over Z_{2^b}; the decoder owns the
        // modular reduction, so the exact i64 sum decodes identically
        let plain = run_pipeline(&mech, &Plain, &mech, &xs, seed as u64);
        let masked = run_pipeline(&mech, &mech.transport(), &mech, &xs, seed as u64);
        plain.estimate == masked.estimate && plain.bits.messages == masked.bits.messages
    });
}

// ---------------------------------------------------------------------------
// session invariants: batched multi-round windows
// ---------------------------------------------------------------------------

/// Run a mechanism through a W=4 windowed session over `windowed_transport`
/// and demand *bit-identical* per-round [`exact_comp::mechanisms::RoundOutput`]s
/// against 4 independent rounds over `independent_transport`: batching may
/// change when masks are derived and when rounds close, never the values.
fn windowed_matches_independent<M>(
    mech: &M,
    windowed_transport: &dyn Transport,
    independent_transport: &dyn Transport,
    n: usize,
    d: usize,
    seed: u64,
) -> bool
where
    M: ClientEncoder + ServerDecoder + MechSpec,
{
    const W: usize = 4;
    let datasets: Vec<Vec<Vec<f64>>> =
        (0..W).map(|r| gen_round_data(n, d, seed ^ (0xABC0 + r as u64))).collect();
    let round_seeds: Vec<u64> =
        (0..W).map(|r| seed.wrapping_add(1 + 7919 * r as u64)).collect();
    let rounds: Vec<(&[Vec<f64>], u64)> =
        datasets.iter().zip(&round_seeds).map(|(xs, &s)| (xs.as_slice(), s)).collect();
    let windowed = run_window(mech, windowed_transport, mech, &rounds, seed ^ 0x5E55);
    rounds.iter().zip(&windowed).all(|(&(xs, s), w)| {
        let ind = run_pipeline(mech, independent_transport, mech, xs, s);
        w.estimate == ind.estimate
            && w.bits.messages == ind.bits.messages
            && w.bits.variable_total == ind.bits.variable_total
            && w.bits.fixed_total == ind.bits.fixed_total
    })
}

/// The acceptance invariant: a W=4 windowed SecAgg session — ONE masking
/// session, per-round mask roots from the session stream, one batched
/// unmask — is bit-identical to 4 independent Plain rounds, for every
/// homomorphic mechanism (DDG runs over its own ℤ_{2^b} SecAgg).
#[test]
fn prop_w4_windowed_secagg_session_equals_independent_plain_rounds() {
    forall("w4-secagg-vs-plain", cfg(8), gen_round_shape, |&(n, (d, seed))| {
        if n < 2 || d == 0 {
            return true;
        }
        let seed = seed as u64;
        let ddg = exact_comp::baselines::Ddg::new(1.5, 1e-2, 4.0, 26);
        windowed_matches_independent(
            &IrwinHallMechanism::new(0.4, 8.0),
            &SecAgg::new(),
            &Plain,
            n,
            d,
            seed,
        ) && windowed_matches_independent(
            &AggregateGaussian::new(0.6, 8.0),
            &SecAgg::new(),
            &Plain,
            n,
            d,
            seed,
        ) && windowed_matches_independent(
            &exact_comp::baselines::Csgm::new(0.2, 0.6, 4.0, 6),
            &SecAgg::new(),
            &Plain,
            n,
            d,
            seed,
        ) && windowed_matches_independent(&ddg, &ddg.transport(), &Plain, n, d, seed)
    });
}

/// The non-homomorphic mechanisms cannot ride SecAgg, but their windowed
/// Unicast sessions must still equal independent Unicast rounds — the ring
/// of per-round accumulators is transport-agnostic.
#[test]
fn prop_w4_windowed_unicast_session_equals_independent_rounds() {
    forall("w4-unicast-window", cfg(6), gen_round_shape, |&(n, (d, seed))| {
        if n < 2 || d == 0 {
            return true;
        }
        let seed = seed as u64;
        windowed_matches_independent(
            &IndividualGaussian::new(0.3, LayeredVariant::Shifted, 4.0),
            &Unicast,
            &Unicast,
            n,
            d,
            seed,
        ) && windowed_matches_independent(&Sigm::new(0.3, 0.5, 4.0), &Unicast, &Unicast, n, d, seed)
            && windowed_matches_independent(
                &exact_comp::baselines::UnbiasedQuantizer::new(6),
                &Unicast,
                &Unicast,
                n,
                d,
                seed,
            )
    });
}

/// Satellite edge case: a W=1 SecAgg session IS the single-round path —
/// bit-identical to the mechanism's plain `aggregate` for any shape.
#[test]
fn prop_window_of_one_equals_single_round_path() {
    forall("w1-vs-single-round", cfg(20), gen_round_shape, |&(n, (d, seed))| {
        if n < 2 || d == 0 {
            return true;
        }
        let seed = seed as u64;
        let xs = gen_round_data(n, d, seed);
        let mech = IrwinHallMechanism::new(0.4, 8.0);
        let w = run_window(&mech, &SecAgg::new(), &mech, &[(xs.as_slice(), seed)], seed);
        let single = mech.aggregate(&xs, seed);
        w.len() == 1
            && w[0].estimate == single.estimate
            && w[0].bits.messages == single.bits.messages
            && w[0].bits.variable_total == single.bits.variable_total
            && w[0].bits.fixed_total == single.bits.fixed_total
    });
}

/// The satellite KS check: the error of the *pipeline* aggregate Gaussian
/// mechanism — clients encode, SecAgg delivers only Σm, the server decodes
/// — is exactly N(0, σ²).
#[test]
fn pipeline_gaussian_error_is_exactly_gaussian() {
    let sigma = 0.5;
    let xs = gen_round_data(6, 4, 0xF00D);
    let mech = Pipeline::secagg(AggregateGaussian::new(sigma, 8.0));
    let mean = exact_comp::mechanisms::traits::true_mean(&xs);
    let mut errs = Vec::new();
    for r in 0..900u64 {
        let out = mech.aggregate(&xs, 60_000 + r);
        for j in 0..mean.len() {
            errs.push(out.estimate[j] - mean[j]);
        }
    }
    let g = Gaussian::new(0.0, sigma);
    let res = exact_comp::util::stats::ks_test(&errs, |e| g.cdf(e));
    assert!(res.p_value > 0.003, "pipeline AINQ violated: p={}", res.p_value);
    let v = exact_comp::util::stats::variance(&errs);
    assert!((v - sigma * sigma).abs() < 0.02, "var={v}");
}

/// Pipeline wrapper advertises the right flags and names the transport.
#[test]
fn pipeline_wrapper_metadata() {
    let p = Pipeline::secagg(IrwinHallMechanism::new(0.3, 4.0));
    assert!(MeanMechanism::is_homomorphic(&p));
    assert!(MeanMechanism::name(&p).contains("secagg"));
    let u = Pipeline::unicast(exact_comp::mechanisms::IndividualGaussian::new(
        0.3,
        exact_comp::mechanisms::LayeredVariant::Shifted,
        4.0,
    ));
    assert!(!MeanMechanism::is_homomorphic(&u));
}

// ---------------------------------------------------------------------------
// dropout-robust sessions: recovery ≡ Plain-over-survivors, per mechanism
// ---------------------------------------------------------------------------

/// The acceptance invariant: a W=4 SecAgg window with ONE announced
/// dropout per round closes successfully and decodes bit-identically to
/// Plain summation over the survivor set — for EVERY homomorphic
/// mechanism (DDG over its own ℤ_{2^b} SecAgg).
#[test]
fn dropout_w4_secagg_recovery_bit_identical_per_mechanism() {
    for (n, d, seed) in [(4usize, 3usize, 0xA1u64), (7, 5, 0xB2), (10, 2, 0xC3)] {
        let fleet = Fleet::new(n, d, seed);
        let schedule = dropout_schedule(n, 4, 1, seed ^ 0xD0);
        assert_window_closes_exactly(
            &IrwinHallMechanism::new(0.4, 8.0),
            &SecAgg::new(),
            &fleet,
            &schedule,
            seed,
        );
        assert_window_closes_exactly(
            &AggregateGaussian::new(0.6, 8.0),
            &SecAgg::new(),
            &fleet,
            &schedule,
            seed,
        );
        assert_window_closes_exactly(
            &exact_comp::baselines::Csgm::new(0.2, 0.6, 4.0, 6),
            &SecAgg::new(),
            &fleet,
            &schedule,
            seed,
        );
        let ddg = exact_comp::baselines::Ddg::new(1.5, 1e-2, 4.0, 26);
        assert_window_closes_exactly(&ddg, &ddg.transport(), &fleet, &schedule, seed);
    }
}

/// Multi-dropout rounds (up to ⌈n/4⌉ per round) recover just as exactly —
/// including rounds with zero dropouts mixed into the same window.
#[test]
fn dropout_w4_multi_dropout_rounds_recover_exactly() {
    let n = 9;
    let fleet = Fleet::new(n, 4, 0x5EED);
    let mut schedule = dropout_schedule(n, 3, n.div_ceil(4), 0x77);
    schedule.push(Vec::new()); // a clean round inside a lossy window
    assert_window_closes_exactly(
        &AggregateGaussian::new(0.5, 8.0),
        &SecAgg::new(),
        &fleet,
        &schedule,
        0xFEED,
    );
}

/// Satellite edge case: W=1 recovery IS the single-round path — the
/// windowed helper and a hand-driven one-round session with
/// `close_with_dropouts` produce the identical estimate.
#[test]
fn dropout_w1_recovery_matches_single_round_path() {
    let n = 5;
    let d = 3;
    let fleet = Fleet::new(n, d, 0x1CE);
    let xs = fleet.round_data(0);
    let mech = IrwinHallMechanism::new(0.4, 8.0);
    let session_seed = 0xABCD;
    let dropped = vec![2usize];

    // windowed path, W=1: the round seed is derived inside the helper the
    // same way assert_window_closes_exactly derives it — use a plain pair
    let round_seed = 0x600D;
    let windowed = run_window_with_dropouts(
        &mech,
        &SecAgg::new(),
        &mech,
        &[(xs.as_slice(), round_seed)],
        session_seed,
        &[dropped.clone()],
    );

    // hand-driven single-round session
    let survivors = SurvivorSet::with_dropped(n, &dropped);
    let mut session =
        TransportSession::open(&SecAgg::new(), session_seed, n, d, &[round_seed]);
    let round = *session.round(0);
    for i in survivors.alive_iter() {
        session.submit(0, i, &mech.encode(i, &xs[i], &round));
    }
    let announced = [RoundDropouts::announce(session_seed, 0, &survivors)];
    let closed = session.close_with_dropouts(&announced);
    let (payload, bits, surv) = &closed[0];
    let estimate = mech.decode_survivors(payload, &round, surv);
    assert_eq!(windowed.len(), 1);
    assert_eq!(windowed[0].estimate, estimate);
    assert_eq!(windowed[0].bits.messages, bits.messages);
    assert_eq!(surv.n_alive(), n - 1);
}

/// The CI dropout suite: a fixed seed matrix — 3 seeds × {0, 1, ⌈n/4⌉}
/// announced dropouts per round — every cell must close exactly.
/// (`scripts/ci.sh` runs this by name; keep `dropout` in the test names.)
#[test]
fn dropout_seed_matrix_windows_close_exactly() {
    let n = 9;
    for seed in [11u64, 22, 33] {
        for drops in [0usize, 1, n.div_ceil(4)] {
            let fleet = Fleet::new(n, 6, seed);
            let schedule = dropout_schedule(n, 4, drops, seed ^ 0xDD);
            assert_window_closes_exactly(
                &AggregateGaussian::new(0.5, 8.0),
                &SecAgg::new(),
                &fleet,
                &schedule,
                seed,
            );
            assert_window_closes_exactly(
                &IrwinHallMechanism::new(0.4, 8.0),
                &SecAgg::new(),
                &fleet,
                &schedule,
                seed ^ 1,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// seed-derived client sampling: cohort sessions ≡ Plain over the cohort,
// exact error laws at cohort size, amplified accounting
// ---------------------------------------------------------------------------

/// The CI sampling suite: a fixed seed matrix — 3 seeds × γ ∈ {0.25, 0.5,
/// 1.0} Poisson sampling — every cell's W=4 sampled SecAgg window must be
/// bit-identical to Plain over the same cohorts.
/// (`scripts/ci.sh` runs this by name; keep `sampling` in the test names.)
#[test]
fn sampling_seed_matrix_windows_close_exactly() {
    let n = 9;
    for seed in [11u64, 22, 33] {
        for gamma in [0.25f64, 0.5, 1.0] {
            let fleet = Fleet::new(n, 5, seed);
            let policy = SamplingPolicy::Poisson { gamma };
            let none: Vec<Vec<usize>> = vec![Vec::new(); 4];
            assert_sampled_window_closes_exactly(
                &AggregateGaussian::new(0.5, 8.0),
                &SecAgg::new(),
                &fleet,
                &policy,
                &none,
                seed,
            );
            assert_sampled_window_closes_exactly(
                &IrwinHallMechanism::new(0.4, 8.0),
                &SecAgg::new(),
                &fleet,
                &policy,
                &none,
                seed ^ 1,
            );
        }
    }
}

/// Sampling composes with the PR 3 dropout path: a Poisson-sampled window
/// where a cohort member additionally drops mid-round still closes — the
/// sampled-out clients need no recovery, the dropped member is recovered
/// over the final survivors, and the result equals Plain over (cohort
/// minus dropped), bit for bit.
#[test]
fn sampling_composes_with_midround_dropouts_bit_identically() {
    let n = 10;
    let fleet = Fleet::new(n, 4, 0x5A);
    let policy = SamplingPolicy::Poisson { gamma: 0.6 };
    let session_seed = 0xC0;
    // drop the first cohort member of every round that has at least two
    // (derived from the policy, so the schedule is valid by construction)
    let dropouts: Vec<Vec<usize>> = (0..4u64)
        .map(|r| {
            let cohort = policy.cohort(session_seed, r, n);
            if cohort.n_alive() >= 2 {
                vec![cohort.alive_iter().next().unwrap()]
            } else {
                Vec::new()
            }
        })
        .collect();
    assert_sampled_window_closes_exactly(
        &AggregateGaussian::new(0.5, 8.0),
        &SecAgg::new(),
        &fleet,
        &policy,
        &dropouts,
        session_seed,
    );
}

/// The KS-exactness acceptance for sampling: with a fixed-size cohort of
/// k out of n, the aggregate Gaussian's error against the COHORT mean is
/// exactly N(0, (σ·n/k)²) — the survivor-aware decoder completes the
/// sampled-out clients' dither terms and rescales, exactly as for
/// dropouts, so the law holds at cohort size n′ = k.
#[test]
fn sampling_error_is_exactly_gaussian_at_cohort_scale() {
    use exact_comp::mechanisms::run_window_sampled;
    let sigma = 0.5;
    let (n, k, d) = (6usize, 4usize, 4usize);
    let fleet = Fleet::new(n, d, 0xF00D);
    let xs = fleet.round_data(0);
    let policy = SamplingPolicy::FixedSize { k };
    let mech = AggregateGaussian::new(sigma, 8.0);
    let mut errs = Vec::new();
    for r in 0..900u64 {
        let seed = 90_000 + r;
        let cohort = policy.cohort(seed, 0, n);
        let out = run_window_sampled(
            &mech,
            &SecAgg::new(),
            &mech,
            &[(xs.as_slice(), seed)],
            seed,
            std::slice::from_ref(&cohort),
            &[Vec::new()],
        );
        let cmean = fleet.survivor_mean(0, &cohort);
        for j in 0..d {
            errs.push(out[0].estimate[j] - cmean[j]);
        }
    }
    let rescaled_sd = sigma * n as f64 / k as f64; // σ·n/n′ = 0.75
    let g = Gaussian::new(0.0, rescaled_sd);
    let res = exact_comp::util::stats::ks_test(&errs, |e| g.cdf(e));
    assert!(res.p_value > 0.003, "sampling exactness violated: p={}", res.p_value);
    let v = exact_comp::util::stats::variance(&errs);
    assert!((v - rescaled_sd * rescaled_sd).abs() < 0.05, "var={v}");
}

/// Irwin–Hall companion: the same sampled decode keeps the exact n-term
/// IH law at scale σ·n/k against the cohort mean.
#[test]
fn sampling_error_is_exactly_irwin_hall_at_cohort_scale() {
    use exact_comp::dist::IrwinHall;
    use exact_comp::mechanisms::run_window_sampled;
    let sigma = 0.6;
    let (n, k, d) = (8usize, 5usize, 4usize);
    let fleet = Fleet::new(n, d, 0xABBA);
    let xs = fleet.round_data(0);
    let policy = SamplingPolicy::FixedSize { k };
    let mech = IrwinHallMechanism::new(sigma, 8.0);
    let mut errs = Vec::new();
    for r in 0..800u64 {
        let seed = 50_000 + r;
        let cohort = policy.cohort(seed, 0, n);
        let out = run_window_sampled(
            &mech,
            &SecAgg::new(),
            &mech,
            &[(xs.as_slice(), seed)],
            seed,
            std::slice::from_ref(&cohort),
            &[Vec::new()],
        );
        let cmean = fleet.survivor_mean(0, &cohort);
        for j in 0..d {
            errs.push(out[0].estimate[j] - cmean[j]);
        }
    }
    let scale = sigma * n as f64 / k as f64;
    let ih = IrwinHall::new(n as u64, 0.0, scale);
    let res = exact_comp::util::stats::ks_test(&errs, |e| ih.cdf(e));
    assert!(res.p_value > 0.003, "sampled IH exactness violated: p={}", res.p_value);
    let v = exact_comp::util::stats::variance(&errs);
    assert!((v - scale * scale).abs() < 0.1, "var={v}");
}

/// The ledger acceptance: amplified ε strictly below the unsampled ε for
/// every γ < 1, exact agreement with `amplify_by_subsampling` at W=1, and
/// additive composition across a window.
#[test]
fn sampling_privacy_ledger_reports_amplified_spend() {
    use exact_comp::dp::{amplify_by_subsampling, PrivacyLedger};
    let (base_eps, base_delta) = (1.2, 1e-5);
    for gamma in [0.25f64, 0.5, 0.9] {
        let mut ledger = PrivacyLedger::new(base_eps, base_delta);
        let s = ledger.record(0, gamma);
        let (want_eps, want_delta) = amplify_by_subsampling(base_eps, base_delta, gamma);
        assert_eq!(s.eps_round, want_eps, "gamma={gamma}: W=1 identity");
        assert_eq!(s.delta_round, want_delta);
        assert!(s.eps_round < base_eps, "gamma={gamma}: not amplified");
        for r in 1..5u64 {
            ledger.record(r, gamma);
        }
        let (total, _) = ledger.basic_eps_delta();
        assert!((total - 5.0 * want_eps).abs() < 1e-9, "gamma={gamma}: composition");
        assert!(total < 5.0 * base_eps);
    }
    // γ = 1 spends exactly the base guarantee
    let mut unsampled = PrivacyLedger::new(base_eps, base_delta);
    let s = unsampled.record(0, 1.0);
    assert!((s.eps_round - base_eps).abs() < 1e-12);
}

/// The KS-exactness satellite: the aggregate Gaussian's survivor-only
/// error under announced dropouts is STILL exactly Gaussian — the decoder
/// completes the missing dither-noise terms and rescales, so the target
/// is N(0, (σ·n/n′)²). An Irwin–Hall companion lives in
/// `rust/src/mechanisms/irwin_hall.rs`
/// (`dropout_survivor_noise_is_exactly_irwin_hall_at_rescaled_scale`).
#[test]
fn dropout_survivor_error_is_exactly_gaussian_at_rescaled_variance() {
    let sigma = 0.5;
    let n = 6;
    let d = 4;
    let fleet = Fleet::new(n, d, 0xF00D);
    let xs = fleet.round_data(0);
    let dropped = vec![3usize];
    let survivors = SurvivorSet::with_dropped(n, &dropped);
    let smean = fleet.survivor_mean(0, &survivors);
    let mech = AggregateGaussian::new(sigma, 8.0);
    let mut errs = Vec::new();
    for r in 0..900u64 {
        let seed = 90_000 + r;
        let out = run_window_with_dropouts(
            &mech,
            &SecAgg::new(),
            &mech,
            &[(xs.as_slice(), seed)],
            seed,
            &[dropped.clone()],
        );
        for j in 0..d {
            errs.push(out[0].estimate[j] - smean[j]);
        }
    }
    let rescaled_sd = sigma * n as f64 / survivors.n_alive() as f64; // σ·n/n′ = 0.6
    let g = Gaussian::new(0.0, rescaled_sd);
    let res = exact_comp::util::stats::ks_test(&errs, |e| g.cdf(e));
    assert!(res.p_value > 0.003, "dropout exactness violated: p={}", res.p_value);
    let v = exact_comp::util::stats::variance(&errs);
    assert!((v - rescaled_sd * rescaled_sd).abs() < 0.03, "var={v}");
}
