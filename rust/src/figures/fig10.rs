//! Figure 10: Langevin sampling MSE for LSD (no compression), QLSD* with
//! unbiased b-bit quantization, and QLSD*-MS with the shifted layered
//! quantizer, b ∈ {4, 8, 16}.
//!
//! Setup (App. C.2.2): n = 20 clients, d = 50, N_i = 50 observations
//! y_ij ~ N(μ_i, I), μ_i ~ N(0, 25·I), γ = 5e−4, full participation, full
//! batch. We run scaled-down chains (DESIGN.md "Substitutions"): the
//! paper's 4.5e5-step burn-in becomes a configurable default of 3e4.

use super::FigOpts;
use crate::apps::langevin::{fig10_arm, Fig10Arm, GaussianPosterior, LangevinOpts};
use crate::util::json::Csv;
use crate::util::rng::{seed_domain, Rng};
use crate::util::stats::OnlineStats;

pub fn run(opts: &FigOpts) {
    println!("\n== Figure 10: Langevin MSE (LSD / QLSD* / QLSD*-MS) ==");
    let runs = opts.runs_or(10);
    let (iters, burn) = if opts.quick { (8_000, 4_000) } else { (40_000, 20_000) };
    let bits: Vec<u32> = vec![4, 8, 16];
    let mut arms: Vec<(String, Fig10Arm)> = vec![("LSD".into(), Fig10Arm::Lsd)];
    for &b in &bits {
        arms.push((format!("QLSD*-b{b}"), Fig10Arm::QlsdUnbiased(b)));
        arms.push((format!("QLSD*-MS-b{b}"), Fig10Arm::QlsdMs(b)));
    }
    let mut csv = Csv::new(&["arm", "bits", "mse_mean", "mse_sem", "bits_per_client", "chain_var"]);
    println!(
        "{:>14} {:>12} {:>12} {:>14} {:>12}",
        "arm", "mse", "sem", "bits/client", "chain-var"
    );
    for (name, arm) in &arms {
        let mut mse = OnlineStats::new();
        let mut bpc = OnlineStats::new();
        let mut cvar = OnlineStats::new();
        for r in 0..runs {
            // repeat r's data and chain roots: REPLICATE-domain derivations
            // at distinct indices (never ad-hoc seed arithmetic)
            let data_seed = Rng::derive_domain(opts.seed, seed_domain::REPLICATE, 2 * r as u64);
            let chain_seed =
                Rng::derive_domain(opts.seed, seed_domain::REPLICATE, 2 * r as u64 + 1);
            let problem = GaussianPosterior::generate(20, 50, 50, data_seed);
            let o = LangevinOpts {
                gamma: 5e-4,
                iters,
                burn_in: burn,
                seed: chain_seed,
                discount_compression_noise: true,
            };
            let res = fig10_arm(&problem, *arm, o);
            mse.push(res.mse);
            bpc.push(res.bits_per_client);
            cvar.push(res.chain_var);
        }
        let b = match arm {
            Fig10Arm::Lsd => 0,
            Fig10Arm::QlsdUnbiased(b) | Fig10Arm::QlsdMs(b) => *b,
        };
        println!(
            "{:>14} {:>12.4e} {:>12.2e} {:>14.0} {:>12.4e}",
            name,
            mse.mean(),
            mse.sem(),
            bpc.mean(),
            cvar.mean()
        );
        csv.rows.push(vec![
            name.clone(),
            b.to_string(),
            format!("{}", mse.mean()),
            format!("{}", mse.sem()),
            format!("{}", bpc.mean()),
            format!("{}", cvar.mean()),
        ]);
    }
    let path = format!("{}/fig10.csv", opts.out_dir);
    csv.save(&path).expect("saving csv");
    println!("saved {path}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_arms_produce_finite_mse() {
        let problem = GaussianPosterior::generate(6, 10, 20, 55);
        let o = LangevinOpts {
            gamma: 5e-4,
            iters: 3000,
            burn_in: 1500,
            seed: 3,
            discount_compression_noise: true,
        };
        for arm in [Fig10Arm::Lsd, Fig10Arm::QlsdUnbiased(4), Fig10Arm::QlsdMs(4)] {
            let res = fig10_arm(&problem, arm, o);
            assert!(res.mse.is_finite() && res.mse >= 0.0);
            assert!(res.chain_var > 0.0);
        }
    }
}
