//! `repro` — the exact-comp launcher.
//!
//! Subcommands:
//!   figures   regenerate the paper's tables/figures
//!               --fig 2|4|5|6|7|8|9|10|D --table 1 --all
//!               --out-dir DIR --runs N --quick --seed S
//!   train     end-to-end FL training through the PJRT runtime
//!               --rounds N --clients N --lr F --sigma F
//!               --mech aggregate|irwin-hall|individual|none
//!               --artifacts DIR --out FILE.csv
//!   langevin  QLSD* sampling demo (Fig. 10 single arm)
//!               --arm lsd|qlsd|qlsd-ms --bits B --iters N
//!   info      print runtime/platform diagnostics

use anyhow::{bail, Result};
use exact_comp::apps::fl_train::{self, MechKind, TrainOpts};
use exact_comp::apps::langevin::{fig10_arm, Fig10Arm, GaussianPosterior, LangevinOpts};
use exact_comp::cli::Args;
use exact_comp::figures::{self, FigOpts};
use exact_comp::runtime::Engine;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("figures") => cmd_figures(&args),
        Some("train") => cmd_train(&args),
        Some("langevin") => cmd_langevin(&args),
        Some("info") => cmd_info(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand: {o}\n");
            }
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "usage: repro <subcommand> [flags]\n\
         \n\
         subcommands:\n\
         \x20 figures   --fig 2|4|5|6|7|8|9|10|D | --table 1 | --all   [--out-dir DIR] [--runs N] [--quick] [--seed S]\n\
         \x20 train     [--rounds N] [--clients N] [--lr F] [--sigma F] [--mech aggregate|irwin-hall|individual|none]\n\
         \x20           [--artifacts DIR] [--out FILE.csv]\n\
         \x20 langevin  [--arm lsd|qlsd|qlsd-ms] [--bits B] [--iters N] [--seed S]\n\
         \x20 info      [--artifacts DIR]"
    );
}

fn fig_opts(args: &Args) -> FigOpts {
    FigOpts {
        out_dir: args.str_or("out-dir", "results"),
        runs: args.usize_or("runs", 0),
        quick: args.has("quick"),
        seed: args.u64_or("seed", 2024),
    }
}

fn cmd_figures(args: &Args) -> Result<()> {
    let opts = fig_opts(args);
    if args.has("all") {
        figures::run_all(&opts);
        return Ok(());
    }
    if let Some(t) = args.get("table") {
        if !figures::run_named(&format!("table{t}"), &opts) {
            bail!("unknown table {t}");
        }
        return Ok(());
    }
    match args.get("fig") {
        Some(f) => {
            if !figures::run_named(f, &opts) {
                bail!("unknown figure {f}");
            }
            Ok(())
        }
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let engine = Engine::load(&dir)?;
    println!(
        "engine up: platform={}, params={}, batch={}",
        engine.platform(),
        engine.manifest.param_count,
        engine.manifest.batch
    );
    let mech = match args.str_or("mech", "aggregate").as_str() {
        "aggregate" => MechKind::Aggregate,
        "irwin-hall" => MechKind::IrwinHall,
        "individual" => MechKind::IndividualShifted,
        "none" => MechKind::None,
        other => bail!("unknown mechanism {other}"),
    };
    let opts = TrainOpts {
        rounds: args.usize_or("rounds", 300),
        lr: args.f64_or("lr", 0.5),
        n_clients: args.usize_or("clients", 8),
        clip_c: args.f64_or("clip", 0.05),
        mech,
        sigma: args.f64_or("sigma", 1e-3),
        eval_every: args.usize_or("eval-every", 20),
        seed: args.u64_or("seed", 0xF1),
        chunk: args.usize_or("chunk", 0),
    };
    let data = fl_train::gen_dataset(&engine, opts.n_clients, opts.seed);
    println!("training: {opts:?}");
    let metrics = fl_train::train(&engine, &data, opts)?;
    println!(
        "final: train_loss={:.4} eval_loss={:.4} eval_acc={:.4} bits/client/round={:.0} ({:.1}s)",
        metrics.last("train_loss").unwrap_or(f64::NAN),
        metrics.last("loss").unwrap_or(f64::NAN),
        metrics.last("acc").unwrap_or(f64::NAN),
        metrics.mean_of("bits_per_client").unwrap_or(f64::NAN),
        metrics.elapsed_secs(),
    );
    let out = args.str_or("out", "results/fl_train.csv");
    metrics.save_csv(&out)?;
    println!("saved {out}");
    Ok(())
}

fn cmd_langevin(args: &Args) -> Result<()> {
    let bits = args.usize_or("bits", 8) as u32;
    let arm = match args.str_or("arm", "qlsd-ms").as_str() {
        "lsd" => Fig10Arm::Lsd,
        "qlsd" => Fig10Arm::QlsdUnbiased(bits),
        "qlsd-ms" => Fig10Arm::QlsdMs(bits),
        other => bail!("unknown arm {other}"),
    };
    let seed = args.u64_or("seed", 7);
    let iters = args.usize_or("iters", 40_000);
    let problem = GaussianPosterior::generate(20, 50, 50, seed);
    let o = LangevinOpts {
        gamma: args.f64_or("gamma", 5e-4),
        iters,
        burn_in: iters / 2,
        seed,
        discount_compression_noise: true,
    };
    println!("running {arm:?} for {iters} iterations ...");
    let res = fig10_arm(&problem, arm, o);
    println!(
        "mse={:.5e} chain_var={:.5e} bits/client={:.0}",
        res.mse, res.chain_var, res.bits_per_client
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("exact-comp repro binary");
    let dir = args.str_or("artifacts", "artifacts");
    match Engine::load(&dir) {
        Ok(e) => {
            println!("artifacts: {dir} (ok)");
            println!("platform:  {}", e.platform());
            println!("manifest:  {:?}", e.manifest);
        }
        Err(err) => println!("artifacts: unavailable ({err:#})"),
    }
    Ok(())
}
