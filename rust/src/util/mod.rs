//! Foundation utilities: PRNGs, special functions, statistics, numeric
//! helpers, micro-benchmark harness, JSON/CSV writers.

pub mod rng;
pub mod special;
pub mod stats;
pub mod interp;
pub mod benchkit;
pub mod json;

pub use rng::Rng;

/// The loud-fail parse contract shared by every typed config/flag getter
/// (`coordinator::config::Config`, `cli::Args`): a missing value takes
/// the default, a present-but-malformed value panics naming the source
/// (`what`, e.g. `config key sigma` or `flag --sigma`) and the expected
/// type — a typo'd value must never silently fall back to a default.
pub fn parse_or_panic<T: std::str::FromStr>(
    val: Option<&str>,
    default: T,
    what: &str,
    expected: &str,
) -> T {
    match val {
        None => default,
        Some(v) => v.parse().unwrap_or_else(|_| {
            panic!("{what} has malformed value {v:?} (expected {expected})")
        }),
    }
}
