//! Figure-harness integration: every table/figure regenerates in --quick
//! mode and emits a non-empty CSV — the "can we reproduce the paper"
//! smoke test.

use exact_comp::figures::{self, FigOpts};

fn opts(dir: &str) -> FigOpts {
    FigOpts { out_dir: dir.to_string(), runs: 2, quick: true, seed: 77 }
}

fn csv_rows(path: &str) -> usize {
    let text = std::fs::read_to_string(path).unwrap_or_else(|_| panic!("missing {path}"));
    text.lines().count().saturating_sub(1)
}

#[test]
fn fig2_quick() {
    let dir = "target/test-results/fig2";
    figures::run_named("2", &opts(dir));
    assert!(csv_rows(&format!("{dir}/fig2.csv")) >= 6);
}

#[test]
fn fig4_quick() {
    let dir = "target/test-results/fig4";
    figures::run_named("4", &opts(dir));
    assert!(csv_rows(&format!("{dir}/fig4a.csv")) >= 3);
    assert!(csv_rows(&format!("{dir}/fig4b.csv")) >= 3);
}

#[test]
fn fig5_and_7_quick() {
    let dir = "target/test-results/fig5";
    figures::run_named("5", &opts(dir));
    assert!(csv_rows(&format!("{dir}/fig5.csv")) >= 4);
    figures::run_named("7", &opts(dir));
    assert!(csv_rows(&format!("{dir}/fig7.csv")) >= 3);
}

#[test]
fn fig6_and_8_quick() {
    let dir = "target/test-results/fig6";
    figures::run_named("6", &opts(dir));
    assert!(csv_rows(&format!("{dir}/fig6.csv")) >= 3);
}

#[test]
fn fig9_quick() {
    let dir = "target/test-results/fig9";
    figures::run_named("9", &opts(dir));
    assert!(csv_rows(&format!("{dir}/fig9.csv")) >= 4);
}

#[test]
fn fig10_quick() {
    let dir = "target/test-results/fig10";
    figures::run_named("10", &opts(dir));
    // 1 LSD + 3 bits × 2 arms
    assert!(csv_rows(&format!("{dir}/fig10.csv")) == 7);
}

#[test]
fn table1_quick() {
    let dir = "target/test-results/table1";
    figures::run_named("table1", &opts(dir));
    assert_eq!(csv_rows(&format!("{dir}/table1.csv")), 5);
    // spot-check the paper's matrix in the emitted CSV
    let text = std::fs::read_to_string(format!("{dir}/table1.csv")).unwrap();
    let agg_row: Vec<&str> = text
        .lines()
        .find(|l| l.starts_with("Aggregate Gaussian"))
        .unwrap()
        .split(',')
        .collect();
    assert_eq!(&agg_row[1..], &["yes", "yes", "yes", "no"]);
    let ih_row: Vec<&str> =
        text.lines().find(|l| l.starts_with("Irwin-Hall")).unwrap().split(',').collect();
    assert_eq!(&ih_row[1..], &["yes", "no", "no", "yes"]);
}

#[test]
fn appd_quick() {
    let dir = "target/test-results/appd";
    figures::run_named("D", &opts(dir));
    assert!(csv_rows(&format!("{dir}/appd.csv")) >= 10);
}

#[test]
fn unknown_figure_rejected() {
    assert!(!figures::run_named("42", &opts("target/test-results/none")));
}
