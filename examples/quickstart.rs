//! Quickstart: the library in 60 seconds.
//!
//! 1. Point-to-point AINQ: quantize a scalar so the error is EXACTLY
//!    N(0, 1) — and verify it with a KS test.
//! 2. n-client aggregation: the homomorphic aggregate Gaussian mechanism,
//!    with bit accounting.
//! 3. Batched multi-round SecAgg: one masking session for a window of
//!    rounds, bit-identical to independent plain rounds.
//!
//! Run: `cargo run --release --example quickstart`

use exact_comp::dist::{Continuous, Gaussian};
use exact_comp::mechanisms::traits::{true_mean, MeanMechanism};
use exact_comp::mechanisms::{AggregateGaussian, Pipeline};
use exact_comp::quantizer::{PointQuantizer, ShiftedLayered};
use exact_comp::util::rng::Rng;
use exact_comp::util::stats::ks_test;

fn main() {
    // --- 1. point-to-point: error exactly N(0, 1) -------------------------
    let target = Gaussian::standard();
    let q = ShiftedLayered::new(target);
    let mut rng = Rng::new(42);
    let x = 13.37;
    let (m, y, s) = q.quantize(x, &mut rng);
    println!("quantize({x}) -> description {m} (step {:.3}), decoded {y:.3}", s.step);
    println!("minimal step eta = {:.3} => fixed-length codable", q.min_step().unwrap());

    let errs: Vec<f64> = (0..20_000).map(|_| q.quantize(x, &mut rng).1 - x).collect();
    let ks = ks_test(&errs, |e| target.cdf(e));
    println!(
        "20k quantizations: error mean {:.4}, var {:.4}, KS p-value {:.3} (exactly Gaussian)",
        exact_comp::util::stats::mean(&errs),
        exact_comp::util::stats::variance(&errs),
        ks.p_value
    );

    // --- 2. n-client aggregate Gaussian mechanism -------------------------
    let n = 64;
    let d = 32;
    let sigma = 0.1;
    let mut drng = Rng::new(7);
    let xs: Vec<Vec<f64>> =
        (0..n).map(|_| (0..d).map(|_| drng.uniform(-2.0, 2.0)).collect()).collect();
    let mech = AggregateGaussian::new(sigma, 4.0);
    let out = mech.aggregate(&xs, 0xFEED);
    let mean = true_mean(&xs);
    let mse = exact_comp::util::stats::mse(&out.estimate, &mean);
    println!(
        "\naggregate Gaussian over n={n}, d={d}: MSE {:.5} (noise floor sigma^2 = {:.5})",
        mse,
        sigma * sigma
    );
    println!(
        "bits/client (Elias gamma): {:.1} for {d} coordinates = {:.2} bits/coordinate",
        out.bits.variable_per_client(n),
        out.bits.variable_per_client(n) / d as f64
    );
    println!(
        "homomorphic: {} — decodable from SecAgg sums alone",
        mech.is_homomorphic()
    );

    // --- 3. batched multi-round SecAgg session ----------------------------
    // one masking session covers a window of W rounds: per-round mask
    // roots derive from a single session seed, the unmask is batched, and
    // every round still decodes exactly what plain summation would.
    let window = 4;
    let rounds: Vec<(&[Vec<f64>], u64)> =
        (0..window).map(|r| (xs.as_slice(), 0xFEED + r as u64)).collect();
    let secagg = Pipeline::secagg(AggregateGaussian::new(sigma, 4.0));
    let plain = Pipeline::plain(AggregateGaussian::new(sigma, 4.0));
    let windowed = secagg.aggregate_window(&rounds, 0x5E55);
    let identical = rounds
        .iter()
        .zip(&windowed)
        .all(|(&(data, seed), w)| w.estimate == plain.aggregate(data, seed).estimate);
    println!(
        "\nW={window} SecAgg session: 1 masking session, {window} rounds, batched unmask — \
         bit-identical to independent plain rounds: {identical}"
    );
}
