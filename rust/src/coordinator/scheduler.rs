//! A std-only M:N work-stealing task pool — the execution substrate of the
//! event-driven async coordinator ([`super::runtime::run_rounds_encoded_async`]).
//!
//! Shape: one global **injector** queue (the orchestrator feeds encode
//! tasks into it as the accumulator ring admits chunk waves) plus one
//! **local deque per worker**. A worker pops its own deque from the
//! front; when empty it batch-grabs a slice of the injector; when the
//! injector is dry it steals half of the richest sibling's deque from the
//! back. Idle workers park on a condvar and are woken by injection,
//! close, or poisoning — there is no spin loop and no global barrier
//! anywhere.
//!
//! Honest scope note: the classic work-stealing runtime uses lock-free
//! Chase–Lev deques; the offline registry has no `crossbeam`, so every
//! queue here lives behind ONE mutex. That is entirely adequate for this
//! coordinator's granularity (a task encodes a whole client-block ×
//! chunk, i.e. milliseconds of work against nanoseconds of queue traffic)
//! and it keeps the scheduler dependency-free. The determinism story does
//! not care either way: which worker runs which task, and in which order,
//! is explicitly allowed to vary — see `docs/determinism.md`, "Work
//! stealing cannot change any drawn bit".
//!
//! Failure model (fail closed, never hang): a panicking task is caught,
//! recorded as a [`WorkerFailure`] naming the worker and carrying the
//! original panic message, and **poisons** the pool — every worker exits
//! at its next dequeue instead of draining a doomed run. The worker
//! threads dropping their shared run-closure is what disconnects any
//! channels the closure held, so an orchestrator blocked on `recv()`
//! observes the failure promptly and can name its cause from
//! [`WorkStealPool::failures`] instead of dying on a bare "disconnected".

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Cap on how many tasks one injector batch-grab moves into a local
/// deque: enough to amortize the lock, small enough that siblings still
/// find injector work without stealing.
const INJECTOR_BATCH: usize = 32;

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads cover every `panic!`/`assert!` in this crate).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One recorded task panic: which worker it died on and the original
/// panic message — what the orchestrator surfaces instead of a bare
/// channel-disconnect panic.
#[derive(Clone, Debug)]
pub struct WorkerFailure {
    pub worker: usize,
    pub message: String,
}

struct Queues<T> {
    injector: VecDeque<T>,
    locals: Vec<VecDeque<T>>,
    /// more tasks may still be injected; workers park instead of exiting
    open: bool,
    /// a task panicked: abandon all queued work, every worker exits
    poisoned: bool,
    failures: Vec<WorkerFailure>,
}

struct Shared<T> {
    queues: Mutex<Queues<T>>,
    ready: Condvar,
}

/// The work-stealing pool. `T` is the task type; the run closure given to
/// [`WorkStealPool::spawn`] executes each task on whichever worker
/// dequeued or stole it.
pub struct WorkStealPool<T: Send + 'static> {
    shared: Arc<Shared<T>>,
    workers: Vec<JoinHandle<()>>,
}

impl<T: Send + 'static> WorkStealPool<T> {
    /// Spawn `workers` worker threads running `run(worker_id, task)` over
    /// everything later passed to [`WorkStealPool::inject`].
    pub fn spawn<F>(workers: usize, run: F) -> Self
    where
        F: Fn(usize, T) + Send + Sync + 'static,
    {
        assert!(workers > 0, "a work-stealing pool needs at least one worker");
        let shared = Arc::new(Shared {
            queues: Mutex::new(Queues {
                injector: VecDeque::new(),
                locals: (0..workers).map(|_| VecDeque::new()).collect(),
                open: true,
                poisoned: false,
                failures: Vec::new(),
            }),
            ready: Condvar::new(),
        });
        let run = Arc::new(run);
        let handles = (0..workers)
            .map(|me| {
                let shared = shared.clone();
                let run = run.clone();
                std::thread::Builder::new()
                    .name(format!("ws-worker-{me}"))
                    .spawn(move || Self::worker_loop(me, shared, run))
                    .expect("spawning work-stealing worker thread")
            })
            .collect();
        Self { shared, workers: handles }
    }

    fn worker_loop<F>(me: usize, shared: Arc<Shared<T>>, run: Arc<F>)
    where
        F: Fn(usize, T) + Send + Sync + 'static,
    {
        loop {
            let task = {
                let mut q = shared.queues.lock().unwrap();
                loop {
                    if q.poisoned {
                        break None;
                    }
                    if let Some(t) = q.locals[me].pop_front() {
                        break Some(t);
                    }
                    // refill from the global injector: grab a fair share
                    // (capped) so one worker cannot hoard the queue
                    if !q.injector.is_empty() {
                        let grab = q
                            .injector
                            .len()
                            .div_ceil(q.locals.len())
                            .clamp(1, INJECTOR_BATCH);
                        for _ in 0..grab {
                            if let Some(t) = q.injector.pop_front() {
                                q.locals[me].push_back(t);
                            }
                        }
                        continue;
                    }
                    // steal: take half of the richest sibling's deque from
                    // the back (they keep working their front undisturbed)
                    let victim = (0..q.locals.len())
                        .filter(|&v| v != me && !q.locals[v].is_empty())
                        .max_by_key(|&v| q.locals[v].len());
                    if let Some(v) = victim {
                        let take = q.locals[v].len().div_ceil(2);
                        for _ in 0..take {
                            let t = q.locals[v].pop_back().unwrap();
                            // push_front preserves the stolen tasks'
                            // relative order for the thief
                            q.locals[me].push_front(t);
                        }
                        continue;
                    }
                    if !q.open {
                        break None;
                    }
                    q = shared.ready.wait(q).unwrap();
                }
            };
            let Some(task) = task else { return };
            let outcome =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run(me, task)));
            if let Err(p) = outcome {
                let mut q = shared.queues.lock().unwrap();
                q.failures
                    .push(WorkerFailure { worker: me, message: panic_message(p.as_ref()) });
                // fail closed: abandon queued work so siblings exit
                // instead of completing a run whose result is already lost
                q.poisoned = true;
                drop(q);
                shared.ready.notify_all();
                return;
            }
        }
    }

    /// Feed tasks into the global injector (wakes parked workers). The
    /// orchestrator calls this both at spawn (the initial chunk waves) and
    /// from its event loop as the accumulator ring admits further waves.
    ///
    /// Panics if the pool was already closed — injecting after close is an
    /// orchestrator bug and fails closed rather than silently dropping
    /// work.
    pub fn inject<I: IntoIterator<Item = T>>(&self, tasks: I) {
        let mut q = self.shared.queues.lock().unwrap();
        assert!(q.open, "fail closed: task injected into a closed work-stealing pool");
        if q.poisoned {
            // a failure is already pending; dropping the new tasks is
            // fine — the orchestrator will observe the failure and panic
            return;
        }
        q.injector.extend(tasks);
        drop(q);
        self.shared.ready.notify_all();
    }

    /// Snapshot of every recorded task panic so far (worker id + message).
    pub fn failures(&self) -> Vec<WorkerFailure> {
        self.shared.queues.lock().unwrap().failures.clone()
    }

    /// Close the injector, let the workers drain every queued task, join
    /// them, and return the recorded failures (empty on a clean run).
    pub fn join(mut self) -> Vec<WorkerFailure> {
        {
            let mut q = self.shared.queues.lock().unwrap();
            q.open = false;
        }
        self.shared.ready.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.shared.queues.lock().unwrap().failures.clone()
    }
}

impl<T: Send + 'static> Drop for WorkStealPool<T> {
    /// Dropping without [`WorkStealPool::join`] (the orchestrator
    /// panicked mid-run) abandons queued tasks and joins the workers —
    /// nothing hangs, nothing leaks a thread.
    fn drop(&mut self) {
        {
            let mut q = self.shared.queues.lock().unwrap();
            q.open = false;
            q.poisoned = true;
        }
        self.shared.ready.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn async_pool_runs_every_task_exactly_once() {
        for workers in [1usize, 2, 7] {
            let sum = Arc::new(AtomicU64::new(0));
            let count = Arc::new(AtomicUsize::new(0));
            let pool = {
                let sum = sum.clone();
                let count = count.clone();
                WorkStealPool::spawn(workers, move |_w, t: u64| {
                    sum.fetch_add(t, Ordering::SeqCst);
                    count.fetch_add(1, Ordering::SeqCst);
                })
            };
            pool.inject(1..=100u64);
            let failures = pool.join();
            assert!(failures.is_empty());
            assert_eq!(count.load(Ordering::SeqCst), 100, "{workers} workers");
            assert_eq!(sum.load(Ordering::SeqCst), 5050, "{workers} workers");
        }
    }

    #[test]
    fn async_pool_accepts_injection_while_running() {
        let count = Arc::new(AtomicUsize::new(0));
        let pool = {
            let count = count.clone();
            WorkStealPool::spawn(3, move |_w, _t: usize| {
                count.fetch_add(1, Ordering::SeqCst);
            })
        };
        for wave in 0..10 {
            pool.inject((0..8).map(|i| wave * 8 + i));
        }
        assert!(pool.join().is_empty());
        assert_eq!(count.load(Ordering::SeqCst), 80);
    }

    #[test]
    fn async_pool_records_panic_with_worker_and_message() {
        let pool = WorkStealPool::spawn(2, |_w, t: usize| {
            if t == 3 {
                panic!("task {t} exploded");
            }
        });
        pool.inject(0..6);
        let failures = pool.join();
        assert_eq!(failures.len(), 1, "exactly one recorded failure");
        assert!(failures[0].worker < 2);
        assert_eq!(failures[0].message, "task 3 exploded");
    }

    #[test]
    fn async_pool_poisons_siblings_after_a_panic() {
        // after the poisoned run, queued tasks are abandoned — the run
        // count stays well below the injected total
        let count = Arc::new(AtomicUsize::new(0));
        let pool = {
            let count = count.clone();
            WorkStealPool::spawn(1, move |_w, t: usize| {
                if t == 0 {
                    panic!("first task dies");
                }
                count.fetch_add(1, Ordering::SeqCst);
            })
        };
        pool.inject(0..1000);
        let failures = pool.join();
        assert_eq!(failures.len(), 1);
        assert_eq!(
            count.load(Ordering::SeqCst),
            0,
            "a single poisoned worker must abandon all queued tasks"
        );
    }

    #[test]
    fn async_pool_drop_without_join_does_not_hang() {
        let pool = WorkStealPool::spawn(2, |_w, _t: usize| {});
        pool.inject(0..10);
        drop(pool);
    }
}
