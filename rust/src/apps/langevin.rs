//! QLSD* — quantized Langevin stochastic dynamics with variance-reduced
//! gradients and exact-error compression (App. C.2, Algorithm 6, Fig. 10).
//!
//! Bayesian FL setting of Vono et al. 2022: posterior
//! π(θ|D) ∝ Π_i exp(−U_i(θ)) with client potentials
//! U_i(θ) = Σ_j ‖θ − y_{ij}‖²/2. The chain
//!
//!   θ_{k+1} = θ_k − γ·g_{k+1} + β·Z_{k+1}
//!
//! uses compressed variance-reduced gradients g = Σ_i 𝒞(H_i(θ)),
//! H_i(θ) = ∇U_i(θ) − ∇U_i(θ*), and the QLSD*-with-exact-error adaptation:
//! the server *discounts* the known compression variance from the injected
//! noise, β² = max(0, 2γ − γ²·Σ_i v_i)  (their assumption H3 still holds).
//!
//! With quadratic potentials the posterior is Gaussian with known mean and
//! covariance, so sampler quality is the MSE between the empirical
//! post-burn-in mean and the exact posterior mean.

use std::sync::Arc;

use crate::apps::driver::{app_round_seed, AppCoordinator, CoordinatorOpts};
use crate::baselines::{CompressedVec, VectorCompressor};
use crate::mechanisms::pipeline::LocalCompute;
use crate::mechanisms::traits::MeanMechanism;
use crate::util::rng::{seed_domain, Rng};

/// The synthetic Gaussian FL problem of App. C.2.2.
#[derive(Clone, Debug)]
pub struct GaussianPosterior {
    pub n_clients: usize,
    pub dim: usize,
    /// observations per client N_i
    pub n_obs: usize,
    /// per-client Σ_j y_{ij}
    pub obs_sums: Vec<Vec<f64>>,
    /// exact posterior mean = Σ_ij y_ij / Σ_i N_i
    pub posterior_mean: Vec<f64>,
}

impl GaussianPosterior {
    /// y_{ij} ~ N(μ_i, I_d), μ_i ~ N(0, 25·I_d) — heterogeneous clients.
    pub fn generate(n_clients: usize, dim: usize, n_obs: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut obs_sums = Vec::with_capacity(n_clients);
        let mut total = vec![0.0; dim];
        for _ in 0..n_clients {
            let mu: Vec<f64> = (0..dim).map(|_| rng.normal_ms(0.0, 5.0)).collect();
            let mut s = vec![0.0; dim];
            for _ in 0..n_obs {
                for (sj, &mj) in s.iter_mut().zip(&mu) {
                    *sj += rng.normal_ms(mj, 1.0);
                }
            }
            for (tj, sj) in total.iter_mut().zip(&s) {
                *tj += sj;
            }
            obs_sums.push(s);
        }
        let n_total = (n_clients * n_obs) as f64;
        let posterior_mean = total.iter().map(|t| t / n_total).collect();
        Self { n_clients, dim, n_obs, obs_sums, posterior_mean }
    }

    /// ∇U_i(θ) = N_i·θ − Σ_j y_ij.
    pub fn grad_client(&self, i: usize, theta: &[f64]) -> Vec<f64> {
        theta
            .iter()
            .zip(&self.obs_sums[i])
            .map(|(&t, &s)| self.n_obs as f64 * t - s)
            .collect()
    }

    /// Variance-reduced H_i(θ) = ∇U_i(θ) − ∇U_i(θ*) = N_i (θ − θ*).
    pub fn h_client(&self, i: usize, theta: &[f64], theta_star: &[f64]) -> Vec<f64> {
        let _ = i;
        theta
            .iter()
            .zip(theta_star)
            .map(|(&t, &ts)| self.n_obs as f64 * (t - ts))
            .collect()
    }

    /// Posterior precision (scalar: isotropic) = Σ_i N_i.
    pub fn precision(&self) -> f64 {
        (self.n_clients * self.n_obs) as f64
    }
}

/// Options for a QLSD* run.
#[derive(Clone, Copy, Debug)]
pub struct LangevinOpts {
    pub gamma: f64,
    pub iters: usize,
    pub burn_in: usize,
    pub seed: u64,
    /// subtract the compression variance from the injected noise (the
    /// paper's QLSD* adaptation); false = always inject √(2γ) noise
    pub discount_compression_noise: bool,
}

/// Result of a QLSD* run.
#[derive(Clone, Debug)]
pub struct LangevinResult {
    /// MSE of the post-burn-in mean vs the exact posterior mean
    pub mse: f64,
    /// total bits sent per client over the run
    pub bits_per_client: f64,
    /// trace of MSE evaluated periodically (iteration, mse)
    pub trace: Vec<(usize, f64)>,
    /// post-burn-in per-coordinate chain variance, averaged over coords —
    /// the chain "temperature": extra (undiscountable) compression noise
    /// inflates it above the exact posterior variance
    pub chain_var: f64,
}

/// Run QLSD* with the given per-client compressor.
pub fn qlsd_star(
    problem: &GaussianPosterior,
    compressor: &dyn VectorCompressor,
    opts: LangevinOpts,
) -> LangevinResult {
    let d = problem.dim;
    let mut rng = Rng::new(opts.seed);
    // θ* = posterior mode = posterior mean (quadratic potential);
    // Σ_i ∇U_i(θ*) = 0 so no server-side correction term is needed.
    let theta_star = problem.posterior_mean.clone();
    let mut theta = vec![0.0f64; d];
    let mut mean_acc = vec![0.0f64; d];
    let mut sq_acc = vec![0.0f64; d];
    let mut count = 0usize;
    let mut bits_total = 0.0;
    let mut trace = Vec::new();

    for k in 0..opts.iters {
        // clients: compress variance-reduced gradients
        let mut g = vec![0.0f64; d];
        let mut var_sum = 0.0;
        for i in 0..problem.n_clients {
            let h = problem.h_client(i, &theta, &theta_star);
            let CompressedVec { y, err_variance, bits } = compressor.compress(&h, &mut rng);
            for (gj, yj) in g.iter_mut().zip(&y) {
                *gj += yj;
            }
            var_sum += err_variance;
            bits_total += bits;
        }
        // server: compensate for known compression noise
        let beta_sq = if opts.discount_compression_noise {
            (2.0 * opts.gamma - opts.gamma * opts.gamma * var_sum).max(0.0)
        } else {
            2.0 * opts.gamma
        };
        let beta = beta_sq.sqrt();
        for j in 0..d {
            theta[j] -= opts.gamma * g[j];
            theta[j] += beta * rng.normal();
        }
        if k >= opts.burn_in {
            for j in 0..d {
                mean_acc[j] += theta[j];
                sq_acc[j] += theta[j] * theta[j];
            }
            count += 1;
            if count % 1000 == 0 {
                let mse = mean_acc
                    .iter()
                    .zip(&problem.posterior_mean)
                    .map(|(a, p)| (a / count as f64 - p).powi(2))
                    .sum::<f64>()
                    / d as f64;
                trace.push((k, mse));
            }
        }
    }
    assert!(count > 0, "burn_in >= iters");
    let mse = mean_acc
        .iter()
        .zip(&problem.posterior_mean)
        .map(|(a, p)| (a / count as f64 - p).powi(2))
        .sum::<f64>()
        / d as f64;
    let chain_var = (0..d)
        .map(|j| {
            let m = mean_acc[j] / count as f64;
            sq_acc[j] / count as f64 - m * m
        })
        .sum::<f64>()
        / d as f64;
    LangevinResult {
        mse,
        bits_per_client: bits_total / problem.n_clients as f64,
        trace,
        chain_var,
    }
}

/// The three arms of Fig. 10, with the paper's discounting semantics:
/// QLSD*-MS discounts its (exactly Gaussian) compression error from the
/// injected noise; plain QLSD* cannot (its error is not Gaussian) and adds
/// the full √(2γ) noise on top.
pub fn fig10_arm(
    problem: &GaussianPosterior,
    arm: Fig10Arm,
    mut opts: LangevinOpts,
) -> LangevinResult {
    match arm {
        Fig10Arm::Lsd => {
            opts.discount_compression_noise = false;
            qlsd_star(problem, &crate::baselines::NoCompression, opts)
        }
        Fig10Arm::QlsdUnbiased(bits) => {
            opts.discount_compression_noise = false;
            qlsd_star(problem, &crate::baselines::UnbiasedQuantizer::new(bits), opts)
        }
        Fig10Arm::QlsdMs(bits) => {
            opts.discount_compression_noise = true;
            qlsd_star(problem, &crate::baselines::LayeredBitsCompressor::new(bits), opts)
        }
    }
}

/// Arm selector for the Fig. 10 comparison.
#[derive(Clone, Copy, Debug)]
pub enum Fig10Arm {
    /// no compression
    Lsd,
    /// classical unbiased b-bit quantization (noise NOT discountable)
    QlsdUnbiased(u32),
    /// shifted layered quantizer (exact Gaussian error, discounted)
    QlsdMs(u32),
}

// ---------------------------------------------------------------------------
// QLSD* on MeanMechanism aggregation — monolithic reference and the
// coordinator-streamed production path, bit-identical by construction.
// ---------------------------------------------------------------------------

/// Shared QLSD* state-update arithmetic for the [`MeanMechanism`]-based
/// paths: both [`qlsd_star_mech`] and [`qlsd_star_coordinator`] feed it
/// the aggregated mean of H_i(θ) per iteration, so any divergence between
/// them is an aggregation difference, never a chain-update difference.
struct ChainAccumulator {
    theta: Vec<f64>,
    mean_acc: Vec<f64>,
    sq_acc: Vec<f64>,
    count: usize,
    bits_total: f64,
    trace: Vec<(usize, f64)>,
}

impl ChainAccumulator {
    fn new(d: usize) -> Self {
        Self {
            theta: vec![0.0f64; d],
            mean_acc: vec![0.0f64; d],
            sq_acc: vec![0.0f64; d],
            count: 0,
            bits_total: 0.0,
            trace: Vec::new(),
        }
    }

    /// One chain step: θ ← θ − γ·n·est + β·Z_k, with Z_k drawn from the
    /// `APP_ROUND`-domain stream of iteration k (independent of the
    /// aggregation's `ROUND`-domain randomness, and identical across the
    /// monolithic and coordinator paths by derivation).
    fn step(
        &mut self,
        k: usize,
        est_mean: &[f64],
        n_clients: usize,
        opts: &LangevinOpts,
        beta: f64,
        posterior_mean: &[f64],
    ) {
        let d = self.theta.len();
        let mut zrng = Rng::new(Rng::derive_domain(opts.seed, seed_domain::APP_ROUND, k as u64));
        for j in 0..d {
            self.theta[j] -= opts.gamma * n_clients as f64 * est_mean[j];
            self.theta[j] += beta * zrng.normal();
        }
        if k >= opts.burn_in {
            for j in 0..d {
                self.mean_acc[j] += self.theta[j];
                self.sq_acc[j] += self.theta[j] * self.theta[j];
            }
            self.count += 1;
            if self.count % 1000 == 0 {
                self.trace.push((k, self.mse(posterior_mean)));
            }
        }
    }

    fn mse(&self, posterior_mean: &[f64]) -> f64 {
        let d = self.theta.len();
        self.mean_acc
            .iter()
            .zip(posterior_mean)
            .map(|(a, p)| (a / self.count as f64 - p).powi(2))
            .sum::<f64>()
            / d as f64
    }

    fn finish(self, n_clients: usize, posterior_mean: &[f64]) -> LangevinResult {
        assert!(self.count > 0, "burn_in >= iters");
        let d = self.theta.len();
        let mse = self.mse(posterior_mean);
        let chain_var = (0..d)
            .map(|j| {
                let m = self.mean_acc[j] / self.count as f64;
                self.sq_acc[j] / self.count as f64 - m * m
            })
            .sum::<f64>()
            / d as f64;
        LangevinResult {
            mse,
            bits_per_client: self.bits_total / n_clients as f64,
            trace: self.trace,
            chain_var,
        }
    }
}

/// β for one iteration: the QLSD* discount applied to a mechanism whose
/// aggregation error is exactly Gaussian. The mechanism's per-coordinate
/// noise sd σ is on the *mean* estimate; the summed gradient g = n·Y
/// carries variance n²σ², so β² = max(0, 2γ − γ²·n²·σ²). Mechanisms whose
/// error is not Gaussian (no H3 guarantee) get no discount.
fn beta_for_mech(mech: &dyn MeanMechanism, n_clients: usize, opts: &LangevinOpts) -> f64 {
    let beta_sq = if opts.discount_compression_noise && mech.gaussian_noise() {
        let sd_sum = n_clients as f64 * mech.noise_sd();
        (2.0 * opts.gamma - opts.gamma * opts.gamma * sd_sum * sd_sum).max(0.0)
    } else {
        2.0 * opts.gamma
    };
    beta_sq.sqrt()
}

/// QLSD* where the per-iteration aggregation Σ_i 𝒞(H_i(θ)) runs through a
/// [`MeanMechanism`] round (monolithic `aggregate()`, iteration k = round
/// k with shared seed `derive_domain(seed, ROUND, k)`). This is the
/// in-process reference for [`qlsd_star_coordinator`]; the property suite
/// pins the two bit-identical.
pub fn qlsd_star_mech(
    problem: &GaussianPosterior,
    mech: &dyn MeanMechanism,
    opts: LangevinOpts,
) -> LangevinResult {
    let d = problem.dim;
    let n = problem.n_clients;
    let theta_star = problem.posterior_mean.clone();
    let mut acc = ChainAccumulator::new(d);
    let beta = beta_for_mech(mech, n, &opts);
    for k in 0..opts.iters {
        let hs: Vec<Vec<f64>> =
            (0..n).map(|i| problem.h_client(i, &acc.theta, &theta_star)).collect();
        let out = mech.aggregate(&hs, app_round_seed(opts.seed, k as u64));
        acc.bits_total += out.bits.variable_total;
        let est = out.estimate;
        acc.step(k, &est, n, &opts, beta, &problem.posterior_mean);
    }
    acc.finish(n, &problem.posterior_mean)
}

/// The streaming producer for QLSD* on the coordinator: client i's
/// iteration-k vector is H_i(θ_k) = N_i·(θ_k − θ*), computed **per
/// coordinate range** directly from the broadcast state — no client ever
/// materializes a whole-d gradient, which is what removes the last
/// O(n·d) client-side residue from the Langevin app.
pub struct HCompute {
    n_obs: f64,
    theta_star: Vec<f64>,
    streams: bool,
}

impl HCompute {
    pub fn new(problem: &GaussianPosterior, streams: bool) -> Self {
        Self {
            n_obs: problem.n_obs as f64,
            theta_star: problem.posterior_mean.clone(),
            streams,
        }
    }
}

impl LocalCompute for HCompute {
    fn compute_chunk(
        &self,
        _client: usize,
        _round: u64,
        state: &[f64],
        range: std::ops::Range<usize>,
        out: &mut [f64],
    ) {
        for (o, j) in out.iter_mut().zip(range) {
            *o = self.n_obs * (state[j] - self.theta_star[j]);
        }
    }

    fn streams_chunks(&self) -> bool {
        self.streams
    }
}

/// [`qlsd_star_mech`] rewired onto the coordinator: each iteration is a
/// one-round chunk-streamed window over an [`HCompute`] fleet (θ_k is the
/// broadcast state), aggregated through the mechanism's pipeline stages.
/// Bit-identical to [`qlsd_star_mech`] for every chunk size — at partial
/// chunks the clients stream O(c) slices straight into
/// `encode_chunk_slice` when the mechanism's encoder allows it.
pub fn qlsd_star_coordinator(
    problem: &GaussianPosterior,
    mech: &dyn MeanMechanism,
    opts: LangevinOpts,
    copts: CoordinatorOpts,
) -> LangevinResult {
    let d = problem.dim;
    let n = problem.n_clients;
    let streams = mech
        .pipeline_parts()
        .map_or(false, |p| p.encoder.slice_chunkable() && copts.chunk != 0);
    let compute = Arc::new(HCompute::new(problem, streams));
    let mut coord = AppCoordinator::new(mech, compute, n, d, copts);
    let mut acc = ChainAccumulator::new(d);
    let beta = beta_for_mech(mech, n, &opts);
    for k in 0..opts.iters {
        // θ is sequential: every iteration depends on the previous round's
        // estimate, so the window is one round wide by construction.
        let mut reports = coord.run_rounds(k as u64, 1, &acc.theta, opts.seed);
        let rep = reports.pop().expect("one-round window yields one report");
        acc.bits_total += rep.output.bits.variable_total;
        let est = rep.output.estimate;
        acc.step(k, &est, n, &opts, beta, &problem.posterior_mean);
    }
    acc.finish(n, &problem.posterior_mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{LayeredBitsCompressor, NoCompression, UnbiasedQuantizer};

    fn tiny_problem() -> GaussianPosterior {
        GaussianPosterior::generate(5, 8, 10, 42)
    }

    fn opts(iters: usize) -> LangevinOpts {
        LangevinOpts {
            gamma: 5e-4,
            iters,
            burn_in: iters / 2,
            seed: 9,
            discount_compression_noise: true,
        }
    }

    #[test]
    fn posterior_mean_is_exact() {
        let p = tiny_problem();
        // posterior mean = overall data mean for the quadratic potential
        let total: f64 = p.obs_sums.iter().flat_map(|s| s.iter()).sum();
        let avg = total / (p.n_clients * p.n_obs * p.dim) as f64;
        let pm_avg: f64 = p.posterior_mean.iter().sum::<f64>() / p.dim as f64;
        assert!((avg - pm_avg).abs() < 1e-12);
    }

    #[test]
    fn uncompressed_chain_converges_to_posterior_mean() {
        let p = tiny_problem();
        let res = qlsd_star(&p, &NoCompression, opts(8000));
        // posterior sd per coordinate = 1/√(Σ N_i) = 1/√50 ≈ 0.14;
        // the posterior-mean estimate over 4000 samples is much tighter
        assert!(res.mse < 3e-3, "mse={}", res.mse);
    }

    #[test]
    fn layered_compression_tracks_uncompressed() {
        let p = tiny_problem();
        let base = qlsd_star(&p, &NoCompression, opts(8000)).mse;
        let ms = qlsd_star(&p, &LayeredBitsCompressor::new(8), opts(8000)).mse;
        assert!(ms < base * 30.0 + 5e-3, "ms={ms} base={base}");
    }

    #[test]
    fn exact_error_discounting_keeps_exact_temperature() {
        // the Fig. 10 mechanism: QLSD*-MS discounts its exactly-Gaussian
        // compression error, so the chain's stationary variance matches the
        // discretized posterior; plain QLSD* cannot discount (non-Gaussian
        // error) and runs hot.
        // regime where compression noise is a large fraction of 2γ:
        // few clients with many observations ⇒ large per-client gradients
        // relative to the posterior scale (inflation ≈ γ·c_b·N_i·κ²/2)
        let p = GaussianPosterior::generate(4, 50, 500, 77);
        let gamma = 5e-4;
        let o = LangevinOpts {
            gamma,
            iters: 24_000,
            burn_in: 4_000,
            seed: 5,
            discount_compression_noise: true, // overridden per arm
        };
        let prec = p.precision();
        // discretized OU stationary variance: 2γ/(1 − (1 − γP)²)
        let var_exact = 2.0 * gamma / (1.0 - (1.0 - gamma * prec).powi(2));
        let ms = super::fig10_arm(&p, super::Fig10Arm::QlsdMs(2), o);
        let uq = super::fig10_arm(&p, super::Fig10Arm::QlsdUnbiased(1), o);
        let err_ms = (ms.chain_var - var_exact).abs() / var_exact;
        let err_uq = (uq.chain_var - var_exact).abs() / var_exact;
        // coarse unbiased quantization runs measurably hot ...
        assert!(err_uq > 0.08, "uq var {} exact {var_exact}", uq.chain_var);
        assert!(uq.chain_var > var_exact);
        // ... while the discounted exact-Gaussian arm stays at temperature
        assert!(err_ms < 0.05, "ms var {} exact {var_exact}", ms.chain_var);
        assert!(err_ms < err_uq);
    }

    #[test]
    fn bits_accounting_positive() {
        let p = tiny_problem();
        let res = qlsd_star(&p, &UnbiasedQuantizer::new(4), opts(200));
        assert!(res.bits_per_client > 0.0);
    }
}
