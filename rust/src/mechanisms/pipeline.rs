//! The client-encode / transport / server-decode pipeline.
//!
//! The paper's mechanisms are by construction distributed: client i sees
//! only its own vector and the round's shared randomness and emits integer
//! descriptions mᵢ ([`ClientEncoder`]); the network delivers either the
//! per-client messages or — for homomorphic mechanisms (Def. 6) — only the
//! sum Σᵢ mᵢ, optionally under secure aggregation ([`Transport`]); the
//! server decodes an estimate from what it observed plus the same shared
//! randomness ([`ServerDecoder`]). [`run_pipeline`] wires the three stages
//! and [`Pipeline`] packages any (encoder, transport, decoder) triple as a
//! [`MeanMechanism`], so the coordinator, figure harnesses and benches all
//! keep working against one interface.
//!
//! Server memory: the summing transports ([`Plain`], [`SecAgg`]) fold each
//! client message into a single O(d) accumulator — the server never holds
//! the O(n·d) description matrix. [`Unicast`] keeps the per-client list,
//! which is what the non-homomorphic mechanisms (individual AINQ, SIGM,
//! unbiased-quant) inherently require.
//!
//! Shared randomness: every stream is derived from the round seed —
//! `Rng::derive(seed, client)` for per-client randomness and
//! `Rng::derive(seed, GLOBAL_STREAM − k)` for globally shared draws — so
//! encoder and decoder reconstruct identical values without communication.
//! [`RoundCache`] memoizes one round's derived shared randomness purely as
//! a simulation speedup (in a deployment each party derives it once).
//! (Why ALL randomness must flow through seeded streams is recorded in the
//! determinism ADR, `docs/determinism.md`.)
//!
//! ## Sessions and windows
//!
//! A single aggregation round is the W=1 special case of a *batched
//! multi-round session* ([`crate::mechanisms::session::TransportSession`]):
//! the session opens the transport once per window of W rounds, keeps a
//! ring of W per-round [`TransportPartial`] accumulators (each still O(d)
//! for the summing transports), and unmasks all rounds in one batched
//! close. Transports participate through
//! [`Transport::for_session_round`], which rekeys any round-scoped
//! transport randomness — for [`SecAgg`], the ℤ_m mask schedule — to the
//! session seed (see [`crate::secagg::session_mask_root`]), amortizing the
//! session opening across the window. [`run_pipeline`] itself delegates to
//! a one-round session, so every mechanism, wrapper and coordinator shape
//! exercises the same code path.
//!
//! ## The Plain ≡ SecAgg bit-identity invariant
//!
//! For any homomorphic mechanism and any round, running over [`SecAgg`]
//! must produce the *bit-identical* [`super::traits::RoundOutput`] that
//! [`Plain`] produces — masking may change who sees what in flight, never
//! the decoded value. The property holds by construction (masks cancel
//! exactly over ℤ_m before the signed lift) and is enforced by property
//! tests per mechanism, both per round and for whole windowed sessions.

use std::sync::{Arc, Mutex};

use super::traits::{BitsAccount, MeanMechanism, RoundOutput};
use crate::secagg::{self, SecAggParams};
use crate::util::rng::Rng;

/// Stream id of globally shared randomness (all clients + server).
pub const GLOBAL_STREAM: u64 = u64::MAX;

/// Base stream tag for the server's *dropout noise completion* draws
/// (xor'd with the dropped client's id). Disjoint by construction from
/// the per-client streams (small integers) and the global/aux streams
/// (`u64::MAX − k`), so completing a dropped client's noise never
/// correlates with any live stream.
pub const DROPOUT_NOISE_STREAM: u64 = 0xD809_B07E_0000_0000;

/// Base stream tag for per-client *coordinate-subsampling rows* (xor'd
/// with the client id): client i's Bernoulli(γ) row derives from its own
/// stream, so encoding is O(d) — no party ever materializes (or caches)
/// the O(n·d) subsample matrix. Families stay disjoint by construction:
/// the high 32 bits differ from every other tag for any fleet below 2³²
/// clients (see `session_stream_ids_are_pairwise_distinct`).
pub const SUBSAMPLE_STREAM: u64 = 0x5AB5_C0DE_0000_0000;

/// One aggregation round's public context: the shared seed plus the round
/// shape. Identical on every client and the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SharedRound {
    pub seed: u64,
    pub n_clients: usize,
    pub dim: usize,
}

impl SharedRound {
    pub fn new(seed: u64, n_clients: usize, dim: usize) -> Self {
        Self { seed, n_clients, dim }
    }

    /// Client i's private-but-shared-with-server stream.
    pub fn client_rng(&self, client: usize) -> Rng {
        Rng::derive(self.seed, client as u64)
    }

    /// The round's global shared-randomness stream.
    pub fn global_rng(&self) -> Rng {
        Rng::derive(self.seed, GLOBAL_STREAM)
    }

    /// Additional global streams (offset ≥ 1), e.g. SIGM's empty-subsample
    /// noise (offset 1) and CSGM's server noise (offset 2).
    pub fn aux_rng(&self, offset: u64) -> Rng {
        Rng::derive(self.seed, GLOBAL_STREAM - offset)
    }

    /// The dropout-noise-completion stream for a dropped client: when a
    /// round closes over survivors, dropout-aware decoders replace each
    /// dropped client's (unknowable) quantization error with a fresh
    /// U(−1/2, 1/2) draw from this stream, restoring the exact n-term
    /// aggregate noise law at a rescaled variance (see
    /// [`ServerDecoder::decode_survivors`]). Derived from the round seed,
    /// so every decode path — and the Plain reference in tests — draws the
    /// identical completion noise.
    pub fn dropout_rng(&self, dropped: usize) -> Rng {
        Rng::derive(self.seed, DROPOUT_NOISE_STREAM ^ dropped as u64)
    }

    /// Client i's coordinate-subsampling row stream. SIGM and CSGM both
    /// derive their Bernoulli(γ) subsample rows through this one stream,
    /// which is what guarantees the two see IDENTICAL subsamples for a
    /// given seed — the matched-subsample comparison of Figs. 5/7 depends
    /// on it. Per-row derivation (stream `SUBSAMPLE_STREAM ^ i`) means a
    /// client derives only its own O(d) row at encode time; before the
    /// seed-format bump the rows were drawn row-major from one global
    /// stream, forcing every party to materialize — and the mechanisms to
    /// cache — the full O(n·d) matrix.
    pub fn subsample_rng(&self, client: usize) -> Rng {
        Rng::derive(self.seed, SUBSAMPLE_STREAM ^ client as u64)
    }

    /// Client i's materialized Bernoulli(γ) subsample row.
    pub fn subsample_row(&self, client: usize, gamma: f64) -> Vec<bool> {
        let mut rng = self.subsample_rng(client);
        (0..self.dim).map(|_| rng.bernoulli(gamma)).collect()
    }

    fn key(&self) -> (u64, usize, usize) {
        (self.seed, self.n_clients, self.dim)
    }
}

/// The clients a round actually closed over: the full announced fleet
/// minus the announced dropouts. Decoders receive this alongside the
/// [`SharedRound`] (whose `n_clients` stays the *announced* fleet size —
/// encoders sized their steps and masks to it before anyone dropped).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SurvivorSet {
    alive: Vec<bool>,
    n_alive: usize,
}

impl SurvivorSet {
    /// Every client survived (the default for dropout-free rounds).
    pub fn full(n_clients: usize) -> Self {
        assert!(n_clients > 0, "need at least one client");
        Self { alive: vec![true; n_clients], n_alive: n_clients }
    }

    /// The fleet minus the announced `dropped` clients. Panics on an
    /// out-of-range id, a duplicate announcement, or an empty survivor
    /// set — all fail-closed conditions.
    pub fn with_dropped(n_clients: usize, dropped: &[usize]) -> Self {
        Self::full(n_clients).drop_clients(dropped)
    }

    /// A survivor set from an explicit per-client alive mask (how sampling
    /// policies materialize a round's cohort). Panics on an empty fleet or
    /// a cohort with zero members — fail-closed conditions.
    pub fn from_alive_mask(alive: Vec<bool>) -> Self {
        assert!(!alive.is_empty(), "need at least one client");
        let n_alive = alive.iter().filter(|&&a| a).count();
        assert!(n_alive > 0, "fails closed: a round cannot close with zero survivors");
        Self { alive, n_alive }
    }

    /// [`SurvivorSet::drop_clients`] for a *sampled* round: every dropped
    /// id must be an alive member of this cohort — announcing a
    /// sampled-out client as dropped fails closed with a
    /// sampling-specific diagnostic (it held no masks, so there is
    /// nothing to recover), while duplicates within `dropped` still
    /// surface as a double-announcement. The single implementation of
    /// this invariant: the coordinator, the in-process window runner and
    /// the session close all validate through it.
    pub fn drop_cohort_members(&self, dropped: &[usize], round_in_window: usize) -> Self {
        let n = self.n();
        for &j in dropped {
            assert!(j < n, "dropped client {j} out of range for {n} clients");
            assert!(
                self.is_alive(j),
                "fails closed: client {j} announced dropped in round {round_in_window} but \
                 is sampled out of the cohort — it held no masks to recover"
            );
        }
        self.drop_clients(dropped)
    }

    /// This set minus the further `dropped` clients — how a sampling
    /// cohort composes with mid-round dropouts: the cohort is fixed at
    /// session open, the dropouts are announced at close, and the decode
    /// set is the difference. Panics (fail closed) on an out-of-range id,
    /// a client dropped twice, or an empty result.
    pub fn drop_clients(&self, dropped: &[usize]) -> Self {
        let mut s = self.clone();
        let n_clients = s.alive.len();
        for &j in dropped {
            assert!(j < n_clients, "dropped client {j} out of range for {n_clients} clients");
            assert!(s.alive[j], "client {j} announced dropped twice");
            s.alive[j] = false;
            s.n_alive -= 1;
        }
        assert!(s.n_alive > 0, "fails closed: a round cannot close with zero survivors");
        s
    }

    /// Announced fleet size n.
    pub fn n(&self) -> usize {
        self.alive.len()
    }

    /// True survivor count n′.
    pub fn n_alive(&self) -> usize {
        self.n_alive
    }

    pub fn is_full(&self) -> bool {
        self.n_alive == self.alive.len()
    }

    pub fn is_alive(&self, client: usize) -> bool {
        self.alive[client]
    }

    /// The per-client alive mask itself (index = global client id) — the
    /// single representation shard skip-lists and tests should reuse
    /// rather than rebuilding it from [`SurvivorSet::is_alive`].
    pub fn alive_mask(&self) -> &[bool] {
        &self.alive
    }

    /// Surviving client ids, ascending.
    pub fn alive_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.alive.iter().enumerate().filter(|(_, &a)| a).map(|(i, _)| i)
    }

    /// Dropped client ids, ascending.
    pub fn dropped_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.alive.iter().enumerate().filter(|(_, &a)| !a).map(|(i, _)| i)
    }
}

/// What one client sends for one round: integer descriptions plus (for
/// mechanisms whose decoder needs data-dependent side information, like a
/// transmitted norm) a few raw reals. `aux` MUST be empty for homomorphic
/// mechanisms — the summing transports reject it.
#[derive(Clone, Debug, Default)]
pub struct Descriptions {
    pub ms: Vec<i64>,
    pub aux: Vec<f64>,
    /// communication accounting for this client's uplink
    pub bits: BitsAccount,
}

/// What the server observes after transport.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Σᵢ mᵢ only — the Def. 6 server view.
    Sum(Vec<i64>),
    /// Per-client messages (ms, aux), indexed by client id.
    PerClient(Vec<(Vec<i64>, Vec<f64>)>),
}

impl Payload {
    /// Exact Σᵢ mᵢ regardless of transport.
    pub fn description_sum(&self) -> Vec<i64> {
        match self {
            Payload::Sum(v) => v.clone(),
            Payload::PerClient(list) => {
                assert!(!list.is_empty());
                let d = list[0].0.len();
                let mut out = vec![0i64; d];
                for (ms, _) in list {
                    assert_eq!(ms.len(), d);
                    for (o, &m) in out.iter_mut().zip(ms) {
                        *o += m;
                    }
                }
                out
            }
        }
    }

    /// The per-client list; panics if the transport delivered only the sum
    /// (a decoder that calls this must return `sum_decodable() == false`).
    pub fn per_client(&self) -> &[(Vec<i64>, Vec<f64>)] {
        match self {
            Payload::PerClient(list) => list,
            Payload::Sum(_) => panic!(
                "decoder needs per-client descriptions but the transport \
                 delivered only their sum — use the Unicast transport"
            ),
        }
    }
}

/// A client-side encoder: produce the integer descriptions of one client's
/// vector under the round's shared randomness. Implementations must be
/// deterministic in `(client, x, round)`.
pub trait ClientEncoder: Send + Sync {
    fn encode(&self, client: usize, x: &[f64], round: &SharedRound) -> Descriptions;
}

/// A mergeable in-flight uplink accumulator. Shards fold their clients into
/// private partials; partials merge associatively into the round total —
/// the server side stays O(d) for the summing transports.
#[derive(Clone, Debug)]
pub enum TransportPartial {
    /// running Σ mᵢ (None until the first submit fixes the length)
    Sum(Option<Vec<i64>>),
    /// running Σ masked(mᵢ) over ℤ_modulus
    Masked { sum: Option<Vec<u64>>, modulus: u64 },
    /// collected (client, ms, aux) messages
    List(Vec<(usize, Vec<i64>, Vec<f64>)>),
}

/// The delivery channel between clients and server.
pub trait Transport: Send + Sync {
    fn name(&self) -> String;

    /// Whether the server ever observes anything beyond Σᵢ mᵢ.
    fn sum_only(&self) -> bool;

    /// A fresh empty accumulator for this round.
    fn empty(&self, round: &SharedRound) -> TransportPartial;

    /// Fold one client's message into an accumulator.
    fn submit(
        &self,
        part: &mut TransportPartial,
        client: usize,
        msg: &Descriptions,
        round: &SharedRound,
    );

    /// Merge another accumulator (another shard's partial) into `a`.
    fn merge(&self, a: &mut TransportPartial, b: TransportPartial);

    /// Close the round and surface the server's view.
    fn finish(&self, part: TransportPartial, round: &SharedRound) -> Payload;

    /// Close the round over a survivor-only client set (announced
    /// dropouts). The default fails closed — a transport must explicitly
    /// support partial client sets. The summing transports do: [`Plain`]'s
    /// accumulator already holds exactly the survivor sum, and [`SecAgg`]
    /// closes after the session has folded the reconstructed masks of
    /// every dropped client back in
    /// ([`crate::secagg::reconstruct_dropped_masks`] — the session layer
    /// owns that step). [`Unicast`] keeps the default: its per-client
    /// decoders index payloads by client id and are not dropout-aware.
    fn finish_survivors(
        &self,
        part: TransportPartial,
        round: &SharedRound,
        survivors: &SurvivorSet,
    ) -> Payload {
        assert!(
            survivors.is_full(),
            "transport {} fails closed under dropouts: it cannot close over a partial \
             client set",
            self.name(),
        );
        self.finish(part, round)
    }

    /// The transport instance serving round `round_in_window` of a batched
    /// session opened with `session_seed`
    /// ([`crate::mechanisms::session::TransportSession`]). Transports with
    /// no round-scoped randomness return themselves unchanged; [`SecAgg`]
    /// re-roots its ℤ_m mask schedule at the session's derived stream so
    /// one pairwise opening serves the whole window. Must be deterministic
    /// in `(session_seed, round_in_window)` — every party re-derives it.
    fn for_session_round(&self, session_seed: u64, round_in_window: u64) -> Arc<dyn Transport>;

    /// Like [`Transport::for_session_round`], but for a *sampled* session
    /// round whose participating cohort is known at open. Cohort-aware
    /// transports restrict their round-scoped randomness to the cohort —
    /// [`SecAgg`] opens its pairwise mask schedule among cohort members
    /// only, so a sampled-out client needs no masks and (unlike a
    /// mid-round dropout) no recovery shares. The default fails closed: a
    /// transport that has not opted in refuses partial cohorts, and a full
    /// cohort degenerates to the unsampled schedule bit for bit.
    fn for_session_round_sampled(
        &self,
        session_seed: u64,
        round_in_window: u64,
        cohort: &SurvivorSet,
    ) -> Arc<dyn Transport> {
        assert!(
            cohort.is_full(),
            "transport {} fails closed under client sampling: it is not cohort-aware",
            self.name(),
        );
        self.for_session_round(session_seed, round_in_window)
    }
}

fn add_i64(acc: &mut Option<Vec<i64>>, ms: &[i64]) {
    match acc {
        None => *acc = Some(ms.to_vec()),
        Some(v) => {
            assert_eq!(v.len(), ms.len(), "description length changed mid-round");
            for (a, &m) in v.iter_mut().zip(ms) {
                *a += m;
            }
        }
    }
}

fn add_mod(acc: &mut Option<Vec<u64>>, ms: &[u64], modulus: u64) {
    match acc {
        None => *acc = Some(ms.to_vec()),
        Some(v) => {
            assert_eq!(v.len(), ms.len(), "description length changed mid-round");
            for (a, &m) in v.iter_mut().zip(ms) {
                *a = (*a + m) % modulus;
            }
        }
    }
}

/// Plain summation: the honest-but-curious server receives every mᵢ but the
/// simulation folds them immediately — the O(d) reference transport for
/// homomorphic (sum-decodable) mechanisms.
#[derive(Clone, Copy, Debug, Default)]
pub struct Plain;

impl Transport for Plain {
    fn name(&self) -> String {
        "plain".into()
    }

    fn sum_only(&self) -> bool {
        true
    }

    fn empty(&self, _round: &SharedRound) -> TransportPartial {
        TransportPartial::Sum(None)
    }

    fn submit(
        &self,
        part: &mut TransportPartial,
        _client: usize,
        msg: &Descriptions,
        _round: &SharedRound,
    ) {
        assert!(
            msg.aux.is_empty(),
            "aux side information requires the Unicast transport"
        );
        match part {
            TransportPartial::Sum(acc) => add_i64(acc, &msg.ms),
            _ => panic!("Plain transport got a foreign partial"),
        }
    }

    fn merge(&self, a: &mut TransportPartial, b: TransportPartial) {
        match (a, b) {
            (TransportPartial::Sum(acc), TransportPartial::Sum(Some(v))) => add_i64(acc, &v),
            (TransportPartial::Sum(_), TransportPartial::Sum(None)) => {}
            _ => panic!("Plain transport got a foreign partial"),
        }
    }

    fn finish(&self, part: TransportPartial, _round: &SharedRound) -> Payload {
        match part {
            TransportPartial::Sum(Some(v)) => Payload::Sum(v),
            TransportPartial::Sum(None) => panic!("no clients submitted"),
            _ => panic!("Plain transport got a foreign partial"),
        }
    }

    fn finish_survivors(
        &self,
        part: TransportPartial,
        round: &SharedRound,
        _survivors: &SurvivorSet,
    ) -> Payload {
        // the accumulator holds exactly the survivors' Σ mᵢ — dropouts
        // simply never contributed, so the full-set close applies as-is
        self.finish(part, round)
    }

    fn for_session_round(&self, _session_seed: u64, _round_in_window: u64) -> Arc<dyn Transport> {
        // no transport randomness: every session round is plain summation
        Arc::new(Plain)
    }

    fn for_session_round_sampled(
        &self,
        _session_seed: u64,
        _round_in_window: u64,
        _cohort: &SurvivorSet,
    ) -> Arc<dyn Transport> {
        // no masks, no cohort-scoped randomness: the accumulator holds
        // whatever the cohort submits
        Arc::new(Plain)
    }
}

/// Per-client delivery: the server keeps the full message list. Required by
/// the non-homomorphic mechanisms (individual AINQ, SIGM, unbiased-quant),
/// whose decoders are not functions of Σ mᵢ.
#[derive(Clone, Copy, Debug, Default)]
pub struct Unicast;

impl Transport for Unicast {
    fn name(&self) -> String {
        "unicast".into()
    }

    fn sum_only(&self) -> bool {
        false
    }

    fn empty(&self, _round: &SharedRound) -> TransportPartial {
        TransportPartial::List(Vec::new())
    }

    fn submit(
        &self,
        part: &mut TransportPartial,
        client: usize,
        msg: &Descriptions,
        _round: &SharedRound,
    ) {
        match part {
            TransportPartial::List(list) => {
                list.push((client, msg.ms.clone(), msg.aux.clone()))
            }
            _ => panic!("Unicast transport got a foreign partial"),
        }
    }

    fn merge(&self, a: &mut TransportPartial, b: TransportPartial) {
        match (a, b) {
            (TransportPartial::List(la), TransportPartial::List(lb)) => la.extend(lb),
            _ => panic!("Unicast transport got a foreign partial"),
        }
    }

    fn finish(&self, part: TransportPartial, round: &SharedRound) -> Payload {
        match part {
            TransportPartial::List(mut list) => {
                list.sort_by_key(|&(c, _, _)| c);
                assert_eq!(list.len(), round.n_clients, "missing client messages");
                let out = list
                    .into_iter()
                    .enumerate()
                    .map(|(i, (c, ms, aux))| {
                        assert_eq!(i, c, "duplicate or missing client id");
                        (ms, aux)
                    })
                    .collect();
                Payload::PerClient(out)
            }
            _ => panic!("Unicast transport got a foreign partial"),
        }
    }

    fn for_session_round(&self, _session_seed: u64, _round_in_window: u64) -> Arc<dyn Transport> {
        // no transport randomness: per-client delivery is stateless
        Arc::new(Unicast)
    }
}

/// Secure aggregation (Bonawitz et al. 2017, §5.2 / Prop. 3): each client
/// masks its descriptions with pairwise-derived additive masks over ℤ_m;
/// the server folds masked vectors mod m and the masks cancel, leaving
/// exactly Σᵢ mᵢ — the server never observes a per-client description. The
/// accumulator is a single length-d field vector: O(d) server state.
#[derive(Clone, Debug)]
pub struct SecAgg {
    pub params: SecAggParams,
    /// Session override of the pairwise-mask root: `Some` when this
    /// instance serves one round of a batched
    /// [`crate::mechanisms::session::TransportSession`] (set by
    /// [`Transport::for_session_round`]), `None` for the legacy standalone
    /// per-round derivation from the round seed.
    mask_root: Option<u64>,
    /// Cohort override for *sampled* session rounds (set by
    /// [`Transport::for_session_round_sampled`]): masks are exchanged only
    /// among these clients (sorted global ids), so the schedule is cheaper
    /// than full-fleet masking and sampled-out clients need no recovery.
    /// `None` = the full announced fleet.
    cohort: Option<Arc<Vec<usize>>>,
}

impl SecAgg {
    pub fn new() -> Self {
        Self { params: SecAggParams::default(), mask_root: None, cohort: None }
    }

    pub fn with_params(params: SecAggParams) -> Self {
        Self { params, mask_root: None, cohort: None }
    }

    /// Pairwise-mask root seed for a standalone round (public derivation —
    /// the masks' security lives in the pairwise PRG streams, not in
    /// hiding the root id).
    pub fn root_seed(round: &SharedRound) -> u64 {
        round.seed ^ 0x5EC_A662
    }

    /// The mask root actually in force: the session schedule's root when
    /// rekeyed, the per-round derivation otherwise. Either way the masks
    /// cancel exactly, so the decoded sum — and the Plain ≡ SecAgg
    /// bit-identity — is independent of the choice.
    fn mask_root_for(&self, round: &SharedRound) -> u64 {
        self.mask_root.unwrap_or_else(|| Self::root_seed(round))
    }
}

impl Default for SecAgg {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for SecAgg {
    fn name(&self) -> String {
        format!("secagg(m=2^{})", self.params.modulus.trailing_zeros())
    }

    fn sum_only(&self) -> bool {
        true
    }

    fn empty(&self, _round: &SharedRound) -> TransportPartial {
        TransportPartial::Masked { sum: None, modulus: self.params.modulus }
    }

    fn submit(
        &self,
        part: &mut TransportPartial,
        client: usize,
        msg: &Descriptions,
        round: &SharedRound,
    ) {
        assert!(
            msg.aux.is_empty(),
            "aux side information cannot pass through secure aggregation"
        );
        let masked = match &self.cohort {
            Some(members) => secagg::mask_descriptions_among(
                &msg.ms,
                client,
                members,
                self.mask_root_for(round),
                self.params,
            ),
            None => secagg::mask_descriptions(
                &msg.ms,
                client,
                round.n_clients,
                self.mask_root_for(round),
                self.params,
            ),
        };
        match part {
            TransportPartial::Masked { sum, modulus } => add_mod(sum, &masked, *modulus),
            _ => panic!("SecAgg transport got a foreign partial"),
        }
    }

    fn merge(&self, a: &mut TransportPartial, b: TransportPartial) {
        match (a, b) {
            (
                TransportPartial::Masked { sum, modulus },
                TransportPartial::Masked { sum: Some(v), modulus: mb },
            ) => {
                assert_eq!(*modulus, mb);
                add_mod(sum, &v, *modulus);
            }
            (TransportPartial::Masked { .. }, TransportPartial::Masked { sum: None, .. }) => {}
            _ => panic!("SecAgg transport got a foreign partial"),
        }
    }

    fn finish(&self, part: TransportPartial, _round: &SharedRound) -> Payload {
        match part {
            TransportPartial::Masked { sum: Some(v), modulus } => {
                // masks cancel over the full client set: the signed
                // representative of the field sum is Σ mᵢ mod m
                Payload::Sum(v.into_iter().map(|x| secagg::from_field(x, modulus)).collect())
            }
            TransportPartial::Masked { sum: None, .. } => panic!("no clients submitted"),
            _ => panic!("SecAgg transport got a foreign partial"),
        }
    }

    fn finish_survivors(
        &self,
        part: TransportPartial,
        round: &SharedRound,
        _survivors: &SurvivorSet,
    ) -> Payload {
        // precondition (enforced by the session layer, the only caller
        // that closes partial rounds): every dropped client's outstanding
        // pairwise masks were reconstructed from the survivors' recovery
        // shares and folded back into the accumulator, so the residual
        // masks cancel and the signed lift below yields the survivors'
        // exact Σ mᵢ — bit-identical to Plain over the same survivor set
        self.finish(part, round)
    }

    fn for_session_round(&self, session_seed: u64, round_in_window: u64) -> Arc<dyn Transport> {
        // one session opening, W per-round mask roots from its stream
        let schedule = secagg::session_mask_root(session_seed);
        Arc::new(Self {
            params: self.params,
            mask_root: Some(secagg::round_mask_root(schedule, round_in_window)),
            cohort: None,
        })
    }

    fn for_session_round_sampled(
        &self,
        session_seed: u64,
        round_in_window: u64,
        cohort: &SurvivorSet,
    ) -> Arc<dyn Transport> {
        // same per-round mask root as the unsampled schedule, but the
        // pairwise agreement opens over the cohort only — a full cohort
        // degenerates to the unsampled transport bit for bit
        let schedule = secagg::session_mask_root(session_seed);
        Arc::new(Self {
            params: self.params,
            mask_root: Some(secagg::round_mask_root(schedule, round_in_window)),
            cohort: if cohort.is_full() {
                None
            } else {
                Some(Arc::new(cohort.alive_iter().collect()))
            },
        })
    }
}

/// Server-side decoder: reconstruct the mean estimate from the transported
/// payload and the shared randomness.
pub trait ServerDecoder: Send + Sync {
    /// Whether decoding needs only Σᵢ mᵢ (Def. 6) — i.e. whether the
    /// mechanism may ride a sum-only transport (Plain, SecAgg).
    fn sum_decodable(&self) -> bool;

    fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64>;

    /// Decode a round that closed over a survivor-only client set
    /// (announced dropouts with mask recovery). `round.n_clients` remains
    /// the announced fleet size n that the encoders sized their steps to;
    /// `survivors` carries the true survivor count n′ the estimate must
    /// average over.
    ///
    /// Dropout-aware decoders must (a) re-derive shared randomness — e.g.
    /// dithers — for *survivors only*, (b) average over n′, and (c) if
    /// their exact-error claim depends on the number of noise terms,
    /// complete the missing terms from [`SharedRound::dropout_rng`] so the
    /// aggregate error keeps its exact n-term law at the rescaled scale
    /// σ·n/n′ (the aggregate Gaussian and Irwin–Hall mechanisms do this).
    ///
    /// The default fails closed: a decoder that has not opted in refuses
    /// survivor-only payloads.
    fn decode_survivors(
        &self,
        payload: &Payload,
        round: &SharedRound,
        survivors: &SurvivorSet,
    ) -> Vec<f64> {
        assert!(
            survivors.is_full(),
            "decoder fails closed under dropouts: it is not survivor-aware"
        );
        self.decode(payload, round)
    }
}

/// Static mechanism metadata (the Table 1 property matrix) shared by the
/// pipeline wrapper and the direct [`MeanMechanism`] impls.
pub trait MechSpec {
    fn name(&self) -> String;
    fn is_homomorphic(&self) -> bool;
    fn gaussian_noise(&self) -> bool;
    fn fixed_length(&self) -> bool;
    fn noise_sd(&self) -> f64;
}

/// Run one round through the three stages — the W=1 special case of a
/// batched [`crate::mechanisms::session::TransportSession`] (the round
/// seed doubles as the session seed).
pub fn run_pipeline(
    encoder: &dyn ClientEncoder,
    transport: &dyn Transport,
    decoder: &dyn ServerDecoder,
    xs: &[Vec<f64>],
    seed: u64,
) -> RoundOutput {
    assert!(!xs.is_empty(), "need at least one client");
    super::session::run_window(encoder, transport, decoder, &[(xs, seed)], seed)
        .pop()
        .expect("one round in, one round out")
}

/// Implement [`MeanMechanism`] for a type that already implements
/// [`ClientEncoder`] + [`ServerDecoder`] + [`MechSpec`] by forwarding the
/// property flags to its `MechSpec` impl and routing `aggregate` through
/// [`run_pipeline`] over the given transport. The transport expression is
/// written closure-style so it may consult the mechanism, e.g.
///
/// ```text
/// impl_mean_mechanism!(IrwinHallMechanism, |_m| Plain);
/// impl_mean_mechanism!(Ddg, |m| m.transport());
/// ```
macro_rules! impl_mean_mechanism {
    ($ty:ty, |$mech:ident| $transport:expr) => {
        impl $crate::mechanisms::traits::MeanMechanism for $ty {
            fn name(&self) -> String {
                $crate::mechanisms::pipeline::MechSpec::name(self)
            }

            fn is_homomorphic(&self) -> bool {
                $crate::mechanisms::pipeline::MechSpec::is_homomorphic(self)
            }

            fn gaussian_noise(&self) -> bool {
                $crate::mechanisms::pipeline::MechSpec::gaussian_noise(self)
            }

            fn fixed_length(&self) -> bool {
                $crate::mechanisms::pipeline::MechSpec::fixed_length(self)
            }

            fn noise_sd(&self) -> f64 {
                $crate::mechanisms::pipeline::MechSpec::noise_sd(self)
            }

            fn aggregate(
                &self,
                xs: &[Vec<f64>],
                seed: u64,
            ) -> $crate::mechanisms::traits::RoundOutput {
                let $mech = self;
                $crate::mechanisms::pipeline::run_pipeline(
                    $mech,
                    &$transport,
                    $mech,
                    xs,
                    seed,
                )
            }
        }
    };
}
pub(crate) use impl_mean_mechanism;

/// Any (encoder, transport, decoder) triple as a [`MeanMechanism`].
#[derive(Clone, Debug)]
pub struct Pipeline<E, T, D> {
    pub encoder: E,
    pub transport: T,
    pub decoder: D,
}

impl<M: ClientEncoder + ServerDecoder + MechSpec + Clone> Pipeline<M, Plain, M> {
    /// Mechanism over plain summation (homomorphic mechanisms only).
    pub fn plain(mech: M) -> Self {
        Self { encoder: mech.clone(), transport: Plain, decoder: mech }
    }
}

impl<M: ClientEncoder + ServerDecoder + MechSpec + Clone> Pipeline<M, SecAgg, M> {
    /// Mechanism over secure aggregation with the default modulus.
    pub fn secagg(mech: M) -> Self {
        Self { encoder: mech.clone(), transport: SecAgg::new(), decoder: mech }
    }

    pub fn secagg_with(mech: M, params: SecAggParams) -> Self {
        Self { encoder: mech.clone(), transport: SecAgg::with_params(params), decoder: mech }
    }
}

impl<M: ClientEncoder + ServerDecoder + MechSpec + Clone> Pipeline<M, Unicast, M> {
    /// Mechanism over per-client delivery.
    pub fn unicast(mech: M) -> Self {
        Self { encoder: mech.clone(), transport: Unicast, decoder: mech }
    }
}

impl<E, T, D> Pipeline<E, T, D>
where
    E: ClientEncoder,
    T: Transport,
    D: ServerDecoder + MechSpec + Send + Sync,
{
    /// Aggregate a whole window of rounds through ONE transport session
    /// (each entry pairs a round's client data with its seed). The
    /// single-round [`MeanMechanism::aggregate`] is the W=1 special case
    /// of this call.
    pub fn aggregate_window(
        &self,
        rounds: &[(&[Vec<f64>], u64)],
        session_seed: u64,
    ) -> Vec<RoundOutput> {
        super::session::run_window(
            &self.encoder,
            &self.transport,
            &self.decoder,
            rounds,
            session_seed,
        )
    }

    /// [`Self::aggregate_window`] under a per-round dropout schedule:
    /// `dropouts[r]` lists the clients dropping in round r of the window
    /// (announced, recovered, decoded over the survivors — see
    /// [`crate::mechanisms::session::run_window_with_dropouts`]).
    pub fn aggregate_window_with_dropouts(
        &self,
        rounds: &[(&[Vec<f64>], u64)],
        session_seed: u64,
        dropouts: &[Vec<usize>],
    ) -> Vec<RoundOutput> {
        super::session::run_window_with_dropouts(
            &self.encoder,
            &self.transport,
            &self.decoder,
            rounds,
            session_seed,
            dropouts,
        )
    }
}

impl<E, T, D> MeanMechanism for Pipeline<E, T, D>
where
    E: ClientEncoder,
    T: Transport,
    D: ServerDecoder + MechSpec + Send + Sync,
{
    fn name(&self) -> String {
        format!("{} via {}", MechSpec::name(&self.decoder), self.transport.name())
    }

    fn is_homomorphic(&self) -> bool {
        MechSpec::is_homomorphic(&self.decoder)
    }

    fn gaussian_noise(&self) -> bool {
        MechSpec::gaussian_noise(&self.decoder)
    }

    fn fixed_length(&self) -> bool {
        MechSpec::fixed_length(&self.decoder)
    }

    fn noise_sd(&self) -> f64 {
        MechSpec::noise_sd(&self.decoder)
    }

    fn aggregate(&self, xs: &[Vec<f64>], seed: u64) -> RoundOutput {
        run_pipeline(&self.encoder, &self.transport, &self.decoder, xs, seed)
    }
}

/// How many rounds of derived shared randomness a [`RoundCache`] retains —
/// sized to cover a full session window (it backs
/// [`crate::mechanisms::session::MAX_WINDOW`]) so shards concurrently
/// encoding different rounds of one window never evict each other's
/// entries.
pub(crate) const ROUND_CACHE_CAP: usize = 16;

/// Memoizes recent rounds' *derived shared randomness*, keyed by
/// (seed, n_clients, dim), with FIFO eviction past [`ROUND_CACHE_CAP`]
/// entries. Every party can derive these values from the seed alone;
/// caching only avoids deriving them once per client in the
/// single-process simulation. Cloning yields a fresh empty cache (contents
/// are always re-derivable).
pub struct RoundCache<V> {
    slots: Mutex<Vec<((u64, usize, usize), Arc<V>)>>,
}

impl<V> RoundCache<V> {
    pub fn new() -> Self {
        Self { slots: Mutex::new(Vec::new()) }
    }

    pub fn get_or(&self, round: &SharedRound, make: impl FnOnce() -> V) -> Arc<V> {
        let key = round.key();
        let mut slots = self.slots.lock().expect("round cache poisoned");
        if let Some((_, v)) = slots.iter().find(|(k, _)| *k == key) {
            return v.clone();
        }
        // built under the lock: a second thread asking for the same round
        // waits instead of duplicating the O(n·d) derivation
        let v = Arc::new(make());
        if slots.len() == ROUND_CACHE_CAP {
            slots.remove(0);
        }
        slots.push((key, v.clone()));
        v
    }
}

impl<V> Default for RoundCache<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> Clone for RoundCache<V> {
    fn clone(&self) -> Self {
        Self::new()
    }
}

impl<V> std::fmt::Debug for RoundCache<V> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("RoundCache")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy homomorphic mechanism: m = round(x) per coordinate, decode =
    /// Σm/n. Exercises the transport plumbing without quantizer noise.
    #[derive(Clone, Debug)]
    struct RoundToInt;

    impl ClientEncoder for RoundToInt {
        fn encode(&self, _client: usize, x: &[f64], _round: &SharedRound) -> Descriptions {
            let mut bits = BitsAccount::default();
            let ms: Vec<i64> = x
                .iter()
                .map(|&v| {
                    let m = crate::quantizer::round_half_up(v);
                    bits.add_description(m);
                    m
                })
                .collect();
            Descriptions { ms, aux: vec![], bits }
        }
    }

    impl ServerDecoder for RoundToInt {
        fn sum_decodable(&self) -> bool {
            true
        }

        fn decode(&self, payload: &Payload, round: &SharedRound) -> Vec<f64> {
            payload
                .description_sum()
                .iter()
                .map(|&s| s as f64 / round.n_clients as f64)
                .collect()
        }
    }

    impl MechSpec for RoundToInt {
        fn name(&self) -> String {
            "round-to-int".into()
        }

        fn is_homomorphic(&self) -> bool {
            true
        }

        fn gaussian_noise(&self) -> bool {
            false
        }

        fn fixed_length(&self) -> bool {
            false
        }

        fn noise_sd(&self) -> f64 {
            0.0
        }
    }

    fn data() -> Vec<Vec<f64>> {
        vec![vec![1.2, -3.9, 0.0], vec![2.2, 1.1, -7.0], vec![0.9, 0.0, 2.0]]
    }

    #[test]
    fn plain_and_secagg_agree_exactly() {
        let xs = data();
        let a = Pipeline::plain(RoundToInt).aggregate(&xs, 9);
        let b = Pipeline::secagg(RoundToInt).aggregate(&xs, 9);
        assert_eq!(a.estimate, b.estimate);
        assert_eq!(a.bits.messages, b.bits.messages);
        assert!((a.bits.variable_total - b.bits.variable_total).abs() < 1e-12);
    }

    #[test]
    fn pipeline_window_matches_per_round_aggregate() {
        // the Pipeline wrapper's windowed session equals independent
        // single-round aggregates over Plain, round for round
        let xs = data();
        let p = Pipeline::secagg(RoundToInt);
        let rounds: Vec<(&[Vec<f64>], u64)> = vec![(xs.as_slice(), 5), (xs.as_slice(), 9)];
        let win = p.aggregate_window(&rounds, 123);
        assert_eq!(win.len(), 2);
        for (o, &(_, seed)) in win.iter().zip(&rounds) {
            let single = Pipeline::plain(RoundToInt).aggregate(&xs, seed);
            assert_eq!(o.estimate, single.estimate);
            assert_eq!(o.bits.messages, single.bits.messages);
        }
    }

    #[test]
    fn unicast_matches_sum_for_sum_decodable() {
        let xs = data();
        let a = Pipeline::plain(RoundToInt).aggregate(&xs, 5);
        let c = Pipeline::unicast(RoundToInt).aggregate(&xs, 5);
        assert_eq!(a.estimate, c.estimate);
    }

    #[test]
    #[should_panic(expected = "not homomorphic")]
    fn sum_only_transport_rejects_non_homomorphic_decoder() {
        #[derive(Clone, Debug)]
        struct NeedsList;
        impl ClientEncoder for NeedsList {
            fn encode(&self, _: usize, x: &[f64], _: &SharedRound) -> Descriptions {
                Descriptions { ms: vec![0; x.len()], aux: vec![], bits: BitsAccount::default() }
            }
        }
        impl ServerDecoder for NeedsList {
            fn sum_decodable(&self) -> bool {
                false
            }
            fn decode(&self, p: &Payload, _: &SharedRound) -> Vec<f64> {
                p.per_client(); // would panic anyway
                vec![]
            }
        }
        impl MechSpec for NeedsList {
            fn name(&self) -> String {
                "needs-list".into()
            }
            fn is_homomorphic(&self) -> bool {
                false
            }
            fn gaussian_noise(&self) -> bool {
                false
            }
            fn fixed_length(&self) -> bool {
                false
            }
            fn noise_sd(&self) -> f64 {
                0.0
            }
        }
        let _ = Pipeline::plain(NeedsList).aggregate(&data(), 1);
    }

    #[test]
    fn secagg_partial_is_o_d_and_masks_cancel_across_merges() {
        // two "shards" submit disjoint clients into separate partials; the
        // merged total must equal the plain sum
        let xs = data();
        let round = SharedRound::new(77, xs.len(), xs[0].len());
        let enc = RoundToInt;
        let t = SecAgg::new();
        let mut p0 = t.empty(&round);
        let mut p1 = t.empty(&round);
        for (i, x) in xs.iter().enumerate() {
            let d = enc.encode(i, x, &round);
            if i % 2 == 0 {
                t.submit(&mut p0, i, &d, &round);
            } else {
                t.submit(&mut p1, i, &d, &round);
            }
        }
        // O(d) check: the partial holds exactly one field vector
        if let TransportPartial::Masked { sum: Some(v), .. } = &p0 {
            assert_eq!(v.len(), xs[0].len());
        } else {
            panic!("wrong partial shape");
        }
        t.merge(&mut p0, p1);
        let got = match t.finish(p0, &round) {
            Payload::Sum(v) => v,
            _ => unreachable!(),
        };
        let plain = {
            let mut p = Plain.empty(&round);
            for (i, x) in xs.iter().enumerate() {
                Plain.submit(&mut p, i, &enc.encode(i, x, &round), &round);
            }
            match Plain.finish(p, &round) {
                Payload::Sum(v) => v,
                _ => unreachable!(),
            }
        };
        assert_eq!(got, plain);
    }

    #[test]
    fn unicast_reorders_by_client_id() {
        let xs = data();
        let round = SharedRound::new(3, xs.len(), xs[0].len());
        let enc = RoundToInt;
        let t = Unicast;
        let mut p = t.empty(&round);
        for &i in &[2usize, 0, 1] {
            t.submit(&mut p, i, &enc.encode(i, &xs[i], &round), &round);
        }
        match t.finish(p, &round) {
            Payload::PerClient(list) => {
                for (i, (ms, _)) in list.iter().enumerate() {
                    let want = enc.encode(i, &xs[i], &round).ms;
                    assert_eq!(ms, &want, "client {i}");
                }
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn round_cache_hits_same_round_only() {
        let cache: RoundCache<u64> = RoundCache::new();
        let r1 = SharedRound::new(1, 4, 8);
        let r2 = SharedRound::new(2, 4, 8);
        let mut calls = 0;
        let v1 = cache.get_or(&r1, || {
            calls += 1;
            10
        });
        let v1b = cache.get_or(&r1, || {
            calls += 1;
            11
        });
        assert_eq!((*v1, *v1b, calls), (10, 10, 1));
        let v2 = cache.get_or(&r2, || {
            calls += 1;
            20
        });
        assert_eq!((*v2, calls), (20, 2));
        // both rounds stay cached (a session window's rounds coexist)
        let v1c = cache.get_or(&r1, || {
            calls += 1;
            12
        });
        assert_eq!((*v1c, calls), (10, 2));
    }

    #[test]
    fn survivor_set_counts_and_iterates() {
        let s = SurvivorSet::with_dropped(5, &[1, 3]);
        assert_eq!((s.n(), s.n_alive()), (5, 3));
        assert!(!s.is_full());
        assert_eq!(s.alive_iter().collect::<Vec<_>>(), vec![0, 2, 4]);
        assert_eq!(s.dropped_iter().collect::<Vec<_>>(), vec![1, 3]);
        assert!(s.is_alive(0) && !s.is_alive(3));
        assert!(SurvivorSet::full(4).is_full());
        assert!(SurvivorSet::with_dropped(4, &[]).is_full());
    }

    #[test]
    #[should_panic(expected = "announced dropped twice")]
    fn survivor_set_rejects_duplicate_dropout() {
        let _ = SurvivorSet::with_dropped(5, &[2, 2]);
    }

    #[test]
    #[should_panic(expected = "zero survivors")]
    fn survivor_set_rejects_empty_survivors() {
        let _ = SurvivorSet::with_dropped(2, &[0, 1]);
    }

    #[test]
    #[should_panic(expected = "fails closed under dropouts")]
    fn unicast_fails_closed_over_partial_client_set() {
        let xs = data();
        let round = SharedRound::new(3, xs.len(), xs[0].len());
        let t = Unicast;
        let mut p = t.empty(&round);
        t.submit(&mut p, 0, &RoundToInt.encode(0, &xs[0], &round), &round);
        t.submit(&mut p, 1, &RoundToInt.encode(1, &xs[1], &round), &round);
        let _ = t.finish_survivors(p, &round, &SurvivorSet::with_dropped(3, &[2]));
    }

    #[test]
    #[should_panic(expected = "not survivor-aware")]
    fn default_decoder_fails_closed_over_partial_client_set() {
        // a decoder without a decode_survivors override must refuse
        // survivor-only payloads rather than silently mis-averaging
        struct NotAware;
        impl ServerDecoder for NotAware {
            fn sum_decodable(&self) -> bool {
                true
            }
            fn decode(&self, _: &Payload, _: &SharedRound) -> Vec<f64> {
                vec![]
            }
        }
        let round = SharedRound::new(1, 3, 2);
        let payload = Payload::Sum(vec![0, 0]);
        let _ = NotAware.decode_survivors(&payload, &round, &SurvivorSet::with_dropped(3, &[1]));
    }

    #[test]
    fn survivor_set_cohort_composition_with_dropouts() {
        // a sampled cohort composed with a mid-round dropout: the decode
        // set is the difference, fleet size n stays fixed
        let cohort = SurvivorSet::from_alive_mask(vec![true, false, true, true, false]);
        assert_eq!((cohort.n(), cohort.n_alive()), (5, 3));
        let after = cohort.drop_clients(&[2]);
        assert_eq!(after.alive_iter().collect::<Vec<_>>(), vec![0, 3]);
        assert_eq!(after.n(), 5);
        // sampled-out AND dropped clients both iterate as dead
        assert_eq!(after.dropped_iter().collect::<Vec<_>>(), vec![1, 2, 4]);
    }

    #[test]
    #[should_panic(expected = "zero survivors")]
    fn survivor_set_from_empty_mask_fails_closed() {
        let _ = SurvivorSet::from_alive_mask(vec![false, false]);
    }

    #[test]
    #[should_panic(expected = "zero survivors")]
    fn survivor_set_drop_clients_cannot_empty_a_cohort() {
        let cohort = SurvivorSet::from_alive_mask(vec![true, false]);
        let _ = cohort.drop_clients(&[0]);
    }

    #[test]
    fn session_stream_ids_are_pairwise_distinct() {
        // every stream family a session derives under one round seed —
        // per-client, global, aux, dropout completion, subsample rows —
        // must live in pairwise-disjoint regions of the u64 stream space
        let n = 1usize << 12; // far above any simulated fleet
        let mut ids: Vec<u64> = Vec::with_capacity(3 * n + 9);
        for c in 0..n as u64 {
            ids.push(c); // client streams
            ids.push(DROPOUT_NOISE_STREAM ^ c);
            ids.push(SUBSAMPLE_STREAM ^ c);
        }
        ids.push(GLOBAL_STREAM);
        for k in 1..=8u64 {
            ids.push(GLOBAL_STREAM - k); // aux streams
        }
        let len = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), len, "stream-id family collision");
    }

    #[test]
    fn subsample_rows_are_per_client_streams_and_deterministic() {
        let round = SharedRound::new(99, 6, 32);
        let r2 = round.subsample_row(2, 0.5);
        assert_eq!(r2, round.subsample_row(2, 0.5));
        assert_ne!(r2, round.subsample_row(3, 0.5));
        // γ boundaries
        assert!(round.subsample_row(0, 1.0).iter().all(|&b| b));
        assert!(!round.subsample_row(0, 0.0).iter().any(|&b| b));
        // independent of n (a row needs no knowledge of the fleet size)
        let other = SharedRound::new(99, 100, 32);
        assert_eq!(r2, other.subsample_row(2, 0.5));
    }

    #[test]
    fn cohort_secagg_masks_cancel_over_the_cohort() {
        // a cohort-rekeyed SecAgg round must decode the cohort's exact sum
        // (masks pair only among members, so the cohort sum cancels them)
        let xs = data();
        let n = xs.len();
        let round = SharedRound::new(55, n, xs[0].len());
        let cohort = SurvivorSet::with_dropped(n, &[1]); // clients 0 and 2
        let t = SecAgg::new().for_session_round_sampled(77, 0, &cohort);
        let enc = RoundToInt;
        let mut part = t.empty(&round);
        for i in cohort.alive_iter() {
            t.submit(&mut part, i, &enc.encode(i, &xs[i], &round), &round);
        }
        let got = match t.finish_survivors(part, &round, &cohort) {
            Payload::Sum(v) => v,
            _ => unreachable!(),
        };
        let mut want = vec![0i64; xs[0].len()];
        for i in cohort.alive_iter() {
            for (w, &m) in want.iter_mut().zip(&enc.encode(i, &xs[i], &round).ms) {
                *w += m;
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    #[should_panic(expected = "not cohort-aware")]
    fn unicast_fails_closed_on_sampled_session_rounds() {
        let cohort = SurvivorSet::with_dropped(3, &[1]);
        let _ = Unicast.for_session_round_sampled(1, 0, &cohort);
    }

    #[test]
    fn full_cohort_secagg_degenerates_to_unsampled_schedule() {
        // bit-identity anchor: a full cohort must produce the exact same
        // masked submissions as the unsampled session transport
        let xs = data();
        let round = SharedRound::new(7, xs.len(), xs[0].len());
        let full = SurvivorSet::full(xs.len());
        let a = SecAgg::new().for_session_round(42, 1);
        let b = SecAgg::new().for_session_round_sampled(42, 1, &full);
        let enc = RoundToInt;
        let mut pa = a.empty(&round);
        let mut pb = b.empty(&round);
        for (i, x) in xs.iter().enumerate() {
            let msg = enc.encode(i, x, &round);
            a.submit(&mut pa, i, &msg, &round);
            b.submit(&mut pb, i, &msg, &round);
        }
        match (pa, pb) {
            (
                TransportPartial::Masked { sum: Some(va), .. },
                TransportPartial::Masked { sum: Some(vb), .. },
            ) => assert_eq!(va, vb),
            _ => panic!("wrong partial shape"),
        }
    }

    #[test]
    fn dropout_rng_streams_are_client_distinct_and_deterministic() {
        let round = SharedRound::new(77, 4, 8);
        let mut r0 = round.dropout_rng(0);
        let mut r0b = round.dropout_rng(0);
        let mut r1 = round.dropout_rng(1);
        let mut c0 = round.client_rng(0);
        let x = r0.next_u64();
        assert_eq!(x, r0b.next_u64());
        assert_ne!(x, r1.next_u64());
        assert_ne!(x, c0.next_u64());
    }

    #[test]
    fn round_cache_evicts_oldest_past_capacity() {
        let cache: RoundCache<u64> = RoundCache::new();
        for i in 0..=16u64 {
            let _ = cache.get_or(&SharedRound::new(i, 4, 8), || i);
        }
        let mut rebuilt = false;
        // round 0 was evicted (17th insert), round 16 still cached
        let _ = cache.get_or(&SharedRound::new(0, 4, 8), || {
            rebuilt = true;
            0
        });
        assert!(rebuilt);
        let mut rebuilt16 = false;
        let _ = cache.get_or(&SharedRound::new(16, 4, 8), || {
            rebuilt16 = true;
            16
        });
        assert!(!rebuilt16);
    }
}
