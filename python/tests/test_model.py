"""L2 correctness: MLP model graph (shapes, gradients, training signal)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")

D_IN, HIDDEN, CLASSES, BATCH = 8, 16, 2, 32
P = model.param_count(D_IN, HIDDEN, CLASSES)


def _data(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(BATCH, D_IN)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def _params(seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(scale=0.3, size=(P,)).astype(np.float32))


def test_param_count():
    assert P == D_IN * HIDDEN + HIDDEN + HIDDEN * CLASSES + CLASSES


def test_unflatten_roundtrip():
    flat = _params()
    w1, b1, w2, b2 = model.unflatten(flat, D_IN, HIDDEN, CLASSES)
    rebuilt = jnp.concatenate([w1.ravel(), b1, w2.ravel(), b2])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))


def test_grad_shapes_and_loss_positive():
    x, y = _data()
    loss, grad = model.model_grad(
        _params(), x, y, d_in=D_IN, hidden=HIDDEN, classes=CLASSES
    )
    assert grad.shape == (P,)
    assert float(loss) > 0.0
    assert np.all(np.isfinite(np.asarray(grad)))


def test_grad_matches_pure_jnp():
    """Grad through the Pallas matmul == grad of an all-jnp clone."""
    x, y = _data()
    flat = _params()

    def loss_jnp(flat):
        w1, b1, w2, b2 = model.unflatten(flat, D_IN, HIDDEN, CLASSES)
        h = jnp.tanh(x @ w1 + b1)
        logits = h @ w2 + b2
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    _, grad = model.model_grad(
        flat, x, y, d_in=D_IN, hidden=HIDDEN, classes=CLASSES
    )
    grad_ref = jax.grad(loss_jnp)(flat)
    np.testing.assert_allclose(
        np.asarray(grad), np.asarray(grad_ref), rtol=1e-4, atol=1e-5
    )


def test_sgd_reduces_loss():
    """A few full-batch SGD steps on separable data must reduce the loss."""
    x, y = _data()
    flat = _params()
    losses = []
    for _ in range(30):
        loss, grad = model.model_grad(
            flat, x, y, d_in=D_IN, hidden=HIDDEN, classes=CLASSES
        )
        losses.append(float(loss))
        flat = flat - 0.5 * grad
    assert losses[-1] < losses[0] * 0.5


def test_eval_accuracy_improves():
    x, y = _data()
    flat = _params()
    _, acc0 = model.model_eval(
        flat, x, y, d_in=D_IN, hidden=HIDDEN, classes=CLASSES
    )
    for _ in range(40):
        _, grad = model.model_grad(
            flat, x, y, d_in=D_IN, hidden=HIDDEN, classes=CLASSES
        )
        flat = flat - 0.5 * grad
    _, acc1 = model.model_eval(
        flat, x, y, d_in=D_IN, hidden=HIDDEN, classes=CLASSES
    )
    assert float(acc1) >= float(acc0)
    assert float(acc1) > 0.9
