//! Figures 6 and 8: less-trusted-server comparison — DDG (with SecAgg) vs
//! the aggregate Gaussian mechanism (also SecAgg-compatible) vs the shifted
//! layered quantizer: MSE (left panel) and bits/client (right panel)
//! against ε.
//!
//! Protocol (§5.2 + App. C.1): n = 500 (Fig. 6) / n ∈ {100, 500, 1000}
//! (Fig. 8), d = 75, δ = 1e−5, data on the ℓ2 sphere of radius c = 10,
//! 30 runs. DDG at b ∈ {12, 14, 16, 18} bits, calibrated through its zCDP
//! bound; the AINQ mechanisms match the *standard Gaussian mechanism* at
//! (ε, δ) with ℓ2 sensitivity 2c/n and report measured Elias-gamma bits
//! (plus Prop. 2 fixed-length bits for the shifted quantizer).

use super::FigOpts;
use crate::apps::mean_estimation::{evaluate, gen_data, DataKind};
use crate::baselines::Ddg;
use crate::dp::accountant::analytic_gaussian_sigma;
use crate::mechanisms::{AggregateGaussian, IndividualGaussian, LayeredVariant};
use crate::util::json::Csv;

pub struct Fig6Row {
    pub n: usize,
    pub eps: f64,
    pub sigma: f64,
    pub mse_agg: f64,
    pub bits_agg: f64,
    pub mse_shifted: f64,
    pub bits_shifted_fixed: f64,
    pub bits_shifted_var: f64,
    /// (bits, mse) per DDG budget
    pub ddg: Vec<(u32, f64)>,
}

pub fn eval_row(n: usize, d: usize, eps: f64, runs: usize, seed: u64, ddg_bits: &[u32]) -> Fig6Row {
    let delta = 1e-5;
    let c = 10.0;
    // per-coordinate noise matching the Gaussian mechanism on the mean
    let sigma = analytic_gaussian_sigma(eps, delta, 2.0 * c / n as f64);
    let xs = gen_data(DataKind::Sphere { radius: c }, n, d, seed);
    // per-coordinate input bound: |x_ij| <= c (loose; sphere data)
    let t = 2.0 * c;

    let agg = evaluate(&AggregateGaussian::new(sigma, t), &xs, runs, seed ^ 0xA);
    let shifted = evaluate(
        &IndividualGaussian::new(sigma, LayeredVariant::Shifted, t),
        &xs,
        runs,
        seed ^ 0xB,
    );

    let mut ddg = Vec::new();
    for &b in ddg_bits {
        // γ_q is fixed-point tuned inside `calibrated` so the SecAgg sum
        // fits the 2^b modulus with margin
        let mech = Ddg::calibrated(eps, delta, c, n, d, b, 0.1);
        let res = evaluate(&mech, &xs, runs.min(10), seed ^ (b as u64));
        ddg.push((b, res.mse_mean));
    }

    Fig6Row {
        n,
        eps,
        sigma,
        mse_agg: agg.mse_mean,
        bits_agg: agg.bits_var_per_client,
        mse_shifted: shifted.mse_mean,
        bits_shifted_fixed: shifted.bits_fixed_per_client.unwrap_or(f64::NAN),
        bits_shifted_var: shifted.bits_var_per_client,
        ddg,
    }
}

pub fn run(opts: &FigOpts, fig8: bool) {
    let (name, ns): (&str, Vec<usize>) =
        if fig8 { ("8", vec![100, 500, 1000]) } else { ("6", vec![500]) };
    println!("\n== Figure {name}: DDG vs aggregate Gaussian (MSE + bits/client) ==");
    let d = 75;
    let runs = opts.runs_or(30);
    // 4/6 bits exhibit the wraparound/rounding degradation; 12-18 are the
    // paper's sweep (DESIGN.md notes the onset shifts left because our
    // lattice step is auto-tuned per b)
    let ddg_bits: Vec<u32> = if opts.quick { vec![14, 18] } else { vec![4, 6, 12, 14, 16, 18] };
    let eps_grid: Vec<f64> =
        if opts.quick { vec![1.0, 4.0, 10.0] } else { vec![1.0, 2.0, 4.0, 6.0, 8.0, 10.0] };
    let mut csv = Csv::new(&[
        "n", "eps", "sigma", "mse_agg", "bits_agg_per_coord", "mse_shifted",
        "bits_shifted_fixed_per_coord", "bits_shifted_var_per_coord", "ddg_bits", "mse_ddg",
    ]);
    for &n in &ns {
        let n = if opts.quick { n / 5 } else { n };
        println!("-- n = {n}, d = {d} --");
        println!(
            "{:>5} {:>10} {:>11} {:>9} {:>11} {:>9} {:>9}  DDG(b→mse)",
            "eps", "sigma", "mse-agg", "agg b/c", "mse-shift", "sh-fix", "sh-var"
        );
        for &eps in &eps_grid {
            let row = eval_row(n, d, eps, runs, opts.seed, &ddg_bits);
            let ddg_str: String = row
                .ddg
                .iter()
                .map(|(b, m)| format!("b{b}:{m:.3e}"))
                .collect::<Vec<_>>()
                .join(" ");
            println!(
                "{:>5} {:>10.3e} {:>11.4e} {:>9.2} {:>11.4e} {:>9.2} {:>9.2}  {ddg_str}",
                eps,
                row.sigma,
                row.mse_agg,
                row.bits_agg / d as f64,
                row.mse_shifted,
                row.bits_shifted_fixed / d as f64,
                row.bits_shifted_var / d as f64,
            );
            for (b, m) in &row.ddg {
                csv.row_f64(&[
                    n as f64,
                    eps,
                    row.sigma,
                    row.mse_agg,
                    row.bits_agg / d as f64,
                    row.mse_shifted,
                    row.bits_shifted_fixed / d as f64,
                    row.bits_shifted_var / d as f64,
                    *b as f64,
                    *m,
                ]);
            }
        }
    }
    let path = format!("{}/fig{name}.csv", opts.out_dir);
    csv.save(&path).expect("saving csv");
    println!("saved {path}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_gaussian_uses_few_bits() {
        // the Fig. 6 headline: aggregate Gaussian needs ~2.5 bits/coordinate
        // where DDG needs 12-18
        let row = eval_row(100, 75, 4.0, 5, 91, &[]);
        assert!(
            row.bits_agg / 75.0 < 6.0,
            "aggregate Gaussian bits/coord = {}",
            row.bits_agg / 75.0
        );
    }

    #[test]
    fn agg_mse_matches_gaussian_mechanism_floor() {
        // MSE of the exact mechanism = d·σ² + (tiny quantization-free) —
        // the whole point of compression-for-free
        let d = 75;
        let row = eval_row(200, d, 4.0, 20, 92, &[]);
        let want = d as f64 * row.sigma * row.sigma;
        assert!(
            (row.mse_agg - want).abs() < 0.5 * want,
            "mse {} vs σ² floor {want}",
            row.mse_agg
        );
    }

    #[test]
    fn ddg_more_bits_better_mse() {
        // regime where the DP noise floor is low enough that the b=8
        // lattice's rounding error is visible against b=16
        let row = eval_row(500, 32, 10.0, 10, 93, &[8, 16]);
        let m8 = row.ddg[0].1;
        let m16 = row.ddg[1].1;
        assert!(m16 < m8, "b=16 {m16} not better than b=8 {m8}");
    }
}
