//! The packed ℤ_m wire-format property matrix. Two layers of guarantees:
//!
//! 1. **Packed ≡ unpacked is a bit identity on every residue.** A
//!    [`PackedZm`] is a pure re-layout of a u64 residue vector — pack,
//!    unpack, blockwise masked folds and word-level merges must reproduce
//!    the scalar mod-m arithmetic exactly, across moduli
//!    {2⁸, 2¹², 2⁴⁰, non-power-of-two} × lengths {1, 7, 64, d, d + 3}.
//! 2. **The pipeline on the packed path keeps its contracts.** With
//!    `TransportPartial::Masked` carrying packed words, Plain ≡ SecAgg,
//!    chunked ≡ unchunked (dropouts and sampled cohorts included) and the
//!    exact decoded error laws (KS) must all hold verbatim — packing
//!    happens after every RNG draw, so it cannot change any drawn bit
//!    (docs/determinism.md, "Packed words cannot change any drawn bit").
//!
//! The third block cross-checks the *measured* byte accounting: the
//! coordinator's `wire_bytes` counter must equal shards × rounds × the
//! packed per-chunk payload, stay within the BitsAccount message count ×
//! per-message packed payload, and respect the ⌈c·w/64⌉·8 per-slot bound.

use exact_comp::coding::packed::{width_for_modulus, PackedZm};
use exact_comp::coordinator::runtime::{run_rounds_mech_chunked, ClientPool};
use exact_comp::coordinator::sampling::SamplingPolicy;
use exact_comp::dist::{Continuous, Gaussian, IrwinHall};
use exact_comp::mechanisms::pipeline::{Plain, SecAgg, SurvivorSet};
use exact_comp::mechanisms::session::run_window_chunked;
use exact_comp::mechanisms::{AggregateGaussian, IrwinHallMechanism};
use exact_comp::secagg::SecAggParams;
use exact_comp::testing::{assert_chunked_window_matches_unchunked, dropout_schedule, Fleet};
use exact_comp::util::rng::Rng;
use std::sync::Arc;

const MODULI: [u64; 4] = [1 << 8, 1 << 12, 1 << 40, 999_983];

/// The length/chunk axis of the acceptance matrix for a given d.
fn matrix_lens(d: usize) -> Vec<usize> {
    vec![1, 7, 64, d, d + 3]
}

fn seeded_residues(len: usize, modulus: u64, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.below(modulus)).collect()
}

#[test]
fn packed_roundtrip_is_a_bit_identity_across_moduli_and_lengths() {
    let d = 96;
    for modulus in MODULI {
        for len in matrix_lens(d) {
            let residues = seeded_residues(len, modulus, 0xF00 ^ modulus ^ len as u64);
            let packed = PackedZm::from_residues(&residues, modulus);
            assert_eq!(packed.to_residues(), residues, "m={modulus} len={len}");
            for (k, &r) in residues.iter().enumerate() {
                assert_eq!(packed.get(k), r, "m={modulus} len={len} k={k}");
            }
            // byte_len is the single source of truth — ⌈len·w/64⌉·8,
            // never worse than the u64 layout
            let w = width_for_modulus(modulus) as usize;
            assert_eq!(packed.byte_len(), (len * w).div_ceil(64) * 8);
            assert_eq!(packed.byte_len(), PackedZm::byte_len_for(len, modulus));
            assert!(packed.byte_len() <= len * 8);
        }
    }
}

#[test]
fn packed_folds_and_merges_match_scalar_mod_arithmetic() {
    let d = 96;
    for modulus in MODULI {
        for len in matrix_lens(d) {
            let a = seeded_residues(len, modulus, 0xA ^ modulus ^ len as u64);
            let b = seeded_residues(len, modulus, 0xB ^ modulus ^ len as u64);
            let c = seeded_residues(len, modulus, 0xC ^ modulus ^ len as u64);
            let want: Vec<u64> = (0..len)
                .map(|k| {
                    // u128 reference: the packed path must agree even
                    // when a + b + c would overflow u64
                    ((a[k] as u128 + b[k] as u128 + c[k] as u128) % modulus as u128) as u64
                })
                .collect();
            // residue-slice folds (the submit path)
            let mut folded = PackedZm::from_residues(&a, modulus);
            folded.fold_residues(&b);
            folded.fold_residues(&c);
            assert_eq!(folded.to_residues(), want, "fold m={modulus} len={len}");
            // word-level merge (the shard-merge path) lands identically
            let mut merged = PackedZm::from_residues(&a, modulus);
            let mut other = PackedZm::from_residues(&b, modulus);
            other.fold_residues(&c);
            merged.add_assign_mod(&other);
            assert_eq!(merged, folded, "merge m={modulus} len={len}");
        }
    }
}

/// Chunked ≡ unchunked through the packed accumulators, over Plain AND
/// SecAgg at the default 2⁴⁰ modulus, with dropouts and a sampled cohort.
#[test]
fn packed_chunked_matrix_matches_unchunked_with_dropouts_and_sampling() {
    let (n, d) = (7usize, 96usize);
    let fleet = Fleet::new(n, d, 0x9AC7);
    let mech = IrwinHallMechanism::new(0.4, 8.0);
    for (policy, seed) in [
        (SamplingPolicy::Full, 0x9A1u64),
        (SamplingPolicy::FixedSize { k: 5 }, 0x9A2),
    ] {
        let dropouts = schedule_for(&policy, seed, n);
        assert_chunked_window_matches_unchunked(
            &mech, &Plain, &fleet, &policy, &dropouts, seed, &matrix_lens(d),
        );
        assert_chunked_window_matches_unchunked(
            &mech, &SecAgg::new(), &fleet, &policy, &dropouts, seed, &matrix_lens(d),
        );
    }
}

/// The same matrix over a NON-power-of-two modulus: width derivation and
/// the carry-aware packed adds cannot rely on power-of-two wrap.
#[test]
fn packed_chunked_matrix_holds_at_a_non_power_of_two_modulus() {
    let (n, d) = (6usize, 96usize);
    let fleet = Fleet::new(n, d, 0x9AC8);
    let mech = AggregateGaussian::new(0.5, 8.0);
    let transport = SecAgg::with_params(SecAggParams { modulus: (1 << 40) - 3 });
    for (policy, seed) in [
        (SamplingPolicy::Full, 0x9B1u64),
        (SamplingPolicy::FixedSize { k: 4 }, 0x9B2),
    ] {
        let dropouts = schedule_for(&policy, seed, n);
        assert_chunked_window_matches_unchunked(
            &mech, &transport, &fleet, &policy, &dropouts, seed, &matrix_lens(d),
        );
    }
}

/// W=2 dropout schedule valid under the policy: round 0 clean, round 1
/// loses one cohort member.
fn schedule_for(policy: &SamplingPolicy, session_seed: u64, n: usize) -> Vec<Vec<usize>> {
    (0..2u64)
        .map(|r| {
            if r == 1 {
                let cohort = policy.cohort(session_seed, r, n);
                if cohort.n_alive() >= 2 {
                    return vec![cohort.alive_iter().next().unwrap()];
                }
            }
            Vec::new()
        })
        .collect()
}

/// Plain ≡ SecAgg re-proved THROUGH the packed path: same seeds, same
/// dropouts, bit-identical estimates and accounting — at the default and
/// a non-power-of-two modulus.
#[test]
fn packed_plain_equals_secagg_under_dropouts() {
    let (n, d) = (8usize, 33usize);
    for seed in [0xE11u64, 0xE12, 0xE13] {
        let fleet = Fleet::new(n, d, seed);
        let schedule = dropout_schedule(n, 3, n.div_ceil(4), seed ^ 0x9);
        let mech = IrwinHallMechanism::new(0.5, 8.0);
        let datasets: Vec<Vec<Vec<f64>>> = (0..3).map(|r| fleet.round_data(r)).collect();
        let rounds: Vec<(&[Vec<f64>], u64)> = datasets
            .iter()
            .enumerate()
            .map(|(r, xs)| (xs.as_slice(), seed ^ ((r as u64) << 8)))
            .collect();
        let cohorts = vec![SurvivorSet::full(n); 3];
        let plain = run_window_chunked(
            &mech, &Plain, &mech, &rounds, seed, &cohorts, &schedule, 7,
        );
        for modulus in [1u64 << 40, (1 << 40) - 3] {
            let secagg = SecAgg::with_params(SecAggParams { modulus });
            let masked = run_window_chunked(
                &mech, &secagg, &mech, &rounds, seed, &cohorts, &schedule, 7,
            );
            for (p, s) in plain.iter().zip(&masked) {
                assert_eq!(p.estimate, s.estimate, "seed={seed:#x} m={modulus}");
                assert_eq!(p.bits.messages, s.bits.messages);
            }
        }
    }
}

/// KS exactness on the packed SecAgg path: the decoded aggregate-Gaussian
/// survivor error is STILL exactly N(0, (σ·n/n′)²) with packed masked
/// accumulators, decoded chunk by chunk under an announced dropout.
#[test]
fn packed_secagg_gaussian_error_stays_exactly_gaussian() {
    let sigma = 0.5;
    let (n, d) = (6usize, 4usize);
    let fleet = Fleet::new(n, d, 0x9AC0);
    let xs = fleet.round_data(0);
    let dropped = vec![2usize];
    let survivors = SurvivorSet::with_dropped(n, &dropped);
    let smean = fleet.survivor_mean(0, &survivors);
    let mech = AggregateGaussian::new(sigma, 8.0);
    let mut errs = Vec::new();
    for r in 0..800u64 {
        let seed = 130_000 + r;
        let out = run_window_chunked(
            &mech,
            &SecAgg::new(),
            &mech,
            &[(xs.as_slice(), seed)],
            seed,
            &[SurvivorSet::full(n)],
            &[dropped.clone()],
            3,
        );
        for j in 0..d {
            errs.push(out[0].estimate[j] - smean[j]);
        }
    }
    let rescaled_sd = sigma * n as f64 / survivors.n_alive() as f64;
    let g = Gaussian::new(0.0, rescaled_sd);
    let res = exact_comp::util::stats::ks_test(&errs, |e| g.cdf(e));
    assert!(res.p_value > 0.003, "packed exactness violated: p={}", res.p_value);
}

/// Irwin–Hall companion at chunk 1 (every coordinate its own packed slot).
#[test]
fn packed_secagg_irwin_hall_error_stays_exactly_irwin_hall() {
    let sigma = 0.6;
    let (n, d) = (7usize, 4usize);
    let fleet = Fleet::new(n, d, 0x1DF0);
    let xs = fleet.round_data(0);
    let dropped = vec![4usize];
    let survivors = SurvivorSet::with_dropped(n, &dropped);
    let smean = fleet.survivor_mean(0, &survivors);
    let mech = IrwinHallMechanism::new(sigma, 8.0);
    let mut errs = Vec::new();
    for r in 0..800u64 {
        let seed = 210_000 + r;
        let out = run_window_chunked(
            &mech,
            &SecAgg::new(),
            &mech,
            &[(xs.as_slice(), seed)],
            seed,
            &[SurvivorSet::full(n)],
            &[dropped.clone()],
            1,
        );
        for j in 0..d {
            errs.push(out[0].estimate[j] - smean[j]);
        }
    }
    let scale = sigma * n as f64 / survivors.n_alive() as f64;
    let ih = IrwinHall::new(n as u64, 0.0, scale);
    let res = exact_comp::util::stats::ks_test(&errs, |e| ih.cdf(e));
    assert!(res.p_value > 0.003, "packed IH exactness violated: p={}", res.p_value);
}

/// The measured-bytes cross-check (the byte-accounting satellite): the
/// coordinator's `wire_bytes` equals shards × rounds × the packed
/// per-chunk payload exactly, stays within the BitsAccount message count
/// × per-message packed payload (folding only shrinks traffic), and the
/// session peak respects the packed ⌈c·w/64⌉·8 per-slot bound.
#[test]
fn packed_wire_bytes_agree_with_bits_accounting() {
    let (n, d, w, chunk) = (8usize, 40usize, 3usize, 7usize);
    let fleet = Fleet::new(n, d, 0xB17E);
    let pool = ClientPool::spawn_with_threads(n, Arc::new(fleet.compute()), Some(4));
    let mech = IrwinHallMechanism::new(0.4, 8.0);
    let (reports, stats) = run_rounds_mech_chunked(
        &pool,
        &mech,
        Arc::new(SecAgg::new()),
        0,
        w,
        &[],
        0xB17E,
        d,
        chunk,
    );
    let modulus = SecAggParams::default().modulus;
    let n_shards = pool.shard_ranges().len();
    // every shard ships one packed O(c) partial per (round, chunk) under
    // the full cohort: the measured total is exactly shards × W × Σ_k
    // ⌈len_k·w_bits/64⌉·8
    let per_window_per_shard: usize = (0..d.div_ceil(chunk))
        .map(|k| PackedZm::byte_len_for(chunk.min(d - k * chunk), modulus))
        .sum();
    assert_eq!(stats.wire_bytes, n_shards * w * per_window_per_shard);
    // BitsAccount cross-check: each round counts n client messages; a
    // shard partial folds ≥ 1 client messages, so the measured channel
    // bytes are bounded by messages × the per-message packed payload
    for report in &reports {
        assert_eq!(report.output.bits.messages, n as u64);
        let per_message = per_window_per_shard; // one client's full-d packed chunks
        assert!(
            (stats.wire_bytes / w) <= report.output.bits.messages as usize * per_message,
            "round {}: channel bytes {} exceed messages × packed payload {}",
            report.round,
            stats.wire_bytes / w,
            report.output.bits.messages as usize * per_message,
        );
    }
    // the packed per-slot bound, asserted against the true high-water mark
    let slot = PackedZm::byte_len_for(chunk, modulus);
    assert_eq!(slot, (chunk * width_for_modulus(modulus) as usize).div_ceil(64) * 8);
    assert!(
        stats.peak_accumulator_bytes <= 3 * (n_shards + 1) * w * slot,
        "peak {} exceeds the packed O(shards·W·⌈c·w/64⌉·8) budget",
        stats.peak_accumulator_bytes,
    );
}
