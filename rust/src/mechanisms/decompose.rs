//! Algorithms DecomposeUnif + Decompose (Appendix A.2 / A.4): decompose the
//! target noise Q = N(0, 1) into a mixture of shifted & scaled copies of
//! P = IH(n, 0, 1), producing the global shared randomness T = (A, B) of
//! the aggregate Q mechanism (Def. 8): if (A, B) ⊥ Z ~ P then A·Z + B ~ Q.
//!
//! Step 1 (`decompose_unif`): express U(−1/2, 1/2) as a mixture of
//! shifted/scaled copies of the standardized f̃ (P rescaled to support
//! [−1/2, 1/2]). Each loop iteration either stops inside the f̃ layer (with
//! prob 1/f̃(0)) or recurses into a shorter uniform — a.s. terminating
//! geometric recursion.
//!
//! Step 2 (`draw`): split g = λf + (1−λ)ψ with
//! λ = inf_{x>0} g′(x)/f′(x) (n ≥ 3; λ = 0 for n ≤ 2 where IH is not
//! smooth enough), sample a height layer of ψ — an interval (−s, s) — and
//! delegate U(−s, s) to Step 1.

use crate::dist::{Continuous, Gaussian, IrwinHall, Unimodal};
use crate::util::rng::Rng;

/// Mixture sampler for Q = N(0,1), P = IH(n, 0, 1).
#[derive(Clone, Debug)]
pub struct Decomposer {
    pub n: u64,
    f: IrwinHall,
    g: Gaussian,
    /// λ = inf_{x>0} g'(x)/f'(x) (0 for n <= 2)
    pub lambda: f64,
    /// support length L = 2·sup{x : f(x) > 0} = 2√(3n)
    pub support_l: f64,
    /// ψ-layer boundary lookup table: (x_i, h(x_i)) with h = g − λf on a
    /// uniform grid of [0, x_max], x ascending / h nonincreasing (see
    /// [`Decomposer::psi_layer_boundary`]). Built once per n; every draw
    /// reduces its boundary search to one binary search over the table
    /// plus a short in-cell bisection, replacing the per-draw expanding
    /// bracket + 60 full-range bisection iterations that used to dominate
    /// encode at large d.
    psi_table: Vec<(f64, f64)>,
}

/// Grid resolution of the ψ-boundary table.
const PSI_TABLE_POINTS: usize = 2048;

impl Decomposer {
    pub fn new(n: u64) -> Self {
        assert!(n >= 1);
        let f = IrwinHall::standard(n);
        let g = Gaussian::standard();
        let support_l = 2.0 * f.support_half_width();
        let lambda = if n >= 3 { Self::compute_lambda(&f, &g) } else { 0.0 };
        let psi_table = Self::build_psi_table(&f, &g, lambda);
        Self { n, f, g, lambda, support_l, psi_table }
    }

    /// Tabulate h(x) = g(x) − λf(x) on [0, x_max], where x_max is pushed
    /// out until h has decayed to the smallest layer heights a draw can
    /// realize. h is symmetric and nonincreasing on x ≥ 0 by the choice
    /// of λ; residual quadrature wiggle in the IH tail is clamped so the
    /// stored table is monotone by construction (a non-monotone table
    /// would mis-bracket the in-cell bisection).
    fn build_psi_table(f: &IrwinHall, g: &Gaussian, lambda: f64) -> Vec<(f64, f64)> {
        let h = |x: f64| g.pdf(x) - lambda * f.pdf(x);
        let mut x_max = f.support_half_width().max(8.0);
        while h(x_max) > 1e-300 && x_max < 1e6 {
            x_max *= 2.0;
        }
        let mut table = Vec::with_capacity(PSI_TABLE_POINTS + 1);
        let mut floor = f64::INFINITY;
        for i in 0..=PSI_TABLE_POINTS {
            let x = x_max * i as f64 / PSI_TABLE_POINTS as f64;
            floor = h(x).max(0.0).min(floor);
            table.push((x, floor));
        }
        table
    }

    /// λ = inf_{x>0} g'(x)/f'(x) on a dense grid of the interior of supp f,
    /// clamped so that g − λf stays nonnegative at the mode.
    ///
    /// The grid stops where f falls below 1e-7·f(0): beyond that point the
    /// CF-quadrature tail of the IH grid is numerical noise, while the TRUE
    /// f, f' there are vanishingly small compared to g, g' (IH tails are
    /// (c−x)^{n−1}-light), so (g − λf)' ≈ g' < 0 holds for any λ ≤ 1 and
    /// unimodality of ψ is unaffected.
    fn compute_lambda(f: &IrwinHall, g: &Gaussian) -> f64 {
        let c = f.support_half_width();
        let f0 = f.pdf(0.0);
        let mut lam = g.pdf(0.0) / f0;
        let grid = 4000;
        let floor = 1e-7 * f0;
        for i in 1..grid {
            let x = c * i as f64 / grid as f64;
            if f.pdf(x) < floor {
                break; // tail: below the quadrature noise floor
            }
            let fp = f.pdf_deriv(x);
            if fp < -floor / c {
                let gp = -x * g.pdf(x); // N(0,1): g'(x) = -x g(x)
                lam = lam.min(gp / fp);
            }
        }
        lam.max(0.0)
    }

    /// ψ-layer boundary: s = sup{x ≥ 0 : v <= g(x) − λ f(x)} (h = g − λf
    /// is symmetric, nonincreasing on x > 0 by choice of λ). The
    /// precomputed table brackets s between two adjacent grid points in
    /// one binary search; a 40-step bisection inside that ~(x_max/2048)
    /// cell polishes it to ≪ 1e-12 absolute — far below anything the
    /// downstream f64 arithmetic can see — instead of re-bisecting the
    /// whole [0, x_max] range per draw.
    fn psi_layer_boundary(&self, v: f64) -> f64 {
        let h = |x: f64| self.g.pdf(x) - self.lambda * self.f.pdf(x);
        let table = &self.psi_table;
        let last = table[table.len() - 1];
        if v <= last.1 {
            // beyond the table floor (astronomically rare: v below the
            // tabulated tail): legacy expanding bracket
            let mut hi = last.0;
            while h(hi) > v && hi < 1e6 {
                hi *= 2.0;
            }
            return crate::util::interp::bisect_monotone(h, v, last.0, hi, true, 60);
        }
        // first grid point with h < v: s lies in the cell before it
        let idx = table.partition_point(|&(_, hv)| hv >= v);
        if idx == 0 {
            return 0.0; // v ≥ h(0): an empty layer boundary
        }
        let (lo, hi) = (table[idx - 1].0, table[idx].0);
        crate::util::interp::bisect_monotone(h, v, lo, hi, true, 40)
    }

    /// DecomposeUnif (Algorithm 1) on the standardized f̃ supported on
    /// [−1/2, 1/2]: returns (a, b) with a·X̃ + b ~ U(−1/2, 1/2),
    /// X̃ = X / L, X ~ P.
    pub fn decompose_unif(&self, rng: &mut Rng) -> (f64, f64) {
        let l = self.support_l;
        // f̃(x) = L · f(L x); f̃⁻¹(y) = b⁺(y / L) / L
        let f0 = l * self.f.pdf(0.0);
        let mut a = 1.0f64;
        let mut b = 0.0f64;
        for _ in 0..10_000 {
            let u = rng.u01() - 0.5;
            let v = rng.u01();
            let fu = l * self.f.pdf(l * u);
            if v <= fu / f0 {
                return (a, b);
            }
            // recurse into U(s, 1/2) (u > 0) or U(-1/2, -s) (u < 0):
            // centre ± (s + 1/2)/2, width (1/2 − s)
            let s = self.f.b_plus(v * f0 / l) / l;
            b += a * u.signum() * (s + 0.5) / 2.0;
            a *= 0.5 - s;
        }
        // unreachable in practice: termination prob per loop is 1/f̃(0)
        (a, b)
    }

    /// Decompose (Algorithm 2): draw (A, B) with A·Z + B ~ N(0,1), Z ~ P.
    pub fn draw(&self, rng: &mut Rng) -> (f64, f64) {
        let x = self.g.sample(rng);
        let v = self.g.pdf(x) * rng.u01();
        if v > self.g.pdf(x) - self.lambda * self.f.pdf(x) {
            // the λf(x) component: noise is P itself
            return (1.0, 0.0);
        }
        // the (1−λ)ψ component: height-v layer is U(−s, s) = 2s·U(−1/2,1/2)
        let s = self.psi_layer_boundary(v);
        let (a, b) = self.decompose_unif(rng);
        (2.0 * a * s / self.support_l, 2.0 * b * s)
    }

    /// Monte-Carlo estimate of E[−log2 |A|] — the communication overhead
    /// term of Theorem 1 (−h_M(Q‖P) is its infimum over mixtures).
    pub fn expected_neg_log_a(&self, reps: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut acc = 0.0;
        for _ in 0..reps {
            let (a, _) = self.draw(&mut rng);
            acc -= a.abs().log2();
        }
        acc / reps as f64
    }

    /// The Theorem 2 lower bound on h_M(Q‖P):
    /// h_M >= −(1−λ)(L f(0) + log2( e·L·(g(0) − λ f(0)) / (2(1−λ)) )).
    pub fn theorem2_lower_bound(&self) -> f64 {
        let l = self.support_l;
        let f0 = self.f.pdf(0.0);
        let g0 = self.g.pdf(0.0);
        let lam = self.lambda;
        if lam >= 1.0 {
            return 0.0;
        }
        let inner = std::f64::consts::E * l * (g0 - lam * f0) / (2.0 * (1.0 - lam));
        -(1.0 - lam) * (l * f0 + inner.log2())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::ks_test;

    #[test]
    fn lambda_properties() {
        for &n in &[3u64, 5, 20, 100] {
            let d = Decomposer::new(n);
            assert!(d.lambda > 0.0 && d.lambda < 1.0, "n={n} λ={}", d.lambda);
            // ψ = (g − λf)/(1−λ) must be nonnegative on a grid
            let c = d.f.support_half_width();
            for i in 0..200 {
                let x = c * i as f64 / 200.0;
                let h = d.g.pdf(x) - d.lambda * d.f.pdf(x);
                assert!(h >= -1e-10, "n={n} x={x} h={h}");
            }
            // λ grows towards 1 as IH(n) → N(0,1)
        }
        let l3 = Decomposer::new(3).lambda;
        let l100 = Decomposer::new(100).lambda;
        assert!(l100 > l3, "λ(100)={l100} <= λ(3)={l3}");
        assert!(Decomposer::new(2).lambda == 0.0);
    }

    #[test]
    fn decompose_unif_reconstructs_uniform() {
        // a·X̃ + b with X̃ = X/L must be exactly U(−1/2, 1/2)
        for &n in &[3u64, 16] {
            let d = Decomposer::new(n);
            let mut rng = Rng::new(200 + n);
            let mut samples = Vec::with_capacity(6000);
            for _ in 0..6000 {
                let (a, b) = d.decompose_unif(&mut rng);
                let x = d.f.sample(&mut rng) / d.support_l;
                samples.push(a * x + b);
            }
            let res = ks_test(&samples, |x| (x + 0.5).clamp(0.0, 1.0));
            assert!(res.p_value > 0.003, "n={n} p={}", res.p_value);
        }
    }

    #[test]
    fn draw_reconstructs_standard_gaussian() {
        // THE theorem: A·Z + B ~ N(0, 1) — validates the whole §4.4 pipeline
        for &n in &[2u64, 3, 10, 50] {
            let d = Decomposer::new(n);
            let mut rng = Rng::new(300 + n);
            let mut samples = Vec::with_capacity(8000);
            for _ in 0..8000 {
                let (a, b) = d.draw(&mut rng);
                let z = d.f.sample(&mut rng);
                samples.push(a * z + b);
            }
            let res = ks_test(&samples, crate::util::special::norm_cdf);
            assert!(res.p_value > 0.003, "n={n} p={} d={}", res.p_value, res.statistic);
        }
    }

    #[test]
    fn psi_table_boundary_matches_direct_bisection() {
        // the lookup-table fast path must reproduce the full-range
        // bisection it replaced, over the whole realizable height range
        for &n in &[3u64, 8, 64] {
            let d = Decomposer::new(n);
            let h = |x: f64| d.g.pdf(x) - d.lambda * d.f.pdf(x);
            let h0 = h(0.0);
            for i in 1..100 {
                // log-spaced heights from near h(0) down to ~1e-7·h(0) —
                // comfortably above the IH grid's quadrature noise floor,
                // below which a "boundary" is ill-defined for both paths
                let v = h0 * (-(i as f64) * 0.15).exp();
                let fast = d.psi_layer_boundary(v);
                let mut hi = d.f.support_half_width().max(8.0);
                while h(hi) > v && hi < 1e6 {
                    hi *= 2.0;
                }
                let slow = crate::util::interp::bisect_monotone(h, v, 0.0, hi, true, 80);
                assert!(
                    (fast - slow).abs() <= 1e-9 * (1.0 + slow.abs()),
                    "n={n} v={v:e}: fast={fast} slow={slow}"
                );
                // and it really is a boundary: h is above v just inside
                assert!(h((fast - 1e-6).max(0.0)) >= v - 1e-12, "n={n} v={v:e}");
            }
        }
    }

    #[test]
    fn scale_a_never_exceeds_one() {
        // every mixture component shrinks: |A| <= 1
        let d = Decomposer::new(8);
        let mut rng = Rng::new(400);
        for _ in 0..5000 {
            let (a, _) = d.draw(&mut rng);
            assert!(a.abs() <= 1.0 + 1e-12, "a={a}");
            assert!(a != 0.0);
        }
    }

    #[test]
    fn expected_neg_log_a_shrinks_with_n() {
        // as IH(n) → Gaussian, the λ component dominates: A = 1 mostly,
        // so E[−log A] → 0 — this is exactly why aggregate Gaussian gets
        // cheaper with many clients (Fig. 4)
        let small = Decomposer::new(3).expected_neg_log_a(4000, 1);
        let large = Decomposer::new(200).expected_neg_log_a(4000, 2);
        assert!(large < small, "E[-log A]: n=200 {large} >= n=3 {small}");
        assert!(large < 0.5, "n=200 E[-log A]={large}");
    }

    #[test]
    fn theorem2_bound_is_consistent() {
        // -h_M <= E[-log2 |A|] for OUR mixture (Def. 9: h_M is the sup of
        // E[log |A|]), so E[-log|A|] >= -h_M >= -(upper bounds)...
        // concretely: MC E[-log|A|] must be >= -theorem2_lower_bound is the
        // wrong direction; the right check: -thm2_bound is an upper bound
        // on achievable E[-log A] infimum, so our MC must be >= -(h_M upper)
        // = -(h(Q) - h(P)) ... we check the weaker sanity: thm2 <= 0 and
        // finite, and our MC cost is >= -thm2_bound - slack is NOT implied;
        // instead check MC >= 0 and thm2 <= 0.
        for &n in &[3u64, 50] {
            let d = Decomposer::new(n);
            let b = d.theorem2_lower_bound();
            assert!(b <= 1e-9, "n={n} bound={b}");
            assert!(b.is_finite());
            let mc = d.expected_neg_log_a(2000, 3);
            assert!(mc >= -1e-9, "n={n} mc={mc}");
            // the MC cost of our constructive mixture cannot beat the
            // optimal −h_M, which Theorem 2 bounds by −b ... i.e. mc can be
            // at most slightly below −b only if thm2 is loose; sanity: the
            // achievable cost should be within a few bits of the bound.
            assert!(mc <= -b + 4.0, "n={n} mc={mc} -bound={}", -b);
        }
    }
}
