//! Fixed-length coding of quantizer descriptions.
//!
//! When a quantizer has a minimal step size η (Prop. 2: the shifted layered
//! quantizer does; the direct does not), the description support is bounded
//! by |Supp M| <= 2 + t/η for inputs in an interval of length t, so M can be
//! sent with a fixed ⌈log2 |Supp M|⌉ bits — no per-S codebook required.

use super::bitio::{BitReader, BitWriter};

/// Fixed-length code for integers in [lo, hi].
#[derive(Clone, Copy, Debug)]
pub struct FixedCode {
    pub lo: i64,
    pub hi: i64,
}

impl FixedCode {
    pub fn new(lo: i64, hi: i64) -> Self {
        assert!(lo <= hi);
        Self { lo, hi }
    }

    /// Support bound of Prop. 2 for input interval length `t` and minimal
    /// step `eta`: |Supp M| <= 2 + t/eta, centred on 0.
    pub fn from_support_bound(t: f64, eta: f64) -> Self {
        assert!(t > 0.0 && eta > 0.0);
        let supp = 2.0 + t / eta;
        let half = (supp / 2.0).ceil() as i64 + 1;
        Self::new(-half, half)
    }

    pub fn support_size(&self) -> u64 {
        (self.hi - self.lo + 1) as u64
    }

    /// Bits per symbol: ceil(log2 |Supp|).
    pub fn bits(&self) -> usize {
        let s = self.support_size();
        (64 - (s - 1).leading_zeros()) as usize
    }

    pub fn contains(&self, m: i64) -> bool {
        m >= self.lo && m <= self.hi
    }

    pub fn encode(&self, w: &mut BitWriter, m: i64) {
        assert!(self.contains(m), "{m} outside [{}, {}]", self.lo, self.hi);
        w.push_bits((m - self.lo) as u64, self.bits());
    }

    pub fn decode(&self, r: &mut BitReader) -> Option<i64> {
        let v = r.read_bits(self.bits())?;
        let m = self.lo + v as i64;
        if self.contains(m) {
            Some(m)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_formula() {
        assert_eq!(FixedCode::new(0, 0).bits(), 0);
        assert_eq!(FixedCode::new(0, 1).bits(), 1);
        assert_eq!(FixedCode::new(-2, 1).bits(), 2);
        assert_eq!(FixedCode::new(0, 255).bits(), 8);
        assert_eq!(FixedCode::new(0, 256).bits(), 9);
    }

    #[test]
    fn roundtrip() {
        let c = FixedCode::new(-37, 58);
        let mut w = BitWriter::new();
        for m in -37..=58 {
            c.encode(&mut w, m);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for m in -37..=58 {
            assert_eq!(c.decode(&mut r), Some(m));
        }
    }

    #[test]
    fn support_bound_prop2_gaussian() {
        // Prop 2: Gaussian η = 2σ√(ln 4), |Supp M| <= 2 + t/η
        let sigma = 1.0;
        let t = 64.0;
        let eta = 2.0 * sigma * (4.0f64.ln()).sqrt();
        let c = FixedCode::from_support_bound(t, eta);
        assert!(c.support_size() as f64 >= 2.0 + t / eta);
        // and not absurdly larger
        assert!(c.support_size() as f64 <= 8.0 + t / eta);
    }

    #[test]
    #[should_panic]
    fn encode_out_of_range_panics() {
        let c = FixedCode::new(0, 3);
        let mut w = BitWriter::new();
        c.encode(&mut w, 9);
    }
}
