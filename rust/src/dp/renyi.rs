//! Rényi-DP and zero-concentrated-DP curves (Mironov 2017; Bun–Steinke
//! 2016). Used for Table 1's "Rényi DP" column and to calibrate the DDG
//! baseline (Kairouz et al. 2021a express DDG's guarantee in zCDP).

/// RDP of the Gaussian mechanism: ε(α) = α·Δ²/(2σ²).
pub fn rdp_gaussian(alpha: f64, sigma: f64, sensitivity: f64) -> f64 {
    assert!(alpha > 1.0);
    alpha * sensitivity * sensitivity / (2.0 * sigma * sigma)
}

/// RDP → (ε, δ): ε = ε_RDP(α) + ln(1/δ)/(α − 1), optimized over α on a
/// grid (standard conversion, Mironov 2017 Prop. 3).
pub fn rdp_to_eps(delta: f64, rdp: impl Fn(f64) -> f64) -> f64 {
    let mut best = f64::INFINITY;
    let mut alpha = 1.01f64;
    while alpha < 512.0 {
        let eps = rdp(alpha) + (1.0 / delta).ln() / (alpha - 1.0);
        best = best.min(eps);
        alpha *= 1.05;
    }
    best
}

/// zCDP ρ → (ε, δ): ε = ρ + 2√(ρ ln(1/δ)) (Bun–Steinke Lemma 3.6).
pub fn zcdp_to_eps(rho: f64, delta: f64) -> f64 {
    rho + 2.0 * (rho * (1.0 / delta).ln()).sqrt()
}

/// Gaussian mechanism zCDP: ρ = Δ²/(2σ²).
pub fn zcdp_gaussian(sigma: f64, sensitivity: f64) -> f64 {
    sensitivity * sensitivity / (2.0 * sigma * sigma)
}

/// σ such that the Gaussian mechanism's zCDP guarantee converts to
/// (ε, δ)-DP: solve ρ + 2√(ρ L) = ε for ρ (L = ln(1/δ)), then
/// σ = Δ/√(2ρ). Used for DDG calibration.
pub fn zcdp_sigma_for_eps(eps: f64, delta: f64, sensitivity: f64) -> f64 {
    let l = (1.0 / delta).ln();
    // ρ + 2√(ρL) = ε ⇒ (√ρ + √L)² = ε + L ⇒ √ρ = √(ε + L) − √L
    let sr = (eps + l).sqrt() - l.sqrt();
    let rho = sr * sr;
    sensitivity / (2.0 * rho).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rdp_linear_in_alpha() {
        assert!((rdp_gaussian(2.0, 1.0, 1.0) - 1.0).abs() < 1e-12);
        assert!((rdp_gaussian(4.0, 2.0, 1.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rdp_conversion_close_to_analytic() {
        // RDP conversion is looser than analytic but within ~50%
        let sigma = 3.0;
        let delta = 1e-5;
        let eps_rdp = rdp_to_eps(delta, |a| rdp_gaussian(a, sigma, 1.0));
        let eps_exact = crate::dp::accountant::analytic_gaussian_eps(delta, sigma, 1.0);
        assert!(eps_rdp >= eps_exact - 1e-6, "rdp {eps_rdp} < exact {eps_exact}");
        assert!(eps_rdp <= eps_exact * 2.0, "rdp {eps_rdp} way above exact {eps_exact}");
    }

    #[test]
    fn zcdp_roundtrip() {
        let (eps, delta) = (2.0, 1e-5);
        let sigma = zcdp_sigma_for_eps(eps, delta, 1.0);
        let rho = zcdp_gaussian(sigma, 1.0);
        let eps_back = zcdp_to_eps(rho, delta);
        assert!((eps_back - eps).abs() < 1e-9, "{eps_back}");
    }

    #[test]
    fn zcdp_sigma_decreasing_in_eps() {
        let s1 = zcdp_sigma_for_eps(0.5, 1e-5, 1.0);
        let s2 = zcdp_sigma_for_eps(4.0, 1e-5, 1.0);
        assert!(s2 < s1);
    }
}
