//! Coordinator / substrate benchmarks: round loop, SecAgg masking, FWHT,
//! Huffman construction, statistics, and the `kernels/*` scalar-vs-batched
//! series that feed the recorded `BENCH_*.json` perf trajectory.
//!
//! Worker threads are pinned to 4 by default so numbers are comparable
//! across machines; `BENCH_THREADS` overrides the pin and the effective
//! value is recorded in the emitted JSON. A full run writes
//! `BENCH_10.json` at the repo root (the trajectory artifact compared by
//! `scripts/bench_diff.sh`); `BENCH_QUICK=1` smoke runs write to
//! `target/BENCH_quick.json` instead so a quick pass can never overwrite
//! a recorded trajectory point.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use exact_comp::apps::driver::CoordinatorOpts;
use exact_comp::apps::langevin::{qlsd_star_coordinator, GaussianPosterior, LangevinOpts};
use exact_comp::apps::mean_estimation::{evaluate, evaluate_coordinator, gen_data, DataKind};
use exact_comp::apps::smoothing::{drs_coordinator, L1Problem, SmoothingOpts};
use exact_comp::coordinator::deadline::DeadlinePolicy;
use exact_comp::coordinator::runtime::{
    run_round, run_round_mech, run_rounds_encoded_chunked, run_rounds_mech,
    run_rounds_mech_async, run_rounds_mech_chunked, run_rounds_mech_sampled,
    run_rounds_mech_with_dropouts, AsyncRunConfig, ClientPool,
};
use exact_comp::coding::packed::PackedZm;
use exact_comp::coordinator::sampling::SamplingPolicy;
use exact_comp::mechanisms::pipeline::{ClientEncoder, LocalCompute, Plain, SecAgg, SharedRound};
use exact_comp::mechanisms::traits::MeanMechanism;
use exact_comp::mechanisms::{AggregateGaussian, IrwinHallMechanism};
use exact_comp::quantizer::round_half_up;
use exact_comp::secagg::{aggregate_masked, mask_descriptions, pair_seed, SecAggParams};
use exact_comp::transforms::hadamard::{fwht, fwht_threaded, RandomizedRotation};
use exact_comp::util::benchkit::{bench_threads, black_box, Measurement, Suite};
use exact_comp::util::rng::{fill_below_coords, fill_u01_coords, Rng};
use exact_comp::util::stats::ks_test;

/// Bump per PR: the trajectory artifact this bench emits on a full run.
const TRAJECTORY_FILE: &str = "BENCH_10.json";

fn main() {
    let mut s = Suite::from_env();
    let threads = bench_threads(4);

    // round loop: parallel local compute + aggregation. Worker count is
    // pinned (BENCH_THREADS-overridable) so numbers are comparable across
    // machines.
    for n in [8usize, 64] {
        let d = 256;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(threads),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let mut round = 0u64;
        s.bench_elements(&format!("coordinator/round(n={n},d={d})"), Some((n * d) as u64), || {
            round += 1;
            black_box(run_round(&pool, &mech, round, &[], 42));
        });
        // pipeline shape: per-shard encode, O(d) orchestrator folding
        let mut round2 = 0u64;
        s.bench_elements(
            &format!("coordinator/round_encoded(n={n},d={d})"),
            Some((n * d) as u64),
            || {
                round2 += 1;
                black_box(run_round_mech(&pool, &mech, Arc::new(Plain), round2, &[], 42));
            },
        );
        // the aggregate mechanism's encode is dominated by the
        // Decomposer's ψ-layer boundary search — this series is where the
        // per-n lookup table (built once, bracketing every draw to one
        // table cell) shows up against the old full-range bisection
        let agg = AggregateGaussian::new(0.5, 4.0);
        let mut round3 = 0u64;
        s.bench_elements(
            &format!("coordinator/round_encoded_aggregate(n={n},d={d})"),
            Some((n * d) as u64),
            || {
                round3 += 1;
                black_box(run_round_mech(&pool, &agg, Arc::new(Plain), round3, &[], 42));
            },
        );
    }

    // batched multi-round sessions: one SecAgg opening per window of W
    // rounds, shards answer once per window, unmask batched. W=1 is the
    // single-round baseline; larger W shows the amortization.
    {
        let n = 16usize;
        let d = 256usize;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(threads),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        for w in [1usize, 4, 16] {
            let mut start = 0u64;
            s.bench_elements(
                &format!("coordinator/rounds_windowed(n={n},d={d},W={w})"),
                Some((n * d * w) as u64),
                || {
                    let reps = run_rounds_mech(
                        &pool,
                        &mech,
                        Arc::new(SecAgg::new()),
                        start,
                        w,
                        &[],
                        42,
                    );
                    start += w as u64;
                    black_box(reps);
                },
            );
        }

        // dropout-robust windows: same session shape, but every round
        // loses ⌈n/4⌉ announced clients — measures the recovery overhead
        // (share reconstruction + survivor-aware decode) on top of the
        // windowed baseline above. Elements are normalized by SURVIVOR
        // work (n − drops clients actually compute/encode), so the
        // per-element rate is comparable to the no-dropout series.
        for w in [4usize] {
            let drops = n.div_ceil(4);
            let schedule = exact_comp::testing::dropout_schedule(n, w, drops, 0xD20);
            let mut start = 0u64;
            s.bench_elements(
                &format!("coordinator/rounds_windowed_dropout(n={n},d={d},W={w},drop={drops})"),
                Some(((n - drops) * d * w) as u64),
                || {
                    let reps = run_rounds_mech_with_dropouts(
                        &pool,
                        &mech,
                        Arc::new(SecAgg::new()),
                        start,
                        w,
                        &[],
                        42,
                        &schedule,
                    );
                    start += w as u64;
                    black_box(reps);
                },
            );
        }
    }

    // seed-derived client sampling: Poisson(γ) cohorts per round — the
    // shards skip sampled-out clients entirely and the masked session
    // opens over the cohort only, so per-round work scales with γ·n, not
    // n. Elements are normalized by the EXPECTED cohort work (γ·n·d·W),
    // so the per-element rate is comparable to the full-participation
    // windowed series above.
    {
        let n = 16usize;
        let d = 256usize;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(threads),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let w = 4usize;
        for gamma in [0.25f64, 0.5] {
            let policy = SamplingPolicy::Poisson { gamma };
            let none: Vec<Vec<usize>> = vec![Vec::new(); w];
            let mut start = 0u64;
            let elements = (gamma * (n * d * w) as f64) as u64;
            s.bench_elements(
                &format!("coordinator/rounds_sampled(n={n},d={d},W={w},gamma={gamma})"),
                Some(elements.max(1)),
                || {
                    let reps = run_rounds_mech_sampled(
                        &pool,
                        &mech,
                        Arc::new(SecAgg::new()),
                        start,
                        w,
                        &[],
                        42,
                        &policy,
                        &none,
                        None,
                    );
                    start += w as u64;
                    black_box(reps);
                },
            );
        }
    }

    // chunked coordinate-space streaming: the same windowed SecAgg
    // session run over chunk plans c ∈ {64, 1024, d} — wall time plus the
    // session's measured peak accumulator bytes, asserting the O(c)
    // memory model (the whole point of chunking: peak scales with c, not
    // d, while estimates stay bit-identical).
    {
        let n = 16usize;
        let d = 4096usize;
        let w = 4usize;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(threads),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let mut peaks = Vec::new();
        for chunk in [64usize, 1024, d] {
            let mut start = 0u64;
            let mut peak = 0usize;
            s.bench_elements(
                &format!("coordinator/rounds_chunked(n={n},d={d},W={w},c={chunk})"),
                Some((n * d * w) as u64),
                || {
                    let (reps, stats) = run_rounds_mech_chunked(
                        &pool,
                        &mech,
                        Arc::new(SecAgg::new()),
                        start,
                        w,
                        &[],
                        42,
                        d,
                        chunk,
                    );
                    start += w as u64;
                    peak = peak.max(stats.peak_accumulator_bytes);
                    black_box(reps);
                },
            );
            println!(
                "  coordinator/rounds_chunked(c={chunk}): peak accumulator bytes = {peak}"
            );
            peaks.push((chunk, peak));
        }
        // the memory-model acceptance: peak accumulator bytes are O(c) —
        // the c=64 run must stay far below the whole-d run's peak, and
        // within a small constant of (shards + in-flight) · W · c
        let (c_small, small) = peaks[0];
        let (_, whole) = peaks[peaks.len() - 1];
        assert!(
            small * 8 < whole,
            "chunked peak {small} not O(c) vs whole-d peak {whole}"
        );
        let budget = 3 * (threads + 1) * w * c_small * 8;
        assert!(
            small <= budget,
            "chunked peak {small} exceeds O(shards·W·c) budget {budget}"
        );
    }

    // packed ℤ_m wire-format series: the same chunked SecAgg window,
    // recorded as its own trajectory line with the TIGHTENED acceptance —
    // peak accumulator bytes must fit the packed ⌈c·w/64⌉·8 per-slot
    // budget (w = 40 bits at the default 2⁴⁰ modulus, a 64/40 = 1.6×
    // cut vs the u64 layout), and the measured channel traffic
    // (`ChunkStreamStats::wire_bytes`) is printed alongside
    {
        let n = 16usize;
        let d = 4096usize;
        let w = 4usize;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(threads),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let modulus = SecAggParams::default().modulus;
        for chunk in [64usize, 1024] {
            let mut start = 0u64;
            let mut peak = 0usize;
            let mut wire = 0usize;
            s.bench_elements(
                &format!("coordinator/rounds_chunked_packed(n={n},d={d},W={w},c={chunk})"),
                Some((n * d * w) as u64),
                || {
                    let (reps, stats) = run_rounds_mech_chunked(
                        &pool,
                        &mech,
                        Arc::new(SecAgg::new()),
                        start,
                        w,
                        &[],
                        42,
                        d,
                        chunk,
                    );
                    start += w as u64;
                    peak = peak.max(stats.peak_accumulator_bytes);
                    wire = stats.wire_bytes;
                    black_box(reps);
                },
            );
            let slot = PackedZm::byte_len_for(chunk, modulus);
            assert!(
                slot <= chunk * 8,
                "packed slot {slot} not below the u64 slot at c = {chunk}"
            );
            let packed_budget = 3 * (threads + 1) * w * slot;
            assert!(
                peak <= packed_budget,
                "packed chunked peak {peak} exceeds O(shards·W·⌈c·w/64⌉·8) budget \
                 {packed_budget}"
            );
            println!(
                "  coordinator/rounds_chunked_packed(c={chunk}): peak = {peak} \
                 (packed budget {packed_budget}), wire bytes/window = {wire}"
            );
        }
    }

    // event-driven work-stealing coordinator (no chunk barrier): the
    // headline series is a million-client Plain round — the fleet scale
    // the barrier runners cannot reach in a bench budget — recording wall
    // time plus the session's peak accumulator bytes, asserting the
    // O(ring·W·c) memory model (live accumulators are bounded by the
    // admission ring, never O(d) and never O(n)). Plain because SecAgg's
    // O(n) pairwise masks per client are quadratic in fleet size; the
    // SecAgg async series below stays at n = 256 for exactly that reason.
    {
        let n = if Suite::quick_mode() { 20_000usize } else { 1_000_000 };
        let d = 8usize;
        let w = 1usize;
        let chunk = 2usize;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(threads),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let cfg = AsyncRunConfig::new(d, chunk);
        let mut start = 0u64;
        let mut peak = 0usize;
        s.bench_elements(
            &format!("coordinator/rounds_async(n={n},d={d},W={w},c={chunk})"),
            Some((n * d * w) as u64),
            || {
                let (reps, stats) = run_rounds_mech_async(
                    &pool,
                    &mech,
                    Arc::new(Plain),
                    start,
                    w,
                    &[],
                    42,
                    &cfg,
                );
                start += w as u64;
                peak = peak.max(stats.peak_accumulator_bytes);
                black_box(reps);
            },
        );
        println!("  coordinator/rounds_async(n={n}): peak accumulator bytes = {peak}");
        // ring waves of W rounds' O(c) accumulators, with fold slack —
        // the same budget the runtime's unit acceptance asserts
        let budget = 3 * (cfg.ring + 1) * w * chunk * 8;
        assert!(
            peak <= budget,
            "async peak {peak} exceeds O(ring·W·c) budget {budget} at n = {n}"
        );
    }

    // async over SecAgg at windowed-series scale (n = 256: pairwise masks
    // are O(n) per client, so fleet size is deliberately modest) — the
    // apples-to-apples line against coordinator/rounds_windowed
    {
        let n = 256usize;
        let d = 256usize;
        let w = 4usize;
        let chunk = 64usize;
        let pool = ClientPool::spawn_with_threads(
            n,
            Arc::new(move |c: usize, r: u64, _s: &[f64]| {
                let mut rng = Rng::derive(r, c as u64);
                (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect::<Vec<f64>>()
            }),
            Some(threads),
        );
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let cfg = AsyncRunConfig::new(d, chunk);
        let mut start = 0u64;
        s.bench_elements(
            &format!("coordinator/rounds_async_secagg(n={n},d={d},W={w},c={chunk})"),
            Some((n * d * w) as u64),
            || {
                let (reps, _) = run_rounds_mech_async(
                    &pool,
                    &mech,
                    Arc::new(SecAgg::new()),
                    start,
                    w,
                    &[],
                    42,
                    &cfg,
                );
                start += w as u64;
                black_box(reps);
            },
        );

        // straggler deadlines on: a tiny conversion rate measures the
        // deadline bookkeeping + Bonawitz recovery overhead riding the
        // async path (conversions are drawn up front on the virtual
        // clock, so the rate is exact and replayable)
        let deadline_cfg = AsyncRunConfig::new(d, chunk)
            .with_deadline(DeadlinePolicy::with_deadline(4.0, 0.05, 1.0));
        let mut start = 0u64;
        let mut converted = 0usize;
        s.bench_elements(
            &format!("coordinator/rounds_async_deadline(n={n},d={d},W={w},c={chunk})"),
            Some((n * d * w) as u64),
            || {
                let (reps, stats) = run_rounds_mech_async(
                    &pool,
                    &mech,
                    Arc::new(SecAgg::new()),
                    start,
                    w,
                    &[],
                    42,
                    &deadline_cfg,
                );
                start += w as u64;
                converted += stats.converted_stragglers;
                black_box(reps);
            },
        );
        println!("  coordinator/rounds_async_deadline: {converted} stragglers converted");

        // packed variant of the async SecAgg line: same shape, tightened
        // packed per-slot acceptance + measured wire traffic
        let mut start = 0u64;
        let mut peak = 0usize;
        let mut wire = 0usize;
        s.bench_elements(
            &format!("coordinator/rounds_async_secagg_packed(n={n},d={d},W={w},c={chunk})"),
            Some((n * d * w) as u64),
            || {
                let (reps, stats) = run_rounds_mech_async(
                    &pool,
                    &mech,
                    Arc::new(SecAgg::new()),
                    start,
                    w,
                    &[],
                    42,
                    &cfg,
                );
                start += w as u64;
                peak = peak.max(stats.peak_accumulator_bytes);
                wire = stats.wire_bytes;
                black_box(reps);
            },
        );
        let slot = PackedZm::byte_len_for(chunk, SecAggParams::default().modulus);
        let packed_budget = 3 * (cfg.ring + 1) * w * slot;
        assert!(
            peak <= packed_budget,
            "packed async peak {peak} exceeds O(ring·W·⌈c·w/64⌉·8) budget {packed_budget}"
        );
        println!(
            "  coordinator/rounds_async_secagg_packed: peak = {peak} (packed budget \
             {packed_budget}), wire bytes/window = {wire}"
        );
    }

    // SecAgg masking
    {
        let params = SecAggParams::default();
        let ms: Vec<i64> = (0..512).map(|i| (i % 13) as i64 - 6).collect();
        s.bench_elements("secagg/mask(d=512,n=16)", Some(512), || {
            black_box(mask_descriptions(&ms, 3, 16, 7, params));
        });
        let masked: Vec<Vec<u64>> =
            (0..16).map(|i| mask_descriptions(&ms, i, 16, 7, params)).collect();
        s.bench_elements("secagg/aggregate(d=512,n=16)", Some(512 * 16), || {
            black_box(aggregate_masked(&masked, params));
        });
    }

    // FWHT + rotation
    {
        let mut rng = Rng::new(1);
        let mut v: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        s.bench_elements("transforms/fwht(4096)", Some(4096), || {
            fwht(black_box(&mut v));
        });
        let rot = RandomizedRotation::new(4096, 5);
        let x: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        s.bench_elements("transforms/rotation_fwd(4096)", Some(4096), || {
            black_box(rot.forward(&x));
        });
    }

    // Huffman build from an empirical description table
    {
        let mut counts = std::collections::HashMap::new();
        for m in -40i64..=40 {
            counts.insert(m, (1000.0 * (-0.15 * (m.abs() as f64)).exp()) as u64 + 1);
        }
        s.bench("coding/huffman_build(81 symbols)", || {
            black_box(exact_comp::coding::huffman::Huffman::from_counts(&counts));
        });
    }

    // KS test (the AINQ verifier)
    {
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        s.bench_elements("stats/ks_test(4000)", Some(4000), || {
            black_box(ks_test(&xs, exact_comp::util::special::norm_cdf));
        });
    }

    // lane-batched kernel series: scalar-vs-batched pairs so the speedup
    // is itself a recorded trajectory number. The scalar baselines
    // replicate what the library did before lane batching — a fresh
    // xoshiro generator derived per coordinate for a single draw.
    {
        let d = 1usize << 16;
        let m = SecAggParams::default().modulus;
        let fam = Rng::derive_domain(0xBE, exact_comp::util::rng::seed_domain::COORD_FAMILY, 1);
        let ps = pair_seed(fam, 0, 1);

        // mask expansion: the SecAgg pair-leg kernel (one below(m) per
        // coordinate) — the acceptance pair for the ≥4× batched speedup
        // every kernels/* series carries bytes-per-iteration (d f64/u64
        // lanes × 8) and its core count, so the trajectory's normalized
        // bytes/sec/core line is machine- and thread-count-comparable
        let dbytes = Some((d * 8) as u64);
        let mut masks = vec![0u64; d];
        s.bench_throughput(&format!("kernels/mask_expand_scalar(d={d})"), Some(d as u64), dbytes, 1, || {
            for (j, o) in masks.iter_mut().enumerate() {
                *o = Rng::derive_coord(black_box(ps), j as u64).below(m);
            }
            black_box(&masks);
        });
        let scalar_mask = s.results.last().unwrap().throughput_mps();
        s.bench_throughput(&format!("kernels/mask_expand_batched(d={d})"), Some(d as u64), dbytes, 1, || {
            fill_below_coords(black_box(ps), 0, m, &mut masks);
            black_box(&masks);
        });
        let batched_mask = s.results.last().unwrap().throughput_mps();
        if let (Some(a), Some(b)) = (scalar_mask, batched_mask) {
            println!("  kernels/mask_expand batched-vs-scalar speedup: {:.2}x", b / a);
        }

        // dither fill: one u01 per coordinate stream (the IH/aggregate
        // encode and survivor-decode kernel)
        let mut dithers = vec![0.0f64; d];
        s.bench_throughput(&format!("kernels/dither_fill_scalar(d={d})"), Some(d as u64), dbytes, 1, || {
            for (j, o) in dithers.iter_mut().enumerate() {
                *o = Rng::derive_coord(black_box(fam), j as u64).u01();
            }
            black_box(&dithers);
        });
        s.bench_throughput(&format!("kernels/dither_fill_batched(d={d})"), Some(d as u64), dbytes, 1, || {
            fill_u01_coords(black_box(fam), 0, &mut dithers);
            black_box(&dithers);
        });

        // FWHT: blocked serial vs top-levels-threaded
        let mut rng = Rng::new(9);
        let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        s.bench_throughput(&format!("kernels/fwht(d={d})"), Some(d as u64), dbytes, 1, || {
            fwht(black_box(&mut v));
        });
        s.bench_throughput(
            &format!("kernels/fwht_threaded(d={d},threads={threads})"),
            Some(d as u64),
            dbytes,
            threads,
            || {
                fwht_threaded(black_box(&mut v), threads);
            },
        );

        // quantizer encode (Irwin–Hall layer): the full dither + scale +
        // round-half-up description loop, scalar reference vs the
        // lane-batched library path
        let n = 16usize;
        let round = SharedRound::new(7, n, d);
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let w = mech.step(n);
        let x: Vec<f64> = (0..d).map(|j| ((j % 97) as f64 - 48.0) / 24.0).collect();
        s.bench_throughput(&format!("kernels/quant_encode_scalar(d={d})"), Some(d as u64), dbytes, 1, || {
            let dither = round.client_coord_stream(3);
            let ms: Vec<i64> =
                (0..d).map(|j| round_half_up(x[j] / w + dither.at(j).u01())).collect();
            black_box(ms);
        });
        s.bench_throughput(&format!("kernels/quant_encode_batched(d={d})"), Some(d as u64), dbytes, 1, || {
            black_box(mech.encode(3, &x, &round));
        });

        // ℤ_m pack/unpack: the packed wire-format kernel. Scalar baseline
        // is a BitWriter/BitReader stream (one push_bits/read_bits per
        // residue, bit-cursor bookkeeping per call); the lane path is
        // PackedZm's word-streaming block kernels over the same residues
        let wbits = exact_comp::coding::packed::width_for_modulus(m) as usize;
        let mut residues = vec![0u64; d];
        fill_below_coords(ps, 0, m, &mut residues);
        let mut scratch = vec![0u64; d];
        s.bench_throughput(&format!("kernels/pack_unpack_scalar(d={d})"), Some(d as u64), dbytes, 1, || {
            let mut bw = exact_comp::coding::BitWriter::new();
            for &r in black_box(&residues).iter() {
                bw.push_bits(r, wbits);
            }
            let bytes = bw.into_bytes();
            let mut br = exact_comp::coding::BitReader::new(&bytes);
            for o in scratch.iter_mut() {
                *o = br.read_bits(wbits).expect("short packed stream");
            }
            black_box(&scratch);
        });
        let scalar_pack = s.results.last().unwrap().throughput_mps();
        s.bench_throughput(&format!("kernels/pack_unpack_lane(d={d})"), Some(d as u64), dbytes, 1, || {
            let packed = PackedZm::from_residues(black_box(&residues), m);
            packed.unpack_into(&mut scratch);
            black_box(&scratch);
        });
        let lane_pack = s.results.last().unwrap().throughput_mps();
        assert_eq!(scratch, residues, "pack/unpack is not a bit identity");
        if let (Some(a), Some(b)) = (scalar_pack, lane_pack) {
            println!("  kernels/pack_unpack lane-vs-scalar speedup: {:.2}x", b / a);
        }
    }

    // apps-on-the-coordinator series: the paper's workloads end-to-end
    // through the chunk-streamed runner (pool spawn + windowed sessions +
    // decode included — these are whole-app numbers, not kernel numbers)
    {
        let n = 32usize;
        let d = 256usize;
        let runs = 4usize;
        let xs = gen_data(DataKind::BoxUniform { c: 2.0 }, n, d, 0xA9);
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let bytes = Some((runs * n * d * 8) as u64);
        s.bench_throughput(
            &format!("apps/mean_eval_monolith(n={n},d={d},runs={runs})"),
            Some((runs * n * d) as u64),
            bytes,
            1,
            || {
                black_box(evaluate(&mech, &xs, runs, 0xE0));
            },
        );
        s.bench_throughput(
            &format!("apps/mean_eval_coordinator(n={n},d={d},runs={runs},c=64)"),
            Some((runs * n * d) as u64),
            bytes,
            threads,
            || {
                black_box(evaluate_coordinator(
                    &mech,
                    &xs,
                    runs,
                    0xE0,
                    CoordinatorOpts {
                        chunk: 64,
                        threads: Some(threads),
                        ..CoordinatorOpts::default()
                    },
                ));
            },
        );

        let posterior = GaussianPosterior::generate(8, 64, 10, 0xA10);
        let lopts = LangevinOpts {
            gamma: 5e-4,
            iters: 20,
            burn_in: 10,
            seed: 0xA11,
            discount_compression_noise: true,
        };
        let agg = AggregateGaussian::new(1e-3, 4.0);
        s.bench_throughput(
            "apps/qlsd_coordinator(n=8,d=64,iters=20,c=16)",
            Some((20 * 8 * 64) as u64),
            Some((20 * 8 * 64 * 8) as u64),
            threads,
            || {
                black_box(qlsd_star_coordinator(
                    &posterior,
                    &agg,
                    lopts,
                    CoordinatorOpts {
                        chunk: 16,
                        threads: Some(threads),
                        ..CoordinatorOpts::default()
                    },
                ));
            },
        );

        let l1 = L1Problem::generate(60, 10, 6, 0xA12);
        let sopts = SmoothingOpts { iters: 20, lr: 0.25, sigma: 0.05, m_samples: 2, seed: 0xA13 };
        s.bench_throughput(
            "apps/drs_coordinator(n=6,d=10,iters=20)",
            Some((20 * 2 * 6 * 10) as u64),
            Some((20 * 2 * 6 * 10 * 8) as u64),
            threads,
            || {
                black_box(drs_coordinator(
                    &l1,
                    &agg,
                    sopts,
                    CoordinatorOpts { threads: Some(threads), ..CoordinatorOpts::default() },
                ));
            },
        );
    }

    // model-scale streamed-compute demo at FedSZ scale: a d = 10⁷ model
    // (full runs; 2¹⁶ for the BENCH_QUICK smoke) over an n = 10⁴ fleet
    // with a FixedSize seed-sampled cohort, every client producing its
    // vector per coordinate range, the uplink under EXPLICIT SecAgg so
    // the accumulators ride the packed ℤ_m wire format. Invariants
    // asserted hot:
    //   1. no whole-d client vector is ever materialized (the compute's
    //      local_update panics, and the max range seen stays ≤ c);
    //   2. the packed accumulator high-water mark stays within the
    //      O(shards·W·⌈c·w/64⌉·8) budget — the orchestrator never holds
    //      O(d) residues, let alone O(n·d), and each live slot is packed.
    {
        let full = !Suite::quick_mode();
        let d = if full { 10_000_000usize } else { 1usize << 16 };
        let n = if full { 10_000usize } else { 1_000 };
        let k = if full { 64usize } else { 16 };
        let chunk = 4096usize.min(d);
        let w = 1usize;

        struct BigModelCompute {
            dim: usize,
            max_range: AtomicUsize,
        }
        impl LocalCompute for BigModelCompute {
            fn local_update(&self, _client: usize, _round: u64, _state: &[f64]) -> Vec<f64> {
                panic!("model-scale demo: a whole-d client vector was materialized");
            }
            fn compute_chunk(
                &self,
                client: usize,
                _round: u64,
                _state: &[f64],
                range: std::ops::Range<usize>,
                out: &mut [f64],
            ) {
                self.max_range.fetch_max(range.len(), Ordering::Relaxed);
                for (o, j) in out.iter_mut().zip(range) {
                    *o = ((client * 31 + j) % 255) as f64 / 64.0 - 2.0;
                }
            }
            fn dim_hint(&self, _state: &[f64]) -> usize {
                self.dim
            }
            fn streams_chunks(&self) -> bool {
                true
            }
        }

        let compute = Arc::new(BigModelCompute { dim: d, max_range: AtomicUsize::new(0) });
        let pool = ClientPool::spawn_with_threads(n, compute.clone(), Some(threads));
        let mech = IrwinHallMechanism::new(0.5, 4.0);
        let parts = mech.pipeline_parts().expect("IH exposes pipeline parts");
        let policy = SamplingPolicy::FixedSize { k };
        let none: Vec<Vec<usize>> = vec![Vec::new(); w];
        let t0 = Instant::now();
        let (reps, stats) = run_rounds_encoded_chunked(
            &pool,
            parts.encoder.clone(),
            Arc::new(SecAgg::new()),
            parts.decoder.as_ref(),
            0,
            w,
            &[],
            0xB16,
            &policy,
            &none,
            None,
            d,
            chunk,
        );
        let elapsed_ns = t0.elapsed().as_nanos() as f64;
        assert_eq!(reps.len(), w);
        assert_eq!(reps[0].cohort, k, "FixedSize cohort size");
        assert_eq!(reps[0].output.estimate.len(), d);
        let max_range = compute.max_range.load(Ordering::Relaxed);
        assert!(
            max_range <= chunk,
            "streamed compute saw a {max_range}-wide range (> c = {chunk})"
        );
        // packed high-water mark: every live slot is a packed ℤ_m chunk,
        // so the budget is the packed per-slot size, not c·8
        let slot = PackedZm::byte_len_for(chunk, SecAggParams::default().modulus);
        assert!(slot <= chunk * 8, "packed slot {slot} not below the u64 slot");
        let budget = 3 * (threads + 1) * w * slot;
        assert!(
            stats.peak_accumulator_bytes <= budget,
            "model-scale peak {} exceeds O(shards·W·⌈c·w/64⌉·8) budget {budget} at d = {d}",
            stats.peak_accumulator_bytes
        );
        println!(
            "  apps/model_scale_streamed(n={n},d={d},k={k},c={chunk}): {:.2}s, \
             peak accumulator bytes = {} (packed budget {budget}), wire bytes = {}, \
             max range = {max_range}",
            elapsed_ns / 1e9,
            stats.peak_accumulator_bytes,
            stats.wire_bytes
        );
        // one-shot measurement: too heavy to loop, still worth a
        // trajectory point (mean = the single run)
        s.results.push(Measurement {
            name: format!("apps/model_scale_streamed(n={n},d={d},k={k},c={chunk})"),
            iters: 1,
            mean_ns: elapsed_ns,
            p50_ns: elapsed_ns,
            p95_ns: elapsed_ns,
            elements: Some((k * d) as u64),
            bytes: Some((k * d * 8) as u64),
            cores: threads,
        });
        black_box(reps);
    }

    s.report();

    // trajectory emission: full runs record the artifact at the repo
    // root; BENCH_QUICK smoke runs write under target/ so they can never
    // overwrite a recorded trajectory point
    let path = if Suite::quick_mode() {
        std::fs::create_dir_all("target").ok();
        "target/BENCH_quick.json".to_string()
    } else {
        TRAJECTORY_FILE.to_string()
    };
    s.write_json(&path, "bench_coordinator", threads)
        .unwrap_or_else(|e| panic!("failed to write {path}: {e}"));
    println!("wrote {path}");
}
